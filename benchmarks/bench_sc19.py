"""Fig. 7: BMQSIM (per-stage compression) vs SC19-Sim (per-gate) —
simulation time and compression-operation counts."""
from .common import emit, fidelity_vs_dense, run_engine


def main():
    for name in ("qft", "ising"):
        qc, st_b, stats_b, t_b = run_engine(name, 12, local_bits=6)
        _, st_s, stats_s, t_s = run_engine(name, 12, local_bits=6,
                                           per_gate=True)
        emit("sc19", f"{name}_bmqsim_s", t_b)
        emit("sc19", f"{name}_sc19_s", t_s)
        emit("sc19", f"{name}_speedup", t_s / t_b)
        emit("sc19", f"{name}_stages_bmqsim", stats_b.n_stages)
        emit("sc19", f"{name}_stages_sc19", stats_s.n_stages)
        emit("sc19", f"{name}_fid_bmqsim", fidelity_vs_dense(qc, st_b))
        emit("sc19", f"{name}_fid_sc19", fidelity_vs_dense(qc, st_s))


if __name__ == "__main__":
    main()
