"""Fig. 14: circuit-partition time as a share of end-to-end time."""
from .common import ALL_CIRCUITS, emit, run_engine


def main():
    for name in ALL_CIRCUITS:
        _, _, stats, t = run_engine(name, 12, local_bits=6)
        emit("partition", f"{name}_partition_pct",
             100.0 * stats.t_partition / max(t, 1e-9))


if __name__ == "__main__":
    main()
