"""Benchmark driver: one module per paper table/figure (DESIGN.md §6).

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--skip-slow]

Emits ``bench,key,value`` CSV on stdout; EXPERIMENTS.md archives a run.
"""
import argparse
import sys
import time

from . import (bench_fidelity, bench_max_qubits, bench_memory,
               bench_multidev, bench_overhead, bench_partition,
               bench_pipeline, bench_sc19, bench_sim_time, bench_tuning)

BENCHES = {
    "max_qubits": bench_max_qubits.main,     # Table 2
    "sc19": bench_sc19.main,                 # Fig. 7
    "fidelity": bench_fidelity.main,         # Fig. 8
    "memory": bench_memory.main,             # Fig. 9
    "sim_time": bench_sim_time.main,         # Fig. 10
    "overhead": bench_overhead.main,         # Fig. 11
    "pipeline": bench_pipeline.main,         # Fig. 12
    "multidev": bench_multidev.main,         # Fig. 13
    "partition": bench_partition.main,       # Fig. 14
    "tuning": bench_tuning.main,             # Fig. 15
}
SLOW = {"multidev"}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--skip-slow", action="store_true")
    args = ap.parse_args(argv)
    names = [args.only] if args.only else list(BENCHES)
    print("bench,key,value")
    for name in names:
        if args.skip_slow and name in SLOW:
            continue
        t0 = time.time()
        BENCHES[name]()
        print(f"{name},elapsed_s,{time.time()-t0:.1f}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
