"""Benchmark driver: one module per paper table/figure (DESIGN.md §6).

    PYTHONPATH=src python -m benchmarks.run [--only NAME] [--skip-slow] \
        [--json BENCH_out.json]

Emits ``bench,key,value`` CSV on stdout; ``--json`` additionally writes a
machine-readable dump (per-bench rows + wall time) so the perf trajectory
— stage-compute times, boundary bytes, transpose counts — diffs cleanly
across PRs.  EXPERIMENTS.md archives a run.
"""
import argparse
import json
import sys
import time

from . import (bench_fidelity, bench_max_qubits, bench_memory,
               bench_multidev, bench_overhead, bench_partition,
               bench_pipeline, bench_sc19, bench_serve, bench_session,
               bench_sim_time, bench_tuning)
from .common import drain_rows

BENCHES = {
    "max_qubits": bench_max_qubits.main,     # Table 2
    "sc19": bench_sc19.main,                 # Fig. 7
    "fidelity": bench_fidelity.main,         # Fig. 8
    "memory": bench_memory.main,             # Fig. 9
    "sim_time": bench_sim_time.main,         # Fig. 10
    "overhead": bench_overhead.main,         # Fig. 11
    "pipeline": bench_pipeline.main,         # Fig. 12 + stage compute
    "multidev": bench_multidev.main,         # Fig. 13
    "partition": bench_partition.main,       # Fig. 14
    "tuning": bench_tuning.main,             # Fig. 15
    "session": bench_session.main,           # Simulator API reuse/readout
    "serve": bench_serve.main,               # service tier cold/warm + merge
}
SLOW = {"multidev"}


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--skip-slow", action="store_true")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write a machine-readable JSON dump "
                         "(convention: BENCH_<date>.json)")
    args = ap.parse_args(argv)
    names = [args.only] if args.only else list(BENCHES)
    print("bench,key,value")
    report: dict = {"benches": {}, "unix_time": time.time()}
    drain_rows()                     # discard rows from stray imports
    for name in names:
        if args.skip_slow and name in SLOW:
            continue
        t0 = time.time()
        BENCHES[name]()
        elapsed = time.time() - t0
        print(f"{name},elapsed_s,{elapsed:.1f}", flush=True)
        entry: dict = {"elapsed_s": elapsed, "metrics": {}}
        for bench, key, value in drain_rows():
            entry["metrics"].setdefault(bench, {})[key] = value
        report["benches"][name] = entry
    if args.json:
        with open(args.json, "w") as fh:
            json.dump(report, fh, indent=2, sort_keys=True)
        print(f"# wrote {args.json}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
