"""Fig. 12 + §4.3 boundary traffic: pipeline depth sweep and codec-backend
comparison (host vs device-resident lossy codec).

Emits, per backend, the host↔device bytes moved per stage — the quantity
the device codec shrinks by shipping packed codes + sign bitmaps instead
of raw complex64 group arrays.
"""
from .common import emit, run_engine


def main():
    base = None
    for depth in (1, 2, 4, 8):
        _, _, _, t = run_engine("qft", 14, local_bits=7,
                                pipeline_depth=depth)
        base = base or t
        emit("pipeline", f"depth_{depth}_s", t)
        emit("pipeline", f"depth_{depth}_speedup", base / t)

    # codec backend: boundary bytes per stage, host vs device
    stats_by_backend = {}
    for backend in ("host", "device"):
        _, _, stats, t = run_engine("qft", 14, local_bits=7,
                                    codec_backend=backend)
        stats_by_backend[backend] = stats
        emit("pipeline", f"backend_{backend}_s", t)
        emit("pipeline", f"backend_{backend}_h2d_bytes", stats.h2d_bytes)
        emit("pipeline", f"backend_{backend}_d2h_bytes", stats.d2h_bytes)
        emit("pipeline", f"backend_{backend}_h2d_bytes_per_stage",
             stats.h2d_bytes / max(1, stats.n_stages))
        emit("pipeline", f"backend_{backend}_d2h_bytes_per_stage",
             stats.d2h_bytes / max(1, stats.n_stages))
        for i, (h2d, d2h) in enumerate(stats.per_stage_boundary_bytes):
            emit("pipeline", f"backend_{backend}_stage{i}_h2d_bytes", h2d)
            emit("pipeline", f"backend_{backend}_stage{i}_d2h_bytes", d2h)
    host, dev = stats_by_backend["host"], stats_by_backend["device"]
    emit("pipeline", "device_boundary_reduction",
         host.boundary_bytes / max(1, dev.boundary_bytes))


if __name__ == "__main__":
    main()
