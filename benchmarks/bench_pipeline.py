"""Fig. 12 + §4.3 boundary traffic + stage-compute comparison.

Four sections:

1. pipeline depth sweep (Fig. 12) on qft-14.
2. codec-backend comparison (host vs device-resident lossy codec): the
   host↔device bytes moved per stage — the quantity the device codec
   shrinks by shipping packed codes + sign bitmaps instead of raw
   complex64 group arrays.
3. stage compute, per-gate (PR-1) path vs the planes-resident
   transpose-minimizing schedule (core/schedule.py), side by side:
   engine-level ``t_compute + t_fetch`` plus warm per-stage-function
   kernel time, and the full-group transpose counts
   (``n_transposes_naive`` vs ``n_transposes_scheduled``).  The
   per-stage-function timing is also taken at a compute-bound layout
   (large ``local_bits`` — fewer, bigger groups) and at qft-18, where
   group planes outgrow the caches and elided transposes are real
   memory passes; the tiny-group qft-14/b=7 layout is dispatch-bound
   and shows the floor, not the ceiling.
4. resilience guardrail overhead: block checksums + pressure monitor on
   vs off at qft-14 with a spill-forcing RAM budget; the within-run
   ``guardrail_overhead`` ratio is gated absolutely by compare.py.
"""
import time

import numpy as np

from repro.core import EngineConfig, Simulator, build_circuit
from repro.core.engine import _stage_fn, _stage_mats
from repro.core.fusion import FusedGate, fuse_gates
from repro.core.groups import GroupLayout
from repro.core.partition import partition_circuit

from .common import emit, run_engine


def _stage_fn_time(name: str, n: int, local_bits: int, reps: int = 8):
    """Warm min-of-reps execution time of every stage's jitted group fn,
    summed over stages x groups, for both compute paths."""
    import jax.numpy as jnp

    qc = build_circuit(name, n)
    part = partition_circuit(qc, local_bits, 2)
    rng = np.random.default_rng(0)
    tot = {False: 0.0, True: 0.0}
    for st in part.stages:
        layout = GroupLayout(n, local_bits, tuple(st.inner))
        fused = fuse_gates(st.gates, 5)
        vg = [FusedGate(layout.remap_qubits(fg.qubits), fg.matrix)
              for fg in fused]
        if not vg:
            continue
        plan = tuple((fg.qubits, fg.is_diagonal) for fg in vg)
        nv = layout.b + layout.m
        base = rng.standard_normal((2, 2 ** nv)).astype(np.float32)
        for gs in (False, True):
            fn = _stage_fn(plan, nv, True, gs, True)
            mats = _stage_mats(vg, plan, gs)
            ins = [jnp.asarray(base) for _ in range(reps + 1)]
            fn(ins[0], *mats).block_until_ready()      # compile
            best = float("inf")
            for r in range(reps):                      # donated buffers
                t0 = time.perf_counter()
                fn(ins[r + 1], *mats).block_until_ready()
                best = min(best, time.perf_counter() - t0)
            tot[gs] += best * layout.n_groups
    return tot


def _depth_sweep(name: str, n: int, local_bits: int, prefix: str = "",
                 rounds: int = 8) -> dict[int, float]:
    """Warm per-depth wall clock of the wave-coalesced scheduler.

    Two measurement rules keep the ~10% overlap effect above the
    container's timing noise:

    * each depth gets one WARMUP run before timing — a new wave width
      means new stage-fn trace shapes, and charging depth>1 (but not
      depth 1, whose traces the warmup also compiled) for one-off jit
      compilation would report the old always-lose artifact instead of
      the steady-state schedule the planner's model predicts;
    * the depths are timed INTERLEAVED round-robin over live sessions
      (min over rounds), not in per-depth blocks — single-core container
      throughput drifts by tens of percent over minutes, and block
      timing folds that drift into the depth ratio.  The min needs a
      deep sample: identical runs swing ~1.6x on a noisy container, so
      fewer than ~8 rounds leaves the ratio itself noise-dominated.

    ``depth_2_speedup`` is the gated headline: sequential min / depth-2
    min from the same interleaved rounds.
    """
    best = _measure_depths(name, n, local_bits, rounds)
    for d in sorted(best):
        emit("pipeline", f"{prefix}depth_{d}_s", best[d])
        emit("pipeline", f"{prefix}depth_{d}_speedup", best[1] / best[d])
    return best


def _measure_depths(name: str, n: int, local_bits: int,
                    rounds: int = 8) -> dict[int, float]:
    qc = build_circuit(name, n)
    depths = (1, 2, 4)
    sims = {}
    try:
        for d in depths:
            sims[d] = Simulator(qc, EngineConfig(
                local_bits=local_bits, pipeline_depth=d)).__enter__()
            sims[d].run()              # warmup: compile stage/wave fns
        best = {d: float("inf") for d in depths}
        for _ in range(rounds):
            for d in depths:
                t0 = time.perf_counter()
                sims[d].run()
                best[d] = min(best[d], time.perf_counter() - t0)
    finally:
        for sim in sims.values():
            sim.__exit__(None, None, None)
    return best


def _depth_sweep_isolated(name: str, n: int, local_bits: int,
                          prefix: str = "") -> None:
    """Run the depth sweep in a FRESH interpreter and re-emit its rows.

    By the time the suite reaches this bench the process has hours of
    allocator churn and jit-cache pressure behind it, which reproducibly
    skews the small (~10%) depth ratios that the ``depth_2_speedup``
    gate protects; a clean process measures the schedule, not the
    process history.  Falls back to in-process when spawning fails.
    """
    import json
    import os
    import subprocess
    import sys

    code = ("import json\n"
            "from benchmarks.bench_pipeline import _measure_depths\n"
            f"best = _measure_depths({name!r}, {n}, {local_bits})\n"
            "print('SWEEP ' + json.dumps(best))\n")
    env = dict(os.environ)
    env["PYTHONPATH"] = "src" + os.pathsep + env.get("PYTHONPATH", "")
    try:
        out = subprocess.run([sys.executable, "-c", code], env=env,
                             capture_output=True, text=True, check=True,
                             timeout=1800).stdout
        payload = [ln for ln in out.splitlines() if ln.startswith("SWEEP ")]
        best = {int(k): v for k, v in json.loads(payload[-1][6:]).items()}
    except (subprocess.SubprocessError, OSError, IndexError):
        _depth_sweep(name, n, local_bits, prefix)
        return
    for d in sorted(best):
        emit("pipeline", f"{prefix}depth_{d}_s", best[d])
        emit("pipeline", f"{prefix}depth_{d}_speedup", best[1] / best[d])


def main():
    # Fig. 12 depth sweep at the paper layout and at a cache-exceeding
    # qft-18 layout; the *_speedup rows feed the compare.py gate
    _depth_sweep_isolated("qft", 14, 7)
    _depth_sweep_isolated("qft", 18, 11, prefix="qft18_")

    # codec backend: boundary bytes per stage, host vs device
    stats_by_backend = {}
    for backend in ("host", "device"):
        _, _, stats, t = run_engine("qft", 14, local_bits=7,
                                    codec_backend=backend)
        stats_by_backend[backend] = stats
        emit("pipeline", f"backend_{backend}_s", t)
        emit("pipeline", f"backend_{backend}_h2d_bytes", stats.h2d_bytes)
        emit("pipeline", f"backend_{backend}_d2h_bytes", stats.d2h_bytes)
        emit("pipeline", f"backend_{backend}_h2d_bytes_per_stage",
             stats.h2d_bytes / max(1, stats.n_stages))
        emit("pipeline", f"backend_{backend}_d2h_bytes_per_stage",
             stats.d2h_bytes / max(1, stats.n_stages))
        for i, (h2d, d2h) in enumerate(stats.per_stage_boundary_bytes):
            emit("pipeline", f"backend_{backend}_stage{i}_h2d_bytes", h2d)
            emit("pipeline", f"backend_{backend}_stage{i}_d2h_bytes", d2h)
    host, dev = stats_by_backend["host"], stats_by_backend["device"]
    emit("pipeline", "device_boundary_reduction",
         host.boundary_bytes / max(1, dev.boundary_bytes))

    # stage compute: per-gate (PR-1) vs scheduled planes path, side by side
    qc = build_circuit("qft", 14)
    for label, gs in (("pergate", False), ("scheduled", True)):
        best = (float("inf"), float("inf"))     # (compute+fetch, fetch)
        with Simulator(qc, EngineConfig(local_bits=7,
                                        gate_schedule=gs)) as sim:
            stats = sim.stats          # accumulates across the session's
            for _ in range(2):         # runs; diff per-run deltas (the
                c0 = stats.t_compute   # second reuses compiled stage fns)
                f0 = stats.t_fetch
                sim.run()
                best = min(best, (stats.t_compute + stats.t_fetch - c0 - f0,
                                  stats.t_fetch - f0))
        emit("pipeline", f"compute_{label}_s", best[0])
        emit("pipeline", f"compute_{label}_t_fetch_s", best[1])
    # the transpose counters are a property of the schedule, not the
    # executed path — emit them once
    emit("pipeline", "transposes_naive", stats.n_transposes_naive)
    emit("pipeline", "transposes_scheduled", stats.n_transposes_scheduled)
    emit("pipeline", "transpose_reduction",
         stats.n_transposes_naive / max(1, stats.n_transposes_scheduled))

    # resilience guardrails: block checksums + pressure monitor, on vs
    # off, at the paper layout with a RAM budget small enough that the
    # spill tier (where the checksums actually run) is exercised.
    # Interleaved min-of-rounds like the depth sweep; the emitted
    # guardrail_overhead ratio is within-run, so machine speed cancels
    # and compare.py gates it against an absolute ceiling.
    guard_cfgs = {
        "on": EngineConfig(local_bits=7, ram_budget_bytes=2048),
        "off": EngineConfig(local_bits=7, ram_budget_bytes=2048,
                            integrity_checks=False,
                            pressure_monitor=False),
    }
    sims = {}
    try:
        for k, c in guard_cfgs.items():
            sims[k] = Simulator(qc, c).__enter__()
            sims[k].run()              # warmup
        best = {k: float("inf") for k in sims}
        for _ in range(6):
            for k, s in sims.items():
                t0 = time.perf_counter()
                s.run()
                best[k] = min(best[k], time.perf_counter() - t0)
        assert sims["on"].stats.n_spills > 0   # the guarded path ran
    finally:
        for s in sims.values():
            s.__exit__(None, None, None)
    emit("pipeline", "guard_on_s", best["on"])
    emit("pipeline", "guard_off_s", best["off"])
    emit("pipeline", "guardrail_overhead", best["on"] / best["off"])

    # stage-fn kernel time (the compute the pipeline dispatches), at the
    # paper layout, a compute-bound qft-14 layout, and a cache-exceeding
    # qft-18 layout
    for label, (name, n, lb, reps) in {
        "qft14_b7": ("qft", 14, 7, 8),
        "qft14_b12": ("qft", 14, 12, 8),
        "qft18_b16": ("qft", 18, 16, 3),
    }.items():
        tot = _stage_fn_time(name, n, lb, reps)
        emit("pipeline", f"stagefn_{label}_pergate_s", tot[False])
        emit("pipeline", f"stagefn_{label}_scheduled_s", tot[True])
        emit("pipeline", f"stagefn_{label}_speedup", tot[False] / tot[True])


if __name__ == "__main__":
    main()
