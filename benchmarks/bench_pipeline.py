"""Fig. 12 + §4.3 boundary traffic + stage-compute comparison.

Three sections:

1. pipeline depth sweep (Fig. 12) on qft-14.
2. codec-backend comparison (host vs device-resident lossy codec): the
   host↔device bytes moved per stage — the quantity the device codec
   shrinks by shipping packed codes + sign bitmaps instead of raw
   complex64 group arrays.
3. stage compute, per-gate (PR-1) path vs the planes-resident
   transpose-minimizing schedule (core/schedule.py), side by side:
   engine-level ``t_compute + t_fetch`` plus warm per-stage-function
   kernel time, and the full-group transpose counts
   (``n_transposes_naive`` vs ``n_transposes_scheduled``).  The
   per-stage-function timing is also taken at a compute-bound layout
   (large ``local_bits`` — fewer, bigger groups) and at qft-18, where
   group planes outgrow the caches and elided transposes are real
   memory passes; the tiny-group qft-14/b=7 layout is dispatch-bound
   and shows the floor, not the ceiling.
"""
import time

import numpy as np

from repro.core import EngineConfig, Simulator, build_circuit
from repro.core.engine import _stage_fn, _stage_mats
from repro.core.fusion import FusedGate, fuse_gates
from repro.core.groups import GroupLayout
from repro.core.partition import partition_circuit

from .common import emit, run_engine


def _stage_fn_time(name: str, n: int, local_bits: int, reps: int = 8):
    """Warm min-of-reps execution time of every stage's jitted group fn,
    summed over stages x groups, for both compute paths."""
    import jax.numpy as jnp

    qc = build_circuit(name, n)
    part = partition_circuit(qc, local_bits, 2)
    rng = np.random.default_rng(0)
    tot = {False: 0.0, True: 0.0}
    for st in part.stages:
        layout = GroupLayout(n, local_bits, tuple(st.inner))
        fused = fuse_gates(st.gates, 5)
        vg = [FusedGate(layout.remap_qubits(fg.qubits), fg.matrix)
              for fg in fused]
        if not vg:
            continue
        plan = tuple((fg.qubits, fg.is_diagonal) for fg in vg)
        nv = layout.b + layout.m
        base = rng.standard_normal((2, 2 ** nv)).astype(np.float32)
        for gs in (False, True):
            fn = _stage_fn(plan, nv, True, gs, True)
            mats = _stage_mats(vg, plan, gs)
            ins = [jnp.asarray(base) for _ in range(reps + 1)]
            fn(ins[0], *mats).block_until_ready()      # compile
            best = float("inf")
            for r in range(reps):                      # donated buffers
                t0 = time.perf_counter()
                fn(ins[r + 1], *mats).block_until_ready()
                best = min(best, time.perf_counter() - t0)
            tot[gs] += best * layout.n_groups
    return tot


def main():
    base = None
    for depth in (1, 2, 4, 8):
        _, _, _, t = run_engine("qft", 14, local_bits=7,
                                pipeline_depth=depth)
        base = base or t
        emit("pipeline", f"depth_{depth}_s", t)
        emit("pipeline", f"depth_{depth}_speedup", base / t)

    # codec backend: boundary bytes per stage, host vs device
    stats_by_backend = {}
    for backend in ("host", "device"):
        _, _, stats, t = run_engine("qft", 14, local_bits=7,
                                    codec_backend=backend)
        stats_by_backend[backend] = stats
        emit("pipeline", f"backend_{backend}_s", t)
        emit("pipeline", f"backend_{backend}_h2d_bytes", stats.h2d_bytes)
        emit("pipeline", f"backend_{backend}_d2h_bytes", stats.d2h_bytes)
        emit("pipeline", f"backend_{backend}_h2d_bytes_per_stage",
             stats.h2d_bytes / max(1, stats.n_stages))
        emit("pipeline", f"backend_{backend}_d2h_bytes_per_stage",
             stats.d2h_bytes / max(1, stats.n_stages))
        for i, (h2d, d2h) in enumerate(stats.per_stage_boundary_bytes):
            emit("pipeline", f"backend_{backend}_stage{i}_h2d_bytes", h2d)
            emit("pipeline", f"backend_{backend}_stage{i}_d2h_bytes", d2h)
    host, dev = stats_by_backend["host"], stats_by_backend["device"]
    emit("pipeline", "device_boundary_reduction",
         host.boundary_bytes / max(1, dev.boundary_bytes))

    # stage compute: per-gate (PR-1) vs scheduled planes path, side by side
    qc = build_circuit("qft", 14)
    for label, gs in (("pergate", False), ("scheduled", True)):
        best = (float("inf"), float("inf"))     # (compute+fetch, fetch)
        with Simulator(qc, EngineConfig(local_bits=7,
                                        gate_schedule=gs)) as sim:
            stats = sim.stats          # accumulates across the session's
            for _ in range(2):         # runs; diff per-run deltas (the
                c0 = stats.t_compute   # second reuses compiled stage fns)
                f0 = stats.t_fetch
                sim.run()
                best = min(best, (stats.t_compute + stats.t_fetch - c0 - f0,
                                  stats.t_fetch - f0))
        emit("pipeline", f"compute_{label}_s", best[0])
        emit("pipeline", f"compute_{label}_t_fetch_s", best[1])
    # the transpose counters are a property of the schedule, not the
    # executed path — emit them once
    emit("pipeline", "transposes_naive", stats.n_transposes_naive)
    emit("pipeline", "transposes_scheduled", stats.n_transposes_scheduled)
    emit("pipeline", "transpose_reduction",
         stats.n_transposes_naive / max(1, stats.n_transposes_scheduled))

    # stage-fn kernel time (the compute the pipeline dispatches), at the
    # paper layout, a compute-bound qft-14 layout, and a cache-exceeding
    # qft-18 layout
    for label, (name, n, lb, reps) in {
        "qft14_b7": ("qft", 14, 7, 8),
        "qft14_b12": ("qft", 14, 12, 8),
        "qft18_b16": ("qft", 18, 16, 3),
    }.items():
        tot = _stage_fn_time(name, n, lb, reps)
        emit("pipeline", f"stagefn_{label}_pergate_s", tot[False])
        emit("pipeline", f"stagefn_{label}_scheduled_s", tot[True])
        emit("pipeline", f"stagefn_{label}_speedup", tot[False] / tot[True])


if __name__ == "__main__":
    main()
