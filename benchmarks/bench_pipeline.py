"""Fig. 12: pipeline depth sweep (paper: CUDA stream count)."""
from .common import emit, run_engine


def main():
    base = None
    for depth in (1, 2, 4, 8):
        _, _, _, t = run_engine("qft", 14, local_bits=7,
                                pipeline_depth=depth)
        base = base or t
        emit("pipeline", f"depth_{depth}_s", t)
        emit("pipeline", f"depth_{depth}_speedup", base / t)


if __name__ == "__main__":
    main()
