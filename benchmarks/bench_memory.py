"""Fig. 9: peak memory vs the 2^(n+4)-byte standard."""
from .common import ALL_CIRCUITS, emit, run_engine


def main():
    for name in ALL_CIRCUITS:
        _, _, stats, _ = run_engine(name, 16, local_bits=10)
        emit("memory", f"{name}_peak_bytes", stats.peak_total_bytes)
        emit("memory", f"{name}_standard_bytes", stats.standard_bytes)
        emit("memory", f"{name}_reduction", stats.memory_reduction)


if __name__ == "__main__":
    main()
