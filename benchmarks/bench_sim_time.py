"""Fig. 10: simulation time, BMQSIM vs the dense engine (SV-Sim-like)."""
from .common import emit, run_engine, timed
from repro.core import build_circuit, simulate_dense


def main():
    for name in ("qft", "qaoa", "bv"):
        qc = build_circuit(name, 14)
        _, t_dense = timed(lambda: simulate_dense(qc).block_until_ready())
        _, _, stats, t_bmq = run_engine(name, 14, local_bits=8)
        emit("sim_time", f"{name}_dense_s", t_dense)
        emit("sim_time", f"{name}_bmqsim_s", t_bmq)
        emit("sim_time", f"{name}_ratio", t_bmq / t_dense)


if __name__ == "__main__":
    main()
