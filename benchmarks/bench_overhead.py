"""Fig. 11: compression overhead — BMQSIM vs BMQSIM-without-compression —
plus the per-stage host↔device traffic each codec backend pays."""
from .common import emit, run_engine


def main():
    for name in ("cat_state", "qft", "qaoa"):
        for n in (12, 14):
            _, _, s_c, t_c = run_engine(name, n, local_bits=n - 6)
            _, _, s_n, t_n = run_engine(name, n, local_bits=n - 6,
                                        compression=False)
            emit("overhead", f"{name}_{n}_with_s", t_c)
            emit("overhead", f"{name}_{n}_without_s", t_n)
            emit("overhead", f"{name}_{n}_overhead_pct",
                 100.0 * (t_c - t_n) / t_n)
            # boundary traffic per stage, both codec backends
            _, _, s_d, _ = run_engine(name, n, local_bits=n - 6,
                                      codec_backend="device")
            for label, s in (("host", s_c), ("device", s_d)):
                emit("overhead", f"{name}_{n}_{label}_h2d_bytes_per_stage",
                     s.h2d_bytes / max(1, s.n_stages))
                emit("overhead", f"{name}_{n}_{label}_d2h_bytes_per_stage",
                     s.d2h_bytes / max(1, s.n_stages))


if __name__ == "__main__":
    main()
