"""Fig. 11: compression overhead — BMQSIM vs BMQSIM-without-compression."""
from .common import emit, run_engine


def main():
    for name in ("cat_state", "qft", "qaoa"):
        for n in (12, 14):
            _, _, s_c, t_c = run_engine(name, n, local_bits=n - 6)
            _, _, s_n, t_n = run_engine(name, n, local_bits=n - 6,
                                        compression=False)
            emit("overhead", f"{name}_{n}_with_s", t_c)
            emit("overhead", f"{name}_{n}_without_s", t_n)
            emit("overhead", f"{name}_{n}_overhead_pct",
                 100.0 * (t_c - t_n) / t_n)


if __name__ == "__main__":
    main()
