"""Table 2: max supported qubits under a fixed memory budget.

Method (container-scale): run each circuit at n=16, measure the peak
compressed footprint ratio, then solve max n with  ratio-scaled 2^(n+4)
<= budget  for BMQSIM vs  2^(n+4) <= budget  for dense simulators.
A second row adds the SSD tier (paper: +5 qubits for BMQSIM)."""
import math

from .common import ALL_CIRCUITS, emit, run_engine

BUDGET = 64 * 2 ** 30          # 64 GiB "machine"
SSD = 4 * 2 ** 40              # + 4 TB storage tier


def main():
    dense_max = int(math.log2(BUDGET)) - 4
    emit("max_qubits", "dense_any_circuit", dense_max)
    for name in ALL_CIRCUITS:
        _, _, stats, _ = run_engine(name, 16, local_bits=10, inner_size=2)
        ratio = stats.standard_bytes / max(1, stats.peak_total_bytes)
        bmq = int(math.log2(BUDGET * ratio)) - 4
        bmq_ssd = int(math.log2((BUDGET + SSD) * ratio)) - 4
        emit("max_qubits", f"{name}_ratio", round(ratio, 1))
        emit("max_qubits", f"{name}_bmqsim", bmq)
        emit("max_qubits", f"{name}_bmqsim_ssd", bmq_ssd)
        emit("max_qubits", f"{name}_extra_qubits", bmq - dense_max)


if __name__ == "__main__":
    main()
