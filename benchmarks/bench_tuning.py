"""Fig. 15: inner size x SV block size -> compression ratio + time."""
from .common import emit, run_engine


def main():
    for b in (5, 6, 7):
        for inner in (2, 3, 4):
            _, _, stats, t = run_engine("qaoa", 13, local_bits=b,
                                        inner_size=inner)
            key = f"b{b}_inner{inner}"
            emit("tuning", f"{key}_ratio", stats.memory_reduction)
            emit("tuning", f"{key}_time_s", t)
            emit("tuning", f"{key}_stages", stats.n_stages)


if __name__ == "__main__":
    main()
