"""Fig. 15: inner size x SV block size -> compression ratio + time, plus
the planner's budget-driven auto pick over the same workload (what the
hand grid looks like when ``EngineConfig(local_bits=None,
memory_budget_bytes=...)`` chooses the knobs instead)."""
import time

from repro.core import EngineConfig, Simulator, build_circuit

from .common import emit, run_engine


def main():
    for b in (5, 6, 7):
        for inner in (2, 3, 4):
            _, _, stats, t = run_engine("qaoa", 13, local_bits=b,
                                        inner_size=inner)
            key = f"b{b}_inner{inner}"
            emit("tuning", f"{key}_ratio", stats.memory_reduction)
            emit("tuning", f"{key}_time_s", t)
            emit("tuning", f"{key}_stages", stats.n_stages)

    # auto-tuned: the planner searches (local_bits, inner_size,
    # pipeline_depth) under a working-set budget; emit what it chose and
    # whether the run honored the budget
    qc = build_circuit("qaoa", 13)
    for budget_kib in (32, 256):
        cfg = EngineConfig(memory_budget_bytes=budget_kib * 2 ** 10)
        with Simulator(qc, cfg) as sim:
            t0 = time.perf_counter()
            sim.run()
            dt = time.perf_counter() - t0
            key = f"auto_{budget_kib}kib"
            emit("tuning", f"{key}_local_bits", sim.config.local_bits)
            emit("tuning", f"{key}_inner_size", sim.config.inner_size)
            emit("tuning", f"{key}_stages", sim.stats.n_stages)
            emit("tuning", f"{key}_time_s", dt)
            emit("tuning", f"{key}_peak_ram_bytes", sim.stats.peak_ram_bytes)
            emit("tuning", f"{key}_within_budget",
                 int(sim.stats.peak_ram_bytes <= budget_kib * 2 ** 10))


if __name__ == "__main__":
    main()
