"""Session reuse + streaming readout (the Simulator API's perf claims).

Two measurements:

* sweep reuse — a parameterized QAOA sweep on ONE session vs rebuilding
  the engine per point (what `simulate_bmqsim` callers did): the session's
  later runs skip partitioning and stage-fn/schedule compilation, so
  `repeat_run_s` should undercut both `first_run_s` and `fresh_engine_s`.
* readout — sampling and a diagonal expectation streamed from the
  compressed store, vs the cost of materializing the dense state first.

CPU timings here are noisy (2-3x swings); min-over-reps is reported.
"""
from __future__ import annotations

import time

from repro.core import (EngineConfig, Simulator, maxcut_cost_fn,
                        maxcut_edges, qaoa_template)

from .common import emit

N = 14
B = 8
REPS = 3


def main() -> None:
    template = qaoa_template(N, layers=1)
    cost = maxcut_cost_fn(maxcut_edges(N))
    cfg = EngineConfig(local_bits=B, inner_size=2)

    with Simulator(template, cfg) as sim:
        t0 = time.perf_counter()
        sim.run(params={"gamma0": 0.4, "beta0": 0.2})
        first = time.perf_counter() - t0
        repeat = float("inf")
        for i in range(REPS):
            t0 = time.perf_counter()
            result = sim.run(params={"gamma0": 0.5 + 0.1 * i,
                                     "beta0": 0.25})
            repeat = min(repeat, time.perf_counter() - t0)
        emit("session", "first_run_s", first)
        emit("session", "repeat_run_s", repeat)
        emit("session", "stagefn_compiles", sim.stats.n_stagefn_compiles)
        emit("session", "stagefn_cache_hits", sim.stats.n_stagefn_cache_hits)

        t0 = time.perf_counter()
        result.sample(1024, seed=0)
        emit("session", "sample_1024_s", time.perf_counter() - t0)
        t0 = time.perf_counter()
        result.expectation(cost)
        emit("session", "expect_s", time.perf_counter() - t0)
        t0 = time.perf_counter()
        result.statevector()
        emit("session", "statevector_s", time.perf_counter() - t0)

    # baseline: a fresh engine per sweep point (pre-session API pattern);
    # the global stage-fn lru is warm from above, so the remaining gap is
    # partition + fusion + operand staging per call
    fresh = float("inf")
    for i in range(REPS):
        bound = template.bind({"gamma0": 0.5 + 0.1 * i, "beta0": 0.25})
        t0 = time.perf_counter()
        with Simulator(bound, cfg) as sim:
            sim.run()
        fresh = min(fresh, time.perf_counter() - t0)
    emit("session", "fresh_engine_s", fresh)
