"""Session reuse + streaming readout (the Simulator API's perf claims).

Three measurements:

* sweep reuse — a parameterized QAOA sweep on ONE session vs rebuilding
  the engine per point (what `simulate_bmqsim` callers did): the session's
  later runs skip partitioning and stage-fn/schedule compilation, so
  `repeat_run_s` should undercut both `first_run_s` and `fresh_engine_s`.
* readout — sampling and a diagonal expectation streamed from the
  compressed store, vs the cost of materializing the dense state first.
* batched execution — `run_batch` with K=8 lanes vs the equivalent
  sequential loop on the dispatch-bound config (qft-14, local_bits=7):
  per (stage, group) the batch pays ONE jitted dispatch / boundary
  crossing for all lanes, so `batch_k8_batched_s` should undercut
  `batch_k8_looped_s` by most of the per-call overhead.

CPU timings here are noisy (2-3x swings); min-over-reps is reported.
"""
from __future__ import annotations

import time

from repro.core import (EngineConfig, Simulator, build_circuit,
                        maxcut_cost_fn, maxcut_edges, qaoa_template)

from .common import emit

N = 14
B = 8
REPS = 3

#: the dispatch-bound batching config (small blocks -> many tiny groups)
BATCH_K = 8
BATCH_B = 7
BATCH_REPS = 2


def main() -> None:
    template = qaoa_template(N, layers=1)
    cost = maxcut_cost_fn(maxcut_edges(N))
    cfg = EngineConfig(local_bits=B, inner_size=2)

    with Simulator(template, cfg) as sim:
        t0 = time.perf_counter()
        sim.run(params={"gamma0": 0.4, "beta0": 0.2})
        first = time.perf_counter() - t0
        repeat = float("inf")
        for i in range(REPS):
            t0 = time.perf_counter()
            result = sim.run(params={"gamma0": 0.5 + 0.1 * i,
                                     "beta0": 0.25})
            repeat = min(repeat, time.perf_counter() - t0)
        emit("session", "first_run_s", first)
        emit("session", "repeat_run_s", repeat)
        emit("session", "stagefn_compiles", sim.stats.n_stagefn_compiles)
        emit("session", "stagefn_cache_hits", sim.stats.n_stagefn_cache_hits)

        t0 = time.perf_counter()
        result.sample(1024, seed=0)
        emit("session", "sample_1024_s", time.perf_counter() - t0)
        t0 = time.perf_counter()
        result.expectation(cost)
        emit("session", "expect_s", time.perf_counter() - t0)
        t0 = time.perf_counter()
        result.statevector()
        emit("session", "statevector_s", time.perf_counter() - t0)

    # baseline: a fresh engine per sweep point (pre-session API pattern);
    # the global stage-fn lru is warm from above, so the remaining gap is
    # partition + fusion + operand staging per call
    fresh = float("inf")
    for i in range(REPS):
        bound = template.bind({"gamma0": 0.5 + 0.1 * i, "beta0": 0.25})
        t0 = time.perf_counter()
        with Simulator(bound, cfg) as sim:
            sim.run()
        fresh = min(fresh, time.perf_counter() - t0)
    emit("session", "fresh_engine_s", fresh)

    # batched execution: K lanes through run_batch vs K sequential runs
    # on one warm session (qft-14 / local_bits=7 — dispatch-bound)
    qc = build_circuit("qft", 14)
    with Simulator(qc, EngineConfig(local_bits=BATCH_B,
                                    inner_size=2)) as sim:
        sim.run()                                  # warm single-lane fns
        sim.run_batch([None] * BATCH_K)            # warm batched fns
        batched = float("inf")
        for _ in range(BATCH_REPS):
            t0 = time.perf_counter()
            sim.run_batch([None] * BATCH_K)
            batched = min(batched, time.perf_counter() - t0)
        looped = float("inf")
        for _ in range(BATCH_REPS):
            t0 = time.perf_counter()
            for _ in range(BATCH_K):
                sim.run()
            looped = min(looped, time.perf_counter() - t0)
        emit("batch", "qft14_b7_k8_batched_s", batched)
        emit("batch", "qft14_b7_k8_looped_s", looped)
        emit("batch", "qft14_b7_k8_speedup", looped / batched)
