"""Benchmark-regression gate: diff two ``BENCH_*.json`` dumps.

    PYTHONPATH=src python -m benchmarks.compare BENCH_4.json BENCH_ci.json

Compares every *keyed timing row* (metric keys ending in ``_s``, i.e. the
min-over-reps wall-clock rows the benchmarks emit) present in both files
and exits nonzero when any row slowed down by more than ``--threshold``
(default 3x — deliberately loose: the CI container's CPU timings swing
2-3x between runs, so only a real regression clears it).  Rows whose
baseline is below ``--min-baseline`` seconds (default 0.5) are skipped:
sub-second rows are dominated by dispatch jitter and observably swing
past 3x between otherwise-identical runs.

Ratios are *median-normalized* by default: every row's new/old ratio is
divided by the suite-wide median ratio before gating.  A uniformly slower
runner (baselines are recorded on whatever container a past PR ran on)
shifts ALL rows together and must not fail the gate; a genuine regression
moves one row relative to the rest and still trips it.  A row that did
not slow down in *raw* seconds never fails regardless of its normalized
ratio — a baseline whose own run drifted non-uniformly (a 40-minute
suite on a throttling container) otherwise flags rows that actually got
faster.  ``--absolute`` disables the normalization.  The blind spot — a change that slows EVERY
row together (say a disabled fast path) normalizes itself away — is
bounded by ``--max-median`` (default 10x): a suite median beyond that is
no longer plausible machine variance and fails outright.

Besides the timing rows, every shared ``*_speedup`` row (the pipeline
depth sweep's overlap ratios, etc.) is gated too — in the OTHER
direction: speedups are unitless ratios taken within one run, so machine
speed cancels and no median normalization applies; a row fails when the
current speedup falls below ``baseline / --speedup-threshold`` (default
1.5x).  This is what keeps ``pipeline/depth_2_speedup`` from silently
regressing back to the pre-wave-coalescing era where depth 2 *lost* to
sequential.

``*_overhead`` rows (within-run on/off ratios, e.g. the resilience
``guardrail_overhead`` of checksums + pressure monitoring) are gated
against an absolute ``--overhead-ceiling`` from the CURRENT dump alone —
no baseline needed, so a newly added guardrail must prove it is close to
free on its first run.

When ``$GITHUB_STEP_SUMMARY`` is set (GitHub Actions), the comparison
table is appended there as markdown so the ``bench-trajectory`` job shows
the per-row ratios without digging through artifacts.
"""

from __future__ import annotations

import argparse
import json
import os
import statistics
import sys

DEFAULT_THRESHOLD = 3.0
DEFAULT_MIN_BASELINE = 0.5
DEFAULT_MAX_MEDIAN = 10.0
DEFAULT_SPEEDUP_THRESHOLD = 1.5
DEFAULT_OVERHEAD_CEILING = 1.15


def _load_rows(path: str, suffix: str) -> dict[str, float]:
    with open(path) as fh:
        report = json.load(fh)
    rows: dict[str, float] = {}
    for bench, entry in report.get("benches", {}).items():
        for section, metrics in entry.get("metrics", {}).items():
            for key, value in metrics.items():
                if key.endswith(suffix) and isinstance(value, (int, float)):
                    rows[f"{bench}/{section}/{key}"] = float(value)
    return rows


def load_timing_rows(path: str) -> dict[str, float]:
    """``bench/section/key -> seconds`` for every ``*_s`` metric row."""
    return _load_rows(path, "_s")


def load_speedup_rows(path: str) -> dict[str, float]:
    """``bench/section/key -> ratio`` for every ``*_speedup`` metric row."""
    return _load_rows(path, "_speedup")


def load_overhead_rows(path: str) -> dict[str, float]:
    """``bench/section/key -> ratio`` for every ``*_overhead`` metric row."""
    return _load_rows(path, "_overhead")


def gate_overhead_rows(
    current: dict[str, float],
    ceiling: float,
) -> list[tuple[str, float, bool]]:
    """``*_overhead`` rows -> ``[(key, value, busted)]``.

    Overheads are within-run on/off ratios (e.g. the pipeline bench's
    resilience ``guardrail_overhead``): machine speed cancels, so they
    are gated against an ABSOLUTE ceiling — no baseline needed, and a
    row present only in the current dump is still gated (that is the
    point: a new guardrail must prove it is close to free)."""
    return [(key, val, val > ceiling) for key, val in sorted(current.items())]


def compare_speedup_rows(
    baseline: dict[str, float],
    current: dict[str, float],
    threshold: float,
) -> list[tuple[str, float, float, float, bool]]:
    """Shared ``*_speedup`` rows -> ``[(key, old, new, old/new, lost)]``.

    Speedups are within-run ratios, so no machine-speed normalization:
    a row regresses when the current speedup dropped to less than
    ``1/threshold`` of the baseline's.
    """
    out = []
    for key in sorted(baseline):
        if key not in current:
            continue
        old, new = baseline[key], current[key]
        drop = old / new if new > 0 else float("inf")
        out.append((key, old, new, drop, drop > threshold))
    return out


def compare_rows(
    baseline: dict[str, float],
    current: dict[str, float],
    threshold: float,
    min_baseline: float,
    normalize: bool = True,
) -> tuple[list[tuple[str, float, float, float, bool]], float]:
    """Shared keyed rows -> ``([(key, old, new, norm_ratio, regressed)],
    median_ratio)``.

    With ``normalize`` (the default) each raw new/old ratio is divided by
    the suite-wide median ratio, so a uniformly faster/slower runner
    cancels out and only relative movement gates.  Keys present on only
    one side are not comparable (benchmarks come and go across PRs) and
    are reported separately by :func:`main`.
    """
    shared = []
    for key in sorted(baseline):
        if key not in current:
            continue
        old, new = baseline[key], current[key]
        if old < min_baseline:
            continue
        ratio = new / old if old > 0 else float("inf")
        shared.append((key, old, new, ratio))
    median = statistics.median([r for _, _, _, r in shared]) if shared else 1.0
    scale = median if (normalize and median > 0) else 1.0
    out = []
    for key, old, new, ratio in shared:
        norm = ratio / scale
        # a row that is absolutely no slower never regresses: baselines
        # recorded under NON-uniform drift (container speed moving over
        # one long run) skew the median enough to push flat-or-faster
        # rows past the normalized threshold
        out.append((key, old, new, norm, norm > threshold and new > old))
    return out, median


def render_markdown(
    rows: list[tuple[str, float, float, float, bool]],
    threshold: float,
    median: float,
) -> str:
    lines = [
        f"### Benchmark regression gate (threshold {threshold:g}x, "
        f"suite median ratio {median:.2f}x)",
        "",
        "| row | baseline (s) | current (s) | ratio vs median | |",
        "|---|---:|---:|---:|---|",
    ]
    for key, old, new, ratio, regressed in rows:
        flag = ":x:" if regressed else ""
        lines.append(f"| `{key}` | {old:.3f} | {new:.3f} | {ratio:.2f}x | {flag} |")
    if not rows:
        lines.append("| _no shared timing rows_ | | | | |")
    return "\n".join(lines) + "\n"


def render_speedup_markdown(
    rows: list[tuple[str, float, float, float, bool]],
    threshold: float,
) -> str:
    if not rows:
        return ""
    lines = [
        f"### Speedup-row gate (fail below baseline/{threshold:g})",
        "",
        "| row | baseline | current | drop | |",
        "|---|---:|---:|---:|---|",
    ]
    for key, old, new, drop, lost in rows:
        flag = ":x:" if lost else ""
        lines.append(
            f"| `{key}` | {old:.2f}x | {new:.2f}x | {drop:.2f}x | {flag} |")
    return "\n".join(lines) + "\n"


def render_overhead_markdown(
    rows: list[tuple[str, float, bool]],
    ceiling: float,
) -> str:
    if not rows:
        return ""
    lines = [
        f"### Overhead-row gate (absolute ceiling {ceiling:g}x)",
        "",
        "| row | current | |",
        "|---|---:|---|",
    ]
    for key, val, busted in rows:
        flag = ":x:" if busted else ""
        lines.append(f"| `{key}` | {val:.3f}x | {flag} |")
    return "\n".join(lines) + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("baseline", help="committed BENCH_*.json baseline")
    ap.add_argument("current", help="fresh BENCH_*.json to gate")
    ap.add_argument(
        "--threshold",
        type=float,
        default=DEFAULT_THRESHOLD,
        help="fail on new/old above this ratio (default %(default)sx; "
        "loose because container CPU timings swing 2-3x)",
    )
    ap.add_argument(
        "--min-baseline",
        type=float,
        default=DEFAULT_MIN_BASELINE,
        help="skip rows whose baseline is below this many seconds "
        "(micro-timings are jitter; default %(default)s)",
    )
    ap.add_argument(
        "--absolute",
        action="store_true",
        help="gate on raw new/old ratios instead of median-normalized "
        "ones (fails on a uniformly slower runner; off by default)",
    )
    ap.add_argument(
        "--speedup-threshold",
        type=float,
        default=DEFAULT_SPEEDUP_THRESHOLD,
        help="fail when a *_speedup row drops below baseline divided by "
        "this (within-run ratios: no median normalization; default "
        "%(default)sx)",
    )
    ap.add_argument(
        "--overhead-ceiling",
        type=float,
        default=DEFAULT_OVERHEAD_CEILING,
        help="fail when any *_overhead row (within-run on/off ratio, "
        "e.g. the resilience guardrail_overhead) exceeds this absolute "
        "ceiling — gated from the current dump alone (default "
        "%(default)sx)",
    )
    ap.add_argument(
        "--max-median",
        type=float,
        default=DEFAULT_MAX_MEDIAN,
        help="fail when the suite-wide median ratio itself exceeds this "
        "(bounds the normalization blind spot: a uniform suite-wide "
        "slowdown this large is a regression, not machine variance; "
        "default %(default)sx)",
    )
    args = ap.parse_args(argv)

    baseline = load_timing_rows(args.baseline)
    current = load_timing_rows(args.current)
    rows, median = compare_rows(
        baseline,
        current,
        args.threshold,
        args.min_baseline,
        normalize=not args.absolute,
    )
    sp_rows = compare_speedup_rows(
        load_speedup_rows(args.baseline),
        load_speedup_rows(args.current),
        args.speedup_threshold,
    )
    ov_rows = gate_overhead_rows(
        load_overhead_rows(args.current), args.overhead_ceiling)
    table = render_markdown(rows, args.threshold, median)
    sp_table = render_speedup_markdown(sp_rows, args.speedup_threshold)
    ov_table = render_overhead_markdown(ov_rows, args.overhead_ceiling)
    print(table)
    if sp_table:
        print(sp_table)
    if ov_table:
        print(ov_table)

    only_base = sorted(set(baseline) - set(current))
    only_new = sorted(set(current) - set(baseline))
    if only_base:
        names = ", ".join(only_base[:8]) + ("..." if len(only_base) > 8 else "")
        print(f"# {len(only_base)} baseline-only rows (not gated): {names}")
    if only_new:
        names = ", ".join(only_new[:8]) + ("..." if len(only_new) > 8 else "")
        print(f"# {len(only_new)} new rows (no baseline yet): {names}")

    summary_path = os.environ.get("GITHUB_STEP_SUMMARY")
    if summary_path:
        with open(summary_path, "a") as fh:
            fh.write(table + "\n")
            if sp_table:
                fh.write(sp_table + "\n")
            if ov_table:
                fh.write(ov_table + "\n")

    if not args.absolute and rows and median > args.max_median:
        print(
            f"REGRESSION suite-wide: median ratio {median:.2f}x exceeds "
            f"{args.max_median:g}x — every row slowed together, which is "
            "beyond plausible runner variance",
            file=sys.stderr,
        )
        return 1

    regressions = [r for r in rows if r[4]]
    sp_regressions = [r for r in sp_rows if r[4]]
    ov_busts = [r for r in ov_rows if r[2]]
    if regressions or sp_regressions or ov_busts:
        for key, old, new, ratio, _ in regressions:
            print(
                f"REGRESSION {key}: {old:.3f}s -> {new:.3f}s "
                f"({ratio:.2f}x > {args.threshold:g}x)",
                file=sys.stderr,
            )
        for key, old, new, drop, _ in sp_regressions:
            print(
                f"REGRESSION {key}: speedup {old:.2f}x -> {new:.2f}x "
                f"(dropped {drop:.2f}x > {args.speedup_threshold:g}x)",
                file=sys.stderr,
            )
        for key, val, _ in ov_busts:
            print(
                f"REGRESSION {key}: overhead {val:.3f}x exceeds the "
                f"{args.overhead_ceiling:g}x ceiling",
                file=sys.stderr,
            )
        return 1
    print(f"# OK: {len(rows)} shared timing rows within {args.threshold:g}x, "
          f"{len(sp_rows)} speedup rows held, "
          f"{len(ov_rows)} overhead rows under {args.overhead_ceiling:g}x")
    return 0


if __name__ == "__main__":
    sys.exit(main())
