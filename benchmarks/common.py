"""Shared benchmark plumbing: timing, CSV emission, standard sizes.

Sizes are container-scale (single CPU core); every benchmark mirrors one
paper table/figure and prints ``bench,key,value`` CSV rows so runs diff
cleanly.  EXPERIMENTS.md records a full run.
"""
from __future__ import annotations

import time

import numpy as np

from repro.core import (EngineConfig, Simulator, build_circuit, fidelity,
                        simulate_dense)

ALL_CIRCUITS = ["cat_state", "cc", "ising", "qft", "bv", "qsvm",
                "ghz_state", "qaoa"]

# every emit() lands here too, so the driver can dump a machine-readable
# BENCH_*.json next to the human CSV (benchmarks/run.py --json)
_ROWS: list[tuple[str, str, object]] = []


def emit(bench: str, key: str, value) -> None:
    _ROWS.append((bench, key, value))
    if isinstance(value, float):
        value = f"{value:.6g}"
    print(f"{bench},{key},{value}", flush=True)


def drain_rows() -> list[tuple[str, str, object]]:
    """Hand the accumulated (bench, key, value) rows over and reset."""
    rows = _ROWS[:]
    _ROWS.clear()
    return rows


def timed(fn, *args, **kw):
    t0 = time.perf_counter()
    out = fn(*args, **kw)
    return out, time.perf_counter() - t0


def run_engine(name: str, n: int, collect_state: bool = True, **cfg_kw):
    """One-shot run through the session API (construction + run timed
    together, like the deprecated ``simulate_bmqsim`` wrapper it
    replaced); ``collect_state=False`` skips the dense materialization."""
    qc = build_circuit(name, n)
    cfg = EngineConfig(**cfg_kw)

    def once():
        with Simulator(qc, cfg) as sim:
            result = sim.run()
            state = result.statevector() if collect_state else None
            return state, sim.stats

    (state, stats), dt = timed(once)
    return qc, state, stats, dt


def fidelity_vs_dense(qc, state) -> float:
    ideal = np.asarray(simulate_dense(qc))
    return fidelity(ideal.astype(np.complex128), state.astype(np.complex128))
