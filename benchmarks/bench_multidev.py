"""Fig. 13: multi-device scaling on a virtual mesh (subprocess with
forced host device counts, like the paper's 1/2/4 GPUs).

Two sharding modes, each swept over 1/2/4/8 virtual devices:

* ``lanes_{d}_*``   — lane-sharded batch (qft-18, K=8 lanes): each device
  runs its contiguous lane slice, zero cross-device exchange.
* ``devices_{d}_*`` — block-sharded single state (qft-18): SV groups are
  placed round-robin on the mesh and only *encoded* wire crosses device
  boundaries at stage hand-offs.

Each measurement runs in a fresh subprocess (the device count is an XLA
startup flag) that prints one machine-readable ``BMQSIM_RESULT {json}``
line; the driver checks the exit code and surfaces stderr instead of
crashing on ``float(stdout.split(...))``.  On a single-core container
the recorded speedups are honest ~1.0x — the row exists so a real
multi-core runner records scaling and compare.py gates it from then on.

``BMQSIM_MULTIDEV_SMOKE=1`` shrinks the sweep (qft-12, K=4, 1/2 devices,
``smoke_``-prefixed keys) so CI can exercise the harness in seconds.
"""
import json
import os
import subprocess
import sys
import textwrap

from .common import emit

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
_TAG = "BMQSIM_RESULT "

_CODE = """
import json, time, jax
import numpy as np
from repro.core import (EngineConfig, Simulator, build_circuit, fidelity,
                        simulate_dense)

mode, n, k, b = {mode!r}, {n}, {k}, {b}
qc = build_circuit("qft", n)
cfg = EngineConfig(local_bits=b, mesh_shape=len(jax.devices()),
                   batch=k if mode == "lanes" else 1)
out = {{"devices": len(jax.devices())}}
t0 = time.perf_counter()
with Simulator(qc, cfg) as sim:
    if mode == "lanes":
        sim.run(trajectories=k)
        out["t"] = time.perf_counter() - t0
    else:
        result = sim.run()
        out["t"] = time.perf_counter() - t0
        ideal = np.asarray(simulate_dense(qc)).astype(np.complex128)
        out["fidelity"] = float(fidelity(
            ideal, result.statevector().astype(np.complex128)))
    out["exchange_bytes"] = sim.stats.exchange_bytes
    out["n_exchanged_blocks"] = sim.stats.n_exchanged_blocks
print({tag!r} + json.dumps(out))
"""


def _run_one(mode: str, ndev: int, n: int, k: int, b: int) -> dict:
    """One measurement in a subprocess with ``ndev`` forced host devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={ndev}"
                        ).strip()
    env["PYTHONPATH"] = "src"
    env.setdefault("JAX_PLATFORMS", "cpu")
    code = textwrap.dedent(_CODE).format(mode=mode, n=n, k=k, b=b, tag=_TAG)
    proc = subprocess.run([sys.executable, "-c", code], capture_output=True,
                          text=True, env=env, timeout=3600, cwd=_ROOT)
    if proc.returncode != 0:
        raise RuntimeError(
            f"multidev subprocess (mode={mode} devices={ndev}) exited "
            f"{proc.returncode}; stderr tail:\n{proc.stderr[-4000:]}")
    for line in reversed(proc.stdout.splitlines()):
        if line.startswith(_TAG):
            return json.loads(line[len(_TAG):])
    raise RuntimeError(
        f"multidev subprocess (mode={mode} devices={ndev}) printed no "
        f"{_TAG!r} line; stdout tail:\n{proc.stdout[-2000:]}\n"
        f"stderr tail:\n{proc.stderr[-2000:]}")


def main():
    smoke = os.environ.get("BMQSIM_MULTIDEV_SMOKE") == "1"
    pre = "smoke_" if smoke else ""
    n, k, b = (12, 4, 8) if smoke else (18, 8, 12)
    sweep = (1, 2) if smoke else (1, 2, 4, 8)

    base = None
    for ndev in sweep:
        r = _run_one("lanes", ndev, n, k, b)
        base = base or r["t"]
        emit("multidev", f"{pre}lanes_{ndev}_s", r["t"])
        emit("multidev", f"{pre}lanes_{ndev}_speedup", base / r["t"])

    base = None
    for ndev in sweep:
        r = _run_one("block", ndev, n, k, b)
        base = base or r["t"]
        emit("multidev", f"{pre}devices_{ndev}_s", r["t"])
        emit("multidev", f"{pre}devices_{ndev}_speedup", base / r["t"])
    # last sweep entry is the widest mesh: record its readout fidelity and
    # how much smaller the encoded exchange wire is than raw block bytes
    emit("multidev", f"{pre}blockshard_fidelity", r["fidelity"])
    if r["fidelity"] < 0.99:
        raise RuntimeError(
            f"block-sharded fidelity {r['fidelity']:.6f} < 0.99 on "
            f"{sweep[-1]} devices")
    if r["n_exchanged_blocks"]:
        raw = r["n_exchanged_blocks"] * (1 << b) * 8   # complex64 blocks
        emit("multidev", f"{pre}exchange_compression_speedup",
             raw / r["exchange_bytes"])


if __name__ == "__main__":
    main()
