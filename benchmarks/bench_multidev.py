"""Fig. 13: multi-device scaling of independent SV groups (subprocess
with forced host device counts, like the paper's 1/2/4 GPUs)."""
import os
import subprocess
import sys
import textwrap

from .common import emit

_CODE = """
import time, jax
from repro.core import build_circuit, EngineConfig, Simulator
qc = build_circuit("qft", 14)
cfg = EngineConfig(local_bits=7, devices=jax.devices())
t0 = time.perf_counter()
with Simulator(qc, cfg) as sim:
    sim.run()
print("T", time.perf_counter() - t0)
"""


def main():
    base = None
    for ndev in (1, 2, 4):
        env = dict(os.environ)
        env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={ndev}"
        env["PYTHONPATH"] = "src"
        out = subprocess.run([sys.executable, "-c", textwrap.dedent(_CODE)],
                             capture_output=True, text=True, env=env,
                             timeout=900, cwd=os.path.dirname(
                                 os.path.dirname(os.path.abspath(__file__))))
        t = float(out.stdout.split("T")[-1])
        base = base or t
        emit("multidev", f"devices_{ndev}_s", t)
        emit("multidev", f"devices_{ndev}_speedup", base / t)


if __name__ == "__main__":
    main()
