"""Fig. 8: fidelity across all 8 circuits (paper: > 0.99 everywhere)."""
from .common import ALL_CIRCUITS, emit, fidelity_vs_dense, run_engine


def main():
    for name in ALL_CIRCUITS:
        qc, state, stats, _ = run_engine(name, 12, local_bits=6)
        emit("fidelity", name, fidelity_vs_dense(qc, state))
        emit("fidelity", f"{name}_stages", stats.n_stages)
        emit("fidelity", f"{name}_gates", stats.n_gates)


if __name__ == "__main__":
    main()
