"""Service tier: cold-compile vs warm-cache latency + lane-merge throughput.

Two measurements of `SimService` (docs/SERVING.md):

* cold vs warm — submit+drain wall latency for the FIRST job of each of
  8 distinct circuit structures (cold: Simulator built, plan compiled,
  stage fns jitted) vs an immediate resubmit of the same structure
  (warm: pooled session, everything reused).  Reported as p50/p95 over
  the 8 structures; the cold/warm gap is the session pool's whole value.
* continuous lane batching — 4 same-structure jobs submitted one-at-a-
  time (4 width-1 rounds) vs co-submitted (ONE width-4 `run_batch` lane
  stack).  `batch_merge_speedup` = sequential/merged wall time; merging
  amortizes the per-round jitted dispatch + boundary crossing exactly
  like `run_batch` beats the sequential loop, so it must stay >= 1.

CPU timings here are noisy (2-3x swings); the merge comparison
interleaves the two modes and reports median-over-reps so drift hits
both sides alike, and the speedup is a within-run ratio so machine
speed cancels.
"""
from __future__ import annotations

import statistics
import time

from repro.core import EngineConfig, SimService, build_circuit

from .common import emit

#: distinct structures for the cold/warm sweep (one cold compile each)
STRUCTURES = ["qft", "ising", "ghz_state", "bv", "cc", "qaoa",
              "cat_state", "qsvm"]
N = 10
B = 6
BUDGET = 256 << 20

#: the merge comparison runs dispatch-bound (small state, sub-second
#: rounds): per (stage, group) the width-4 stack pays ONE jitted
#: dispatch + boundary crossing where sequential rounds pay four, and
#: short rounds let many interleaved reps beat down container noise
MERGE_NAME, MERGE_N, MERGE_B = "qft", 10, 6
MERGE_K = 4
REPS = 9


def _pctl(samples: list[float], q: float) -> float:
    ordered = sorted(samples)
    idx = min(len(ordered) - 1, round(q * (len(ordered) - 1)))
    return ordered[int(idx)]


def main() -> None:
    cfg = EngineConfig(local_bits=B)
    cold, warm = [], []
    with SimService(BUDGET, config=cfg,
                    max_sessions=len(STRUCTURES)) as svc:
        for name in STRUCTURES:
            qc = build_circuit(name, N)
            t0 = time.perf_counter()
            job = svc.submit(qc)
            svc.drain()
            cold.append(time.perf_counter() - t0)
            assert job.cold and job.state == "done"
            t0 = time.perf_counter()
            job = svc.submit(qc)
            svc.drain()
            warm.append(time.perf_counter() - t0)
            assert not job.cold and job.state == "done"
        emit("serve", "cold_p50_s", _pctl(cold, 0.50))
        emit("serve", "cold_p95_s", _pctl(cold, 0.95))
        emit("serve", "warm_p50_s", _pctl(warm, 0.50))
        emit("serve", "warm_p95_s", _pctl(warm, 0.95))
        emit("serve", "cold_over_warm_p50",
             _pctl(cold, 0.50) / _pctl(warm, 0.50))

    qc = build_circuit(MERGE_NAME, MERGE_N)
    cfg = EngineConfig(local_bits=MERGE_B)
    with SimService(BUDGET, config=cfg) as svc:
        # prewarm BOTH dispatch widths: the jitted stage fns specialize on
        # lane count, and a serving system pays each width's compile once —
        # the rows below are steady-state round times, not first-batch jit
        svc.submit(qc)
        svc.drain()
        for i in range(MERGE_K):
            svc.submit(qc, seed=i)
        svc.drain()

        seq_reps, mrg_reps = [], []
        for _ in range(REPS):             # interleaved A/B, median-of-reps:
            t0 = time.perf_counter()      # the ~5-10% merge win is real but
            for i in range(MERGE_K):      # container timings swing 2-3x
                svc.submit(qc, seed=i)    # one-at-a-time: width-1 rounds
                svc.drain()
            seq_reps.append(time.perf_counter() - t0)

            t0 = time.perf_counter()
            jobs = [svc.submit(qc, seed=i) for i in range(MERGE_K)]
            svc.drain()                   # co-admitted: ONE width-K stack
            mrg_reps.append(time.perf_counter() - t0)
            assert all(j.merge_width == MERGE_K for j in jobs)

        sequential = statistics.median(seq_reps)
        merged = statistics.median(mrg_reps)
        emit("serve", f"sequential_{MERGE_K}jobs_s", sequential)
        emit("serve", f"merged_{MERGE_K}jobs_s", merged)
        emit("serve", "batch_merge_speedup", sequential / merged)
        emit("serve", "max_merge_width", svc.stats.max_merge_width)


if __name__ == "__main__":
    main()
