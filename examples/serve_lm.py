"""Serve a small LM with batched requests: prefill + decode loop, with
optional pwrel-compressed KV cache (the paper's technique as a serving
feature — 1.78x less cache HBM).

    PYTHONPATH=src python examples/serve_lm.py --arch qwen3-4b \
        --batch 4 --prompt-len 32 --gen 16 [--compressed-kv]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config, reduced_config
from repro.models import transformer as T
from repro.serving.kvcache import compress_prefill_cache


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--compressed-kv", action="store_true")
    args = ap.parse_args()

    cfg = reduced_config(get_config(args.arch))
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    max_len = args.prompt_len + args.gen
    prompts = jax.random.randint(key, (args.batch, args.prompt_len), 0,
                                 cfg.vocab)

    t0 = time.perf_counter()
    logits, cache = T.forward_prefill(cfg, params, prompts, max_len=max_len)
    if args.compressed_kv:
        cache = compress_prefill_cache(cache)
        nbytes = sum(x.nbytes for x in jax.tree.leaves(cache))
        print(f"compressed KV cache: {nbytes/2**20:.2f} MiB")
    t_prefill = time.perf_counter() - t0

    decode = jax.jit(
        lambda p, tok, c, pos: T.forward_decode(cfg, p, tok, c, pos))
    tok = jnp.argmax(logits, -1)[:, None]
    outs = [tok]
    t0 = time.perf_counter()
    for i in range(args.gen):
        logits, cache = decode(params, tok, cache, args.prompt_len + i)
        tok = jnp.argmax(logits, -1)[:, None]
        outs.append(tok)
    t_dec = time.perf_counter() - t0

    gen = jnp.concatenate(outs, 1)
    print(f"arch {cfg.name} batch={args.batch} prompt={args.prompt_len}")
    print(f"prefill {t_prefill*1e3:.0f} ms | "
          f"decode {t_dec/args.gen*1e3:.1f} ms/tok "
          f"({args.batch*args.gen/t_dec:.1f} tok/s)")
    print("generated token ids, request 0:", gen[0].tolist())


if __name__ == "__main__":
    main()
