"""Train an LM with the full production substrate on CPU: any assigned
--arch at reduced size (default) or full config, with checkpoints,
restart-after-failure, and optional error-bounded gradient compression.

    PYTHONPATH=src python examples/train_lm.py --arch xlstm-125m \
        --steps 100 [--full] [--grad-compress] [--fail-at 30]
"""
import argparse

import jax

from repro.configs import get_config, reduced_config
from repro.models import transformer as T
from repro.optim import AdamW, GradCompressor
from repro.train.data import SyntheticTokens
from repro.train.runtime import RuntimeConfig, TrainRuntime
from repro.train.step import init_train_state, make_train_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true",
                    help="full assigned config (slow on CPU)")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--fail-at", type=int, default=None,
                    help="inject a failure at this step (restart demo)")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced_config(cfg)
    cfg = cfg.with_(remat=False)
    params = T.init_params(cfg, jax.random.PRNGKey(0))
    opt = AdamW(lr=3e-3)
    gc = GradCompressor(1e-2) if args.grad_compress else None
    state = init_train_state(cfg, params, opt, gc)
    step_fn = jax.jit(make_train_step(cfg, opt, gc))
    src = SyntheticTokens(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)

    rt = TrainRuntime(
        cfg=RuntimeConfig(ckpt_dir=args.ckpt_dir, ckpt_every=25,
                          fail_at_step=args.fail_at),
        train_step=step_fn, data_source=src)
    params, state, hist = rt.run(params, state, n_steps=args.steps)
    for m in hist[:: max(1, len(hist) // 10)]:
        print(f"step {m['step']:4d} loss {m['loss']:.4f} "
              f"({m['step_time']*1e3:.0f} ms, restarts={m['restarts']})")
    print(f"final loss {hist[-1]['loss']:.4f}")


if __name__ == "__main__":
    main()
