"""End-to-end driver (the paper's workload): a QAOA MaxCut angle sweep on
ONE simulation session.

The ansatz is a parameterized template — `gamma0`/`beta0` are bound per
`run()`, so the circuit partition, the compiled stage functions, and the
transpose-minimizing schedules are built once and reused across every
point of the sweep (`SimStats.n_stagefn_compiles` stops growing after the
first run).  Energies and samples stream from the compressed store; the
2^n state never materializes.

    PYTHONPATH=src python examples/qaoa_sim.py [--qubits 18] [--ram-mb 8]
"""
import argparse

from repro import (EngineConfig, Simulator, maxcut_cost_fn, maxcut_edges,
                   qaoa_template)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--qubits", type=int, default=18)
    ap.add_argument("--layers", type=int, default=1)
    ap.add_argument("--block-bits", type=int, default=12)
    ap.add_argument("--sweep", type=int, default=3,
                    help="number of (gamma, beta) points to evaluate")
    ap.add_argument("--ram-mb", type=float, default=None,
                    help="primary-tier budget; overflow spills to disk")
    args = ap.parse_args()

    n = args.qubits
    template = qaoa_template(n, layers=args.layers)
    cost = maxcut_cost_fn(maxcut_edges(n))
    cfg = EngineConfig(
        local_bits=args.block_bits, inner_size=2, b_r=1e-3,
        pipeline_depth=2,
        ram_budget_bytes=(int(args.ram_mb * 2 ** 20)
                          if args.ram_mb else None))

    with Simulator(template, cfg) as sim:
        print(f"qaoa n={n}: {len(template.gates)} gates, free params "
              f"{sorted(template.free_parameters)}")
        best = None
        for i in range(args.sweep):
            frac = (i + 1) / (args.sweep + 1)
            params = {}
            for l in range(args.layers):
                params[f"gamma{l}"] = 0.9 * frac
                params[f"beta{l}"] = 0.45 * frac
            result = sim.run(params=params)
            energy = result.expectation(cost)     # streamed, no 2^n array
            compiles = sim.stats.n_stagefn_compiles
            print(f"  run {i + 1}: gamma={params['gamma0']:.3f} "
                  f"beta={params['beta0']:.3f} -> <cut> = {energy:.4f} "
                  f"(stage-fn compiles so far: {compiles})")
            if best is None or energy > best[0]:
                best = (energy, params)

        stats = sim.stats
        assert stats.n_runs == args.sweep
        print(f"sweep of {stats.n_runs} runs compiled "
              f"{stats.n_stagefn_compiles} stage fns once, then scored "
              f"{stats.n_stagefn_cache_hits} cache hits")
        print(f"peak memory {stats.peak_total_bytes/2**20:.1f} MiB "
              f"(standard {stats.standard_bytes/2**20:.1f} MiB, "
              f"{stats.memory_reduction:.1f}x reduction); "
              f"spills={stats.n_spills}")
        print(f"phase times: decompress {stats.t_decompress:.2f}s "
              f"compute {stats.t_compute:.2f}s fetch {stats.t_fetch:.2f}s "
              f"compress {stats.t_compress:.2f}s total {stats.t_total:.2f}s")

        # the last run's handle is live: sample the best-energy angles'
        # state straight from the compressed store (peak extra memory =
        # one decoded block)
        result = sim.run(params=best[1])
        counts = result.sample(1024, seed=0)
        top = sorted(counts.items(), key=lambda kv: -kv[1])[:5]
        print(f"best angles gamma={best[1]['gamma0']:.3f} "
              f"beta={best[1]['beta0']:.3f}; top-5 sampled cuts:",
              [(format(k, f"0{n}b"), v) for k, v in top])


if __name__ == "__main__":
    main()
