"""End-to-end driver (the paper's workload): QAOA MaxCut simulation at the
largest size this container handles comfortably, with the full BMQSIM
stack — circuit partition, pwrel compression, two-level store, pipeline.

    PYTHONPATH=src python examples/qaoa_sim.py [--qubits 18] [--ram-mb 8]
"""
import argparse

import numpy as np

from repro.core import EngineConfig, build_circuit
from repro.core.engine import BMQSimEngine
from repro.core.measure import sample_counts


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--qubits", type=int, default=18)
    ap.add_argument("--layers", type=int, default=2)
    ap.add_argument("--block-bits", type=int, default=12)
    ap.add_argument("--ram-mb", type=float, default=None,
                    help="primary-tier budget; overflow spills to disk")
    args = ap.parse_args()

    qc = build_circuit("qaoa", args.qubits, layers=args.layers)
    cfg = EngineConfig(
        local_bits=args.block_bits, inner_size=2, b_r=1e-3,
        pipeline_depth=2,
        ram_budget_bytes=(int(args.ram_mb * 2 ** 20)
                          if args.ram_mb else None))
    eng = BMQSimEngine(qc, cfg)
    eng.run(collect_state=False)       # state never materializes
    stats = eng.stats

    print(f"qaoa n={args.qubits}: {stats.n_gates} gates -> "
          f"{stats.n_stages} stages")
    print(f"peak memory {stats.peak_total_bytes/2**20:.1f} MiB "
          f"(standard {stats.standard_bytes/2**20:.1f} MiB, "
          f"{stats.memory_reduction:.1f}x reduction)")
    print(f"spills to disk tier: {stats.n_spills}")
    print(f"phase times: decompress {stats.t_decompress:.2f}s "
          f"compute {stats.t_compute:.2f}s fetch {stats.t_fetch:.2f}s "
          f"compress {stats.t_compress:.2f}s "
          f"total {stats.t_total:.2f}s")
    # memory-conscious readout: sample bitstrings straight from the
    # compressed store (block-streaming; peak extra memory = one block)
    counts = sample_counts(eng, 1024, seed=0)
    top = sorted(counts.items(), key=lambda kv: -kv[1])[:5]
    print("top-5 sampled cuts:",
          [(format(k, f"0{args.qubits}b"), v) for k, v in top])
    eng.close()


if __name__ == "__main__":
    main()
