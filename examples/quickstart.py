"""Quickstart: a compressed simulation session in ~20 lines.

The session never materializes the 2^n state: samples, expectation
values, and single amplitudes stream straight from the compressed
block store (`statevector()` is the explicit opt-out, used here only to
score fidelity against the dense reference at this small n).

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro import (EngineConfig, Simulator, build_circuit, fidelity,
                   simulate_dense)


def main():
    qc = build_circuit("qft", 14)                    # 14-qubit QFT
    cfg = EngineConfig(local_bits=8,                 # SV block = 256 amps
                       inner_size=2,                 # Algorithm 1 threshold
                       b_r=1e-3)                     # point-wise rel. bound
    with Simulator(qc, cfg) as sim:
        result = sim.run()
        stats = sim.stats

        counts = result.sample(1024)                 # streamed readout
        amp0 = result.amplitudes([0])[0]             # one block decoded
        state = result.statevector()                 # opt-in: 2^14 is tiny

    ideal = np.asarray(simulate_dense(qc))
    print(f"circuit            : qft, n=14, {stats.n_gates} gates")
    print(f"stages (Alg. 1)    : {stats.n_stages} "
          f"(vs {stats.n_gates} per-gate compressions in SC19-Sim)")
    print(f"fidelity           : "
          f"{fidelity(ideal.astype(np.complex128), state.astype(np.complex128)):.6f}")
    print(f"peak memory        : {stats.peak_total_bytes/2**20:.2f} MiB "
          f"(standard: {stats.standard_bytes/2**20:.1f} MiB, "
          f"{stats.memory_reduction:.1f}x less)")
    print(f"readout            : {len(counts)} distinct outcomes in 1024 "
          f"shots, |<0|psi>| = {abs(amp0):.6f}")


if __name__ == "__main__":
    main()
