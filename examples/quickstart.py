"""Quickstart: compressed state-vector simulation in ~20 lines.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import (EngineConfig, build_circuit, fidelity,
                        simulate_bmqsim, simulate_dense)


def main():
    qc = build_circuit("qft", 14)                    # 14-qubit QFT
    cfg = EngineConfig(local_bits=8,                 # SV block = 256 amps
                       inner_size=2,                 # Algorithm 1 threshold
                       b_r=1e-3)                     # point-wise rel. bound
    state, stats = simulate_bmqsim(qc, cfg)

    ideal = np.asarray(simulate_dense(qc))
    print(f"circuit            : qft, n=14, {stats.n_gates} gates")
    print(f"stages (Alg. 1)    : {stats.n_stages} "
          f"(vs {stats.n_gates} per-gate compressions in SC19-Sim)")
    print(f"fidelity           : "
          f"{fidelity(ideal.astype(np.complex128), state.astype(np.complex128)):.6f}")
    print(f"peak memory        : {stats.peak_total_bytes/2**20:.2f} MiB "
          f"(standard: {stats.standard_bytes/2**20:.1f} MiB, "
          f"{stats.memory_reduction:.1f}x less)")


if __name__ == "__main__":
    main()
