"""Stage pipeline + codec backends: host/device parity, boundary bytes,
cross-backend store compatibility."""
import numpy as np
import pytest

from repro.compression import PwRelParams
from repro.compression.device_codec import (decode_block_device,
                                            encode_group_device,
                                            fetch_group_wire,
                                            segments_to_wire,
                                            wire_to_segments)
from repro.core import (EngineConfig, build_circuit, fidelity,
                        simulate_bmqsim, simulate_dense)

import jax


def _fidelity_vs_dense(qc, state) -> float:
    ideal = np.asarray(simulate_dense(qc)).astype(np.complex128)
    return fidelity(ideal, state.astype(np.complex128))


@pytest.mark.parametrize("backend", ["host", "device"])
@pytest.mark.parametrize("name,n", [("ghz_state", 10), ("qft", 10)])
def test_backend_fidelity_vs_dense(backend, name, n):
    qc = build_circuit(name, n)
    state, stats = simulate_bmqsim(
        qc, EngineConfig(local_bits=6, b_r=1e-3, codec_backend=backend))
    assert _fidelity_vs_dense(qc, state) >= 0.99
    assert stats.h2d_bytes > 0 and stats.d2h_bytes > 0
    assert len(stats.per_stage_boundary_bytes) == stats.n_stages


@pytest.mark.parametrize("name", ["ghz_state", "qft"])
def test_backends_agree_and_device_moves_fewer_bytes(name):
    qc = build_circuit(name, 10)
    out = {}
    for backend in ("host", "device"):
        state, stats = simulate_bmqsim(
            qc, EngineConfig(local_bits=6, b_r=1e-3, codec_backend=backend))
        out[backend] = (state, stats)
    sh, st_h = out["host"]
    sd, st_d = out["device"]
    # same lossy math on both sides of the boundary -> near-identical states
    f = fidelity(sh.astype(np.complex128), sd.astype(np.complex128))
    assert f >= 0.999999
    # the point of the device codec: strictly less boundary traffic
    assert st_d.h2d_bytes < st_h.h2d_bytes
    assert st_d.d2h_bytes < st_h.d2h_bytes
    for (h2d_d, d2h_d), (h2d_h, d2h_h) in zip(
            st_d.per_stage_boundary_bytes, st_h.per_stage_boundary_bytes):
        assert h2d_d < h2d_h and d2h_d < d2h_h


def test_device_backend_with_pipeline_depth_and_spill(tmp_path):
    qc = build_circuit("qft", 9)
    cfg = EngineConfig(local_bits=5, codec_backend="device",
                       pipeline_depth=4, ram_budget_bytes=512,
                       spill_dir=str(tmp_path))
    state, stats = simulate_bmqsim(qc, cfg)
    assert _fidelity_vs_dense(qc, state) >= 0.99
    assert stats.n_spills > 0            # disk tier actually exercised


def test_device_backend_falls_back_without_compression():
    qc = build_circuit("ghz_state", 8)
    state, stats = simulate_bmqsim(
        qc, EngineConfig(local_bits=5, compression=False,
                         codec_backend="device"))
    assert _fidelity_vs_dense(qc, state) >= 0.999999


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="codec backend"):
        simulate_bmqsim(build_circuit("ghz_state", 6),
                        EngineConfig(local_bits=4, codec_backend="gpu"))


def test_device_codec_blocks_readable_by_host_codec():
    """Blocks written by the device encoder are bit-identical to the host
    encoder's — the stored format is backend-agnostic."""
    from repro.compression.codec import decode_block_host, encode_block_host

    rng = np.random.default_rng(11)
    params = PwRelParams(1e-3)
    bsz, n_blocks = 192, 2               # non-lane-aligned block size
    amps = (rng.standard_normal(bsz * n_blocks)
            + 1j * rng.standard_normal(bsz * n_blocks)).astype(np.complex64)
    dev = jax.devices()[0]

    wire, d2h = fetch_group_wire(
        encode_group_device(jax.device_put(amps, dev), n_blocks, params))
    assert d2h < amps.nbytes
    for i, pair in enumerate(wire):
        blk = amps[i * bsz:(i + 1) * bsz]
        seg_dev = wire_to_segments(pair, bsz)
        seg_host = encode_block_host(blk, params)
        assert seg_dev == seg_host
        # and the device decoder inverts the host encoder
        amps_dev, h2d = decode_block_device(segments_to_wire(seg_host), bsz,
                                            params, dev)
        assert h2d < blk.nbytes
        np.testing.assert_array_equal(np.asarray(amps_dev),
                                      decode_block_host(seg_host, params))
