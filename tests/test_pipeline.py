"""Stage pipeline + codec backends: host/device parity, boundary bytes,
cross-backend store compatibility."""
import numpy as np
import pytest

from repro.compression import PwRelParams
from repro.compression.device_codec import (decode_block_device,
                                            encode_group_device,
                                            fetch_group_wire,
                                            segments_to_wire,
                                            wire_to_segments)
from repro.core import (EngineConfig, build_circuit, fidelity,
                        simulate_bmqsim, simulate_dense)

import jax


def _fidelity_vs_dense(qc, state) -> float:
    ideal = np.asarray(simulate_dense(qc)).astype(np.complex128)
    return fidelity(ideal, state.astype(np.complex128))


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("pipeline_depth", [1, 2])
@pytest.mark.parametrize("backend", ["host", "device"])
@pytest.mark.parametrize("name,n", [("ghz_state", 10), ("qft", 10)])
def test_backend_fidelity_vs_dense(backend, name, n, pipeline_depth,
                                   use_kernel):
    qc = build_circuit(name, n)
    state, stats = simulate_bmqsim(
        qc, EngineConfig(local_bits=6, b_r=1e-3, codec_backend=backend,
                         pipeline_depth=pipeline_depth,
                         use_kernel=use_kernel))
    assert _fidelity_vs_dense(qc, state) >= 0.99
    assert stats.h2d_bytes > 0 and stats.d2h_bytes > 0
    assert len(stats.per_stage_boundary_bytes) == stats.n_stages
    assert stats.n_transposes_scheduled <= stats.n_transposes_naive


@pytest.mark.parametrize("use_kernel", [False, True])
def test_scheduled_matches_pergate_path(use_kernel):
    """The transpose-minimizing schedule and the per-gate path agree to
    float32 arithmetic noise on the same lossy pipeline."""
    qc = build_circuit("qft", 9)
    out = {}
    for gs in (False, True):
        state, stats = simulate_bmqsim(
            qc, EngineConfig(local_bits=5, b_r=1e-3, use_kernel=use_kernel,
                             gate_schedule=gs))
        out[gs] = (state, stats)
    f = fidelity(out[False][0].astype(np.complex128),
                 out[True][0].astype(np.complex128))
    assert f >= 0.999999
    # the point of the schedule: strictly fewer full-group transposes
    assert (out[True][1].n_transposes_scheduled
            < out[True][1].n_transposes_naive)


@pytest.mark.parametrize("name", ["ghz_state", "qft"])
def test_backends_agree_and_device_moves_fewer_bytes(name):
    qc = build_circuit(name, 10)
    out = {}
    for backend in ("host", "device"):
        state, stats = simulate_bmqsim(
            qc, EngineConfig(local_bits=6, b_r=1e-3, codec_backend=backend))
        out[backend] = (state, stats)
    sh, st_h = out["host"]
    sd, st_d = out["device"]
    # same lossy math on both sides of the boundary -> near-identical states
    f = fidelity(sh.astype(np.complex128), sd.astype(np.complex128))
    assert f >= 0.999999
    # the point of the device codec: strictly less boundary traffic
    assert st_d.h2d_bytes < st_h.h2d_bytes
    assert st_d.d2h_bytes < st_h.d2h_bytes
    for (h2d_d, d2h_d), (h2d_h, d2h_h) in zip(
            st_d.per_stage_boundary_bytes, st_h.per_stage_boundary_bytes):
        assert h2d_d < h2d_h and d2h_d < d2h_h


def test_device_backend_with_pipeline_depth_and_spill(tmp_path):
    qc = build_circuit("qft", 9)
    cfg = EngineConfig(local_bits=5, codec_backend="device",
                       pipeline_depth=4, ram_budget_bytes=512,
                       spill_dir=str(tmp_path))
    state, stats = simulate_bmqsim(qc, cfg)
    assert _fidelity_vs_dense(qc, state) >= 0.99
    assert stats.n_spills > 0            # disk tier actually exercised


def test_device_backend_falls_back_without_compression():
    qc = build_circuit("ghz_state", 8)
    with pytest.warns(RuntimeWarning, match="falling back to the host"):
        state, stats = simulate_bmqsim(
            qc, EngineConfig(local_bits=5, compression=False,
                             codec_backend="device"))
    assert _fidelity_vs_dense(qc, state) >= 0.999999


def test_device_backend_no_warning_with_compression():
    import warnings

    qc = build_circuit("ghz_state", 8)
    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        state, _ = simulate_bmqsim(
            qc, EngineConfig(local_bits=5, codec_backend="device"))
    assert not [w for w in caught if "falling back to the host" in str(w.message)]
    assert _fidelity_vs_dense(qc, state) >= 0.99


def test_unknown_backend_rejected():
    with pytest.raises(ValueError, match="codec backend"):
        simulate_bmqsim(build_circuit("ghz_state", 6),
                        EngineConfig(local_bits=4, codec_backend="gpu"))


def test_planes_path_matches_dense_on_random_circuits():
    """Hypothesis property: the planes-resident scheduled path tracks the
    dense oracle on random circuits across layouts and backends."""
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    from repro.core import random_circuit

    @settings(max_examples=12, deadline=None)
    @given(n=st.integers(4, 8), b=st.integers(2, 6),
           n_gates=st.integers(1, 30), seed=st.integers(0, 10_000),
           backend=st.sampled_from(["host", "device"]),
           use_kernel=st.booleans())
    def prop(n, b, n_gates, seed, backend, use_kernel):
        qc = random_circuit(n, n_gates, seed=seed)
        state, stats = simulate_bmqsim(
            qc, EngineConfig(local_bits=min(b, n), b_r=1e-4,
                             codec_backend=backend, use_kernel=use_kernel,
                             gate_schedule=True))
        assert _fidelity_vs_dense(qc, state) >= 1 - 1e-3
        assert stats.n_transposes_scheduled <= stats.n_transposes_naive

    prop()


# -- pipeline depth is a pure *scheduling* knob ------------------------------
#
# The wave-coalesced scheduler must never change the answer.  Host backend
# states are bitwise identical across depths (same jitted ops, same block
# codec, only the dispatch grouping differs); the device codec's batched
# encode launches different kernel grids, so it gets a TV-distance /
# fidelity bound instead.

def _depth_states(qc, backend, depth, batched):
    from repro.core import Simulator

    cfg = EngineConfig(local_bits=3, inner_size=2, b_r=1e-3,
                       codec_backend=backend, pipeline_depth=depth)
    if batched:
        with Simulator(qc, cfg) as sim:
            batch = sim.run_batch([None] * 2)
            return [np.asarray(lane.statevector()) for lane in batch]
    state, _ = simulate_bmqsim(qc, cfg)
    return [np.asarray(state)]


def _tv_distance(a, b):
    return 0.5 * np.sum(np.abs(np.abs(a.astype(np.complex128)) ** 2
                               - np.abs(b.astype(np.complex128)) ** 2))


def _check_depth_invariance(n, n_gates, seed, backend, batched):
    from repro.core import random_circuit

    qc = random_circuit(n, n_gates, seed=seed)
    ref = _depth_states(qc, backend, 1, batched)
    for depth in (2, 4):
        got = _depth_states(qc, backend, depth, batched)
        assert len(got) == len(ref)
        for a, b in zip(ref, got):
            if backend == "host":
                np.testing.assert_array_equal(a, b)       # bitwise
            else:
                f = fidelity(a.astype(np.complex128), b.astype(np.complex128))
                assert f >= 1 - 1e-7
                assert _tv_distance(a, b) <= 1e-5


@pytest.mark.parametrize("batched", [False, True])
@pytest.mark.parametrize("backend", ["host", "device"])
def test_depth_invariant_smoke(backend, batched):
    """Always-on deterministic slice of the depth-invariance property."""
    _check_depth_invariance(6, 12, seed=3, backend=backend, batched=batched)


def test_final_state_invariant_across_pipeline_depths():
    """Hypothesis property: random circuits, depth {1, 2, 4} x backend
    {host, device} x {single-lane, lane-batched} all agree (bitwise on
    host, TV/fidelity on device)."""
    pytest.importorskip("hypothesis", reason="property tests need hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=8, deadline=None)
    @given(n=st.integers(5, 7), n_gates=st.integers(3, 18),
           seed=st.integers(0, 10_000),
           backend=st.sampled_from(["host", "device"]),
           batched=st.booleans())
    def prop(n, n_gates, seed, backend, batched):
        _check_depth_invariance(n, n_gates, seed, backend, batched)

    prop()


def test_device_codec_blocks_readable_by_host_codec():
    """Blocks written by the device encoder are bit-identical to the host
    encoder's — the stored format is backend-agnostic."""
    from repro.compression.codec import decode_block_host, encode_block_host

    rng = np.random.default_rng(11)
    params = PwRelParams(1e-3)
    bsz, n_blocks = 192, 2               # non-lane-aligned block size
    amps = (rng.standard_normal(bsz * n_blocks)
            + 1j * rng.standard_normal(bsz * n_blocks)).astype(np.complex64)
    dev = jax.devices()[0]

    wire, d2h = fetch_group_wire(
        encode_group_device(jax.device_put(amps, dev), n_blocks, params))
    assert d2h < amps.nbytes
    for i, pair in enumerate(wire):
        blk = amps[i * bsz:(i + 1) * bsz]
        seg_dev = wire_to_segments(pair, bsz)
        seg_host = encode_block_host(blk, params)
        assert seg_dev == seg_host
        # and the device decoder inverts the host encoder
        amps_dev, h2d = decode_block_device(segments_to_wire(seg_host), bsz,
                                            params, dev)
        assert h2d < blk.nbytes
        np.testing.assert_array_equal(np.asarray(amps_dev),
                                      decode_block_host(seg_host, params))
