"""Multi-device behaviour (subprocess with 8 host devices, since the
parent process is pinned to 1 device): BMQSIM group-parallel equivalence,
dense sharded baseline, sharding rules on a real mesh."""
import os
import subprocess
import sys
import textwrap

ROOT = os.path.join(os.path.dirname(__file__), "..")


def _run_sub(code: str, devices: int = 8) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["PYTHONPATH"] = os.path.join(ROOT, "src")
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=600)
    assert out.returncode == 0, out.stderr[-4000:]
    return out.stdout


def test_engine_multidevice_equals_single():
    """SV groups round-robined over 8 devices == single device (zero
    collectives by construction — the paper's multi-GPU property)."""
    out = _run_sub("""
        import jax, numpy as np
        from repro.core import build_circuit, simulate_bmqsim, EngineConfig, simulate_dense, fidelity
        qc = build_circuit("qft", 10)
        ideal = np.asarray(simulate_dense(qc))
        s1, st1 = simulate_bmqsim(qc, EngineConfig(local_bits=4))
        s8, st8 = simulate_bmqsim(qc, EngineConfig(local_bits=4,
                                                   devices=jax.devices()))
        assert len(jax.devices()) == 8
        np.testing.assert_allclose(s1, s8, atol=2e-5)
        print("FID", fidelity(ideal.astype(np.complex128), s8.astype(np.complex128)))
    """)
    assert float(out.split("FID")[1]) > 0.99


def test_dense_sharded_baseline():
    """SV-Sim-like pjit engine (state sharded over devices) == dense."""
    out = _run_sub("""
        import jax, numpy as np
        from repro.core import build_circuit, simulate_dense, simulate_dense_sharded
        qc = build_circuit("ghz_state", 8)
        mesh = jax.make_mesh((8,), ("data",))
        a = np.asarray(simulate_dense(qc))
        b = np.asarray(simulate_dense_sharded(qc, mesh))
        np.testing.assert_allclose(a, b, atol=1e-6)
        print("OK")
    """)
    assert "OK" in out


def test_sharded_train_step_runs():
    """A reduced model executes a REAL sharded train step on a 4x2 mesh
    with the production sharding rules (not just lowering)."""
    out = _run_sub("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.configs import get_config, reduced_config
        from repro.distributed.sharding import activate_mesh, param_pspecs, named_shardings
        from repro.models import transformer as T
        from repro.optim import AdamW
        from repro.train.step import init_train_state, make_train_step
        from jax.sharding import NamedSharding, PartitionSpec as P

        cfg = reduced_config(get_config("qwen3-4b")).with_(remat=False)
        mesh = jax.make_mesh((4, 2), ("data", "model"))
        params = T.init_params(cfg, jax.random.PRNGKey(0))
        pspecs = param_pspecs(cfg, params, mesh)
        params = jax.device_put(params, named_shardings(pspecs, mesh))
        opt = AdamW(lr=1e-3)
        state = init_train_state(cfg, params, opt)
        step = jax.jit(make_train_step(cfg, opt))
        toks = jnp.zeros((8, 16), jnp.int32)
        with activate_mesh(mesh):
            params, state, m = step(params, state, {"tokens": toks})
        assert np.isfinite(float(m["loss"]))
        # params kept their shardings through the step
        leaf = params["units"][0]["attn"]["wq"]
        assert not leaf.sharding.is_fully_replicated
        print("LOSS", float(m["loss"]))
    """)
    assert "LOSS" in out


def test_lane_sharded_batch_bitwise_equals_single():
    """run_batch over an 8-device lanes mesh: each device runs its
    contiguous lane slice against its own store partition, so with the
    bit-exact host codec every lane statevector is BITWISE equal to the
    single-device run — and no block ever changes owners (exchange 0)."""
    out = _run_sub("""
        import numpy as np
        from repro.core import build_circuit, EngineConfig, Simulator
        qc = build_circuit("qft", 9)
        with Simulator(qc, EngineConfig(local_bits=4)) as sim:
            ref = [lane.statevector() for lane in sim.run_batch([None] * 8)]
        with Simulator(qc, EngineConfig(local_bits=4,
                                        mesh_shape=8)) as sim:
            assert len(sim._engine._devices) == 8
            sharded = [lane.statevector()
                       for lane in sim.run_batch([None] * 8)]
            assert sim.stats.exchange_bytes == 0
            assert sim.stats.n_exchanged_blocks == 0
        for r, s in zip(ref, sharded):
            assert np.array_equal(r, s)
        print("OK")
    """)
    assert "OK" in out


def test_block_sharded_device_codec_fidelity():
    """Block-sharded single run on the lossy device codec: fidelity
    >= 0.99 vs dense, and the exchange ledger shows only ENCODED wire
    crossing device boundaries (less than raw block bytes), stage sums
    consistent, stage 0 free (initial distribution is not an exchange)."""
    out = _run_sub("""
        import jax, numpy as np
        from repro.core import (build_circuit, EngineConfig, Simulator,
                                simulate_dense, fidelity)
        qc = build_circuit("qft", 10)
        ideal = np.asarray(simulate_dense(qc)).astype(np.complex128)
        with Simulator(qc, EngineConfig(local_bits=4,
                                        codec_backend="device",
                                        devices=jax.devices())) as sim:
            sv = sim.run().statevector().astype(np.complex128)
            st = sim.stats
            assert st.exchange_bytes > 0
            assert st.n_exchanged_blocks > 0
            raw = st.n_exchanged_blocks * (1 << 4) * 8
            assert st.exchange_bytes < raw
            assert sum(st.per_stage_exchange_bytes) == st.exchange_bytes
            assert st.per_stage_exchange_bytes[0] == 0
        print("FID", fidelity(ideal, sv))
    """)
    assert float(out.split("FID")[1]) > 0.99


def test_exchange_crash_resume():
    """A hard crash at a cross-device block hand-off (the new
    ``pipeline.exchange`` fault point) leaves the last stage-boundary
    checkpoint on disk; resuming reproduces the uninterrupted state
    bitwise on the host codec."""
    out = _run_sub("""
        import os, tempfile
        import jax, numpy as np, pytest
        from repro.core import build_circuit, EngineConfig, Simulator
        from repro.faults import InjectedCrash, inject_faults
        qc = build_circuit("qft", 9)
        mk = lambda: EngineConfig(local_bits=4, devices=jax.devices())
        with Simulator(qc, mk()) as sim:
            ref = sim.run().statevector()
            n_stages = sim.stats.n_stages
        ck = os.path.join(tempfile.mkdtemp(), "ck.bmq")
        with inject_faults(["pipeline.exchange:crash:hit=40"]) as inj:
            with pytest.raises(InjectedCrash):
                with Simulator(qc, mk()) as sim:
                    sim.run(checkpoint_path=ck, checkpoint_every=1)
        assert inj.fired["pipeline.exchange:crash"] == 1
        assert os.path.exists(ck)
        resumed = Simulator.resume(ck, circuit=qc, config=mk())
        try:
            assert 0 < resumed._start_stage < n_stages
            assert np.array_equal(resumed.run().statevector(), ref)
        finally:
            resumed.close()
        print("OK")
    """)
    assert "OK" in out


def test_multidevice_scaling_stats():
    """Fig. 13 harness sanity: per-device group placement covers all groups."""
    out = _run_sub("""
        import jax
        from repro.core import build_circuit, EngineConfig
        from repro.core.engine import BMQSimEngine
        qc = build_circuit("qaoa", 10)
        eng = BMQSimEngine(qc, EngineConfig(local_bits=4,
                                            devices=jax.devices()))
        state = eng.run()
        import numpy as np
        print("NORM", float(np.linalg.norm(state)))
        eng.close()
    """)
    assert abs(float(out.split("NORM")[1]) - 1.0) < 5e-3
