"""Compression substrate property tests (require ``hypothesis``).

Plain (no-optional-deps) codec/store tests live in test_codec_store.py.
"""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st
from hypothesis.extra import numpy as hnp

from repro.compression import (PwRelParams, compress_complex_block,
                               decompress_complex_block)
from repro.compression.codec import (prescan_decode_bitmap,
                                     prescan_encode_bitmap)
from repro.compression.pwrel import dequantize_plane, quantize_plane


@settings(max_examples=30, deadline=None)
@given(hnp.arrays(np.float32, st.integers(1, 400),
                  elements=st.floats(min_value=np.float32(-1e30),
                                     max_value=np.float32(1e30), width=32)),
       st.sampled_from([1e-2, 1e-3, 1e-4]))
def test_pwrel_pointwise_bound(x, b_r):
    """The defining property: point-wise relative error <= b_r (f32 slack),
    zeros exact, signs exact — for arbitrary floats incl. subnormals."""
    from repro.compression.pwrel import log_step
    params = PwRelParams(b_r=b_r)
    codes, signs, l_max = quantize_plane(x, params)
    xhat = np.asarray(dequantize_plane(codes, signs, l_max, params))
    max_abs = float(np.abs(x).max()) if x.size else 0.0
    floor = max_abs * 2.0 ** (-65520 * log_step(b_r))  # uint16 range floor
    # bound holds for NORMAL floats; subnormal magnitudes may flush to 0
    # in XLA's FTZ arithmetic (documented contract, like the paper's
    # bitcomp on denormals)
    big = np.abs(x) > max(floor, 1.2e-38)
    if big.any():
        rel = np.abs(xhat[big] - x[big]) / np.abs(x[big])
        assert rel.max() <= b_r * 1.1 + 1e-6, rel.max()
    assert np.all(xhat[x == 0] == 0)
    nz = (x != 0) & (xhat != 0)
    assert np.all(np.sign(xhat[nz]) == np.sign(x[nz]))


@settings(max_examples=25, deadline=None)
@given(st.integers(4, 2048), st.integers(0, 10_000), st.floats(0.0, 1.0))
def test_codec_roundtrip(n, seed, sparsity):
    rng = np.random.default_rng(seed)
    amps = (rng.standard_normal(n) + 1j * rng.standard_normal(n)) \
        .astype(np.complex64)
    amps[rng.random(n) < sparsity] = 0
    params = PwRelParams(b_r=1e-3)
    blk = compress_complex_block(amps, params)
    out = decompress_complex_block(blk, params)
    assert out.shape == amps.shape
    nz = amps != 0
    if nz.any():
        rel = np.abs(out[nz] - amps[nz]) / np.abs(amps[nz])
        assert rel.max() < 2.5e-3        # sqrt(2)*b_r (re/im independent)
    assert np.all(out[~nz] == 0)


@settings(max_examples=30, deadline=None)
@given(hnp.arrays(np.bool_, st.integers(1, 5000)))
def test_prescan_bitmap_roundtrip(bits):
    blob = prescan_encode_bitmap(bits)
    out = prescan_decode_bitmap(blob)
    np.testing.assert_array_equal(out, bits)


def test_prescan_helps_on_uniform_signs():
    bits = np.zeros(2 ** 15, bool)       # all-positive block
    with_ps = len(prescan_encode_bitmap(bits))
    assert with_ps < 2 ** 15 // 8 / 10   # >10x smaller than raw packed
