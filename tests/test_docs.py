"""Docs stay honest: the generated API reference matches the live
public surface, the doc tree's relative links resolve, and the
generator/linkcheck CLIs behave as CI invokes them."""
import os

import pytest

from repro.analysis import api_doc, linkcheck

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
API_MD = os.path.join(REPO, "docs", "API.md")


# -- API drift (the CI docs gate, in-process) --------------------------------

def test_api_md_matches_live_surface():
    """docs/API.md is generated — regenerate and compare byte-for-byte.

    Fails when repro.__all__ gains/loses/renames an export, a signature
    changes, or a first docstring line changes, without the committed
    doc being regenerated (python -m repro.analysis.api_doc --write)."""
    with open(API_MD, encoding="utf-8") as fh:
        committed = fh.read()
    assert committed == api_doc.generate()


def test_every_export_has_entry_and_summary():
    import repro

    text = api_doc.generate()
    for name in repro.__all__:
        assert f"### `{name}`" in text
    assert "(no docstring)" not in text   # every export carries a summary


def test_sections_cover_all_in_declared_order():
    import repro

    flat = [n for _, names in api_doc._sections() for n in names]
    assert flat == list(repro.__all__)


def test_api_doc_check_mode_detects_drift(tmp_path, capsys):
    good = tmp_path / "API.md"
    good.write_text(api_doc.generate(), encoding="utf-8")
    assert api_doc.main(["--check", str(good)]) == 0

    stale = tmp_path / "stale.md"
    stale.write_text("# Public API reference\n\nold\n", encoding="utf-8")
    assert api_doc.main(["--check", str(stale)]) == 1
    assert "--write docs/API.md" in capsys.readouterr().out

    missing = tmp_path / "absent.md"
    assert api_doc.main(["--check", str(missing)]) == 1


def test_signature_rendering_is_stable():
    """The two rendering pitfalls pinned: keyword-only markers appear
    exactly once, and no default leaks a memory address."""
    text = api_doc.generate()
    for block in text.split("```python")[1:]:
        sig = block.split("```")[0]
        assert sig.count("\n    *,\n") <= 1
        assert "0x" not in sig


# -- link check --------------------------------------------------------------

def test_repo_docs_have_no_broken_links():
    assert linkcheck.check_files(
        [os.path.join(REPO, "README.md"), os.path.join(REPO, "docs")]) == []


def test_linkcheck_flags_missing_relative_target(tmp_path):
    md = tmp_path / "doc.md"
    md.write_text(
        "see [other](other.md) and [web](https://example.com) and\n"
        "[anchor](#here) and [frag](other.md#sec)\n"
        "```\n[not a link](nope.md) in a fence\n```\n",
        encoding="utf-8")
    problems = linkcheck.check_files([str(md)])
    assert len(problems) == 2              # other.md twice, fence skipped
    (tmp_path / "other.md").write_text("x", encoding="utf-8")
    assert linkcheck.check_files([str(md)]) == []


def test_linkcheck_cli_exit_codes(tmp_path):
    ok = tmp_path / "ok.md"
    ok.write_text("[self](ok.md)\n", encoding="utf-8")
    assert linkcheck.main([str(ok)]) == 0
    bad = tmp_path / "bad.md"
    bad.write_text("[gone](gone.md)\n", encoding="utf-8")
    assert linkcheck.main([str(tmp_path)]) == 1


# -- README claims that must track the code ----------------------------------

def test_readme_quotes_real_stats_line_shape():
    """The README serving quickstart embeds a stats line; its field set
    must match ServiceStats.summary() so the transcript can't rot."""
    from repro.core.service import ServiceStats

    with open(os.path.join(REPO, "README.md"), encoding="utf-8") as fh:
        readme = fh.read()
    lines = [ln for ln in readme.splitlines() if "[serve] stats:" in ln]
    assert lines, "README lost the serve quickstart stats line"
    quoted = lines[0].split("[serve] stats: ", 1)[1]
    live_fields = [kv.split("=")[0] for kv in ServiceStats().summary().split()]
    assert [kv.split("=")[0] for kv in quoted.split()] == live_fields


@pytest.mark.parametrize("doc", ["SERVING.md", "API.md", "ARCHITECTURE.md"])
def test_readme_links_the_doc(doc):
    with open(os.path.join(REPO, "README.md"), encoding="utf-8") as fh:
        assert f"docs/{doc}" in fh.read()
