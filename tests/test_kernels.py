"""Per-kernel validation: shape/dtype sweeps, assert_allclose vs ref.py
oracles (Pallas interpret=True on CPU)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.compression.pwrel import (PwRelParams, dequantize_plane, log_step,
                                     quantize_plane)
from repro.core.dense_engine import apply_matrix
from repro.kernels import ops, ref
from repro.kernels.gate_apply import diag_apply, gemm_planes
from repro.kernels.quantize import dequantize_tiles, quantize_tiles

rng = np.random.default_rng(42)


def _rand_unitary(K):
    m = rng.standard_normal((K, K)) + 1j * rng.standard_normal((K, K))
    q, r = np.linalg.qr(m)
    return (q * (np.diag(r) / np.abs(np.diag(r)))).astype(np.complex64)


@pytest.mark.parametrize("R,K", [(8, 8), (32, 16), (256, 64), (512, 128),
                                 (1024, 128)])
def test_gemm_planes_sweep(R, K):
    ar, ai = rng.standard_normal((2, R, K)).astype(np.float32)
    br, bi = rng.standard_normal((2, K, K)).astype(np.float32)
    cr, ci = gemm_planes(*map(jnp.asarray, (ar, ai, br, bi)))
    err, eri = ref.gemm_planes_ref(*map(jnp.asarray, (ar, ai, br, bi)))
    np.testing.assert_allclose(cr, err, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(ci, eri, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("R,K", [(16, 8), (128, 32), (512, 128)])
def test_diag_apply_sweep(R, K):
    ar, ai = rng.standard_normal((2, R, K)).astype(np.float32)
    d = np.exp(1j * rng.uniform(0, 2 * np.pi, K)).astype(np.complex64)
    dr, di = np.real(d).copy(), np.imag(d).copy()
    cr, ci = diag_apply(*map(jnp.asarray, (ar, ai, dr, di)))
    err, eri = ref.diag_apply_ref(*map(jnp.asarray, (ar, ai, dr, di)))
    np.testing.assert_allclose(cr, err, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(ci, eri, rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("nv,k", [(5, 1), (6, 2), (8, 3), (10, 4), (12, 5)])
def test_apply_fused_gate_vs_dense(nv, k):
    amps = (rng.standard_normal(2 ** nv)
            + 1j * rng.standard_normal(2 ** nv)).astype(np.complex64)
    u = _rand_unitary(2 ** k)
    vq = tuple(sorted(rng.choice(nv, size=k, replace=False).tolist()))
    got = ops.apply_fused_gate(jnp.asarray(amps), jnp.asarray(u), vq, nv,
                               diag=False)
    want = apply_matrix(jnp.asarray(amps), jnp.asarray(u), vq, nv)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("rows", [1, 4, 8, 64, 256])
@pytest.mark.parametrize("b_r", [1e-2, 1e-3, 1e-4])
def test_quantize_kernel_matches_pwrel_ref(rows, b_r):
    n = rows * 128
    x = (rng.standard_normal(n) * np.exp(rng.uniform(-25, 4, n))
         ).astype(np.float32)
    x[rng.random(n) < 0.15] = 0.0
    codes_k, packed, flags, l_max_k = ops.quantize_block(jnp.asarray(x), b_r)
    codes_r, signs_r, l_max_r = quantize_plane(x, PwRelParams(b_r))
    np.testing.assert_array_equal(np.asarray(codes_k), np.asarray(codes_r))
    assert np.isclose(float(l_max_k), float(l_max_r))
    xhat = np.asarray(ops.dequantize_block(codes_k, packed, l_max_k, b_r))
    xref = np.asarray(dequantize_plane(codes_r, signs_r, l_max_r,
                                       PwRelParams(b_r)))
    np.testing.assert_array_equal(xhat, xref)
    # the bound holds above the code-range floor: max_abs * 2^-(65534*step)
    # (elements below it quantize to exact 0 by design — see pwrel.py)
    floor = float(np.abs(x).max()) * 2.0 ** (-65520 * log_step(b_r))
    nz = np.abs(x) > floor
    if nz.any():
        rel = np.abs(xhat[nz] - x[nz]) / np.abs(x[nz])
        assert rel.max() <= b_r * 1.1 + 1e-7


def test_quantize_kernel_flags():
    """Pre-scan uniformity flags: all-zero tile and uniform-sign tiles."""
    x = np.zeros(8 * 128, np.float32)
    _, _, flags, _ = ops.quantize_block(jnp.asarray(x), 1e-3)
    assert int(flags[0, 0]) == 1       # all codes zero
    assert int(flags[0, 1]) == 1       # no negative signs
    x = -np.abs(rng.standard_normal(8 * 128)).astype(np.float32) - 0.1
    _, _, flags, _ = ops.quantize_block(jnp.asarray(x), 1e-3)
    assert int(flags[0, 2]) == 1       # all negative


def test_kernels_vs_tiles_ref_direct():
    """quantize_tiles / dequantize_tiles against their ref.py twins."""
    rows = 16
    x = rng.standard_normal((rows, 128)).astype(np.float32)
    step = log_step(1e-3)
    l_max = jnp.asarray([[float(np.log2(np.abs(x).max()))]], jnp.float32)
    ck, pk, fk = quantize_tiles(jnp.asarray(x), l_max, step)
    cr, pr, fr = ref.quantize_tiles_ref(jnp.asarray(x), l_max, step)
    # codes may differ by 1 at exact rounding ties (different f32 op order
    # inside the interpreted kernel); that's still within the pwrel bound
    dc = np.abs(np.asarray(ck, np.int64) - np.asarray(cr, np.int64))
    assert dc.max() <= 1 and (dc > 0).mean() < 0.005
    np.testing.assert_array_equal(np.asarray(pk), np.asarray(pr))
    np.testing.assert_array_equal(np.asarray(fk), np.asarray(fr))
    dk = dequantize_tiles(ck, pk, l_max, step)
    dr = ref.dequantize_tiles_ref(cr, pr, l_max, step)
    np.testing.assert_allclose(np.asarray(dk), np.asarray(dr),
                               rtol=float(np.exp2(step)) - 1 + 1e-6)


def test_gemm_inside_jit():
    """Kernel wrappers compose with jax.jit (engine use_kernel path)."""
    @jax.jit
    def f(a, b):
        return gemm_planes(a, jnp.zeros_like(a), b, jnp.zeros_like(b))[0]

    a = jnp.asarray(rng.standard_normal((64, 32)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((32, 32)), jnp.float32)
    np.testing.assert_allclose(f(a, b), a @ b, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("rows", [1, 4, 8, 64])
def test_pack_unpack_codes_roundtrip(rows):
    codes = rng.integers(0, 65536, size=rows * 128, dtype=np.int64)
    packed = ops.pack_codes(jnp.asarray(codes))
    assert packed.shape == (rows, 64) and packed.dtype == jnp.int32
    # little-endian view of the words is the row-major uint16 stream
    u16 = np.ascontiguousarray(np.asarray(packed)).view("<u2").reshape(-1)
    np.testing.assert_array_equal(u16, codes.astype(np.uint16))
    back = ops.unpack_codes(packed)
    np.testing.assert_array_equal(np.asarray(back), codes)


@pytest.mark.parametrize("rows", [1, 8, 32])
def test_pack_unpack_bitmap_roundtrip(rows):
    bits = rng.random(rows * 128) < 0.3
    packed = ops.pack_sign_bitmap(jnp.asarray(bits))
    assert packed.shape == (rows, 4) and packed.dtype == jnp.int32
    back = ops.unpack_sign_bitmap(packed)
    np.testing.assert_array_equal(np.asarray(back), bits)
    # matches the pack fused into the quantizer kernel
    x = np.where(bits, -1.0, 1.0).astype(np.float32) * \
        rng.uniform(0.5, 2.0, rows * 128).astype(np.float32)
    _, packed_q, _, _ = ops.quantize_block(jnp.asarray(x), b_r=1e-3)
    np.testing.assert_array_equal(np.asarray(packed_q), np.asarray(packed))
