"""Training substrate: optimizer correctness, grad-compression convergence
preservation, loss goes down on the synthetic task."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import transformer as T
from repro.optim import AdamW, Adafactor, GradCompressor
from repro.train.data import SyntheticTokens, make_batches
from repro.train.step import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def test_adamw_quadratic():
    """AdamW minimizes a quadratic."""
    opt = AdamW(lr=0.1)
    params = {"w": jnp.array([3.0, -2.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = {"w": 2.0 * params["w"]}
        params, state = opt.update(grads, state, params)
    assert float(jnp.abs(params["w"]).max()) < 1e-2


def test_adamw_bf16_moments():
    opt = AdamW(lr=0.05, moment_dtype="bfloat16")
    params = {"w": jnp.array([1.0, -1.0])}
    state = opt.init(params)
    assert state["m"]["w"].dtype == jnp.bfloat16
    for _ in range(100):
        params, state = opt.update({"w": 2 * params["w"]}, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adafactor_quadratic():
    opt = Adafactor(lr=0.1)
    params = {"w": jnp.ones((4, 4)) * 3.0}
    state = opt.init(params)
    assert "r" in state["f"]["w"]       # factored, not full
    for _ in range(300):
        params, state = opt.update({"w": 2 * params["w"]}, state, params)
    assert float(jnp.abs(params["w"]).max()) < 0.05


def test_grad_compressor_bound_and_feedback():
    gc = GradCompressor(b_r=1e-2)
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .standard_normal(512), jnp.float32)}
    err = gc.init(g)
    q, err = gc.roundtrip(g, err)
    rel = np.abs(np.asarray(q["w"]) - np.asarray(g["w"])) / \
        np.maximum(np.abs(np.asarray(g["w"])), 1e-20)
    assert rel.max() < 2e-2 + 1e-6
    # error feedback: residual equals what quantization dropped
    np.testing.assert_allclose(np.asarray(err["w"]),
                               np.asarray(g["w"]) - np.asarray(q["w"]),
                               atol=1e-7)
    assert gc.bytes_ratio > 1.8


def _short_train(arch="xlstm-125m", steps=20, compress=False):
    cfg = reduced_config(get_config(arch)).with_(remat=False)
    params = T.init_params(cfg, KEY)
    opt = AdamW(lr=3e-3)
    gc = GradCompressor(1e-2) if compress else None
    state = init_train_state(cfg, params, opt, gc)
    step_fn = jax.jit(make_train_step(cfg, opt, gc))
    src = SyntheticTokens(vocab=cfg.vocab, seq_len=32, global_batch=8)
    losses = []
    for step, batch in make_batches(src):
        if step >= steps:
            break
        params, state, metrics = step_fn(params, state, {"tokens": batch})
        losses.append(float(metrics["loss"]))
    return losses


def test_loss_decreases():
    losses = _short_train(steps=20)
    assert losses[-1] < losses[0] - 0.2, losses[::5]
    assert all(np.isfinite(losses))


def test_grad_compression_preserves_convergence():
    """Paper-technique-as-DP-trick: compressed-grad training tracks the
    uncompressed trajectory (same data, same init)."""
    base = _short_train(steps=15, compress=False)
    comp = _short_train(steps=15, compress=True)
    assert comp[-1] < comp[0] - 0.15
    assert abs(comp[-1] - base[-1]) < 0.3, (base[-1], comp[-1])


def test_data_pipeline_deterministic_resume():
    src = SyntheticTokens(vocab=100, seq_len=16, global_batch=4)
    a = [b for _, b in zip(range(5), make_batches(src))]
    b = [b for _, b in zip(range(3), make_batches(src, start_step=2))]
    np.testing.assert_array_equal(a[2][1], b[0][1])   # replay == original
    # sharded streams partition the same step
    s0 = SyntheticTokens(vocab=100, seq_len=16, global_batch=4,
                         n_shards=2, shard=0)
    s1 = SyntheticTokens(vocab=100, seq_len=16, global_batch=4,
                         n_shards=2, shard=1)
    assert s0.batch(7).shape == (2, 16)
    assert not np.array_equal(s0.batch(7), s1.batch(7))
