"""Codec + two-level store, no optional deps: round trips, RAW escape,
structured block segments, spill/alias semantics."""
import numpy as np

from repro.compression import (BlockSegments, BlockStore, PwRelParams,
                               compress_complex_block,
                               decompress_complex_block)
from repro.compression.codec import decode_block_host, encode_block_host


def test_codec_roundtrip_bound():
    rng = np.random.default_rng(7)
    amps = (rng.standard_normal(1024)
            + 1j * rng.standard_normal(1024)).astype(np.complex64)
    params = PwRelParams(b_r=1e-3)
    out = decompress_complex_block(compress_complex_block(amps, params),
                                   params)
    rel = np.abs(out - amps) / np.abs(amps)
    assert rel.max() < 2.5e-3            # sqrt(2)*b_r (re/im independent)


def test_codec_never_inflates():
    rng = np.random.default_rng(0)
    # adversarial: white noise with huge dynamic range
    amps = (rng.standard_normal(512) * 10.0 **
            rng.uniform(-30, 0, 512)).astype(np.complex64)
    blk = compress_complex_block(amps, PwRelParams(1e-4))
    assert blk.nbytes <= amps.nbytes + 16


def test_zero_block_tiny():
    amps = np.zeros(2 ** 12, np.complex64)
    blk = compress_complex_block(amps, PwRelParams(1e-3))
    assert blk.nbytes < 200              # ~1000x on all-zero blocks
    out = decompress_complex_block(blk, PwRelParams(1e-3))
    assert np.all(out == 0)


def test_segments_serialization_roundtrip():
    rng = np.random.default_rng(3)
    amps = (rng.standard_normal(300)
            + 1j * rng.standard_normal(300)).astype(np.complex64)
    params = PwRelParams(1e-3)
    seg = encode_block_host(amps, params)
    assert not seg.is_raw
    assert seg.nbytes == len(seg.to_bytes())
    back = BlockSegments.from_bytes(seg.to_bytes())
    assert back == seg
    np.testing.assert_array_equal(decode_block_host(back, params),
                                  decode_block_host(seg, params))


def test_segments_raw_escape_roundtrip():
    rng = np.random.default_rng(1)
    amps = (rng.standard_normal(64)
            + 1j * rng.standard_normal(64)).astype(np.complex64)
    seg = BlockSegments(n_amps=64, raw=amps.tobytes())
    assert seg.is_raw and seg.nbytes == 8 + 64 * 8
    back = BlockSegments.from_bytes(seg.to_bytes())
    np.testing.assert_array_equal(
        decode_block_host(back, PwRelParams(1e-3)), amps)


def test_store_structured_blocks_roundtrip(tmp_path):
    """put_block/get_block keep structure in RAM and across a disk spill."""
    rng = np.random.default_rng(5)
    params = PwRelParams(1e-3)
    segs = [encode_block_host(
        (rng.standard_normal(256)
         + 1j * rng.standard_normal(256)).astype(np.complex64), params)
        for _ in range(3)]
    store = BlockStore(ram_budget_bytes=segs[0].nbytes + 1,
                       spill_dir=str(tmp_path))
    for i, s in enumerate(segs):
        store.put_block(i, s)
    assert store.stats.n_spills >= 1     # later blocks overflowed to disk
    for i, s in enumerate(segs):
        got = store.get_block(i)
        assert got.n_amps == s.n_amps
        assert got.re.codes == s.re.codes
        assert got.im.bitmap == s.im.bitmap
        assert (got.re.l_max, got.im.l_max) == (s.re.l_max, s.im.l_max)
    # byte view of a structured block is its serialization
    assert store.get(0) == segs[0].to_bytes()
    # alias + overwrite semantics hold for structured blobs too
    store.put_alias(10, 0)
    store.put_block(0, segs[1])
    assert store.get_block(10).re.codes == segs[0].re.codes
    store.close()


def test_store_spill_and_alias(tmp_path):
    store = BlockStore(ram_budget_bytes=100, spill_dir=str(tmp_path))
    a = b"x" * 80
    b_ = b"y" * 80
    store.put(0, a)
    store.put(1, b_)                     # exceeds budget -> disk
    assert store.stats.n_spills == 1
    assert store.get(0) == a and store.get(1) == b_
    store.put_alias(2, 1)
    assert store.get(2) == b_
    store.put(1, b"z" * 10)              # overwrite canonical
    assert store.get(2) == b_            # alias still sees old blob
    assert store.get(1) == b"z" * 10
    store.delete(2)
    store.delete(1)
    assert 1 not in store and 2 not in store
    store.close()


def test_spilled_alias_refcounting_under_budget(tmp_path):
    """Budget/spill x put_alias: an aliased blob that spilled to disk
    survives the overwrite of one aliased key — the other keys keep
    reading the old bytes off the disk tier, refcounts release the blob
    only when the last key drops it, and the stats ledgers stay exact."""
    blob_a = b"a" * 120
    blob_b = b"b" * 120
    store = BlockStore(ram_budget_bytes=130, spill_dir=str(tmp_path))
    store.put(0, blob_a)                 # fits RAM
    store.put(1, blob_b)                 # exceeds budget -> disk tier
    assert store.stats.n_spills == 1
    for k in (2, 3):
        store.put_alias(k, 1)            # three keys share the spilled blob
    assert store.stats.disk_bytes == 120     # aliases are zero-copy

    store.put(1, b"n" * 5)               # overwrite one aliased key
    assert store.get(1) == b"n" * 5
    for k in (2, 3):                     # survivors still read the old blob
        assert store.get(k) == blob_b
    assert store.stats.disk_bytes == 120     # blob alive: 2 refs remain

    store.delete(2)
    assert store.get(3) == blob_b        # one ref left, still readable
    assert store.stats.disk_bytes == 120
    store.delete(3)                      # last ref: file released
    assert store.stats.disk_bytes == 0
    assert store.stats.ram_bytes == len(blob_a) + 5
    assert store.get(0) == blob_a
    # RAM tier never exceeded its budget through any of the above
    assert store.stats.peak_ram_bytes <= 130
    store.close()


def test_spilled_structured_alias_roundtrip(tmp_path):
    """Same refcount semantics for structured blocks: an aliased
    BlockSegments spilled to disk re-parses identically after the
    canonical key is overwritten."""
    rng = np.random.default_rng(9)
    params = PwRelParams(1e-3)
    segs = [encode_block_host(
        (rng.standard_normal(128)
         + 1j * rng.standard_normal(128)).astype(np.complex64), params)
        for _ in range(2)]
    store = BlockStore(ram_budget_bytes=1, spill_dir=str(tmp_path))
    store.put_block(0, segs[0])          # everything spills (budget ~ 0)
    store.put_alias(5, 0)
    assert store.stats.n_spills == 1
    store.put_block(0, segs[1])          # rebind canonical key
    got = store.get_block(5)             # alias reads the old spilled blob
    assert got.re.codes == segs[0].re.codes
    assert got.im.bitmap == segs[0].im.bitmap
    np.testing.assert_array_equal(
        decode_block_host(got, params), decode_block_host(segs[0], params))
    store.close()


def test_store_byte_accounting():
    store = BlockStore()
    store.put(0, b"a" * 100)
    store.put(1, b"b" * 50)
    assert store.total_bytes == 150
    store.put(0, b"c" * 10)              # replace
    assert store.total_bytes == 60
    assert store.stats.peak_ram_bytes == 160  # old+new coexist momentarily
    store.close()
