"""Flash-attention Pallas kernel vs jnp oracle (shape sweep, causal+full)."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention import flash_attention

rng = np.random.default_rng(7)


def _ref(q, k, v, causal):
    S = q.shape[1]
    s = jnp.einsum("bsd,btd->bst", q, k) * (q.shape[-1] ** -0.5)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None], s, -2.0 ** 30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bst,btd->bsd", p, v)


@pytest.mark.parametrize("BH,S,hd", [(2, 128, 64), (4, 256, 32),
                                     (1, 512, 128), (3, 96, 16)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(BH, S, hd, causal):
    q, k, v = (jnp.asarray(rng.standard_normal((BH, S, hd)), jnp.float32)
               for _ in range(3))
    got = flash_attention(q, k, v, causal=causal, q_tile=64, k_tile=64)
    want = _ref(q, k, v, causal)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_skips_masked_tiles():
    """Causal tile skipping changes nothing numerically."""
    q, k, v = (jnp.asarray(rng.standard_normal((1, 256, 32)), jnp.float32)
               for _ in range(3))
    a = flash_attention(q, k, v, causal=True, q_tile=32, k_tile=32)
    b = flash_attention(q, k, v, causal=True, q_tile=256, k_tile=256)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=2e-4, atol=2e-4)


def test_flash_attention_under_jit():
    q, k, v = (jnp.asarray(rng.standard_normal((2, 128, 64)), jnp.float32)
               for _ in range(3))
    f = jax.jit(lambda q, k, v: flash_attention(q, k, v))
    np.testing.assert_allclose(np.asarray(f(q, k, v)),
                               np.asarray(_ref(q, k, v, True)),
                               rtol=2e-4, atol=2e-4)
