"""Measurement readout from the compressed store (streaming, block-wise)."""
import numpy as np

from repro.core import EngineConfig, build_circuit
from repro.core.engine import BMQSimEngine
from repro.core.measure import (block_probabilities, expect_diagonal,
                                sample_counts)


def _run(name, n, b=4):
    eng = BMQSimEngine(build_circuit(name, n), EngineConfig(local_bits=b))
    eng.run(collect_state=False)
    return eng


def test_ghz_samples_two_outcomes():
    eng = _run("ghz_state", 10)
    counts = sample_counts(eng, 2000, seed=1)
    # GHZ: only |0...0> and |1...1>
    assert set(counts) <= {0, 2 ** 10 - 1}
    frac = counts.get(0, 0) / 2000
    assert 0.4 < frac < 0.6
    eng.close()


def test_block_probabilities_normalized():
    eng = _run("qft", 10, b=5)
    masses = block_probabilities(eng)
    assert abs(masses.sum() - 1.0) < 5e-3
    # QFT of |0> is uniform: every block carries equal mass
    assert np.allclose(masses, masses[0], rtol=2e-2)
    eng.close()


def test_expect_diagonal_matches_dense():
    from repro.core import simulate_dense
    qc = build_circuit("qaoa", 9)
    eng = BMQSimEngine(qc, EngineConfig(local_bits=4))
    eng.run(collect_state=False)

    def parity(idx):          # <Z_0 Z_1>-ish diagonal observable
        b0 = (idx >> 0) & 1
        b1 = (idx >> 1) & 1
        return 1.0 - 2.0 * np.asarray(b0 ^ b1, np.float64)

    got = expect_diagonal(eng, parity)
    state = np.asarray(simulate_dense(qc))
    idx = np.arange(state.size)
    want = float(np.sum(np.abs(state) ** 2 * parity(idx)))
    assert abs(got - want) < 5e-3
    eng.close()


def test_sampling_distribution_chi2ish():
    """bv circuit: the secret string dominates the samples (the ancilla
    qubit n-1 remains in superposition, so mask it out)."""
    eng = _run("bv", 9)
    counts = sample_counts(eng, 500, seed=3)
    masked: dict[int, int] = {}
    for k, v in counts.items():
        masked[k & (2 ** 8 - 1)] = masked.get(k & (2 ** 8 - 1), 0) + v
    top = max(masked, key=masked.get)
    assert masked[top] > 400          # deterministic up to b_r noise
    eng.close()
