"""Deterministic overlap tests for the double-buffered wave scheduler.

These tests drive :class:`StagePipeline` directly with a synthetic codec
backend that records a timestamped phase-event trace and gates individual
phases on :class:`threading.Event` objects.  NO assertion in this file
depends on wall-clock timing or ``time.sleep`` — overlap is proven by
trace *order* (which phases the scheduler interleaved) and by event gates
that would deadlock a sequential schedule; every ``Event.wait`` uses a
generous timeout whose expiry is converted into a test failure, never a
hang.

Scheduler guarantees under test (see pipeline.py's module docs):

* depth >= 2: wave w's blocking ``await_result_batch`` runs only AFTER
  wave w+1's compute/encode ``dispatch_result_batch`` (the in-flight
  window) and after wave w+2's fetch has been submitted (the lookahead).
* depth == 1: strictly sequential fetch -> stage -> dispatch -> await ->
  store per wave, on the caller's thread, in wave order.
* the completion ready-queue consumes fetches in *completion* order — a
  slow decode does not serialize the waves behind it.
* ``run_stage`` returns only after every store future has drained (the
  stage barrier), and a fetch exception propagates out of ``run_stage``
  without deadlocking the pools.
* the backend byte/count ledgers are exact under concurrent phase hooks.
"""
import threading

import numpy as np
import pytest

from repro.core.pipeline import CodecBackend, HostCodecBackend, StagePipeline

_TIMEOUT = 10.0          # failsafe only — expiry == test failure, not a hang


def _wait(event: threading.Event, what: str) -> None:
    assert event.wait(_TIMEOUT), \
        f"expected overlap did not happen: timed out waiting for {what}"


class RecordingBackend(CodecBackend):
    """Synthetic codec backend: an in-memory dict of float values keyed by
    block id, a thread-safe ``(phase, wave_first_key)`` trace, and optional
    per-phase event gates.

    The wave scheduler only touches the ``*_batch`` hooks (plus
    ``add_bytes``/``add_counts``), so nothing here imports JAX — "device
    planes" are plain numpy arrays and the stage function is whatever the
    test passes as ``wave_fn``.
    """

    name = "recording"

    def __init__(self, n_keys: int):
        super().__init__(store=None, params=None, bsz=1)
        self.data = {k: float(k) for k in range(n_keys)}
        self._data_lock = threading.Lock()
        self.trace: list[tuple[str, int]] = []
        self._trace_lock = threading.Lock()
        # {phase-name: {wave_first_key: Event}} — the hook blocks on the
        # event before doing its work (failsafe timeout -> test failure)
        self.gates: dict[str, dict[int, threading.Event]] = {}
        # {phase-name: {wave_first_key: Event}} — set when the hook runs,
        # so tests (or other gates) can sequence on phase entry
        self.reached: dict[str, dict[int, threading.Event]] = {}
        # {wave_first_key: exception} raised from fetch_group_batch
        self.fetch_raises: dict[int, BaseException] = {}

    # -- instrumentation ------------------------------------------------------
    def gate(self, phase: str, wid: int) -> threading.Event:
        ev = threading.Event()
        self.gates.setdefault(phase, {})[wid] = ev
        return ev

    def reached_event(self, phase: str, wid: int) -> threading.Event:
        ev = threading.Event()
        self.reached.setdefault(phase, {})[wid] = ev
        return ev

    def _enter(self, phase: str, wid: int) -> None:
        ev = self.reached.get(phase, {}).get(wid)
        if ev is not None:
            ev.set()
        gate = self.gates.get(phase, {}).get(wid)
        if gate is not None:
            _wait(gate, f"gate on {phase}[wave {wid}]")
        with self._trace_lock:
            self.trace.append((phase, wid))

    def index(self, phase: str, wid: int) -> int:
        """Trace index of the (unique) ``(phase, wid)`` event."""
        hits = [i for i, e in enumerate(self.trace) if e == (phase, wid)]
        assert len(hits) == 1, f"{(phase, wid)} appeared {len(hits)}x"
        return hits[0]

    # -- batch phase hooks (all the wave scheduler calls) ---------------------
    def fetch_group_batch(self, key_rows):
        wid = int(key_rows[0, 0])
        self._enter("fetch", wid)
        exc = self.fetch_raises.get(wid)
        if exc is not None:
            raise exc
        with self._data_lock:
            staged = np.array([[self.data[int(k)] for k in row]
                               for row in key_rows])
        self.add_counts(decompressions=key_rows.size)
        return (wid, staged)

    def stage_to_device_batch(self, staged, device):
        wid, arr = staged
        self._enter("stage", wid)
        self.add_bytes(h2d=arr.nbytes)
        return (wid, arr)

    def dispatch_result_batch(self, planes_dev, n_blocks):
        wid, arr = planes_dev
        self._enter("dispatch", wid)
        return (wid, arr)

    def await_result_batch(self, ticket):
        wid, arr = ticket
        self._enter("await", wid)
        self.add_bytes(d2h=arr.nbytes)
        return (wid, arr)

    def store_group_batch(self, key_rows, results):
        wid, arr = results
        self._enter("store", wid)
        with self._data_lock:
            for row, vals in zip(key_rows, arr):
                for k, v in zip(row, vals):
                    self.data[int(k)] = float(v)
        self.add_counts(compressions=key_rows.size)
        self._enter("store_done", wid)


def _double(planes, *mats):
    wid, arr = planes
    return (wid, arr * 2.0)


def _run(backend: RecordingBackend, depth: int, n_groups: int,
         n_blocks: int = 2, **pipe_kw) -> None:
    # force the threaded overlap scheduler: the adaptive default builds
    # no pools on a single-core host (CI containers), and these tests
    # assert the *overlapped* schedule, not the coalescing-only one
    pipe_kw.setdefault("fetch_workers", 1)
    block_ids = np.arange(n_groups * n_blocks).reshape(n_groups, n_blocks)
    pipe = StagePipeline(backend, depth=depth, **pipe_kw)
    with pipe:
        pipe.run_stage(block_ids, fn=None, mats=[], wave_fn=_double)


def test_depth1_is_strictly_sequential():
    back = RecordingBackend(8)
    _run(back, depth=1, n_groups=4)
    expected = [(ph, 2 * g)
                for g in range(4)
                for ph in ("fetch", "stage", "dispatch", "await",
                           "store", "store_done")]
    assert back.trace == expected
    assert back.data == {k: 2.0 * k for k in range(8)}


def test_coalescing_only_mode_is_sequential_over_waves():
    # fetch_workers=0 (and the adaptive default on a single-core host)
    # keeps the wave coalescing but drops the worker pools: waves run
    # strictly sequentially on the caller's thread, one batched hook
    # call per phase per wave
    back = RecordingBackend(16)
    _run(back, depth=2, n_groups=8, fetch_workers=0)
    expected = [(ph, 4 * w)                       # wave ids 0, 4, 8, 12
                for w in range(4)
                for ph in ("fetch", "stage", "dispatch", "await",
                           "store", "store_done")]
    assert back.trace == expected
    assert back.data == {k: 2.0 * k for k in range(16)}


@pytest.mark.parametrize("depth", [2, 4])
def test_deeper_waves_dispatch_before_older_await(depth):
    # 4 waves; wave ids (first store key) are 0, 2W, 4W, 6W for n_blocks=2
    back = RecordingBackend(8 * depth)
    wids = [2 * depth * w for w in range(4)]
    # deterministically pin the fetch lookahead: wave w's blocking await
    # does not proceed until wave w+2's fetch has entered.  The scheduler
    # submits the lookahead before awaiting, so the gate clears on a pool
    # worker; a scheduler without the lookahead would time out (= fail).
    for w in range(2):
        back.gates.setdefault("await", {})[wids[w]] = \
            back.reached_event("fetch", wids[w + 2])
    _run(back, depth=depth, n_groups=4 * depth)
    for w in range(3):
        # the in-flight window: wave w is awaited only after wave w+1's
        # compute has been dispatched — the headline overlap property
        assert back.index("dispatch", wids[w + 1]) \
            < back.index("await", wids[w])
    for w in range(2):
        assert back.index("fetch", wids[w + 2]) < back.index("await", wids[w])
    assert back.data == {k: 2.0 * k for k in range(8 * depth)}


def test_await_gated_on_next_dispatch_does_not_deadlock():
    # stronger, event-gated form of the overlap property: wave 0's await
    # BLOCKS until wave 1's dispatch has happened.  A sequential schedule
    # (await w before dispatch w+1) would time out here; the overlapped
    # scheduler satisfies the gate on its own thread before awaiting.
    back = RecordingBackend(8)
    back.gates.setdefault("await", {})[0] = \
        back.reached_event("dispatch", 4)     # wave 1 first key = 4
    _run(back, depth=2, n_groups=4)
    assert back.index("dispatch", 4) < back.index("await", 0)
    assert back.data == {k: 2.0 * k for k in range(8)}


def test_ready_queue_consumes_fetches_in_completion_order():
    # Make wave 0 the slow decode: its fetch blocks until the compute
    # loop has already begun *staging* wave 1 — i.e. until the ready
    # queue has delivered wave 1 first.  With a lookahead-wide fetch pool
    # (forced explicitly: the adaptive default is 1 worker on a 1-core
    # host) both fetches are in flight at once, so the gate clears and
    # the loop computes wave 1 before wave 0 despite submission order; a
    # scheduler that insisted on wave order would time out (= fail).
    back = RecordingBackend(8)
    back.gates.setdefault("fetch", {})[0] = back.reached_event("stage", 4)
    _run(back, depth=2, n_groups=4, fetch_workers=2)
    assert back.index("dispatch", 4) < back.index("dispatch", 0)
    # correctness is unaffected by the reordering
    assert back.data == {k: 2.0 * k for k in range(8)}


def test_stage_barrier_drains_every_store_future():
    back = RecordingBackend(16)
    _run(back, depth=4, n_groups=8)          # 2 waves of 4 groups
    done = [e for e in back.trace if e[0] == "store_done"]
    assert len(done) == 2                     # every wave's store finished
    assert back.data == {k: 2.0 * k for k in range(16)}


def test_fetch_exception_propagates_without_deadlock():
    class Boom(RuntimeError):
        pass

    back = RecordingBackend(32)
    back.fetch_raises[16] = Boom("injected fetch failure")   # wave 2 of 4
    block_ids = np.arange(32).reshape(16, 2)
    pipe = StagePipeline(back, depth=4, fetch_workers=1)
    with pytest.raises(Boom, match="injected fetch failure"):
        with pipe:
            pipe.run_stage(block_ids, fn=None, mats=[], wave_fn=_double)
    # the context exited cleanly (pools shut down) and the failing wave
    # never reached the store
    assert pipe._dec_pool is None and pipe._com_pool is None
    assert ("store", 16) not in back.trace
    # a fresh pipeline on the same backend still works (no poisoned state)
    back.fetch_raises.clear()
    back.trace.clear()
    back.data = {k: float(k) for k in range(32)}
    _run(back, depth=4, n_groups=16)
    assert back.data == {k: 2.0 * k for k in range(32)}


# -- byte/count ledger under concurrency -------------------------------------

def test_byte_ledger_exact_under_concurrent_add_bytes():
    back = RecordingBackend(1)
    n_threads, n_iter = 8, 2000
    start = threading.Barrier(n_threads)

    def hammer():
        start.wait()
        for _ in range(n_iter):
            back.add_bytes(h2d=3, d2h=7)
            back.add_counts(decompressions=1, compressions=2)

    threads = [threading.Thread(target=hammer) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert back.h2d_bytes == 3 * n_threads * n_iter
    assert back.d2h_bytes == 7 * n_threads * n_iter
    assert back.n_decompressions == n_threads * n_iter
    assert back.n_compressions == 2 * n_threads * n_iter


def test_host_backend_ledger_exact_under_concurrent_phase_hooks():
    """Run the REAL host backend's staged/await hooks from many threads at
    once and check the byte ledger to the exact byte — the regression test
    for the unlocked ``+=`` the hooks used to do."""
    jax = pytest.importorskip("jax")
    from repro.compression.pwrel import PwRelParams
    from repro.compression.store import BlockStore

    bsz = 32
    back = HostCodecBackend(BlockStore(), PwRelParams(), bsz)
    rng = np.random.default_rng(7)
    amps = (rng.standard_normal(bsz) + 1j * rng.standard_normal(bsz)) \
        .astype(np.complex64)
    back.encode_host_block(0, amps)
    dev = jax.devices()[0]
    n_threads, n_iter = 4, 16
    start = threading.Barrier(n_threads)
    errors: list[BaseException] = []

    def worker():
        try:
            start.wait()
            keys = np.zeros(1, dtype=np.int64)
            for _ in range(n_iter):
                staged = back.fetch_group(keys)
                planes = back.stage_to_device(staged, dev)
                back.await_result(back.dispatch_result(planes, 1))
        except BaseException as e:      # pragma: no cover - failure path
            errors.append(e)

    threads = [threading.Thread(target=worker) for _ in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    per_xfer = bsz * 8                  # complex64 both ways on host backend
    assert back.h2d_bytes == per_xfer * n_threads * n_iter
    assert back.d2h_bytes == per_xfer * n_threads * n_iter
    assert back.n_decompressions == n_threads * n_iter
