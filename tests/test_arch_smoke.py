"""Per-architecture smoke tests (deliverable f): reduced configs of the
same family run one forward/train step on CPU; shapes + no NaNs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ALIASES, get_config, reduced_config
from repro.configs.shapes import SHAPES, cell_is_applicable, input_specs
from repro.models import encdec as E
from repro.models import transformer as T

ARCHS = list(ALIASES)
KEY = jax.random.PRNGKey(0)


def _finite(tree) -> bool:
    return all(np.isfinite(np.asarray(x, np.float32)).all()
               for x in jax.tree.leaves(tree))


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_and_train_step(arch):
    cfg = reduced_config(get_config(arch))
    B, S = 2, 16
    if cfg.family == "audio":
        params = E.init_encdec_params(cfg, KEY)
        frames = jax.random.normal(
            KEY, (B, cfg.encoder.n_frames, cfg.d_model), jnp.bfloat16)
        toks = jax.random.randint(KEY, (B, cfg.encoder.dec_len), 0, cfg.vocab)
        logits = E.encdec_train(cfg, params, frames, toks)
        assert logits.shape == (B, cfg.encoder.dec_len, cfg.vocab)
        loss, grads = jax.value_and_grad(
            lambda p: E.loss_fn_encdec(cfg, p, frames, toks))(params)
    else:
        aux = None
        if cfg.family == "vlm":
            aux = jax.random.normal(
                KEY, (B, cfg.n_image_tokens, cfg.d_model), jnp.bfloat16)
        params = T.init_params(cfg, KEY)
        toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
        logits = T.forward_train(cfg, params, toks, aux)
        assert logits.shape == (B, S, cfg.vocab)
        loss, grads = jax.value_and_grad(
            lambda p: T.loss_fn(cfg, p, toks, aux))(params)
    assert np.isfinite(float(loss))
    assert _finite(grads), f"{arch}: non-finite grads"
    assert _finite(logits)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_exact_assignment(arch):
    """The full configs carry the exact assigned hyperparameters."""
    cfg = get_config(arch)
    expect = {
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "qwen1.5-32b": (64, 5120, 40, 40, 27392, 152064),
        "granite-20b": (52, 6144, 48, 1, 24576, 49152),
        "qwen3-4b": (36, 2560, 32, 8, 9728, 151936),
        "llama-3.2-vision-90b": (100, 8192, 64, 8, 28672, 128256),
        "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
        "mixtral-8x22b": (56, 6144, 48, 8, 16384, 32768),
        "recurrentgemma-2b": (26, 2560, 10, 1, 7680, 256000),
        "xlstm-125m": (12, 768, 4, 4, 0, 50304),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
           cfg.d_ff, cfg.vocab)
    assert got == expect, (arch, got, expect)


@pytest.mark.parametrize("arch", ARCHS)
def test_input_specs_all_shapes(arch):
    """input_specs produce abstract specs for every applicable cell."""
    cfg = get_config(arch)
    for shape in SHAPES:
        if not cell_is_applicable(cfg, shape):
            continue
        specs = input_specs(cfg, shape)
        for leaf in jax.tree.leaves(
                specs, is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct)):
            assert isinstance(leaf, jax.ShapeDtypeStruct)


def test_moe_structure():
    cfg = get_config("arctic-480b")
    assert cfg.moe.n_experts == 128 and cfg.moe.top_k == 2
    assert cfg.moe.dense_residual
    cfg = get_config("mixtral-8x22b")
    assert cfg.moe.n_experts == 8 and cfg.moe.top_k == 2
    assert cfg.sliding_window == 4096


def test_patterns():
    assert get_config("gemma3-12b").pattern == ("attn_local",) * 5 + ("attn",)
    assert get_config("recurrentgemma-2b").pattern == \
        ("rglru", "rglru", "attn_local")
    assert get_config("xlstm-125m").pattern == ("mlstm", "slstm")
    assert get_config("llama-3.2-vision-90b").pattern == \
        ("attn",) * 4 + ("cross_attn",)


def test_param_counts_in_range():
    """Sanity: total params within +-40% of each model's nameplate."""
    nameplate = {
        "gemma3-12b": 12e9, "qwen1.5-32b": 32e9, "granite-20b": 20e9,
        "qwen3-4b": 4e9, "llama-3.2-vision-90b": 90e9, "arctic-480b": 480e9,
        "mixtral-8x22b": 141e9, "recurrentgemma-2b": 2.7e9,
        "xlstm-125m": 125e6, "whisper-large-v3": 1.5e9,
    }
    for arch, n in nameplate.items():
        got = get_config(arch).param_count()
        assert 0.5 * n < got < 1.6 * n, (arch, got / 1e9)
