"""Serving: prefill/decode == train-forward logits; compressed-KV decode
matches raw within the pwrel bound; ring caches at long context."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.models import transformer as T
from repro.models import encdec as E
from repro.serving.kvcache import (compress_prefill_cache, dequantize_kv,
                                   kv_bytes_ratio, quantize_kv)

KEY = jax.random.PRNGKey(3)

CONSISTENCY_ARCHS = ["gemma3-12b", "qwen3-4b", "mixtral-8x22b",
                     "recurrentgemma-2b", "granite-20b"]


def _setup(arch, B=2, S=24):
    cfg = reduced_config(get_config(arch))
    params = T.init_params(cfg, KEY)
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab)
    return cfg, params, toks


@pytest.mark.parametrize("arch", CONSISTENCY_ARCHS)
def test_prefill_decode_matches_train_forward(arch):
    cfg, params, toks = _setup(arch)
    B, S = toks.shape
    ref = T.forward_train(cfg, params, toks)
    lp, cache = T.forward_prefill(cfg, params, toks[:, :S - 4], max_len=S)
    scale = float(jnp.max(jnp.abs(ref)))
    assert float(jnp.max(jnp.abs(lp - ref[:, S - 5]))) < 2e-2 * scale
    for i in range(4):
        pos = S - 4 + i
        lg, cache = T.forward_decode(cfg, params, toks[:, pos:pos + 1],
                                     cache, pos)
        err = float(jnp.max(jnp.abs(lg - ref[:, pos])))
        assert err < 2e-2 * scale, (arch, pos, err / scale)


def test_ring_cache_matches_full_cache():
    """Sliding-window ring buffer == full cache + window mask."""
    cfg, params, toks = _setup("mixtral-8x22b", S=30)
    B, S = toks.shape
    W = cfg.sliding_window
    assert W and W < S                  # ring actually engaged
    ref = T.forward_train(cfg, params, toks)
    lp, cache = T.forward_prefill(cfg, params, toks[:, :S - 6], max_len=S)
    # cache is ring-sized
    k_leaf = jax.tree.leaves(cache["units"][0])[0]
    assert k_leaf.shape[2] == W
    scale = float(jnp.max(jnp.abs(ref)))
    for i in range(6):
        pos = S - 6 + i
        lg, cache = T.forward_decode(cfg, params, toks[:, pos:pos + 1],
                                     cache, pos)
        err = float(jnp.max(jnp.abs(lg - ref[:, pos])))
        assert err < 2e-2 * scale, (pos, err / scale)


def test_kv_quantization_bound():
    x = jax.random.normal(KEY, (2, 16, 4, 32), jnp.bfloat16)
    q = quantize_kv(x)
    xhat = dequantize_kv(q)
    xf = np.asarray(x, np.float32)
    xh = np.asarray(xhat, np.float32)
    nz = np.abs(xf) > np.abs(xf).max() * 2 ** -15
    rel = np.abs(xh[nz] - xf[nz]) / np.abs(xf[nz])
    assert rel.max() < 0.03             # 2^(step/2)-1 ~ 2.2% + bf16 noise
    assert kv_bytes_ratio(128) > 1.7


@pytest.mark.parametrize("arch", ["qwen3-4b", "granite-20b", "gemma3-12b"])
def test_compressed_kv_decode_matches_raw(arch):
    cfg, params, toks = _setup(arch)
    B, S = toks.shape
    lp, cache = T.forward_prefill(cfg, params, toks[:, :S - 4], max_len=S)
    qcache = compress_prefill_cache(cache)
    raw, comp = cache, qcache
    for i in range(4):
        pos = S - 4 + i
        lg_r, raw = T.forward_decode(cfg, params, toks[:, pos:pos + 1],
                                     raw, pos)
        lg_c, comp = T.forward_decode(cfg, params, toks[:, pos:pos + 1],
                                      comp, pos)
        scale = float(jnp.max(jnp.abs(lg_r)))
        err = float(jnp.max(jnp.abs(lg_r - lg_c)))
        assert err < 5e-2 * scale, (arch, pos, err / scale)


def test_compressed_cache_smaller():
    cfg, params, toks = _setup("qwen3-4b")
    _, cache = T.forward_prefill(cfg, params, toks, max_len=toks.shape[1])
    qcache = compress_prefill_cache(cache)
    raw_b = sum(x.nbytes for x in jax.tree.leaves(cache))
    q_b = sum(x.nbytes for x in jax.tree.leaves(qcache))
    assert q_b < raw_b * 0.72           # ~1.78x smaller


def test_encdec_serving():
    cfg = reduced_config(get_config("whisper-large-v3"))
    params = E.init_encdec_params(cfg, KEY)
    B = 2
    frames = jax.random.normal(KEY, (B, cfg.encoder.n_frames, cfg.d_model),
                               jnp.bfloat16)
    toks = jax.random.randint(KEY, (B, cfg.encoder.dec_len), 0, cfg.vocab)
    ref = E.encdec_train(cfg, params, frames, toks)
    S = toks.shape[1]
    lp, cache = E.encdec_prefill(cfg, params, frames, toks[:, :S - 2],
                                 max_len=S)
    scale = float(jnp.max(jnp.abs(ref)))
    assert float(jnp.max(jnp.abs(lp - ref[:, S - 3]))) < 2e-2 * scale
    for i in range(2):
        pos = S - 2 + i
        lg, cache = E.encdec_decode(cfg, params, toks[:, pos:pos + 1],
                                    cache, pos)
        assert float(jnp.max(jnp.abs(lg - ref[:, pos]))) < 2e-2 * scale


def test_greedy_generation_runs():
    """End-to-end generation loop (quickstart example behaviour)."""
    cfg, params, toks = _setup("qwen3-4b", S=8)
    lg, cache = T.forward_prefill(cfg, params, toks, max_len=24)
    out = []
    tok = jnp.argmax(lg, -1)[:, None]
    for i in range(8):
        out.append(np.asarray(tok))
        lg, cache = T.forward_decode(cfg, params, tok, cache, 8 + i)
        tok = jnp.argmax(lg, -1)[:, None]
    gen = np.concatenate(out, 1)
    assert gen.shape == (2, 8)
    assert (gen >= 0).all() and (gen < cfg.vocab).all()
