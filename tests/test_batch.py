"""Batched execution engine: run_batch lane equivalence, noise
trajectories vs the dense oracle and the analytic noisy expectation,
budget-driven sub-batch chunking, and the benchmark regression gate."""
import json

import numpy as np
import pytest

from benchmarks import compare as bench_compare
from repro.core import (Circuit, EngineConfig, Simulator, build_circuit,
                        fidelity, qaoa_template, random_circuit,
                        simulate_dense, with_depolarizing, zsum_cost_fn)

#: cross-path fidelity floor: the batched kernels and the single-lane
#: kernels round differently and both sides quantize at b_r=1e-3; deep
#: circuits land around 0.9998 — don't assert tighter
FIDELITY_FLOOR = 0.999


def _fid(a, b):
    return fidelity(np.asarray(a, np.complex128), np.asarray(b, np.complex128))


# -- batch-vs-sequential equivalence -----------------------------------------

def test_run_batch_deterministic_lanes_match_single_run():
    qc = build_circuit("qft", 8)
    cfg = EngineConfig(local_bits=4, inner_size=2)
    with Simulator(qc, cfg) as sim:
        batch = sim.run_batch([None] * 3)
        assert len(batch) == 3
        lanes = [lane.statevector() for lane in batch]
    with Simulator(qc, cfg) as sim:
        ref = sim.run().statevector()
    for sv in lanes:
        assert _fid(ref, sv) > FIDELITY_FLOOR


def test_run_batch_param_sweep_matches_sequential():
    template = qaoa_template(8, layers=1)
    cfg = EngineConfig(local_bits=4, inner_size=2)
    points = [{"gamma0": 0.3 + 0.2 * i, "beta0": 0.1 + 0.1 * i}
              for i in range(4)]
    with Simulator(template, cfg) as sim:
        batch = sim.run_batch(points)
        lanes = [lane.statevector() for lane in batch]
    with Simulator(template, cfg) as sim:
        for p, sv in zip(points, lanes):
            ref = sim.run(params=p).statevector()
            assert _fid(ref, sv) > FIDELITY_FLOOR


def test_run_batch_matches_sequential_hypothesis():
    hyp = pytest.importorskip("hypothesis")
    st = pytest.importorskip("hypothesis.strategies")

    template = qaoa_template(6, layers=1)
    cfg = EngineConfig(local_bits=3, inner_size=2)

    @hyp.settings(max_examples=5, deadline=None)
    @hyp.given(angles=st.lists(
        st.tuples(st.floats(0.05, 3.0), st.floats(0.05, 3.0)),
        min_size=1, max_size=4))
    def inner(angles):
        points = [{"gamma0": g, "beta0": b} for g, b in angles]
        with Simulator(template, cfg) as sim:
            batch = sim.run_batch(points)
            lanes = [lane.statevector() for lane in batch]
        with Simulator(template, cfg) as sim:
            for p, sv in zip(points, lanes):
                ref = sim.run(params=p).statevector()
                assert _fid(ref, sv) > FIDELITY_FLOOR

    inner()


@pytest.mark.parametrize("seed", [1, 2])
def test_run_batch_random_circuits_match_dense(seed):
    """Random circuits hit every schedule op type (GemmOp/MidGemmOp,
    block + scattered DiagOp, bmap'd operands) — the batched executor
    must agree with the dense oracle on all of them."""
    qc = random_circuit(6, 24, seed=seed)
    ref = simulate_dense(qc)
    with Simulator(qc, EngineConfig(local_bits=3, inner_size=2)) as sim:
        batch = sim.run_batch([None] * 2)
        for lane in batch:
            assert _fid(ref, lane.statevector()) > FIDELITY_FLOOR


def test_batch_stagefns_compile_once_across_repeats():
    qc = build_circuit("qft", 8)
    with Simulator(qc, EngineConfig(local_bits=4)) as sim:
        sim.run_batch([None] * 2)
        compiles = sim.stats.n_stagefn_compiles
        sim.run_batch([None] * 2)
        assert sim.stats.n_stagefn_compiles == compiles
        assert sim.stats.n_lanes == 2


def test_batch_result_goes_stale_on_next_run():
    qc = build_circuit("qft", 8)
    with Simulator(qc, EngineConfig(local_bits=4)) as sim:
        batch = sim.run_batch([None] * 2)
        lane = batch[1]
        lane.sample(16)                         # live
        sim.run()
        with pytest.raises(RuntimeError, match="stale"):
            lane.sample(16)
        with pytest.raises(RuntimeError, match="not supported"):
            # a batched run has no single-state checkpoint manifest
            sim.run_batch([None] * 2)[0].save("nope.bmq")


# -- noise trajectories ------------------------------------------------------

def test_trajectory_lane_matches_realized_dense_oracle():
    noisy = with_depolarizing(build_circuit("ghz_state", 6), 0.08)
    assert noisy.is_stochastic
    with Simulator(noisy, EngineConfig(local_bits=3)) as sim:
        batch = sim.run(trajectories=3, seed=11)
        for j in range(3):
            oracle = simulate_dense(noisy.realize(11 + j))
            assert _fid(oracle, batch[j].statevector()) > FIDELITY_FLOOR


def test_trajectory_average_converges_to_analytic_noisy_expectation():
    """|0..0> through one depolarizing layer: <sum Z> = n * (1 - 4p/3)
    analytically; the K-trajectory Monte-Carlo average must land near it
    (loose tolerance — K=48 trajectories of a 4-qubit state)."""
    n, p, K = 4, 0.2, 48
    qc = Circuit(n)
    for q in range(n):
        qc.depolarize(p, q)
    with Simulator(qc, EngineConfig(local_bits=2)) as sim:
        batch = sim.run(trajectories=K, seed=3)
        est = batch.expectation(zsum_cost_fn(n))
    analytic = n * (1.0 - 4.0 * p / 3.0)
    assert abs(est - analytic) < 0.6            # ~3 sigma at K=48


def test_trajectories_are_seeded_and_reproducible():
    noisy = with_depolarizing(build_circuit("cat_state", 5), 0.1)
    cost = zsum_cost_fn(5)
    with Simulator(noisy, EngineConfig(local_bits=3)) as sim:
        a = sim.run(trajectories=4, seed=9).expectations(cost)
        b = sim.run(trajectories=4, seed=9).expectations(cost)
        np.testing.assert_allclose(a, b)


def test_stochastic_circuit_rejects_plain_run():
    noisy = with_depolarizing(build_circuit("cat_state", 5), 0.1)
    with Simulator(noisy, EngineConfig(local_bits=3)) as sim:
        with pytest.raises(ValueError, match="trajectories"):
            sim.run()


def test_channel_builder_validates():
    qc = Circuit(2)
    with pytest.raises(ValueError):
        qc.depolarize(1.5, 0)
    with pytest.raises(KeyError):
        qc.append_channel("amp_damp", [0], 0.1)
    qc.depolarize(0.25, 1)
    assert qc.is_stochastic and qc.gates[0].matrix is None
    concrete = qc.realize(0)
    assert not concrete.is_stochastic
    assert concrete.gates[0].matrix is not None


# -- planner: budget awareness of the batch factor ---------------------------

def test_tight_budget_forces_chunked_subbatches_and_holds_peak():
    from repro.core.planner import _predict_working_set, estimate_bytes_per_amp
    qc = build_circuit("qft", 10)
    K = 4
    # a budget that admits the predicted 2-lane working set but not 4
    # lanes: run_batch must warn and execute chunked sub-batches
    bpa = estimate_bytes_per_amp(1e-3, True)
    peak2, pipe2 = _predict_working_set(10, 5, 2, 2, bpa, lanes=2)
    budget = peak2 + pipe2 + 1
    cfg = EngineConfig(local_bits=5, inner_size=2,
                       memory_budget_bytes=budget, batch=K)
    with Simulator(qc, cfg) as sim:
        with pytest.warns(RuntimeWarning, match="sub-batches"):
            batch = sim.run_batch([None] * K)
        assert sim.stats.n_batch_chunks > 1
        assert sim.stats.n_lanes == K
        # the store budget backstop holds even while K final states live
        assert sim.stats.peak_ram_bytes <= budget
        # chunking must not change the answer
        ref = simulate_dense(qc)
        for lane in batch:
            assert _fid(ref, lane.statevector()) > FIDELITY_FLOOR


def test_planner_scales_working_set_with_batch():
    from repro.core.planner import _predict_working_set, max_feasible_lanes
    peak1, pipe1 = _predict_working_set(12, 6, 2, 2, 4.0, lanes=1)
    peak4, pipe4 = _predict_working_set(12, 6, 2, 2, 4.0, lanes=4)
    assert peak4 > 3 * peak1 and pipe4 == 4 * pipe1
    budget = (peak1 + pipe1) * 2
    got = max_feasible_lanes(12, 6, 2, 2, 4.0, budget, 8)
    assert 1 <= got < 8
    assert max_feasible_lanes(12, 6, 2, 2, 4.0, 10 * (peak4 + pipe4), 4) == 4


def test_plan_records_batch_factor_and_round_trips():
    from repro.core.plan import ExecutionPlan
    qc = build_circuit("qft", 10)
    cfg = EngineConfig(local_bits=5, batch=4)
    with Simulator(qc, cfg) as sim:
        plan = sim.compile()
        assert plan.batch == 4
        again = ExecutionPlan.from_json(plan.to_json())
        assert again.batch == 4 and again.fingerprint == plan.fingerprint


# -- the CI benchmark regression gate ----------------------------------------

@pytest.fixture(autouse=True)
def _no_step_summary(monkeypatch):
    """compare.main appends its table to $GITHUB_STEP_SUMMARY when set —
    the synthetic fixtures here must not pollute a real CI job summary
    with fake regression tables."""
    monkeypatch.delenv("GITHUB_STEP_SUMMARY", raising=False)


def _bench_json(tmp_path, name, values):
    report = {"benches": {"demo": {"elapsed_s": 1.0, "metrics": {
        "demo": values}}}, "unix_time": 0.0}
    path = tmp_path / name
    path.write_text(json.dumps(report))
    return str(path)


def test_compare_passes_on_noise_and_fails_on_5x(tmp_path):
    base = _bench_json(tmp_path, "base.json",
                       {"a_s": 1.0, "b_s": 2.0, "c_s": 4.0, "n_gates": 9})
    ok = _bench_json(tmp_path, "ok.json",
                     {"a_s": 1.8, "b_s": 2.5, "c_s": 3.1, "n_gates": 9})
    slow = _bench_json(tmp_path, "slow.json",
                       {"a_s": 5.0, "b_s": 2.0, "c_s": 4.0, "n_gates": 9})
    assert bench_compare.main([base, ok]) == 0
    assert bench_compare.main([base, slow]) != 0
    # a uniformly 4x slower runner is machine noise, not a regression
    uniform = _bench_json(tmp_path, "uniform.json",
                          {"a_s": 4.0, "b_s": 8.0, "c_s": 16.0})
    assert bench_compare.main([base, uniform]) == 0
    # ... unless the gate is asked for absolute ratios
    assert bench_compare.main([base, uniform, "--absolute"]) != 0
    # the normalization blind spot is bounded: a suite-wide 20x slowdown
    # cannot hide behind its own median
    crater = _bench_json(tmp_path, "crater.json",
                         {"a_s": 20.0, "b_s": 40.0, "c_s": 80.0})
    assert bench_compare.main([base, crater]) != 0


def test_compare_skips_micro_rows_and_disjoint_keys(tmp_path):
    base = _bench_json(tmp_path, "base.json",
                       {"tiny_s": 0.001, "real_s": 1.0, "gone_s": 1.0})
    new = _bench_json(tmp_path, "new.json",
                      {"tiny_s": 0.9, "real_s": 1.1, "fresh_s": 1.0})
    # tiny_s blew up 900x but sits under the noise floor; gone_s/fresh_s
    # have no counterpart — neither may trip the gate
    assert bench_compare.main([base, new]) == 0


def test_compare_gates_speedup_rows(tmp_path):
    """``*_speedup`` rows gate in the opposite direction: a depth-2
    overlap ratio collapsing back toward the pre-wave-coalescing losing
    range fails, mild jitter passes, and improvements never gate."""
    base = _bench_json(tmp_path, "base.json",
                       {"run_s": 2.0, "depth_2_speedup": 1.10})
    held = _bench_json(tmp_path, "held.json",
                       {"run_s": 2.1, "depth_2_speedup": 0.95})
    better = _bench_json(tmp_path, "better.json",
                         {"run_s": 2.0, "depth_2_speedup": 1.40})
    lost = _bench_json(tmp_path, "lost.json",
                       {"run_s": 2.0, "depth_2_speedup": 0.58})
    assert bench_compare.main([base, held]) == 0
    assert bench_compare.main([base, better]) == 0
    assert bench_compare.main([base, lost]) != 0


def test_compare_gate_on_committed_baselines():
    """The real pair the CI job diffs: the two newest committed
    perf-trajectory baselines must pass their own gate."""
    import pathlib
    root = pathlib.Path(__file__).resolve().parent.parent
    benches = sorted(root.glob("BENCH_*.json"),
                     key=lambda p: int(p.stem.split("_")[1]))
    if len(benches) < 2:
        pytest.skip("committed BENCH baselines not present")
    base, cur = benches[-2], benches[-1]
    assert bench_compare.main([str(base), str(cur)]) == 0
