"""Resilience layer: deterministic fault injection, end-to-end block
integrity, crash/resume equivalence at every stage boundary, and the
memory-pressure degradation ladder.

The fault matrix this file pins down: every injected fault is either
(a) retried/degraded away and the run completes with the correct state,
or (b) surfaced as a typed error carrying a resumable checkpoint that
reproduces the uninterrupted result — and corrupted blobs/snapshots are
ALWAYS detected, never silently decoded.
"""
import os
import random

import numpy as np
import pytest

from repro import (BlockCorruptionError, CheckpointError, EngineConfig,
                   MemoryPressureError, ResumableError, Simulator,
                   StoreIOError, build_circuit, inject_faults)
from repro.compression.store import BlockStore
from repro.core.pressure import RUNGS, PressureMonitor
from repro.faults import (INJECTION_POINTS, FaultInjector, FaultSpec,
                          InjectedCrash, fault_point)

# small enough to be fast, big enough to spill + multi-stage
QC9 = build_circuit("qft", 9)


def _cfg(**kw):
    kw.setdefault("local_bits", 4)
    kw.setdefault("ram_budget_bytes", 1000)   # forces the disk tier
    return EngineConfig(**kw)


def _amps(sim_result):
    return sim_result.amplitudes(range(32))


@pytest.fixture(scope="module")
def ref9():
    with Simulator(QC9, _cfg()) as sim:
        yield _amps(sim.run()), sim.stats.n_stages


# -- fault-injection framework ----------------------------------------------

def test_fault_spec_parse_roundtrip():
    s = FaultSpec.parse("store.spill_read:ioerror:hit=3,7:times=2")
    assert s.point == "store.spill_read" and s.kind == "ioerror"
    assert s.hits == (3, 7) and s.times == 2 and s.p == 0.0
    s2 = FaultSpec.parse("pipeline.fetch:crash:p=0.25")
    assert s2.p == 0.25 and s2.hits is None


@pytest.mark.parametrize("bad", [
    "nonsense.point:ioerror",          # unknown point
    "store.spill_read:meltdown",       # unknown kind
    "pipeline.fetch:corrupt",          # corrupt needs a byte-carrying point
    "store.spill_read:ioerror:hit=x",  # unparsable hit
])
def test_fault_spec_rejects(bad):
    with pytest.raises(ValueError):
        FaultSpec.parse(bad)


def test_injector_hit_determinism():
    """hit= specs fire on exactly the named per-point hits."""
    inj = FaultInjector([FaultSpec.parse("codec.encode:ioerror:hit=2,4")])
    fired = []
    for i in range(1, 6):
        try:
            inj.fire("codec.encode", None)
        except OSError:
            fired.append(i)
    assert fired == [2, 4]
    assert inj.fired["codec.encode:ioerror"] == 2


def test_injector_probabilistic_seed_determinism():
    """Same seed -> identical firing pattern; p=1 always fires."""
    def pattern(seed):
        inj = FaultInjector([FaultSpec.parse("pipeline.fetch:ioerror:p=0.5")],
                            seed=seed)
        out = []
        for i in range(20):
            try:
                inj.fire("pipeline.fetch", None)
                out.append(0)
            except OSError:
                out.append(1)
        return out

    assert pattern(3) == pattern(3)
    assert 0 < sum(pattern(3)) < 20


def test_injector_corrupt_flips_one_byte_and_times_cap():
    inj = FaultInjector(
        [FaultSpec.parse("store.spill_write:corrupt:p=1:times=1")], seed=1)
    data = bytes(range(64))
    out = inj.fire("store.spill_write", data)
    assert len(out) == len(data)
    assert sum(a != b for a, b in zip(out, data)) == 1
    # times=1 exhausted: passes through untouched now
    assert inj.fire("store.spill_write", data) == data


def test_fault_point_is_noop_without_injector():
    payload = b"abc"
    assert fault_point("store.spill_read", payload) is payload
    assert fault_point("pipeline.fetch") is None


def test_injection_points_frozen():
    assert "checkpoint.write" in INJECTION_POINTS
    with pytest.raises(ValueError, match="unknown injection point"):
        FaultInjector([FaultSpec.parse("store.spill_read:ioerror")]) \
            .fire("not.a.point", None)


# -- store integrity & typed I/O errors -------------------------------------

def test_spill_write_transient_ioerror_retried(ref9, tmp_path):
    ref, _ = ref9
    with inject_faults(["store.spill_write:ioerror:hit=1"]) as inj:
        with Simulator(QC9, _cfg(spill_dir=str(tmp_path))) as sim:
            amps = _amps(sim.run())
            assert sim.stats.n_io_retries >= 1
    assert inj.fired["store.spill_write:ioerror"] == 1
    assert np.array_equal(amps, ref)


def test_spill_io_exhaustion_is_typed(tmp_path):
    """Retries exhausted -> StoreIOError naming the key, not a raw
    OSError escaping a worker thread."""
    with inject_faults(["store.spill_write:ioerror"]):
        with pytest.raises(StoreIOError) as ei:
            with Simulator(QC9, _cfg(spill_dir=str(tmp_path))) as sim:
                sim.run()
    assert ei.value.key is not None
    assert ei.value.retries == 3
    assert "spill write" in str(ei.value)


def test_direct_disk_byte_flip_detected(tmp_path):
    """Flip one byte of a spilled blob on disk: the next read must raise
    BlockCorruptionError, never return wrong bytes."""
    store = BlockStore(ram_budget_bytes=64, spill_dir=str(tmp_path))
    try:
        store.put(0, b"A" * 256)
        store.put(1, b"B" * 256)          # pushes key 0 to disk
        spilled = [f for f in os.listdir(tmp_path)
                   if f.startswith("blob_")]
        assert spilled
        victim = os.path.join(str(tmp_path), spilled[0])
        raw = bytearray(open(victim, "rb").read())
        raw[len(raw) // 2] ^= 0xFF
        with open(victim, "wb") as f:
            f.write(bytes(raw))
        with pytest.raises(BlockCorruptionError) as ei:
            store.get(0)
        assert ei.value.expected_crc != ei.value.actual_crc
        assert store.stats.n_corruptions_detected == 1
    finally:
        store.close()


def test_checksums_off_skips_verification(tmp_path):
    store = BlockStore(ram_budget_bytes=64, spill_dir=str(tmp_path),
                       checksums=False)
    try:
        store.put(0, b"A" * 256)
        store.put(1, b"B" * 256)
        assert store.get(0) == b"A" * 256   # round-trips fine
        assert store.stats.n_corruptions_detected == 0
    finally:
        store.close()


def test_injected_corruption_detected_midrun(tmp_path):
    with inject_faults(["store.spill_write:corrupt:hit=1"]):
        with pytest.raises(BlockCorruptionError):
            with Simulator(QC9, _cfg(spill_dir=str(tmp_path))) as sim:
                _amps(sim.run())


def test_proactive_spill_moves_blobs(tmp_path):
    store = BlockStore(ram_budget_bytes=None, spill_dir=str(tmp_path))
    try:
        for k in range(8):
            store.put(k, bytes([k]) * 128)
        assert store.stats.disk_bytes == 0
        moved = store.spill(256)
        assert moved >= 6
        assert store.stats.ram_bytes <= 256
        assert store.stats.n_proactive_spills == moved
        for k in range(8):
            assert store.get(k) == bytes([k]) * 128
    finally:
        store.close()


# -- snapshot durability & validation ---------------------------------------

def _snapshot_of_run(tmp_path, name="snap.bmq"):
    path = str(tmp_path / name)
    with Simulator(QC9, _cfg()) as sim:
        sim.run().save(path)
    return path


def test_snapshot_truncation_detected(tmp_path):
    path = _snapshot_of_run(tmp_path)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.truncate(size - 7)
    with pytest.raises(CheckpointError, match="truncated|length"):
        BlockStore.restore(path)


def test_snapshot_blob_tamper_detected(tmp_path):
    path = _snapshot_of_run(tmp_path)
    size = os.path.getsize(path)
    with open(path, "r+b") as f:
        f.seek(size - 9)                  # inside the last blob
        b = f.read(1)
        f.seek(size - 9)
        f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(BlockCorruptionError, match="snapshot"):
        BlockStore.restore(path)


def test_snapshot_bad_magic_is_valueerror(tmp_path):
    path = str(tmp_path / "junk.bmq")
    with open(path, "wb") as f:
        f.write(b"not a checkpoint at all")
    with pytest.raises(ValueError):      # CheckpointError subclasses it
        BlockStore.restore(path)


def test_snapshot_leaves_no_temp_files(tmp_path):
    _snapshot_of_run(tmp_path)
    names = os.listdir(tmp_path)
    assert not [n for n in names if "tmp" in n]


def test_snapshot_write_ioerror_retried_then_typed(tmp_path):
    path = str(tmp_path / "ck.bmq")
    with Simulator(QC9, _cfg()) as sim:
        r = sim.run()
        with inject_faults(["checkpoint.write:ioerror:hit=1"]):
            r.save(path)                 # transient: retried
        store2, _ = BlockStore.restore(path)
        store2.close()
        with inject_faults(["checkpoint.write:ioerror"]):
            with pytest.raises(StoreIOError, match="snapshot"):
                r.save(str(tmp_path / "ck2.bmq"))
    assert not os.path.exists(str(tmp_path / "ck2.bmq"))


# -- simulator-level recovery contracts -------------------------------------

def test_auto_replay_from_checkpoint(ref9, tmp_path):
    """Corruption detected after a checkpoint exists -> the run replays
    from it in-process and still produces the correct state."""
    ref, _ = ref9
    ck = str(tmp_path / "ck.bmq")
    with inject_faults(["store.spill_write:corrupt:hit=40"]):
        with Simulator(QC9, _cfg()) as sim:
            amps = _amps(sim.run(checkpoint_path=ck, checkpoint_every=1))
            assert sim.stats.n_replays == 1
    assert np.array_equal(amps, ref)


def test_corruption_without_checkpoint_propagates(tmp_path):
    with inject_faults(["store.spill_write:corrupt:hit=40"]):
        with pytest.raises(BlockCorruptionError):
            with Simulator(QC9, _cfg(spill_dir=str(tmp_path))) as sim:
                _amps(sim.run())


def test_io_exhaustion_becomes_resumable(ref9, tmp_path):
    """checkpoint 2's write dies persistently -> ResumableError naming
    checkpoint 1, which reproduces the uninterrupted run."""
    ref, _ = ref9
    ck = str(tmp_path / "ck.bmq")
    with inject_faults(["checkpoint.write:ioerror:hit=2,3,4,5"]):
        with pytest.raises(ResumableError) as ei:
            with Simulator(QC9, _cfg()) as sim:
                sim.run(checkpoint_path=ck, checkpoint_every=1)
    assert ei.value.resume_path == ck and ei.value.stages_done == 1
    assert isinstance(ei.value.__cause__, StoreIOError)
    resumed = Simulator.resume(ck, circuit=QC9, config=_cfg())
    try:
        assert resumed._start_stage == 1
        assert np.array_equal(_amps(resumed.run()), ref)
    finally:
        resumed.close()


def test_midstage_fetch_crash_then_resume(ref9, tmp_path):
    """A hard crash inside a pipeline fetch (mid-stage!) leaves the last
    stage-boundary checkpoint on disk; resuming it is exact."""
    ref, n_stages = ref9
    assert n_stages > 3
    ck = str(tmp_path / "ck.bmq")
    with inject_faults(["pipeline.fetch:crash:hit=30"]):
        with pytest.raises(InjectedCrash):
            with Simulator(QC9, _cfg()) as sim:
                sim.run(checkpoint_path=ck, checkpoint_every=1)
    resumed = Simulator.resume(ck, circuit=QC9, config=_cfg())
    try:
        assert 0 < resumed._start_stage < n_stages
        assert np.array_equal(_amps(resumed.run()), ref)
    finally:
        resumed.close()


@pytest.mark.parametrize("backend", ["host", "device"])
def test_crash_resume_equivalence_every_boundary(backend, tmp_path):
    """Kill the run at EVERY stage boundary in turn (crash while writing
    checkpoint k+1, so checkpoint k is the last good one); resuming must
    reproduce the uninterrupted state — bitwise on the host codec,
    TV-bound on the lossy device codec (same compressed blocks, so in
    practice bitwise there too)."""
    qc = build_circuit("qft", 7)
    mk = lambda: EngineConfig(local_bits=4, codec_backend=backend)  # noqa: E731
    with Simulator(qc, mk()) as sim:
        ref = _amps(sim.run())
        n_stages = sim.stats.n_stages
    assert n_stages >= 3
    for k in range(1, n_stages):
        ck = str(tmp_path / f"{backend}-{k}.bmq")
        with inject_faults([f"checkpoint.write:crash:hit={k + 1}"]):
            with pytest.raises(InjectedCrash):
                with Simulator(qc, mk()) as sim:
                    sim.run(checkpoint_path=ck, checkpoint_every=1)
        resumed = Simulator.resume(ck, circuit=qc, config=mk())
        try:
            assert resumed._start_stage == k
            amps = _amps(resumed.run())
        finally:
            resumed.close()
        assert np.array_equal(amps, ref), f"boundary {k} diverged"


@pytest.mark.parametrize("point,hit", [
    ("store.spill_write", 60),
    ("store.spill_read", 120),
    ("codec.encode", 60),
    ("codec.decode", 60),
    ("pipeline.fetch", 30),
    ("pipeline.store", 30),
    ("checkpoint.write", 3),
])
def test_every_point_crash_is_resumable(point, hit, ref9, tmp_path):
    """The fault matrix, crash row: a hard crash at EVERY registered
    injection point (at a hit deep enough that a checkpoint exists)
    leaves a checkpoint that reproduces the uninterrupted state."""
    ref, n_stages = ref9
    ck = str(tmp_path / f"{point}.bmq")
    with inject_faults([f"{point}:crash:hit={hit}"]) as inj:
        with pytest.raises(InjectedCrash):
            with Simulator(QC9, _cfg()) as sim:
                sim.run(checkpoint_path=ck, checkpoint_every=1)
    assert inj.fired[f"{point}:crash"] == 1
    assert os.path.exists(ck), f"no checkpoint survived {point} crash"
    resumed = Simulator.resume(ck, circuit=QC9, config=_cfg())
    try:
        assert 0 < resumed._start_stage < n_stages
        assert np.array_equal(_amps(resumed.run()), ref)
    finally:
        resumed.close()


# -- memory-pressure degradation ladder -------------------------------------

def test_pressure_ladder_escalates_in_order(ref9):
    """An (artificially) hopeless headroom walks shrink_window ->
    wave_depth_1 -> proactive_spill, one rung per boundary, and the run
    still completes correctly."""
    ref, _ = ref9
    with Simulator(QC9, _cfg(pipeline_depth=2,
                             pressure_headroom=1e-6)) as sim:
        amps = _amps(sim.run())
        rungs = [r.split(":")[1] for r in sim.stats.pressure_rungs]
        assert rungs == list(RUNGS)
        assert sim.stats.n_pressure_events == len(RUNGS)
        assert sim.stats.n_proactive_spills > 0
    assert np.array_equal(amps, ref)


def test_no_pressure_no_rungs(ref9):
    with Simulator(QC9, _cfg()) as sim:
        sim.run()
        assert sim.stats.pressure_rungs == []
        assert sim.stats.n_pressure_events == 0


def test_disk_budget_abort_is_resumable(ref9):
    """Disk-tier overflow aborts at a stage boundary with an emergency
    checkpoint; resuming it (without the budget) completes correctly."""
    ref, _ = ref9
    with pytest.raises(MemoryPressureError) as ei:
        with Simulator(QC9, _cfg(disk_budget_bytes=500)) as sim:
            sim.run()
    err = ei.value
    assert err.resume_path and os.path.exists(err.resume_path)
    assert err.stages_done >= 1
    assert any("abort" in r for r in sim.stats.pressure_rungs)
    try:
        resumed = Simulator.resume(err.resume_path, circuit=QC9,
                                   config=_cfg())
        try:
            assert resumed._start_stage == err.stages_done
            assert np.array_equal(_amps(resumed.run()), ref)
        finally:
            resumed.close()
    finally:
        os.unlink(err.resume_path)


def test_pressure_monitor_unit():
    class _Stats:
        disk_bytes = 0
        ram_bytes = 0

    class _Store:
        total_bytes = 10_000
        stats = _Stats()

    class _Pipe:
        depth = 4
        inflight_window = 2

    mon = PressureMonitor(predicted_bpa=1e-9, n_qubits=4, headroom=1.5)
    pipe = _Pipe()
    mon.check(_Store(), pipe, None, 1)
    assert pipe.inflight_window == 1 and pipe.depth == 4
    mon.check(_Store(), pipe, None, 2)
    assert pipe.depth == 1
    mon2 = PressureMonitor(predicted_bpa=1e9, n_qubits=4)
    mon2.check(_Store(), pipe, None, 1)
    assert mon2.rung == 0                 # no pressure, no escalation


# -- batched runs are checkpoint-free by contract ----------------------------

def test_run_batch_rejects_checkpointing(tmp_path):
    with Simulator(QC9, _cfg()) as sim:
        with pytest.raises(ValueError, match="run_batch does not support"):
            sim.run_batch([None, None],
                          checkpoint_path=str(tmp_path / "x.bmq"),
                          checkpoint_every=1)
        with pytest.raises(ValueError, match="run_batch does not support"):
            sim.run_batch([None], checkpoint_every=2)


# -- chaos: seeded random fault sweep ----------------------------------------

_CHAOS_MENU = [
    "store.spill_write:ioerror:p=0.02",
    "store.spill_read:ioerror:p=0.02",
    "store.spill_write:corrupt:hit=17",
    "pipeline.fetch:ioerror:hit=9",
    "pipeline.store:crash:hit=11",
    "codec.decode:crash:hit=25",
    "checkpoint.write:ioerror:hit=3",
    "checkpoint.write:crash:hit=4",
]


def test_chaos_typed_or_correct(ref9, tmp_path):
    """Under ANY injected fault mix the run either completes with the
    correct state or fails with a typed, attributable error — and when
    it names a resume path, that path reproduces the reference.  Seeded
    from BMQSIM_CHAOS_SEED so CI can sweep."""
    ref, _ = ref9
    seed = int(os.environ.get("BMQSIM_CHAOS_SEED", "0"))
    rng = random.Random(seed)
    specs = rng.sample(_CHAOS_MENU, k=2)
    ck = str(tmp_path / "chaos.bmq")
    try:
        with inject_faults(specs, seed=seed):
            with Simulator(QC9, _cfg()) as sim:
                amps = _amps(sim.run(checkpoint_path=ck,
                                     checkpoint_every=1))
        assert np.array_equal(amps, ref), f"specs={specs} seed={seed}"
    except (StoreIOError, BlockCorruptionError, InjectedCrash) as e:
        # typed + attributable; chaos may legitimately kill the run
        assert type(e).__module__.startswith("repro") or \
            isinstance(e, (OSError, RuntimeError))
    except ResumableError as e:
        assert e.resume_path
        resumed = Simulator.resume(e.resume_path, circuit=QC9,
                                   config=_cfg())
        try:
            assert np.array_equal(_amps(resumed.run()), ref), \
                f"resume diverged: specs={specs} seed={seed}"
        finally:
            resumed.close()


# -- spill path raises typed errors, not raw OSError -------------------------

def test_missing_spill_file_is_typed(tmp_path):
    """Deleting a spilled blob behind the store's back surfaces as a
    typed StoreIOError naming the path (FileNotFoundError is a rebind
    signal internally, but a truly missing blob must not leak raw)."""
    store = BlockStore(ram_budget_bytes=64, spill_dir=str(tmp_path))
    try:
        store.put(0, b"A" * 256)
        store.put(1, b"B" * 256)
        for f in os.listdir(tmp_path):
            if f.startswith("blob_"):
                os.unlink(os.path.join(str(tmp_path), f))
        with pytest.raises(StoreIOError, match="missing"):
            store.get(0)
    finally:
        store.close()


def test_segments_nbytes_matches_serialization():
    """The spill byte-ledger depends on nbytes == len(to_bytes())."""
    from repro.compression.codec import encode_block_host
    from repro.compression.pwrel import PwRelParams
    rng = np.random.default_rng(0)
    x = (rng.standard_normal(64) + 1j * rng.standard_normal(64)) \
        .astype(np.complex64)
    seg = encode_block_host(x, PwRelParams(b_r=1e-3))
    assert seg.nbytes == len(seg.to_bytes())
