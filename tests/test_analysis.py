"""Static-analysis layer: the AST lint checkers and the plan verifier.

Two halves, mirroring ``repro.analysis``:

* each lint checker is pinned with a *positive* fixture (a seeded
  violation it must flag) and a *negative* fixture (correct idiom it
  must stay silent on), plus the pragma discipline around them;
* the plan verifier is proven to reject tampered plans that the
  state-layout fingerprint alone accepts — the exact gap it exists to
  close — while passing every plan the planner actually emits.
"""
import textwrap
from dataclasses import replace

import pytest

from repro.analysis import check_plan, verify_plan
from repro.analysis.lint import (Violation, all_checkers, is_quarantined,
                                 load_quarantine, run_checkers)
from repro.core import EngineConfig, Simulator, build_circuit
from repro.core.groups import GroupLayout
from repro.core.plan import ExecutionPlan
from repro.errors import PlanVerificationError

# ---------------------------------------------------------------------------
# lint framework helpers
# ---------------------------------------------------------------------------


def _lint(tmp_path, source, checker=None, name="snippet.py"):
    """Write ``source`` to a temp file and run (one) checker over it."""
    path = tmp_path / name
    path.write_text(textwrap.dedent(source))
    select = [checker] if checker else None
    violations, n_files, _ = run_checkers(
        [str(path)], select=select, use_quarantine=False)
    assert n_files == 1
    return violations


def test_checker_registry_is_complete():
    names = set(all_checkers())
    assert {"fault-coverage", "lock-discipline",
            "jit-purity", "typed-errors"} <= names


def test_unknown_checker_is_an_error(tmp_path):
    (tmp_path / "x.py").write_text("pass\n")
    with pytest.raises(ValueError, match="unknown checker"):
        run_checkers([str(tmp_path)], select=["no-such-checker"])


def test_syntax_error_is_reported_not_raised(tmp_path):
    (tmp_path / "broken.py").write_text("def f(:\n")
    violations, _, _ = run_checkers([str(tmp_path)], use_quarantine=False)
    assert [v.checker for v in violations] == ["parse"]


# -- pragma discipline -------------------------------------------------------

def test_pragma_without_reason_is_itself_flagged(tmp_path):
    violations = _lint(tmp_path, """\
        def spill(path):
            with open(path, "rb") as fh:  # lint: disable=fault-coverage
                return fh.read()
        """)
    checkers = {v.checker for v in violations}
    # the reasonless pragma suppresses nothing AND is flagged itself
    assert "pragma" in checkers
    assert "fault-coverage" in checkers


def test_pragma_with_reason_suppresses(tmp_path):
    violations = _lint(tmp_path, """\
        def spill(path):
            with open(path, "rb") as fh:  # lint: disable=fault-coverage -- test fixture
                return fh.read()
        """)
    assert violations == []


# -- fault-coverage ----------------------------------------------------------

def test_fault_coverage_flags_uninstrumented_io(tmp_path):
    violations = _lint(tmp_path, """\
        def spill(path, blob):
            with open(path, "wb") as fh:
                fh.write(blob)
        """, checker="fault-coverage")
    assert len(violations) == 1
    assert violations[0].checker == "fault-coverage"
    assert "open" in violations[0].message


def test_fault_coverage_accepts_instrumented_io(tmp_path):
    violations = _lint(tmp_path, """\
        from repro.faults import fault_point

        def spill(path, blob):
            fault_point("store.spill_write", blob)
            with open(path, "wb") as fh:
                fh.write(blob)
        """, checker="fault-coverage")
    assert violations == []


def test_fault_coverage_accepts_def_annotation(tmp_path):
    violations = _lint(tmp_path, """\
        # fault-covered: store.spill_write
        def spill(path, blob):
            with open(path, "wb") as fh:
                fh.write(blob)
        """, checker="fault-coverage")
    assert violations == []


def test_fault_coverage_rejects_unknown_point(tmp_path):
    # a typo'd point name must not silently satisfy the checker
    violations = _lint(tmp_path, """\
        from repro.faults import fault_point

        def spill(path, blob):
            fault_point("store.bogus_point", blob)
            with open(path, "wb") as fh:
                fh.write(blob)
        """, checker="fault-coverage")
    assert any("store.bogus_point" in v.message for v in violations)


def test_fault_coverage_rejects_unknown_annotation(tmp_path):
    violations = _lint(tmp_path, """\
        # fault-covered: not.a.point
        def spill(path, blob):
            with open(path, "wb") as fh:
                fh.write(blob)
        """, checker="fault-coverage")
    assert any("not.a.point" in v.message for v in violations)


def test_fault_coverage_flags_codec_primitives(tmp_path):
    violations = _lint(tmp_path, """\
        def roundtrip(planes, n, params):
            return encode_group_planes(planes, n, params)
        """, checker="fault-coverage")
    assert len(violations) == 1
    assert "encode_group_planes" in violations[0].message


# -- lock-discipline ---------------------------------------------------------

def test_lock_discipline_flags_unguarded_access(tmp_path):
    violations = _lint(tmp_path, """\
        import threading

        class Counter:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0   # guarded-by: _lock

            def bump(self):
                self.count += 1
        """, checker="lock-discipline")
    assert len(violations) == 1
    assert "count" in violations[0].message


def test_lock_discipline_accepts_with_block(tmp_path):
    violations = _lint(tmp_path, """\
        import threading

        class Counter:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0   # guarded-by: _lock

            def bump(self):
                with self._lock:
                    self.count += 1
        """, checker="lock-discipline")
    assert violations == []


def test_lock_discipline_accepts_holds_lock_annotation(tmp_path):
    violations = _lint(tmp_path, """\
        import threading

        class Counter:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0   # guarded-by: _lock

            def _bump_locked(self):  # holds-lock: _lock
                self.count += 1
        """, checker="lock-discipline")
    assert violations == []


def test_lock_discipline_tracks_nested_closures(tmp_path):
    # a closure defined inside a with-block still holds the lock
    violations = _lint(tmp_path, """\
        import threading

        class Counter:
            def __init__(self):
                self._lock = threading.Lock()
                self.count = 0   # guarded-by: _lock

            def bump_twice(self):
                with self._lock:
                    def inner():
                        self.count += 1
                    inner()
                    inner()
        """, checker="lock-discipline")
    assert violations == []


# -- jit-purity --------------------------------------------------------------

def test_jit_purity_flags_host_sync_in_jitted_fn(tmp_path):
    violations = _lint(tmp_path, """\
        import jax
        import numpy as np

        @jax.jit
        def f(x):
            return np.asarray(x)
        """, checker="jit-purity")
    assert len(violations) == 1
    assert "asarray" in violations[0].message


def test_jit_purity_follows_call_graph(tmp_path):
    # the sync hides one call deep behind a bare-name helper
    violations = _lint(tmp_path, """\
        import jax

        def helper(x):
            return float(x)

        @jax.jit
        def f(x):
            return helper(x) + 1
        """, checker="jit-purity")
    assert len(violations) == 1
    assert "float" in violations[0].message


def test_jit_purity_allows_static_values(tmp_path):
    # float()/int() over trace-time constants is not a device sync
    violations = _lint(tmp_path, """\
        import jax

        LANES = 4

        @jax.jit
        def f(x):
            return x * float(LANES) + int(len("ab"))
        """, checker="jit-purity")
    assert violations == []


def test_jit_purity_honors_jit_ok_pragma(tmp_path):
    violations = _lint(tmp_path, """\
        import jax
        import numpy as np

        @jax.jit
        def f(x, perm):
            inv = np.argsort(np.asarray(perm))  # jit-ok: perm is static
            return x[inv]
        """, checker="jit-purity")
    assert violations == []


def test_jit_purity_ignores_unreachable_code(tmp_path):
    violations = _lint(tmp_path, """\
        import jax
        import numpy as np

        def host_only(x):
            return np.asarray(x)

        @jax.jit
        def f(x):
            return x + 1
        """, checker="jit-purity")
    assert violations == []


# -- typed-errors ------------------------------------------------------------

def test_typed_errors_flags_swallowed_broad_except(tmp_path):
    violations = _lint(tmp_path, """\
        def f():
            try:
                g()
            except Exception:
                pass
        """, checker="typed-errors")
    assert len(violations) == 1


def test_typed_errors_flags_bare_except_and_broad_raise(tmp_path):
    violations = _lint(tmp_path, """\
        def f():
            try:
                g()
            except:
                raise
            raise Exception("boom")
        """, checker="typed-errors")
    assert len(violations) == 2


def test_typed_errors_accepts_broad_except_that_reraises(tmp_path):
    violations = _lint(tmp_path, """\
        def f():
            try:
                g()
            except Exception:
                cleanup()
                raise
        """, checker="typed-errors")
    assert violations == []


def test_typed_errors_accepts_narrow_except(tmp_path):
    violations = _lint(tmp_path, """\
        def f():
            try:
                g()
            except (OSError, ValueError):
                pass
        """, checker="typed-errors")
    assert violations == []


# -- quarantine --------------------------------------------------------------

def test_quarantine_skips_listed_paths(tmp_path):
    (tmp_path / "live.py").write_text("raise Exception('x')\n")
    dead = tmp_path / "deadwood"
    dead.mkdir()
    (dead / "old.py").write_text("raise Exception('x')\n")
    q = tmp_path / "quarantine.txt"
    q.write_text("deadwood  # dead scaffolding\n")
    violations, n_files, skipped = run_checkers(
        [str(tmp_path)], select=["typed-errors"],
        quarantine_path=str(q))
    assert n_files == 1 and len(skipped) == 1
    assert len(violations) == 1 and "live.py" in violations[0].path


def test_shipped_quarantine_matches_dead_scaffolding():
    entries = load_quarantine()
    frags = [frag for frag, _reason in entries]
    assert "repro/models" in frags and "repro/train" in frags
    # every entry carries its justification
    assert all(reason for _frag, reason in entries)
    assert is_quarantined("src/repro/models/transformer.py", entries)
    assert not is_quarantined("src/repro/core/engine.py", entries)


def test_violation_render_is_clickable():
    v = Violation("typed-errors", "src/x.py", 7, "msg")
    assert v.render() == "src/x.py:7: [typed-errors] msg"


# ---------------------------------------------------------------------------
# the live tree itself must be clean — this IS the CI gate, as a test
# ---------------------------------------------------------------------------

def test_live_tree_has_no_violations():
    import os
    root = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src", "repro")
    violations, n_files, skipped = run_checkers([root])
    assert violations == [], "\n".join(v.render() for v in violations)
    assert n_files > 40          # the live tree, not an empty walk
    assert skipped               # quarantine actually engaged


# ---------------------------------------------------------------------------
# plan verifier
# ---------------------------------------------------------------------------

QC = build_circuit("qft", 9)


@pytest.fixture(scope="module")
def compiled():
    sim = Simulator(QC, EngineConfig(local_bits=4))
    plan = sim.compile(verify=False)
    yield sim, plan
    sim.close()


def test_planner_emitted_plan_is_clean(compiled):
    sim, plan = compiled
    findings = verify_plan(plan, sim.circuit)
    assert [f for f in findings if f.severity == "error"] == []


def test_json_roundtrip_stays_clean(compiled):
    _, plan = compiled
    findings = verify_plan(ExecutionPlan.from_json(plan.to_json()))
    assert [f for f in findings if f.severity == "error"] == []


def test_check_plan_returns_findings_when_clean(compiled):
    sim, plan = compiled
    assert check_plan(plan, sim.circuit) == verify_plan(plan, sim.circuit)


def _tamper_stage(plan, i, **changes):
    stages = list(plan.stages)
    stages[i] = replace(stages[i], **changes)
    return replace(plan, stages=tuple(stages))


def test_shifted_gate_slice_is_fingerprint_invisible_but_caught(compiled):
    """THE motivating case: same slice length, wrong gates."""
    sim, plan = compiled
    lo, hi = plan.stages[0].gate_slice
    bad = _tamper_stage(plan, 0, gate_slice=(lo + 1, hi + 1))
    # the fingerprint hashes only slice LENGTHS — it cannot see this
    assert bad.fingerprint == plan.fingerprint
    with pytest.raises(PlanVerificationError) as exc:
        check_plan(bad, sim.circuit)
    assert any(f.code == "gate-tiling" for f in exc.value.findings)


def test_wrong_layout_chain_is_fingerprint_invisible_but_caught(compiled):
    """Same inner set, GroupLayout rebuilt with the wrong local_bits."""
    sim, plan = compiled
    lay = plan.stages[0].layout
    bad_layout = GroupLayout(lay.n_qubits, lay.local_bits + 1, lay.inner)
    bad = _tamper_stage(plan, 0, layout=bad_layout)
    assert bad.fingerprint == plan.fingerprint
    with pytest.raises(PlanVerificationError) as exc:
        check_plan(bad, sim.circuit)
    assert any(f.code == "layout-chain" for f in exc.value.findings)


def test_tampered_predictions_are_caught(compiled):
    _, plan = compiled
    bad = replace(plan, predicted=replace(
        plan.predicted, boundary_bytes=plan.predicted.boundary_bytes + 1))
    with pytest.raises(PlanVerificationError) as exc:
        check_plan(bad)
    assert any(f.code == "predictions" for f in exc.value.findings)


def test_stale_stagefn_key_is_caught(compiled):
    _, plan = compiled
    sp = plan.stages[0]
    bad = _tamper_stage(plan, 0, stagefn_key=sp.stagefn_key[:1]
                        + (sp.stagefn_key[1] + 1,) + sp.stagefn_key[2:])
    with pytest.raises(PlanVerificationError) as exc:
        check_plan(bad)
    assert any(f.code == "stagefn-key" for f in exc.value.findings)


def test_wrong_transpose_counts_are_caught(compiled):
    _, plan = compiled
    i = next(i for i, sp in enumerate(plan.stages) if sp.plan)
    bad = _tamper_stage(plan, i,
                        n_transposes=plan.stages[i].n_transposes + 1)
    with pytest.raises(PlanVerificationError) as exc:
        check_plan(bad)
    assert any(f.code == "schedule-replay" for f in exc.value.findings)


def test_foreign_circuit_is_rejected(compiled):
    _, plan = compiled
    other = build_circuit("cat_state", 9)
    with pytest.raises(PlanVerificationError) as exc:
        check_plan(plan, other)
    assert any(f.code == "gate-tiling" for f in exc.value.findings)


def test_bogus_knobs_are_rejected(compiled):
    _, plan = compiled
    with pytest.raises(PlanVerificationError):
        check_plan(replace(plan, pipeline_depth=0))
    with pytest.raises(PlanVerificationError):
        check_plan(replace(plan, local_bits=plan.n_qubits + 1))


def test_over_budget_plan_warns_but_executes(compiled):
    _, plan = compiled
    tight = replace(plan, memory_budget_bytes=1)
    findings = check_plan(tight)      # must NOT raise
    assert any(f.severity == "warning" and f.code == "budget"
               for f in findings)


def test_plan_verification_error_is_a_value_error(compiled):
    sim, plan = compiled
    lo, hi = plan.stages[0].gate_slice
    bad = _tamper_stage(plan, 0, gate_slice=(lo + 1, hi + 1))
    with pytest.raises(ValueError):   # generic bad-artifact handling
        check_plan(bad, sim.circuit)


def test_simulator_compile_verifies_by_default():
    sim = Simulator(QC, EngineConfig(local_bits=4))
    try:
        plan = sim.compile()          # verify=True is the default
        assert plan.n_stages > 1
    finally:
        sim.close()


def test_finding_render_carries_stage():
    from repro.analysis.plan_check import PlanFinding
    f = PlanFinding("error", "gate-tiling", "oops", stage=3)
    assert f.render() == "[error] gate-tiling: stage 3: oops"
    g = PlanFinding("warning", "budget", "tight")
    assert g.render() == "[warning] budget: tight"


# ---------------------------------------------------------------------------
# CLI surfaces
# ---------------------------------------------------------------------------

def test_analysis_cli_lints_and_exits_nonzero(tmp_path, capsys):
    from repro.analysis.__main__ import main
    (tmp_path / "bad.py").write_text("raise Exception('x')\n")
    assert main([str(tmp_path), "--select", "typed-errors"]) == 1
    assert "typed-errors" in capsys.readouterr().out
    (tmp_path / "bad.py").write_text("raise ValueError('x')\n")
    assert main([str(tmp_path), "--select", "typed-errors"]) == 0


def test_analysis_cli_verifies_plan_artifact(tmp_path, capsys, compiled):
    from repro.analysis.__main__ import main
    _, plan = compiled
    artifact = tmp_path / "plan.json"
    artifact.write_text(plan.to_json())
    assert main(["--plan", str(artifact)]) == 0
    # tamper the artifact on disk: shift stage 0's slice (same length)
    import json
    doc = json.loads(plan.to_json())
    lo, hi = doc["stages"][0]["gate_slice"]
    doc["stages"][0]["gate_slice"] = [lo + 1, hi + 1]
    artifact.write_text(json.dumps(doc))
    capsys.readouterr()
    assert main(["--plan", str(artifact)]) == 1
    assert "gate-tiling" in capsys.readouterr().out


def test_qsim_verify_flag(capsys):
    from repro.launch.qsim import main
    rc = main(["--circuit", "qft", "--qubits", "9", "--block-bits", "4",
               "--verify"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "verified" in out and "no stage executed" in out
