"""End-to-end behaviour of the paper's system: BMQSIM vs the dense oracle.

Covers the paper's headline claims at container scale:
  * fidelity > 0.99 on all 8 NWQBench circuits          (Fig. 8)
  * compression count == #stages << #gates              (4.1)
  * memory reduction vs the 2^(n+4) standard            (Fig. 9 direction)
  * two-level store spill correctness under a RAM budget (4.4)
  * no-compression engine == compressed within bound     (Fig. 11 harness)
"""
import numpy as np
import pytest

from repro.core import (CIRCUIT_BUILDERS, EngineConfig, build_circuit,
                        fidelity, partition_circuit, random_circuit,
                        simulate_bmqsim, simulate_dense)

ALL_CIRCUITS = sorted(CIRCUIT_BUILDERS)


def _fid(circuit, config):
    ideal = np.asarray(simulate_dense(circuit))
    state, stats = simulate_bmqsim(circuit, config)
    return fidelity(ideal.astype(np.complex128),
                    state.astype(np.complex128)), stats


@pytest.mark.parametrize("name", ALL_CIRCUITS)
def test_fidelity_all_circuits(name):
    qc = build_circuit(name, 10)
    fid, stats = _fid(qc, EngineConfig(local_bits=5, inner_size=2))
    assert fid > 0.99, (name, fid)
    assert stats.n_stages <= stats.n_gates


@pytest.mark.parametrize("name", ["qft", "qaoa"])
def test_fidelity_deep_circuits(name):
    """Deeper circuits: error accumulation stays bounded (paper: >0.99)."""
    qc = build_circuit(name, 12)
    fid, _ = _fid(qc, EngineConfig(local_bits=6, inner_size=2))
    assert fid > 0.99, (name, fid)


def test_stage_count_much_less_than_gates():
    qc = build_circuit("qft", 14)
    part = partition_circuit(qc, local_bits=8, inner_size=2)
    # paper's 33q example: 2673 gates -> 28 stages; same shape here
    assert part.n_stages < len(qc) / 4


def test_compression_counts_match_stages():
    qc = build_circuit("qft", 10)
    _, stats = _fid(qc, EngineConfig(local_bits=5, inner_size=2))
    layouts = partition_circuit(qc, 5, 2)
    assert stats.n_block_decompressions > 0
    assert stats.n_stages == layouts.n_stages


def test_memory_reduction_sparse_state():
    """cat/ghz states compress enormously (paper: 678x)."""
    qc = build_circuit("ghz_state", 16)
    _, stats = _fid(qc, EngineConfig(local_bits=10, inner_size=2))
    assert stats.memory_reduction > 30


def test_ram_budget_spills_to_disk(tmp_path):
    qc = build_circuit("qsvm", 10)
    cfg = EngineConfig(local_bits=5, inner_size=2,
                       ram_budget_bytes=2000, spill_dir=str(tmp_path))
    fid, stats = _fid(qc, cfg)
    assert fid > 0.99
    assert stats.n_spills > 0          # the 2nd tier actually engaged


def test_no_compression_mode_matches():
    qc = build_circuit("ising", 9)
    ideal = np.asarray(simulate_dense(qc))
    s1, st1 = simulate_bmqsim(qc, EngineConfig(local_bits=5, compression=False))
    assert fidelity(ideal.astype(np.complex128), s1.astype(np.complex128)) \
        > 1 - 1e-5
    assert st1.peak_total_bytes >= st1.standard_bytes_c64 * 0.9


def test_random_circuits_fidelity():
    for seed in range(3):
        qc = random_circuit(9, 40, seed=seed)
        fid, stats = _fid(qc, EngineConfig(local_bits=4, inner_size=2))
        assert fid > 0.99, (seed, fid)


def test_norm_preserved():
    qc = random_circuit(10, 50, seed=7)
    state, _ = simulate_bmqsim(qc, EngineConfig(local_bits=5))
    assert abs(np.linalg.norm(state) - 1.0) < 5e-3


def test_kernel_engine_path_matches_jnp_path():
    qc = build_circuit("qft", 8)
    s1, _ = simulate_bmqsim(qc, EngineConfig(local_bits=4, use_kernel=True,
                                             max_fused_qubits=4))
    s2, _ = simulate_bmqsim(qc, EngineConfig(local_bits=4, use_kernel=False,
                                             max_fused_qubits=4))
    np.testing.assert_allclose(s1, s2, atol=1e-5)


def test_inner_size_sweep_fidelity():
    qc = build_circuit("qft", 10)
    for inner in (2, 3, 4):
        fid, _ = _fid(qc, EngineConfig(local_bits=4, inner_size=inner))
        assert fid > 0.99, (inner, fid)


def test_initial_state_trick():
    """Init compresses exactly 2 blocks regardless of block count (4.2)."""
    from repro.core.engine import BMQSimEngine
    qc = build_circuit("ghz_state", 12)
    eng = BMQSimEngine(qc, EngineConfig(local_bits=4))
    eng._init_state()
    assert eng.stats.n_block_compressions == 2
    assert len(eng.store.keys()) == 2 ** 8
    eng.close()
