"""Session API: Simulator/SimResult — schedule reuse across runs,
streaming readout correctness + memory bounds, checkpoint round trips,
parameterized binding."""
import tracemalloc

import numpy as np
import pytest

from repro.core import (Circuit, EngineConfig, Parameter, Simulator,
                        build_circuit, maxcut_cost_fn, maxcut_edges,
                        qaoa_template, random_circuit, simulate_dense)
from repro.compression.pwrel import PwRelParams
from repro.compression.store import BlockStore
from repro.core.pipeline import HostCodecBackend
from repro.core.result import stream_sample


# -- schedule reuse (the session's core perf contract) -----------------------

def test_sweep_compiles_stage_fns_exactly_once():
    """A two-point angle sweep on one session must not compile any stage
    function after the first run — only score cache hits."""
    cfg = EngineConfig(local_bits=5)
    with Simulator(qaoa_template(10, layers=1), cfg) as sim:
        r1 = sim.run(params={"gamma0": 0.3, "beta0": 0.2})
        e1 = r1.expectation(maxcut_cost_fn(maxcut_edges(10)))
        compiles_1 = sim.stats.n_stagefn_compiles
        hits_1 = sim.stats.n_stagefn_cache_hits

        r2 = sim.run(params={"gamma0": 1.1, "beta0": 0.6})
        e2 = r2.expectation(maxcut_cost_fn(maxcut_edges(10)))
        assert sim.stats.n_stagefn_compiles == compiles_1
        assert sim.stats.n_stagefn_cache_hits > hits_1
        assert sim.stats.n_runs == 2
        assert abs(e1 - e2) > 1e-6      # the angles actually changed


def test_boundary_bytes_list_is_per_run():
    """per_stage_boundary_bytes describes the LATEST run only — a sweep
    must not grow it without bound; lifetime totals stay in the scalar
    byte counters, which remain the exact sum of the per-stage pairs."""
    cfg = EngineConfig(local_bits=5)
    with Simulator(qaoa_template(10, layers=1), cfg) as sim:
        sim.run(params={"gamma0": 0.3, "beta0": 0.2})
        first = list(sim.stats.per_stage_boundary_bytes)
        h2d_1, d2h_1 = sim.stats.h2d_bytes, sim.stats.d2h_bytes
        assert first and h2d_1 == sum(h for h, _ in first)
        sim.run(params={"gamma0": 0.9, "beta0": 0.4})
        second = sim.stats.per_stage_boundary_bytes
        assert len(second) == len(first)            # reset, not appended
        # scalars accumulate: lifetime = run1 + exactly the new list
        assert sim.stats.h2d_bytes == h2d_1 + sum(h for h, _ in second)
        assert sim.stats.d2h_bytes == d2h_1 + sum(d for _, d in second)


def test_rerun_same_circuit_reuses_everything():
    cfg = EngineConfig(local_bits=4)
    with Simulator(build_circuit("qft", 8), cfg) as sim:
        sim.run()
        compiles_1 = sim.stats.n_stagefn_compiles
        sim.run()
        assert sim.stats.n_stagefn_compiles == compiles_1


# -- readout correctness vs the dense oracle ---------------------------------

@pytest.mark.parametrize("name,shots,tv_bound", [
    ("ghz_state", 2000, 0.08),     # 2 outcomes: tight statistical bound
    ("qaoa", 4000, 0.35),          # spread over 2^10: sqrt(K/N)-ish bound
    ("qft", 4000, 0.40),           # uniform over 2^10 (worst case)
])
def test_sample_total_variation_vs_dense(name, shots, tv_bound):
    qc = build_circuit(name, 10)
    dense_p = np.abs(np.asarray(simulate_dense(qc),
                                dtype=np.complex128)) ** 2
    dense_p = dense_p / dense_p.sum()
    with Simulator(qc, EngineConfig(local_bits=5)) as sim:
        counts = sim.run().sample(shots, seed=11)
    emp = np.zeros(dense_p.size)
    for k, v in counts.items():
        emp[k] = v / shots
    tv = 0.5 * np.abs(emp - dense_p).sum()
    assert tv < tv_bound, f"{name}: TV={tv:.3f}"


def test_amplitudes_match_dense_oracle():
    """compression=False stores blocks losslessly: amplitudes() equals
    the dense oracle up to f32 arithmetic, and is always byte-identical
    to the (opt-in) statevector at the same indices."""
    qc = random_circuit(8, 24, seed=3)
    idx = [0, 1, 17, 100, 255, 128, 17]     # dupes + unsorted on purpose
    dense = np.asarray(simulate_dense(qc), dtype=np.complex64)
    with Simulator(qc, EngineConfig(local_bits=4,
                                    compression=False)) as sim:
        r = sim.run()
        amps = r.amplitudes(idx)
        sv = r.statevector()
    assert np.array_equal(amps, sv[idx])
    np.testing.assert_allclose(amps, dense[idx], atol=2e-6)

    with Simulator(qc, EngineConfig(local_bits=4)) as sim:   # lossy path
        r = sim.run()
        assert np.array_equal(r.amplitudes(idx), r.statevector()[idx])
        np.testing.assert_allclose(r.amplitudes(idx), dense[idx],
                                   atol=3e-3)


def test_probabilities_marginal_matches_dense():
    qc = build_circuit("qaoa", 8)
    dense_p = np.abs(np.asarray(simulate_dense(qc),
                                dtype=np.complex128)) ** 2
    qs = [0, 3, 6]      # spans local (b=4) and global qubits
    idxs = np.arange(dense_p.size)
    want = np.zeros(2 ** len(qs))
    midx = np.zeros(idxs.shape, np.int64)
    for j, q in enumerate(qs):
        midx |= ((idxs >> q) & 1) << j
    np.add.at(want, midx, dense_p)
    with Simulator(qc, EngineConfig(local_bits=4)) as sim:
        got = sim.run().probabilities(qs)
    np.testing.assert_allclose(got, want / want.sum(), atol=5e-3)
    assert abs(got.sum() - 1.0) < 1e-12


def test_expectation_matches_dense():
    qc = build_circuit("qaoa", 9)
    cost = maxcut_cost_fn(maxcut_edges(9))
    state = np.asarray(simulate_dense(qc))
    p = np.abs(state) ** 2
    want = float(np.sum(p * cost(np.arange(state.size))) / p.sum())
    with Simulator(qc, EngineConfig(local_bits=4)) as sim:
        got = sim.run().expectation(cost)
    assert abs(got - want) < 5e-3


# -- readout memory bound ----------------------------------------------------

def test_readout_never_materializes_state():
    """At n=20 the dense complex64 state is 8 MiB; sample/expectation/
    amplitudes over the compressed store must stay within a small
    constant x one 2^10-amplitude block (asserted via tracemalloc, which
    tracks numpy heap allocations)."""
    n, b = 20, 10
    qc = build_circuit("ghz_state", n)
    with Simulator(qc, EngineConfig(local_bits=b, inner_size=4)) as sim:
        r = sim.run()
        tracemalloc.start()
        counts = r.sample(256, seed=0)
        r.expectation(lambda idx: np.asarray(idx & 1, np.float64))
        r.amplitudes([0, 2 ** n - 1])
        _, peak = tracemalloc.get_traced_memory()
        tracemalloc.stop()
    dense_bytes = 2 ** n * 8
    block_bytes = 2 ** b * 8
    assert peak < 64 * block_bytes, \
        f"readout peak {peak} bytes vs block {block_bytes}"
    assert peak < dense_bytes / 8
    assert set(counts) <= {0, 2 ** n - 1}     # GHZ sanity


# -- checkpoint / resume -----------------------------------------------------

def test_resume_equals_fresh(tmp_path):
    path = str(tmp_path / "qft10.bmq")
    qc = build_circuit("qft", 10)
    with Simulator(qc, EngineConfig(local_bits=5)) as sim:
        r = sim.run()
        fresh_counts = r.sample(500, seed=7)
        fresh_amps = r.amplitudes([0, 33, 1023])
        fresh_masses = r.block_probabilities()
        r.save(path)

    sim2 = Simulator.resume(path)
    try:
        r2 = sim2.result()
        assert r2.n_qubits == 10 and r2.local_bits == 5
        assert r2.sample(500, seed=7) == fresh_counts
        assert np.array_equal(r2.amplitudes([0, 33, 1023]), fresh_amps)
        assert np.array_equal(r2.block_probabilities(), fresh_masses)
    finally:
        sim2.close()


def test_resume_continues_interrupted_run(tmp_path, monkeypatch):
    """Checkpoint every stage, die after the 2nd — resuming with the
    circuit must finish the run and reproduce the uninterrupted state."""
    path = str(tmp_path / "partial.bmq")
    qc = build_circuit("qft", 9)
    cfg = EngineConfig(local_bits=4)
    with Simulator(qc, cfg) as ref:
        sv_ref = ref.run().statevector()
        n_stages = ref.stats.n_stages
    assert n_stages > 3     # the interruption point must be mid-run

    class Died(Exception):
        pass

    orig = Simulator._save_checkpoint

    def dying_save(self, p, stages_done=None, run_params=None):
        orig(self, p, stages_done=stages_done, run_params=run_params)
        if stages_done == 2:
            raise Died

    monkeypatch.setattr(Simulator, "_save_checkpoint", dying_save)
    sim = Simulator(qc, cfg)
    with pytest.raises(Died):
        sim.run(checkpoint_path=path, checkpoint_every=1)
    sim.close()
    monkeypatch.setattr(Simulator, "_save_checkpoint", orig)

    resumed = Simulator.resume(path, circuit=build_circuit("qft", 9))
    try:
        assert resumed._start_stage == 2
        # the finished stages were bound with the checkpointed params;
        # a different binding for the tail must be refused
        with pytest.raises(ValueError, match="different"):
            resumed.run(params={"bogus": 1.0})
        sv = resumed.run().statevector()
    finally:
        resumed.close()
    assert np.array_equal(sv, sv_ref)


def test_resume_rejects_mismatches(tmp_path):
    path = str(tmp_path / "ck.bmq")
    with Simulator(build_circuit("ghz_state", 8),
                   EngineConfig(local_bits=4)) as sim:
        sim.run().save(path)

    with pytest.raises(ValueError, match="fingerprint"):
        Simulator.resume(path, circuit=build_circuit("qft", 8))
    with pytest.raises(ValueError, match="local_bits"):
        Simulator.resume(path, circuit=build_circuit("ghz_state", 8),
                         config=EngineConfig(local_bits=5))
    with pytest.raises(ValueError, match="not a"):
        bad = str(tmp_path / "junk.bmq")
        with open(bad, "wb") as f:
            f.write(b"not a checkpoint")
        BlockStore.restore(bad)


# -- handle lifetime ---------------------------------------------------------

def test_stale_result_raises():
    with Simulator(build_circuit("ghz_state", 8),
                   EngineConfig(local_bits=4)) as sim:
        r1 = sim.run()
        r1.sample(16)                       # live
        sim.run()
        with pytest.raises(RuntimeError, match="stale"):
            r1.sample(16)
        r2 = sim.result()
    with pytest.raises(RuntimeError, match="stale"):
        r2.amplitudes([0])                  # close() invalidates too


def test_statevector_is_guarded():
    with Simulator(build_circuit("ghz_state", 6),
                   EngineConfig(local_bits=3)) as sim:
        r = sim.run()
        r.n_qubits = 30                     # simulate a huge run
        with pytest.raises(MemoryError, match="force=True"):
            r.statevector()
        with pytest.raises(MemoryError, match="qubit subset"):
            r.probabilities()               # default=all is guarded too
        r.n_qubits = 6


def test_maxcut_edges_small_graphs_terminate():
    assert maxcut_edges(2) == [(0, 1)]
    assert maxcut_edges(3) == [(0, 1), (0, 2), (1, 2)]
    assert len(maxcut_edges(4)) <= 6
    with pytest.raises(ValueError, match=">= 2 nodes"):
        maxcut_edges(1)


# -- parameterized circuits --------------------------------------------------

def test_parameter_binding():
    qc = Circuit(2)
    th = Parameter("theta")
    qc.h(0).rz(th, 0).cp(th, 0, 1)
    assert qc.is_parameterized
    assert qc.free_parameters == {"theta"}
    assert qc.gates[1].matrix is None
    bound = qc.bind({"theta": 0.5})
    assert not bound.is_parameterized
    assert bound.gates[1].matrix is not None
    ref = build_circuit("qft", 2)           # just any concrete circuit
    assert not ref.is_parameterized
    with pytest.raises(KeyError, match="no value bound"):
        qc.bind({})
    with pytest.raises(KeyError, match="unknown"):
        qc.bind({"theta": 0.5, "phi": 1.0})
    with pytest.raises(KeyError, match="unknown gate"):
        Circuit(1).append("nope", [0], Parameter("t"))


def test_run_requires_binding():
    t = qaoa_template(8, layers=1)
    with Simulator(t, EngineConfig(local_bits=4)) as sim:
        with pytest.raises(ValueError, match="unbound parameters"):
            sim.run()
        with pytest.raises(KeyError, match="unknown"):
            sim.run(params={"gamma0": 0.1, "beta0": 0.1, "nope": 1.0})
        sim.run(params={"gamma0": 0.1, "beta0": 0.1})   # now fine


def test_bound_template_matches_dense():
    t = qaoa_template(8, layers=1)
    params = {"gamma0": 0.7, "beta0": 0.35}
    dense = np.asarray(simulate_dense(t.bind(params)), np.complex64)
    with Simulator(t, EngineConfig(local_bits=4)) as sim:
        sv = sim.run(params=params).statevector()
    np.testing.assert_allclose(sv, dense, atol=3e-3)


def test_failed_run_does_not_stale_previous_result():
    """A run() rejected at parameter validation must leave the previous
    result handle readable — the store it reads was never touched."""
    t = qaoa_template(8, layers=1)
    with Simulator(t, EngineConfig(local_bits=4)) as sim:
        r1 = sim.run(params={"gamma0": 0.3, "beta0": 0.2})
        counts = r1.sample(32, seed=5)
        with pytest.raises(ValueError, match="unbound"):
            sim.run()                           # missing params
        with pytest.raises(KeyError, match="unknown"):
            sim.run(params={"gamma0": 1.0, "beta0": 0.1, "x": 1.0})
        assert r1.sample(32, seed=5) == counts  # handle survived


def test_checkpoint_accepts_numpy_param_values(tmp_path):
    """Optimizer loops hand np.float64 angles; mid-run checkpointing
    must coerce them to JSON-native floats instead of crashing."""
    path = str(tmp_path / "np.bmq")
    t = qaoa_template(8, layers=1)
    with Simulator(t, EngineConfig(local_bits=4)) as sim:
        sim.run(params={"gamma0": np.float64(0.3),
                        "beta0": np.float64(0.2)},
                checkpoint_path=path, checkpoint_every=1)
    sim2 = Simulator.resume(path)
    try:
        assert sim2.result().sample(16, seed=0)
    finally:
        sim2.close()


# -- lossy-tail drift warning (satellite: sample_counts dead branch) ---------

def test_norm_drift_warns_and_renormalizes():
    bsz = 16
    store = BlockStore()
    backend = HostCodecBackend(store, PwRelParams(b_r=1e-3), bsz)
    rng = np.random.default_rng(0)
    state = rng.standard_normal(64) + 1j * rng.standard_normal(64)
    state = (state / np.linalg.norm(state) * 0.9).astype(np.complex64)
    for blk in range(4):                    # norm^2 = 0.81: drifted
        backend.encode_host_block(blk, state[blk * bsz:(blk + 1) * bsz])
    with pytest.warns(RuntimeWarning, match="renormalizing"):
        counts = stream_sample(backend, 6, 4, 200, seed=1)
    assert sum(counts.values()) == 200
    store.close()
