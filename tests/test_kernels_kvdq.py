"""Fused KV-dequant decode attention kernel vs the serving-path oracle."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.kernels.kv_dequant_attention import kv_dequant_decode_attention
from repro.serving.kvcache import dequantize_kv, quantize_kv

rng = np.random.default_rng(11)


def _make_cache(BG, T, hd):
    kv = jnp.asarray(rng.standard_normal((BG, T, 1, hd)), jnp.float32)
    q = quantize_kv(kv)
    # flatten the singleton head dim into the (BG, T, hd) kernel layout
    return (kv[:, :, 0, :],
            q["codes"][:, :, 0, :], q["signs"][:, :, 0, :],
            q["scale"][:, :, 0, :])


@pytest.mark.parametrize("BG,T,hd,rep,pos", [
    (2, 64, 32, 2, 63), (4, 128, 64, 1, 100), (1, 256, 16, 4, 17),
])
def test_kv_dequant_attention_matches_oracle(BG, T, hd, rep, pos):
    q = jnp.asarray(rng.standard_normal((BG, rep, hd)), jnp.float32)
    _, ck, sk, lk = _make_cache(BG, T, hd)
    _, cv, sv, lv = _make_cache(BG, T, hd)

    got = kv_dequant_decode_attention(q, ck, sk, lk, cv, sv, lv, pos,
                                      k_tile=32)

    # oracle: dequantize with the serving codec, then exact attention
    k = dequantize_kv({"codes": ck[:, :, None], "signs": sk[:, :, None],
                       "scale": lk[:, :, None]}, jnp.float32)[:, :, 0]
    v = dequantize_kv({"codes": cv[:, :, None], "signs": sv[:, :, None],
                       "scale": lv[:, :, None]}, jnp.float32)[:, :, 0]
    s = jnp.einsum("brd,btd->brt", q, k) * (hd ** -0.5)
    mask = jnp.arange(T)[None, None] <= pos
    s = jnp.where(mask, s, -2.0 ** 30)
    p = jax.nn.softmax(s, axis=-1)
    want = jnp.einsum("brt,btd->brd", p, v)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=2e-4, atol=2e-4)


def test_kernel_reads_fewer_bytes():
    """The point of the kernel: compressed operands are ~2.11x smaller."""
    BG, T, hd = 2, 128, 64
    kv, ck, sk, lk = _make_cache(BG, T, hd)
    raw = kv.astype(jnp.bfloat16).nbytes
    comp = ck.nbytes + sk.nbytes + lk.nbytes
    assert raw / comp > 1.6
