"""Service tier: plan-admission scheduling + continuous lane batching.

Pins the SimService contracts documented in docs/SERVING.md: the
admission decision table (reject only when a job can *never* fit), the
budget invariant (the reservation sum never exceeds the global budget,
merged execution included), FIFO within a structure class, bitwise
merge-vs-solo lane equality, cold/warm session-pool accounting, and
exact virtual-clock latencies.
"""
import re

import numpy as np
import pytest

from repro.core import (EngineConfig, Simulator, SimService, VirtualClock,
                        build_circuit, qaoa_template)
from repro.core.planner import peak_ram_for
from repro.errors import StoreIOError

CFG = EngineConfig(local_bits=4)


def peak1(circuit, cfg=CFG) -> int:
    """Admission price of `circuit` at lanes=1 (what submit() charges)."""
    with Simulator(circuit, cfg) as sim:
        return peak_ram_for(sim.compile(), 1)


# -- the admission decision table --------------------------------------------

def test_admission_decision_table():
    """budget = 2x peak: of four identical jobs, two admit, two queue;
    the queue drains in arrival order as rounds free budget."""
    qc = build_circuit("qft", 8)
    p1 = peak1(qc)
    with SimService(2 * p1, config=CFG) as svc:
        jobs = [svc.submit(qc) for _ in range(4)]
        assert [j.state for j in jobs] == ["admitted", "admitted",
                                          "queued", "queued"]
        assert svc.reserved_bytes == 2 * p1
        done = svc.drain()
        assert [j.job_id for j in done] == [0, 1, 2, 3]
        assert all(j.state == "done" for j in jobs)
        assert svc.reserved_bytes == 0
        s = svc.stats
        assert (s.n_submitted, s.n_admitted, s.n_queued, s.n_rejected) \
            == (4, 2, 2, 0)
        assert (s.n_cold_compiles, s.n_warm_hits) == (1, 3)
        assert s.peak_reserved_bytes == 2 * p1 <= svc.memory_budget_bytes


def test_rejection_only_when_never_fits():
    """peak_ram(1) > budget is terminal rejection; peak_ram(1) == budget
    admits — the boundary belongs to the job."""
    qc = build_circuit("qft", 8)
    p1 = peak1(qc)
    with SimService(p1 - 1, config=CFG) as svc:
        job = svc.submit(qc)
        assert job.state == "rejected" and job.done
        assert svc.drain() == []
        assert svc.stats.n_rejected == 1 and svc.stats.n_completed == 0
    with SimService(p1, config=CFG) as svc:
        job = svc.submit(qc)
        assert job.state == "admitted"
        svc.drain()
        assert job.state == "done"


def test_admission_sum_never_exceeds_budget():
    """The core invariant under concurrent mixed-structure load: at every
    observable point the reservation sum stays within the budget, yet
    every job eventually completes."""
    circuits = [build_circuit("qft", 8), build_circuit("ising", 8),
                build_circuit("ghz_state", 8)]
    prices = [peak1(qc) for qc in circuits]
    budget = max(prices) + min(prices)       # forces queueing, rejects none
    with SimService(budget, config=CFG) as svc:
        jobs = []
        for rnd in range(3):
            for qc in circuits:
                jobs.append(svc.submit(qc))
                assert svc.reserved_bytes <= budget
        while True:
            done = svc.step()
            assert svc.reserved_bytes <= budget
            if not done:
                break
        assert all(j.state == "done" for j in jobs)
        assert svc.stats.peak_reserved_bytes <= budget
        assert svc.stats.n_queued > 0        # the budget actually bound


def test_fifo_within_structure_class():
    """budget = 1 job: strictly sequential width-1 rounds, completion in
    arrival order, every job's merge_width is 1."""
    qc = build_circuit("qft", 8)
    with SimService(peak1(qc), config=CFG) as svc:
        jobs = [svc.submit(qc, seed=i) for i in range(3)]
        done = svc.drain()
        assert [j.job_id for j in done] == [0, 1, 2]
        assert all(j.merge_width == 1 for j in jobs)
        assert svc.stats.merge_widths == [1, 1, 1]
        assert svc.stats.n_merged_jobs == 0


# -- continuous lane batching ------------------------------------------------

def test_merge_bitwise_equal_vs_solo():
    """Three co-admitted same-structure jobs merge into one width-3
    run_batch whose per-lane states are bitwise identical to each job
    run solo (every dispatch goes through run_batch, width 1 included)."""
    qc = qaoa_template(8)
    points = [{"gamma0": g, "beta0": b}
              for g, b in [(0.3, 0.15), (0.7, 0.40), (1.1, 0.65)]]
    grab = {"readout": lambda view: np.asarray(view.statevector())}

    with SimService(64 << 20, config=CFG) as svc:
        merged = [svc.submit(qc, params=p, **grab) for p in points]
        svc.drain()
    assert all(j.merge_width == 3 for j in merged)
    assert svc.stats.n_batches == 1 and svc.stats.max_merge_width == 3

    for p, mj in zip(points, merged):
        with SimService(64 << 20, config=CFG) as solo_svc:
            sj = solo_svc.submit(qc, params=p, **grab)
            solo_svc.drain()
        assert sj.merge_width == 1
        assert np.array_equal(mj.result["readout"], sj.result["readout"])


def test_different_structures_never_merge():
    qft, ising = build_circuit("qft", 8), build_circuit("ising", 8)
    with SimService(64 << 20, config=CFG) as svc:
        jobs = [svc.submit(qc) for qc in (qft, ising, qft, ising)]
        svc.drain()
        assert svc.stats.n_batches == 2
        assert sorted(svc.stats.merge_widths) == [2, 2]
        assert jobs[0].structure == jobs[2].structure
        assert jobs[0].structure != jobs[1].structure


# -- session pool ------------------------------------------------------------

def test_session_pool_cold_warm_and_lru_eviction():
    qft, ising = build_circuit("qft", 8), build_circuit("ising", 8)
    with SimService(64 << 20, config=CFG, max_sessions=1) as svc:
        svc.submit(qft)
        svc.drain()
        assert (svc.stats.n_cold_compiles, svc.n_sessions) == (1, 1)
        svc.submit(ising)                    # evicts the idle qft session
        svc.drain()
        assert svc.stats.n_sessions_evicted == 1 and svc.n_sessions == 1
        job = svc.submit(qft)                # structure re-enters cold
        svc.drain()
        assert job.cold and svc.stats.n_cold_compiles == 3


def test_pending_sessions_are_not_evicted():
    """A structure with admitted-but-unfinished jobs survives the pool
    cap — its jobs were priced against that compiled plan."""
    qft, ising = build_circuit("qft", 8), build_circuit("ising", 8)
    with SimService(64 << 20, config=CFG, max_sessions=1) as svc:
        j1 = svc.submit(qft)                 # pending on the qft session
        svc.submit(ising)                    # pool over cap, qft busy
        assert svc.n_sessions == 2
        svc.drain()
        assert j1.state == "done"


# -- determinism under a virtual clock ---------------------------------------

def test_virtual_clock_exact_waits_and_latencies():
    qc = build_circuit("qft", 8)
    p1 = peak1(qc)
    clock = VirtualClock()
    with SimService(p1, config=CFG, clock=clock) as svc:
        first, second = svc.submit(qc), svc.submit(qc)
        assert (first.state, second.state) == ("admitted", "queued")
        clock.advance(2.0)
        assert svc.step() == [first]
        assert first.wait_s == 0.0 and first.latency_s == 2.0
        assert second.wait_s == 2.0          # promoted when round 1 freed
        clock.advance(1.5)
        assert svc.step() == [second]
        assert second.latency_s == 3.5
    with pytest.raises(ValueError):
        clock.advance(-1.0)


# -- failure semantics -------------------------------------------------------

def test_typed_engine_failure_fails_batch_and_keeps_serving():
    qc = build_circuit("qft", 8)
    with SimService(64 << 20, config=CFG) as svc:
        job = svc.submit(qc)
        sess = svc._sessions[job.structure]

        def boom(*a, **k):
            raise StoreIOError("read", key=7)

        sess.sim.run_batch = boom
        assert svc.step() == [job]
        assert job.state == "failed" and "StoreIOError" in job.error
        assert svc.reserved_bytes == 0 and svc.stats.n_failed == 1
        ok = svc.submit(build_circuit("ising", 8))
        svc.drain()
        assert ok.state == "done"            # the service kept serving


def test_submit_after_close_raises():
    svc = SimService(64 << 20, config=CFG)
    svc.close()
    with pytest.raises(RuntimeError):
        svc.submit(build_circuit("qft", 8))


# -- stats surface -----------------------------------------------------------

def test_stats_summary_is_the_documented_line():
    qc = build_circuit("qft", 8)
    with SimService(64 << 20, config=CFG) as svc:
        svc.submit(qc)
        svc.submit(qc)
        svc.drain()
        line = svc.stats.summary()
    assert re.fullmatch(
        r"submitted=2 admitted=2 queued=0 rejected=0 completed=2 failed=0 "
        r"cold=1 warm=1 batches=1 merged=2 max_merge=2 "
        r"peak_reserved_mib=\d+\.\d\d", line)
