"""Property tests (hypothesis): partition validity, fusion, group math."""
import numpy as np
import pytest

pytest.importorskip("hypothesis", reason="property tests need hypothesis")
from hypothesis import given, settings, strategies as st

from repro.core import (GroupLayout, gates_to_unitary, fuse_gates,
                        partition_circuit, random_circuit)
from repro.core.dense_engine import apply_matrix, initial_state
import jax.numpy as jnp


@settings(max_examples=25, deadline=None)
@given(n=st.integers(3, 10), b=st.integers(0, 6), inner=st.integers(2, 4),
       n_gates=st.integers(1, 60), seed=st.integers(0, 10_000))
def test_partition_invariants(n, b, inner, n_gates, seed):
    b = min(b, n)
    qc = random_circuit(n, n_gates, seed=seed)
    part = partition_circuit(qc, local_bits=b, inner_size=inner)
    # (1) gates preserved in order
    flat = [g for stg in part.stages for g in stg.gates]
    assert flat == qc.gates
    # (2) per-stage global support bounded
    thr = max(inner, 2)
    for stg in part.stages:
        sup = {q for g in stg.gates for q in g.qubits if q >= b}
        assert len(sup) <= thr
        assert sup == set(stg.inner)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(2, 8), n_gates=st.integers(1, 25),
       f=st.integers(2, 5), seed=st.integers(0, 10_000))
def test_fusion_equivalence(n, n_gates, f, seed):
    """Fused unitaries applied in order == original gate sequence."""
    qc = random_circuit(n, n_gates, seed=seed, two_qubit_frac=0.5)
    fused = fuse_gates(qc.gates, max_fused_qubits=max(f, 2))
    state = initial_state(n, jnp.complex64)
    for g in qc.gates:
        state = apply_matrix(state, jnp.asarray(g.matrix, jnp.complex64),
                             g.qubits, n)
    state2 = initial_state(n, jnp.complex64)
    for fg in fused:
        state2 = apply_matrix(state2, jnp.asarray(fg.matrix, jnp.complex64),
                              fg.qubits, n)
    np.testing.assert_allclose(np.asarray(state), np.asarray(state2),
                               atol=2e-5)


@settings(max_examples=30, deadline=None)
@given(n=st.integers(2, 12), b=st.integers(0, 8),
       seed=st.integers(0, 10_000), data=st.data())
def test_group_block_ids_partition_blocks(n, b, seed, data):
    """Every block id appears exactly once across groups; member order
    spells the inner-assignment integer."""
    b = min(b, n)
    c = n - b
    rng = np.random.default_rng(seed)
    m = data.draw(st.integers(0, min(3, c)))
    inner = tuple(sorted(rng.choice(np.arange(b, n), size=m, replace=False).tolist()))
    lay = GroupLayout(n, b, inner)
    ids = lay.group_block_ids()
    assert ids.shape == (lay.n_groups, lay.blocks_per_group)
    flat = ids.reshape(-1)
    assert sorted(flat.tolist()) == list(range(2 ** c))
    # member i of any group has inner bits spelling i
    for g in range(min(4, lay.n_groups)):
        for i in range(lay.blocks_per_group):
            got = 0
            for j, p in enumerate(lay.inner_positions):
                got |= ((int(ids[g, i]) >> p) & 1) << j
            assert got == i


def test_gates_to_unitary_is_unitary():
    qc = random_circuit(4, 12, seed=3)
    u = gates_to_unitary(qc.gates, (0, 1, 2, 3))
    np.testing.assert_allclose(u @ u.conj().T, np.eye(16), atol=1e-10)


@settings(max_examples=15, deadline=None)
@given(q=st.integers(0, 5))
def test_virtual_qubit_map(q):
    lay = GroupLayout(10, 4, (5, 7))
    if q < 4:
        assert lay.virtual_qubit(q) == q
    assert lay.virtual_qubit(5) == 4
    assert lay.virtual_qubit(7) == 5
