"""Fault tolerance: atomic checkpoints, restart-after-failure replay,
elastic re-shard, straggler accounting."""
import os

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.models import transformer as T
from repro.optim import AdamW
from repro.train.checkpoint import CheckpointManager
from repro.train.data import SyntheticTokens
from repro.train.runtime import RuntimeConfig, TrainRuntime
from repro.train.step import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


def test_save_restore_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    tree = {"a": jnp.arange(10, dtype=jnp.float32),
            "b": {"c": jnp.ones((3, 4), jnp.bfloat16),
                  "d": [jnp.zeros(2), jnp.full((2, 2), 7)]}}
    mgr.save(3, tree)
    got, step = mgr.restore(tree)
    assert step == 3
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        assert a.dtype == b.dtype


def test_compressed_checkpoint_lossless(tmp_path):
    mgr = CheckpointManager(str(tmp_path), compress=True)
    tree = {"w": jnp.asarray(np.random.default_rng(0)
                             .standard_normal((64, 64)), jnp.float32)}
    mgr.save(1, tree)
    got, _ = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(tree["w"]),
                                  np.asarray(got["w"]))


def test_keep_last_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2)
    for s in range(5):
        mgr.save(s, {"x": jnp.zeros(1)})
    assert mgr.steps() == [3, 4]


def test_atomicity_no_partial_dirs(tmp_path):
    mgr = CheckpointManager(str(tmp_path))
    mgr.save(1, {"x": jnp.zeros(4)})
    names = os.listdir(tmp_path)
    assert "step_00000001" in names
    assert not any(n.endswith(".tmp") for n in names)


def _mk_runtime(tmp_path, fail_at=None, n_steps=12):
    cfg = reduced_config(get_config("xlstm-125m")).with_(remat=False)
    params = T.init_params(cfg, KEY)
    opt = AdamW(lr=1e-3)
    state = init_train_state(cfg, params, opt)
    step_fn = jax.jit(make_train_step(cfg, opt))
    src = SyntheticTokens(vocab=cfg.vocab, seq_len=16, global_batch=4)
    rt = TrainRuntime(
        cfg=RuntimeConfig(ckpt_dir=str(tmp_path), ckpt_every=4,
                          fail_at_step=fail_at),
        train_step=step_fn, data_source=src)
    return rt, params, state


def test_runtime_failure_injection_and_restart(tmp_path):
    """A 'node failure' at step 6 restarts from step 4's checkpoint and
    the final losses match an uninterrupted run (deterministic replay)."""
    rt, params, state = _mk_runtime(tmp_path / "a", fail_at=6)
    p1, s1, hist1 = rt.run(params, state, n_steps=10)
    assert any(m["restarts"] == 1 for m in hist1)

    rt2, params2, state2 = _mk_runtime(tmp_path / "b", fail_at=None)
    p2, s2, hist2 = rt2.run(params2, state2, n_steps=10)
    last1 = [m["loss"] for m in hist1 if m["step"] == 9][0]
    last2 = [m["loss"] for m in hist2 if m["step"] == 9][0]
    assert abs(last1 - last2) < 1e-3    # replay converged to same state


def test_runtime_resume_from_disk(tmp_path):
    """Simulated preemption: a second runtime resumes where the first
    stopped (latest checkpoint) instead of from scratch."""
    rt, params, state = _mk_runtime(tmp_path)
    rt.run(params, state, n_steps=5)
    rt2, params2, state2 = _mk_runtime(tmp_path)
    _, _, hist = rt2.run(params2, state2, n_steps=8)
    assert hist[0]["step"] == 5         # continued, not restarted


def test_elastic_reshard(tmp_path):
    """Checkpoint written unsharded restores onto a 2-device mesh (and the
    leaves land with the requested shardings)."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.asarray(np.arange(64, dtype=np.float32).reshape(8, 8))}
    mgr.save(0, tree)
    if len(jax.devices()) >= 2:
        mesh = jax.make_mesh((2,), ("data",))
        sh = {"w": NamedSharding(mesh, P("data", None))}
        got, _ = mgr.restore(tree, shardings=sh)
        assert got["w"].sharding == sh["w"]
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(tree["w"]))
    else:  # single-device container: restore still round-trips
        got, _ = mgr.restore(tree)
        np.testing.assert_array_equal(np.asarray(got["w"]),
                                      np.asarray(tree["w"]))
