"""Stage scheduler: transpose elision rules + planes execution vs the
dense oracle (core/schedule.py)."""
import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.dense_engine import apply_matrix
from repro.core.schedule import (DiagOp, GemmOp, MidGemmOp, TransposeOp,
                                 compile_schedule, execute_schedule)

rng = np.random.default_rng(7)


def _rand_unitary(K):
    m = rng.standard_normal((K, K)) + 1j * rng.standard_normal((K, K))
    q, r = np.linalg.qr(m)
    return (q * (np.diag(r) / np.abs(np.diag(r)))).astype(np.complex64)


def _rand_diag(K):
    return np.exp(1j * rng.uniform(0, 2 * np.pi, K)).astype(np.complex64)


def _mats_for(plan, gates):
    mats = []
    for (vq, diag), g in zip(plan, gates):
        m = g if diag else g
        mats.append(jnp.asarray(np.stack([m.real, m.imag]), jnp.float32))
    return mats


def _run_both(plan, gates, nv, use_kernel=False):
    """Scheduled planes execution vs gate-by-gate dense application."""
    amps = (rng.standard_normal(2 ** nv)
            + 1j * rng.standard_normal(2 ** nv)).astype(np.complex64)
    want = jnp.asarray(amps)
    for (vq, diag), g in zip(plan, gates):
        mat = jnp.asarray(np.diag(g) if diag else g)
        want = apply_matrix(want, mat, vq, nv)
    sched = compile_schedule(plan, nv)
    planes = jnp.asarray(np.stack([amps.real, amps.imag]), jnp.float32)
    out = execute_schedule(sched, planes, _mats_for(plan, gates),
                          use_kernel=use_kernel)
    got = np.asarray(out[0]) + 1j * np.asarray(out[1])
    np.testing.assert_allclose(got, np.asarray(want), rtol=2e-4, atol=2e-4)
    return sched


@pytest.mark.parametrize("use_kernel", [False, True])
@pytest.mark.parametrize("seed", range(6))
def test_random_plans_match_dense(seed, use_kernel):
    r = np.random.default_rng(seed)
    nv = int(r.integers(4, 9))
    plan, gates = [], []
    for _ in range(int(r.integers(1, 7))):
        k = int(r.integers(1, min(4, nv) + 1))
        vq = tuple(int(q) for q in r.choice(nv, size=k, replace=False))
        diag = bool(r.random() < 0.4)
        plan.append((vq, diag))
        gates.append(_rand_diag(2 ** k) if diag else _rand_unitary(2 ** k))
    _run_both(tuple(plan), gates, nv, use_kernel=use_kernel)


def test_diag_gates_never_transpose():
    """Diagonal unitaries run in any layout: zero transposes, any qubits."""
    nv = 6
    plan = tuple(((q, (q + 2) % nv), True) for q in range(4))
    gates = [_rand_diag(4) for _ in plan]
    sched = _run_both(plan, gates, nv)
    assert sched.n_transposes == 0
    assert all(isinstance(op, DiagOp) for op in sched.ops)


def test_identical_qubit_sets_share_layout():
    """Consecutive dense gates on one qubit set: at most one transpose in,
    one out — never per gate."""
    nv = 6
    vq = (1, 3, 4)
    plan = tuple((vq, False) for _ in range(5))
    gates = [_rand_unitary(8) for _ in plan]
    sched = _run_both(plan, gates, nv)
    assert sched.n_transposes <= 2
    assert sched.n_transposes_naive == 2 * len(plan)


def test_contiguous_major_block_uses_mid_gemm():
    """A gate whose axes sit contiguously at the major end (QFT's
    recurring top-qubit unitaries) runs with zero transposes."""
    nv = 7
    vq = (nv - 2, nv - 1)           # axes 0,1 — major-most, ascending order
    sched = _run_both((((vq), False),), [_rand_unitary(4)], nv)
    assert sched.n_transposes == 0
    assert any(isinstance(op, MidGemmOp) for op in sched.ops)


def test_minor_block_wrong_bit_order_permutes_matrix():
    """Gate qubits minor-most but bit-swapped (CX stored target-first):
    the K x K operand is permuted, not the group array."""
    nv = 5
    sched = _run_both((((1, 0), False),), [_rand_unitary(4)], nv)
    assert sched.n_transposes == 0
    (op,) = sched.ops
    assert isinstance(op, GemmOp) and op.bmap == (0, 2, 1, 3)


def test_minor_block_canonical_order_no_bmap():
    nv = 5
    sched = _run_both((((0, 1), False),), [_rand_unitary(4)], nv)
    (op,) = sched.ops
    assert isinstance(op, GemmOp) and op.bmap is None
    assert sched.n_transposes == 0


def test_scattered_axes_still_one_transpose_per_layout_change():
    """Non-contiguous supports transpose once in and once back out."""
    nv = 6
    plan = (((0, 5), False),)
    sched = _run_both(plan, [_rand_unitary(4)], nv)
    assert sched.n_transposes == 2
    kinds = [type(op) for op in sched.ops]
    assert kinds == [TransposeOp, GemmOp, TransposeOp]


def test_qft_like_ladder_halves_transposes():
    """H + controlled-phase ladder (QFT stage shape): scheduled count is
    less than half the naive per-gate count."""
    nv = 6
    plan, gates = [], []
    for q in range(4):
        plan.append(((q,), False))
        gates.append(_rand_unitary(2))
        for t in range(q + 1, 5):
            plan.append(((q, t), True))
            gates.append(_rand_diag(4))
    sched = _run_both(tuple(plan), gates, nv)
    assert sched.n_transposes * 2 <= sched.n_transposes_naive


def test_schedule_is_cached():
    plan = (((0, 1), False), ((2,), True))
    assert compile_schedule(plan, 5) is compile_schedule(plan, 5)
