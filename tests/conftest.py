import os
import sys

# tests see 1 device (per assignment: only dryrun.py forces 512).
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
