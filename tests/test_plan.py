"""Planner/executor split: ExecutionPlan artifacts, budget-driven
auto-tuning, plan fingerprints in checkpoints, qsim --explain."""
import numpy as np
import pytest

from repro import (EngineConfig, ExecutionPlan, Simulator, build_circuit,
                   qaoa_template, random_circuit)
from repro.core.planner import estimate_bytes_per_amp, resolve_config
from repro.launch import qsim


# -- cost model ----------------------------------------------------------------

def test_bytes_per_amp_estimate_shape():
    """Conservative, monotone in b_r, never above the RAW-escape bound."""
    assert estimate_bytes_per_amp(1e-3, compression=False) == 8.0
    loose = estimate_bytes_per_amp(1e-2)
    tight = estimate_bytes_per_amp(1e-5)
    assert 0.5 < loose <= tight <= 8.0


def test_resolve_config_explicit_passthrough():
    qc = build_circuit("qft", 10)
    cfg, auto, part = resolve_config(qc, EngineConfig(local_bits=5))
    assert not auto and part is None
    assert (cfg.local_bits, cfg.inner_size, cfg.pipeline_depth) == (5, 2, 2)
    # memory budget flows into the store backstop even with explicit knobs
    cfg, _, _ = resolve_config(qc, EngineConfig(local_bits=5,
                                                memory_budget_bytes=4096))
    assert cfg.ram_budget_bytes == 4096
    # ... but never tramples an explicit ram budget
    cfg, _, _ = resolve_config(qc, EngineConfig(local_bits=5,
                                                memory_budget_bytes=4096,
                                                ram_budget_bytes=999))
    assert cfg.ram_budget_bytes == 999
    # the budget search hands back the partition it already computed
    cfg, auto, part = resolve_config(
        qc, EngineConfig(memory_budget_bytes=64 * 2 ** 10))
    assert auto and part is not None
    assert part.local_bits == cfg.local_bits


# -- pipeline depth auto-tuning off measured calibration -----------------------

def test_depth_model_fetch_dominant_picks_sequential():
    """When the blocking d2h wait dominates the phase mix, coalescing
    waves can't pay for its dispatch tax — the auto-tuner must fall back
    to depth 1 instead of reproducing the old always-2 losing choice."""
    from repro.core.planner import PipelineCalibration, predict_depth_speedup

    fetch_dom = PipelineCalibration(t_load=0.1, t_compute=0.1,
                                    t_fetch=1.0, t_store=0.1)
    assert predict_depth_speedup(2, fetch_dom) < 1.0
    qc = build_circuit("qft", 10)
    cfg, _, _ = resolve_config(qc, EngineConfig(local_bits=5),
                               calibration=fetch_dom)
    assert cfg.pipeline_depth == 1
    # budget-driven search honors the same model
    cfg, _, _ = resolve_config(
        qc, EngineConfig(memory_budget_bytes=64 * 2 ** 10),
        calibration=fetch_dom)
    assert cfg.pipeline_depth == 1


def test_depth_model_compute_dominant_picks_overlap():
    from repro.core.planner import PipelineCalibration, predict_depth_speedup

    comp_dom = PipelineCalibration(t_load=0.1, t_compute=1.0,
                                   t_fetch=0.1, t_store=0.1)
    assert predict_depth_speedup(2, comp_dom) > 1.0
    qc = build_circuit("qft", 10)
    cfg, _, _ = resolve_config(qc, EngineConfig(local_bits=5),
                               calibration=comp_dom)
    assert cfg.pipeline_depth >= 2


def test_depth_model_never_repeats_bench5_losing_choice():
    """BENCH_5 recorded depth-2 at 0.58x of sequential.  A calibration
    carrying that measured profile must drive every auto-tuned path to
    depth 1 — the planner never again selects a depth whose (measured or
    predicted) speedup is below 1."""
    from repro.core.planner import PipelineCalibration, predict_depth_speedup

    bench5 = PipelineCalibration(t_load=0.3, t_compute=0.5, t_fetch=0.2,
                                 t_store=0.3,
                                 measured=((2, 0.58), (4, 0.54), (8, 0.46)))
    assert predict_depth_speedup(2, bench5) == pytest.approx(0.58)
    qc = build_circuit("qft", 14)
    for cfg_in in (EngineConfig(local_bits=7),
                   EngineConfig(memory_budget_bytes=96 * 2 ** 10)):
        cfg, _, _ = resolve_config(qc, cfg_in, calibration=bench5)
        assert cfg.pipeline_depth == 1
    # an explicit depth is the user's call — passed through untouched
    cfg, _, _ = resolve_config(qc, EngineConfig(local_bits=7,
                                                pipeline_depth=2),
                               calibration=bench5)
    assert cfg.pipeline_depth == 2


def test_auto_depth_never_predicts_losing_speedup():
    """Whatever depth the auto-tuner lands on, its own model must rate
    that depth >= 1.0x — across a sweep of synthetic phase mixes."""
    from repro.core.planner import PipelineCalibration, predict_depth_speedup

    qc = build_circuit("qft", 10)
    mixes = [(l, c, f, s)
             for l in (0.1, 1.0) for c in (0.1, 1.0)
             for f in (0.05, 1.0) for s in (0.1, 1.0)]
    for l, c, f, s in mixes:
        cal = PipelineCalibration(t_load=l, t_compute=c, t_fetch=f, t_store=s)
        cfg, _, _ = resolve_config(qc, EngineConfig(local_bits=5),
                                   calibration=cal)
        assert predict_depth_speedup(cfg.pipeline_depth, cal) >= 1.0


def test_sim_stats_expose_pipeline_calibration():
    """A run yields the per-group-phase calibration the next plan's depth
    model consumes, and the plan artifact records its predicted overlap."""
    qc = build_circuit("qft", 10)
    with Simulator(qc, EngineConfig(local_bits=5)) as sim:
        plan = sim.compile()
        assert plan.predicted.depth_speedup > 0
        assert "overlap speedup" in plan.describe()
        rt = ExecutionPlan.from_json(plan.to_json())
        assert rt.predicted.depth_speedup == plan.predicted.depth_speedup
        sim.run()
        stats = sim.stats
    assert stats.n_group_phases > 0
    cal = stats.pipeline_calibration()
    assert cal.t_load >= 0 and cal.t_compute >= 0
    assert cal.t_fetch >= 0 and cal.t_store >= 0


# -- budget guarantee (the acceptance criterion) -------------------------------

@pytest.mark.parametrize("n,budget_kib", [(14, 96), (18, 2048)])
def test_planner_respects_budget_qft(n, budget_kib):
    """Auto-planned qft-14/qft-18 under a budget: the chosen
    (local_bits, inner_size) keeps the store's RAM peak within it, with
    no disk spill needed on the happy path."""
    budget = budget_kib * 2 ** 10
    qc = build_circuit("qft", n)
    with Simulator(qc, EngineConfig(memory_budget_bytes=budget)) as sim:
        assert sim.config.local_bits is not None
        plan = sim.compile()
        assert plan.auto_tuned
        assert plan.predicted.working_set_bytes <= budget
        sim.run()
        stats = sim.stats
    assert stats.peak_ram_bytes <= budget
    assert stats.n_spills == 0
    assert 0.0 < stats.bytes_per_amp_measured <= 8.0


def test_unsatisfiable_budget_warns_and_spills():
    """A budget below any candidate's working set still runs: the
    planner warns, and the store budget backstop spills to disk while
    keeping the RAM tier within budget."""
    budget = 2000
    qc = build_circuit("qft", 10)
    with pytest.warns(RuntimeWarning, match="spill"):
        sim = Simulator(qc, EngineConfig(memory_budget_bytes=budget))
    with sim:
        sim.run()
        stats = sim.stats
    assert stats.peak_ram_bytes <= budget
    assert stats.n_spills > 0


# -- planned == explicit (property) --------------------------------------------

def test_planned_execution_state_identical_property():
    """Planned execution is state-identical to running the explicit
    config the planner chose — across random circuits and budgets."""
    pytest.importorskip("hypothesis")
    from hypothesis import given, settings, strategies as st

    @settings(max_examples=5, deadline=None)
    @given(n=st.integers(7, 9), seed=st.integers(0, 10 ** 6),
           budget_kib=st.sampled_from([8, 32, 128]))
    def check(n, seed, budget_kib):
        qc = random_circuit(n, 3 * n, seed=seed)
        cfg = EngineConfig(memory_budget_bytes=budget_kib * 2 ** 10)
        with Simulator(qc, cfg) as sim:
            plan = sim.compile()
            sv_auto = sim.run().statevector()
            assert sim.stats.peak_ram_bytes <= cfg.memory_budget_bytes
        explicit = EngineConfig(local_bits=plan.local_bits,
                                inner_size=plan.inner_size,
                                pipeline_depth=plan.pipeline_depth)
        with Simulator(qc, explicit) as sim:
            sv_exp = sim.run().statevector()
        assert np.array_equal(sv_auto, sv_exp)

    check()


def test_execute_from_deserialized_plan():
    """A plan survives JSON and drives a fresh session to the identical
    state — the executor honors the artifact, not its own search."""
    qc = build_circuit("qaoa", 10)
    with Simulator(qc, EngineConfig(memory_budget_bytes=64 * 2 ** 10)) as s1:
        plan = s1.compile()
        sv1 = s1.run().statevector()
    plan2 = ExecutionPlan.from_json(plan.to_json())
    assert plan2 == plan
    assert hash(plan2) == hash(plan)
    assert plan2.fingerprint == plan.fingerprint
    with Simulator(qc, EngineConfig(), plan=plan2) as s2:
        assert s2.config.local_bits == plan.local_bits
        sv2 = s2.run().statevector()
    assert np.array_equal(sv1, sv2)
    # a plan compiled for a different circuit is refused
    with pytest.raises(ValueError, match="different circuit"):
        Simulator(build_circuit("qft", 10), EngineConfig(), plan=plan2)


def test_plan_execution_adopts_every_recorded_knob():
    """'Executes it verbatim' means ALL recorded knobs — codec params
    included — override whatever the config says, so the checkpointed
    plan fingerprint always matches the artifact's."""
    qc = build_circuit("ghz_state", 8)
    src = EngineConfig(local_bits=4, b_r=1e-2, gate_schedule=False,
                       prescan=False)
    with Simulator(qc, src) as s1:
        plan = s1.compile()
    with Simulator(qc, EngineConfig(), plan=plan) as s2:
        cfg = s2.config
        assert (cfg.b_r, cfg.gate_schedule, cfg.prescan) == \
            (1e-2, False, False)
        assert s2._engine.plan_fingerprint() == plan.fingerprint
        s2.run()


def test_corrupt_plan_gate_slices_rejected():
    """A plan whose gate slices don't tile the circuit's gate list is
    refused instead of silently simulating a different circuit."""
    import dataclasses
    qc = build_circuit("qft", 8)
    with Simulator(qc, EngineConfig(local_bits=4)) as sim:
        plan = sim.compile()
    last = plan.stages[-1]
    truncated = dataclasses.replace(
        last, gate_slice=(last.gate_slice[0], last.gate_slice[1] - 1))
    bad = dataclasses.replace(plan, stages=plan.stages[:-1] + (truncated,))
    with pytest.raises(ValueError, match="covers"):
        Simulator(qc, EngineConfig(), plan=bad)


def test_compile_stamps_requested_binding():
    """The cached structural plan is re-labeled with the binding it was
    asked for, not the first one compiled."""
    with Simulator(qaoa_template(8, layers=1),
                   EngineConfig(local_bits=4)) as sim:
        p1 = sim.compile(params={"gamma0": 0.3, "beta0": 0.2})
        p2 = sim.compile(params={"gamma0": 1.0, "beta0": 0.5})
        assert dict(p1.params_key)["gamma0"] == 0.3
        assert dict(p2.params_key)["gamma0"] == 1.0
        assert p1.fingerprint == p2.fingerprint


# -- reuse contract under auto-tuning ------------------------------------------

def test_auto_sweep_compiles_once_and_resets_boundary_list():
    """An auto-planned parameter sweep compiles stage fns exactly once;
    per_stage_boundary_bytes describes the latest run only."""
    cfg = EngineConfig(memory_budget_bytes=32 * 2 ** 10)
    with Simulator(qaoa_template(10, layers=1), cfg) as sim:
        sim.run(params={"gamma0": 0.3, "beta0": 0.2})
        compiles = sim.stats.n_stagefn_compiles
        n1 = len(sim.stats.per_stage_boundary_bytes)
        sim.run(params={"gamma0": 1.0, "beta0": 0.7})
        assert sim.stats.n_stagefn_compiles == compiles
        assert len(sim.stats.per_stage_boundary_bytes) == n1


# -- the artifact itself -------------------------------------------------------

def test_plan_artifact_contents():
    qc = build_circuit("qft", 10)
    with Simulator(qc, EngineConfig(local_bits=5)) as sim:
        plan = sim.compile()
        assert plan is sim.compile()        # cached per structure
        assert plan.n_stages == sim.stats.n_stages
        assert plan.fingerprint == sim._engine.plan_fingerprint()
        # stage records: operand slots tile the gate list in order
        lo = 0
        for sp in plan.stages:
            assert sp.gate_slice[0] == lo
            lo = sp.gate_slice[1]
            assert sp.stagefn_key[0] == sp.plan
            assert sp.device_slot(0) == 0
        assert lo == len(qc.gates)
        text = plan.describe()
        assert "ExecutionPlan" in text and "local_bits=5" in text
        assert f"{plan.n_stages} stages" in text


def test_multidevice_plan_roundtrip_and_placement():
    """A multi-device plan records per-device predictions and round-robin
    group placement, survives JSON round-trip, and describe() surfaces
    the mesh; a pre-v9 dump (no per_device_peak_bytes) is backfilled."""
    import jax
    qc = build_circuit("qft", 10)
    cfg = EngineConfig(local_bits=4, devices=list(jax.devices()) * 4)
    with Simulator(qc, cfg) as sim:
        plan = sim.compile(verify=False)
        assert plan.n_devices == 4
        p = plan.predicted
        assert 0 < p.per_device_peak_bytes <= (p.peak_ram_bytes
                                               + p.pipeline_bytes)
        for sp in plan.stages:
            slots = {sp.device_slot(g) for g in range(sp.layout.n_groups)}
            assert slots <= set(range(4))
            assert sp.device_slot(5) == 5 % 4
        text = plan.describe()
        assert "devices=4" in text and "per-device peak" in text
        blob = plan.to_json()
        rt = ExecutionPlan.from_json(blob)
        assert rt.predicted.per_device_peak_bytes == p.per_device_peak_bytes
        assert rt.n_devices == 4
        # pre-v9 dump: drop the field, from_json falls back to mesh peak
        import json
        old = json.loads(blob)
        del old["predicted"]["per_device_peak_bytes"]
        legacy = ExecutionPlan.from_json(json.dumps(old))
        assert legacy.predicted.per_device_peak_bytes == (
            p.peak_ram_bytes + p.pipeline_bytes)


def test_plan_fingerprint_tracks_layout_not_execution_knobs():
    qc = build_circuit("qft", 8)
    def fp(**kw):
        with Simulator(qc, EngineConfig(**kw)) as sim:
            return sim.compile().fingerprint
    base = fp(local_bits=4)
    assert base == fp(local_bits=4, use_kernel=False, pipeline_depth=4)
    assert base != fp(local_bits=5)
    assert base != fp(local_bits=4, inner_size=3)
    assert base != fp(local_bits=4, b_r=1e-2)


# -- checkpoint integration ----------------------------------------------------

def test_checkpoint_carries_plan_fingerprint(tmp_path):
    path = str(tmp_path / "ck.bmq")
    qc = build_circuit("ghz_state", 8)
    with Simulator(qc, EngineConfig(local_bits=4)) as sim:
        plan = sim.compile()
        sim.run().save(path)
    from repro.compression.store import BlockStore
    store, meta = BlockStore.restore(path)
    store.close()
    assert meta["plan_fingerprint"] == plan.fingerprint
    # resuming with auto knobs adopts the checkpointed plan
    sim2 = Simulator.resume(path, circuit=qc, config=EngineConfig())
    try:
        assert sim2.config.local_bits == 4
    finally:
        sim2.close()


def test_resume_rejects_incompatible_plan(tmp_path):
    """A tampered/mismatched plan fingerprint in the manifest is refused
    even when every config attribute matches."""
    path = str(tmp_path / "ck.bmq")
    bad = str(tmp_path / "bad.bmq")
    qc = build_circuit("ghz_state", 8)
    with Simulator(qc, EngineConfig(local_bits=4)) as sim:
        sim.run().save(path)
    from repro.compression.store import BlockStore
    store, meta = BlockStore.restore(path)
    meta["plan_fingerprint"] = "0" * 40
    store.snapshot(bad, meta=meta)
    store.close()
    with pytest.raises(ValueError, match="incompatible execution plan"):
        Simulator.resume(bad, circuit=qc)


# -- launcher ------------------------------------------------------------------

def test_qsim_explain_prints_plan_without_executing(capsys, monkeypatch):
    from repro.core.engine import BMQSimEngine

    def boom(self, *a, **kw):
        raise AssertionError("--explain must not execute a stage")

    monkeypatch.setattr(BMQSimEngine, "run", boom)
    rc = qsim.main(["--circuit", "qft", "--qubits", "10",
                    "--memory-budget", "1", "--explain"])
    assert rc == 0
    out = capsys.readouterr().out
    assert "ExecutionPlan" in out and "predicted" in out
    assert "[qsim] total" not in out
