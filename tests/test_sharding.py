"""Sharding-rule unit tests (pure spec logic — no big meshes needed)."""
import jax
from jax.sharding import PartitionSpec as P

from repro.configs import get_config
from repro.configs.shapes import input_specs
from repro.distributed.sharding import batch_pspecs, dp_axes, param_pspecs


class FakeMesh:
    """Duck-typed mesh: axis_names + shape dict (spec rules need no devices)."""
    def __init__(self, shape: dict):
        self._shape = shape

    @property
    def axis_names(self):
        return tuple(self._shape)

    @property
    def shape(self):
        return self._shape


MESH = FakeMesh({"data": 16, "model": 16})
MESH_MP = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _abstract_params(arch):
    from repro.models import transformer as T
    from repro.models import encdec as E
    cfg = get_config(arch)
    init = E.init_encdec_params if cfg.family == "audio" else T.init_params
    return cfg, jax.eval_shape(lambda: init(cfg, jax.random.PRNGKey(0)))


def test_dense_param_specs():
    cfg, params = _abstract_params("qwen3-4b")
    specs = param_pspecs(cfg, params, MESH)
    unit = specs["units"][0]
    assert unit["attn"]["wq"] == P(None, "data", "model")
    assert unit["attn"]["wo"] == P(None, "model", "data")
    assert unit["mlp"]["w_in"] == P(None, "data", "model")
    assert unit["mlp"]["w_out"] == P(None, "model", "data")
    assert specs["embed"] == P("model", "data")
    assert unit["ln1"] == P(None, None)  # stacked scalar-per-d norm


def test_moe_param_specs_expert_parallel_vs_dff():
    # arctic: 128 experts / 16 = expert parallel over data
    cfg, params = _abstract_params("arctic-480b")
    specs = param_pspecs(cfg, params, MESH)
    assert specs["units"][0]["mlp"]["w_in"] == P(None, "data", None,
                                                 "model")
    # mixtral: 8 experts < 16 -> d-dim FSDP instead (E replicated)
    cfg, params = _abstract_params("mixtral-8x22b")
    specs = param_pspecs(cfg, params, MESH)
    assert specs["units"][0]["mlp"]["w_in"] == P(None, None, "data",
                                                 "model")
    assert specs["units"][0]["mlp"]["w_out"] == P(None, None, "model",
                                                  "data")


def test_multipod_adds_pod_axis():
    cfg, params = _abstract_params("qwen3-4b")
    assert dp_axes(MESH_MP) == ("pod", "data")
    specs = param_pspecs(cfg, params, MESH_MP)
    assert specs["units"][0]["attn"]["wq"] == P(None, ("pod", "data"),
                                                "model")


def test_non_divisible_dims_fall_back_to_replicated():
    cfg, params = _abstract_params("whisper-large-v3")
    specs = param_pspecs(cfg, params, MESH)
    # whisper vocab 51866 doesn't divide 16 -> embed vocab dim unsharded
    assert specs["embed"][0] is None


def test_cache_specs_sequence_parallel():
    cfg = get_config("qwen3-4b")
    specs = input_specs(cfg, "decode_32k")
    b = batch_pspecs(cfg, specs, MESH)
    kv = b["cache"]["units"][0]["k"]
    assert kv == P(None, "data", "model", None, None)  # B/dp, T/tp


def test_cache_specs_batch1_long():
    cfg = get_config("recurrentgemma-2b")
    specs = input_specs(cfg, "long_500k")
    b = batch_pspecs(cfg, specs, MESH)
    leaves = jax.tree.leaves(
        b["cache"], is_leaf=lambda x: isinstance(x, P))
    # batch=1: nothing sharded over data; widths/seq may shard over model
    for sp in leaves:
        flat = [a for e in sp if e for a in (e if isinstance(e, tuple) else (e,))]
        assert "data" not in flat


def test_batch_specs_tokens():
    cfg = get_config("granite-20b")
    specs = input_specs(cfg, "train_4k")
    b = batch_pspecs(cfg, specs, MESH)
    assert b["tokens"] == P("data", None)
