"""Render EXPERIMENTS.md from the dry-run / hillclimb JSON artifacts +
archived benchmark CSV. Regenerate with:
    PYTHONPATH=src python make_experiments_md.py
"""
import json
import os

GIB = 2 ** 30


def load(path):
    return json.load(open(path)) if os.path.exists(path) else []


def fmt_cell(r):
    if "skipped" in r:
        return None
    peak = (r["bytes_per_device"]["peak"] or 0) / GIB
    return (f"| {r['arch']} | {r['shape']} | {r['step_kind']} | "
            f"{r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.1f} | "
            f"{r['collective_s']*1e3:.1f} | **{r['bottleneck']}** | "
            f"{r['useful_flops_ratio']:.3f} | {peak:.2f} |")


def coll_split(r):
    cb = r["collective_bytes"]
    tot = cb.get("total", 0) or 1
    parts = sorted(((v, k) for k, v in cb.items() if k != "total"),
                   reverse=True)
    return ", ".join(f"{k} {100*v/tot:.0f}%" for v, k in parts[:3] if v > 0)


def main():
    single = load("dryrun_single_pod.json")
    multi = load("dryrun_multi_pod.json")
    hc = load("hillclimb.json")

    out = []
    w = out.append
    w("# EXPERIMENTS — BMQSIM-JAX\n")
    w("All numbers from THIS container (single-CPU-core host; TPU v5e is "
      "the modeled target: 197 TF/s bf16, 819 GB/s HBM, 50 GB/s ICI/link). "
      "Regenerate: `PYTHONPATH=src python -m repro.launch.dryrun --all "
      "[--multi-pod] --out <json>` then `python make_experiments_md.py`.\n")

    # ---------------------------------------------------------------- method
    w("## Method notes (how the numbers are derived)\n")
    w("* Every cell is **lowered AND compiled** (`.lower().compile()`) with "
      "`ShapeDtypeStruct` inputs on the production mesh — no allocation.")
    w("* The compiled artifact is the per-device SPMD module: "
      "`cost_analysis()` FLOPs/bytes and HLO collective sizes are "
      "**per-device**; terms below use them directly (= total/(chips·peak)).")
    w("* XLA's analytical cost model counts `while`-loop (layer-scan) "
      "bodies ONCE. Roofline terms therefore come from a **paired-compile "
      "extrapolation**: two cheap *unrolled* variants with 2 and 3 pattern "
      "units give X(2), X(3); total = X(2) + (U−2)·(X(3)−X(2)). Validated "
      "against a full 36-layer unroll (qwen3-4b train_4k): compute within "
      "2%, collectives within 0.01%, bytes within 22% (copy-elision "
      "differs). The scanned production program is still what's compiled "
      "for the fit/compile proof and `memory_analysis()`.")
    w("* collective bytes = sum of output-operand bytes over all-gather / "
      "all-reduce / reduce-scatter / all-to-all / collective-permute ops "
      "parsed from `compiled.as_text()` (ring-topology factors ~2(n−1)/n "
      "not applied — they'd scale every cell equally).")
    w("* train cells donate (params, opt state); decode cells donate the "
      "KV cache (in-place update — without it XLA double-buffers: qwen1.5 "
      "decode measured 40.2 GiB/dev undonated vs 20.25 donated).\n")

    # ---------------------------------------------------------------- dryrun
    w("## §Dry-run\n")
    n_ok = sum(1 for r in single if "error" not in r and "skipped" not in r)
    n_skip = sum(1 for r in single if "skipped" in r)
    w(f"**Single pod 16×16 (256 chips, axes `(data, model)`)**: "
      f"{n_ok} cells compiled, {n_skip} skipped by §Arch-applicability, "
      f"0 failures.")
    if multi:
        m_ok = sum(1 for r in multi if "error" not in r and "skipped" not in r)
        m_err = sum(1 for r in multi if "error" in r)
        w(f"**Multi-pod 2×16×16 (512 chips, axes `(pod, data, model)`)**: "
          f"{m_ok} cells compiled, {m_err} failures — the `pod` axis "
          f"shards (FSDP/DP extends over `(pod, data)`).")
    w("\nSkips (recorded in DESIGN.md §Arch-applicability):\n")
    for r in single:
        if "skipped" in r:
            w(f"* {r['arch']} × {r['shape']}: {r['skipped']}")
    w("\nPer-device memory fit, largest cells (single pod, bf16 params; "
      "v5e budget 16 GiB):\n")
    w("| arch × shape | peak GiB/dev | fits? | note |")
    w("|---|---|---|---|")
    fat = sorted((r for r in single if "skipped" not in r),
                 key=lambda r: -(r["bytes_per_device"]["peak"] or 0))[:8]
    for r in fat:
        peak = (r["bytes_per_device"]["peak"] or 0) / GIB
        note = ""
        fits = "yes" if peak <= 16 else "**no**"
        if r["arch"] == "qwen1.5-32b" and r["shape"] == "decode_32k":
            note = ("MHA kv=40 cache is 2.7 TB global; fixed by the "
                    "paper-technique compressed KV — see §Perf climb 1")
        w(f"| {r['arch']} × {r['shape']} | {peak:.2f} | {fits} | {note} |")

    # -------------------------------------------------------------- roofline
    w("\n## §Roofline (single pod, per (arch × shape); times are "
      "seconds×10³ = ms per step)\n")
    w("| arch | shape | step | compute ms | memory ms | collective ms | "
      "bottleneck | MODEL/HLO flops | peak GiB/dev |")
    w("|---|---|---|---|---|---|---|---|---|")
    for r in single:
        line = fmt_cell(r)
        if line:
            w(line)
        else:
            w(f"| {r['arch']} | {r['shape']} | — | — | — | — | skipped | — | — |")
    w("\n**Reading the table**: `memory` dominates 28/34 cells — "
      "bytes-accessed counts every HLO operand, so it over-states real HBM "
      "traffic post-fusion, but the *ranking* is what the perf loop "
      "optimizes. MODEL_FLOPS/HLO_FLOPS < 1 shows remat recompute (+2·N·D), "
      "attention FLOPs (not in 6·N·D), and f32 softmax/norm work; "
      "recurrent/ssm archs are lowest (gate machinery ≫ 6·N·D).\n")
    w("Dominant collectives for the most collective-bound cells:\n")
    for r in single:
        if "skipped" in r or r["bottleneck"] != "collective":
            continue
        w(f"* {r['arch']} × {r['shape']}: {coll_split(r)}")

    # ------------------------------------------------------------------ perf
    w("\n## §Perf — hillclimbing log (hypothesis → change → before → after)\n")
    idx = {(r["arch"], r["shape"]): r for r in single if "skipped" not in r}

    def pair(arch, shape, key):
        b = idx.get((arch, shape))
        a = next((r for r in hc if r["arch"] == arch and r["shape"] == shape
                  and (r.get("variant") == key or
                       (key == "ckv" and r.get("compressed_kv")))), None)
        return b, a

    w("Cells chosen per the assignment: worst roofline fit "
      "(qwen1.5-32b × decode_32k — the only cell over HBM), most "
      "collective-bound (arctic-480b × prefill_32k), most representative "
      "of the paper's technique (gemma3-12b × train_4k via banded local "
      "attention + the compressed-KV decode lever).\n")

    climbs = [
        ("1 — paper technique", "qwen1.5-32b", "decode_32k", "ckv",
         "HYPOTHESIS: decode reads the whole KV cache every step; the "
         "cache is 2.7 TB global (MHA kv=40 — the fattest assigned cache) "
         "→ memory term ∝ cache bytes, and the baseline cell does NOT fit "
         "HBM (20.25 GiB/dev > 16). pwrel-compressing K/V (paper §4.3 as "
         "a serving feature: uint8 log-codes + packed sign bitmap + "
         "per-(token,head) scale = 2.11× fewer bytes, ≤2.2% point-wise "
         "error) should cut the memory term ≈2× and bring peak under "
         "budget. Iteration 1 (naive) REPLICATED the compressed cache — "
         "185 GiB/dev — because the sharding rules didn't recognize "
         "codes_/signs_/scale_ leaves; fixed, then:"),
        ("3 — beyond-paper", "gemma3-12b", "train_4k", "banded",
         "HYPOTHESIS: 5/6 of gemma3's layers are 1024-window local "
         "attention, yet the baseline computes full 4096² scores + mask. "
         "Block-banded computation (each W-block attends to [prev|self]) "
         "computes only 2W keys per query → attention FLOPs ×2W/S = 0.5 "
         "on those layers, and the (S,S) f32 buffer becomes (S,2W). "
         "(Validated exact vs the masked path: 2.4e-7 max err in f32.)"),
    ]
    # climb 2 is a hand-written negative-result log (3 iterations)
    for num, arch, shape, key, hyp in climbs[:1]:
        b, a = pair(arch, shape, key)
        w(f"### Climb {num}: {arch} × {shape} (+{key})\n")
        w(hyp + "\n")
        if not (b and a):
            w("*(variant run pending — see hillclimb.json)*\n")
            continue
        w("| metric | baseline | optimized | Δ |")
        w("|---|---|---|---|")
        for label, kk, scale in [
                ("compute ms", "compute_s", 1e3),
                ("memory ms", "memory_s", 1e3),
                ("collective ms", "collective_s", 1e3),
                ("peak GiB/dev", None, None)]:
            if kk:
                vb, va = b[kk] * scale, a[kk] * scale
            else:
                vb = (b["bytes_per_device"]["peak"] or 0) / GIB
                va = (a["bytes_per_device"]["peak"] or 0) / GIB
            delta = (va - vb) / vb * 100 if vb else 0.0
            w(f"| {label} | {vb:.2f} | {va:.2f} | {delta:+.1f}% |")
        dom_b = b["bottleneck"]
        dom_key = {"compute": "compute_s", "memory": "memory_s",
                   "collective": "collective_s"}[dom_b]
        moved = (a[dom_key] - b[dom_key]) / b[dom_key] * 100
        verdict = "CONFIRMED" if moved < -5 else (
            "PARTIAL" if moved < 0 else "REFUTED")
        w(f"\nDominant term was **{dom_b}**: moved {moved:+.1f}% → "
          f"**{verdict}**.\n")

    # ---- climb 2: collective-bound arctic prefill (negative-result log)
    w("### Climb 2 — most collective-bound: arctic-480b × prefill_32k\n")
    w("HYPOTHESIS: HLO inspection shows the top all-reduce is "
      "`f32[2,1,32768,32768,7]` = **56 GiB/layer** — full S×S attention "
      "scores, 2-way-replica-all-reduced because kv=8 heads < model=16 "
      "(GSPMD can only half-shard the head dim). Re-sharding attention "
      "should remove it. Three iterations (napkin-math'd, then measured; "
      "baseline under the same mesh context: compute 1520 / memory 64492 "
      "/ collective 113459 ms):\n")
    w("| iteration | change | compute | memory | collective | verdict |")
    w("|---|---|---|---|---|---|")
    w("| v1 | constrain scores S-dim over `model` | 1468 | 55865 | 62804 "
      "| no-op — constraint silently unbound under the legacy mesh "
      "context (tooling lesson: must lower under `jax.set_mesh`) |")
    w("| v2 | shard q's S-dim over `model` | 1542 | 102308 | **406904** "
      "| REFUTED — every layer now pays full activation reshards between "
      "the S-sharded attention and the batch-sharded residual stream |")
    w("| v3 | KV-parallel: shard k/v/scores T-dim over `model` | 6834 | "
      "**242045** | **93042 (−18%)** | PARTIAL — the dominant collective "
      "term drops 18% and the 56 GiB all-reduce disappears, but the "
      "replicated (S,S) causal mask now materializes against T-sharded "
      "scores: memory +3.8×. Net worse. |")
    w("")
    w("LESSON (recorded per methodology — a refuted hypothesis is as "
      "informative as a confirmed one): constraint-level re-sharding "
      "cannot beat GSPMD's head-sharding for G<TP full attention; the "
      "real fix is *structural* — a flash/banded attention kernel that "
      "never materializes S×S scores (kernels/flash_attention.py is "
      "that kernel, interpret-validated; on-TPU compilation is the "
      "deployment step this container cannot measure). Three consecutive "
      "<5% iterations on the dominant term → stop per the protocol. The "
      "same structural fix measured on mixtral prefill (banded, SWA "
      "4096): memory −34%, compute −24% — see Additional measurements.\n")

    for num, arch, shape, key, hyp in climbs[1:]:
        b, a = pair(arch, shape, key)
        w(f"### Climb {num}: {arch} × {shape} (+{key})\n")
        w(hyp + "\n")
        if not (b and a):
            w("*(variant run pending — see hillclimb.json)*\n")
            continue
        w("| metric | baseline | optimized | Δ |")
        w("|---|---|---|---|")
        for label, kk, scale in [
                ("compute ms", "compute_s", 1e3),
                ("memory ms", "memory_s", 1e3),
                ("collective ms", "collective_s", 1e3),
                ("peak GiB/dev", None, None)]:
            if kk:
                vb, va = b[kk] * scale, a[kk] * scale
            else:
                vb = (b["bytes_per_device"]["peak"] or 0) / GIB
                va = (a["bytes_per_device"]["peak"] or 0) / GIB
            delta = (va - vb) / vb * 100 if vb else 0.0
            w(f"| {label} | {vb:.2f} | {va:.2f} | {delta:+.1f}% |")
        dom_b = b["bottleneck"]
        dom_key = {"compute": "compute_s", "memory": "memory_s",
                   "collective": "collective_s"}[dom_b]
        moved = (a[dom_key] - b[dom_key]) / b[dom_key] * 100
        verdict = "CONFIRMED" if moved < -5 else (
            "PARTIAL" if moved < 0 else "REFUTED")
        w(f"\nDominant term was **{dom_b}**: moved {moved:+.1f}% → "
          f"**{verdict}** (and compute {100*(a['compute_s']-b['compute_s'])/b['compute_s']:+.1f}%).\n")

    # extras
    extras = [r for r in hc if (r.get("variant") not in (None, "baseline")
                                or r.get("compressed_kv"))
              and not any(r["arch"] == c[1] and r["shape"] == c[2]
                          and (r.get("variant") == c[3] or
                               (c[3] == "ckv" and r.get("compressed_kv")))
                          for c in climbs)]
    if extras:
        w("### Additional beyond-paper measurements\n")
        for r in extras:
            if "error" in r or "bytes_per_device" not in r:
                continue
            b = idx.get((r["arch"], r["shape"]))
            if not b:
                continue
            tag = r.get("variant") if r.get("variant") != "baseline" else ""
            if r.get("compressed_kv"):
                tag = (tag + "+ckv").lstrip("+")
            w(f"* {r['arch']} × {r['shape']} (+{tag}): memory "
              f"{b['memory_s']*1e3:.1f} → {r['memory_s']*1e3:.1f} ms, "
              f"collective {b['collective_s']*1e3:.1f} → "
              f"{r['collective_s']*1e3:.1f} ms, peak "
              f"{(b['bytes_per_device']['peak'] or 0)/GIB:.2f} → "
              f"{(r['bytes_per_device']['peak'] or 0)/GIB:.2f} GiB/dev")

    # ------------------------------------------------------------ paper-repro
    w("\n## §Paper reproduction (container scale; full CSV in "
      "bench_output.txt)\n")
    if os.path.exists("bench_output.txt"):
        rows = [l.strip() for l in open("bench_output.txt")
                if "," in l and not l.startswith("bench,")]
        picks = [l for l in rows if any(k in l for k in (
            "fidelity,", "_reduction", "_speedup", "_overhead_pct",
            "_extra_qubits", "partition_pct"))]
        w("```\n" + "\n".join(picks[:60]) + "\n```")
    w("\nHeadline checks vs the paper:")
    w("* fidelity > 0.99 on all 8 NWQBench circuits at b_r = 1e-3 "
      "(paper Fig. 8 claims the same bound) — tests/test_system.py asserts "
      "it; benchmark prints exact values.")
    w("* compressions = #stages ≪ #gates (paper §4.1: 2673→28 at 33q; "
      "here e.g. qft-14: 91 gates → ~21 stages at b=8/inner=2).")
    w("* memory ≥30–600× under the 2^(n+4) standard for sparse-state "
      "circuits (paper Fig. 9: 678× cat/ghz, 10.5× qft — same ordering "
      "here, magnitudes scale with n).")
    w("* per-gate (SC19-Sim) baseline is strictly slower and "
      "lower-fidelity (paper Fig. 7/8 direction) — bench `sc19`.")
    w("* two-level store engages under an artificial RAM budget and the "
      "run completes (paper §4.4/Table 2 SSD row) — bench `max_qubits`, "
      "test `test_ram_budget_spills_to_disk`.")

    with open("EXPERIMENTS.md", "w") as f:
        f.write("\n".join(out) + "\n")
    print(f"wrote EXPERIMENTS.md ({len(out)} lines)")


if __name__ == "__main__":
    main()
