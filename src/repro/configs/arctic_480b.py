"""arctic-480b [moe]: 35L, 128 experts top-2 + dense residual branch.

[hf:Snowflake/snowflake-arctic-base; hf]
"""
from ..models.config import ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    n_layers=35,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=4864,
    vocab=32000,
    head_dim=128,
    moe=MoEConfig(n_experts=128, top_k=2, dense_residual=True,
                  dense_d_ff=4864),
    # even bf16 Adam moments overflow a 256-chip pod (21.3 GiB/dev measured
    # in the dry-run); factored second moments fit.  See EXPERIMENTS.md.
    optimizer="adafactor",
    opt_state_dtype="bfloat16",
)
