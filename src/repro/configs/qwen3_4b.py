"""qwen3-4b [dense]: 36L, qk-norm, GQA kv=8.

[hf:Qwen/Qwen3-8B scaled per assignment; hf]
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-4b",
    family="dense",
    n_layers=36,
    d_model=2560,
    n_heads=32,
    n_kv_heads=8,
    d_ff=9728,
    vocab=151936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
)
