"""xlstm-125m [ssm]: 12L alternating mLSTM / sLSTM blocks, d_ff=0.

[arXiv:2405.04517; unverified]  Block-internal projections replace the
FFN (d_ff=0 per assignment).
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-125m",
    family="ssm",
    n_layers=12,
    d_model=768,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    pattern=("mlstm", "slstm"),
)
