"""llama-3.2-vision-90b [vlm]: 100L, cross-attn image layers every 5th.

Vision frontend is a STUB: input_specs provides precomputed patch
embeddings (B, n_image_tokens, d_model).  [hf:meta-llama/Llama-3.2-11B-
Vision scaled per assignment; unverified]
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    n_layers=100,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=28672,
    vocab=128256,
    head_dim=128,
    rope_theta=500_000.0,
    pattern=("attn",) * 4 + ("cross_attn",),
    n_image_tokens=576,
    tie_embeddings=False,
)
