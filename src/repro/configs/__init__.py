"""Assigned-architecture registry: ``get_config(arch_id)`` + shapes.

Each ``<id>.py`` holds the exact published configuration; ``reduced_config``
shrinks any of them (same family/pattern, tiny dims) for CPU smoke tests.
"""
from __future__ import annotations

from dataclasses import replace
from importlib import import_module

from ..models.config import EncoderConfig, ModelConfig, MoEConfig

ARCH_IDS = [
    "gemma3_12b", "qwen15_32b", "granite_20b", "qwen3_4b",
    "llama32_vision_90b", "arctic_480b", "mixtral_8x22b",
    "recurrentgemma_2b", "xlstm_125m", "whisper_large_v3",
]

# canonical dashed ids from the assignment -> module names
ALIASES = {
    "gemma3-12b": "gemma3_12b",
    "qwen1.5-32b": "qwen15_32b",
    "granite-20b": "granite_20b",
    "qwen3-4b": "qwen3_4b",
    "llama-3.2-vision-90b": "llama32_vision_90b",
    "arctic-480b": "arctic_480b",
    "mixtral-8x22b": "mixtral_8x22b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "xlstm-125m": "xlstm_125m",
    "whisper-large-v3": "whisper_large_v3",
}


def get_config(arch: str) -> ModelConfig:
    mod = ALIASES.get(arch, arch).replace("-", "_").replace(".", "_")
    return import_module(f"repro.configs.{mod}").CONFIG


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Family-preserving tiny config for CPU smoke tests."""
    pat = cfg.pattern
    kw = dict(
        n_layers=len(pat) * 2 + (1 if cfg.n_remainder else 0),
        d_model=64,
        n_heads=4,
        n_kv_heads=min(cfg.n_kv_heads, 2) if cfg.n_kv_heads > 1 else 1,
        d_ff=cfg.d_ff and 128,
        vocab=256,
        head_dim=16,
        sliding_window=min(cfg.sliding_window, 8) if cfg.sliding_window else 0,
        rglru_width=64 if cfg.rglru_width else 0,
        n_image_tokens=8,
        remat=False,
    )
    if cfg.moe is not None:
        # capacity_factor 8 => provably drop-free at smoke scale, so
        # prefill/decode logits match the train path exactly (production
        # keeps 1.25 and accepts capacity-drop jitter — FLOPs honesty)
        kw["moe"] = replace(cfg.moe, n_experts=4, capacity_factor=8.0,
                            dense_d_ff=128 if cfg.moe.dense_residual else 0)
    if cfg.encoder is not None:
        kw["encoder"] = EncoderConfig(n_layers=2, n_frames=16, dec_len=12)
    if cfg.family == "ssm":
        kw["d_ff"] = 0
        kw["n_kv_heads"] = 4
        kw["head_dim"] = 0
    return replace(cfg, **kw)
