"""whisper-large-v3 [audio]: enc-dec, 32+32L, conv frontend stubbed.

[arXiv:2212.04356; unverified]  input_specs feeds precomputed frame
embeddings; decoder positions use RoPE (deviation noted in DESIGN.md).
"""
from ..models.config import ModelConfig, EncoderConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    n_layers=32,                      # decoder layers
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    d_ff=5120,
    vocab=51866,
    head_dim=64,
    act="gelu",
    encoder=EncoderConfig(n_layers=32, n_frames=1500, dec_len=512),
)
