"""recurrentgemma-2b [hybrid]: 26L, RG-LRU + local attention, 1:2 ratio.

[arXiv:2402.19427; hf]  Pattern (rglru, rglru, attn_local); 26 = 8*3 + 2,
the remainder unrolls the first two pattern positions.
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    d_ff=7680,
    vocab=256000,
    head_dim=256,
    sliding_window=2048,
    pattern=("rglru", "rglru", "attn_local"),
    rglru_width=2560,
)
