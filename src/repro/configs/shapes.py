"""Assigned input shapes x applicability + abstract input specs.

40 cells = 10 archs x 4 shapes; ``long_500k`` runs only for sub-quadratic
archs (SSM / hybrid / SWA / mostly-local) and whisper has no 512k decode
(decoder context is architecturally bounded) — skips recorded here AND in
DESIGN.md §Arch-applicability.

``input_specs`` returns ShapeDtypeStructs only (the dry-run never
allocates); ``step_kind`` says which program to lower for the cell.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..models import encdec, transformer
from ..models.config import ModelConfig

__all__ = ["SHAPES", "ShapeSpec", "step_kind", "cell_is_applicable",
           "skip_reason", "input_specs", "all_cells"]


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524288, 1),
}

# archs with a sub-quadratic (or bounded-window) path for 512k decode
_LONG_OK = {"gemma3-12b", "mixtral-8x22b", "recurrentgemma-2b", "xlstm-125m"}


def step_kind(shape: str) -> str:
    return SHAPES[shape].kind


def cell_is_applicable(cfg: ModelConfig, shape: str) -> bool:
    if shape == "long_500k":
        return cfg.name in _LONG_OK
    return True


def skip_reason(cfg: ModelConfig, shape: str) -> str:
    if shape == "long_500k" and cfg.name not in _LONG_OK:
        if cfg.family == "audio":
            return "enc-dec decoder context architecturally bounded (<=448)"
        return "pure full attention: 512k decode needs sub-quadratic path"
    return ""


def all_cells():
    """Yield (arch_id, shape_name) for all 40 assigned cells (incl. skips)."""
    from . import ALIASES
    for arch in ALIASES:
        for shape in SHAPES:
            yield arch, shape


def _tok(shape, dtype=jnp.int32):
    return jax.ShapeDtypeStruct(shape, dtype)


def input_specs(cfg: ModelConfig, shape: str) -> dict:
    """Abstract model inputs for one cell (see launch/dryrun.py for use)."""
    sp = SHAPES[shape]
    B, S = sp.global_batch, sp.seq_len
    d = cfg.d_model

    if cfg.family == "audio":
        enc = cfg.encoder
        if sp.kind == "train":
            return {"frames": _tok((B, S // 4, d), jnp.bfloat16),
                    "tokens": _tok((B, enc.dec_len))}
        if sp.kind == "prefill":
            return {"frames": _tok((B, S // 4, d), jnp.bfloat16),
                    "tokens": _tok((B, enc.dec_len))}
        # decode: self cache of length S (mechanical capability check)
        cache = jax.eval_shape(
            lambda: encdec.init_encdec_cache(cfg, B, S, enc.n_frames))
        return {"token": _tok((B, 1)), "cache": cache,
                "pos": jax.ShapeDtypeStruct((), jnp.int32)}

    aux = None
    if cfg.family == "vlm":
        aux = _tok((B, cfg.n_image_tokens, d), jnp.bfloat16)

    if sp.kind == "train":
        spec = {"tokens": _tok((B, S))}
        if aux is not None:
            spec["aux"] = aux
        return spec
    if sp.kind == "prefill":
        spec = {"tokens": _tok((B, S))}
        if aux is not None:
            spec["aux"] = aux
        return spec
    # decode
    cache = jax.eval_shape(
        lambda: transformer.init_decode_cache(cfg, B, S))
    spec = {"token": _tok((B, 1)), "cache": cache,
            "pos": jax.ShapeDtypeStruct((), jnp.int32)}
    if aux is not None:
        spec["aux"] = aux
    return spec
