"""gemma3-12b [dense]: 48L, 5:1 local:global attention, GQA kv=8.

[hf:google/gemma-3-1b-pt scaled per assignment; unverified]
"""
from ..models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-12b",
    family="dense",
    n_layers=48,
    d_model=3840,
    n_heads=16,
    n_kv_heads=8,
    d_ff=15360,
    vocab=262144,
    head_dim=256,
    qk_norm=True,
    rope_theta=1_000_000.0,
    sliding_window=1024,
    pattern=("attn_local",) * 5 + ("attn",),   # 5 local : 1 global
    logits_softcap=30.0,
)
