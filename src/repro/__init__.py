"""BMQSIM reproduction: compressed, staged state-vector simulation in JAX.

Reproduces "Overcoming Memory Constraints in Quantum Circuit Simulation
with a High-Fidelity Compression Framework": a full-state simulator that
holds the state as lossy-compressed SV blocks (point-wise relative error
control, §4.3), partitions the circuit into stages that each touch few
global qubits (§4.1), and pipelines decode/compute/encode per group (§4.2)
over a two-level RAM/disk store (§4.4).

Public API (the stable surface; everything else is internal layering):

    Circuits     build_circuit, random_circuit, Circuit, Gate
    Simulation   simulate_bmqsim, EngineConfig, SimStats, simulate_dense
    Metrics      fidelity, max_pointwise_rel_error
    Compression  PwRelParams, compress_complex_block,
                 decompress_complex_block, BlockSegments, BlockStore

Quickstart::

    from repro import EngineConfig, build_circuit, simulate_bmqsim
    state, stats = simulate_bmqsim(build_circuit("qft", 14),
                                   EngineConfig(local_bits=8))
"""
from .compression import (  # noqa: F401
    BlockSegments, BlockStore, CompressedBlock, PwRelParams,
    compress_complex_block, decompress_complex_block,
)
from .core import (  # noqa: F401
    BMQSimEngine, Circuit, EngineConfig, Gate, SimStats, build_circuit,
    fidelity, max_pointwise_rel_error, random_circuit, simulate_bmqsim,
    simulate_dense,
)

__all__ = [
    # circuits
    "Circuit", "Gate", "build_circuit", "random_circuit",
    # simulation
    "simulate_bmqsim", "BMQSimEngine", "EngineConfig", "SimStats",
    "simulate_dense",
    # metrics
    "fidelity", "max_pointwise_rel_error",
    # compression
    "PwRelParams", "CompressedBlock", "compress_complex_block",
    "decompress_complex_block", "BlockSegments", "BlockStore",
]

__version__ = "0.2.0"
