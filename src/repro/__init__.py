"""BMQSIM reproduction: compressed, staged state-vector simulation in JAX.

Reproduces "Overcoming Memory Constraints in Quantum Circuit Simulation
with a High-Fidelity Compression Framework": a full-state simulator that
holds the state as lossy-compressed SV blocks (point-wise relative error
control, §4.3), partitions the circuit into stages that each touch few
global qubits (§4.1), and pipelines decode/compute/encode per group (§4.2)
over a two-level RAM/disk store (§4.4).

Public API (the stable surface; everything else is internal layering):

    Circuits     build_circuit, random_circuit, qaoa_template, Circuit,
                 Gate, Parameter; noise channels via Circuit.depolarize /
                 with_depolarizing (sampled Pauli trajectories)
    Sessions     Simulator, SimResult, EngineConfig, SimStats; batched
                 execution via Simulator.run_batch / run(trajectories=K)
                 -> BatchResult (per-lane views + trajectory averages)
    Planning     ExecutionPlan (Simulator.compile), StagePlan,
                 PlanPredictions — EngineConfig(local_bits=None,
                 memory_budget_bytes=...) auto-tunes the knobs
    Service      SimService: multi-tenant plan-admission scheduling +
                 continuous lane batching over a structure-keyed session
                 pool; ServiceStats, Job, VirtualClock (docs/SERVING.md)
    One-shot     simulate_bmqsim (compat wrapper), simulate_dense
    Metrics      fidelity, max_pointwise_rel_error
    Compression  PwRelParams, compress_complex_block,
                 decompress_complex_block, BlockSegments, BlockStore
    Resilience   inject_faults / FaultSpec (deterministic fault
                 injection), typed failures (StoreIOError,
                 BlockCorruptionError, ResumableError,
                 MemoryPressureError), PressureMonitor (degradation
                 ladder when compression underdelivers)

Quickstart — a session that never materializes the 2^n state::

    from repro import EngineConfig, Simulator, build_circuit

    with Simulator(build_circuit("qft", 14),
                   EngineConfig(local_bits=8)) as sim:
        result = sim.run()
        counts = result.sample(1024)        # streams the compressed store
        amp0 = result.amplitudes([0])[0]

``simulate_bmqsim(circuit, config)`` remains as the one-shot compat
wrapper returning ``(dense_state, stats)``; prefer :class:`Simulator`,
which reuses the partition and compiled stage schedules across runs
(parameter sweeps) and reads observables from the compressed blocks.
"""
from .compression import (  # noqa: F401
    BlockSegments, BlockStore, CompressedBlock, PwRelParams,
    compress_complex_block, decompress_complex_block,
)
from .core import (  # noqa: F401
    BatchResult, BMQSimEngine, Circuit, EngineConfig, ExecutionPlan,
    FaultInjector, FaultSpec, Gate, InjectedCrash, Job, Parameter,
    PlanPredictions, PressureMonitor, ServiceStats, SimResult, SimService,
    SimStats, Simulator, StagePlan, VirtualClock, build_circuit, fidelity,
    inject_faults, max_pointwise_rel_error, maxcut_cost_fn, maxcut_edges,
    qaoa_template, random_circuit, simulate_bmqsim, simulate_dense,
    with_depolarizing, zsum_cost_fn,
)
from .errors import (  # noqa: F401
    BlockCorruptionError, CheckpointError, MemoryPressureError,
    ResumableError, StoreIOError,
)

__all__ = [
    # circuits
    "Circuit", "Gate", "Parameter", "build_circuit", "random_circuit",
    "qaoa_template", "maxcut_edges", "maxcut_cost_fn",
    # sessions
    "Simulator", "SimResult", "BatchResult", "EngineConfig", "SimStats",
    # service tier
    "SimService", "ServiceStats", "Job", "VirtualClock",
    # noise trajectories
    "with_depolarizing", "zsum_cost_fn",
    # planning
    "ExecutionPlan", "StagePlan", "PlanPredictions",
    # one-shot + internals kept public
    "simulate_bmqsim", "BMQSimEngine", "simulate_dense",
    # metrics
    "fidelity", "max_pointwise_rel_error",
    # compression
    "PwRelParams", "CompressedBlock", "compress_complex_block",
    "decompress_complex_block", "BlockSegments", "BlockStore",
    # resilience
    "FaultSpec", "FaultInjector", "InjectedCrash", "inject_faults",
    "PressureMonitor", "StoreIOError", "BlockCorruptionError",
    "CheckpointError", "ResumableError", "MemoryPressureError",
]

__version__ = "0.4.0"
