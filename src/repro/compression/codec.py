"""Host block codec: pwrel lossy stage + lossless stage (paper §4.3).

Per complex SV block:

* re/im planes are pwrel-quantized (``pwrel.py``; the device pipeline uses
  the Pallas kernels in ``kernels/quantize.py`` instead) into uint16 codes
  + sign bitmaps + per-plane ``l_max``.
* the lossless stage (``lossless.py``) pre-scans the bitmaps and
  zlib-encodes the code streams.  If the payload would exceed the raw
  block, a RAW escape stores the original complex bytes — compression
  never inflates.

The structured result is a :class:`~repro.compression.segments.BlockSegments`
(``encode_block_host`` / ``decode_block_host``) — the unit the two-level
store and the stage pipeline traffic in.  ``compress_complex_block`` /
``decompress_complex_block`` are the flat-bytes convenience API over the
same self-describing layout (see ``segments.py`` for the byte format).
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .lossless import (decode_bitmap, decode_codes, encode_bitmap,
                       encode_codes, prescan_decode_bitmap,
                       prescan_encode_bitmap)
from ..faults import fault_point
from .pwrel import PwRelParams, dequantize_plane, quantize_plane
from .segments import BlockSegments, PlaneSegments

__all__ = [
    "CompressedBlock", "compress_complex_block", "decompress_complex_block",
    "encode_block_host", "decode_block_host",
    "prescan_encode_bitmap", "prescan_decode_bitmap",
]


@dataclass(frozen=True)
class CompressedBlock:
    """One compressed SV block as flat bytes, ready for the two-level store."""

    payload: bytes
    n_amps: int  # complex amplitudes in the block

    @property
    def nbytes(self) -> int:
        return len(self.payload)

    @property
    def raw_nbytes(self) -> int:
        return self.n_amps * 8  # complex64

    @property
    def ratio(self) -> float:
        return self.raw_nbytes / max(1, self.nbytes)


def _encode_plane_host(x: np.ndarray, params: PwRelParams,
                       prescan: bool) -> PlaneSegments:
    codes, signs, l_max = quantize_plane(x, params)
    return PlaneSegments(
        l_max=float(l_max),
        codes=encode_codes(np.asarray(codes, dtype=np.uint16)),
        bitmap=encode_bitmap(np.asarray(signs), prescan),
    )


def _decode_plane_host(p: PlaneSegments, n: int, params: PwRelParams,
                       prescan: bool) -> np.ndarray:
    codes = decode_codes(p.codes, n)
    signs = decode_bitmap(p.bitmap, n, prescan)
    return np.asarray(dequantize_plane(codes, signs, p.l_max, params))


def encode_block_host(amps: np.ndarray, params: PwRelParams,
                      prescan: bool = True) -> BlockSegments:
    """Compress a complex64 block entirely on the host.

    Args:
        amps: complex amplitudes, flattened to 1-D (any shape accepted).
        params: the point-wise relative bound (``PwRelParams.b_r``).
        prescan: RLE uniform bitmap chunks before zlib (§4.3 pre-scan).

    Returns:
        Structured segments; falls back to the RAW escape when the pwrel
        payload would be larger than the raw complex bytes.
    """
    amps = np.asarray(amps, dtype=np.complex64).reshape(-1)
    seg = BlockSegments(
        n_amps=amps.size, prescan=prescan,
        re=_encode_plane_host(amps.real.copy(), params, prescan),
        im=_encode_plane_host(amps.imag.copy(), params, prescan),
    )
    if seg.nbytes >= seg.raw_nbytes + 8:
        seg = BlockSegments(n_amps=amps.size, raw=amps.tobytes())
    return seg


def decode_block_host(seg: BlockSegments, params: PwRelParams) -> np.ndarray:
    """Inverse of :func:`encode_block_host` -> complex64 amplitudes (1-D)."""
    if seg.is_raw:
        return np.frombuffer(seg.raw, dtype=np.complex64,
                             count=seg.n_amps).copy()
    re = _decode_plane_host(seg.re, seg.n_amps, params, seg.prescan)
    im = _decode_plane_host(seg.im, seg.n_amps, params, seg.prescan)
    return (re + 1j * im).astype(np.complex64)


def compress_complex_block(amps: np.ndarray, params: PwRelParams,
                           prescan: bool = True) -> CompressedBlock:
    """complex64 block -> :class:`CompressedBlock` (pwrel payload or RAW).

    Args:
        amps: complex amplitudes; flattened to 1-D.
        params: :class:`~repro.compression.pwrel.PwRelParams` — the
            point-wise relative error bound ``b_r``.
        prescan: enable the §4.3 bitmap pre-scan RLE.

    Returns:
        A :class:`CompressedBlock` whose ``payload`` is the self-describing
        byte layout documented in ``segments.py``; never larger than the
        raw block plus a fixed 8-byte header.
    """
    fault_point("codec.encode")
    amps = np.asarray(amps, dtype=np.complex64).reshape(-1)
    seg = encode_block_host(amps, params, prescan)
    return CompressedBlock(payload=seg.to_bytes(), n_amps=amps.size)


def decompress_complex_block(block: CompressedBlock | bytes,
                             params: PwRelParams) -> np.ndarray:
    """Inverse of :func:`compress_complex_block`.

    Args:
        block: a :class:`CompressedBlock` or its raw ``payload`` bytes.
        params: must carry the same ``b_r`` used to compress.

    Returns:
        The reconstructed complex64 amplitudes (1-D), each non-zero element
        within relative error ``b_r`` per real plane.
    """
    fault_point("codec.decode")
    blob = block.payload if isinstance(block, CompressedBlock) else block
    return decode_block_host(BlockSegments.from_bytes(blob), params)
