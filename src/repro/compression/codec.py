"""Host-side lossless stage + block container (paper §4.3 lines 15-17).

Per complex SV block:

* re/im planes are pwrel-quantized (``pwrel.py`` / the Pallas kernel) into
  uint16 codes + sign bitmaps + per-plane ``l_max``.
* bitmaps get the *pre-scan*: split into chunks, drop all-0 / all-1 chunks
  (signs repeat over long ranges — the paper's warp-ballot observation),
  keep a 2-bit flag per chunk, then lossless-encode what remains.
* code streams are lossless-encoded (zlib here; bitcomp's lossless stage in
  the paper).  If the payload would exceed the raw block, a RAW escape
  stores the original complex bytes — compression never inflates.

The byte layout is self-describing so blocks round-trip through the
two-level store (RAM / disk tiers) unchanged.
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass

import numpy as np

from .pwrel import PwRelParams, quantize_plane, dequantize_plane

__all__ = [
    "CompressedBlock", "compress_complex_block", "decompress_complex_block",
    "prescan_encode_bitmap", "prescan_decode_bitmap",
]

_FMT_PWREL = 1   # pwrel codes + bitmaps
_FMT_RAW = 2     # raw complex64 escape
_CHUNK_BYTES = 128          # bitmap pre-scan chunk = 1024 bits
_ZLEVEL = 1                 # throughput-oriented, like bitcomp

_FLAG_ZERO, _FLAG_ONE, _FLAG_MIXED = 0, 1, 2


def prescan_encode_bitmap(bits: np.ndarray) -> bytes:
    """Pack a bool array to bits, RLE away uniform chunks, zlib the rest.

    Layout: u32 n_bits | u32 n_mixed | flags(2b/chunk, packed) | z(mixed).
    """
    bits = np.asarray(bits, dtype=bool).reshape(-1)
    packed = np.packbits(bits)  # big-endian bit order within bytes
    n = packed.size
    n_chunks = (n + _CHUNK_BYTES - 1) // _CHUNK_BYTES
    pad = n_chunks * _CHUNK_BYTES - n
    if pad:
        packed = np.concatenate([packed, np.zeros(pad, dtype=np.uint8)])
    chunks = packed.reshape(n_chunks, _CHUNK_BYTES)
    all_zero = (chunks == 0x00).all(axis=1)
    all_one = (chunks == 0xFF).all(axis=1)
    flags = np.full(n_chunks, _FLAG_MIXED, dtype=np.uint8)
    flags[all_zero] = _FLAG_ZERO
    flags[all_one] = _FLAG_ONE
    mixed = chunks[flags == _FLAG_MIXED]
    # pack 2-bit flags, 4 per byte
    fpad = (-len(flags)) % 4
    fl = np.concatenate([flags, np.zeros(fpad, dtype=np.uint8)]).reshape(-1, 4)
    fpacked = (fl[:, 0] | (fl[:, 1] << 2) | (fl[:, 2] << 4) | (fl[:, 3] << 6))
    zmixed = zlib.compress(mixed.tobytes(), _ZLEVEL)
    head = struct.pack("<II", int(bits.size), int(mixed.shape[0]))
    return head + fpacked.astype(np.uint8).tobytes() + zmixed


def prescan_decode_bitmap(blob: bytes) -> np.ndarray:
    n_bits, n_mixed = struct.unpack_from("<II", blob, 0)
    n_bytes = (n_bits + 7) // 8
    n_chunks = (n_bytes + _CHUNK_BYTES - 1) // _CHUNK_BYTES
    f_len = (n_chunks + 3) // 4
    off = 8
    fpacked = np.frombuffer(blob, dtype=np.uint8, count=f_len, offset=off)
    off += f_len
    flags = np.empty(n_chunks, dtype=np.uint8)
    idx = np.arange(n_chunks)
    flags[:] = (fpacked[idx // 4] >> (2 * (idx % 4))) & 0x3
    mixed = np.frombuffer(zlib.decompress(blob[off:]), dtype=np.uint8)
    mixed = mixed.reshape(n_mixed, _CHUNK_BYTES) if n_mixed else \
        mixed.reshape(0, _CHUNK_BYTES)
    chunks = np.zeros((n_chunks, _CHUNK_BYTES), dtype=np.uint8)
    chunks[flags == _FLAG_ONE] = 0xFF
    chunks[flags == _FLAG_MIXED] = mixed
    packed = chunks.reshape(-1)[:n_bytes]
    return np.unpackbits(packed, count=n_bits).astype(bool)


@dataclass(frozen=True)
class CompressedBlock:
    """One compressed SV block, ready for the two-level store."""

    payload: bytes
    n_amps: int  # complex amplitudes in the block

    @property
    def nbytes(self) -> int:
        return len(self.payload)

    @property
    def raw_nbytes(self) -> int:
        return self.n_amps * 8  # complex64

    @property
    def ratio(self) -> float:
        return self.raw_nbytes / max(1, self.nbytes)


def _encode_plane(x: np.ndarray, params: PwRelParams,
                  prescan: bool) -> tuple[bytes, float]:
    codes, signs, l_max = quantize_plane(x, params)
    codes_b = zlib.compress(np.asarray(codes, dtype=np.uint16).tobytes(), _ZLEVEL)
    signs_np = np.asarray(signs)
    if prescan:
        bitmap_b = prescan_encode_bitmap(signs_np)
    else:
        bitmap_b = zlib.compress(np.packbits(signs_np).tobytes(), _ZLEVEL)
    seg = struct.pack("<fII", float(l_max), len(codes_b), len(bitmap_b))
    return seg + codes_b + bitmap_b, float(l_max)


def _decode_plane(blob: bytes, off: int, n: int, params: PwRelParams,
                  prescan: bool) -> tuple[np.ndarray, int]:
    l_max, len_codes, len_bitmap = struct.unpack_from("<fII", blob, off)
    off += 12
    codes = np.frombuffer(zlib.decompress(blob[off:off + len_codes]),
                          dtype=np.uint16)
    off += len_codes
    braw = blob[off:off + len_bitmap]
    off += len_bitmap
    if prescan:
        signs = prescan_decode_bitmap(braw)
    else:
        signs = np.unpackbits(
            np.frombuffer(zlib.decompress(braw), dtype=np.uint8), count=n
        ).astype(bool)
    plane = np.asarray(dequantize_plane(codes, signs, l_max, params))
    return plane, off


def compress_complex_block(amps: np.ndarray, params: PwRelParams,
                           prescan: bool = True) -> CompressedBlock:
    """complex64 block -> CompressedBlock (pwrel payload or RAW escape)."""
    amps = np.asarray(amps, dtype=np.complex64).reshape(-1)
    n = amps.size
    re_b, _ = _encode_plane(amps.real.copy(), params, prescan)
    im_b, _ = _encode_plane(amps.imag.copy(), params, prescan)
    head = struct.pack("<BBHI", _FMT_PWREL, int(prescan), 0, n)
    payload = head + re_b + im_b
    raw = amps.tobytes()
    if len(payload) >= len(raw) + 8:
        payload = struct.pack("<BBHI", _FMT_RAW, 0, 0, n) + raw
    return CompressedBlock(payload=payload, n_amps=n)


def decompress_complex_block(block: CompressedBlock | bytes,
                             params: PwRelParams) -> np.ndarray:
    blob = block.payload if isinstance(block, CompressedBlock) else block
    fmt, prescan, _, n = struct.unpack_from("<BBHI", blob, 0)
    off = 8
    if fmt == _FMT_RAW:
        return np.frombuffer(blob, dtype=np.complex64, count=n, offset=off).copy()
    re, off = _decode_plane(blob, off, n, params, bool(prescan))
    im, off = _decode_plane(blob, off, n, params, bool(prescan))
    return (re + 1j * im).astype(np.complex64)
