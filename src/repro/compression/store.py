"""Two-level block store (paper §4.4).

Compressed SV block sizes are unpredictable (variable-ratio compression),
so the simulation needs a memory manager that (1) tracks the actual bytes
held in the primary tier and (2) spills overflow to a secondary tier so a
run never aborts mid-circuit.  On the paper's machines the tiers are
CPU-RAM -> SSD via GPUDirect Storage; here they are a RAM dict -> disk
files (the data plane stays framework-agnostic bytes).

Extras matching the paper:
* ``put_alias`` — the §4.2 initialization trick: all-zero blocks are stored
  once and aliased (refcounted), so initial compression is O(1) not O(2^c).
* peak statistics for the memory benchmarks (Fig. 9).

Keys map to refcounted internal blobs, so overwriting a key never disturbs
other keys aliased to the same blob.
"""
from __future__ import annotations

import itertools
import os
import tempfile
from dataclasses import dataclass


@dataclass
class StoreStats:
    ram_bytes: int = 0
    disk_bytes: int = 0
    peak_ram_bytes: int = 0
    peak_total_bytes: int = 0
    n_spills: int = 0
    n_disk_reads: int = 0
    puts: int = 0
    gets: int = 0

    def observe(self) -> None:
        self.peak_ram_bytes = max(self.peak_ram_bytes, self.ram_bytes)
        self.peak_total_bytes = max(self.peak_total_bytes,
                                    self.ram_bytes + self.disk_bytes)


class BlockStore:
    """Key -> bytes store with a RAM budget and a disk spill tier."""

    def __init__(self, ram_budget_bytes: int | None = None,
                 spill_dir: str | None = None):
        self.ram_budget = ram_budget_bytes
        self._key2blob: dict[int, int] = {}
        self._refs: dict[int, int] = {}        # blob id -> refcount
        self._ram: dict[int, bytes] = {}       # blob id -> bytes
        self._disk: dict[int, str] = {}        # blob id -> path
        self._ids = itertools.count()
        self._spill_dir = spill_dir
        self._tmp: tempfile.TemporaryDirectory | None = None
        self.stats = StoreStats()

    # -- tier plumbing ---------------------------------------------------------
    def _spill_path(self, blob_id: int) -> str:
        if self._spill_dir is None:
            if self._tmp is None:
                self._tmp = tempfile.TemporaryDirectory(prefix="bmqsim_spill_")
            self._spill_dir = self._tmp.name
        return os.path.join(self._spill_dir, f"blob_{blob_id}.bin")

    def _fits_ram(self, nbytes: int) -> bool:
        if self.ram_budget is None:
            return True
        return self.stats.ram_bytes + nbytes <= self.ram_budget

    def _store_blob(self, blob: bytes) -> int:
        bid = next(self._ids)
        self._refs[bid] = 0
        if self._fits_ram(len(blob)):
            self._ram[bid] = blob
            self.stats.ram_bytes += len(blob)
        else:
            path = self._spill_path(bid)
            with open(path, "wb") as f:
                f.write(blob)
            self._disk[bid] = path
            self.stats.disk_bytes += len(blob)
            self.stats.n_spills += 1
        self.stats.observe()
        return bid

    def _release_blob(self, bid: int) -> None:
        self._refs[bid] -= 1
        if self._refs[bid] > 0:
            return
        del self._refs[bid]
        if bid in self._ram:
            self.stats.ram_bytes -= len(self._ram.pop(bid))
        else:
            path = self._disk.pop(bid)
            self.stats.disk_bytes -= os.path.getsize(path)
            os.unlink(path)

    def _bind(self, key: int, bid: int) -> None:
        old = self._key2blob.get(key)
        self._key2blob[key] = bid
        self._refs[bid] += 1
        if old is not None:
            self._release_blob(old)

    # -- public API ------------------------------------------------------------
    def put(self, key: int, blob: bytes) -> None:
        self.stats.puts += 1
        self._bind(key, self._store_blob(blob))

    def put_alias(self, key: int, existing_key: int) -> None:
        """Point ``key`` at the blob of ``existing_key`` (zero-copy)."""
        self._bind(key, self._key2blob[existing_key])

    def get(self, key: int) -> bytes:
        self.stats.gets += 1
        bid = self._key2blob[key]
        if bid in self._ram:
            return self._ram[bid]
        self.stats.n_disk_reads += 1
        with open(self._disk[bid], "rb") as f:
            return f.read()

    def __contains__(self, key: int) -> bool:
        return key in self._key2blob

    def nbytes_of(self, key: int) -> int:
        bid = self._key2blob[key]
        if bid in self._ram:
            return len(self._ram[bid])
        return os.path.getsize(self._disk[bid])

    def delete(self, key: int) -> None:
        bid = self._key2blob.pop(key, None)
        if bid is not None:
            self._release_blob(bid)

    @property
    def total_bytes(self) -> int:
        return self.stats.ram_bytes + self.stats.disk_bytes

    def keys(self):
        return sorted(self._key2blob)

    def close(self) -> None:
        if self._tmp is not None:
            self._tmp.cleanup()
            self._tmp = None
