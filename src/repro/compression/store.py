"""Two-level block store (paper §4.4).

Compressed SV block sizes are unpredictable (variable-ratio compression),
so the simulation needs a memory manager that (1) tracks the actual bytes
held in the primary tier and (2) spills overflow to a secondary tier so a
run never aborts mid-circuit.  On the paper's machines the tiers are
CPU-RAM -> SSD via GPUDirect Storage; here they are a RAM dict -> disk
files (the data plane stays framework-agnostic bytes).

Extras matching the paper:
* ``put_alias`` — the §4.2 initialization trick: all-zero blocks are stored
  once and aliased (refcounted), so initial compression is O(1) not O(2^c).
* peak statistics for the memory benchmarks (Fig. 9).
* structured blocks — ``put_block`` / ``get_block`` store a
  :class:`~repro.compression.segments.BlockSegments` *as an object* in the
  RAM tier (no serialize/parse on the hot path; the pipeline reaches its
  ``codes`` / ``bitmap`` / ``l_max`` segments directly) and serialize it
  only when it spills to disk.

Keys map to refcounted internal blobs, so overwriting a key never disturbs
other keys aliased to the same blob.

Resilience (the paper's "dedicated error control" / stable-execution
claim, §4.4):

* **Integrity** — every blob that takes serialized form gets a crc32
  content checksum: opaque ``bytes`` at ``put`` time, structured blocks
  when they serialize to spill.  Every disk-tier read and every snapshot
  restore verifies it; a mismatch raises
  :class:`~repro.errors.BlockCorruptionError` — corrupted data is
  *detected*, never silently decoded.  (RAM-resident structured blocks
  are never serialized, so the hot path pays nothing.)
* **Transient-fault tolerance** — spill and snapshot I/O retries with
  exponential backoff (``io_retries`` / ``io_backoff_s``); exhausted
  retries raise a typed :class:`~repro.errors.StoreIOError` naming the
  operation, key/blob and path instead of leaking a raw ``OSError`` out
  of a worker thread.
* **Durable snapshots** — :meth:`snapshot` fsyncs the temp file (and its
  parent directory) before the atomic rename, stamps per-blob digests in
  the header, and :meth:`restore` validates the total file length against
  ``blob_sizes`` so a truncated/torn checkpoint raises a clear
  :class:`~repro.errors.CheckpointError` instead of failing deep in
  decode.
* **Pressure relief** — :meth:`spill` proactively moves RAM-tier blobs
  to disk (the degradation ladder's third rung), and
  :meth:`load_snapshot` reloads a snapshot *into* an existing store
  in place (the engine's replay-from-checkpoint path).
"""
from __future__ import annotations

import itertools
import json
import os
import struct
import tempfile
import threading
import time
import zlib
from dataclasses import dataclass

from ..errors import BlockCorruptionError, CheckpointError, StoreIOError
from ..faults import fault_point
from .segments import BlockSegments

_SNAP_MAGIC = b"BMQSNAP1"
_SNAP_HEAD = struct.Struct("<Q")   # header JSON length


@dataclass
class StoreStats:
    ram_bytes: int = 0
    disk_bytes: int = 0
    peak_ram_bytes: int = 0
    peak_total_bytes: int = 0
    n_spills: int = 0
    n_disk_reads: int = 0
    puts: int = 0
    gets: int = 0
    #: transient I/O errors absorbed by retry-with-backoff
    n_io_retries: int = 0
    #: blobs moved RAM -> disk by an explicit spill() call (pressure rung)
    n_proactive_spills: int = 0
    #: checksum mismatches detected (each raised a BlockCorruptionError)
    n_corruptions_detected: int = 0

    def observe(self) -> None:
        self.peak_ram_bytes = max(self.peak_ram_bytes, self.ram_bytes)
        self.peak_total_bytes = max(self.peak_total_bytes,
                                    self.ram_bytes + self.disk_bytes)


def _blob_nbytes(blob) -> int:
    return len(blob) if isinstance(blob, (bytes, bytearray)) else blob.nbytes


def _blob_bytes(blob) -> bytes:
    return blob if isinstance(blob, (bytes, bytearray)) else blob.to_bytes()


class BlockStore:
    """Key -> block store with a RAM budget and a disk spill tier.

    Values are either opaque ``bytes`` (``put`` / ``get``) or structured
    :class:`BlockSegments` (``put_block`` / ``get_block``); the two views
    are interchangeable — a spilled structured block deserializes on read,
    and ``get_block`` on a byte blob parses the self-describing layout.

    Args:
        ram_budget_bytes: primary-tier byte budget (None = unbounded).
        spill_dir: secondary-tier directory (default: a temp dir).
        checksums: stamp/verify crc32 content checksums on serialized
            blobs (disk tier + snapshots).  Default on; the guardrail
            overhead is benchmarked in ``bench_pipeline``.
        io_retries: bounded retries of a failed spill/snapshot I/O op.
        io_backoff_s: initial backoff between retries (doubles per try).
    """

    def __init__(self, ram_budget_bytes: int | None = None,
                 spill_dir: str | None = None, *,
                 checksums: bool = True, io_retries: int = 3,
                 io_backoff_s: float = 0.01):
        self.ram_budget = ram_budget_bytes
        self.checksums = checksums
        self.io_retries = io_retries
        self.io_backoff_s = io_backoff_s
        # blob maps (_refs: id->refcount, _ram: id->blob, _disk:
        # id->path, _crc: id->crc32 of serialized bytes).  The pipeline
        # worker pools hit the store from several threads at once, so
        # every field marked guarded-by below may only be touched inside
        # 'with self._lock:' (enforced by the lock-discipline checker).
        self._key2blob: dict[int, int] = {}    # guarded-by: _lock
        self._refs: dict[int, int] = {}        # guarded-by: _lock
        self._ram: dict[int, bytes] = {}       # guarded-by: _lock
        self._disk: dict[int, str] = {}        # guarded-by: _lock
        self._crc: dict[int, int] = {}         # guarded-by: _lock
        self._ids = itertools.count()          # guarded-by: _lock
        self._spill_dir = spill_dir
        self._tmp: tempfile.TemporaryDirectory | None = None
        self._lock = threading.RLock()
        self.stats = StoreStats()              # guarded-by: _lock

    # -- tier plumbing ---------------------------------------------------------
    def _spill_path(self, blob_id: int) -> str:
        if self._spill_dir is None:
            if self._tmp is None:
                self._tmp = tempfile.TemporaryDirectory(prefix="bmqsim_spill_")
            self._spill_dir = self._tmp.name
        return os.path.join(self._spill_dir, f"blob_{blob_id}.bin")

    def _fits_ram(self, nbytes: int) -> bool:  # holds-lock: _lock
        if self.ram_budget is None:
            return True
        return self.stats.ram_bytes + nbytes <= self.ram_budget

    def _with_retries(self, op, opname: str, *, key=None, bid=None,
                      path=None, fnf_is_signal: bool = False):
        """Run ``op`` with bounded exponential-backoff retries on
        ``OSError``; exhausted retries raise a typed
        :class:`StoreIOError` naming the operation and blob.

        ``fnf_is_signal`` passes ``FileNotFoundError`` through untouched
        — on the read path it means the key was rebound mid-read (a
        normal race the caller resolves under the lock), not a fault.
        """
        delay = self.io_backoff_s
        last: OSError | None = None
        for attempt in range(self.io_retries + 1):
            try:
                return op()
            except FileNotFoundError:
                if fnf_is_signal:
                    raise
                raise StoreIOError(opname, key=key, blob_id=bid, path=path,
                                   retries=attempt) from None
            except StoreIOError:
                raise
            except OSError as e:
                last = e
                if attempt < self.io_retries:
                    with self._lock:
                        self.stats.n_io_retries += 1
                    time.sleep(delay)
                    delay *= 2
        raise StoreIOError(opname, key=key, blob_id=bid, path=path,
                           retries=self.io_retries) from last

    def _write_spill(self, path: str, data: bytes, *, key=None,
                     bid=None) -> None:
        """One spill-tier file write: fault-injectable, retried, typed."""
        def op():
            payload = fault_point("store.spill_write", data)
            with open(path, "wb") as f:
                f.write(payload)
        self._with_retries(op, "spill write", key=key, bid=bid, path=path)

    def _read_spill(self, path: str, *, key=None, bid=None) -> bytes:
        """One spill-tier file read: fault-injectable, retried, verified."""
        def op():
            with open(path, "rb") as f:
                raw = f.read()
            return fault_point("store.spill_read", raw)
        data = self._with_retries(op, "spill read", key=key, bid=bid,
                                  path=path, fnf_is_signal=True)
        self._verify(data, bid, key=key, path=path, where="spill read")
        return data

    def _verify(self, data: bytes, bid, *, key=None, path=None,
                where: str) -> None:
        if not self.checksums or bid is None:
            return
        with self._lock:
            expected = self._crc.get(bid)
        if expected is None:
            return
        actual = zlib.crc32(data)
        if actual != expected:
            with self._lock:
                self.stats.n_corruptions_detected += 1
            raise BlockCorruptionError(where, key=key, blob_id=bid,
                                       path=path, expected_crc=expected,
                                       actual_crc=actual)

    def _put(self, key: int, blob) -> None:
        """Bind ``key`` to a fresh blob; disk writes happen outside the
        lock (the new blob id is invisible to readers until ``_bind``)."""
        nbytes = _blob_nbytes(blob)
        # opaque bytes are checksummed at put time; structured blocks
        # only when they serialize (spill/snapshot) — the RAM tier keeps
        # the object, so there is nothing byte-stable to stamp yet
        crc = (zlib.crc32(blob) if self.checksums
               and isinstance(blob, (bytes, bytearray)) else None)
        with self._lock:
            self.stats.puts += 1
            bid = next(self._ids)
            self._refs[bid] = 0
            if crc is not None:
                self._crc[bid] = crc
            if self._fits_ram(nbytes):
                self._ram[bid] = blob
                self.stats.ram_bytes += nbytes
                self.stats.observe()
                self._bind(key, bid)
                return
            path = self._spill_path(bid)
        data = _blob_bytes(blob)
        if self.checksums and crc is None:
            crc = zlib.crc32(data)
        self._write_spill(path, data, key=key, bid=bid)
        with self._lock:
            if crc is not None:
                self._crc[bid] = crc
            self._disk[bid] = path
            self.stats.disk_bytes += nbytes
            self.stats.n_spills += 1
            self.stats.observe()
            self._bind(key, bid)

    def _release_blob(self, bid: int) -> None:  # holds-lock: _lock
        self._refs[bid] -= 1
        if self._refs[bid] > 0:
            return
        del self._refs[bid]
        self._crc.pop(bid, None)
        if bid in self._ram:
            self.stats.ram_bytes -= _blob_nbytes(self._ram.pop(bid))
        else:
            path = self._disk.pop(bid)
            self.stats.disk_bytes -= os.path.getsize(path)
            os.unlink(path)

    def _bind(self, key: int, bid: int) -> None:  # holds-lock: _lock
        old = self._key2blob.get(key)
        self._key2blob[key] = bid
        self._refs[bid] += 1
        if old is not None:
            self._release_blob(old)

    # -- public API ------------------------------------------------------------
    def put(self, key: int, blob: bytes) -> None:
        """Store opaque bytes under ``key`` (raw/uncompressed block path)."""
        self._put(key, blob)

    def put_block(self, key: int, seg: BlockSegments) -> None:
        """Store a structured compressed block under ``key``.

        The RAM tier keeps the :class:`BlockSegments` object itself;
        serialization happens only if the block spills to disk.
        """
        self._put(key, seg)

    def put_alias(self, key: int, existing_key: int) -> None:
        """Point ``key`` at the blob of ``existing_key`` (zero-copy)."""
        with self._lock:
            self._bind(key, self._key2blob[existing_key])

    def _fetch(self, key: int):
        with self._lock:
            self.stats.gets += 1
            bid = self._key2blob[key]
            blob = self._ram.get(bid)
            if blob is not None:
                return blob
            self.stats.n_disk_reads += 1
            path = self._disk[bid]
        try:
            # disk read outside the lock so concurrent workers overlap I/O
            return self._read_spill(path, key=key, bid=bid)
        except FileNotFoundError:
            # the key was rebound and its old blob released mid-read —
            # retry under the lock for a consistent snapshot
            with self._lock:
                bid = self._key2blob[key]
                blob = self._ram.get(bid)
                if blob is not None:
                    return blob
                path = self._disk[bid]
                try:
                    return self._read_spill(path, key=key, bid=bid)
                except FileNotFoundError as e:
                    # still bound to this blob and still missing: the
                    # file is genuinely gone, not a rebind race
                    raise StoreIOError("spill read", key=key, blob_id=bid,
                                       path=path,
                                       detail="blob file missing") from e

    def get(self, key: int) -> bytes:
        """Fetch ``key`` as flat bytes (serializing a structured block)."""
        return _blob_bytes(self._fetch(key))

    def get_block(self, key: int) -> BlockSegments:
        """Fetch ``key`` as structured segments (parsing a byte blob)."""
        blob = self._fetch(key)
        if isinstance(blob, BlockSegments):
            return blob
        return BlockSegments.from_bytes(blob)

    def __contains__(self, key: int) -> bool:
        with self._lock:
            return key in self._key2blob

    def nbytes_of(self, key: int) -> int:
        with self._lock:
            bid = self._key2blob[key]
            if bid in self._ram:
                return _blob_nbytes(self._ram[bid])
            return os.path.getsize(self._disk[bid])

    def delete(self, key: int) -> None:
        with self._lock:
            bid = self._key2blob.pop(key, None)
            if bid is not None:
                self._release_blob(bid)

    @property
    def total_bytes(self) -> int:
        with self._lock:
            return self.stats.ram_bytes + self.stats.disk_bytes

    def keys(self):
        with self._lock:
            return sorted(self._key2blob)

    # -- pressure relief -------------------------------------------------------
    def spill(self, target_ram_bytes: int) -> int:
        """Proactively move RAM-tier blobs to disk (largest first) until
        ``ram_bytes <= target_ram_bytes``; returns blobs moved.

        The degradation ladder's third rung
        (:class:`~repro.core.pressure.PressureMonitor`): called between
        stages, when no pipeline workers are mid-flight, so the move
        happens under the lock without racing readers.
        """
        moved = 0
        with self._lock:
            if self.stats.ram_bytes <= target_ram_bytes:
                return 0
            order = sorted(self._ram.items(),
                           key=lambda kv: -_blob_nbytes(kv[1]))
            for bid, blob in order:
                if self.stats.ram_bytes <= target_ram_bytes:
                    break
                data = _blob_bytes(blob)
                path = self._spill_path(bid)
                self._write_spill(path, data, bid=bid)
                if self.checksums:
                    self._crc[bid] = zlib.crc32(data)
                nbytes = _blob_nbytes(blob)
                del self._ram[bid]
                self._disk[bid] = path
                self.stats.ram_bytes -= nbytes
                self.stats.disk_bytes += len(data)
                self.stats.n_spills += 1
                self.stats.n_proactive_spills += 1
                moved += 1
            self.stats.observe()
        return moved

    # -- checkpointing ---------------------------------------------------------
    def snapshot(self, path: str, meta: dict | None = None) -> None:
        """Serialize every key to one checkpoint file (atomic + durable).

        Alias structure is preserved: keys sharing a blob (the §4.2
        zero-block trick) serialize the blob once and restore shared.
        ``meta`` is an opaque caller dict (the engine's layout/codec
        manifest) stored alongside and handed back by :meth:`restore`.

        Durability: the temp file is flushed + fsynced, atomically
        renamed over ``path``, and the parent directory fsynced — a
        crash mid-checkpoint leaves either the old complete file or the
        new complete file, never a torn one.  The header carries
        per-blob crc32 digests (``blob_crc``) that :meth:`restore`
        verifies.
        """
        with self._lock:
            key2blob = dict(self._key2blob)
        blob_order: list[int] = []
        blob_pos: dict[int, int] = {}
        keys = []
        for key in sorted(key2blob):
            bid = key2blob[key]
            if bid not in blob_pos:
                blob_pos[bid] = len(blob_order)
                blob_order.append(bid)
            keys.append([key, blob_pos[bid]])
        blobs: list[bytes] = []
        for bid in blob_order:
            with self._lock:
                blob = self._ram.get(bid)
                disk_path = None if blob is not None else self._disk[bid]
            if blob is not None:
                blobs.append(_blob_bytes(blob))
            else:
                blobs.append(self._read_spill(disk_path, bid=bid))
        header = json.dumps({
            "meta": meta or {},
            "keys": keys,
            "blob_sizes": [len(b) for b in blobs],
            "blob_crc": [zlib.crc32(b) for b in blobs],
        }).encode()
        tmp = path + ".tmp"

        def op():
            fault_point("checkpoint.write")
            with open(tmp, "wb") as f:
                f.write(_SNAP_MAGIC)
                f.write(_SNAP_HEAD.pack(len(header)))
                f.write(header)
                for b in blobs:
                    f.write(b)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
            # fsync the parent directory so the rename itself is durable
            dfd = os.open(os.path.dirname(os.path.abspath(path)),
                          os.O_RDONLY)
            try:
                os.fsync(dfd)
            finally:
                os.close(dfd)
        try:
            self._with_retries(op, "snapshot", path=path)
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise

    @staticmethod
    def _read_snapshot(path: str) -> tuple[dict, list[bytes]]:
        """Parse + validate a snapshot file -> (header, blobs).

        Structural validation happens BEFORE any blob is decoded: bad
        magic or a file length inconsistent with ``blob_sizes`` raises
        :class:`CheckpointError`; a per-blob digest mismatch raises
        :class:`BlockCorruptionError` naming the blob index.  The raw
        read is the ``checkpoint.read`` injection point; I/O failures
        other than a missing file (the caller's "no checkpoint yet"
        signal) surface as :class:`StoreIOError`.
        """
        try:
            fault_point("checkpoint.read")
            file_len = os.path.getsize(path)
            with open(path, "rb") as f:
                magic = f.read(len(_SNAP_MAGIC))
                if magic != _SNAP_MAGIC:
                    raise CheckpointError(f"{path}: not a BMQSIM checkpoint "
                                          f"(bad magic {magic!r})")
                (hlen,) = _SNAP_HEAD.unpack(f.read(_SNAP_HEAD.size))
                head_raw = f.read(hlen)
                if len(head_raw) < hlen:
                    raise CheckpointError(
                        f"{path}: truncated checkpoint (header cut short: "
                        f"{len(head_raw)}/{hlen} bytes)")
                try:
                    header = json.loads(head_raw.decode())
                except (UnicodeDecodeError, json.JSONDecodeError) as e:
                    raise CheckpointError(
                        f"{path}: corrupt checkpoint header ({e})") from e
                sizes = header["blob_sizes"]
                expected_len = (len(_SNAP_MAGIC) + _SNAP_HEAD.size + hlen
                                + sum(sizes))
                if file_len != expected_len:
                    raise CheckpointError(
                        f"{path}: truncated/torn checkpoint — file is "
                        f"{file_len} bytes but header promises "
                        f"{expected_len} ({len(sizes)} blobs totaling "
                        f"{sum(sizes)} bytes)")
                blobs = [f.read(sz) for sz in sizes]
        except FileNotFoundError:
            raise
        except OSError as e:
            raise StoreIOError("checkpoint read", path=path) from e
        for i, (blob, sz) in enumerate(zip(blobs, sizes)):
            if len(blob) != sz:
                raise CheckpointError(
                    f"{path}: truncated checkpoint (blob {i}: "
                    f"{len(blob)}/{sz} bytes)")
        crcs = header.get("blob_crc")
        if crcs is not None:      # pre-resilience snapshots lack digests
            for i, (blob, crc) in enumerate(zip(blobs, crcs)):
                actual = zlib.crc32(blob)
                if actual != crc:
                    raise BlockCorruptionError(
                        f"snapshot restore ({path}, blob {i})",
                        blob_id=i, path=path, expected_crc=crc,
                        actual_crc=actual)
        return header, blobs

    @classmethod
    def restore(cls, path: str, ram_budget_bytes: int | None = None,
                spill_dir: str | None = None) -> tuple["BlockStore", dict]:
        """Rebuild a store from a :meth:`snapshot` file -> (store, meta).

        Blobs land in the RAM tier as serialized bytes (``get_block``
        re-parses structured blocks lazily); the usual budget/spill rules
        apply, so a snapshot larger than ``ram_budget_bytes`` restores
        with overflow on the disk tier.  Every blob's stored digest is
        verified first.
        """
        header, blobs = cls._read_snapshot(path)
        store = cls(ram_budget_bytes=ram_budget_bytes, spill_dir=spill_dir)
        store._load_keys(header, blobs)
        return store, header["meta"]

    def load_snapshot(self, path: str) -> dict:
        """Reload a snapshot *into this store*, replacing every current
        key -> the snapshot's meta dict.

        The engine's replay-from-checkpoint path: on a detected
        corruption mid-run, the simulator rewinds the live store to the
        last checkpoint without rebuilding the session (backend/engine
        references to this store stay valid).
        """
        header, blobs = self._read_snapshot(path)
        with self._lock:
            for key in list(self._key2blob):
                self.delete(key)
            self._load_keys(header, blobs)
        return header["meta"]

    def _load_keys(self, header: dict, blobs: list[bytes]) -> None:
        first_key: dict[int, int] = {}
        for key, blob_idx in header["keys"]:
            if blob_idx in first_key:
                self.put_alias(key, first_key[blob_idx])
            else:
                self.put(key, blobs[blob_idx])
                first_key[blob_idx] = key

    def close(self) -> None:
        if self._tmp is not None:
            self._tmp.cleanup()
            self._tmp = None
