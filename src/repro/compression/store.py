"""Two-level block store (paper §4.4).

Compressed SV block sizes are unpredictable (variable-ratio compression),
so the simulation needs a memory manager that (1) tracks the actual bytes
held in the primary tier and (2) spills overflow to a secondary tier so a
run never aborts mid-circuit.  On the paper's machines the tiers are
CPU-RAM -> SSD via GPUDirect Storage; here they are a RAM dict -> disk
files (the data plane stays framework-agnostic bytes).

Extras matching the paper:
* ``put_alias`` — the §4.2 initialization trick: all-zero blocks are stored
  once and aliased (refcounted), so initial compression is O(1) not O(2^c).
* peak statistics for the memory benchmarks (Fig. 9).
* structured blocks — ``put_block`` / ``get_block`` store a
  :class:`~repro.compression.segments.BlockSegments` *as an object* in the
  RAM tier (no serialize/parse on the hot path; the pipeline reaches its
  ``codes`` / ``bitmap`` / ``l_max`` segments directly) and serialize it
  only when it spills to disk.

Keys map to refcounted internal blobs, so overwriting a key never disturbs
other keys aliased to the same blob.
"""
from __future__ import annotations

import itertools
import json
import os
import struct
import tempfile
import threading
from dataclasses import dataclass

from .segments import BlockSegments

_SNAP_MAGIC = b"BMQSNAP1"
_SNAP_HEAD = struct.Struct("<Q")   # header JSON length


@dataclass
class StoreStats:
    ram_bytes: int = 0
    disk_bytes: int = 0
    peak_ram_bytes: int = 0
    peak_total_bytes: int = 0
    n_spills: int = 0
    n_disk_reads: int = 0
    puts: int = 0
    gets: int = 0

    def observe(self) -> None:
        self.peak_ram_bytes = max(self.peak_ram_bytes, self.ram_bytes)
        self.peak_total_bytes = max(self.peak_total_bytes,
                                    self.ram_bytes + self.disk_bytes)


def _blob_nbytes(blob) -> int:
    return len(blob) if isinstance(blob, (bytes, bytearray)) else blob.nbytes


def _blob_bytes(blob) -> bytes:
    return blob if isinstance(blob, (bytes, bytearray)) else blob.to_bytes()


class BlockStore:
    """Key -> block store with a RAM budget and a disk spill tier.

    Values are either opaque ``bytes`` (``put`` / ``get``) or structured
    :class:`BlockSegments` (``put_block`` / ``get_block``); the two views
    are interchangeable — a spilled structured block deserializes on read,
    and ``get_block`` on a byte blob parses the self-describing layout.
    """

    def __init__(self, ram_budget_bytes: int | None = None,
                 spill_dir: str | None = None):
        self.ram_budget = ram_budget_bytes
        self._key2blob: dict[int, int] = {}
        self._refs: dict[int, int] = {}        # blob id -> refcount
        self._ram: dict[int, bytes] = {}       # blob id -> bytes
        self._disk: dict[int, str] = {}        # blob id -> path
        self._ids = itertools.count()
        self._spill_dir = spill_dir
        self._tmp: tempfile.TemporaryDirectory | None = None
        self._lock = threading.RLock()   # pipeline pools hit the store
        self.stats = StoreStats()        # from both sides concurrently

    # -- tier plumbing ---------------------------------------------------------
    def _spill_path(self, blob_id: int) -> str:
        if self._spill_dir is None:
            if self._tmp is None:
                self._tmp = tempfile.TemporaryDirectory(prefix="bmqsim_spill_")
            self._spill_dir = self._tmp.name
        return os.path.join(self._spill_dir, f"blob_{blob_id}.bin")

    def _fits_ram(self, nbytes: int) -> bool:
        if self.ram_budget is None:
            return True
        return self.stats.ram_bytes + nbytes <= self.ram_budget

    def _put(self, key: int, blob) -> None:
        """Bind ``key`` to a fresh blob; disk writes happen outside the
        lock (the new blob id is invisible to readers until ``_bind``)."""
        nbytes = _blob_nbytes(blob)
        with self._lock:
            self.stats.puts += 1
            bid = next(self._ids)
            self._refs[bid] = 0
            if self._fits_ram(nbytes):
                self._ram[bid] = blob
                self.stats.ram_bytes += nbytes
                self.stats.observe()
                self._bind(key, bid)
                return
            path = self._spill_path(bid)
        with open(path, "wb") as f:
            f.write(_blob_bytes(blob))
        with self._lock:
            self._disk[bid] = path
            self.stats.disk_bytes += nbytes
            self.stats.n_spills += 1
            self.stats.observe()
            self._bind(key, bid)

    def _release_blob(self, bid: int) -> None:
        self._refs[bid] -= 1
        if self._refs[bid] > 0:
            return
        del self._refs[bid]
        if bid in self._ram:
            self.stats.ram_bytes -= _blob_nbytes(self._ram.pop(bid))
        else:
            path = self._disk.pop(bid)
            self.stats.disk_bytes -= os.path.getsize(path)
            os.unlink(path)

    def _bind(self, key: int, bid: int) -> None:
        old = self._key2blob.get(key)
        self._key2blob[key] = bid
        self._refs[bid] += 1
        if old is not None:
            self._release_blob(old)

    # -- public API ------------------------------------------------------------
    def put(self, key: int, blob: bytes) -> None:
        """Store opaque bytes under ``key`` (raw/uncompressed block path)."""
        self._put(key, blob)

    def put_block(self, key: int, seg: BlockSegments) -> None:
        """Store a structured compressed block under ``key``.

        The RAM tier keeps the :class:`BlockSegments` object itself;
        serialization happens only if the block spills to disk.
        """
        self._put(key, seg)

    def put_alias(self, key: int, existing_key: int) -> None:
        """Point ``key`` at the blob of ``existing_key`` (zero-copy)."""
        with self._lock:
            self._bind(key, self._key2blob[existing_key])

    def _fetch(self, key: int):
        with self._lock:
            self.stats.gets += 1
            bid = self._key2blob[key]
            blob = self._ram.get(bid)
            if blob is not None:
                return blob
            self.stats.n_disk_reads += 1
            path = self._disk[bid]
        try:
            # disk read outside the lock so concurrent workers overlap I/O
            with open(path, "rb") as f:
                return f.read()
        except FileNotFoundError:
            # the key was rebound and its old blob released mid-read —
            # retry under the lock for a consistent snapshot
            with self._lock:
                bid = self._key2blob[key]
                blob = self._ram.get(bid)
                if blob is not None:
                    return blob
                with open(self._disk[bid], "rb") as f:
                    return f.read()

    def get(self, key: int) -> bytes:
        """Fetch ``key`` as flat bytes (serializing a structured block)."""
        return _blob_bytes(self._fetch(key))

    def get_block(self, key: int) -> BlockSegments:
        """Fetch ``key`` as structured segments (parsing a byte blob)."""
        blob = self._fetch(key)
        if isinstance(blob, BlockSegments):
            return blob
        return BlockSegments.from_bytes(blob)

    def __contains__(self, key: int) -> bool:
        return key in self._key2blob

    def nbytes_of(self, key: int) -> int:
        with self._lock:
            bid = self._key2blob[key]
            if bid in self._ram:
                return _blob_nbytes(self._ram[bid])
            return os.path.getsize(self._disk[bid])

    def delete(self, key: int) -> None:
        with self._lock:
            bid = self._key2blob.pop(key, None)
            if bid is not None:
                self._release_blob(bid)

    @property
    def total_bytes(self) -> int:
        return self.stats.ram_bytes + self.stats.disk_bytes

    def keys(self):
        return sorted(self._key2blob)

    # -- checkpointing ---------------------------------------------------------
    def snapshot(self, path: str, meta: dict | None = None) -> None:
        """Serialize every key to one checkpoint file (atomic via rename).

        Alias structure is preserved: keys sharing a blob (the §4.2
        zero-block trick) serialize the blob once and restore shared.
        ``meta`` is an opaque caller dict (the engine's layout/codec
        manifest) stored alongside and handed back by :meth:`restore`.
        """
        with self._lock:
            key2blob = dict(self._key2blob)
        blob_order: list[int] = []
        blob_pos: dict[int, int] = {}
        keys = []
        for key in sorted(key2blob):
            bid = key2blob[key]
            if bid not in blob_pos:
                blob_pos[bid] = len(blob_order)
                blob_order.append(bid)
            keys.append([key, blob_pos[bid]])
        blobs: list[bytes] = []
        for bid in blob_order:
            with self._lock:
                blob = self._ram.get(bid)
                disk_path = None if blob is not None else self._disk[bid]
            if blob is not None:
                blobs.append(_blob_bytes(blob))
            else:
                with open(disk_path, "rb") as f:
                    blobs.append(f.read())
        header = json.dumps({
            "meta": meta or {},
            "keys": keys,
            "blob_sizes": [len(b) for b in blobs],
        }).encode()
        tmp = path + ".tmp"
        with open(tmp, "wb") as f:
            f.write(_SNAP_MAGIC)
            f.write(_SNAP_HEAD.pack(len(header)))
            f.write(header)
            for b in blobs:
                f.write(b)
        os.replace(tmp, path)

    @classmethod
    def restore(cls, path: str, ram_budget_bytes: int | None = None,
                spill_dir: str | None = None) -> tuple["BlockStore", dict]:
        """Rebuild a store from a :meth:`snapshot` file -> (store, meta).

        Blobs land in the RAM tier as serialized bytes (``get_block``
        re-parses structured blocks lazily); the usual budget/spill rules
        apply, so a snapshot larger than ``ram_budget_bytes`` restores
        with overflow on the disk tier.
        """
        with open(path, "rb") as f:
            magic = f.read(len(_SNAP_MAGIC))
            if magic != _SNAP_MAGIC:
                raise ValueError(f"{path}: not a BMQSIM checkpoint "
                                 f"(bad magic {magic!r})")
            (hlen,) = _SNAP_HEAD.unpack(f.read(_SNAP_HEAD.size))
            header = json.loads(f.read(hlen).decode())
            blobs = [f.read(sz) for sz in header["blob_sizes"]]
        store = cls(ram_budget_bytes=ram_budget_bytes, spill_dir=spill_dir)
        first_key: dict[int, int] = {}
        for key, blob_idx in header["keys"]:
            if blob_idx in first_key:
                store.put_alias(key, first_key[blob_idx])
            else:
                store.put(key, blobs[blob_idx])
                first_key[blob_idx] = key
        return store, header["meta"]

    def close(self) -> None:
        if self._tmp is not None:
            self._tmp.cleanup()
            self._tmp = None
