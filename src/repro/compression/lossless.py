"""Host-side lossless stage of the codec (paper §4.3 lines 15-17).

After the lossy half (device kernels or the ``pwrel`` reference) has turned
a plane into uint16 codes + a sign bitmap + ``l_max``, this module does the
part the paper keeps on the CPU, mirroring bitcomp's lossless stage:

* ``encode_codes`` / ``decode_codes`` — zlib the little-endian uint16 code
  stream (level 1, throughput-oriented).
* ``prescan_encode_bitmap`` / ``prescan_decode_bitmap`` — the bitmap
  *pre-scan*: split into chunks, drop all-0 / all-1 chunks (signs repeat
  over long ranges — the paper's warp-ballot observation), keep a 2-bit
  flag per chunk, zlib what remains.
Everything here is plain numpy + zlib and releases the GIL, so it runs in
the pipeline's worker threads.  (The device wire format's sign bytes are
LSB-first ``np.packbits(bitorder="little")`` layout — ``device_codec``
converts at the byte level directly.)
"""
from __future__ import annotations

import struct
import zlib

import numpy as np

__all__ = [
    "encode_codes", "decode_codes",
    "prescan_encode_bitmap", "prescan_decode_bitmap",
    "encode_bitmap", "decode_bitmap",
    "ZLEVEL",
]

_CHUNK_BYTES = 128          # bitmap pre-scan chunk = 1024 bits
ZLEVEL = 1                  # throughput-oriented, like bitcomp

_FLAG_ZERO, _FLAG_ONE, _FLAG_MIXED = 0, 1, 2


# --------------------------------------------------------------------------
# uint16 code streams
# --------------------------------------------------------------------------

def encode_codes(codes: np.ndarray) -> bytes:
    """uint16 code array -> zlib'd little-endian byte stream."""
    codes = np.ascontiguousarray(codes, dtype="<u2")
    return zlib.compress(codes.tobytes(), ZLEVEL)


def decode_codes(blob: bytes, n: int) -> np.ndarray:
    """Inverse of :func:`encode_codes`; returns exactly ``n`` uint16 codes."""
    return np.frombuffer(zlib.decompress(blob), dtype="<u2", count=n)


# --------------------------------------------------------------------------
# sign bitmaps
# --------------------------------------------------------------------------

def prescan_encode_bitmap(bits: np.ndarray) -> bytes:
    """Pack a bool array to bits, RLE away uniform chunks, zlib the rest.

    Layout: u32 n_bits | u32 n_mixed | flags(2b/chunk, packed) | z(mixed).
    """
    bits = np.asarray(bits, dtype=bool).reshape(-1)
    packed = np.packbits(bits)  # big-endian bit order within bytes
    n = packed.size
    n_chunks = (n + _CHUNK_BYTES - 1) // _CHUNK_BYTES
    pad = n_chunks * _CHUNK_BYTES - n
    if pad:
        packed = np.concatenate([packed, np.zeros(pad, dtype=np.uint8)])
    chunks = packed.reshape(n_chunks, _CHUNK_BYTES)
    all_zero = (chunks == 0x00).all(axis=1)
    all_one = (chunks == 0xFF).all(axis=1)
    flags = np.full(n_chunks, _FLAG_MIXED, dtype=np.uint8)
    flags[all_zero] = _FLAG_ZERO
    flags[all_one] = _FLAG_ONE
    mixed = chunks[flags == _FLAG_MIXED]
    # pack 2-bit flags, 4 per byte
    fpad = (-len(flags)) % 4
    fl = np.concatenate([flags, np.zeros(fpad, dtype=np.uint8)]).reshape(-1, 4)
    fpacked = (fl[:, 0] | (fl[:, 1] << 2) | (fl[:, 2] << 4) | (fl[:, 3] << 6))
    zmixed = zlib.compress(mixed.tobytes(), ZLEVEL)
    head = struct.pack("<II", int(bits.size), int(mixed.shape[0]))
    return head + fpacked.astype(np.uint8).tobytes() + zmixed


def prescan_decode_bitmap(blob: bytes) -> np.ndarray:
    n_bits, n_mixed = struct.unpack_from("<II", blob, 0)
    n_bytes = (n_bits + 7) // 8
    n_chunks = (n_bytes + _CHUNK_BYTES - 1) // _CHUNK_BYTES
    f_len = (n_chunks + 3) // 4
    off = 8
    fpacked = np.frombuffer(blob, dtype=np.uint8, count=f_len, offset=off)
    off += f_len
    flags = np.empty(n_chunks, dtype=np.uint8)
    idx = np.arange(n_chunks)
    flags[:] = (fpacked[idx // 4] >> (2 * (idx % 4))) & 0x3
    mixed = np.frombuffer(zlib.decompress(blob[off:]), dtype=np.uint8)
    mixed = mixed.reshape(n_mixed, _CHUNK_BYTES) if n_mixed else \
        mixed.reshape(0, _CHUNK_BYTES)
    chunks = np.zeros((n_chunks, _CHUNK_BYTES), dtype=np.uint8)
    chunks[flags == _FLAG_ONE] = 0xFF
    chunks[flags == _FLAG_MIXED] = mixed
    packed = chunks.reshape(-1)[:n_bytes]
    return np.unpackbits(packed, count=n_bits).astype(bool)


def encode_bitmap(bits: np.ndarray, prescan: bool = True) -> bytes:
    """Bool sign array -> bitmap blob (prescan RLE or plain zlib'd packbits)."""
    if prescan:
        return prescan_encode_bitmap(bits)
    return zlib.compress(np.packbits(np.asarray(bits, bool)).tobytes(), ZLEVEL)


def decode_bitmap(blob: bytes, n: int, prescan: bool = True) -> np.ndarray:
    """Inverse of :func:`encode_bitmap`; returns ``n`` bools."""
    if prescan:
        return prescan_decode_bitmap(blob)
    return np.unpackbits(
        np.frombuffer(zlib.decompress(blob), dtype=np.uint8), count=n
    ).astype(bool)
