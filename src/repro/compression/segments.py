"""Structured compressed-block segments (paper §4.3 / §4.4 interface).

A compressed SV block is not an opaque blob: it is a small set of named
segments —

    {codes, bitmap, l_max}  per real plane  (+ a RAW escape variant)

and the pipeline wants them individually addressable: the device-resident
codec ships ``codes`` and ``bitmap`` across the host↔device boundary
without ever materializing the raw amplitudes on the host, and the
two-level store keeps the structure in its RAM tier so the hot path never
re-parses a byte stream.

``to_bytes`` / ``from_bytes`` give the self-describing wire layout used by
the disk spill tier and the legacy ``codec.compress_complex_block`` API:

    header   <BBHI>   fmt (1=pwrel, 2=raw) | prescan | reserved | n_amps
    per plane <fII>   l_max | len(codes) | len(bitmap)   then the two blobs
    (RAW:             header + n_amps raw complex64 bytes)
"""
from __future__ import annotations

import struct
from dataclasses import dataclass

__all__ = ["PlaneSegments", "BlockSegments", "FMT_PWREL", "FMT_RAW"]

FMT_PWREL = 1   # pwrel codes + bitmaps
FMT_RAW = 2     # raw complex64 escape

_HEAD = struct.Struct("<BBHI")
_PLANE_HEAD = struct.Struct("<fII")


@dataclass(frozen=True)
class PlaneSegments:
    """Lossless-encoded segments of one real plane of a block.

    Attributes:
        l_max:  block-max log2 magnitude (the quantizer anchor, §4.3 Alg. 2).
        codes:  zlib-compressed little-endian uint16 code stream.
        bitmap: sign bitmap — prescan blob (``lossless.prescan_encode_bitmap``)
                or zlib'd ``np.packbits`` stream, per ``BlockSegments.prescan``.
    """

    l_max: float
    codes: bytes
    bitmap: bytes

    @property
    def nbytes(self) -> int:
        return _PLANE_HEAD.size + len(self.codes) + len(self.bitmap)


@dataclass(frozen=True)
class BlockSegments:
    """One compressed SV block as named segments (two-level-store unit).

    Exactly one of (``re`` and ``im``) or ``raw`` is populated:
    pwrel-format blocks carry per-plane segments, RAW-escape blocks carry
    the original complex64 bytes.
    """

    n_amps: int
    prescan: bool = True
    re: PlaneSegments | None = None
    im: PlaneSegments | None = None
    raw: bytes | None = None

    @property
    def is_raw(self) -> bool:
        return self.raw is not None

    @property
    def nbytes(self) -> int:
        """Serialized size — what the store's byte accounting charges."""
        if self.is_raw:
            return _HEAD.size + len(self.raw)
        return _HEAD.size + self.re.nbytes + self.im.nbytes

    @property
    def raw_nbytes(self) -> int:
        return self.n_amps * 8  # complex64

    @property
    def ratio(self) -> float:
        return self.raw_nbytes / max(1, self.nbytes)

    def to_bytes(self) -> bytes:
        """Serialize to the self-describing wire layout (disk tier, legacy API)."""
        if self.is_raw:
            return _HEAD.pack(FMT_RAW, 0, 0, self.n_amps) + self.raw
        parts = [_HEAD.pack(FMT_PWREL, int(self.prescan), 0, self.n_amps)]
        for p in (self.re, self.im):
            parts.append(_PLANE_HEAD.pack(float(p.l_max), len(p.codes),
                                          len(p.bitmap)))
            parts.append(p.codes)
            parts.append(p.bitmap)
        return b"".join(parts)

    @classmethod
    def from_bytes(cls, blob: bytes) -> "BlockSegments":
        fmt, prescan, _, n = _HEAD.unpack_from(blob, 0)
        off = _HEAD.size
        if fmt == FMT_RAW:
            return cls(n_amps=n, raw=blob[off:off + n * 8])
        planes = []
        for _ in range(2):
            l_max, len_codes, len_bitmap = _PLANE_HEAD.unpack_from(blob, off)
            off += _PLANE_HEAD.size
            codes = blob[off:off + len_codes]
            off += len_codes
            bitmap = blob[off:off + len_bitmap]
            off += len_bitmap
            planes.append(PlaneSegments(l_max=l_max, codes=codes,
                                        bitmap=bitmap))
        return cls(n_amps=n, prescan=bool(prescan), re=planes[0],
                   im=planes[1])
