"""Device-resident lossy codec (paper §4.3 on the accelerator).

The paper's headline design point: the lossy half of the compressor runs
*next to the compute*, so only the compressed representation crosses the
host↔device boundary.  Per real plane of an n-amplitude block, the wire
format is exact-sized:

    codes       (n,)              uint16  — quantizer output, packed into
                                            u16-pair words by
                                            ``kernels.pack.pack_codes_tiles``
                                            and bitcast for transfer
    sign_bytes  (4*ceil(n/32),)   uint8   — ballot-packed sign bits
                                            (LSB-first, fused into
                                            ``quantize_tiles``)
    l_max       (1, 1)            float32 — quantizer anchor scalar

i.e. ~2.13 bytes per element instead of 4 (f32) — ~4.25 vs 8 bytes per
complex amplitude — before the host lossless stage shrinks it further.

Encode path (device -> store):   ``encode_group_device`` dispatches the
quantize + pack kernels per block, ``wire_to_segments`` runs the host
lossless stage on the fetched wire arrays.

Decode path (store -> device):   ``segments_to_wire`` inflates a block's
segments back to wire arrays, ``decode_block_device`` ships them to the
accelerator and runs unpack + dequantize there.

Planes are zero-padded on device to a multiple of 128 lanes around the
kernels; pad elements quantize to the exact-zero escape code and never
cross the boundary or reach the store — pwrel-format blocks written by one
backend are bit-identical to the other's, so the two are freely
interchangeable.  (RAW-escape blocks are the one exception: the device
path never ships raw amplitudes, so its RAW fallback stores the lossy
reconstruction — same size bound, same error bound, different bytes.)
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from ..faults import fault_point
from ..kernels import pack as _pk
from ..kernels import quantize as _qz
from .lossless import decode_bitmap, decode_codes, encode_bitmap, encode_codes
from .pwrel import CODE_MAX, PwRelParams, log_step
from .segments import BlockSegments, PlaneSegments

__all__ = [
    "PlaneWire", "plane_geometry", "sign_wire_bytes",
    "encode_group_device", "encode_group_planes", "fetch_group_wire",
    "wire_to_segments", "segments_to_wire", "decode_block_device",
    "decode_blocks_device", "decode_blocks_planes",
]

_LANES = 128


class PlaneWire(NamedTuple):
    """One plane's boundary-crossing representation (device or host arrays)."""

    codes: jax.Array | np.ndarray        # (n,) u16
    sign_bytes: jax.Array | np.ndarray   # (4*ceil(n/32),) u8, LSB-first
    l_max: jax.Array | np.ndarray        # (1, 1) f32

    @property
    def nbytes(self) -> int:
        return int(self.codes.nbytes + self.sign_bytes.nbytes
                   + self.l_max.nbytes)


def plane_geometry(n: int) -> tuple[int, int]:
    """(rows, pad) for an n-element plane padded to 128-lane rows."""
    pad = (-n) % _LANES
    return (n + pad) // _LANES, pad


def sign_wire_bytes(n: int) -> int:
    """Sign-bitmap wire size: whole ballot words, 4 bytes per 32 elements."""
    return 4 * ((n + 31) // 32)


# --------------------------------------------------------------------------
# encode: device kernels -> wire -> host lossless stage
# --------------------------------------------------------------------------

def _encode_plane_dev(x: jax.Array, pad: int, step: float,
                      interpret: bool) -> PlaneWire:
    n = x.shape[0]
    if pad:
        x = jnp.concatenate([x, jnp.zeros((pad,), jnp.float32)])
    x2d = x.reshape(-1, _LANES)
    max_abs = jnp.max(jnp.abs(x2d))
    l_max = jnp.where(max_abs > 0,
                      jnp.log2(jnp.maximum(max_abs, 1e-45)), 0.0)
    l_max = l_max.reshape(1, 1).astype(jnp.float32)
    codes, packed_signs, _flags = _qz.quantize_tiles(x2d, l_max, step,
                                                     interpret=interpret)
    packed_codes = _pk.pack_codes_tiles(codes, interpret=interpret)
    codes_u16 = lax.bitcast_convert_type(packed_codes,
                                         jnp.uint16).reshape(-1)[:n]
    sign_bytes = lax.bitcast_convert_type(
        packed_signs, jnp.uint8).reshape(-1)[:sign_wire_bytes(n)]
    return PlaneWire(codes_u16, sign_bytes, l_max)


@partial(jax.jit, static_argnames=("n_blocks", "step", "interpret"))
def _encode_group_jit(planes: jax.Array, n_blocks: int, step: float,
                      interpret: bool):
    bsz = planes.shape[1] // n_blocks
    _, pad = plane_geometry(bsz)
    re = planes[0].reshape(n_blocks, bsz)
    im = planes[1].reshape(n_blocks, bsz)
    out = []
    for i in range(n_blocks):
        out.append((
            _encode_plane_dev(re[i], pad, step, interpret),
            _encode_plane_dev(im[i], pad, step, interpret),
        ))
    return tuple(out)


def encode_group_planes(planes: jax.Array, n_blocks: int,
                        params: PwRelParams, *, interpret: bool = True):
    """Dispatch the lossy encode of a planes-resident group on its device.

    Args:
        planes: (2, n_blocks * 2^b) f32 re/im plane stack (device-resident)
            — the stage compute's native representation; no complex64 is
            materialized on the encode path.
        n_blocks: SV blocks in the group (2^m).
        params: pwrel bound.

    Returns:
        Tuple of ``(re: PlaneWire, im: PlaneWire)`` per block — device
        arrays, dispatched asynchronously (nothing is fetched yet).
    """
    return _encode_group_jit(jnp.asarray(planes, jnp.float32), n_blocks,
                             log_step(params.b_r), interpret)


def encode_group_device(amps: jax.Array, n_blocks: int, params: PwRelParams,
                        *, interpret: bool = True):
    """Complex-array convenience over :func:`encode_group_planes` —
    identical stored bytes (a complex64's components are already f32)."""
    fault_point("codec.encode")
    planes = jnp.stack([jnp.real(amps), jnp.imag(amps)]).astype(jnp.float32)
    return encode_group_planes(planes, n_blocks, params, interpret=interpret)


def fetch_group_wire(encoded) -> tuple[list[tuple[PlaneWire, PlaneWire]], int]:
    """Block on the device encode and fetch wire arrays to host numpy.

    Returns (per-block host PlaneWire pairs, total bytes moved d2h).
    """
    out, moved = [], 0
    for re_w, im_w in encoded:
        host_pair = []
        for w in (re_w, im_w):
            h = PlaneWire(np.asarray(w.codes), np.asarray(w.sign_bytes),
                          np.asarray(w.l_max))
            moved += h.nbytes
            host_pair.append(h)
        out.append(tuple(host_pair))
    return out, moved


def _wire_plane_to_segments(w: PlaneWire, n: int,
                            prescan: bool) -> PlaneSegments:
    u16 = np.asarray(w.codes, dtype="<u2")
    bits = np.unpackbits(np.asarray(w.sign_bytes, dtype=np.uint8),
                         bitorder="little", count=n).astype(bool)
    return PlaneSegments(l_max=float(np.asarray(w.l_max).reshape(())),
                         codes=encode_codes(u16),
                         bitmap=encode_bitmap(bits, prescan))


def _wire_plane_to_f32(w: PlaneWire, n: int, step: float) -> np.ndarray:
    """Pure-numpy dequantize of a host wire plane (pwrel.py math, GIL-free)."""
    codes = np.asarray(w.codes, dtype="<u2")
    bits = np.unpackbits(np.asarray(w.sign_bytes, dtype=np.uint8),
                         bitorder="little", count=n).astype(bool)
    d = np.float32(CODE_MAX) - codes.astype(np.float32)
    mag = np.exp2(np.float32(np.asarray(w.l_max).reshape(()))
                  - d * np.float32(step)).astype(np.float32)
    mag[codes == 0] = 0.0
    return np.where(bits, -mag, mag).astype(np.float32)


def wire_to_segments(pair: tuple[PlaneWire, PlaneWire], n: int,
                     prescan: bool = True,
                     params: PwRelParams | None = None) -> BlockSegments:
    """Host lossless stage: fetched wire arrays -> structured block segments.

    When ``params`` is given, the host codec's never-inflate contract is
    honored: if the pwrel segments would exceed the raw block, the wire is
    dequantized on the host (pure numpy — the quantized data is all the
    device shipped, so the RAW bytes hold the reconstruction, not the
    pre-quantization amplitudes the host encoder would have stored).
    """
    seg = BlockSegments(n_amps=n, prescan=prescan,
                        re=_wire_plane_to_segments(pair[0], n, prescan),
                        im=_wire_plane_to_segments(pair[1], n, prescan))
    if params is not None and seg.nbytes >= seg.raw_nbytes + 8:
        step = log_step(params.b_r)
        amps = (_wire_plane_to_f32(pair[0], n, step)
                + 1j * _wire_plane_to_f32(pair[1], n, step)) \
            .astype(np.complex64)
        seg = BlockSegments(n_amps=n, raw=amps.tobytes())
    return seg


# --------------------------------------------------------------------------
# decode: host lossless stage -> wire -> device kernels
# --------------------------------------------------------------------------

def _segments_plane_to_wire(p: PlaneSegments, n: int,
                            prescan: bool) -> PlaneWire:
    u16 = np.asarray(decode_codes(p.codes, n))
    bits = decode_bitmap(p.bitmap, n, prescan)
    sign_bytes = np.packbits(bits, bitorder="little")
    want = sign_wire_bytes(n)
    if sign_bytes.size < want:
        sign_bytes = np.concatenate(
            [sign_bytes, np.zeros(want - sign_bytes.size, np.uint8)])
    l_max = np.asarray(p.l_max, dtype=np.float32).reshape(1, 1)
    return PlaneWire(u16, sign_bytes, l_max)


def segments_to_wire(seg: BlockSegments) -> tuple[PlaneWire, PlaneWire]:
    """Inflate a block's lossless segments to host wire arrays (GIL-free)."""
    assert not seg.is_raw, "RAW blocks bypass the device codec"
    return (_segments_plane_to_wire(seg.re, seg.n_amps, seg.prescan),
            _segments_plane_to_wire(seg.im, seg.n_amps, seg.prescan))


def _decode_plane_dev(codes_u16: jax.Array, sign_bytes: jax.Array,
                      l_max: jax.Array, n: int, step: float,
                      interpret: bool) -> jax.Array:
    rows, pad = plane_geometry(n)
    if pad:
        codes_u16 = jnp.concatenate(
            [codes_u16, jnp.zeros((pad,), jnp.uint16)])
    packed_codes = lax.bitcast_convert_type(
        codes_u16.reshape(rows * (_LANES // 2), 2),
        jnp.int32).reshape(rows, _LANES // 2)
    spad = rows * 16 - sign_bytes.shape[0]
    if spad:
        sign_bytes = jnp.concatenate(
            [sign_bytes, jnp.zeros((spad,), jnp.uint8)])
    packed_signs = lax.bitcast_convert_type(
        sign_bytes.reshape(rows, 4, 4), jnp.int32)
    codes = _pk.unpack_codes_tiles(packed_codes, interpret=interpret)
    plane = _qz.dequantize_tiles(codes, packed_signs, l_max, step,
                                 interpret=interpret)
    return plane.reshape(-1)[:n]


@partial(jax.jit, static_argnames=("n", "step", "interpret"))
def _decode_blocks_jit(codes, sign_bytes, l_max, n: int, step: float,
                       interpret: bool):
    """codes (2k, n) u16 / sign_bytes (2k, s) u8 / l_max (2k, 1, 1) f32,
    planes in block order [re0, im0, re1, im1, ...] -> (k, 2, n) f32."""
    k2 = codes.shape[0]
    planes = [_decode_plane_dev(codes[i], sign_bytes[i], l_max[i], n, step,
                                interpret) for i in range(k2)]
    return jnp.stack(planes).reshape(k2 // 2, 2, n)


@partial(jax.jit, static_argnames=())
def _planes_to_complex(planes: jax.Array) -> jax.Array:
    """(..., 2, n) f32 plane pairs -> (..., n) complex64."""
    return (planes[..., 0, :] + 1j * planes[..., 1, :]).astype(jnp.complex64)


def decode_blocks_planes(pairs: list, n: int, params: PwRelParams, device,
                         *, interpret: bool = True) -> tuple[jax.Array, int]:
    """Ship several blocks' wire arrays to ``device`` in three batched
    transfers and decode them in one kernel dispatch.

    Args:
        pairs: per-block ``(re, im)`` host :class:`PlaneWire` tuples.

    Returns (device f32 planes (len(pairs), 2, n), bytes moved h2d) — the
    stage compute's native representation; no complex64 is materialized.
    The decode is dispatched asynchronously — callers can overlap it with
    compute of the previous group (§4.2).
    """
    planes = [w for pair in pairs for w in pair]
    codes = np.stack([np.asarray(w.codes) for w in planes])
    sign_bytes = np.stack([np.asarray(w.sign_bytes) for w in planes])
    l_max = np.stack([np.asarray(w.l_max) for w in planes])
    moved = codes.nbytes + sign_bytes.nbytes + l_max.nbytes
    blocks = _decode_blocks_jit(
        jax.device_put(codes, device), jax.device_put(sign_bytes, device),
        jax.device_put(l_max, device), n=n, step=log_step(params.b_r),
        interpret=interpret)
    return blocks, moved


def decode_blocks_device(pairs: list, n: int, params: PwRelParams, device,
                         *, interpret: bool = True) -> tuple[jax.Array, int]:
    """Complex-array convenience over :func:`decode_blocks_planes`.

    Returns (device complex64 blocks (len(pairs), n), bytes moved h2d).
    """
    fault_point("codec.decode")
    planes, moved = decode_blocks_planes(pairs, n, params, device,
                                         interpret=interpret)
    return _planes_to_complex(planes), moved


def decode_block_device(pair: tuple[PlaneWire, PlaneWire], n: int,
                        params: PwRelParams, device,
                        *, interpret: bool = True) -> tuple[jax.Array, int]:
    """Single-block convenience over :func:`decode_blocks_device`."""
    blocks, moved = decode_blocks_device([pair], n, params, device,
                                         interpret=interpret)
    return blocks[0], moved
