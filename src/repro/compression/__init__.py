"""Compression subsystem (paper §4.3/§4.4).

Layering:

* ``pwrel``        — the lossy quantizer math (host/jnp reference; the
                     Pallas kernels in :mod:`repro.kernels` mirror it).
* ``lossless``     — the host-only lossless stage (zlib + bitmap pre-scan).
* ``segments``     — the structured compressed-block container + wire layout.
* ``codec``        — host composition of the two stages (block <-> bytes).
* ``device_codec`` — the device-resident lossy half (kernels next to the
                     compute; only compressed wire crosses the boundary).
* ``store``        — the two-level (RAM/disk) block store.
"""
from .pwrel import PwRelParams, quantize_plane, dequantize_plane  # noqa: F401
from .codec import (  # noqa: F401
    CompressedBlock, compress_complex_block, decompress_complex_block,
    encode_block_host, decode_block_host,
)
from .segments import BlockSegments, PlaneSegments  # noqa: F401
from .lossless import (  # noqa: F401
    prescan_encode_bitmap, prescan_decode_bitmap,
)
from .store import BlockStore  # noqa: F401
