from .pwrel import PwRelParams, quantize_plane, dequantize_plane  # noqa: F401
from .codec import (  # noqa: F401
    CompressedBlock, compress_complex_block, decompress_complex_block,
)
from .store import BlockStore  # noqa: F401
