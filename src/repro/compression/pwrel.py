"""Point-wise relative-error quantization (paper §4.3, Alg. 2) — jnp reference.

Scheme (per real plane of a complex SV block):

1. sign bitmap          s_i = (x_i < 0)                       (1 bit/elem)
2. log transform        L_i = log2 |x_i|
3. absolute-bound       quantize L with step 2*b_a, b_a = log2(1 + b_r)
   quantization         => point-wise relative error <= b_r  (Eq. 1/2)

Codes are anchored at the block maximum:  code = CODE_MAX - round((l_max -
L)/step), clipped to [1, CODE_MAX]; code 0 is the exact-zero escape.  With
uint16 codes and b_r = 1e-3 the representable dynamic range below the block
max is ~189 log2 units (~10^57): anything smaller is quantized to exact 0.
(That floor technically breaks the *relative* bound for those elements, but
they are < 2^-189 of the block max — beneath f32 resolution of any inner
product; the paper's fixed-length bitcomp quantizer makes the same trade.)
Additionally, SUBNORMAL magnitudes (|x| < 2^-126) may reconstruct to exact
0 under XLA's flush-to-zero arithmetic — the bound is guaranteed for
normal floats (hypothesis found this edge; tests/test_compression.py).

All arithmetic is float32 so this file doubles as the bit-exact oracle for
the Pallas quantize/dequantize kernels (kernels/ref.py re-exports it).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PwRelParams", "quantize_plane", "dequantize_plane",
    "CODE_MAX", "log_step",
]

CODE_MAX = 65535  # uint16 code space; 0 = exact zero escape


def log_step(b_r: float) -> float:
    """Quantization step in log2 domain: 2 * b_a = 2 * log2(1 + b_r)."""
    return float(2.0 * np.log2(1.0 + b_r))


@dataclass(frozen=True)
class PwRelParams:
    b_r: float = 1e-3  # the paper's default point-wise relative bound

    @property
    def step(self) -> float:
        return log_step(self.b_r)


@partial(jax.jit, static_argnames=("step",))
def _quantize(x: jax.Array, step: float):
    absx = jnp.abs(x).astype(jnp.float32)
    signs = x < 0
    max_abs = jnp.max(absx)
    l_max = jnp.where(max_abs > 0, jnp.log2(jnp.maximum(max_abs, 1e-45)), 0.0)
    L = jnp.log2(jnp.maximum(absx, 1e-45))          # -149.. for subnormal floor
    d = jnp.round((l_max - L) / jnp.float32(step))
    codes_f = jnp.float32(CODE_MAX) - d
    codes_f = jnp.where(absx <= 0, 0.0, codes_f)
    codes = jnp.clip(codes_f, 0.0, float(CODE_MAX)).astype(jnp.int32)
    return codes, signs, l_max


def quantize_plane(x, params: PwRelParams):
    """f32 plane -> (uint16 codes, bool signs, f32 l_max scalar)."""
    x = jnp.asarray(x, dtype=jnp.float32)
    codes, signs, l_max = _quantize(x, params.step)
    return codes.astype(jnp.uint16), signs, l_max


@partial(jax.jit, static_argnames=("step",))
def _dequantize(codes: jax.Array, signs: jax.Array, l_max: jax.Array,
                step: float) -> jax.Array:
    d = jnp.float32(CODE_MAX) - codes.astype(jnp.float32)
    mag = jnp.exp2(l_max - d * jnp.float32(step))
    mag = jnp.where(codes == 0, 0.0, mag)
    return jnp.where(signs, -mag, mag).astype(jnp.float32)


def dequantize_plane(codes, signs, l_max, params: PwRelParams):
    codes = jnp.asarray(codes).astype(jnp.int32)
    return _dequantize(codes, jnp.asarray(signs), jnp.asarray(l_max, jnp.float32),
                       params.step)
