"""Generate ``docs/API.md`` from the ``repro`` public surface.

The public API is whatever :data:`repro.__all__` declares; this module
renders one entry per export — heading, cleaned signature, first
docstring line — grouped by the ``#`` section comments inside the
``__all__`` literal itself (parsed from source, so the doc's grouping
can never drift from the code's).

Two CLI modes keep the committed file honest:

``python -m repro.analysis.api_doc --write docs/API.md``
    Regenerate the file in place.

``python -m repro.analysis.api_doc --check docs/API.md``
    Exit nonzero (printing a unified diff) when the committed doc and
    the live surface disagree — the CI ``docs`` gate.

Rendering is deterministic for a given source tree: annotations are
PEP-563 strings (every public module uses ``from __future__ import
annotations``), defaults render via ``repr``, and signatures longer
than 88 columns wrap one-parameter-per-line.
"""

from __future__ import annotations

import argparse
import difflib
import inspect
import re
import sys

__all__ = ["generate", "main"]

_WIDTH = 88

_SECTION_RE = re.compile(r"^\s*#\s*(.+?)\s*$")
_NAME_RE = re.compile(r"\"([A-Za-z_][A-Za-z0-9_]*)\"")
_BUILTIN_RE = re.compile(r"<built-in function (\w+)>")
_CLASS_RE = re.compile(r"<class '([\w.]+)'>")


def _sections():
    """``[(section_title, [export, ...]), ...]`` in ``__all__`` order.

    Parsed from the source of ``repro/__init__.py`` so the grouping
    comments inside the ``__all__`` literal carry over to the doc.
    """
    import repro

    src = inspect.getsource(repro)
    body = src.split("__all__ = [", 1)[1].split("]", 1)[0]
    sections: list[tuple[str, list[str]]] = []
    title = "exports"
    for line in body.splitlines():
        m = _SECTION_RE.match(line)
        if m:
            title = m.group(1)
            continue
        for name in _NAME_RE.findall(line):
            if not sections or sections[-1][0] != title:
                sections.append((title, []))
            sections[-1][1].append(name)
    flat = [n for _, names in sections for n in names]
    if flat != list(repro.__all__):
        raise RuntimeError(
            "api_doc parsed __all__ inconsistently with repro.__all__: "
            f"{flat!r} != {list(repro.__all__)!r}"
        )
    return sections


def _fmt_param(p: inspect.Parameter) -> str:
    s = p.name
    if p.kind is p.VAR_POSITIONAL:
        s = "*" + s
    elif p.kind is p.VAR_KEYWORD:
        s = "**" + s
    if p.annotation is not p.empty:
        ann = p.annotation
        if not isinstance(ann, str):
            ann = inspect.formatannotation(ann)
        s += f": {ann}"
    if p.default is not p.empty:
        d = repr(p.default)
        d = _BUILTIN_RE.sub(r"\1", d)
        d = _CLASS_RE.sub(r"\1", d)
        sep = " = " if p.annotation is not p.empty else "="
        s += f"{sep}{d}"
    return s


def _fmt_signature(obj) -> str | None:
    """Render ``obj``'s signature, or None when it has no useful one.

    Private (``_``-prefixed) parameters are dropped; ``*`` / ``/``
    markers are preserved around the drop.
    """
    try:
        sig = inspect.signature(obj)
    except (TypeError, ValueError):
        return None
    parts: list[str] = []
    saw_var_positional = False
    marker_emitted = False
    for p in sig.parameters.values():
        if p.kind is p.VAR_POSITIONAL:
            saw_var_positional = True
        if p.name.startswith("_"):
            continue
        if (
            p.kind is p.KEYWORD_ONLY
            and not saw_var_positional
            and not marker_emitted
        ):
            parts.append("*")
            marker_emitted = True
        parts.append(_fmt_param(p))
    one_line = f"({', '.join(parts)})"
    ret = ""
    if not inspect.isclass(obj) and sig.return_annotation is not sig.empty:
        ann = sig.return_annotation
        if not isinstance(ann, str):
            ann = inspect.formatannotation(ann)
        ret = f" -> {ann}"
    return one_line + ret


def _headline(obj, name: str) -> str:
    """``class Name(Base)`` / ``def name`` — the fenced block's first line."""
    if inspect.isclass(obj):
        bases = [
            b.__name__
            for b in obj.__bases__
            if b is not object and not b.__name__.startswith("_")
        ]
        suffix = f"({', '.join(bases)})" if bases else ""
        return f"class {name}{suffix}"
    return f"def {name}"


def _wrap(decl: str, sig: str) -> str:
    """One line when it fits, else one parameter per line."""
    flat = decl + sig
    if len(flat) <= _WIDTH:
        return flat
    params, _, ret = sig.rpartition(")")
    params = params[1:]
    depth = 0
    parts, cur = [], ""
    for ch in params:
        if ch in "([{":
            depth += 1
        elif ch in ")]}":
            depth -= 1
        if ch == "," and depth == 0:
            parts.append(cur.strip())
            cur = ""
        else:
            cur += ch
    if cur.strip():
        parts.append(cur.strip())
    body = "".join(f"    {p},\n" for p in parts)
    return f"{decl}(\n{body}){ret}"


def _summary(obj) -> str:
    doc = inspect.getdoc(obj) or ""
    for line in doc.splitlines():
        if line.strip():
            return line.strip()
    return "*(no docstring)*"


def generate() -> str:
    """The full ``docs/API.md`` body as a string."""
    import repro

    out = [
        "# Public API reference",
        "",
        "<!-- GENERATED FILE - DO NOT EDIT BY HAND. -->",
        "<!-- Regenerate: PYTHONPATH=src python -m repro.analysis.api_doc"
        " --write docs/API.md -->",
        "",
        f"`repro` {repro.__version__} — every name in `repro.__all__`, in"
        " declared order.",
        "The CI docs gate (`--check`) fails when this file and the live"
        " surface disagree.",
        "",
    ]
    for title, names in _sections():
        out.append(f"## {title.capitalize()}")
        out.append("")
        for name in names:
            obj = getattr(repro, name)
            out.append(f"### `{name}`")
            out.append("")
            sig = _fmt_signature(obj)
            if sig is not None:
                out.append("```python")
                out.append(_wrap(_headline(obj, name), sig))
                out.append("```")
                out.append("")
            out.append(_summary(obj))
            out.append("")
    return "\n".join(out).rstrip() + "\n"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.api_doc",
        description="generate/verify docs/API.md from repro.__all__",
    )
    mode = ap.add_mutually_exclusive_group(required=True)
    mode.add_argument(
        "--write", action="store_true", help="(re)write PATH from the live surface"
    )
    mode.add_argument(
        "--check",
        action="store_true",
        help="diff PATH against the live surface; exit 1 on drift",
    )
    ap.add_argument("path", nargs="?", default="docs/API.md")
    args = ap.parse_args(argv)

    want = generate()
    if args.write:
        fh = open(args.path, "w", encoding="utf-8")  # lint: disable=fault-coverage -- CLI
        with fh:
            fh.write(want)
        print(f"wrote {args.path} ({len(want.splitlines())} lines)")
        return 0

    try:
        fh = open(args.path, encoding="utf-8")  # lint: disable=fault-coverage -- CLI
        with fh:
            have = fh.read()
    except OSError as e:
        print(f"cannot read {args.path}: {e}", file=sys.stderr)
        return 1
    if have == want:
        print(f"{args.path} is up to date with repro.__all__")
        return 0
    diff = difflib.unified_diff(
        have.splitlines(keepends=True),
        want.splitlines(keepends=True),
        fromfile=f"{args.path} (committed)",
        tofile=f"{args.path} (generated)",
    )
    sys.stdout.writelines(diff)
    print(
        f"\n{args.path} is stale - regenerate with: "
        "PYTHONPATH=src python -m repro.analysis.api_doc --write docs/API.md"
    )
    return 1


if __name__ == "__main__":
    sys.exit(main())
