"""Relative-link checker for the repo's markdown docs.

``python -m repro.analysis.linkcheck README.md docs`` walks the given
markdown files (directories are scanned for ``*.md``), extracts every
inline link/image target, and exits nonzero when a *relative* target
does not exist on disk — the CI ``docs`` gate against stale
cross-references.

Scope is file existence only: external (``http(s)://``, ``mailto:``)
targets and same-file ``#anchors`` are skipped, and a ``#fragment``
suffix on a relative target is stripped before the existence check.
"""

from __future__ import annotations

import argparse
import os
import re
import sys

__all__ = ["check_files", "iter_links", "main"]

_LINK_RE = re.compile(r"!?\[[^\]]*\]\(([^)\s]+)(?:\s+\"[^\"]*\")?\)")
_SKIP_PREFIXES = ("http://", "https://", "mailto:")


def iter_links(text: str):
    """Yield ``(line_number, target)`` for every inline markdown link."""
    fenced = False
    for lineno, line in enumerate(text.splitlines(), start=1):
        if line.lstrip().startswith("```"):
            fenced = not fenced
            continue
        if fenced:
            continue
        for m in _LINK_RE.finditer(line):
            yield lineno, m.group(1)


def _collect(paths: list[str]) -> list[str]:
    files: list[str] = []
    for p in paths:
        if os.path.isdir(p):
            for entry in sorted(os.listdir(p)):
                if entry.endswith(".md"):
                    files.append(os.path.join(p, entry))
        else:
            files.append(p)
    return files


def check_files(paths: list[str]) -> list[str]:
    """Broken-link messages (``file:line: target``) for the given paths."""
    problems: list[str] = []
    for path in _collect(paths):
        fh = open(path, encoding="utf-8")  # lint: disable=fault-coverage -- CLI
        with fh:
            text = fh.read()
        base = os.path.dirname(path)
        for lineno, target in iter_links(text):
            if target.startswith(_SKIP_PREFIXES) or target.startswith("#"):
                continue
            rel = target.split("#", 1)[0]
            if not os.path.exists(os.path.join(base, rel)):
                problems.append(f"{path}:{lineno}: broken link -> {target}")
    return problems


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.linkcheck",
        description="verify relative markdown links resolve on disk",
    )
    ap.add_argument(
        "paths",
        nargs="+",
        help="markdown files or directories (scanned for *.md)",
    )
    args = ap.parse_args(argv)
    problems = check_files(args.paths)
    for p in problems:
        print(p)
    n_files = len(_collect(args.paths))
    print(f"{len(problems)} broken link(s) in {n_files} file(s) checked")
    return 1 if problems else 0


if __name__ == "__main__":
    sys.exit(main())
