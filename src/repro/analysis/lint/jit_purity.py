"""jit-purity: no host synchronization inside traced code.

Functions compiled by ``jax.jit`` (or lowered as Pallas kernels) trace
once and run on device; a ``np.asarray``/``jax.device_get``/
``.block_until_ready()``/dynamic ``float(...)`` inside one either
breaks tracing outright or — worse — silently forces a blocking
device->host sync in the middle of the stage pipeline, serializing the
exact overlap the pipeline exists to create.

The checker builds a project-wide call graph:

* roots: functions decorated ``@jax.jit`` / ``@partial(jax.jit, ...)``,
  functions passed to a ``jax.jit(f, ...)`` call, and kernel bodies
  passed to ``pallas_call``;
* edges: bare-name calls and ``self.method()`` calls resolved against
  every analyzed file's function definitions (conservative: all
  same-named defs are followed);
* inside any reachable function (nested helpers included), flag
  ``np.asarray``/``np.array``/``np.frombuffer``, ``jax.device_get``,
  ``.block_until_ready()``, and ``float()``/``int()``/``bool()`` on a
  non-static argument (constants, ALL_CAPS module constants and
  ``len(...)`` of traced-time-static containers are fine).

Intentional trace-time host math on static Python values is annotated
``# jit-ok: <reason>`` at the call line.
"""

from __future__ import annotations

import ast

from .base import Checker, SourceFile, Violation, register

_NP_FORBIDDEN = frozenset({"asarray", "array", "frombuffer"})
_CASTS = frozenset({"float", "int", "bool"})
_FUNC_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef)


class _FileInfo:
    def __init__(self, src: SourceFile):
        self.src = src
        self.np_aliases: set[str] = set()
        self.jax_aliases: set[str] = set()
        self.defs: list[ast.AST] = []
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Import):
                for a in node.names:
                    if a.name == "numpy":
                        self.np_aliases.add(a.asname or "numpy")
                    elif a.name == "jax":
                        self.jax_aliases.add(a.asname or "jax")
            elif isinstance(node, _FUNC_DEFS):
                self.defs.append(node)

    def is_jit(self, f: ast.AST) -> bool:
        if isinstance(f, ast.Name) and f.id == "jit":
            return True
        if not isinstance(f, ast.Attribute) or f.attr != "jit":
            return False
        if not isinstance(f.value, ast.Name):
            return False
        return f.value.id in (self.jax_aliases or {"jax"})


def _is_partial(f: ast.AST) -> bool:
    if isinstance(f, ast.Name):
        return f.id == "partial"
    return isinstance(f, ast.Attribute) and f.attr == "partial"


def _is_pallas(f: ast.AST) -> bool:
    if isinstance(f, ast.Name):
        return f.id == "pallas_call"
    return isinstance(f, ast.Attribute) and f.attr == "pallas_call"


def _static_arg(arg: ast.AST) -> bool:
    """Trace-time-static expressions a float()/int() cast may consume."""
    if isinstance(arg, ast.Constant):
        return True
    if isinstance(arg, ast.UnaryOp):
        return _static_arg(arg.operand)
    if isinstance(arg, ast.BinOp):
        return _static_arg(arg.left) and _static_arg(arg.right)
    if isinstance(arg, ast.Name) and arg.id.isupper():
        return True  # module-level constant
    if isinstance(arg, ast.Attribute) and arg.attr.isupper():
        return True
    if isinstance(arg, ast.Call) and isinstance(arg.func, ast.Name):
        if arg.func.id == "len":
            return True  # shapes are static under trace
    return False


def _jit_decorated(info: _FileInfo, fn: ast.AST) -> bool:
    for dec in fn.decorator_list:
        if info.is_jit(dec):
            return True
        if not isinstance(dec, ast.Call):
            continue
        if info.is_jit(dec.func):
            return True
        if _is_partial(dec.func) and dec.args and info.is_jit(dec.args[0]):
            return True
    return False


def _called_def_name(node: ast.Call) -> str | None:
    """Call edge name: bare ``helper()`` or ``self.method()`` only —
    matching arbitrary attribute names would conflate ``list.append`` /
    ``int.to_bytes`` with same-named project functions."""
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        if isinstance(f.value, ast.Name) and f.value.id == "self":
            return f.attr
    return None


@register
class JitPurity(Checker):
    name = "jit-purity"
    description = "no host syncs reachable from jit/Pallas-traced code"

    def check_project(self, files: list[SourceFile]) -> list[Violation]:
        infos = [_FileInfo(src) for src in files]
        table: dict[str, list[tuple[_FileInfo, ast.AST]]] = {}
        for info in infos:
            for fn in info.defs:
                table.setdefault(fn.name, []).append((info, fn))

        roots: list[tuple[_FileInfo, ast.AST]] = []
        for info in infos:
            local: dict[str, list[tuple[_FileInfo, ast.AST]]] = {}
            for fn in info.defs:
                local.setdefault(fn.name, []).append((info, fn))
            for fn in info.defs:
                if _jit_decorated(info, fn):
                    roots.append((info, fn))
            for node in ast.walk(info.src.tree):
                if not isinstance(node, ast.Call) or not node.args:
                    continue
                if info.is_jit(node.func) or _is_pallas(node.func):
                    arg0 = node.args[0]
                    if isinstance(arg0, ast.Name):
                        hits = local.get(arg0.id) or table.get(arg0.id, [])
                        roots.extend(hits)

        # BFS over called names, conservatively following every
        # same-named definition in the analyzed set
        reachable: dict[int, tuple[_FileInfo, ast.AST]] = {}
        stack = list(roots)
        while stack:
            info, fn = stack.pop()
            if id(fn) in reachable:
                continue
            reachable[id(fn)] = (info, fn)
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                name = _called_def_name(node)
                if name and name in table:
                    stack.extend(table[name])

        out: list[Violation] = []
        seen: set[tuple[str, int, str]] = set()

        def flag(src, lineno, msg):
            key = (src.path, lineno, msg)
            if key in seen:
                return
            seen.add(key)
            if src.jit_ok(lineno) or src.disabled(lineno, self.name):
                return
            out.append(Violation(self.name, src.path, lineno, msg))

        for info, fn in reachable.values():
            self._scan_fn(info, fn, flag)
        out.sort(key=lambda v: (v.path, v.line))
        return out

    def _scan_fn(self, info, fn, flag):
        src = info.src
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            f = node.func
            if isinstance(f, ast.Attribute):
                if f.attr == "block_until_ready":
                    msg = (
                        f".block_until_ready() inside jit-reachable "
                        f"{fn.name}() — host sync in traced code"
                    )
                    flag(src, node.lineno, msg)
                elif isinstance(f.value, ast.Name):
                    is_np = f.value.id in info.np_aliases
                    if is_np and f.attr in _NP_FORBIDDEN:
                        msg = (
                            f"{f.value.id}.{f.attr}() inside jit-reachable "
                            f"{fn.name}() — forces device->host transfer "
                            f"under trace"
                        )
                        flag(src, node.lineno, msg)
                    elif f.value.id in info.jax_aliases and f.attr == "device_get":
                        msg = f"jax.device_get() inside jit-reachable {fn.name}()"
                        flag(src, node.lineno, msg)
            elif isinstance(f, ast.Name) and f.id in _CASTS:
                if node.args and not _static_arg(node.args[0]):
                    msg = (
                        f"{f.id}() on a non-static value inside jit-reachable "
                        f"{fn.name}() — concretizes a tracer (add "
                        f"'# jit-ok: <reason>' if the value is static at "
                        f"trace time)"
                    )
                    flag(src, node.lineno, msg)
