"""lock-discipline: a static race detector for annotated shared state.

The threaded wave scheduler (core/pipeline.py) and the two-level store
(compression/store.py) share mutable counters and dicts across worker
threads.  The convention: declare the guard on the field's ``__init__``
assignment —

    self.t_load = 0.0          # guarded-by: _t_lock

— and from then on every ``self.t_load`` access (read or write) must
sit lexically inside ``with self._t_lock:`` in the same class, or in a
method annotated ``# holds-lock: _t_lock`` (callers own the lock).

Scope and limits (by design, to stay zero-false-positive):

* tracking is per-class and lexical — a closure defined inside the
  ``with`` block counts as inside it;
* only ``self.<field>`` accesses are checked; cross-object accesses
  (``store.stats`` from the pressure monitor) are a documented blind
  spot — annotate those call sites by hand if they become load-bearing;
* the declaration line itself is exempt.
"""

from __future__ import annotations

import ast

from .base import Checker, SourceFile, Violation, register

_FUNC_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef)
_ASSIGNS = (ast.Assign, ast.AnnAssign, ast.AugAssign)


def _is_self_attr(node: ast.AST) -> bool:
    if not isinstance(node, ast.Attribute):
        return False
    return isinstance(node.value, ast.Name) and node.value.id == "self"


def _with_locks(node: ast.With) -> set[str]:
    """Lock attribute names acquired by ``with self.<lock>[, ...]:``."""
    out = set()
    for item in node.items:
        ctx = item.context_expr
        if _is_self_attr(ctx):
            out.add(ctx.attr)
    return out


@register
class LockDiscipline(Checker):
    name = "lock-discipline"
    description = "'# guarded-by:' fields only touched under their lock"

    def check(self, src: SourceFile) -> list[Violation]:
        out: list[Violation] = []
        classes = [n for n in ast.walk(src.tree) if isinstance(n, ast.ClassDef)]
        for cls in classes:
            guarded: dict[str, str] = {}
            decl_lines: set[int] = set()
            for node in ast.walk(cls):
                if not isinstance(node, _ASSIGNS):
                    continue
                lock = src.guarded_by(node.lineno)
                if lock is None:
                    continue
                if isinstance(node, ast.Assign):
                    targets = node.targets
                else:
                    targets = [node.target]
                for tgt in targets:
                    if _is_self_attr(tgt):
                        guarded[tgt.attr] = lock
                        decl_lines.add(node.lineno)
            if not guarded:
                continue
            for func in cls.body:
                if isinstance(func, _FUNC_DEFS):
                    self._check_func(src, func, guarded, decl_lines, out)
        return out

    def _check_func(self, src, func, guarded, decl_lines, out):
        held0 = frozenset(src.holds_locks(func))

        def flag(node, lock):
            if node.lineno in decl_lines:
                return
            if src.disabled(node.lineno, self.name):
                return
            msg = (
                f"self.{node.attr} accessed outside 'with self.{lock}:' "
                f"in {func.name}() (declared # guarded-by: {lock})"
            )
            out.append(Violation(self.name, src.path, node.lineno, msg))

        def visit(node, held):
            if isinstance(node, ast.With):
                held = held | _with_locks(node)
            elif isinstance(node, _FUNC_DEFS) and node is not func:
                # nested scope: lexical nesting keeps `held`, plus the
                # closure's own holds-lock annotation
                held = held | src.holds_locks(node)
            elif _is_self_attr(node) and node.attr in guarded:
                lock = guarded[node.attr]
                if lock not in held:
                    flag(node, lock)
            for child in ast.iter_child_nodes(node):
                visit(child, held)

        visit(func, held0)
