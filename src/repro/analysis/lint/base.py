"""Checker registry, pragma conventions and the file walker.

A checker is a small class over one parsed :class:`SourceFile` (or, for
whole-program analyses, over all of them at once via
:meth:`Checker.check_project`).  Checkers register themselves with
:func:`register`; ``python -m repro.analysis`` discovers them there.

Suppression is *annotation-with-justification*, never blanket excludes:

``# lint: disable=<checker>[,<checker>] -- <reason>``
    Silence the named checkers on this line.  The ``-- <reason>`` is
    mandatory — a pragma without one does not suppress anything, so
    every allowlisted violation carries its justification in-tree.

``# guarded-by: <lock>``
    On a ``self.field = ...`` declaration: the field may only be
    accessed inside ``with self.<lock>:`` (the *lock-discipline*
    checker).

``# holds-lock: <lock>``
    On a ``def`` line (or the line above): the whole function body runs
    with ``<lock>`` held by its callers.

``# jit-ok: <reason>``
    On a line inside a jit-reachable function: the flagged host-sync
    call is intentional and safe (e.g. operates on a static Python
    value at trace time).

``# fault-covered: <point>``
    On a ``def`` line (or the line above): the function's I/O flows
    through the named registered injection point elsewhere on the same
    data path.  ``<point>`` must be a member of
    :data:`repro.faults.INJECTION_POINTS` — a typo is itself a
    violation, so the annotation can't rot.

Files matching an entry in ``analysis/quarantine.txt`` are skipped
entirely (dead seed scaffolding; see ARCHITECTURE.md).
"""

from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass

__all__ = [
    "Violation",
    "SourceFile",
    "Checker",
    "register",
    "all_checkers",
    "load_quarantine",
    "is_quarantined",
    "iter_source_files",
    "run_checkers",
    "DEFAULT_QUARANTINE",
]

_PRAGMA_RE = re.compile(r"#\s*lint:\s*disable=([\w,\s-]+?)\s*--\s*\S")
_PRAGMA_NO_REASON_RE = re.compile(r"#\s*lint:\s*disable=([\w,\s-]+)\s*$")
_GUARDED_BY_RE = re.compile(r"#\s*guarded-by:\s*(\w+)")
_HOLDS_LOCK_RE = re.compile(r"#\s*holds-lock:\s*(\w+)")
_JIT_OK_RE = re.compile(r"#\s*jit-ok:\s*\S")
_FAULT_COVERED_RE = re.compile(r"#\s*fault-covered:\s*([\w.]+)")

#: quarantine list shipped next to the analysis package
DEFAULT_QUARANTINE = os.path.join(
    os.path.dirname(os.path.dirname(os.path.abspath(__file__))),
    "quarantine.txt",
)


@dataclass(frozen=True)
class Violation:
    """One finding: which checker, where, what."""

    checker: str
    path: str
    line: int
    message: str

    def render(self) -> str:
        return f"{self.path}:{self.line}: [{self.checker}] {self.message}"


class SourceFile:
    """One parsed source file plus its per-line annotations."""

    def __init__(self, path: str, text: str):
        self.path = path
        self.text = text
        self.lines = text.splitlines()
        self.tree = ast.parse(text, filename=path)  # may raise SyntaxError
        self._disabled: dict[int, frozenset[str]] = {}
        self._bare_pragmas: list[int] = []
        for i, ln in enumerate(self.lines, 1):
            m = _PRAGMA_RE.search(ln)
            if m:
                names = (s.strip() for s in m.group(1).split(","))
                self._disabled[i] = frozenset(s for s in names if s)
            elif _PRAGMA_NO_REASON_RE.search(ln):
                # a pragma with no `-- reason` suppresses nothing
                self._bare_pragmas.append(i)

    @classmethod
    def load(cls, path: str) -> "SourceFile":
        # lint tooling reading source text, not simulator state I/O
        fh = open(path, encoding="utf-8")  # lint: disable=fault-coverage -- tool IO
        with fh:
            return cls(path, fh.read())

    # -- annotation lookups --------------------------------------------------
    def line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1]
        return ""

    def disabled(self, lineno: int, checker: str) -> bool:
        names = self._disabled.get(lineno, ())
        return checker in names or "all" in names

    def reasonless_pragmas(self) -> list[int]:
        """Lines carrying a ``lint: disable`` with no ``-- reason``."""
        return list(self._bare_pragmas)

    def guarded_by(self, lineno: int) -> str | None:
        m = _GUARDED_BY_RE.search(self.line(lineno))
        return m.group(1) if m else None

    def jit_ok(self, lineno: int) -> bool:
        return bool(_JIT_OK_RE.search(self.line(lineno)))

    def _def_annotation(self, node: ast.AST, regex: re.Pattern) -> list[str]:
        """Matches of ``regex`` on the def line or the line above it."""
        out = []
        for lineno in (node.lineno, node.lineno - 1):
            m = regex.search(self.line(lineno))
            if m:
                out.append(m.group(1))
        return out

    def holds_locks(self, func: ast.AST) -> set[str]:
        return set(self._def_annotation(func, _HOLDS_LOCK_RE))

    def fault_covered(self, func: ast.AST) -> list[str]:
        return self._def_annotation(func, _FAULT_COVERED_RE)


class Checker:
    """Base class.  Subclasses set ``name``/``description`` and override
    :meth:`check` (per-file) or :meth:`check_project` (whole-program)."""

    name: str = ""
    description: str = ""

    def check(self, src: SourceFile) -> list[Violation]:
        return []

    def check_project(self, files: list[SourceFile]) -> list[Violation]:
        out: list[Violation] = []
        for src in files:
            out.extend(self.check(src))
        return out


_REGISTRY: dict[str, type[Checker]] = {}


def register(cls: type[Checker]) -> type[Checker]:
    if not cls.name:
        raise ValueError(f"checker {cls.__name__} has no name")
    if cls.name in _REGISTRY:
        raise ValueError(f"duplicate checker name {cls.name!r}")
    _REGISTRY[cls.name] = cls
    return cls


def all_checkers() -> dict[str, type[Checker]]:
    return dict(_REGISTRY)


# -- quarantine + walking ----------------------------------------------------
def load_quarantine(path: str | None = None) -> list[tuple[str, str]]:
    """Parse the quarantine file into ``(path_fragment, reason)`` pairs."""
    path = path or DEFAULT_QUARANTINE
    if not os.path.exists(path):
        return []
    out = []
    fh = open(path, encoding="utf-8")  # lint: disable=fault-coverage -- tool IO
    with fh:
        for raw in fh:
            line = raw.strip()
            if not line or line.startswith("#"):
                continue
            frag, _, reason = line.partition("#")
            frag = frag.strip().rstrip("/")
            if frag:
                out.append((frag, reason.strip()))
    return out


def is_quarantined(path: str, quarantine: list[tuple[str, str]]) -> bool:
    norm = "/" + os.path.abspath(path).replace(os.sep, "/").lstrip("/")
    for frag, _reason in quarantine:
        if f"/{frag}/" in norm or norm.endswith(f"/{frag}"):
            return True
    return False


def iter_source_files(paths, quarantine):
    """Yield ``(path, SourceFile | SyntaxError | None)`` for every .py
    under ``paths`` — ``None`` marks a quarantined (skipped) file."""
    seen = set()
    for root in paths:
        if os.path.isfile(root):
            candidates = [root]
        else:
            candidates = []
            for dirpath, dirnames, filenames in os.walk(root):
                dirnames[:] = sorted(
                    d for d in dirnames if d not in ("__pycache__", ".git")
                )
                candidates.extend(
                    os.path.join(dirpath, f)
                    for f in sorted(filenames)
                    if f.endswith(".py")
                )
        for path in candidates:
            key = os.path.abspath(path)
            if key in seen:
                continue
            seen.add(key)
            if is_quarantined(path, quarantine):
                yield path, None
                continue
            try:
                yield path, SourceFile.load(path)
            except SyntaxError as exc:
                yield path, exc


def run_checkers(paths, select=None, quarantine_path=None, use_quarantine=True):
    """Run (selected) checkers over every live source under ``paths``.

    Returns ``(violations, n_checked, skipped)`` — ``skipped`` is the
    list of quarantined paths, so callers can surface what the gate did
    NOT look at.
    """
    from . import fault_coverage, jit_purity, lock_discipline  # noqa: F401
    from . import typed_errors  # noqa: F401  (register on import)

    registry = all_checkers()
    names = list(registry) if select is None else list(select)
    unknown = [nm for nm in names if nm not in registry]
    if unknown:
        raise ValueError(
            f"unknown checker(s) {unknown}; available: {sorted(registry)}"
        )

    quarantine = load_quarantine(quarantine_path) if use_quarantine else []
    files: list[SourceFile] = []
    skipped: list[str] = []
    violations: list[Violation] = []
    for path, src in iter_source_files(paths, quarantine):
        if src is None:
            skipped.append(path)
        elif isinstance(src, SyntaxError):
            v = Violation("parse", path, src.lineno or 0, f"syntax error: {src.msg}")
            violations.append(v)
        else:
            files.append(src)
            for lineno in src.reasonless_pragmas():
                msg = (
                    "lint: disable pragma without a '-- reason' justification "
                    "(it suppresses nothing)"
                )
                violations.append(Violation("pragma", path, lineno, msg))

    for nm in names:
        violations.extend(registry[nm]().check_project(files))
    violations.sort(key=lambda v: (v.path, v.line, v.checker))
    return violations, len(files), skipped
