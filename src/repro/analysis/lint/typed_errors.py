"""typed-errors: the failure contract stays typed.

repro.errors defines the complete failure vocabulary of the public
paths (StoreIOError, BlockCorruptionError, CheckpointError,
ResumableError, MemoryPressureError, PlanVerificationError).  Raw
``Exception`` raising or broad swallowing erases the context the
resilience layer depends on (what failed, whether it is resumable).

Rules:

* ``raise Exception(...)`` / ``raise BaseException(...)`` — always a
  violation: raise a :mod:`repro.errors` type (or a stdlib type that
  one of them subclasses).
* bare ``except:`` — always a violation.
* ``except Exception`` / ``except BaseException`` (alone or in a
  tuple) — a violation *unless* the handler re-raises: a handler whose
  last statement is a bare ``raise`` is cleanup code, not swallowing,
  and is allowed as-is.  Anything else needs
  ``# lint: disable=typed-errors -- <reason>`` on the ``except`` line —
  the explicit allowlist-with-justification.
"""

from __future__ import annotations

import ast

from .base import Checker, SourceFile, Violation, register

_BROAD = ("Exception", "BaseException")


def _names(expr: ast.AST):
    """Exception names in an except clause (handles tuples)."""
    if expr is None:
        return
    nodes = expr.elts if isinstance(expr, ast.Tuple) else [expr]
    for node in nodes:
        if isinstance(node, ast.Name):
            yield node.id
        elif isinstance(node, ast.Attribute):
            yield node.attr


def _reraises(handler: ast.ExceptHandler) -> bool:
    """Handler body ends in a bare ``raise`` (cleanup/re-raise idiom)."""
    body = handler.body
    if not body or not isinstance(body[-1], ast.Raise):
        return False
    return body[-1].exc is None


def _raised_name(node: ast.Raise) -> str | None:
    exc = node.exc
    if isinstance(exc, ast.Call) and isinstance(exc.func, ast.Name):
        return exc.func.id
    if isinstance(exc, ast.Name):
        return exc.id
    return None


@register
class TypedErrors(Checker):
    name = "typed-errors"
    description = "raise typed errors; no unjustified broad excepts"

    def check(self, src: SourceFile) -> list[Violation]:
        out: list[Violation] = []
        for node in ast.walk(src.tree):
            if isinstance(node, ast.Raise):
                name = _raised_name(node)
                if name in _BROAD and not src.disabled(node.lineno, self.name):
                    msg = f"raise {name} — use a typed error from repro.errors"
                    out.append(Violation(self.name, src.path, node.lineno, msg))
            elif isinstance(node, ast.ExceptHandler):
                if src.disabled(node.lineno, self.name):
                    continue
                if node.type is None:
                    msg = (
                        "bare 'except:' swallows everything including "
                        "KeyboardInterrupt — name the exception types"
                    )
                    out.append(Violation(self.name, src.path, node.lineno, msg))
                    continue
                broad = [nm for nm in _names(node.type) if nm in _BROAD]
                if broad and not _reraises(node):
                    msg = (
                        f"'except {broad[0]}' without re-raise — narrow to "
                        f"the repro.errors types the block can actually "
                        f"produce, or justify with "
                        f"'# lint: disable=typed-errors -- <reason>'"
                    )
                    out.append(Violation(self.name, src.path, node.lineno, msg))
        return out
