"""fault-coverage: the injection surface must not silently shrink.

The resilience layer (repro.faults) only exercises failure paths that
actually pass a registered ``fault_point``.  A new disk read, codec
call or checkpoint path added *without* one is invisible to the chaos
sweep and the crash/resume tests — the exact rot this checker stops.

Rule: any function whose body (excluding nested ``def``s, which are
checked as their own scopes) performs raw file I/O (``open``/``os.open``)
or calls a codec primitive must either

* call ``fault_point(...)`` in the same scope,
* carry ``# fault-covered: <registered point>`` on its ``def`` line
  (the data path is instrumented elsewhere — say where), or
* suppress the specific line with a justified pragma:
  ``# lint: disable=fault-coverage -- reason`` (the reason is mandatory).

The checker also validates every literal point name passed to
``fault_point`` / listed in ``# fault-covered:`` against
``repro.faults.INJECTION_POINTS``, so typos surface statically instead
of as never-firing injections.
"""

from __future__ import annotations

import ast

from ...faults import INJECTION_POINTS
from .base import Checker, SourceFile, Violation, register

#: the compression layer's encode/decode/wire primitives — every call
#: site is a byte-touching seam that must be on the injection surface
CODEC_PRIMITIVES = frozenset(
    {
        "encode_block_host",
        "decode_block_host",
        "encode_group_planes",
        "decode_blocks_planes",
        "segments_to_wire",
        "wire_to_segments",
        "fetch_group_wire",
    }
)

_FUNC_DEFS = (ast.FunctionDef, ast.AsyncFunctionDef)


def _call_name(node: ast.Call) -> str | None:
    """Bare or attribute call name: ``open(...)`` -> "open",
    ``os.open(...)`` -> "os.open", ``codec.encode_block_host`` ->
    "encode_block_host" (attribute calls match by terminal name)."""
    f = node.func
    if isinstance(f, ast.Name):
        return f.id
    if isinstance(f, ast.Attribute):
        if isinstance(f.value, ast.Name) and f.value.id == "os" and f.attr == "open":
            return "os.open"
        return f.attr
    return None


def _own_statements(func: ast.AST):
    """Walk a function body, stopping at nested function/class scopes."""
    stack = list(getattr(func, "body", []))
    while stack:
        node = stack.pop()
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef, ast.ClassDef)):
            continue
        yield node
        stack.extend(ast.iter_child_nodes(node))


@register
class FaultCoverage(Checker):
    name = "fault-coverage"
    description = "raw I/O and codec calls must pass a registered fault_point"

    def check(self, src: SourceFile) -> list[Violation]:
        out: list[Violation] = []
        funcs = [n for n in ast.walk(src.tree) if isinstance(n, _FUNC_DEFS)]
        for func in funcs:
            triggers: list[tuple[int, str]] = []
            covered = False
            for node in _own_statements(func):
                if not isinstance(node, ast.Call):
                    continue
                name = _call_name(node)
                if name == "fault_point":
                    covered = True
                    # validate a literal point name against the registry
                    if node.args and isinstance(node.args[0], ast.Constant):
                        point = node.args[0].value
                        if point not in INJECTION_POINTS:
                            msg = (
                                f"fault_point({point!r}) is not a registered "
                                f"injection point (see "
                                f"repro.faults.INJECTION_POINTS)"
                            )
                            v = Violation(self.name, src.path, node.lineno, msg)
                            out.append(v)
                elif name in ("open", "os.open"):
                    triggers.append((node.lineno, f"{name}()"))
                elif name in CODEC_PRIMITIVES and func.name != name:
                    triggers.append((node.lineno, f"{name}()"))
            if not triggers or covered:
                continue
            annotations = src.fault_covered(func)
            bad = [p for p in annotations if p not in INJECTION_POINTS]
            for p in bad:
                msg = f"# fault-covered: {p!r} is not a registered injection point"
                out.append(Violation(self.name, src.path, func.lineno, msg))
            if annotations and not bad:
                continue
            for lineno, what in triggers:
                if src.disabled(lineno, self.name):
                    continue
                msg = (
                    f"{what} in {func.name}() without a fault_point on its "
                    f"path — add one, or annotate the def with "
                    f"'# fault-covered: <point>'"
                )
                out.append(Violation(self.name, src.path, lineno, msg))
        return out
