"""Project-specific AST lint framework (see ``base`` for conventions).

Importing this package registers the four shipped checkers:
fault-coverage, lock-discipline, jit-purity, typed-errors.
"""

from . import fault_coverage  # noqa: F401
from . import jit_purity  # noqa: F401
from . import lock_discipline  # noqa: F401
from . import typed_errors  # noqa: F401
from .base import (
    Checker,
    SourceFile,
    Violation,
    all_checkers,
    is_quarantined,
    load_quarantine,
    register,
    run_checkers,
)

__all__ = [
    "Checker",
    "SourceFile",
    "Violation",
    "all_checkers",
    "is_quarantined",
    "load_quarantine",
    "register",
    "run_checkers",
]
