"""ExecutionPlan verifier: prove a plan is safe to execute verbatim.

The engine executes an :class:`~repro.core.plan.ExecutionPlan` without
re-deriving anything — stage layouts, gate slices, compiled schedules
and byte predictions are trusted as written.  The plan's
:attr:`~repro.core.plan.ExecutionPlan.fingerprint` deliberately covers
only the *state-layout* half (inner sets + slice lengths), so a plan
whose ``gate_slice`` was shifted, whose ``GroupLayout`` chain disagrees
with the plan-level knobs, or whose predictions were tampered with is
fingerprint-identical to a good one.  This module closes that gap with
a pure structural pass:

* **layout flow** — every stage's :class:`GroupLayout` chains to the
  plan-level ``(n_qubits, local_bits)``, its inner set is sorted,
  in-range and within the partition threshold;
* **gate tiling** — the stage ``gate_slice`` ranges tile ``[0, n_gates)``
  contiguously with no gaps or overlaps, and (when the circuit is at
  hand) each slice's global support equals the stage's inner set;
* **schedule replay** — each stage's compiled permutation plan is
  replayed: every ``TransposeOp.perm`` is a true permutation, the
  composition returns the group tensor to the canonical layout, and the
  recorded transpose counts match the schedule's;
* **byte self-consistency** — every byte prediction is recomputed from
  the planner's own cost model and compared exactly; a predicted
  working set above ``memory_budget_bytes`` is surfaced as a *warning*
  (the store's spill tier is the documented backstop, and the planner
  already warns when it plans over budget).

Wired in as the default ``Simulator.compile(verify=True)`` and the
plan-only ``qsim --verify`` (zero stages executed, like ``--explain``).

:func:`verify_plan` returns findings; :func:`check_plan` raises
:class:`~repro.errors.PlanVerificationError` on any error-severity
finding.
"""

from __future__ import annotations

import math
from dataclasses import dataclass

from ..core.plan import ExecutionPlan, circuit_fingerprint
from ..core.planner import (
    _BLOCK_OVERHEAD,
    _predict_working_set,
    estimate_bytes_per_amp,
    predict_depth_speedup,
    wire_bytes_per_block,
)
from ..core.schedule import TransposeOp, compile_schedule
from ..errors import PlanVerificationError

__all__ = ["PlanFinding", "verify_plan", "check_plan"]


@dataclass(frozen=True)
class PlanFinding:
    """One verifier finding.

    Attributes:
        severity: ``"error"`` (plan must not execute) or ``"warning"``
            (suspicious but executable — e.g. over-budget working set,
            which the spill tier absorbs by design).
        code: stable machine-readable identifier (``gate-tiling``,
            ``layout-chain``, ``schedule-replay``, ``predictions``, ...).
        message: human-readable description.
        stage: stage index the finding is anchored to, or None for
            whole-plan findings.
    """

    severity: str
    code: str
    message: str
    stage: int | None = None

    def render(self) -> str:
        where = f"stage {self.stage}: " if self.stage is not None else ""
        return f"[{self.severity}] {self.code}: {where}{self.message}"


def _isclose(a: float, b: float) -> bool:
    # predictions round-trip JSON exactly (IEEE doubles), so the
    # tolerance only needs to absorb float re-derivation, not drift
    return math.isclose(a, b, rel_tol=1e-9, abs_tol=1e-12)


def _check_knobs(plan: ExecutionPlan, err) -> bool:
    """Plan-level knob sanity; False means layout math below is bogus."""
    n, b = plan.n_qubits, plan.local_bits
    if not 0 <= b <= n:
        err("knobs", f"local_bits={b} out of range for n_qubits={n}")
        return False
    if plan.inner_size < 1:
        err("knobs", f"inner_size={plan.inner_size} must be >= 1")
    if plan.pipeline_depth < 1:
        err("knobs", f"pipeline_depth={plan.pipeline_depth} must be >= 1")
    if plan.b_r <= 0:
        err("knobs", f"b_r={plan.b_r} must be > 0")
    if plan.n_devices < 1:
        err("knobs", f"n_devices={plan.n_devices} must be >= 1")
    if plan.batch < 1:
        err("knobs", f"batch={plan.batch} must be >= 1")
    return True


def _check_layout(plan: ExecutionPlan, sp, i: int, thr: int, err) -> None:
    """Layout chain: every stage must agree with the plan-level state
    layout — the fingerprint only covers the inner set, so a layout
    rebuilt with the wrong (n_qubits, local_bits) is invisible to it."""
    n, b = plan.n_qubits, plan.local_bits
    lay = sp.layout
    if lay.n_qubits != n:
        msg = f"layout.n_qubits={lay.n_qubits} != plan n_qubits={n}"
        err("layout-chain", msg, i)
    if lay.local_bits != b:
        msg = f"layout.local_bits={lay.local_bits} != plan local_bits={b}"
        err("layout-chain", msg, i)
    inner = lay.inner
    if list(inner) != sorted(set(inner)):
        err("layout-chain", f"inner set {inner} is not strictly increasing", i)
    bad = [q for q in inner if not b <= q < n]
    if bad:
        msg = f"inner qubits {bad} outside global range [{b}, {n})"
        err("layout-chain", msg, i)
    if lay.m > thr:
        msg = f"stage has {lay.m} inner qubits > partition threshold {thr}"
        err("layout-chain", msg, i)


def _check_schedule(sp, nv: int, i: int, err) -> None:
    """Schedule replay: recompile the stage schedule and replay its
    permutation plan — it must compose back to the identity layout."""
    if not sp.plan:
        if sp.n_transposes or sp.n_transposes_naive:
            err("schedule-replay", "empty fused plan but nonzero transpose counts", i)
        return
    sched = compile_schedule(sp.plan, nv)
    ident = tuple(range(nv))
    cur = ident
    n_t = 0
    valid = True
    for op in sched.ops:
        if not isinstance(op, TransposeOp):
            continue
        n_t += 1
        if sorted(op.perm) != list(ident):
            msg = f"transpose perm {op.perm} is not a permutation of {nv} axes"
            err("schedule-replay", msg, i)
            valid = False
            break
        cur = tuple(cur[p] for p in op.perm)
    if valid:
        if cur != ident:
            msg = (
                f"transpose chain composes to {cur}, not identity — "
                f"the stage would emit a permuted state"
            )
            err("schedule-replay", msg, i)
        if n_t != sched.n_transposes:
            msg = (
                f"schedule op list has {n_t} transposes but records "
                f"n_transposes={sched.n_transposes}"
            )
            err("schedule-replay", msg, i)
    if sp.n_transposes != sched.n_transposes:
        msg = (
            f"stage records {sp.n_transposes} transposes, "
            f"compiled schedule has {sched.n_transposes}"
        )
        err("schedule-replay", msg, i)
    if sp.n_transposes_naive != sched.n_transposes_naive:
        msg = (
            f"stage records {sp.n_transposes_naive} naive transposes, "
            f"schedule has {sched.n_transposes_naive}"
        )
        err("schedule-replay", msg, i)


def _check_circuit(plan: ExecutionPlan, circuit, gate_hi: int, err) -> None:
    """Gate tiling against the circuit itself (fingerprint, length and
    per-stage global support) — the checks a deserialized plan alone
    cannot do."""
    n, b = plan.n_qubits, plan.local_bits
    if circuit.n_qubits != n:
        msg = f"circuit has {circuit.n_qubits} qubits, plan has {n}"
        err("gate-tiling", msg)
    fp = circuit_fingerprint(circuit)
    if fp != plan.circuit_fp:
        msg = (
            f"circuit fingerprint {fp[:12]} != plan circuit_fp "
            f"{plan.circuit_fp[:12]}"
        )
        err("gate-tiling", msg)
    n_gates = len(circuit.gates)
    if gate_hi != n_gates:
        msg = (
            f"stage slices cover [0, {gate_hi}) but the circuit "
            f"has {n_gates} gates"
        )
        err("gate-tiling", msg)
    for sp in plan.stages:
        lo, hi = sp.gate_slice
        sup = {q for g in circuit.gates[lo:hi] for q in g.qubits if q >= b}
        if sup != set(sp.layout.inner):
            msg = (
                f"gates[{lo}:{hi}] global support {sorted(sup)} != "
                f"stage inner set {list(sp.layout.inner)}"
            )
            err("gate-tiling", msg, sp.index)


def verify_plan(plan: ExecutionPlan, circuit=None) -> list[PlanFinding]:
    """Run every check; returns all findings (empty list = clean).

    ``circuit`` is optional: with it, the gate slices are additionally
    checked against the circuit's length, fingerprint and per-stage
    global support (the checks a deserialized plan alone cannot do).
    """
    out: list[PlanFinding] = []

    def err(code, msg, stage=None):
        out.append(PlanFinding("error", code, msg, stage))

    def warn(code, msg, stage=None):
        out.append(PlanFinding("warning", code, msg, stage))

    n, b = plan.n_qubits, plan.local_bits
    if not _check_knobs(plan, err):
        return out

    # partition's effective threshold (see partition_circuit): the
    # requested inner_size is clamped to at least 2 (two-qubit gates)
    # and to the number of global bits
    thr = max(plan.inner_size, 2)
    if thr > n - b:
        thr = max(n - b, 0)

    # -- per-stage structure -------------------------------------------------
    gate_hi = 0
    tot_t = tot_tn = tot_boundary = 0
    max_m = 0
    wire = wire_bytes_per_block(1 << b, plan.codec_backend, plan.compression)
    for i, sp in enumerate(plan.stages):
        if sp.index != i:
            err("stage-index", f"recorded index {sp.index} != position {i}", i)
        _check_layout(plan, sp, i, thr, err)
        max_m = max(max_m, sp.layout.m)
        if sp.n_devices != plan.n_devices:
            msg = f"stage n_devices={sp.n_devices} != plan n_devices={plan.n_devices}"
            err("placement", msg, i)
        else:
            # placement replay: every group must land on a real mesh slot
            # (the engine trusts device_slot verbatim for its group ->
            # device map and the exchange ledger).  Bounded: the slot
            # assignment is periodic in n_devices, so the first 64k
            # groups witness every residue class many times over.
            for g in range(min(sp.layout.n_groups, 1 << 16)):
                slot = sp.device_slot(g)
                if not 0 <= slot < plan.n_devices:
                    msg = (
                        f"group {g} maps to device slot {slot}, outside "
                        f"mesh [0, {plan.n_devices})"
                    )
                    err("placement", msg, i)
                    break

        # gate tiling: slices must cover the circuit contiguously —
        # a shifted slice of equal length passes the fingerprint but
        # would apply the wrong gates to the wrong stage layout
        lo, hi = sp.gate_slice
        if lo > hi:
            err("gate-tiling", f"gate_slice ({lo}, {hi}) is reversed", i)
        elif lo != gate_hi:
            msg = (
                f"gate_slice starts at {lo}, expected {gate_hi} "
                f"(gap or overlap with previous stage)"
            )
            err("gate-tiling", msg, i)
        gate_hi = max(gate_hi, hi)

        # fused plan: virtual qubits must be unique and inside the group
        nv = sp.layout.b + sp.layout.m
        for gi, (vq, _diag) in enumerate(sp.plan):
            if len(set(vq)) != len(vq) or any(not 0 <= q < nv for q in vq):
                msg = f"fused gate {gi} vqubits {vq} invalid for nv={nv}"
                err("fused-plan", msg, i)

        # stage-fn key: the engine compiles (or reuses) exactly this key;
        # a stale key silently runs the wrong jitted function
        key = (sp.plan, nv, plan.use_kernel, plan.gate_schedule, plan.interpret)
        if sp.stagefn_key != key:
            msg = f"stagefn_key {sp.stagefn_key!r} != expected {key!r}"
            err("stagefn-key", msg, i)

        _check_schedule(sp, nv, i, err)

        # per-stage boundary traffic from the planner's wire model
        lay = sp.layout
        stage_bytes = wire * lay.n_groups * lay.blocks_per_group * max(1, plan.batch)
        if sp.est_h2d_bytes != stage_bytes:
            msg = f"est_h2d_bytes={sp.est_h2d_bytes} != wire model {stage_bytes}"
            err("predictions", msg, i)
        if sp.est_d2h_bytes != stage_bytes:
            msg = f"est_d2h_bytes={sp.est_d2h_bytes} != wire model {stage_bytes}"
            err("predictions", msg, i)
        tot_boundary += 2 * stage_bytes
        tot_t += sp.n_transposes * lay.n_groups
        tot_tn += sp.n_transposes_naive * lay.n_groups

    if circuit is not None:
        _check_circuit(plan, circuit, gate_hi, err)

    # -- whole-plan predictions ---------------------------------------------
    p = plan.predicted
    bpa = estimate_bytes_per_amp(plan.b_r, plan.compression)
    if not _isclose(p.bytes_per_amp, bpa):
        err("predictions", f"bytes_per_amp={p.bytes_per_amp} != cost model {bpa}")
    state_bytes = int((1 << n) * bpa) + (1 << (n - b)) * _BLOCK_OVERHEAD
    if p.state_bytes != state_bytes:
        msg = f"state_bytes={p.state_bytes} != cost model {state_bytes}"
        err("predictions", msg)
    peak_ram, pipeline = _predict_working_set(
        n, b, max_m, plan.pipeline_depth, bpa, max(1, plan.batch)
    )
    if p.peak_ram_bytes != peak_ram:
        msg = f"peak_ram_bytes={p.peak_ram_bytes} != cost model {peak_ram}"
        err("predictions", msg)
    if p.pipeline_bytes != pipeline:
        msg = f"pipeline_bytes={p.pipeline_bytes} != cost model {pipeline}"
        err("predictions", msg)
    if p.boundary_bytes != tot_boundary:
        msg = f"boundary_bytes={p.boundary_bytes} != sum of stage traffic {tot_boundary}"
        err("predictions", msg)
    if p.n_transposes != tot_t:
        msg = f"n_transposes={p.n_transposes} != group-weighted stage total {tot_t}"
        err("predictions", msg)
    if p.n_transposes_naive != tot_tn:
        msg = (
            f"n_transposes_naive={p.n_transposes_naive} != "
            f"group-weighted stage total {tot_tn}"
        )
        err("predictions", msg)
    speedup = predict_depth_speedup(plan.pipeline_depth)
    if not _isclose(p.depth_speedup, speedup):
        msg = f"depth_speedup={p.depth_speedup} != overlap model {speedup}"
        err("predictions", msg)
    dev_peak, dev_pipe = _predict_working_set(
        n, b, max_m, plan.pipeline_depth, bpa, max(1, plan.batch), plan.n_devices
    )
    if p.per_device_peak_bytes != dev_peak + dev_pipe:
        msg = (
            f"per_device_peak_bytes={p.per_device_peak_bytes} != "
            f"cost model {dev_peak + dev_pipe} for {plan.n_devices} device(s)"
        )
        err("predictions", msg)

    # over-budget is a warning: the planner documents planning the
    # smallest candidate over budget and relying on the disk spill tier.
    # The budget is per device — the busiest device's predicted share is
    # what must fit (identical to the whole working set at n_devices=1)
    budget = plan.memory_budget_bytes
    if budget is not None and p.per_device_peak_bytes > budget:
        msg = (
            f"predicted per-device peak {p.per_device_peak_bytes} B exceeds "
            f"the per-device memory budget {budget} B — the run will lean "
            f"on the disk spill tier"
        )
        warn("budget", msg)
    # ragged lane shards are legal but cost one extra jit specialization
    # per distinct shard width — surface the split explicitly
    if plan.batch > 1 and plan.n_devices > 1 and plan.batch % plan.n_devices:
        msg = (
            f"batch={plan.batch} does not divide over {plan.n_devices} "
            f"devices — lane shards are ragged "
            f"({plan.batch % plan.n_devices} device(s) carry an extra lane)"
        )
        warn("placement", msg)
    return out


def check_plan(plan: ExecutionPlan, circuit=None) -> list[PlanFinding]:
    """:func:`verify_plan`, raising on errors.

    Returns the (possibly warning-bearing) findings when the plan is
    executable; raises :class:`PlanVerificationError` carrying every
    finding when any error-severity finding exists.
    """
    findings = verify_plan(plan, circuit)
    errors = [f for f in findings if f.severity == "error"]
    if errors:
        head = "; ".join(f.render() for f in errors[:3])
        more = f" (+{len(errors) - 3} more)" if len(errors) > 3 else ""
        raise PlanVerificationError(
            f"ExecutionPlan failed verification: {head}{more}", findings
        )
    return findings
