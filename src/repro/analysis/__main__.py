"""CLI: ``python -m repro.analysis [paths...]``.

Runs the registered AST checkers over every live (non-quarantined)
``.py`` file under the given paths and exits nonzero on any violation —
the CI ``static-analysis`` gate.  ``--plan plan.json`` instead verifies
a serialized :class:`~repro.core.plan.ExecutionPlan` (same pass as
``qsim --verify``, for plan artifacts at rest).
"""

from __future__ import annotations

import argparse
import sys

from .lint import all_checkers, run_checkers


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="BMQSim static analysis: project lint + plan verify",
    )
    ap.add_argument(
        "paths",
        nargs="*",
        help="files or directories to lint (e.g. src/repro)",
    )
    ap.add_argument(
        "--select",
        metavar="NAMES",
        help="comma-separated checker names (default: all)",
    )
    ap.add_argument(
        "--list",
        action="store_true",
        help="list registered checkers and exit",
    )
    ap.add_argument(
        "--no-quarantine",
        action="store_true",
        help="also lint files matching analysis/quarantine.txt",
    )
    ap.add_argument(
        "--plan",
        metavar="PLAN_JSON",
        help="verify a serialized ExecutionPlan instead of linting",
    )
    args = ap.parse_args(argv)

    if args.list:
        for name, cls in sorted(all_checkers().items()):
            print(f"{name:16s} {cls.description}")
        return 0

    if args.plan:
        from ..core.plan import ExecutionPlan
        from .plan_check import verify_plan

        fh = open(args.plan, encoding="utf-8")  # lint: disable=fault-coverage -- CLI
        with fh:
            plan = ExecutionPlan.from_json(fh.read())
        findings = verify_plan(plan)
        for f in findings:
            print(f.render())
        errors = sum(f.severity == "error" for f in findings)
        summary = f"{errors} error(s), {len(findings) - errors} warning(s)"
        print(f"plan {plan.fingerprint[:12]}: {summary}")
        return 1 if errors else 0

    if not args.paths:
        ap.error("no paths given (try: python -m repro.analysis src/repro)")
    select = None
    if args.select:
        select = [s.strip() for s in args.select.split(",") if s.strip()]
    violations, n_files, skipped = run_checkers(
        args.paths,
        select=select,
        use_quarantine=not args.no_quarantine,
    )
    for v in violations:
        print(v.render())
    tail = f", {len(skipped)} quarantined file(s) skipped" if skipped else ""
    print(f"{len(violations)} violation(s) in {n_files} file(s) checked{tail}")
    return 1 if violations else 0


if __name__ == "__main__":
    sys.exit(main())
