"""Static analysis & invariant verification for the simulator.

Two halves (see ARCHITECTURE.md "Static analysis & invariants"):

* :mod:`repro.analysis.plan_check` — a pure pass over
  :class:`~repro.core.plan.ExecutionPlan` proving layout flow, gate
  tiling, schedule composition and byte predictions are internally
  consistent before the engine executes the plan verbatim.  Runs by
  default in ``Simulator.compile(verify=True)`` and as the plan-only
  ``qsim --verify``.
* :mod:`repro.analysis.lint` — an AST checker framework
  (``python -m repro.analysis src/repro``) enforcing the project's
  cross-cutting invariants: fault-point coverage, lock discipline,
  jit purity and the typed-error contract.

``plan_check`` pulls in the planner (and through it jax), so it is
exposed lazily — linting stays importable in seconds on a cold cache.
"""

from __future__ import annotations

from .lint import Violation, all_checkers, run_checkers

__all__ = [
    "Violation",
    "all_checkers",
    "run_checkers",
    "PlanFinding",
    "verify_plan",
    "check_plan",
]

_PLAN_CHECK = ("PlanFinding", "verify_plan", "check_plan")


def __getattr__(name: str):
    if name in _PLAN_CHECK:
        from . import plan_check

        return getattr(plan_check, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
