"""xLSTM blocks (arXiv:2405.04517): mLSTM (matrix memory) + sLSTM (scalar).

mLSTM — exponential-gated matrix-memory LSTM.  Training/prefill uses the
paper's *parallel (quadratic) form* — a gated-attention-like S x S kernel
with log-domain max stabilization; decode uses the O(1) recurrent form

    C_t = f_t C_{t-1} + i_t v_t k_t^T        (per head, C: hd x hd)
    n_t = f_t n_{t-1} + i_t k_t
    h_t = o_t ⊙ (C_t q_t) / max(|n_t·q_t|, exp(-m_t))

sLSTM — scalar-memory LSTM with exponential gating and a true nonlinear
recurrence (h feeds back into the gates), so training runs a ``lax.scan``
over time (no parallel form exists; this is the sequential member of the
block pattern and is why the assigned xlstm config is small).

Both are wrapped in the paper's block structure: pre-norm, up-projection
with a SiLU gate branch, mixer, down-projection.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Param, dense_init

__all__ = [
    "init_mlstm_params", "mlstm_full", "mlstm_decode", "init_mlstm_state",
    "init_slstm_params", "slstm_full", "slstm_decode", "init_slstm_state",
]

NEG_INF = -2.0 ** 30


# ===========================================================================
# mLSTM
# ===========================================================================

def init_mlstm_params(p: Param, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    H, hd = cfg.n_heads, d // cfg.n_heads
    return {
        "w_up": dense_init(p.next(), (d, 2 * d), dtype=dtype),   # mixer+gate
        "w_q": dense_init(p.next(), (d, H * hd), dtype=dtype),
        "w_k": dense_init(p.next(), (d, H * hd), dtype=dtype),
        "w_v": dense_init(p.next(), (d, H * hd), dtype=dtype),
        "w_if": dense_init(p.next(), (d, 2 * H), dtype=jnp.float32),
        "w_down": dense_init(p.next(), (d, d), dtype=dtype),
    }


def _mlstm_qkv(z: jax.Array, prm: dict, H: int):
    B, S, d = z.shape
    hd = d // H
    q = (z @ prm["w_q"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    k = (z @ prm["w_k"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    v = (z @ prm["w_v"]).reshape(B, S, H, hd).transpose(0, 2, 1, 3)
    gates = z.astype(jnp.float32) @ prm["w_if"]          # (B, S, 2H)
    i_raw = gates[..., :H].transpose(0, 2, 1)            # (B, H, S)
    f_raw = gates[..., H:].transpose(0, 2, 1)
    return q, k, v, i_raw, f_raw


def mlstm_full(x: jax.Array, prm: dict, cfg: ModelConfig,
               want_state: bool = False):
    """Parallel form. x: (B, S, d) -> (out, final_state | None).

    The final recurrent state is reconstructed exactly from the parallel
    quantities (telescoping the recurrence):
        m_S  = max_j (F_S - F_j + i~_j)
        w_j  = exp(F_S - F_j + i~_j - m_S)
        C_S  = sum_j w_j v_j (k_j/sqrt(hd))^T,   n_S = sum_j w_j k_j/sqrt(hd)
    so serve-prefill can hand decode an O(1) state.
    """
    B, S, d = x.shape
    H = cfg.n_heads
    hd = d // H
    up = x @ prm["w_up"]
    z, gate = up[..., :d], jax.nn.silu(up[..., d:])
    q, k, v, i_raw, f_raw = _mlstm_qkv(z, prm, H)

    logf = jax.nn.log_sigmoid(f_raw)                     # (B, H, S)
    F = jnp.cumsum(logf, axis=-1)                        # sum_{<=t} log f
    # D~_ij = F_i - F_j + i~_j   (j <= i)
    Dt = F[..., :, None] - F[..., None, :] + i_raw[..., None, :]
    causal = jnp.tril(jnp.ones((S, S), bool))
    Dt = jnp.where(causal[None, None], Dt, NEG_INF)
    m = jnp.max(Dt, axis=-1, keepdims=True)              # (B, H, S, 1)
    Dmat = jnp.exp(Dt - m)

    scores = jnp.einsum("bhsd,bhtd->bhst", q, k,
                        preferred_element_type=jnp.float32) * (hd ** -0.5)
    Smat = scores * Dmat
    nrm = jnp.maximum(jnp.abs(jnp.sum(Smat, axis=-1, keepdims=True)),
                      jnp.exp(-m))
    h = jnp.einsum("bhst,bhtd->bhsd", (Smat / nrm).astype(v.dtype), v)
    h = h.transpose(0, 2, 1, 3).reshape(B, S, d)
    out = (h * gate) @ prm["w_down"]

    state = None
    if want_state:
        w_log = F[..., -1:] - F + i_raw                  # (B, H, S)
        m_S = jnp.max(w_log, axis=-1)                    # (B, H)
        w = jnp.exp(w_log - m_S[..., None])
        kf = k.astype(jnp.float32) * (hd ** -0.5)
        vf = v.astype(jnp.float32)
        C_S = jnp.einsum("bhs,bhsd,bhse->bhde", w, vf, kf)
        n_S = jnp.einsum("bhs,bhsd->bhd", w, kf)
        state = {"C": C_S, "n": n_S, "m": m_S}
    return out, state


def init_mlstm_state(cfg: ModelConfig, batch: int, n_layers: int) -> dict:
    d = cfg.d_model
    H, hd = cfg.n_heads, d // cfg.n_heads
    return {
        "C": jnp.zeros((n_layers, batch, H, hd, hd), jnp.float32),
        "n": jnp.zeros((n_layers, batch, H, hd), jnp.float32),
        "m": jnp.zeros((n_layers, batch, H), jnp.float32),
    }


def mlstm_decode(x: jax.Array, prm: dict, cfg: ModelConfig,
                 C: jax.Array, n: jax.Array, m: jax.Array):
    """Recurrent step. x: (B, 1, d); C: (B,H,hd,hd); n: (B,H,hd); m: (B,H)."""
    B, _, d = x.shape
    H = cfg.n_heads
    hd = d // H
    up = x @ prm["w_up"]
    z, gate = up[..., :d], jax.nn.silu(up[..., d:])
    q, k, v, i_raw, f_raw = _mlstm_qkv(z, prm, H)
    q, k, v = q[:, :, 0], k[:, :, 0], v[:, :, 0]         # (B, H, hd)
    i_raw, f_raw = i_raw[..., 0], f_raw[..., 0]          # (B, H)

    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + m, i_raw)
    f_eff = jnp.exp(logf + m - m_new)[..., None]
    i_eff = jnp.exp(i_raw - m_new)[..., None]

    kf = k.astype(jnp.float32) * (hd ** -0.5)
    C_new = f_eff[..., None] * C + (i_eff[..., None]
                                    * v.astype(jnp.float32)[..., :, None]
                                    * kf[..., None, :])
    n_new = f_eff * n + i_eff * kf
    qf = q.astype(jnp.float32)
    num = jnp.einsum("bhde,bhe->bhd", C_new, qf)
    den = jnp.maximum(jnp.abs(jnp.sum(n_new * qf, axis=-1, keepdims=True)),
                      jnp.exp(-m_new)[..., None])
    h = (num / den).reshape(B, 1, d).astype(x.dtype)
    out = (h * gate) @ prm["w_down"]
    return out, C_new, n_new, m_new


# ===========================================================================
# sLSTM
# ===========================================================================

def init_slstm_params(p: Param, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    return {
        "w_gates": dense_init(p.next(), (d, 4 * d), dtype=dtype),   # i f z o
        "r_gates": dense_init(p.next(), (d, 4 * d), dtype=dtype),   # recurrent
        "b_gates": jnp.zeros((4 * d,), jnp.float32),
        "w_up": dense_init(p.next(), (d, 2 * d), dtype=dtype),      # post-FFN
        "w_down": dense_init(p.next(), (d, d), dtype=dtype),
    }


def _slstm_step(prm, carry, wx_t):
    """carry: (h, c, n, m) each (B, d) f32; wx_t: (B, 4d) f32."""
    h, c, n, m = carry
    raw = wx_t + h @ prm["r_gates"].astype(jnp.float32) + prm["b_gates"]
    i_raw, f_raw, z_raw, o_raw = jnp.split(raw, 4, axis=-1)
    logf = jax.nn.log_sigmoid(f_raw)
    m_new = jnp.maximum(logf + m, i_raw)
    i = jnp.exp(i_raw - m_new)
    f = jnp.exp(logf + m - m_new)
    c_new = f * c + i * jnp.tanh(z_raw)
    n_new = f * n + i
    h_new = jax.nn.sigmoid(o_raw) * c_new / jnp.maximum(n_new, 1e-6)
    return (h_new, c_new, n_new, m_new)


def slstm_full(x: jax.Array, prm: dict, cfg: ModelConfig):
    """Sequential scan over time. x: (B, S, d) -> (out, final carry)."""
    B, S, d = x.shape
    wx = (x @ prm["w_gates"]).astype(jnp.float32)        # (B, S, 4d)
    carry0 = tuple(jnp.zeros((B, d), jnp.float32) for _ in range(4))

    def step(carry, wx_t):
        new = _slstm_step(prm, carry, wx_t)
        return new, new[0]

    carry, hs = jax.lax.scan(step, carry0, wx.transpose(1, 0, 2))
    h = hs.transpose(1, 0, 2).astype(x.dtype)            # (B, S, d)
    up = h @ prm["w_up"]
    out = (up[..., :d] * jax.nn.silu(up[..., d:])) @ prm["w_down"]
    return out, carry


def init_slstm_state(cfg: ModelConfig, batch: int, n_layers: int) -> dict:
    d = cfg.d_model
    z = jnp.zeros((n_layers, batch, d), jnp.float32)
    return {"h": z, "c": z, "n": z, "m": z}


def slstm_decode(x: jax.Array, prm: dict, cfg: ModelConfig, carry):
    """One-token step; carry: (h, c, n, m) each (B, d)."""
    d = x.shape[-1]
    wx = (x[:, 0] @ prm["w_gates"]).astype(jnp.float32)
    carry = _slstm_step(prm, carry, wx)
    h = carry[0][:, None, :].astype(x.dtype)
    up = h @ prm["w_up"]
    out = (up[..., :d] * jax.nn.silu(up[..., d:])) @ prm["w_down"]
    return out, carry
