"""Feed-forward blocks: SwiGLU (llama-family) / GeLU (whisper)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .layers import Param, dense_init

__all__ = ["init_mlp_params", "mlp"]


def init_mlp_params(p: Param, d_model: int, d_ff: int, act: str,
                    dtype=jnp.bfloat16) -> dict:
    prm = {
        "w_in": dense_init(p.next(), (d_model, d_ff), dtype=dtype),
        "w_out": dense_init(p.next(), (d_ff, d_model), dtype=dtype),
    }
    if act == "silu":                 # gated
        prm["w_gate"] = dense_init(p.next(), (d_model, d_ff), dtype=dtype)
    return prm


def mlp(x: jax.Array, prm: dict, act: str = "silu") -> jax.Array:
    h = x @ prm["w_in"]
    if act == "silu":
        h = jax.nn.silu(x @ prm["w_gate"]) * h
    else:
        h = jax.nn.gelu(h)
    return h @ prm["w_out"]
