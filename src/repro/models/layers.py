"""Primitive layers: norms, RoPE, initializers (pure functions on pytrees)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["rms_norm", "rope", "rope_cos_sin", "dense_init", "Param",
           "maybe_constrain"]


def maybe_constrain(x: jax.Array, *spec) -> jax.Array:
    """Best-effort sharding constraint: applies only to axes that exist in
    the ambient mesh and divide the dim; silently a no-op on CPU/1-device
    (tests) so model code stays mesh-agnostic."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        fixed = []
        for dim, ax in zip(x.shape, spec):
            if ax is None:
                fixed.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            if not all(a in mesh.axis_names for a in axes):
                fixed.append(None)
                continue
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            fixed.append(ax if (size > 1 and dim % size == 0) else None)
        if all(f is None for f in fixed):
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*fixed))
    except Exception:
        return x


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dt)


def rope_cos_sin(positions: jax.Array, head_dim: int,
                 theta: float) -> tuple[jax.Array, jax.Array]:
    """positions (..., S) int -> cos/sin (..., S, head_dim/2) f32."""
    half = head_dim // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions.astype(jnp.float32)[..., None] * freqs
    return jnp.cos(ang), jnp.sin(ang)


def rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x: (B, S, H, hd); cos/sin: (B, S, hd/2) or (S, hd/2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    while cos.ndim < x.ndim:         # (S, hd/2) or (B, S, hd/2) -> (B,S,1,hd/2)
        cos = cos[..., None, :] if cos.ndim == x.ndim - 1 else cos[None]
        sin = sin[..., None, :] if sin.ndim == x.ndim - 1 else sin[None]
    o1 = x1 * cos - x2 * sin
    o2 = x2 * cos + x1 * sin
    return jnp.concatenate([o1, o2], axis=-1).astype(x.dtype)


def dense_init(key, shape, in_axis: int = 0, dtype=jnp.bfloat16):
    """Truncated-normal fan-in init."""
    fan_in = shape[in_axis] if isinstance(in_axis, int) else int(
        np.prod([shape[a] for a in in_axis]))
    std = (1.0 / max(1, fan_in)) ** 0.5
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32)
            * std).astype(dtype)


class Param:
    """Tiny helper to build param dicts with per-leaf PRNG splitting."""

    def __init__(self, key):
        self._key = key

    def next(self):
        self._key, sub = jax.random.split(self._key)
        return sub
