"""Decoder-only LM assembly over a layer-kind pattern.

Layers are applied as ``lax.scan`` over *pattern units* (config.py) so HLO
size stays flat in depth; the pattern remainder is unrolled.  One codebase
covers all assigned decoder families:

  attn / attn_local   GQA attention (full / sliding-window)
  cross_attn          cross-attention to stub image embeddings (VLM)
  rglru               RecurrentGemma temporal mixing
  mlstm / slstm       xLSTM blocks

Three modes:
  forward_train   tokens -> logits                     (no caches)
  forward_prefill tokens -> logits_last + caches       (serve prefill)
  forward_decode  1 token + caches -> logits + caches  (serve step)

Caches/states are pytrees stacked per pattern position: attention KV
(U, B, T, G, hd), recurrent states (U, B, ...); the decode scan threads
them through the same unit loop.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as A
from . import recurrent as R
from . import xlstm as X
from .config import ModelConfig
from .layers import Param, dense_init, rms_norm
from .mlp import init_mlp_params, mlp
from .moe import init_moe_params, moe_layer

__all__ = ["init_params", "forward_train", "forward_prefill",
           "forward_decode", "init_decode_cache", "loss_fn"]

ATTN_KINDS = ("attn", "attn_local", "cross_attn")


def _has_mlp(cfg: ModelConfig, kind: str) -> bool:
    return kind in ATTN_KINDS and (cfg.d_ff > 0 or cfg.moe is not None)


def _mixes_tokens_with(cfg: ModelConfig, kind: str) -> int:
    """Window for local kinds (0 = full)."""
    return cfg.sliding_window if kind == "attn_local" else 0


# ===========================================================================
# parameter init
# ===========================================================================

def _init_layer(p: Param, cfg: ModelConfig, kind: str, dtype) -> dict:
    d = cfg.d_model
    prm = {"ln1": jnp.zeros((d,), jnp.float32)}
    if kind in ("attn", "attn_local", "cross_attn"):
        prm["attn"] = A.init_attn_params(p, cfg, dtype)
    elif kind == "rglru":
        prm["mix"] = R.init_rglru_params(p, cfg, dtype)
    elif kind == "mlstm":
        prm["mix"] = X.init_mlstm_params(p, cfg, dtype)
    elif kind == "slstm":
        prm["mix"] = X.init_slstm_params(p, cfg, dtype)
    else:
        raise ValueError(kind)
    if _has_mlp(cfg, kind):
        prm["ln2"] = jnp.zeros((d,), jnp.float32)
        prm["mlp"] = (init_moe_params(p, cfg, dtype) if cfg.moe is not None
                      else init_mlp_params(p, d, cfg.d_ff, cfg.act, dtype))
    return prm


def init_params(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    p = Param(key)
    params = {
        "embed": dense_init(p.next(), (cfg.vocab, cfg.d_model), in_axis=1,
                            dtype=dtype),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(
            p.next(), (cfg.d_model, cfg.vocab), dtype=dtype)
    units = []
    for pos, kind in enumerate(cfg.pattern):
        copies = [_init_layer(p, cfg, kind, dtype) for _ in range(cfg.n_units)]
        units.append(jax.tree.map(lambda *xs: jnp.stack(xs), *copies))
    params["units"] = units
    params["rem"] = [
        _init_layer(p, cfg, cfg.pattern[i], dtype)
        for i in range(cfg.n_remainder)
    ]
    return params


# ===========================================================================
# single layer application
# ===========================================================================

def _apply_layer_full(cfg: ModelConfig, kind: str, x, prm, positions, aux,
                      want_cache: bool, max_len: int):
    """Full-sequence pass; returns (x, cache_entry or ())."""
    h = rms_norm(x, prm["ln1"], cfg.norm_eps)
    cache = ()
    if kind in ("attn", "attn_local"):
        W = _mixes_tokens_with(cfg, kind)
        mix, (k, v) = A.attention_full(h, prm["attn"], cfg, positions,
                                       window=W)
        if want_cache:
            S = k.shape[1]
            Tc = min(max_len, W) if W else max_len
            ck = jnp.zeros((x.shape[0], Tc, cfg.n_kv_heads, cfg.hd), k.dtype)
            cv = jnp.zeros_like(ck)
            if W and S > Tc:
                # ring layout: logical position p -> slot p % W; keep last W
                pos_tail = jnp.arange(S - Tc, S)
                slots = jnp.mod(pos_tail, Tc)
                ck = ck.at[:, slots].set(k[:, S - Tc:])
                cv = cv.at[:, slots].set(v[:, S - Tc:])
            else:
                ck, cv = A.update_cache(ck, cv, k, v, 0)
            cache = {"k": ck, "v": cv}
    elif kind == "cross_attn":
        mix, (k, v) = A.attention_cross(h, prm["attn"], cfg, kv_src=aux)
        if want_cache:
            cache = {"k": k, "v": v}
    elif kind == "rglru":
        mix, (hlast, conv) = R.rglru_full(h, prm["mix"], cfg)
        if want_cache:
            cache = {"h": hlast, "conv": conv}
    elif kind == "mlstm":
        mix, state = X.mlstm_full(h, prm["mix"], cfg, want_state=want_cache)
        if want_cache:
            cache = state
    elif kind == "slstm":
        mix, carry = X.slstm_full(h, prm["mix"], cfg)
        if want_cache:
            cache = {"h": carry[0], "c": carry[1], "n": carry[2],
                     "m": carry[3]}
    else:
        raise ValueError(kind)
    x = x + mix
    if _has_mlp(cfg, kind):
        h2 = rms_norm(x, prm["ln2"], cfg.norm_eps)
        ff = (moe_layer(h2, prm["mlp"], cfg) if cfg.moe is not None
              else mlp(h2, prm["mlp"], cfg.act))
        x = x + ff
    return x, cache


def _apply_layer_decode(cfg: ModelConfig, kind: str, x, prm, pos, aux, cache):
    h = rms_norm(x, prm["ln1"], cfg.norm_eps)
    if kind in ("attn", "attn_local"):
        if "codes_k" in cache:           # pwrel-compressed KV (serving/kvcache)
            from ..serving import kvcache as KV
            mix, cache = KV.compressed_attention_decode(
                h, prm["attn"], cfg, cache, pos,
                window=_mixes_tokens_with(cfg, kind))
        else:
            mix, ck, cv = A.attention_decode(
                h, prm["attn"], cfg, cache["k"], cache["v"], pos,
                window=_mixes_tokens_with(cfg, kind))
            cache = {"k": ck, "v": cv}
    elif kind == "cross_attn":
        if "codes_k" in cache:
            from ..serving import kvcache as KV
            kv = (KV.dequantize_kv(KV._unpack(cache, "k")),
                  KV.dequantize_kv(KV._unpack(cache, "v")))
            mix, _ = A.attention_cross(h, prm["attn"], cfg, kv_cache=kv)
        else:
            mix, _ = A.attention_cross(h, prm["attn"], cfg,
                                       kv_cache=(cache["k"], cache["v"]))
    elif kind == "rglru":
        mix, hn, conv = R.rglru_decode(h, prm["mix"], cfg, cache["h"],
                                       cache["conv"])
        cache = {"h": hn, "conv": conv}
    elif kind == "mlstm":
        mix, C, n, m = X.mlstm_decode(h, prm["mix"], cfg, cache["C"],
                                      cache["n"], cache["m"])
        cache = {"C": C, "n": n, "m": m}
    elif kind == "slstm":
        mix, carry = X.slstm_decode(h, prm["mix"], cfg,
                                    (cache["h"], cache["c"], cache["n"],
                                     cache["m"]))
        cache = {"h": carry[0], "c": carry[1], "n": carry[2], "m": carry[3]}
    else:
        raise ValueError(kind)
    x = x + mix
    if _has_mlp(cfg, kind):
        h2 = rms_norm(x, prm["ln2"], cfg.norm_eps)
        ff = (moe_layer(h2, prm["mlp"], cfg) if cfg.moe is not None
              else mlp(h2, prm["mlp"], cfg.act))
        x = x + ff
    return x, cache


# ===========================================================================
# trunk traversal (scan over units + unrolled remainder)
# ===========================================================================

def _trunk_full(cfg: ModelConfig, params, x, positions, aux,
                want_cache: bool, max_len: int):
    def unit_body(x, unit_params):
        caches = []
        for pos_i, kind in enumerate(cfg.pattern):
            x, c = _apply_layer_full(cfg, kind, x, unit_params[pos_i],
                                     positions, aux, want_cache, max_len)
            caches.append(c)
        return x, tuple(caches)

    body = (jax.checkpoint(unit_body) if (cfg.remat and not want_cache)
            else unit_body)
    if cfg.n_units > 0 and cfg.scan_layers:
        x, unit_caches = jax.lax.scan(body, x, tuple(params["units"]))
    elif cfg.n_units > 0:
        # unrolled path (dry-run roofline): same params layout, static slices
        per_unit = []
        for u in range(cfg.n_units):
            unit_params = jax.tree.map(lambda t: t[u], tuple(params["units"]))
            x, caches_u = body(x, unit_params)
            per_unit.append(caches_u)
        unit_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *per_unit)
    else:
        unit_caches = tuple(() for _ in cfg.pattern)
    rem_caches = []
    for i, prm in enumerate(params["rem"]):
        kind = cfg.pattern[i]
        x, c = _apply_layer_full(cfg, kind, x, prm, positions, aux,
                                 want_cache, max_len)
        rem_caches.append(c)
    return x, {"units": unit_caches, "rem": tuple(rem_caches)}


def _trunk_decode(cfg: ModelConfig, params, x, pos, aux, cache):
    def unit_body(x, scan_in):
        unit_params, unit_cache = scan_in
        new_caches = []
        for pos_i, kind in enumerate(cfg.pattern):
            x, c = _apply_layer_decode(cfg, kind, x, unit_params[pos_i], pos,
                                       aux, unit_cache[pos_i])
            new_caches.append(c)
        return x, tuple(new_caches)

    if cfg.n_units > 0 and cfg.scan_layers:
        x, unit_caches = jax.lax.scan(
            unit_body, x, (tuple(params["units"]), cache["units"]))
    elif cfg.n_units > 0:
        per_unit = []
        for u in range(cfg.n_units):
            sl = jax.tree.map(lambda t: t[u],
                              (tuple(params["units"]), cache["units"]))
            x, caches_u = unit_body(x, sl)
            per_unit.append(caches_u)
        unit_caches = jax.tree.map(lambda *xs: jnp.stack(xs), *per_unit)
    else:
        unit_caches = cache["units"]
    rem_caches = []
    for i, prm in enumerate(params["rem"]):
        kind = cfg.pattern[i]
        x, c = _apply_layer_decode(cfg, kind, x, prm, pos, aux,
                                   cache["rem"][i])
        rem_caches.append(c)
    return x, {"units": unit_caches, "rem": tuple(rem_caches)}


# ===========================================================================
# public entry points
# ===========================================================================

def _embed(cfg: ModelConfig, params, tokens):
    x = params["embed"][tokens]
    return x * jnp.asarray(cfg.d_model ** 0.5, x.dtype)


def _logits(cfg: ModelConfig, params, x):
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    logits = (x @ w).astype(jnp.float32)
    if cfg.logits_softcap:
        c = cfg.logits_softcap
        logits = c * jnp.tanh(logits / c)
    return logits


def forward_train(cfg: ModelConfig, params, tokens, aux=None):
    """tokens (B, S) -> logits (B, S, V) f32."""
    S = tokens.shape[1]
    positions = jnp.arange(S)
    x = _embed(cfg, params, tokens)
    x, _ = _trunk_full(cfg, params, x, positions, aux, False, S)
    return _logits(cfg, params, x)


def loss_fn(cfg: ModelConfig, params, tokens, aux=None):
    """Next-token cross-entropy (mean over B*(S-1) targets)."""
    logits = forward_train(cfg, params, tokens, aux)
    tgt = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def forward_prefill(cfg: ModelConfig, params, tokens, aux=None,
                    max_len: int | None = None):
    """tokens (B, S) -> (last-position logits (B, V), decode cache)."""
    S = tokens.shape[1]
    max_len = max_len or S
    positions = jnp.arange(S)
    x = _embed(cfg, params, tokens)
    x, cache = _trunk_full(cfg, params, x, positions, aux, True, max_len)
    return _logits(cfg, params, x[:, -1:, :])[:, 0, :], cache


def forward_decode(cfg: ModelConfig, params, token, cache, pos, aux=None,
                   kv_codec: bool = False):
    """token (B, 1) + cache -> (logits (B, V), new cache).

    ``kv_codec`` is informational — the compressed path triggers off the
    cache's own leaves (``codes_k`` present => pwrel-compressed KV).
    """
    del kv_codec
    x = _embed(cfg, params, token)
    x, cache = _trunk_decode(cfg, params, x, pos, aux, cache)
    return _logits(cfg, params, x)[:, 0, :], cache


def init_decode_cache(cfg: ModelConfig, batch: int, max_len: int,
                      dtype=jnp.bfloat16, n_image_tokens: int | None = None):
    """Abstract-shaped cache matching _trunk_decode's expectations."""
    n_img = n_image_tokens or cfg.n_image_tokens

    def entry(kind: str, L: int):
        if L == 0:
            return None
        if kind in ("attn", "attn_local"):
            Tc = max_len
            if kind == "attn_local" and cfg.sliding_window:
                Tc = min(max_len, cfg.sliding_window)   # ring buffer
            shape = (L, batch, Tc, cfg.n_kv_heads, cfg.hd)
            return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        if kind == "cross_attn":
            shape = (L, batch, n_img, cfg.n_kv_heads, cfg.hd)
            return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}
        if kind == "rglru":
            w = cfg.rglru_width or cfg.d_model
            return {"h": jnp.zeros((L, batch, w), jnp.float32),
                    "conv": jnp.zeros((L, batch, cfg.conv1d_width - 1, w),
                                      dtype)}
        if kind == "mlstm":
            H, hd = cfg.n_heads, cfg.d_model // cfg.n_heads
            return {"C": jnp.zeros((L, batch, H, hd, hd), jnp.float32),
                    "n": jnp.zeros((L, batch, H, hd), jnp.float32),
                    "m": jnp.zeros((L, batch, H), jnp.float32)}
        if kind == "slstm":
            z = jnp.zeros((L, batch, cfg.d_model), jnp.float32)
            return {"h": z, "c": z, "n": z, "m": z}
        raise ValueError(kind)

    units = tuple(
        (entry(kind, cfg.n_units) or ()) for kind in cfg.pattern
    )
    rem = tuple(
        jax.tree.map(lambda x: x[0], entry(cfg.pattern[i], 1)) or ()
        for i in range(cfg.n_remainder)
    )
    return {"units": units, "rem": rem}
