"""RG-LRU temporal-mixing block (RecurrentGemma / Griffin, arXiv:2402.19427).

Structure: two width-``w`` branches from x — gate branch (GeLU) and signal
branch (short causal conv1d -> RG-LRU) — multiplied and projected back.

RG-LRU recurrence (diagonal linear, hence parallelizable):

    r_t = sigmoid(W_a x_t)        a_t = exp(c * softplus(Λ) * (-r_t))
    i_t = sigmoid(W_i x_t)
    h_t = a_t ⊙ h_{t-1} + sqrt(1 - a_t^2) ⊙ (i_t ⊙ x_t)

Training uses ``jax.lax.associative_scan`` over time (log-depth parallel
scan — the TPU-native substitute for the paper family's CUDA linear-scan
kernels); decode is the O(1) single-step update.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Param, dense_init

__all__ = ["init_rglru_params", "rglru_full", "rglru_decode",
           "init_rglru_state"]

_C = 8.0  # Griffin's gate sharpness constant


def init_rglru_params(p: Param, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d = cfg.d_model
    w = cfg.rglru_width or d
    return {
        "w_x": dense_init(p.next(), (d, w), dtype=dtype),      # signal branch
        "w_g": dense_init(p.next(), (d, w), dtype=dtype),      # gate branch
        "w_out": dense_init(p.next(), (w, d), dtype=dtype),
        "conv_w": dense_init(p.next(), (cfg.conv1d_width, w), dtype=dtype),
        "conv_b": jnp.zeros((w,), dtype),
        "w_a": dense_init(p.next(), (w, w), dtype=dtype),      # recurrence gate
        "w_i": dense_init(p.next(), (w, w), dtype=dtype),      # input gate
        "lam": jnp.full((w,), 0.65, jnp.float32),              # Λ init
    }


def _gates(u: jax.Array, prm: dict):
    """u: (..., w) f32 conv output -> (a, beta*u_gated) recurrence coeffs."""
    r = jax.nn.sigmoid((u @ prm["w_a"].astype(u.dtype)))
    i = jax.nn.sigmoid((u @ prm["w_i"].astype(u.dtype)))
    log_a = -_C * jax.nn.softplus(prm["lam"]) * r
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-6))
    return a, beta * (i * u)


def _causal_conv(x: jax.Array, prm: dict, state: jax.Array | None = None):
    """Depthwise causal conv1d, width K.  x: (B, S, w).

    ``state`` carries the trailing K-1 inputs for decode; returns
    (out, new_state).
    """
    K = prm["conv_w"].shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], K - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)              # (B, S+K-1, w)
    out = sum(xp[:, i:i + x.shape[1], :] * prm["conv_w"][i][None, None, :]
              for i in range(K))
    new_state = xp[:, -(K - 1):, :] if K > 1 else pad
    return out + prm["conv_b"], new_state


def rglru_full(x: jax.Array, prm: dict, cfg: ModelConfig):
    """Train/prefill pass. x: (B, S, d) -> (out, (h_last, conv_state))."""
    gate = jax.nn.gelu(x @ prm["w_g"])
    u, conv_state = _causal_conv(x @ prm["w_x"], prm)
    a, b = _gates(u.astype(jnp.float32), prm)

    def combine(left, right):
        a1, b1 = left
        a2, b2 = right
        return a1 * a2, a2 * b1 + b2

    a_s, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h_last = h[:, -1, :]                                # f32, decode state
    h = h.astype(x.dtype)
    out = (h * gate) @ prm["w_out"]
    return out, (h_last, conv_state)


def init_rglru_state(cfg: ModelConfig, batch: int, n_layers: int,
                     dtype=jnp.bfloat16) -> dict:
    w = cfg.rglru_width or cfg.d_model
    K = cfg.conv1d_width
    return {
        "h": jnp.zeros((n_layers, batch, w), jnp.float32),
        "conv": jnp.zeros((n_layers, batch, K - 1, w), dtype),
    }


def rglru_decode(x: jax.Array, prm: dict, cfg: ModelConfig,
                 h_prev: jax.Array, conv_state: jax.Array):
    """One-token step. x: (B, 1, d) -> (out, h_new, conv_state_new)."""
    gate = jax.nn.gelu(x @ prm["w_g"])
    u, conv_state = _causal_conv(x @ prm["w_x"], prm, state=conv_state)
    a, b = _gates(u.astype(jnp.float32), prm)           # (B, 1, w)
    h = a[:, 0] * h_prev + b[:, 0]
    out = (h[:, None, :].astype(x.dtype) * gate) @ prm["w_out"]
    return out, h, conv_state
