"""Model configuration + layer-pattern machinery.

Every assigned architecture is expressed as a ``ModelConfig`` whose
``pattern`` is the repeating unit of layer kinds (e.g. gemma3's
5 local + 1 global attention).  The transformer assembles layers as
``lax.scan`` over pattern units (keeps HLO size flat in depth — essential
for compiling 48-100 layer models on a 512-device mesh) plus an unrolled
remainder when ``n_layers % len(pattern) != 0``.
"""
from __future__ import annotations

from dataclasses import dataclass, replace

__all__ = ["MoEConfig", "EncoderConfig", "ModelConfig", "LayerKind"]

# layer kinds understood by transformer.py
LayerKind = str  # "attn" | "attn_local" | "cross_attn" | "rglru" | "mlstm" | "slstm"


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    dense_residual: bool = False      # arctic: dense MLP in parallel w/ MoE
    dense_d_ff: int = 0               # width of the dense residual branch


@dataclass(frozen=True)
class EncoderConfig:
    """Whisper-style encoder (conv frontend stubbed to precomputed frames)."""
    n_layers: int
    n_frames: int = 1500              # post-conv frame count at train shape
    dec_len: int = 512                # decoder tokens at train shape


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                       # dense|moe|hybrid|ssm|vlm|audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0                 # 0 => d_model // n_heads
    # attention variants
    qk_norm: bool = False             # qwen3
    qkv_bias: bool = False            # qwen1.5
    rope_theta: float = 10_000.0
    sliding_window: int = 0           # 0 = full attention (for *_local kinds)
    pattern: tuple[LayerKind, ...] = ("attn",)
    # moe / vlm / audio extras
    moe: MoEConfig | None = None
    n_image_tokens: int = 576         # vlm stub frontend output length
    encoder: EncoderConfig | None = None
    # hybrid/ssm extras
    rglru_width: int = 0              # recurrence width (0 => d_model)
    conv1d_width: int = 4
    # embedding/misc
    tie_embeddings: bool = True
    norm_eps: float = 1e-6
    act: str = "silu"                 # mlp activation: silu (swiglu) | gelu
    # training-time memory knobs (per-arch defaults; launcher may override)
    remat: bool = True
    optimizer: str = "adamw"          # "adafactor" for the very largest
    opt_state_dtype: str = "float32"  # "bfloat16" for the very large models
    logits_softcap: float = 0.0
    # scan over pattern units (flat HLO; production default).  The dry-run
    # sets False for its roofline pass: XLA's analytical cost model counts
    # while-loop bodies ONCE, so exact FLOP/byte/collective accounting
    # needs the layers unrolled (EXPERIMENTS.md §Method).
    scan_layers: bool = True
    # -- beyond-paper performance levers (EXPERIMENTS.md §Perf) -------------
    # shard attention scores over the query-sequence dim instead of heads
    # (wins when n_kv_heads < TP size: kills the replicated S x S scores)
    seq_parallel_attn: bool = False
    # block-banded computation for sliding-window layers: only the
    # in-window (2W per query) score band is computed/materialized
    banded_local_attn: bool = False

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def n_rep(self) -> int:
        return self.n_heads // self.n_kv_heads

    def layer_kinds(self) -> list[LayerKind]:
        """Expanded per-layer kind list of length n_layers."""
        unit = list(self.pattern)
        kinds = (unit * ((self.n_layers + len(unit) - 1) // len(unit)))
        return kinds[: self.n_layers]

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def n_remainder(self) -> int:
        return self.n_layers % len(self.pattern)

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    # -- parameter count (for 6ND roofline MODEL_FLOPS) ----------------------
    def param_count(self, active_only: bool = False) -> int:
        d, hd = self.d_model, self.hd
        nq, nkv = self.n_heads, self.n_kv_heads
        n = 0
        kinds = self.layer_kinds()
        for kind in kinds:
            if kind in ("attn", "attn_local", "cross_attn"):
                n += d * nq * hd + 2 * d * nkv * hd + nq * hd * d  # qkvo
                if self.qkv_bias:
                    n += (nq + 2 * nkv) * hd
                n += 2 * d  # norms
            if kind in ("attn", "attn_local", "cross_attn", "mlstm", "slstm"):
                pass
            if kind == "rglru":
                w = self.rglru_width or d
                n += 2 * d * w + w * d + 3 * w  # in/gate proj, out proj, gates
                n += 2 * d
            if kind in ("mlstm", "slstm"):
                w = self.d_model
                n += 4 * d * w + w * d  # qkv+gates projections (approx exact below)
                n += 2 * d
            # mlp / moe attached to every unit layer except pure-recurrent xlstm
            if kind in ("attn", "attn_local", "cross_attn"):
                if self.moe is not None:
                    if active_only:
                        n += self.moe.top_k * 3 * d * self.d_ff
                    else:
                        n += self.moe.n_experts * 3 * d * self.d_ff
                    n += d * self.moe.n_experts  # router
                    if self.moe.dense_residual:
                        n += 3 * d * self.moe.dense_d_ff
                elif self.d_ff:
                    nmul = 3 if self.act == "silu" else 2
                    n += nmul * d * self.d_ff
        n += self.vocab * d  # embeddings (tied)
        if not self.tie_embeddings:
            n += self.vocab * d
        if self.encoder is not None:
            enc = self.encoder
            per = (d * nq * hd + 2 * d * nkv * hd + nq * hd * d + 2 * d * self.d_ff
                   + 2 * d)
            # decoder cross-attn blocks add another attention per layer
            n += enc.n_layers * per
            n += len(kinds) * (d * nq * hd + 2 * d * nkv * hd + nq * hd * d)
        return n
