"""LM model framework: configs, layers, assemblies (decoder-only + enc-dec)."""
from .config import EncoderConfig, ModelConfig, MoEConfig  # noqa: F401
