"""Mixture-of-Experts layer: top-k routing with sort-based capacity dispatch.

Design notes (and why not a (T, E, C) one-hot dispatch tensor): at the
assigned shapes a dense dispatch mask is ~10^12 elements.  Instead tokens
are *sorted by expert id* (MegaBlocks-style), ranked within their expert
run, and scattered into an (E, C, d) buffer — O(T·k) memory, batched expert
GEMMs of shape (E, C, d) x (E, d, ff) that shard cleanly: E over the
``data``/``expert`` axes (expert parallelism), ff over ``model`` (TP).

FLOP accounting: only top-k experts run per token (capacity drops excess),
so cost_analysis FLOPs track 6·N_active·D as the roofline expects.

Arctic's ``dense_residual``: a small dense SwiGLU branch runs in parallel
with the MoE and is summed (the "dense + MoE hybrid" of snowflake-arctic).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig, MoEConfig
from .layers import Param, dense_init
from .mlp import init_mlp_params, mlp

__all__ = ["init_moe_params", "moe_layer"]


def _pick_ec_axes(E: int, capacity: int):
    """(E axis, C axis) for dispatch-buffer sharding over 'data'."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or "data" not in (mesh.axis_names or ()):
            return None, None
        dpz = mesh.shape["data"]
        if dpz > 1 and E % dpz == 0:
            return "data", None
        if dpz > 1 and capacity % dpz == 0:
            return None, "data"
    except Exception:
        pass
    return None, None


def _constrain(x, *spec):
    """Best-effort sharding constraint: applies only when the named axes
    exist in the ambient mesh and divide the dims; no-op otherwise (CPU
    tests, single device).  The MoE dispatch buffers are the largest
    activations in the MoE train cells — without explicit constraints
    GSPMD replicated them (mixtral train: 158 GiB/device observed)."""
    try:
        mesh = jax.sharding.get_abstract_mesh()
        if mesh is None or not mesh.axis_names:
            return x
        fixed = []
        for dim, ax in zip(x.shape, spec):
            if ax is None:
                fixed.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            if not all(a in mesh.axis_names for a in axes):
                fixed.append(None)
                continue
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            fixed.append(ax if (size > 1 and dim % size == 0) else None)
        if all(f is None for f in fixed):
            return x
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*fixed))
    except Exception:
        return x


def init_moe_params(p: Param, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    mc = cfg.moe
    d, ff = cfg.d_model, cfg.d_ff
    prm = {
        "router": dense_init(p.next(), (d, mc.n_experts), dtype=jnp.float32),
        "w_in": dense_init(p.next(), (mc.n_experts, d, ff), in_axis=1,
                           dtype=dtype),
        "w_gate": dense_init(p.next(), (mc.n_experts, d, ff), in_axis=1,
                             dtype=dtype),
        "w_out": dense_init(p.next(), (mc.n_experts, ff, d), in_axis=1,
                            dtype=dtype),
    }
    if mc.dense_residual:
        prm["dense"] = init_mlp_params(p, d, mc.dense_d_ff or ff, "silu",
                                       dtype=dtype)
    return prm


def moe_layer(x: jax.Array, prm: dict, cfg: ModelConfig) -> jax.Array:
    """x: (B, S, d) -> (B, S, d)."""
    mc: MoEConfig = cfg.moe
    B, S, d = x.shape
    T = B * S
    k = mc.top_k
    E = mc.n_experts
    xt = x.reshape(T, d)

    # -- routing (f32) ---------------------------------------------------------
    logits = xt.astype(jnp.float32) @ prm["router"]          # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_ids = jax.lax.top_k(probs, k)          # (T, k)
    gate_vals = gate_vals / jnp.sum(gate_vals, axis=-1, keepdims=True)

    # -- sort-based dispatch ----------------------------------------------------
    Tk = T * k
    flat_expert = expert_ids.reshape(Tk)
    flat_token = jnp.repeat(jnp.arange(T), k)
    flat_gate = gate_vals.reshape(Tk)

    order = jnp.argsort(flat_expert)                         # stable
    sorted_e = flat_expert[order]
    idx = jnp.arange(Tk)
    run_start = jnp.where(jnp.concatenate(
        [jnp.ones((1,), bool), sorted_e[1:] != sorted_e[:-1]]), idx, 0)
    rank = idx - jax.lax.cummax(run_start, axis=0)           # pos within expert

    # capacity: cf * fair share, floored so tiny-T (decode: T = batch)
    # doesn't spuriously drop, capped at Tk (= provably drop-free)
    capacity = min(Tk, max(4, int((Tk / E) * mc.capacity_factor)))
    keep = rank < capacity
    slot = jnp.where(keep, sorted_e * capacity + rank, E * capacity)  # drop bin

    # scatter tokens into (E*C + 1, d); last row is the drop bin
    src = _constrain(xt[flat_token[order]], ("data",), None)
    buf = jnp.zeros((E * capacity + 1, d), x.dtype).at[slot].set(src)
    h = buf[: E * capacity].reshape(E, capacity, d)

    # -- batched expert SwiGLU ---------------------------------------------------
    # shard E over data when divisible (expert parallel: arctic 128e),
    # else shard capacity over data (mixtral 8e < 16 devices); ff over TP
    e_ax, c_ax = _pick_ec_axes(E, capacity)
    h = _constrain(h, e_ax, c_ax, None)
    hin = _constrain(jnp.einsum("ecd,edf->ecf", h, prm["w_in"]),
                     e_ax, c_ax, "model")
    hgate = _constrain(
        jax.nn.silu(jnp.einsum("ecd,edf->ecf", h, prm["w_gate"])),
        e_ax, c_ax, "model")
    hout = jnp.einsum("ecf,efd->ecd", hin * hgate, prm["w_out"])
    hout = _constrain(hout, e_ax, c_ax, None)

    # -- combine ------------------------------------------------------------------
    flat_out = hout.reshape(E * capacity, d)
    gathered = jnp.where(keep[:, None], flat_out[jnp.clip(slot, 0, E * capacity - 1)],
                         0.0)
    weighted = gathered.astype(jnp.float32) * flat_gate[order][:, None]
    out = jnp.zeros((T, d), jnp.float32).at[flat_token[order]].add(weighted)
    out = out.astype(x.dtype).reshape(B, S, d)

    if mc.dense_residual:
        out = out + mlp(x, prm["dense"], "silu")
    return out
