"""GQA attention: full-sequence (train/prefill), decode-with-cache, cross.

Covers every assigned variant: GQA group sizes from MQA (granite kv=1) to
MHA (qwen1.5 kv=40), qk-norm (qwen3), QKV bias (qwen1.5), sliding windows
(mixtral SWA, gemma3 / recurrentgemma local layers), cross-attention
(llama-vision, whisper decoder).

Softmax always accumulates in f32; activations are bf16.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .config import ModelConfig
from .layers import Param, dense_init, rms_norm, rope, rope_cos_sin

__all__ = ["init_attn_params", "attention_full", "attention_decode",
           "attention_cross", "init_cache", "update_cache"]

NEG_INF = -2.0 ** 30  # large-negative mask in f32 (avoids bf16 -inf NaNs)


def init_attn_params(p: Param, cfg: ModelConfig, dtype=jnp.bfloat16) -> dict:
    d, hd = cfg.d_model, cfg.hd
    prm = {
        "wq": dense_init(p.next(), (d, cfg.n_heads * hd), dtype=dtype),
        "wk": dense_init(p.next(), (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wv": dense_init(p.next(), (d, cfg.n_kv_heads * hd), dtype=dtype),
        "wo": dense_init(p.next(), (cfg.n_heads * hd, d), in_axis=0,
                         dtype=dtype),
    }
    if cfg.qkv_bias:
        prm["bq"] = jnp.zeros((cfg.n_heads * hd,), dtype)
        prm["bk"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
        prm["bv"] = jnp.zeros((cfg.n_kv_heads * hd,), dtype)
    if cfg.qk_norm:
        prm["q_norm"] = jnp.zeros((hd,), jnp.float32)
        prm["k_norm"] = jnp.zeros((hd,), jnp.float32)
    return prm


def _project_qkv(x, prm, cfg: ModelConfig):
    B, S, _ = x.shape
    hd = cfg.hd
    q = x @ prm["wq"]
    k = x @ prm["wk"]
    v = x @ prm["wv"]
    if cfg.qkv_bias:
        q = q + prm["bq"]
        k = k + prm["bk"]
        v = v + prm["bv"]
    q = q.reshape(B, S, cfg.n_heads, hd)
    k = k.reshape(B, S, cfg.n_kv_heads, hd)
    v = v.reshape(B, S, cfg.n_kv_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, prm["q_norm"], cfg.norm_eps)
        k = rms_norm(k, prm["k_norm"], cfg.norm_eps)
    return q, k, v


def _gqa_scores(q, k, cfg: ModelConfig):
    """q (B,S,Hq,hd), k (B,T,G,hd) -> scores (B,G,rep,S,T) f32."""
    B, S, Hq, hd = q.shape
    G = cfg.n_kv_heads
    q = q.reshape(B, S, G, cfg.n_rep, hd)
    scores = jnp.einsum("bsgrd,btgd->bgrst", q, k,
                        preferred_element_type=jnp.float32)
    return scores * (hd ** -0.5)


def _gqa_out(probs, v, cfg: ModelConfig):
    """probs (B,G,rep,S,T) f32, v (B,T,G,hd) -> (B,S,Hq*hd)."""
    B, G, rep, S, T = probs.shape
    out = jnp.einsum("bgrst,btgd->bsgrd", probs.astype(v.dtype), v)
    return out.reshape(B, S, G * rep * v.shape[-1])


def attention_full(x, prm, cfg: ModelConfig, positions, *,
                   window: int = 0, causal: bool = True):
    """Train/prefill self-attention. Returns (out, (k, v)) for caching."""
    q, k, v = _project_qkv(x, prm, cfg)
    cos, sin = rope_cos_sin(positions, cfg.hd, cfg.rope_theta)
    q = rope(q, cos, sin)
    k = rope(k, cos, sin)

    S = x.shape[1]
    if (cfg.banded_local_attn and window and S % window == 0
            and S >= 2 * window and positions.ndim == 1):
        out = _banded_window_attention(q, k, v, cfg, window) @ prm["wo"]
        return out, (k, v)

    if cfg.seq_parallel_attn:
        # KV-parallel attention: shard the KEY/VALUE sequence dim over
        # "model" instead of heads.  When G < TP, head sharding leaves an
        # S x S scores replica + a spurious all-reduce (56 GiB f32/layer at
        # 32k prefill, arctic).  With T sharded: scores (.., S, T/tp) are
        # partitioned with NO comm, softmax over T all-reduces only the
        # (B,G,r,S) max/sum stats, and the out einsum pays one
        # row-parallel activation all-reduce — O(S·d), not O(S²).
        from .layers import maybe_constrain
        k = maybe_constrain(k, None, "model", None, None)
        v = maybe_constrain(v, None, "model", None, None)
    scores = _gqa_scores(q, k, cfg)                       # (B,G,r,S,T)
    if cfg.seq_parallel_attn:
        from .layers import maybe_constrain
        scores = maybe_constrain(scores, None, None, None, None, "model")
    i = positions[..., :, None]
    j = positions[..., None, :]
    mask = jnp.ones((S, S), bool) if not causal else (i >= j)
    if window:
        mask = mask & (i - j < window)
    if mask.ndim == 2:               # positions was (S,) -> add batch dim
        mask = mask[None]
    scores = jnp.where(mask[:, None, None, :, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v, cfg) @ prm["wo"]
    return out, (k, v)


def _banded_window_attention(q, k, v, cfg: ModelConfig, W: int):
    """Sliding-window attention computed block-banded: each W-sized query
    block attends only to [its own block (causal) | the previous block],
    so score buffers are (S, 2W) not (S, S) and FLOPs scale with S*W.

    q: (B,S,Hq,hd), k/v: (B,S,G,hd) -> (B,S,Hq*hd).
    """
    B, S, Hq, hd = q.shape
    G = cfg.n_kv_heads
    rep = cfg.n_rep
    nb = S // W
    qb = q.reshape(B, nb, W, G, rep, hd)
    kb = k.reshape(B, nb, W, G, hd)
    vb = v.reshape(B, nb, W, G, hd)
    # previous block of k/v (block 0 gets zeros + full mask)
    kp = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    vp = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)

    scale = hd ** -0.5
    s_self = jnp.einsum("bnwgrd,bnxgd->bngrwx", qb, kb,
                        preferred_element_type=jnp.float32) * scale
    s_prev = jnp.einsum("bnwgrd,bnxgd->bngrwx", qb, kp,
                        preferred_element_type=jnp.float32) * scale
    qi = jnp.arange(W)[:, None]
    kj = jnp.arange(W)[None, :]
    s_self = jnp.where(qi >= kj, s_self, NEG_INF)         # causal in-block
    # prev-block distance = W + qi - kj < W  <=>  qi < kj
    m_prev = (qi < kj)[None, None, None, None]            # (1,1,1,1,W,W)
    blk0 = (jnp.arange(nb) != 0)[None, :, None, None, None, None]
    s_prev = jnp.where(m_prev & blk0, s_prev, NEG_INF)

    s = jnp.concatenate([s_prev, s_self], axis=-1)        # (B,nb,G,r,W,2W)
    p = jax.nn.softmax(s, axis=-1)
    p_prev, p_self = p[..., :W], p[..., W:]
    o = (jnp.einsum("bngrwx,bnxgd->bnwgrd", p_prev.astype(v.dtype), vp)
         + jnp.einsum("bngrwx,bnxgd->bnwgrd", p_self.astype(v.dtype), vb))
    return o.reshape(B, S, Hq * hd)


def init_cache(cfg: ModelConfig, batch: int, max_len: int, n_layers: int,
               dtype=jnp.bfloat16) -> dict:
    """Stacked KV cache for n_layers of one kind: (L, B, T, G, hd)."""
    shape = (n_layers, batch, max_len, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def update_cache(cache_k, cache_v, k, v, pos):
    """Write (B,S,G,hd) at sequence offset ``pos`` (scalar)."""
    cache_k = jax.lax.dynamic_update_slice(
        cache_k, k.astype(cache_k.dtype), (0, pos, 0, 0))
    cache_v = jax.lax.dynamic_update_slice(
        cache_v, v.astype(cache_v.dtype), (0, pos, 0, 0))
    return cache_k, cache_v


def attention_decode(x, prm, cfg: ModelConfig, cache_k, cache_v, pos, *,
                     window: int = 0):
    """One-token decode: x (B,1,d) against cache (B,T,G,hd) at offset pos.

    RING MODE (sliding-window layers at long context): when the cache is
    exactly ``window`` slots, it is treated as a ring buffer — slot
    ``pos % window`` is overwritten and all written slots attend (keys are
    already RoPE'd, and softmax is permutation-invariant over slots, so
    slot order never matters).  This keeps a local layer's cache O(window)
    instead of O(context): gemma3 @ 500k context would otherwise need a
    2.1 GB cache *per local layer*.

    Returns (out, new_cache_k, new_cache_v).
    """
    B = x.shape[0]
    T = cache_k.shape[1]
    ring = bool(window) and T == window
    q, k, v = _project_qkv(x, prm, cfg)
    posv = jnp.full((B, 1), pos, jnp.int32)
    cos, sin = rope_cos_sin(posv, cfg.hd, cfg.rope_theta)
    q = rope(q, cos, sin)
    k = rope(k, cos, sin)
    slot = jnp.mod(pos, T) if ring else pos
    cache_k, cache_v = update_cache(cache_k, cache_v, k, v, slot)

    scores = _gqa_scores(q, cache_k, cfg)                 # (B,G,r,1,T)
    j = jnp.arange(T)
    if ring:
        mask = (j <= pos)                 # warm-up: only written slots
        mask = mask | (pos >= T)          # steady state: every slot in-window
    else:
        mask = j <= pos
        if window:
            mask = mask & (pos - j < window)
    scores = jnp.where(mask[None, None, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, cache_v, cfg) @ prm["wo"]
    return out, cache_k, cache_v


def attention_cross(x, prm, cfg: ModelConfig, kv_src=None,
                    kv_cache: tuple | None = None):
    """Cross-attention: queries from x, keys/values from encoder output
    ``kv_src`` (B, T_enc, d) — or a precomputed (k, v) pair in decode."""
    B, S, _ = x.shape
    hd = cfg.hd
    q = (x @ prm["wq"]).reshape(B, S, cfg.n_heads, hd)
    if cfg.qk_norm:
        q = rms_norm(q, prm["q_norm"], cfg.norm_eps)
    if kv_cache is not None:
        k, v = kv_cache
    else:
        T = kv_src.shape[1]
        k = (kv_src @ prm["wk"]).reshape(B, T, cfg.n_kv_heads, hd)
        v = (kv_src @ prm["wv"]).reshape(B, T, cfg.n_kv_heads, hd)
        if cfg.qk_norm:
            k = rms_norm(k, prm["k_norm"], cfg.norm_eps)
    scores = _gqa_scores(q, k, cfg)
    probs = jax.nn.softmax(scores, axis=-1)
    out = _gqa_out(probs, v, cfg) @ prm["wo"]
    return out, (k, v)
