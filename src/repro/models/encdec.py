"""Encoder-decoder assembly (whisper-large-v3 backbone).

The conv frontend is a STUB per the assignment: ``input_specs`` feeds
precomputed frame embeddings (B, T_enc, d_model).  Encoder layers are
non-causal self-attention + GeLU MLP; decoder layers are causal
self-attention + cross-attention to the encoder output + GeLU MLP.
(Positional encoding: RoPE in place of whisper's learned embeddings —
recorded as a deviation in DESIGN.md; it changes no system property.)

Serve path: prefill encodes once and caches (a) decoder self-attn KV and
(b) cross-attn KV of the encoder output — the best case for the paper's
compression technique since the cross KV is written once and read every
step.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as A
from .config import ModelConfig
from .layers import Param, dense_init, rms_norm
from .mlp import init_mlp_params, mlp

__all__ = ["init_encdec_params", "encdec_train", "encdec_prefill",
           "encdec_decode", "init_encdec_cache", "loss_fn_encdec"]


def _init_enc_layer(p: Param, cfg: ModelConfig, dtype) -> dict:
    return {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "attn": A.init_attn_params(p, cfg, dtype),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        "mlp": init_mlp_params(p, cfg.d_model, cfg.d_ff, cfg.act, dtype),
    }


def _init_dec_layer(p: Param, cfg: ModelConfig, dtype) -> dict:
    prm = _init_enc_layer(p, cfg, dtype)
    prm["lnx"] = jnp.zeros((cfg.d_model,), jnp.float32)
    prm["xattn"] = A.init_attn_params(p, cfg, dtype)
    return prm


def init_encdec_params(cfg: ModelConfig, key, dtype=jnp.bfloat16) -> dict:
    p = Param(key)
    enc_L = cfg.encoder.n_layers
    dec_L = cfg.n_layers
    enc = [_init_enc_layer(p, cfg, dtype) for _ in range(enc_L)]
    dec = [_init_dec_layer(p, cfg, dtype) for _ in range(dec_L)]
    return {
        "embed": dense_init(p.next(), (cfg.vocab, cfg.d_model), in_axis=1,
                            dtype=dtype),
        "enc": jax.tree.map(lambda *xs: jnp.stack(xs), *enc),
        "dec": jax.tree.map(lambda *xs: jnp.stack(xs), *dec),
        "enc_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }


def _encode(cfg: ModelConfig, params, frames):
    positions = jnp.arange(frames.shape[1])

    def body(x, prm):
        h = rms_norm(x, prm["ln1"], cfg.norm_eps)
        mix, _ = A.attention_full(h, prm["attn"], cfg, positions,
                                  causal=False)
        x = x + mix
        h = rms_norm(x, prm["ln2"], cfg.norm_eps)
        return x + mlp(h, prm["mlp"], cfg.act), ()

    body = jax.checkpoint(body) if cfg.remat else body
    x = frames
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["enc"])
    else:
        for li in range(cfg.encoder.n_layers):
            x, _ = body(x, jax.tree.map(lambda t: t[li], params["enc"]))
    return rms_norm(x, params["enc_norm"], cfg.norm_eps)


def _dec_layer_full(cfg, x, prm, positions, enc_out, want_cache, max_len):
    h = rms_norm(x, prm["ln1"], cfg.norm_eps)
    mix, (k, v) = A.attention_full(h, prm["attn"], cfg, positions)
    x = x + mix
    h = rms_norm(x, prm["lnx"], cfg.norm_eps)
    xmix, (xk, xv) = A.attention_cross(h, prm["xattn"], cfg, kv_src=enc_out)
    x = x + xmix
    h = rms_norm(x, prm["ln2"], cfg.norm_eps)
    x = x + mlp(h, prm["mlp"], cfg.act)
    cache = ()
    if want_cache:
        ck = jnp.zeros((x.shape[0], max_len, cfg.n_kv_heads, cfg.hd), k.dtype)
        cv = jnp.zeros_like(ck)
        ck, cv = A.update_cache(ck, cv, k, v, 0)
        cache = {"k": ck, "v": cv, "xk": xk, "xv": xv}
    return x, cache


def encdec_train(cfg: ModelConfig, params, frames, tokens):
    """frames (B, T_enc, d), tokens (B, S_dec) -> logits (B, S_dec, V)."""
    enc_out = _encode(cfg, params, frames)
    positions = jnp.arange(tokens.shape[1])
    x = params["embed"][tokens] * jnp.asarray(cfg.d_model ** 0.5, jnp.bfloat16)

    def body(x, prm):
        x, _ = _dec_layer_full(cfg, x, prm, positions, enc_out, False, 0)
        return x, ()

    body = jax.checkpoint(body) if cfg.remat else body
    if cfg.scan_layers:
        x, _ = jax.lax.scan(body, x, params["dec"])
    else:
        for li in range(cfg.n_layers):
            x, _ = body(x, jax.tree.map(lambda t: t[li], params["dec"]))
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return (x @ params["embed"].T).astype(jnp.float32)


def loss_fn_encdec(cfg: ModelConfig, params, frames, tokens):
    logits = encdec_train(cfg, params, frames, tokens)
    tgt = tokens[:, 1:]
    lp = jax.nn.log_softmax(logits[:, :-1], axis=-1)
    nll = -jnp.take_along_axis(lp, tgt[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def encdec_prefill(cfg: ModelConfig, params, frames, tokens,
                   max_len: int | None = None):
    enc_out = _encode(cfg, params, frames)
    S = tokens.shape[1]
    max_len = max_len or S
    positions = jnp.arange(S)
    x = params["embed"][tokens] * jnp.asarray(cfg.d_model ** 0.5, jnp.bfloat16)

    def body(x, prm):
        return _dec_layer_full(cfg, x, prm, positions, enc_out, True, max_len)

    if cfg.scan_layers:
        x, cache = jax.lax.scan(body, x, params["dec"])
    else:
        per = []
        for li in range(cfg.n_layers):
            x, c = body(x, jax.tree.map(lambda t: t[li], params["dec"]))
            per.append(c)
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, -1, :] @ params["embed"].T).astype(jnp.float32)
    return logits, cache


def init_encdec_cache(cfg: ModelConfig, batch: int, max_len: int,
                      n_frames: int, dtype=jnp.bfloat16) -> dict:
    L = cfg.n_layers
    kv = (L, batch, max_len, cfg.n_kv_heads, cfg.hd)
    xkv = (L, batch, n_frames, cfg.n_kv_heads, cfg.hd)
    return {"k": jnp.zeros(kv, dtype), "v": jnp.zeros(kv, dtype),
            "xk": jnp.zeros(xkv, dtype), "xv": jnp.zeros(xkv, dtype)}


def encdec_decode(cfg: ModelConfig, params, token, cache, pos):
    """token (B, 1) + cache -> (logits (B, V), new cache)."""
    x = params["embed"][token] * jnp.asarray(cfg.d_model ** 0.5, jnp.bfloat16)

    def body(x, scan_in):
        prm, c = scan_in
        h = rms_norm(x, prm["ln1"], cfg.norm_eps)
        mix, ck, cv = A.attention_decode(h, prm["attn"], cfg, c["k"], c["v"],
                                         pos)
        x = x + mix
        h = rms_norm(x, prm["lnx"], cfg.norm_eps)
        xmix, _ = A.attention_cross(h, prm["xattn"], cfg,
                                    kv_cache=(c["xk"], c["xv"]))
        x = x + xmix
        h = rms_norm(x, prm["ln2"], cfg.norm_eps)
        x = x + mlp(h, prm["mlp"], cfg.act)
        return x, {"k": ck, "v": cv, "xk": c["xk"], "xv": c["xv"]}

    if cfg.scan_layers:
        x, cache = jax.lax.scan(body, x, (params["dec"], cache))
    else:
        per = []
        for li in range(cfg.n_layers):
            x, c = body(x, jax.tree.map(lambda t: t[li],
                                        (params["dec"], cache)))
            per.append(c)
        cache = jax.tree.map(lambda *xs: jnp.stack(xs), *per)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = (x[:, 0, :] @ params["embed"].T).astype(jnp.float32)
    return logits, cache
