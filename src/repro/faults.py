"""Deterministic, seed-driven fault injection for the resilience layer.

Every I/O-and-bytes-touching seam in the engine is a *named injection
point* that calls :func:`fault_point` — a no-op (one global read) unless
an injector is installed.  Tests and ``qsim --inject`` install one to
deterministically provoke the failure paths the resilience layer exists
to handle:

    with inject_faults(["store.spill_read:ioerror:times=2"]):
        sim.run()          # first two spill reads fail, are retried

Registered points (see ARCHITECTURE.md "Resilience layer"):

===================== =====================================================
``store.spill_write`` every spill-tier file write (payload: blob bytes)
``store.spill_read``  every spill-tier file read (payload: bytes read)
``codec.encode``      every host/device block-encode dispatch
``codec.decode``      every host/device block-decode dispatch
``pipeline.fetch``    every pipeline fetch-worker step (one wave/group)
``pipeline.store``    every pipeline store-worker step (one wave/group)
``pipeline.exchange`` every cross-device block hand-off (one block moving
                      owners between stages of a block-sharded run)
``checkpoint.write``  every store snapshot (once per checkpoint)
``checkpoint.read``   every snapshot parse (restore / resume / replay)
===================== =====================================================

Fault *kinds*:

* ``ioerror`` — raise ``OSError(EIO)`` at the point (exercises the
  retry/typed-error paths).
* ``corrupt`` — flip one byte of the payload (only meaningful at the
  byte-carrying spill points; exercises checksum detection).
* ``crash`` — raise :class:`InjectedCrash`, simulating hard process
  death at that point (exercises checkpoint/resume).

A spec fires at deterministic 1-based *hit* numbers (``hit=3`` or
``hit=2,5``), with a seeded probability (``p=0.1`` — the chaos sweep),
or on every hit; ``times=K`` caps the total number of firings.  All
bookkeeping is under one lock, so firing decisions are reproducible for
a fixed seed and call order.

This module is stdlib-only (no ``repro`` imports) so the compression
layer can use it without import cycles; :mod:`repro.core.faults` is the
canonical public import surface.
"""
from __future__ import annotations

import contextlib
import errno
import random
import threading
from dataclasses import dataclass

__all__ = [
    "INJECTION_POINTS",
    "InjectedCrash",
    "FaultSpec",
    "FaultInjector",
    "fault_point",
    "install_faults",
    "clear_faults",
    "active_injector",
    "inject_faults",
]

INJECTION_POINTS = frozenset({
    "store.spill_write",
    "store.spill_read",
    "codec.encode",
    "codec.decode",
    "pipeline.fetch",
    "pipeline.store",
    "pipeline.exchange",
    "checkpoint.write",
    "checkpoint.read",
})

#: points whose payload is raw bytes — the only ones ``corrupt`` touches
_CORRUPTIBLE = frozenset({"store.spill_write", "store.spill_read"})

_KINDS = ("ioerror", "corrupt", "crash")


class InjectedCrash(RuntimeError):
    """Simulated hard crash (process death) at an injection point.

    Deliberately NOT an ``OSError``: nothing in the stack retries or
    converts it — it unwinds like a kill signal would, leaving whatever
    checkpoint files are already on disk."""


@dataclass(frozen=True)
class FaultSpec:
    """One fault to inject: where, what, and when.

    Attributes:
        point: injection-point name (member of :data:`INJECTION_POINTS`).
        kind: ``ioerror`` | ``corrupt`` | ``crash``.
        hits: fire at these 1-based hit numbers of the point (None =
            every hit, subject to ``p``/``times``).
        p: fire each hit with this probability (seeded rng) when no
            explicit ``hits`` are given; 0 means "always".
        times: stop firing after this many firings (None = unlimited).
    """

    point: str
    kind: str
    hits: tuple[int, ...] | None = None
    p: float = 0.0
    times: int | None = None

    def __post_init__(self):
        if self.point not in INJECTION_POINTS:
            raise ValueError(
                f"unknown injection point {self.point!r}; expected one of "
                f"{sorted(INJECTION_POINTS)}")
        if self.kind not in _KINDS:
            raise ValueError(
                f"unknown fault kind {self.kind!r}; expected one of "
                f"{_KINDS}")
        if self.kind == "corrupt" and self.point not in _CORRUPTIBLE:
            raise ValueError(
                f"kind 'corrupt' only applies to byte-carrying points "
                f"{sorted(_CORRUPTIBLE)}, not {self.point!r}")

    @classmethod
    def parse(cls, spec: str) -> "FaultSpec":
        """Parse the CLI form ``point:kind[:hit=N[,M]][:p=F][:times=K]``.

        Examples: ``store.spill_read:ioerror:times=2``,
        ``pipeline.fetch:crash:hit=5``, ``store.spill_write:corrupt:p=0.05``.
        """
        parts = spec.split(":")
        if len(parts) < 2:
            raise ValueError(
                f"bad fault spec {spec!r}: expected "
                "'point:kind[:hit=N][:p=F][:times=K]'")
        point, kind = parts[0], parts[1]
        kwargs: dict = {}
        for opt in parts[2:]:
            if "=" not in opt:
                raise ValueError(f"bad fault option {opt!r} in {spec!r}")
            k, v = opt.split("=", 1)
            if k == "hit":
                kwargs["hits"] = tuple(int(x) for x in v.split(","))
            elif k == "p":
                kwargs["p"] = float(v)
            elif k == "times":
                kwargs["times"] = int(v)
            else:
                raise ValueError(f"unknown fault option {k!r} in {spec!r}")
        return cls(point, kind, **kwargs)


class FaultInjector:
    """Evaluates :class:`FaultSpec` firings at every :func:`fault_point`.

    All state (per-point hit counters, per-spec fire counters, the
    seeded rng) mutates under one lock, so a fixed ``seed`` + call order
    reproduces the same firing pattern."""

    def __init__(self, specs, seed: int = 0):
        self.specs = [FaultSpec.parse(s) if isinstance(s, str) else s
                      for s in specs]
        self.seed = seed
        self._rng = random.Random(seed)
        self._hits: dict[str, int] = {}
        self._fired: list[int] = [0] * len(self.specs)
        self._lock = threading.Lock()

    @property
    def fired(self) -> dict[str, int]:
        """Total firings so far, keyed ``point:kind``."""
        out: dict[str, int] = {}
        with self._lock:
            for spec, n in zip(self.specs, self._fired):
                key = f"{spec.point}:{spec.kind}"
                out[key] = out.get(key, 0) + n
        return out

    def fire(self, point: str, payload=None):
        """Evaluate all specs at ``point``; returns the (possibly
        corrupted) payload or raises the injected failure."""
        if point not in INJECTION_POINTS:
            raise ValueError(f"unknown injection point {point!r}; "
                             f"known: {sorted(INJECTION_POINTS)}")
        with self._lock:
            hit = self._hits.get(point, 0) + 1
            self._hits[point] = hit
            todo = None
            for i, spec in enumerate(self.specs):
                if spec.point != point:
                    continue
                if spec.times is not None and self._fired[i] >= spec.times:
                    continue
                if spec.hits is not None:
                    if hit not in spec.hits:
                        continue
                elif spec.p and self._rng.random() >= spec.p:
                    continue
                self._fired[i] += 1
                todo = spec
                # corruption draws its flip position under the same lock
                # so the pattern is reproducible
                flip = (self._rng.randrange(len(payload))
                        if spec.kind == "corrupt" and payload else 0)
                break
        if todo is None:
            return payload
        if todo.kind == "ioerror":
            raise OSError(errno.EIO,
                          f"injected I/O fault at {point} (hit {hit})")
        if todo.kind == "crash":
            raise InjectedCrash(f"injected crash at {point} (hit {hit})")
        # corrupt: flip one byte of the payload
        if not payload:
            return payload
        buf = bytearray(payload)
        buf[flip] ^= 0xFF
        return bytes(buf)


_active: FaultInjector | None = None


def install_faults(injector: FaultInjector) -> None:
    """Install ``injector`` process-wide (``qsim --inject``)."""
    global _active
    _active = injector


def clear_faults() -> None:
    global _active
    _active = None


def active_injector() -> FaultInjector | None:
    return _active


@contextlib.contextmanager
def inject_faults(specs, seed: int = 0):
    """Scoped installation for tests::

        with inject_faults(["pipeline.fetch:crash:hit=3"]) as inj:
            ...
        inj.fired   # {"pipeline.fetch:crash": 1}
    """
    inj = FaultInjector(specs, seed=seed)
    prev = _active
    install_faults(inj)
    try:
        yield inj
    finally:
        install_faults(prev) if prev is not None else clear_faults()


def fault_point(point: str, payload=None):
    """The instrumentation hook: near-zero cost when no injector is
    installed; otherwise evaluates the active injector's specs at
    ``point`` and returns the (possibly corrupted) ``payload``."""
    inj = _active
    if inj is None:
        return payload
    return inj.fire(point, payload)
