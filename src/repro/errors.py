"""Typed failure contract of the resilience layer.

Every layer that touches bytes raises (or converts into) one of these
instead of leaking a raw ``OSError``/``ValueError`` out of a worker
thread with no context:

* :class:`StoreIOError` — a spill-file / checkpoint read or write failed
  after the store's bounded retries; names the operation, key/blob and
  path.  Subclasses ``OSError`` so callers already catching I/O errors
  keep working.
* :class:`BlockCorruptionError` — a stored blob's content checksum did
  not match on read (flipped bits on the spill tier or inside a
  snapshot).  The Simulator converts this into an automatic
  replay-from-last-checkpoint when one exists.
* :class:`CheckpointError` — a snapshot file is structurally bad
  (truncated/torn/bad magic).  Subclasses ``ValueError`` for backward
  compatibility with callers that treated "not a checkpoint" as one.
* :class:`ResumableError` — the run died mid-flight but a consistent
  checkpoint exists; carries ``resume_path`` + ``stages_done`` so the
  caller can ``Simulator.resume(resume_path, circuit=...)``.
* :class:`MemoryPressureError` — the pressure ladder's final rung: the
  run was aborted at a stage boundary because memory blew past every
  degradation step; a :class:`ResumableError` (an emergency checkpoint
  is flushed first when possible).

This module is deliberately stdlib-only and import-cycle-free: both the
``compression`` and ``core`` packages raise these.
"""
from __future__ import annotations

__all__ = [
    "StoreIOError",
    "BlockCorruptionError",
    "CheckpointError",
    "ResumableError",
    "MemoryPressureError",
    "PlanVerificationError",
]


class StoreIOError(OSError):
    """A spill/checkpoint I/O operation failed after bounded retries.

    Attributes:
        op: what was being done ("spill write", "spill read", "snapshot",
            "pipeline fetch", ...).
        key: the store key involved, when known.
        blob_id: the internal blob id involved, when known.
        path: the file path involved, when known.
        retries: how many retries were exhausted before giving up.
    """

    def __init__(self, op: str, *, key=None, blob_id=None, path=None,
                 retries: int = 0, detail: str = ""):
        self.op = op
        self.key = key
        self.blob_id = blob_id
        self.path = path
        self.retries = retries
        parts = [f"{op} failed"]
        if key is not None:
            parts.append(f"key={key}")
        if blob_id is not None:
            parts.append(f"blob={blob_id}")
        if path is not None:
            parts.append(f"path={path}")
        if retries:
            parts.append(f"after {retries} retries")
        if detail:
            parts.append(detail)
        super().__init__(" ".join(parts))


class BlockCorruptionError(RuntimeError):
    """A blob's stored bytes failed their content-checksum verification.

    Raised on every disk-tier read and on snapshot restore — corrupted
    data is *detected*, never silently decoded.  Attributes name the
    blob so the failure is attributable: ``key``, ``blob_id``, ``path``,
    ``expected_crc``, ``actual_crc``.
    """

    def __init__(self, where: str, *, key=None, blob_id=None, path=None,
                 expected_crc=None, actual_crc=None):
        self.where = where
        self.key = key
        self.blob_id = blob_id
        self.path = path
        self.expected_crc = expected_crc
        self.actual_crc = actual_crc
        parts = [f"block checksum mismatch at {where}"]
        if key is not None:
            parts.append(f"key={key}")
        if blob_id is not None:
            parts.append(f"blob={blob_id}")
        if path is not None:
            parts.append(f"path={path}")
        if expected_crc is not None:
            parts.append(f"expected=0x{expected_crc:08x} "
                         f"got=0x{actual_crc:08x}")
        super().__init__(" ".join(parts))


class CheckpointError(ValueError):
    """A checkpoint file is structurally invalid (truncated/torn/bad
    magic) — distinct from a *corrupted blob inside* a structurally
    sound snapshot, which is :class:`BlockCorruptionError`."""


class ResumableError(RuntimeError):
    """The run failed, but a consistent checkpoint can continue it.

    ``resume_path`` names a snapshot written at a stage boundary;
    ``Simulator.resume(resume_path, circuit=...)`` then ``run()``
    reproduces the uninterrupted result.  ``stages_done`` is the number
    of stages the checkpoint contains.  The original failure is chained
    as ``__cause__``.
    """

    def __init__(self, msg: str, *, resume_path: str | None = None,
                 stages_done: int | None = None):
        self.resume_path = resume_path
        self.stages_done = stages_done
        if resume_path is not None:
            msg = (f"{msg} — resume from {resume_path!r} "
                   f"(stages_done={stages_done})")
        super().__init__(msg)


class MemoryPressureError(ResumableError):
    """The pressure ladder's terminal rung: measured memory blew past the
    plan's prediction beyond what degradation could absorb (or the disk
    tier's own budget overflowed).  When checkpointing is active the
    Simulator flushes an emergency checkpoint at the failing stage
    boundary and re-raises this carrying its path."""


class PlanVerificationError(ValueError):
    """An :class:`~repro.core.plan.ExecutionPlan` failed static
    verification (:func:`repro.analysis.plan_check.check_plan`): its
    stage layouts, gate slices, schedules or byte predictions are not
    internally consistent, so executing it verbatim would corrupt state
    or blow the budget.  ``findings`` carries every
    ``PlanFinding`` (errors and warnings) from the failed pass.

    Subclasses ``ValueError`` so callers treating "bad plan artifact"
    generically (e.g. around ``ExecutionPlan.from_json``) keep working.
    """

    def __init__(self, msg: str, findings=()):
        self.findings = tuple(findings)
        super().__init__(msg)
