from .adamw import AdamW, Adafactor, make_optimizer  # noqa: F401
from .grad_compress import GradCompressor  # noqa: F401
