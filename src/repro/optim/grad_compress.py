"""Error-bounded gradient compression with error feedback (beyond-paper).

The paper's insight — point-wise relative-error log-domain quantization
preserves result quality while slashing bytes — applied to data-parallel
gradient exchange: gradients are pwrel-quantized to 16-bit codes before
the (conceptual) cross-pod all-reduce and dequantized after, with the
per-element residual carried into the next step (error feedback), so the
optimizer trajectory stays unbiased in the long run.

In the pjit programming model the all-reduce itself is implicit; this
module implements the quantize -> dequantize + residual transformation
that brackets it, and reports the analytic byte saving (16-bit codes +
1-bit signs vs 32-bit f32 = 2.82x less DP traffic).  Convergence
preservation is exercised by tests/test_train.py on a toy task.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from ..compression.pwrel import CODE_MAX, log_step

__all__ = ["GradCompressor"]


@dataclass(frozen=True)
class GradCompressor:
    b_r: float = 1e-2          # grads tolerate a looser bound than SV amps

    @property
    def bytes_ratio(self) -> float:
        """f32 bytes / compressed bytes (codes u16 + sign bit)."""
        return 32.0 / (16.0 + 1.0)

    def init(self, params):
        return jax.tree.map(
            lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)

    def roundtrip(self, grads, err_state):
        """(grads, residuals) -> (decompressed grads, new residuals)."""
        step = log_step(self.b_r)

        def one(g, e):
            g32 = g.astype(jnp.float32) + e
            absg = jnp.abs(g32)
            max_abs = jnp.max(absg)
            l_max = jnp.where(max_abs > 0,
                              jnp.log2(jnp.maximum(max_abs, 1e-45)), 0.0)
            L = jnp.log2(jnp.maximum(absg, 1e-45))
            d = jnp.round((l_max - L) / step)
            codes = jnp.clip(jnp.float32(CODE_MAX) - d, 0.0, float(CODE_MAX))
            mag = jnp.exp2(l_max - (jnp.float32(CODE_MAX) - codes) * step)
            q = jnp.where(codes < 0.5, 0.0, jnp.sign(g32) * mag)
            return q.astype(g.dtype), g32 - q

        out = jax.tree.map(one, grads, err_state)
        newg = jax.tree.map(lambda t: t[0], out,
                            is_leaf=lambda t: isinstance(t, tuple))
        newe = jax.tree.map(lambda t: t[1], out,
                            is_leaf=lambda t: isinstance(t, tuple))
        return newg, newe
