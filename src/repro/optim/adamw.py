"""Optimizers: AdamW (dtype-configurable moments) and factored Adafactor.

Written against plain pytrees (no optax dependency in this container).
``moment_dtype="bfloat16"`` halves optimizer memory — required to fit
arctic-480b on a single 256-chip pod (see configs/arctic_480b.py).
Adafactor drops the second moment to row+col factors — the fallback if
even bf16 moments don't fit.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

__all__ = ["AdamW", "Adafactor", "make_optimizer"]


@dataclass(frozen=True)
class AdamW:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    moment_dtype: str = "float32"

    def init(self, params):
        dt = jnp.dtype(self.moment_dtype)
        zeros = lambda p: jnp.zeros_like(p, dtype=dt)  # noqa: E731
        return {
            "m": jax.tree.map(zeros, params),
            "v": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(self, grads, state, params):
        step = state["step"] + 1
        b1, b2 = self.b1, self.b2
        c1 = 1.0 - b1 ** step.astype(jnp.float32)
        c2 = 1.0 - b2 ** step.astype(jnp.float32)
        dt = jnp.dtype(self.moment_dtype)

        def upd(p, g, m, v):
            g = g.astype(jnp.float32)
            m32 = b1 * m.astype(jnp.float32) + (1 - b1) * g
            v32 = b2 * v.astype(jnp.float32) + (1 - b2) * g * g
            mhat = m32 / c1
            vhat = v32 / c2
            delta = mhat / (jnp.sqrt(vhat) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            newp = p.astype(jnp.float32) - self.lr * delta
            return newp.astype(p.dtype), m32.astype(dt), v32.astype(dt)

        out = jax.tree.map(upd, params, grads, state["m"], state["v"])
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda t: isinstance(t, tuple))
        new_m = jax.tree.map(lambda t: t[1], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        new_v = jax.tree.map(lambda t: t[2], out,
                             is_leaf=lambda t: isinstance(t, tuple))
        return new_params, {"m": new_m, "v": new_v, "step": step}


@dataclass(frozen=True)
class Adafactor:
    """Factored second moment (row/col means) — O(rows+cols) state for
    matrices, full vector state otherwise.  First moment omitted."""
    lr: float = 3e-4
    decay: float = 0.8
    eps: float = 1e-30
    clip_threshold: float = 1.0

    def init(self, params):
        def factors(p):
            if p.ndim >= 2:
                return {"r": jnp.zeros(p.shape[:-1], jnp.float32),
                        "c": jnp.zeros(p.shape[:-2] + p.shape[-1:],
                                       jnp.float32)}
            return {"v": jnp.zeros_like(p, dtype=jnp.float32)}

        return {"f": jax.tree.map(factors, params,
                                  is_leaf=lambda x: isinstance(x, jax.Array)
                                  or hasattr(x, "shape")),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params):
        step = state["step"] + 1
        beta = 1.0 - (step.astype(jnp.float32) + 1.0) ** (-self.decay)

        def upd(p, g, f):
            g = g.astype(jnp.float32)
            g2 = g * g + self.eps
            if p.ndim >= 2:
                r = beta * f["r"] + (1 - beta) * jnp.mean(g2, axis=-1)
                c = beta * f["c"] + (1 - beta) * jnp.mean(g2, axis=-2)
                denom = (r[..., None] * c[..., None, :]
                         / jnp.maximum(jnp.mean(r, axis=-1,
                                                keepdims=True)[..., None],
                                       self.eps))
                u = g * jax.lax.rsqrt(jnp.maximum(denom, self.eps))
                nf = {"r": r, "c": c}
            else:
                v = beta * f["v"] + (1 - beta) * g2
                u = g * jax.lax.rsqrt(jnp.maximum(v, self.eps))
                nf = {"v": v}
            norm = jnp.sqrt(jnp.mean(u * u))
            u = u / jnp.maximum(1.0, norm / self.clip_threshold)
            newp = (p.astype(jnp.float32) - self.lr * u).astype(p.dtype)
            return newp, nf

        leaves_p, treedef = jax.tree.flatten(params)
        leaves_g = treedef.flatten_up_to(grads)
        leaves_f = treedef.flatten_up_to(state["f"])
        outs = [upd(p, g, f) for p, g, f in zip(leaves_p, leaves_g, leaves_f)]
        new_params = jax.tree.unflatten(treedef, [o[0] for o in outs])
        new_f = jax.tree.unflatten(treedef, [o[1] for o in outs])
        return new_params, {"f": new_f, "step": step}


def make_optimizer(kind: str, lr: float, moment_dtype: str = "float32",
                   weight_decay: float = 0.0):
    if kind == "adamw":
        return AdamW(lr=lr, moment_dtype=moment_dtype,
                     weight_decay=weight_decay)
    if kind == "adafactor":
        return Adafactor(lr=lr)
    raise ValueError(kind)
