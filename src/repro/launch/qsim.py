"""Quantum-simulation launcher (the paper's own workload at scale):
BMQSIM engine over all host devices with a RAM budget + disk tier.

    PYTHONPATH=src python -m repro.launch.qsim --circuit qft --qubits 20 \
        --block-bits 14 [--ram-mb 64]
"""
import argparse

import jax
import numpy as np

from ..core import EngineConfig, build_circuit, simulate_bmqsim


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--circuit", default="qft")
    ap.add_argument("--qubits", type=int, default=18)
    ap.add_argument("--block-bits", type=int, default=12)
    ap.add_argument("--inner-size", type=int, default=2)
    ap.add_argument("--b-r", type=float, default=1e-3)
    ap.add_argument("--ram-mb", type=float, default=None)
    ap.add_argument("--pipeline-depth", type=int, default=2)
    ap.add_argument("--codec-backend", default="host",
                    choices=("host", "device"),
                    help="where the lossy codec runs; 'device' ships only "
                         "the compressed wire across the host-device "
                         "boundary (§4.3)")
    ap.add_argument("--use-kernel", dest="use_kernel", action="store_true",
                    default=True,
                    help="apply gates via the Pallas plane kernels "
                         "(default; --no-kernel for XLA contractions)")
    ap.add_argument("--no-kernel", dest="use_kernel", action="store_false")
    ap.add_argument("--no-schedule", dest="gate_schedule",
                    action="store_false", default=True,
                    help="disable the transpose-minimizing stage schedule "
                         "and run the per-gate transpose/apply/inverse "
                         "path (for comparison)")
    args = ap.parse_args(argv)

    qc = build_circuit(args.circuit, args.qubits)
    cfg = EngineConfig(
        local_bits=args.block_bits, inner_size=args.inner_size,
        b_r=args.b_r, pipeline_depth=args.pipeline_depth,
        codec_backend=args.codec_backend,
        use_kernel=args.use_kernel, gate_schedule=args.gate_schedule,
        devices=jax.devices(),
        ram_budget_bytes=(int(args.ram_mb * 2 ** 20)
                          if args.ram_mb else None))
    state, stats = simulate_bmqsim(qc, cfg,
                                   collect_state=args.qubits <= 20)
    print(f"[qsim] {args.circuit} n={args.qubits}: {stats.n_gates} gates, "
          f"{stats.n_stages} stages, {stats.n_fused_unitaries} fused")
    print(f"[qsim] peak {stats.peak_total_bytes/2**20:.1f} MiB "
          f"({stats.memory_reduction:.1f}x less than standard), "
          f"spills={stats.n_spills}")
    print(f"[qsim] total {stats.t_total:.2f}s (decomp {stats.t_decompress:.2f}"
          f" compute {stats.t_compute:.2f} fetch {stats.t_fetch:.2f}"
          f" comp {stats.t_compress:.2f})")
    print(f"[qsim] group transposes: {stats.n_transposes_scheduled} "
          f"scheduled vs {stats.n_transposes_naive} per-gate")
    print(f"[qsim] boundary traffic ({args.codec_backend} codec): "
          f"{stats.h2d_bytes/2**20:.2f} MiB h2d, "
          f"{stats.d2h_bytes/2**20:.2f} MiB d2h "
          f"over {stats.n_stages} stages")
    if state is not None:
        print(f"[qsim] ||state|| = {np.linalg.norm(state):.6f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
