"""Quantum-simulation launcher (the paper's own workload at scale):
BMQSIM session over all host devices with a RAM budget + disk tier, plus
compressed-store readout — the 2^n state is never materialized.

    PYTHONPATH=src python -m repro.launch.qsim --circuit qft --qubits 20 \
        [--block-bits 14] [--memory-budget 64] [--explain] [--ram-mb 64] \
        [--shots 1024] [--expect zsum] [--save ck.bmq | --resume ck.bmq] \
        [--checkpoint-every 2] [--inject store.spill_read:ioerror:hit=3] \
        [--disk-budget 256] [--no-guardrails]

``--block-bits`` defaults to **auto**: the planner picks
``(local_bits, inner_size, pipeline_depth)`` under ``--memory-budget``
(MiB) when given.  ``--explain`` prints the compiled
:class:`~repro.core.plan.ExecutionPlan` — stage layouts, predicted
working set and boundary traffic — and exits without executing a stage.
``--verify`` instead runs the plan through the static verifier
(:mod:`repro.analysis.plan_check`) and exits nonzero on any error
finding — also without executing a stage.
"""
import argparse
import contextlib
import os

import jax

from ..core import (EngineConfig, Simulator, build_circuit,
                    with_depolarizing, zsum_cost_fn)
from ..core.faults import INJECTION_POINTS, inject_faults
from ..core.planner import estimate_bytes_per_amp
from ..errors import ResumableError


def _ensure_host_devices(n):
    """Expose ``n`` virtual CPU devices before the jax backend spins up.

    Must run before the first device query of the process; once the
    backend is initialized the flag is inert (``sim_devices`` then clamps
    the mesh to whatever is visible, with a warning).
    """
    flags = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in flags:
        os.environ["XLA_FLAGS"] = (
            flags + f" --xla_force_host_platform_device_count={n}").strip()


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--circuit", default="qft")
    ap.add_argument("--qubits", type=int, default=18)
    ap.add_argument("--block-bits", type=int, default=None,
                    help="b: SV block = 2^b amplitudes (default: auto — "
                         "the planner chooses under --memory-budget)")
    ap.add_argument("--inner-size", type=int, default=None,
                    help="Algorithm 1 stage threshold (default: auto)")
    ap.add_argument("--b-r", type=float, default=1e-3)
    ap.add_argument("--memory-budget", type=float, default=None,
                    metavar="MIB",
                    help="working-set budget the planner tunes "
                         "(local_bits, inner_size, pipeline_depth) "
                         "against; also the store's RAM backstop")
    ap.add_argument("--explain", action="store_true",
                    help="print the compiled ExecutionPlan (stage "
                         "layouts, predicted working set/traffic) and "
                         "exit without executing")
    ap.add_argument("--verify", action="store_true",
                    help="compile the plan and run the static verifier "
                         "(layout chain, gate tiling, schedule identity, "
                         "byte predictions) against the circuit, then "
                         "exit without executing; nonzero on any error "
                         "finding")
    ap.add_argument("--ram-mb", type=float, default=None)
    ap.add_argument("--pipeline-depth", type=int, default=None)
    ap.add_argument("--devices", type=int, default=None, metavar="D",
                    help="run on a D-device mesh: lanes shard across "
                         "devices when batched, SV block groups shard "
                         "across devices otherwise (only encoded wire "
                         "crosses device boundaries); on CPU, forces D "
                         "virtual host devices")
    ap.add_argument("--codec-backend", default="host",
                    choices=("host", "device"),
                    help="where the lossy codec runs; 'device' ships only "
                         "the compressed wire across the host-device "
                         "boundary (§4.3)")
    ap.add_argument("--use-kernel", dest="use_kernel", action="store_true",
                    default=True,
                    help="apply gates via the Pallas plane kernels "
                         "(default; --no-kernel for XLA contractions)")
    ap.add_argument("--no-kernel", dest="use_kernel", action="store_false")
    ap.add_argument("--no-schedule", dest="gate_schedule",
                    action="store_false", default=True,
                    help="disable the transpose-minimizing stage schedule "
                         "and run the per-gate transpose/apply/inverse "
                         "path (for comparison)")
    ap.add_argument("--noise", type=float, default=None, metavar="P",
                    help="insert a depolarizing Pauli channel with "
                         "probability P after every gate (stochastic "
                         "circuit; needs --trajectories)")
    ap.add_argument("--trajectories", type=int, default=None, metavar="K",
                    help="sample K noise trajectories as ONE lane-batched "
                         "run; --expect reports the trajectory average")
    ap.add_argument("--batch", type=int, default=None, metavar="K",
                    help="run K identical lanes of a deterministic "
                         "circuit through the batched engine (one "
                         "dispatch per stage+group covers all lanes)")
    ap.add_argument("--noise-seed", type=int, default=0,
                    help="base trajectory seed (lane j draws with "
                         "seed+j)")
    ap.add_argument("--shots", type=int, default=0,
                    help="sample N bitstrings from the compressed final "
                         "state (streamed; prints the top-5 outcomes)")
    ap.add_argument("--expect", default=None, choices=("zsum",),
                    help="streamed diagonal expectation value: 'zsum' = "
                         "<sum_i Z_i>")
    ap.add_argument("--save", default=None, metavar="PATH",
                    help="checkpoint the compressed final state to PATH")
    ap.add_argument("--checkpoint-every", type=int, default=0, metavar="K",
                    help="with --save: also snapshot the store to PATH "
                         "every K stages DURING the run, so a crash is "
                         "resumable from the last completed checkpoint "
                         "(and a detected blob corruption auto-replays "
                         "in-process)")
    ap.add_argument("--resume", default=None, metavar="PATH",
                    help="read a saved checkpoint out (readout flags "
                         "still apply); a PARTIAL mid-run checkpoint is "
                         "finished first (pass the same --circuit/"
                         "--qubits it was launched with)")
    ap.add_argument("--inject", action="append", default=None,
                    metavar="SPEC",
                    help="deterministic fault injection for resilience "
                         "drills: 'point:kind[:hit=N[,M]][:p=F]"
                         "[:times=K]' with kind in ioerror|corrupt|crash"
                         " and point one of "
                         + "|".join(sorted(INJECTION_POINTS))
                         + "; repeatable")
    ap.add_argument("--inject-seed", type=int, default=0,
                    help="seed for probabilistic injection draws and "
                         "corruption positions")
    ap.add_argument("--disk-budget", type=float, default=None,
                    metavar="MIB",
                    help="byte budget for the spill tier; overflowing it "
                         "aborts at a stage boundary with an emergency "
                         "checkpoint (the pressure ladder's final rung)")
    ap.add_argument("--no-guardrails", action="store_true",
                    help="disable block checksums and the memory-"
                         "pressure monitor (benchmark baseline)")
    args = ap.parse_args(argv)

    if args.devices is not None and args.devices < 1:
        ap.error("--devices needs a positive device count")
    if args.devices and args.devices > 1:
        _ensure_host_devices(args.devices)   # before any jax device query

    lanes = args.trajectories or args.batch
    if args.trajectories and args.batch:
        ap.error("--trajectories and --batch are exclusive (both set "
                 "the lane count)")
    if args.noise is not None and not args.trajectories:
        ap.error("--noise makes the circuit stochastic; pass "
                 "--trajectories K to sample it")
    if lanes and (args.save or args.resume):
        ap.error("checkpointing a batched run is not supported; drop "
                 "--save/--resume or the batch flags")
    if args.checkpoint_every and not (args.save or args.resume):
        ap.error("--checkpoint-every needs --save PATH (the checkpoint "
                 "file to roll forward; with --resume it rolls that "
                 "checkpoint forward)")

    inject_ctx = (inject_faults(args.inject, seed=args.inject_seed)
                  if args.inject else contextlib.nullcontext())
    if args.inject:
        print(f"[qsim] injecting faults (seed {args.inject_seed}): "
              + "; ".join(args.inject))

    batch = None                       # BatchResult of a lane-batched run
    if args.resume:
        if args.explain or args.verify:
            ap.error("--explain/--verify need a circuit to compile; they "
                     "cannot be combined with --resume (a checkpoint is "
                     "a finished state, not a plan)")
        try:
            sim = Simulator.resume(args.resume)
            result = sim.result()
        except ValueError as e:
            if "partial checkpoint" not in str(e):
                raise
            # mid-run checkpoint: rebuild the circuit and finish the run
            qc = build_circuit(args.circuit, args.qubits)
            sim = Simulator.resume(args.resume, circuit=qc)
            print(f"[qsim] partial checkpoint "
                  f"({sim._start_stage}/{sim._engine.partition.n_stages} "
                  f"stages done); finishing the run")
            with inject_ctx:
                result = sim.run(checkpoint_path=args.resume
                                 if args.checkpoint_every else None,
                                 checkpoint_every=args.checkpoint_every)
        n = result.n_qubits
        print(f"[qsim] resumed {args.resume}: n={n}, "
              f"local_bits={result.local_bits}")
    else:
        n = args.qubits
        qc = build_circuit(args.circuit, n)
        if args.noise is not None:
            qc = with_depolarizing(qc, args.noise)
        cfg = EngineConfig(
            local_bits=args.block_bits, inner_size=args.inner_size,
            b_r=args.b_r, pipeline_depth=args.pipeline_depth,
            codec_backend=args.codec_backend,
            use_kernel=args.use_kernel, gate_schedule=args.gate_schedule,
            devices=None if args.devices else jax.devices(),
            mesh_shape=(args.devices,) if args.devices else None,
            batch=lanes or 1,
            memory_budget_bytes=(int(args.memory_budget * 2 ** 20)
                                 if args.memory_budget else None),
            ram_budget_bytes=(int(args.ram_mb * 2 ** 20)
                              if args.ram_mb else None),
            disk_budget_bytes=(int(args.disk_budget * 2 ** 20)
                               if args.disk_budget else None),
            integrity_checks=not args.no_guardrails,
            pressure_monitor=not args.no_guardrails)
        sim = Simulator(qc, cfg)
        if args.verify:
            from ..analysis.plan_check import verify_plan
            plan = sim.compile(verify=False)   # verify_plan prints below
            findings = verify_plan(plan, sim.circuit)
            for f in findings:
                print(f.render())
            errors = sum(f.severity == "error" for f in findings)
            print(f"[qsim] plan {plan.fingerprint[:12]}: "
                  f"{plan.n_stages} stage(s) verified, {errors} error(s), "
                  f"{len(findings) - errors} warning(s); no stage executed")
            sim.close()
            return 1 if errors else 0
        if args.explain:
            print(sim.compile().describe())
            rcfg = sim.config
            if rcfg.pressure_monitor:
                bpa = estimate_bytes_per_amp(rcfg.b_r, rcfg.compression)
                ladder = ("shrink_window -> wave_depth_1 -> "
                          "proactive_spill"
                          + (" -> abort+emergency-checkpoint"
                             if args.disk_budget else ""))
                print(f"[qsim] resilience: checksums="
                      f"{'on' if rcfg.integrity_checks else 'off'} "
                      f"io_retries={rcfg.io_retries}; pressure ladder "
                      f"armed at >{rcfg.pressure_headroom:g}x predicted "
                      f"{bpa:.2f} B/amp: {ladder}")
            else:
                print("[qsim] resilience: guardrails off "
                      "(--no-guardrails)")
            sim.close()
            return 0
        rcfg = sim.config
        if args.block_bits is None:
            print(f"[qsim] planned: local_bits={rcfg.local_bits} "
                  f"inner_size={rcfg.inner_size} "
                  f"pipeline_depth={rcfg.pipeline_depth}"
                  + (f" under {args.memory_budget:g} MiB budget"
                     if args.memory_budget else " (no budget: heuristic)"))
        try:
            with inject_ctx:
                if lanes:
                    batch = sim.run(trajectories=lanes,
                                    seed=args.noise_seed)
                    result = batch[0]  # readout flags stream lane 0
                else:
                    result = sim.run(
                        checkpoint_path=(args.save
                                         if args.checkpoint_every
                                         else None),
                        checkpoint_every=args.checkpoint_every)
        except ResumableError as e:
            print(f"[qsim] run failed but is resumable: {e}")
            print(f"[qsim] continue with: qsim --circuit {args.circuit} "
                  f"--qubits {n} --resume {e.resume_path}")
            sim.close()
            return 1
        stats = sim.stats
        if lanes:
            kind = "trajectories" if args.trajectories else "lanes"
            print(f"[qsim] batched run: {lanes} {kind} in "
                  f"{stats.n_batch_chunks} sub-batch(es)"
                  + (f", depolarizing p={args.noise:g}"
                     if args.noise is not None else ""))
        print(f"[qsim] {args.circuit} n={n}: {stats.n_gates} gates, "
              f"{stats.n_stages} stages, {stats.n_fused_unitaries} fused")
        print(f"[qsim] peak {stats.peak_total_bytes/2**20:.1f} MiB "
              f"({stats.memory_reduction:.1f}x less than standard), "
              f"spills={stats.n_spills}")
        print(f"[qsim] total {stats.t_total:.2f}s "
              f"(decomp {stats.t_decompress:.2f}"
              f" compute {stats.t_compute:.2f} fetch {stats.t_fetch:.2f}"
              f" comp {stats.t_compress:.2f})")
        print(f"[qsim] group transposes: {stats.n_transposes_scheduled} "
              f"scheduled vs {stats.n_transposes_naive} per-gate")
        print(f"[qsim] boundary traffic ({args.codec_backend} codec): "
              f"{stats.h2d_bytes/2**20:.2f} MiB h2d, "
              f"{stats.d2h_bytes/2**20:.2f} MiB d2h "
              f"over {stats.n_stages} stages")
        if args.devices and args.devices > 1:
            print(f"[qsim] device exchange ({args.devices} devices): "
                  f"{stats.exchange_bytes/2**20:.2f} MiB encoded wire "
                  f"over {stats.n_exchanged_blocks} block hand-off(s)")
        if (stats.n_io_retries or stats.n_replays
                or stats.n_corruptions_detected or stats.n_pressure_events):
            print(f"[qsim] resilience: io_retries={stats.n_io_retries} "
                  f"replays={stats.n_replays} corruptions_detected="
                  f"{stats.n_corruptions_detected} pressure_rungs="
                  f"{','.join(stats.pressure_rungs) or 'none'}")

    # readout streams the compressed store — one decoded block at a time
    if args.shots:
        counts = result.sample(args.shots, seed=0)
        top = sorted(counts.items(), key=lambda kv: -kv[1])[:5]
        print(f"[qsim] top-5 of {args.shots} shots: "
              + ", ".join(f"|{k:0{n}b}>x{v}" for k, v in top))
    if args.expect == "zsum":
        if batch is None:
            val = result.expectation(zsum_cost_fn(n))
            print(f"[qsim] <sum Z_i> = {val:.6f}")
        else:
            vals = batch.expectations(zsum_cost_fn(n))
            print(f"[qsim] <sum Z_i> = {vals.mean():.6f} "
                  f"(avg over {len(vals)} lanes, "
                  f"std {vals.std():.6f})")
    if args.save:
        result.save(args.save)
        print(f"[qsim] checkpoint -> {args.save}")
    sim.close()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
