"""Production train launcher: mesh + sharded params + fault-tolerant loop.

On this container it runs reduced configs over host devices; on a real
pod-slice the same entry point runs the full config (the dry-run proves
the full-config lowering).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b \
        --steps 50 --mesh 2x4 [--full] [--grad-compress]
"""
import argparse

import jax
import numpy as np

from ..configs import get_config, reduced_config
from ..distributed.sharding import (activate_mesh, named_shardings,
                                    param_pspecs)
from ..models import transformer as T
from ..optim import GradCompressor, make_optimizer
from ..train.data import SyntheticTokens
from ..train.runtime import RuntimeConfig, TrainRuntime
from ..train.step import init_train_state, make_train_step


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--mesh", default="1x1", help="DATAxMODEL")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--grad-compress", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/repro_train_ckpt")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced_config(cfg)
    d, m = map(int, args.mesh.split("x"))
    mesh = jax.make_mesh((d, m), ("data", "model"))

    params = T.init_params(cfg, jax.random.PRNGKey(0))
    pspecs = param_pspecs(cfg, params, mesh)
    params = jax.device_put(params, named_shardings(pspecs, mesh))
    opt = make_optimizer(cfg.optimizer, 3e-3,
                         moment_dtype=cfg.opt_state_dtype)
    gc = GradCompressor(1e-2) if args.grad_compress else None
    state = init_train_state(cfg, params, opt, gc)
    step_fn = jax.jit(make_train_step(cfg, opt, gc))
    src = SyntheticTokens(vocab=cfg.vocab, seq_len=args.seq,
                          global_batch=args.batch)

    rt = TrainRuntime(cfg=RuntimeConfig(ckpt_dir=args.ckpt_dir,
                                        ckpt_every=25),
                      train_step=step_fn, data_source=src)
    with activate_mesh(mesh):
        params, state, hist = rt.run(params, state, n_steps=args.steps)
    losses = [m_["loss"] for m_ in hist]
    print(f"[train] {args.arch} mesh={args.mesh}: "
          f"loss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({np.mean([m_['step_time'] for m_ in hist])*1e3:.0f} ms/step)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
