"""Simulation service launcher: plan-admission scheduling + continuous
lane batching over a structure-keyed session pool.

    PYTHONPATH=src python -m repro.launch.serve \
        --jobs qft:12x4,ising:12x2 --memory-budget 8 --shots 128

Submits the ``--jobs`` workload to an in-process
:class:`~repro.core.service.SimService` — every request is priced at its
:class:`~repro.core.plan.ExecutionPlan`'s predicted peak RAM and
admitted/queued/rejected against the global ``--memory-budget``;
co-admitted requests sharing a circuit *structure* merge into one
``run_batch`` lane stack (cold compile once per structure, warm cache
after) — then drains the scheduler round by round and prints per-job
admission decisions, per-round batch dispatches, and the service stats
line.  See docs/SERVING.md for the operator guide.

Workload spec: ``name:qubits[xCOUNT]``, comma-separated, e.g.
``qft:12x4,ising:12x2,ghz_state:10`` (circuit names from
``repro.core.library.CIRCUIT_BUILDERS``).
"""
import argparse

from ..core import EngineConfig, SimService, build_circuit, with_depolarizing
from ..core.library import CIRCUIT_BUILDERS


def parse_workload(spec: str) -> list[tuple[str, int]]:
    """``"qft:12x4,ising:10"`` -> ``[("qft", 12) x4, ("ising", 10)]``."""
    jobs: list[tuple[str, int]] = []
    for item in spec.split(","):
        item = item.strip()
        if not item:
            continue
        try:
            name, rest = item.split(":", 1)
            if "x" in rest:
                qubits_s, count_s = rest.split("x", 1)
                qubits, count = int(qubits_s), int(count_s)
            else:
                qubits, count = int(rest), 1
        except ValueError:
            raise SystemExit(
                f"bad job spec {item!r} (want name:qubits[xCOUNT])")
        if name not in CIRCUIT_BUILDERS:
            raise SystemExit(
                f"unknown circuit {name!r} (have: "
                f"{', '.join(sorted(CIRCUIT_BUILDERS))})")
        if qubits < 1 or count < 1:
            raise SystemExit(f"bad job spec {item!r}: non-positive size")
        jobs.extend([(name, qubits)] * count)
    if not jobs:
        raise SystemExit("empty --jobs workload")
    return jobs


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="in-process quantum-sim service: plan admission + "
                    "continuous lane batching")
    ap.add_argument("--jobs", default="qft:12x4,ising:12x2",
                    help="workload: name:qubits[xCOUNT],... "
                         "(default qft:12x4,ising:12x2)")
    ap.add_argument("--memory-budget", type=float, default=64.0,
                    metavar="MIB",
                    help="global admission budget in MiB (default 64): the "
                         "sum of admitted plans' predicted peak RAM never "
                         "exceeds it")
    ap.add_argument("--block-bits", type=int, default=None,
                    help="SV block size 2^b per session (default: the "
                         "planner auto-tunes under the budget)")
    ap.add_argument("--shots", type=int, default=None,
                    help="sample counts per job (streamed readout)")
    ap.add_argument("--noise", type=float, default=None, metavar="P",
                    help="wrap every circuit with depolarizing channels "
                         "(jobs become seeded noise-trajectory lanes)")
    ap.add_argument("--seed", type=int, default=0,
                    help="base trajectory seed (job i draws seed+i)")
    ap.add_argument("--max-sessions", type=int, default=8,
                    help="session-pool size (LRU eviction past it)")
    ap.add_argument("--interleave", action="store_true",
                    help="submit round-robin across structures instead of "
                         "spec order (more realistic mixed traffic)")
    args = ap.parse_args(argv)

    budget = int(args.memory_budget * 2 ** 20)
    workload = parse_workload(args.jobs)
    if args.interleave:
        by_name: dict[tuple[str, int], list[tuple[str, int]]] = {}
        for item in workload:
            by_name.setdefault(item, []).append(item)
        workload, queues = [], list(by_name.values())
        while queues:
            queues = [q for q in queues if q]
            workload.extend(q.pop(0) for q in queues)

    config = EngineConfig(local_bits=args.block_bits)
    print(f"[serve] budget {args.memory_budget:g} MiB, "
          f"block-bits {args.block_bits if args.block_bits else 'auto'}, "
          f"session pool <= {args.max_sessions}, "
          f"{len(workload)} job(s): {args.jobs}")

    circuits: dict[tuple[str, int], object] = {}
    with SimService(budget, config=config,
                    max_sessions=args.max_sessions) as svc:
        jobs = []
        for i, (name, qubits) in enumerate(workload):
            key = (name, qubits)
            if key not in circuits:
                qc = build_circuit(name, qubits)
                if args.noise:
                    qc = with_depolarizing(qc, args.noise)
                circuits[key] = qc
            job = svc.submit(circuits[key], seed=args.seed + i,
                             shots=args.shots)
            jobs.append((f"{name}-{qubits}", job))
            peak = job.peak_ram_bytes / 2 ** 20
            print(f"[serve] job {job.job_id:3d} submit {name}-{qubits:<3d}"
                  f" {job.state:8s} {'cold' if job.cold else 'warm'}"
                  f"  peak {peak:.2f} MiB"
                  f"  reserved {svc.reserved_bytes / 2 ** 20:.2f} MiB")

        rnd = 0
        while True:
            done = svc.step()
            if not done:
                break
            rnd += 1
            label = next(lbl for lbl, j in jobs
                         if j.job_id == done[0].job_id)
            print(f"[serve] round {rnd}: {label} x{len(done)} lane(s) "
                  f"merged into one run_batch")
            for job in done:
                lbl = next(lbl for lbl, j in jobs if j.job_id == job.job_id)
                line = (f"[serve] job {job.job_id:3d} {job.state:6s} "
                        f"{lbl:<9s} width {job.merge_width}  "
                        f"wait {job.wait_s:.2f}s  "
                        f"latency {job.latency_s:.2f}s")
                if job.error:
                    line += f"  error {job.error}"
                print(line)

        n_failed = svc.stats.n_failed
        print(f"[serve] stats: {svc.stats.summary()}")
        if args.shots:
            for lbl, job in jobs[:1]:
                if job.state == "done" and "counts" in job.result:
                    top = sorted(job.result["counts"].items(),
                                 key=lambda kv: -kv[1])[:3]
                    pretty = ", ".join(f"{k:#x}:{v}" for k, v in top)
                    print(f"[serve] job {job.job_id} top counts: {pretty}")
    return 1 if n_failed else 0


if __name__ == "__main__":
    raise SystemExit(main())
