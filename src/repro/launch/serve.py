"""Production serve launcher: batched prefill+decode with optional
compressed KV, sharded over a host mesh.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen3-4b \
        --requests 8 --gen 16 [--compressed-kv] [--full]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, reduced_config
from ..distributed.sharding import (activate_mesh, named_shardings,
                                    param_pspecs)
from ..models import transformer as T
from ..serving.kvcache import compress_prefill_cache


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-4b")
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--mesh", default="1x1")
    ap.add_argument("--compressed-kv", action="store_true")
    ap.add_argument("--full", action="store_true")
    args = ap.parse_args(argv)

    cfg = get_config(args.arch)
    if not args.full:
        cfg = reduced_config(cfg)
    d, m = map(int, args.mesh.split("x"))
    mesh = jax.make_mesh((d, m), ("data", "model"))
    key = jax.random.PRNGKey(0)
    params = T.init_params(cfg, key)
    params = jax.device_put(
        params, named_shardings(param_pspecs(cfg, params, mesh), mesh))

    max_len = args.prompt_len + args.gen
    prompts = jax.random.randint(key, (args.requests, args.prompt_len),
                                 0, cfg.vocab)
    with activate_mesh(mesh):
        t0 = time.perf_counter()
        logits, cache = T.forward_prefill(cfg, params, prompts,
                                          max_len=max_len)
        if args.compressed_kv:
            cache = compress_prefill_cache(cache)
        t_prefill = time.perf_counter() - t0
        decode = jax.jit(
            lambda p, t, c, pos: T.forward_decode(cfg, p, t, c, pos))
        tok = jnp.argmax(logits, -1)[:, None]
        t0 = time.perf_counter()
        for i in range(args.gen):
            logits, cache = decode(params, tok, cache,
                                   args.prompt_len + i)
            tok = jnp.argmax(logits, -1)[:, None]
        t_dec = time.perf_counter() - t0
    print(f"[serve] {args.arch} reqs={args.requests} "
          f"ckv={args.compressed_kv}: prefill {t_prefill*1e3:.0f} ms, "
          f"decode {t_dec/args.gen*1e3:.1f} ms/tok, "
          f"{args.requests*args.gen/t_dec:.1f} tok/s")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
