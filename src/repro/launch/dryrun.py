import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^ MUST be the first two lines, before ANY other import (incl. repro.*):
#   jax locks the device count on first init.

__doc__ = """Multi-pod dry-run (deliverable e): lower + compile every assigned
(architecture x input shape) cell on the production meshes and extract
the roofline terms from the compiled artifact.

Per cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=..., out_shardings=...)\
                      .lower(*abstract_args)
        compiled = lowered.compile()
        memory_analysis(), cost_analysis()      -> EXPERIMENTS.md §Dry-run
        collective bytes parsed from HLO        -> §Roofline

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen3-4b \
        --shape train_4k [--multi-pod] [--compressed-kv] [--out out.json]
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]
"""

import argparse
import json
import re
import sys
import time

import jax
from jax.sharding import PartitionSpec as P

from ..configs import ALIASES, get_config
from ..configs.shapes import (SHAPES, cell_is_applicable, input_specs,
                              skip_reason, step_kind)
from ..distributed.sharding import (activate_mesh, batch_pspecs,
                                    cache_pspecs, dp_axes,
                                    named_shardings, param_pspecs)
from ..models import encdec as E
from ..models import transformer as T
from ..optim import make_optimizer
from ..serving.kvcache import compress_prefill_cache
from ..serving.step import make_decode_step, make_prefill_step
from ..train.step import init_train_state, make_train_step
from .mesh import make_production_mesh

# TPU v5e constants (assignment §ROOFLINE ANALYSIS)
PEAK_FLOPS = 197e12          # bf16 / chip
HBM_BW = 819e9               # bytes/s / chip
ICI_BW = 50e9                # bytes/s / link

_COLL_RE = re.compile(
    r"\b(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[-a-z0-9.]*\(", re.I)
_SHAPE_RE = re.compile(r"(f32|bf16|f16|s32|u32|s8|u8|u16|s16|f64|pred|s64|u64)"
                       r"\[([0-9,]*)\]")
_BYTES = {"f64": 8, "s64": 8, "u64": 8, "f32": 4, "s32": 4, "u32": 4,
          "bf16": 2, "f16": 2, "u16": 2, "s16": 2, "s8": 1, "u8": 1,
          "pred": 1}


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Sum output-operand bytes of every collective op in HLO text."""
    per_op: dict[str, int] = {}
    for line in hlo.splitlines():
        m = _COLL_RE.search(line)
        if not m:
            continue
        op = m.group(1).lower()
        # output shape(s): text before the op name, e.g. "x = bf16[..] all-reduce("
        lhs = line.split(m.group(0))[0]
        nbytes = 0
        for dm in _SHAPE_RE.finditer(lhs):
            dtype, dims = dm.group(1), dm.group(2)
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            nbytes += n * _BYTES[dtype]
        per_op[op] = per_op.get(op, 0) + nbytes
    per_op["total"] = sum(per_op.values())
    return per_op


def abstract_params(cfg):
    init = (E.init_encdec_params if cfg.family == "audio" else T.init_params)
    return jax.eval_shape(lambda: init(cfg, jax.random.PRNGKey(0)))


def model_flops(cfg, shape_name: str) -> float:
    """6·N·D (dense) / 6·N_active·D (MoE); decode counts D = batch tokens."""
    sp = SHAPES[shape_name]
    n = cfg.param_count(active_only=True)
    if sp.kind == "train":
        tokens = sp.global_batch * (sp.seq_len if cfg.family != "audio"
                                    else sp.seq_len // 4 + cfg.encoder.dec_len)
        return 6.0 * n * tokens
    if sp.kind == "prefill":
        tokens = sp.global_batch * (sp.seq_len if cfg.family != "audio"
                                    else sp.seq_len // 4 + cfg.encoder.dec_len)
        return 2.0 * n * tokens
    return 2.0 * n * sp.global_batch     # decode: one token per sequence


# hillclimb variants (EXPERIMENTS.md §Perf): config overrides per name
VARIANTS = {
    "baseline": {},
    "seqattn": {"seq_parallel_attn": True},
    "banded": {"banded_local_attn": True},
    "banded+seqattn": {"banded_local_attn": True, "seq_parallel_attn": True},
    "optbf16": {"opt_state_dtype": "bfloat16"},
    "noremat": {"remat": False},
    "adafactor": {"optimizer": "adafactor"},
}


def build_cell(arch: str, shape_name: str, mesh, compressed_kv=False,
               unroll=False, n_layers_override=None, variant="baseline"):
    """Returns (fn, args, in_shardings, out_shardings) ready to lower."""
    cfg = get_config(arch)
    if VARIANTS.get(variant):
        cfg = cfg.with_(**VARIANTS[variant])
    if unroll:
        cfg = cfg.with_(scan_layers=False)
    if n_layers_override is not None:
        cfg = cfg.with_(n_layers=n_layers_override)
        if cfg.encoder is not None:
            # encoder depth must scale with the SAME unit count as the
            # decoder so the X(2)/X(3) extrapolation covers both stacks
            units = max(1, n_layers_override // len(cfg.pattern))
            cfg = cfg.with_(encoder=__import__("dataclasses").replace(
                cfg.encoder, n_layers=units))
    kind = step_kind(shape_name)
    specs = input_specs(cfg, shape_name)
    params = abstract_params(cfg)
    p_specs = param_pspecs(cfg, params, mesh)
    b_specs = batch_pspecs(cfg, specs, mesh)
    repl = P()

    if kind == "train":
        opt = make_optimizer(cfg.optimizer, 3e-4,
                             moment_dtype=cfg.opt_state_dtype)
        state = jax.eval_shape(lambda: init_train_state(cfg, params, opt))
        if cfg.optimizer == "adafactor":
            dp = dp_axes(mesh)
            dpz = 1
            for a in dp:
                dpz *= mesh.shape[a]
            f_specs = jax.tree.map(
                lambda leaf: (P(dp) if leaf.ndim >= 1 and leaf.shape and
                              leaf.shape[0] % dpz == 0 else P()),
                state["opt"]["f"])
            s_specs = {"opt": {"f": f_specs, "step": repl}}
        else:
            s_specs = {"opt": {"m": p_specs, "v": p_specs, "step": repl}}
        step = make_train_step(cfg, opt)
        in_sh = (p_specs, s_specs, b_specs)
        out_sh = (p_specs, s_specs, {"loss": repl, "grad_norm": repl})
        args = (params, state, specs)
        fn = step
    elif kind == "prefill":
        sp = SHAPES[shape_name]
        fn = make_prefill_step(cfg, max_len=sp.seq_len)
        # cache out-sharding: same rules as decode cache
        out_cache = jax.eval_shape(fn, params, specs)[1]
        c_specs = cache_pspecs(cfg, out_cache, mesh, sp.global_batch)
        dp = dp_axes(mesh)
        dpz = 1
        for a in dp:
            dpz *= mesh.shape[a]
        v_ax = "model" if cfg.vocab % mesh.shape["model"] == 0 else None
        logits_spec = P(dp if sp.global_batch % dpz == 0 else None, v_ax)
        in_sh = (p_specs, b_specs)
        out_sh = (logits_spec, c_specs)
        args = (params, specs)
    else:  # decode
        sp = SHAPES[shape_name]
        if compressed_kv:
            specs = dict(specs)
            specs["cache"] = jax.eval_shape(compress_prefill_cache,
                                            specs["cache"])
            b_specs = batch_pspecs(cfg, specs, mesh)
        fn = make_decode_step(cfg)
        dp = dp_axes(mesh)
        dpz = 1
        for a in dp:
            dpz *= mesh.shape[a]
        v_ax = "model" if cfg.vocab % mesh.shape["model"] == 0 else None
        logits_spec = P(dp if sp.global_batch % dpz == 0 else None, v_ax)
        in_sh = (p_specs, b_specs)
        out_sh = (logits_spec, b_specs["cache"])
        args = (params, specs)

    return fn, args, in_sh, out_sh


def _compile_once(arch, shape_name, mesh, compressed_kv, unroll,
                  n_layers_override=None, variant="baseline"):
    fn, args, in_sh, out_sh = build_cell(arch, shape_name, mesh,
                                         compressed_kv, unroll,
                                         n_layers_override, variant)
    # donation: train updates (params, opt state) in place; decode updates
    # the KV cache in place — without it XLA double-buffers the largest
    # arrays (qwen1.5 decode: 40 GiB/device observed -> ~2x less donated)
    kind = step_kind(shape_name)
    donate = (0, 1) if kind == "train" else ((1,) if kind == "decode" else ())
    jitted = jax.jit(fn,
                     in_shardings=named_shardings(in_sh, mesh),
                     out_shardings=named_shardings(out_sh, mesh),
                     donate_argnums=donate)
    lowered = jitted.lower(*args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis()
    return {
        "compiled": compiled,
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": collective_bytes_from_hlo(compiled.as_text()),
    }


def run_cell(arch: str, shape_name: str, multi_pod: bool = False,
             compressed_kv: bool = False, unroll: bool = False,
             variant: str = "baseline", verbose: bool = True) -> dict:
    """Roofline terms via the paired-compile scan correction: XLA's
    analytical cost model counts while-loop (scan) bodies ONCE, so we
    compile (A) the production scanned program -> outside + body, and
    (C) a cheap 2-unit unrolled variant -> outside + 2*body, and
    reconstruct  total = A*(2-U) + C*(U-1)  for every linear quantity
    (FLOPs, bytes accessed, per-collective bytes).  A is also the
    memory-fit/compile-success artifact.  ``unroll=True`` instead unrolls
    the full depth (exact, but ~25x slower compiles; used to validate the
    correction — see EXPERIMENTS.md §Method)."""
    cfg = get_config(arch)
    if not cell_is_applicable(cfg, shape_name):
        return {"arch": arch, "shape": shape_name,
                "skipped": skip_reason(cfg, shape_name)}
    mesh = make_production_mesh(multi_pod=multi_pod)
    n_chips = mesh.devices.size
    t0 = time.time()
    with activate_mesh(mesh):
        A = _compile_once(arch, shape_name, mesh, compressed_kv, unroll,
                          variant=variant)
        t_lower = time.time() - t0
        U = cfg.n_units
        if unroll or U <= 1:
            flops, bytes_accessed, coll = A["flops"], A["bytes"], A["coll"]
        else:
            # X(k) = outside + k*(per-unit cost) for UNROLLED k-unit programs
            # -> X(U) = X(2) + (U-2)*(X(3) - X(2)).  (The scanned program A
            # can't enter this model: its loop inputs carry all U units'
            # params/caches at once.)  A still provides memory_analysis +
            # the production compile proof.
            pat, rem = len(cfg.pattern), cfg.n_remainder
            C = _compile_once(arch, shape_name, mesh, compressed_kv,
                              unroll=True, n_layers_override=2 * pat + rem,
                              variant=variant)
            D = _compile_once(arch, shape_name, mesh, compressed_kv,
                              unroll=True, n_layers_override=3 * pat + rem,
                              variant=variant)
            ext = lambda c, d: c + (U - 2.0) * (d - c)  # noqa: E731
            flops = ext(C["flops"], D["flops"])
            bytes_accessed = ext(C["bytes"], D["bytes"])
            keys = set(C["coll"]) | set(D["coll"])
            coll = {k: max(0, int(ext(C["coll"].get(k, 0),
                                      D["coll"].get(k, 0))))
                    for k in keys}
        t_compile = time.time() - t0 - t_lower

    compiled = A["compiled"]
    mem = compiled.memory_analysis()

    # NOTE: the compiled artifact is the per-device SPMD module, so
    # cost_analysis() FLOPs/bytes and the HLO collective sizes are all
    # PER-DEVICE quantities.  total = per_device * n_chips.
    compute_s = flops / PEAK_FLOPS                  # = total/(chips*peak)
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll["total"] / ICI_BW
    mf = model_flops(cfg, shape_name)

    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "n_chips": n_chips,
        "compressed_kv": compressed_kv,
        "variant": variant,
        "step_kind": step_kind(shape_name),
        "hlo_flops_per_device": flops,
        "hlo_flops": flops * n_chips,
        "hlo_bytes_per_device": bytes_accessed,
        "hlo_bytes": bytes_accessed * n_chips,
        "collective_bytes": coll,
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "bottleneck": max(
            [("compute", compute_s), ("memory", memory_s),
             ("collective", collective_s)], key=lambda kv: kv[1])[0],
        "model_flops": mf,
        "useful_flops_ratio": mf / (flops * n_chips) if flops else 0.0,
        "bytes_per_device": {
            "argument": getattr(mem, "argument_size_in_bytes", None),
            "output": getattr(mem, "output_size_in_bytes", None),
            "temp": getattr(mem, "temp_size_in_bytes", None),
            "peak": getattr(mem, "peak_memory_in_bytes", None),
        },
        "t_lower_s": round(t_lower, 1),
        "t_compile_s": round(t_compile, 1),
    }
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} ({rec['mesh']}"
              f"{' +ckv' if compressed_kv else ''}"
              f"{'' if variant == 'baseline' else ' +' + variant}): "
              f"compute {compute_s*1e3:.2f}ms memory {memory_s*1e3:.2f}ms "
              f"collective {collective_s*1e3:.2f}ms -> {rec['bottleneck']}"
              f" | peak/dev {(rec['bytes_per_device']['peak'] or 0)/2**30:.2f}"
              f"GiB | compile {t_compile:.0f}s", flush=True)
    return rec


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--compressed-kv", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="unroll layer scans for exact roofline accounting")
    ap.add_argument("--variant", default="baseline", choices=list(VARIANTS))
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    cells = []
    if args.all:
        for arch in ALIASES:
            for shape in SHAPES:
                cells.append((arch, shape))
    else:
        if not args.arch or not args.shape:
            ap.error("--arch/--shape or --all required")
        cells = [(args.arch, args.shape)]

    results = []
    for arch, shape in cells:
        try:
            results.append(run_cell(arch, shape, multi_pod=args.multi_pod,
                                    compressed_kv=args.compressed_kv,
                                    unroll=args.unroll,
                                    variant=args.variant))
        except Exception as exc:  # noqa: BLE001 — report, keep sweeping
            print(f"[dryrun] {arch} x {shape} FAILED: {exc}", flush=True)
            results.append({"arch": arch, "shape": shape,
                            "error": f"{type(exc).__name__}: {exc}"})
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1, default=str)
    n_err = sum(1 for r in results if "error" in r)
    print(f"[dryrun] {len(results)} cells, {n_err} failures", flush=True)
    return 1 if n_err else 0


if __name__ == "__main__":
    sys.exit(main())
