"""Production mesh factory (assignment §MULTI-POD DRY-RUN item 1).

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_host_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 (256-chip pod) or 2x16x16 (2 pods = 512 chips)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(data: int = 1, model: int = 1):
    """Small mesh over whatever devices exist (tests / examples)."""
    n = len(jax.devices())
    if data * model > n:
        raise ValueError(f"mesh {data}x{model} needs {data*model} devices, "
                         f"have {n}")
    return jax.make_mesh((data, model), ("data", "model"))
