from .step import make_train_step, make_loss_fn  # noqa: F401
