"""Deterministic, checkpointable data pipeline.

Production shape: each host draws its own disjoint shard of the global
batch from a seeded stateless generator (step -> batch is a pure
function), so (1) restart-after-failure replays the exact stream from the
checkpointed step with no iterator state to persist beyond an int, and
(2) elastic re-sharding (host count change) re-partitions the SAME global
stream.  A file-backed source (memory-mapped token file) slots in behind
the same interface.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["SyntheticTokens", "FileTokens", "make_batches"]


@dataclass(frozen=True)
class SyntheticTokens:
    """Stateless synthetic LM stream: batch = f(seed, step, shard)."""

    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_shards: int = 1
    shard: int = 0

    @property
    def shard_batch(self) -> int:
        assert self.global_batch % self.n_shards == 0
        return self.global_batch // self.n_shards

    def batch(self, step: int) -> np.ndarray:
        """(shard_batch, seq_len) int32 — a Zipf-ish mixture so losses move."""
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        b = self.shard_batch
        # mixture: local n-gram structure + global skew -> learnable signal
        base = rng.zipf(1.3, size=(b, self.seq_len)).astype(np.int64)
        toks = base % (self.vocab - 3)
        # inject copy structure: second half repeats first half shifted
        half = self.seq_len // 2
        toks[:, half:half * 2] = toks[:, :half]
        return toks.astype(np.int32)


@dataclass(frozen=True)
class FileTokens:
    """Memory-mapped flat token file (uint16/uint32), random-access crops."""

    path: str
    vocab: int
    seq_len: int
    global_batch: int
    dtype: str = "uint16"
    seed: int = 0
    n_shards: int = 1
    shard: int = 0

    @property
    def shard_batch(self) -> int:
        return self.global_batch // self.n_shards

    def batch(self, step: int) -> np.ndarray:
        data = np.memmap(self.path, dtype=self.dtype, mode="r")
        n = data.shape[0] - self.seq_len - 1
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed, step, self.shard]))
        starts = rng.integers(0, n, size=self.shard_batch)
        out = np.stack([data[s:s + self.seq_len] for s in starts])
        return (out.astype(np.int64) % self.vocab).astype(np.int32)


def make_batches(source, start_step: int = 0):
    """Infinite iterator of (step, batch) resuming at ``start_step``."""
    step = start_step
    while True:
        yield step, source.batch(step)
        step += 1
