"""Fault-tolerant training runtime.

What "runs on 1000+ nodes" means here and how each piece maps:

* **checkpoint/restart** — ``TrainRuntime.run`` checkpoints every
  ``ckpt_every`` steps through the atomic CheckpointManager and, on ANY
  exception from the step function, restores the latest checkpoint and
  replays (the data pipeline is stateless-resumable, so the stream is
  bit-identical).  ``max_restarts`` bounds flapping.
* **elastic scaling** — restore re-device_puts against the *current*
  mesh's shardings: a job preempted on N hosts resumes on M hosts
  unchanged (exercised by tests/test_checkpoint.py).
* **straggler mitigation** — step-time watchdog: steps slower than
  ``straggler_factor`` x the trailing median are counted and surfaced in
  metrics; on real fleets this signal feeds the scheduler's hot-spare
  swap. (A single-process container can observe, not migrate.)
* **failure injection** — ``fail_at_step`` deterministically raises inside
  the loop to exercise the restart path in tests.
"""
from __future__ import annotations

import statistics
import time
from dataclasses import dataclass, field

import jax

from .checkpoint import CheckpointManager

__all__ = ["TrainRuntime", "RuntimeConfig"]


@dataclass
class RuntimeConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    keep_last: int = 3
    max_restarts: int = 3
    straggler_factor: float = 2.0
    fail_at_step: int | None = None     # test hook: raise once at this step


@dataclass
class TrainRuntime:
    cfg: RuntimeConfig
    train_step: object                   # jitted (params, state, batch) -> ...
    data_source: object                  # .batch(step) -> np array
    shardings: object = None             # pytree for elastic restore

    _failed_once: bool = field(default=False, init=False)

    def run(self, params, state, n_steps: int, batch_to_device=None):
        mgr = CheckpointManager(self.cfg.ckpt_dir, keep_last=self.cfg.keep_last)
        restarts = 0
        step = 0
        # resume if a checkpoint exists
        if mgr.latest_step() is not None:
            (params, state), step = mgr.restore((params, state),
                                                shardings=self.shardings)
            step += 1
        metrics_hist = []
        step_times: list[float] = []
        stragglers = 0
        while step < n_steps:
            try:
                t0 = time.perf_counter()
                if (self.cfg.fail_at_step == step and not self._failed_once):
                    self._failed_once = True
                    raise RuntimeError(f"injected node failure @step {step}")
                batch = {"tokens": self.data_source.batch(step)}
                if batch_to_device is not None:
                    batch = batch_to_device(batch)
                params, state, metrics = self.train_step(params, state, batch)
                metrics = jax.tree.map(float, metrics)
                dt = time.perf_counter() - t0
                if len(step_times) >= 5:
                    med = statistics.median(step_times[-20:])
                    if dt > self.cfg.straggler_factor * med:
                        stragglers += 1
                step_times.append(dt)
                metrics.update(step=step, step_time=dt,
                               stragglers=stragglers, restarts=restarts)
                metrics_hist.append(metrics)
                if step % self.cfg.ckpt_every == 0 or step == n_steps - 1:
                    mgr.save(step, (params, state))
                step += 1
            except (KeyboardInterrupt,):
                raise
            except Exception as exc:  # noqa: BLE001 — restart path
                restarts += 1
                if restarts > self.cfg.max_restarts:
                    raise RuntimeError(
                        f"exceeded max_restarts={self.cfg.max_restarts}"
                    ) from exc
                if mgr.latest_step() is None:
                    # nothing saved yet: restart from the initial state
                    step = 0
                    continue
                (params, state), last = mgr.restore((params, state),
                                                    shardings=self.shardings)
                step = last + 1
        return params, state, metrics_hist
