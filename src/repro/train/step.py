"""Train-step factory: loss -> grads -> (optional grad compression) -> update.

One factory covers all families; the batch dict keys select the path:
  decoder LMs   {"tokens"}           (+ "aux" image embeddings for VLM)
  enc-dec       {"frames", "tokens"}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import encdec as E
from ..models import transformer as T
from ..models.config import ModelConfig
from ..optim.grad_compress import GradCompressor

__all__ = ["make_loss_fn", "make_train_step", "init_train_state"]


def make_loss_fn(cfg: ModelConfig):
    if cfg.family == "audio":
        def loss(params, batch):
            return E.loss_fn_encdec(cfg, params, batch["frames"],
                                    batch["tokens"])
    else:
        def loss(params, batch):
            return T.loss_fn(cfg, params, batch["tokens"],
                             batch.get("aux"))
    return loss


def init_train_state(cfg: ModelConfig, params, optimizer,
                     grad_compressor: GradCompressor | None = None):
    state = {"opt": optimizer.init(params)}
    if grad_compressor is not None:
        state["gc_err"] = grad_compressor.init(params)
    return state


def make_train_step(cfg: ModelConfig, optimizer,
                    grad_compressor: GradCompressor | None = None):
    loss_fn = make_loss_fn(cfg)

    def train_step(params, state, batch):
        loss, grads = jax.value_and_grad(loss_fn)(params, batch)
        if grad_compressor is not None:
            grads, new_err = grad_compressor.roundtrip(grads,
                                                       state["gc_err"])
        params, opt = optimizer.update(grads, state["opt"], params)
        new_state = {"opt": opt}
        if grad_compressor is not None:
            new_state["gc_err"] = new_err
        gnorm = jnp.sqrt(sum(
            jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree.leaves(grads)))
        return params, new_state, {"loss": loss, "grad_norm": gnorm}

    return train_step
