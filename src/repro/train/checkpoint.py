"""Checkpoint manager: atomic, resumable, elastic-reshardable.

Fault-tolerance contract (assignment: "checkpoint/restart, handle node
failures"):

* ``save`` writes every leaf as a raw ``.npy`` under ``step_XXXX.tmp`` and
  atomically renames to ``step_XXXX`` — a crash mid-save never corrupts
  the latest checkpoint.
* ``restore`` loads the newest complete step; leaves are ``device_put``
  against the CURRENT mesh's shardings, so a checkpoint written on one
  topology restores onto another (elastic re-shard: 8 hosts -> 4 hosts ->
  512 chips are all the same bytes).
* optional pwrel+zlib compression of leaves (the paper's two-level-store
  idea applied to checkpoint bytes; lossless for exact restart).
* ``keep_last`` garbage-collects old steps.

The leaf<->file mapping is the pytree path (stable across runs because
params are plain dicts/lists of fixed layout).
"""
from __future__ import annotations

import json
import os
import shutil
import zlib

import jax
import numpy as np

__all__ = ["CheckpointManager"]


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in leaves:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        out[key] = leaf
    return out, treedef


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3,
                 compress: bool = False):
        self.dir = directory
        self.keep_last = keep_last
        self.compress = compress
        os.makedirs(directory, exist_ok=True)

    # -- paths -----------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> list[int]:
        out = []
        for name in os.listdir(self.dir):
            if name.startswith("step_") and not name.endswith(".tmp"):
                out.append(int(name.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # -- save --------------------------------------------------------------------
    def save(self, step: int, tree) -> None:
        leaves, _ = _flatten(tree)
        tmp = self._step_dir(step) + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)
        manifest = {}
        for key, leaf in leaves.items():
            arr = np.asarray(jax.device_get(leaf))
            fn = key.replace("/", "__") + ".bin"
            path = os.path.join(tmp, fn)
            # raw-bytes container (np.save chokes on ml_dtypes like bf16)
            blob = arr.tobytes()
            codec = "raw"
            if self.compress:
                blob = zlib.compress(blob, 1)
                codec = "zlib"
            with open(path, "wb") as f:
                f.write(blob)
            manifest[key] = {"file": fn, "dtype": str(arr.dtype),
                             "shape": list(arr.shape), "codec": codec}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        final = self._step_dir(step)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)            # atomic commit
        self._gc()

    def _gc(self) -> None:
        steps = self.steps()
        for s in steps[: -self.keep_last]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # -- restore -------------------------------------------------------------------
    def restore(self, template, step: int | None = None, shardings=None):
        """Load into the structure of ``template``; ``shardings`` (same
        pytree) re-shards each leaf onto the current mesh."""
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)

        leaves, treedef = _flatten(template)
        shard_leaves = None
        if shardings is not None:
            shard_leaves, _ = _flatten(shardings)
        import ml_dtypes

        def _dtype(name: str):
            try:
                return np.dtype(name)
            except TypeError:
                return np.dtype(getattr(ml_dtypes, name))

        out = {}
        for key in leaves:
            ent = manifest[key]
            path = os.path.join(d, ent["file"])
            with open(path, "rb") as f:
                blob = f.read()
            if ent["codec"] == "zlib":
                blob = zlib.decompress(blob)
            arr = np.frombuffer(blob, dtype=_dtype(ent["dtype"])) \
                .reshape(ent["shape"])
            if shard_leaves is not None:
                out[key] = jax.device_put(arr, shard_leaves[key])
            else:
                out[key] = jax.numpy.asarray(arr)
        ordered = [out[k] for k in leaves]
        return jax.tree_util.tree_unflatten(treedef, ordered), step
