"""Simulator: a persistent session around the compressed engine.

The one-shot :func:`simulate_bmqsim` call re-partitions the circuit and
rebuilds every stage schedule per invocation, and its only readout is the
dense 2^n state — which defeats the memory budget the engine exists to
honor.  The session API fixes both ends:

    sim = Simulator(qaoa_template(24, layers=1), EngineConfig(local_bits=16))
    r1 = sim.run(params={"gamma0": 0.8, "beta0": 0.4})
    e1 = r1.expectation(maxcut_cost_fn(maxcut_edges(24)))
    r2 = sim.run(params={"gamma0": 1.1, "beta0": 0.7})   # NO recompilation
    counts = r2.sample(4096)                              # streams blocks

* **Construction** plans: auto knobs (``local_bits=None`` +
  ``memory_budget_bytes``) resolve through the planner's cost model, and
  the §4.1 partition happens once.  Every ``run()`` reuses it, plus the
  compiled stage functions and transpose-minimizing schedules (cached on
  stage *structure*, which parameter values don't change) —
  ``SimStats.n_stagefn_compiles`` must not grow after the first run of a
  sweep.  :meth:`Simulator.compile` returns the frozen
  :class:`~repro.core.plan.ExecutionPlan` artifact without executing
  anything (``qsim --explain``).
* **Readout** returns a :class:`~repro.core.result.SimResult` handle over
  the compressed store; sampling/expectations/amplitudes stream
  block-by-block with ~one decoded block of peak extra memory.
* **Checkpointing**: ``result.save(path)`` serializes the compressed
  blocks + layout; :meth:`Simulator.resume` reopens them — readout-only
  (no circuit needed), or with the circuit to continue an interrupted
  run from the last checkpointed stage
  (``run(checkpoint_path=..., checkpoint_every=k)``).
"""
from __future__ import annotations

import os
import tempfile
from dataclasses import replace

from ..compression.pwrel import PwRelParams
from ..compression.store import BlockStore
from ..errors import (BlockCorruptionError, MemoryPressureError,
                      ResumableError, StoreIOError)
from ..kernels.ops import default_interpret
from .circuit import Circuit
from .engine import BMQSimEngine, EngineConfig, SimStats
from .pipeline import make_backend
from .plan import ExecutionPlan, circuit_fingerprint
from .result import BatchResult, SimResult

__all__ = ["Simulator", "circuit_fingerprint"]

_CKPT_KIND = "bmqsim-checkpoint"
_CKPT_VERSION = 2

#: automatic replays-from-checkpoint after a detected corruption before
#: giving up with a ResumableError (persistent corruption means the
#: medium, not a transient flip)
_MAX_REPLAYS = 2


class Simulator:
    """A simulation session: one partition, many runs, streaming readout.

    Use as a context manager (owns the block store)::

        with Simulator(circuit, config) as sim:
            result = sim.run()
            counts = result.sample(1024)

    A session is either *engine-backed* (constructed from a circuit, can
    ``run()``) or *readout-only* (``Simulator.resume(path)`` without a
    circuit: the checkpointed final state is readable, re-running needs
    the circuit).
    """

    def __init__(self, circuit: Circuit, config: EngineConfig,
                 *, plan: ExecutionPlan | None = None,
                 _store: BlockStore | None = None):
        self._engine: BMQSimEngine | None = \
            BMQSimEngine(circuit, config, store=_store, plan=plan)
        self._backend = self._engine.backend
        self.n_qubits = self._engine.n
        self.local_bits = self._engine.b
        self._meta: dict | None = None
        self._generation = 0
        self._last: SimResult | BatchResult | None = None
        self._batched = False          # latest run was a run_batch
        self._start_stage = 0          # nonzero after a partial resume
        self._resume_params: dict | None = None
        self._closed = False

    # -- session lifecycle -----------------------------------------------------
    def __enter__(self) -> "Simulator":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        self._generation += 1          # invalidate outstanding handles
        if self._engine is not None:
            self._engine.close()
        else:
            self._backend.store.close()

    @property
    def stats(self) -> SimStats | None:
        """Cumulative counters/timings across every run of this session
        (None for a readout-only resumed session)."""
        return self._engine.stats if self._engine is not None else None

    @property
    def circuit(self) -> Circuit | None:
        return self._engine.circuit if self._engine is not None else None

    @property
    def config(self) -> EngineConfig | None:
        """The *resolved* engine config (auto knobs made concrete)."""
        return self._engine.cfg if self._engine is not None else None

    # -- planning --------------------------------------------------------------
    def compile(self, params: dict | None = None, *,
                verify: bool = True) -> ExecutionPlan:
        """Compile (but do not execute) the circuit: returns the
        :class:`~repro.core.plan.ExecutionPlan` this session will run —
        per-stage layouts/fused plans/schedules/stage-fn keys plus the
        planner's working-set and traffic predictions.

        ``params`` is needed iff the circuit template is parameterized
        (fused structure requires concrete matrices); any binding of one
        template yields the same plan, which is cached.  The subsequent
        :meth:`run` executes exactly this plan with zero additional
        schedule compilation.

        With ``verify=True`` (the default) the plan is run through the
        static verifier (:func:`repro.analysis.plan_check.check_plan`)
        before being returned: layout chaining, gate-slice tiling,
        permutation identity and byte-prediction consistency are proven
        against the circuit, and a plan that fails raises
        :class:`~repro.errors.PlanVerificationError`.  This catches
        planner regressions and tampered/stale plan artifacts that the
        fingerprint alone cannot (the fingerprint hashes only the stage
        inner-sets and slice *lengths*).
        """
        if self._closed:
            raise RuntimeError("Simulator is closed")
        if self._engine is None:
            raise RuntimeError(
                "readout-only session (resumed without a circuit) has "
                "no plan to compile; pass circuit= to Simulator.resume")
        plan = self._engine.compile(params)
        if verify:
            # lazy: analysis.plan_check is pure but pulls the planner
            from ..analysis.plan_check import check_plan
            check_plan(plan, self._engine.circuit)
        return plan

    # -- execution -------------------------------------------------------------
    def run(self, params: dict | None = None, *,
            trajectories: int | None = None, seed: int = 0,
            checkpoint_path: str | None = None,
            checkpoint_every: int = 0) -> "SimResult | BatchResult":
        """Execute the circuit; returns a readout handle over the final
        compressed state.

        Args:
            params: values for the circuit's free parameters (required iff
                the circuit template is parameterized).  Re-running with
                new values reuses the partition, compiled stage functions
                and schedules; only the fused gate operands are rebuilt
                (and cached per binding).
            trajectories: run K stochastic noise trajectories of the
                circuit as ONE lane-batched execution and return a
                :class:`BatchResult` (lane j realizes the circuit's Pauli
                channels with rng seed ``seed + j``).  Required for
                circuits containing channels (see
                ``library.with_depolarizing``); a deterministic circuit
                runs K identical lanes (a batching benchmark).
            seed: base trajectory seed (lane j draws with ``seed + j``).
            checkpoint_path: with ``checkpoint_every=k``, snapshot the
                store + progress every k stages so an interrupted run can
                :meth:`resume` from the last completed checkpoint.  A
                blob corruption detected mid-run additionally triggers an
                automatic in-process replay from that checkpoint
                (``stats.n_replays``), and exhausted I/O retries surface
                as a :class:`~repro.errors.ResumableError` naming it.
            checkpoint_every: checkpoint period in stages (0 = never).

        Returns:
            A live :class:`SimResult` (or :class:`BatchResult` with
            ``trajectories``); invalidated by the next ``run()`` or
            :meth:`close` (persist with ``result.save(path)``).
        """
        if trajectories is not None:
            if checkpoint_path or checkpoint_every:
                raise ValueError(
                    "mid-run checkpointing is not supported for batched "
                    "trajectory runs")
            return self.run_batch(
                [params] * trajectories,
                seeds=[seed + j for j in range(trajectories)])
        if self._closed:
            raise RuntimeError("Simulator is closed")
        if self._engine is None:
            raise RuntimeError(
                "readout-only session (resumed without a circuit); pass "
                "circuit= to Simulator.resume to re-run or continue")
        if self._start_stage > 0:
            # continuing a partial checkpoint: the already-executed stages
            # were bound with the checkpointed params — a different
            # binding for the remaining stages would produce a state no
            # single parameter setting generates
            if params is None:
                params = self._resume_params
            elif (BMQSimEngine._params_key(params)
                  != BMQSimEngine._params_key(self._resume_params)):
                raise ValueError(
                    "cannot continue a partial checkpoint with different "
                    f"params: checkpointed {self._resume_params!r}, "
                    f"given {params!r}")
        # validate the binding BEFORE invalidating anything: a bad
        # params dict must not stale the previous (still intact) result
        # or discard a partial checkpoint's resume position.  Cached, so
        # the actual run re-pays nothing.
        self._engine._bind_stages(params)
        start = self._start_stage
        self._start_stage = 0
        self._resume_params = None
        self._generation += 1          # old handles read overwritten blocks
        self._batched = False
        on_stage_done = None
        last_ckpt = {"stage": None}    # last checkpoint written THIS run
        if checkpoint_path and checkpoint_every > 0:
            def on_stage_done(idx: int) -> None:
                if (idx + 1) % checkpoint_every == 0:
                    self._save_checkpoint(checkpoint_path,
                                          stages_done=idx + 1,
                                          run_params=params)
                    last_ckpt["stage"] = idx + 1
        self._run_resilient(params, start, on_stage_done,
                            checkpoint_path, last_ckpt)
        self._last = SimResult(self._backend, self.n_qubits, self.local_bits,
                               stats=self._engine.stats, owner=self,
                               generation=self._generation)
        return self._last

    def _run_resilient(self, params, start, on_stage_done,
                       checkpoint_path, last_ckpt) -> None:
        """Drive ``engine.run`` with the resilience contract.

        * :class:`~repro.errors.BlockCorruptionError` — a blob failed its
          checksum mid-run.  If a checkpoint was written *this run*,
          replay from it (restore the snapshot in place, restart from the
          checkpointed stage; ``stats.n_replays``), up to ``_MAX_REPLAYS``
          times; otherwise (or when corruption persists) propagate.
        * :class:`~repro.errors.MemoryPressureError` — the monitor's
          terminal rung fired at a stage boundary, where the store is
          consistent: flush an emergency checkpoint
          (``stats.n_emergency_checkpoints``) and re-raise carrying its
          ``resume_path``.
        * :class:`~repro.errors.StoreIOError` — retries exhausted
          mid-stage, where the store holds a mix of old/new blocks, so NO
          new snapshot is taken; re-raised as a
          :class:`~repro.errors.ResumableError` naming the last periodic
          checkpoint when one exists.
        """
        eng = self._engine
        replays = 0
        while True:
            try:
                eng.run(collect_state=False, params=params,
                        start_stage=start, on_stage_done=on_stage_done)
                return
            except BlockCorruptionError as e:
                eng._snap_store_stats()
                stage = last_ckpt["stage"]
                if (stage is None or checkpoint_path is None
                        or replays >= _MAX_REPLAYS):
                    if stage is not None and checkpoint_path is not None:
                        raise ResumableError(
                            f"corruption persisted across {replays} "
                            f"replays: {e}",
                            resume_path=checkpoint_path,
                            stages_done=stage) from e
                    raise
                replays += 1
                eng.stats.n_replays += 1
                self._backend.store.load_snapshot(checkpoint_path)
                start = stage
            except MemoryPressureError as e:
                eng._snap_store_stats()
                path = checkpoint_path
                if path is None:
                    fd, path = tempfile.mkstemp(
                        prefix="bmqsim-emergency-", suffix=".ckpt")
                    os.close(fd)
                try:
                    self._save_checkpoint(path, stages_done=e.stages_done,
                                          run_params=params)
                except OSError:
                    # the flush itself failed (e.g. the disk that just
                    # overflowed — snapshot I/O surfaces as StoreIOError,
                    # an OSError): surface the original pressure abort.
                    # An InjectedCrash stays fatal, as a real kill would.
                    raise e from None
                eng.stats.n_emergency_checkpoints += 1
                raise MemoryPressureError(
                    e.args[0], resume_path=path,
                    stages_done=e.stages_done) from e
            except StoreIOError as e:
                eng._snap_store_stats()
                stage = last_ckpt["stage"]
                if stage is not None and checkpoint_path is not None:
                    raise ResumableError(
                        f"store I/O failed after retries ({e})",
                        resume_path=checkpoint_path,
                        stages_done=stage) from e
                raise

    def run_batch(self, params_list, *, seeds=None,
                  checkpoint_path: str | None = None,
                  checkpoint_every: int = 0) -> BatchResult:
        """Execute K parameter bindings (and/or noise trajectories) as
        ONE lane-batched run.

        Every lane shares the partition, the compiled transpose-
        minimizing schedules, and — crucially — every jitted stage
        dispatch, boundary crossing and store barrier: per (stage,
        group) the whole batch costs one call instead of K.  On
        dispatch-bound configs (small blocks, many groups) this beats
        the equivalent sequential sweep outright; see
        ``benchmarks/bench_session.py``.

        Args:
            params_list: one params dict (or None) per lane.
            seeds: per-lane trajectory seeds realizing stochastic Pauli
                channels; defaults to ``range(K)`` for a stochastic
                circuit and no draws otherwise.

        Returns:
            A live :class:`BatchResult` — per-lane :class:`SimResult`
            views plus lane-averaged ``expectation`` — invalidated by
            the next run.  When a memory budget is set and K lanes
            exceed it, the engine warns and executes chunked
            sub-batches (``stats.n_batch_chunks``); results are
            identical.

        Mid-run checkpointing is NOT supported for batched runs — the
        store holds K lane states under one manifest, and a snapshot
        taken mid-batch could not be resumed into any single-lane
        session.  Passing ``checkpoint_path``/``checkpoint_every``
        raises ``ValueError`` up front; checkpoint per-binding ``run()``
        calls instead, or persist finished lanes from the
        :class:`BatchResult`.
        """
        if checkpoint_path is not None or checkpoint_every:
            raise ValueError(
                "run_batch does not support mid-run checkpointing: the "
                "store holds K lane states under one manifest and a "
                "mid-batch snapshot cannot be resumed; checkpoint "
                "per-binding run() calls instead, or persist lanes via "
                "BatchResult readout")
        if self._closed:
            raise RuntimeError("Simulator is closed")
        if self._engine is None:
            raise RuntimeError(
                "readout-only session (resumed without a circuit); pass "
                "circuit= to Simulator.resume to re-run")
        if self._start_stage > 0:
            raise RuntimeError(
                "a partial checkpoint is pending; finish it with run() "
                "before starting a batched run")
        params_list = list(params_list)
        if seeds is None:
            seeds = (list(range(len(params_list)))
                     if self._engine._stochastic
                     else [None] * len(params_list))
        if len(seeds) != len(params_list):
            raise ValueError(
                f"{len(params_list)} lanes but {len(seeds)} seeds")
        bindings = tuple(zip(params_list, seeds))
        # validate BEFORE invalidating the previous (still intact) result
        self._engine._validate_bindings(bindings)
        self._generation += 1
        self._batched = True
        self._engine.run_batch(bindings)
        self._last = BatchResult(self._backend, self.n_qubits,
                                 self.local_bits, len(bindings),
                                 stats=self._engine.stats, owner=self,
                                 generation=self._generation)
        return self._last

    def result(self) -> "SimResult | BatchResult":
        """The latest run's (or resumed checkpoint's) readout handle."""
        if self._last is None:
            raise RuntimeError("no result yet: call run() first")
        return self._last

    # -- checkpointing ---------------------------------------------------------
    def _manifest(self, stages_done: int, run_params: dict | None) -> dict:
        if self._engine is not None:
            cfg = self._engine.cfg
            return {
                "kind": _CKPT_KIND, "version": _CKPT_VERSION,
                "n_qubits": self.n_qubits, "local_bits": self.local_bits,
                "inner_size": cfg.inner_size, "b_r": cfg.b_r,
                "compression": cfg.compression, "prescan": cfg.prescan,
                "stages_done": stages_done,
                "n_stages": self._engine.partition.n_stages,
                "fingerprint": circuit_fingerprint(self._engine.circuit),
                "plan_fingerprint": self._engine.plan_fingerprint(),
                # JSON-native coercion: optimizer loops hand np.float64
                # values, which json.dumps inside store.snapshot rejects
                "run_params": (None if run_params is None else
                               {str(k): float(v)
                                for k, v in run_params.items()}),
            }
        return dict(self._meta)        # readout-only: re-save as loaded

    def _save_checkpoint(self, path: str, stages_done: int | None = None,
                         run_params: dict | None = None) -> None:
        if self._batched:
            raise RuntimeError(
                "checkpointing a batched run is not supported: the store "
                "holds K lane states under one manifest; read the lanes "
                "out (BatchResult) or re-run the binding you want to keep")
        if stages_done is None and self._engine is not None:
            stages_done = self._engine.partition.n_stages
        self._backend.store.snapshot(
            path, meta=self._manifest(stages_done, run_params))

    @classmethod
    def resume(cls, path: str, circuit: Circuit | None = None,
               config: EngineConfig | None = None) -> "Simulator":
        """Reopen a checkpoint written by ``result.save`` / mid-run
        checkpointing.

        Without ``circuit``: a readout-only session over the checkpointed
        (complete) final state — ``sim.result()`` streams it.  With
        ``circuit`` (+ optionally ``config``): a full session whose store
        is the checkpoint; a partial checkpoint continues from the first
        unfinished stage on the next ``run()``, a complete one exposes
        ``result()`` immediately.
        """
        store, meta = BlockStore.restore(
            path,
            ram_budget_bytes=config.ram_budget_bytes if config else None,
            spill_dir=config.spill_dir if config else None)
        if meta.get("kind") != _CKPT_KIND:
            store.close()
            raise ValueError(f"{path}: not a {_CKPT_KIND} file")
        complete = meta["stages_done"] == meta["n_stages"]

        if circuit is None:
            if not complete:
                store.close()
                raise ValueError(
                    f"{path} is a partial checkpoint "
                    f"({meta['stages_done']}/{meta['n_stages']} stages); "
                    "pass the circuit to continue the run")
            sim = cls.__new__(cls)
            sim._engine = None
            sim._backend = make_backend(
                "host", store, PwRelParams(b_r=meta["b_r"]),
                2 ** meta["local_bits"], compression=meta["compression"],
                prescan=meta["prescan"], interpret=default_interpret())
            sim.n_qubits = meta["n_qubits"]
            sim.local_bits = meta["local_bits"]
            sim._meta = meta
            sim._generation = 1
            sim._batched = False
            sim._start_stage = 0
            sim._resume_params = None
            sim._closed = False
            sim._last = SimResult(sim._backend, sim.n_qubits, sim.local_bits,
                                  owner=sim, generation=1)
            return sim

        if circuit_fingerprint(circuit) != meta["fingerprint"]:
            store.close()
            raise ValueError(
                f"{path}: circuit does not match the checkpointed one "
                "(structural fingerprint mismatch)")
        if config is None:
            config = EngineConfig(local_bits=meta["local_bits"],
                                  inner_size=meta["inner_size"],
                                  b_r=meta["b_r"],
                                  compression=meta["compression"],
                                  prescan=meta["prescan"])
        else:
            # auto knobs (None) adopt the checkpointed values; explicit
            # ones must match — the compressed blocks on disk are laid
            # out for exactly one (local_bits, inner_size) plan
            for attr in ("local_bits", "inner_size", "b_r", "compression",
                         "prescan"):
                given = getattr(config, attr)
                if given is None:
                    continue
                if given != meta[attr]:
                    store.close()
                    raise ValueError(
                        f"{path}: config.{attr}={given!r} "
                        f"!= checkpointed {meta[attr]!r}")
            config = replace(config, local_bits=meta["local_bits"],
                             inner_size=meta["inner_size"])
        sim = cls(circuit, config, _store=store)
        if sim._engine.partition.n_stages != meta["n_stages"]:
            sim.close()
            raise ValueError(
                f"{path}: partition produced "
                f"{sim._engine.partition.n_stages} stages but checkpoint "
                f"recorded {meta['n_stages']}")
        ckpt_pf = meta.get("plan_fingerprint")
        if ckpt_pf is not None and sim._engine.plan_fingerprint() != ckpt_pf:
            sim.close()
            raise ValueError(
                f"{path}: incompatible execution plan — the checkpointed "
                "compressed state was laid out by plan "
                f"{ckpt_pf[:12]} but this session compiles "
                f"{sim._engine.plan_fingerprint()[:12]}")
        sim._meta = meta
        if complete:
            sim._generation = 1
            sim._last = SimResult(sim._backend, sim.n_qubits, sim.local_bits,
                                  stats=sim._engine.stats, owner=sim,
                                  generation=1)
        else:
            sim._start_stage = meta["stages_done"]
            sim._resume_params = meta.get("run_params")
        return sim
