"""SV block / SV group index arithmetic (paper §3 + §4.1, Figs. 1/2/4).

Layout (little-endian): a flat 2^n state splits into 2^c SV blocks of 2^b
amplitudes; block id = the high c bits (*global index*), offset inside a
block = the low b bits (*local index*).

For a stage whose inner set is ``inner = [s_0 < ... < s_{m-1}]`` (global
qubits, each >= b), an *SV group* is the set of 2^m blocks sharing the
same *outer* global bits.  A group is processed as one flat array of
2^(b+m) amplitudes in which:

* local qubit  q (< b)       -> virtual bit  q
* inner qubit  s_j           -> virtual bit  b + j

so every gate in the stage acts entirely inside the group — this is the
paper's Insight, and the reason one (de)compression per stage suffices.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

__all__ = ["GroupLayout", "expand_bits"]


def expand_bits(vals: np.ndarray, positions: list[int]) -> np.ndarray:
    """Scatter bit j of each value into bit ``positions[j]`` (vectorized)."""
    vals = np.asarray(vals, dtype=np.int64)
    out = np.zeros_like(vals)
    for j, p in enumerate(positions):
        out |= ((vals >> j) & 1) << p
    return out


@dataclass(frozen=True)
class GroupLayout:
    """Index plumbing for one stage."""

    n_qubits: int
    local_bits: int                 # b
    inner: tuple[int, ...]          # sorted inner global qubits

    @property
    def b(self) -> int:
        return self.local_bits

    @property
    def c(self) -> int:
        return self.n_qubits - self.local_bits

    @property
    def m(self) -> int:
        return len(self.inner)

    @property
    def n_blocks(self) -> int:
        return 1 << self.c

    @property
    def n_groups(self) -> int:
        return 1 << (self.c - self.m)

    @property
    def blocks_per_group(self) -> int:
        return 1 << self.m

    @property
    def group_size(self) -> int:
        """Amplitudes per group = 2^(b+m)."""
        return 1 << (self.b + self.m)

    # -- positions within the c-bit global index ----------------------------
    @property
    def inner_positions(self) -> list[int]:
        return [q - self.b for q in self.inner]

    @property
    def outer_positions(self) -> list[int]:
        inner = set(self.inner_positions)
        return [p for p in range(self.c) if p not in inner]

    # -- block membership ----------------------------------------------------
    def group_block_ids(self) -> np.ndarray:
        """(n_groups, 2^m) array: block id of member i of group g.

        Member order is the inner-assignment order, i.e. member i holds the
        amplitudes whose inner global bits spell the integer i — so simply
        concatenating a group's member blocks yields the flat group array
        with the virtual-bit layout documented above.
        """
        outer_vals = np.arange(self.n_groups, dtype=np.int64)
        inner_vals = np.arange(self.blocks_per_group, dtype=np.int64)
        outer_part = expand_bits(outer_vals, self.outer_positions)  # (G,)
        inner_part = expand_bits(inner_vals, self.inner_positions)  # (M,)
        return outer_part[:, None] | inner_part[None, :]

    # -- gate remapping --------------------------------------------------------
    def virtual_qubit(self, q: int) -> int:
        """Physical qubit -> virtual bit inside the flat group array."""
        if q < self.b:
            return q
        try:
            j = self.inner.index(q)
        except ValueError:
            raise ValueError(
                f"qubit {q} is an outer global index for inner={self.inner}"
            ) from None
        return self.b + j

    def remap_qubits(self, qubits: tuple[int, ...]) -> tuple[int, ...]:
        return tuple(self.virtual_qubit(q) for q in qubits)
