"""Stage pipeline: load/decode → compute → encode/store (paper §4.1/§4.2).

One stage of the partitioned simulation processes every SV group through
three phases:

    1. load/decode   — fetch the group's 2^m blocks from the two-level
                       store and produce the flat 2^(b+m) device array
    2. compute       — apply the stage's fused unitaries on-device
    3. encode/store  — compress the updated blocks back into the store

:class:`StagePipeline` owns the phase orchestration — host phases run in
worker thread pools (zlib/numpy release the GIL), device phases dispatch
asynchronously so decode-of-group-g+1 overlaps compute-of-group-g (§4.2's
transfer-concealed workflow) — while a :class:`CodecBackend` decides *where
the codec runs*:

``host``   (:class:`HostCodecBackend`)   — the correctness baseline: blocks
    are fully decompressed on the host and the **raw** 2^(b+m) complex64
    group array crosses the host↔device boundary (8 bytes/amplitude each
    way).

``device`` (:class:`DeviceCodecBackend`) — the paper's design: only the
    **compressed wire representation** (packed uint16 codes + ballot sign
    words + ``l_max`` scalars, ~4.25 bytes/amplitude) crosses the boundary;
    the Pallas kernels quantize/dequantize next to the compute, and the
    host keeps only the lossless zlib/prescan stage and the store.

Both backends read and write the same stored :class:`BlockSegments`
format, so they are interchangeable mid-simulation and verifiable against
each other (tests/test_pipeline.py).
"""
from __future__ import annotations

import threading
import time
import warnings
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

from ..compression.codec import decode_block_host, encode_block_host
from ..compression.device_codec import (decode_blocks_planes,
                                        encode_group_planes,
                                        fetch_group_wire, segments_to_wire,
                                        wire_to_segments)
from ..compression.pwrel import PwRelParams
from ..compression.store import BlockStore

__all__ = ["CodecBackend", "HostCodecBackend", "DeviceCodecBackend",
           "StagePipeline", "make_backend",
           "complex_to_planes", "planes_to_complex"]


def complex_to_planes(amps: jax.Array) -> jax.Array:
    """(n,) complex64 -> (2, n) f32 re/im plane stack (traceable)."""
    return jnp.stack([jnp.real(amps), jnp.imag(amps)]).astype(jnp.float32)


def planes_to_complex(planes: jax.Array) -> jax.Array:
    """(2, n) f32 plane stack -> (n,) complex64 (traceable)."""
    return (planes[0] + 1j * planes[1]).astype(jnp.complex64)


_complex_to_planes = jax.jit(complex_to_planes)
_planes_to_complex = jax.jit(planes_to_complex)


def _complex_to_planes_batch(amps: jax.Array) -> jax.Array:
    """(L, n) complex64 -> (L, 2, n) f32 lane-batched plane stacks."""
    return jnp.stack([jnp.real(amps), jnp.imag(amps)],
                     axis=1).astype(jnp.float32)


def _planes_to_complex_batch(planes: jax.Array) -> jax.Array:
    """(L, 2, n) f32 lane-batched plane stacks -> (L, n) complex64."""
    return (planes[:, 0] + 1j * planes[:, 1]).astype(jnp.complex64)


_complex_to_planes_b = jax.jit(_complex_to_planes_batch)
_planes_to_complex_b = jax.jit(_planes_to_complex_batch)


class CodecBackend:
    """Where the block codec runs, as four phase hooks.

    ``fetch_group`` / ``store_group`` are the *host* halves (called from
    worker threads; GIL-friendly numpy/zlib only — they never touch JAX).
    ``stage_to_device`` / ``fetch_result`` are the *device* halves (called
    from the dispatch thread); ``stage_to_device`` only dispatches — it
    never blocks — so the pipeline can overlap it with compute.

    Byte counters ``h2d_bytes`` / ``d2h_bytes`` accumulate the size of
    every array that crosses the host↔device boundary — the quantity the
    device-resident codec exists to shrink.

    Args:
        store: the two-level block store.
        params: pwrel bound shared by both codec halves.
        bsz: amplitudes per SV block (2^b, engine-constant).
        compression: False = raw complex64 blocks (Fig. 11 baseline).
        prescan: bitmap pre-scan RLE in the lossless stage (§4.3).
    """

    name: str = "abstract"

    def __init__(self, store: BlockStore, params: PwRelParams, bsz: int,
                 compression: bool = True, prescan: bool = True):
        self.store = store
        self.params = params
        self.bsz = bsz
        self.compression = compression
        self.prescan = prescan
        self.h2d_bytes = 0
        self.d2h_bytes = 0
        self.n_decompressions = 0
        self.n_compressions = 0
        # host-phase hooks run in concurrent worker threads; counter
        # updates are read-modify-write and need the lock
        self._count_lock = threading.Lock()

    def add_counts(self, decompressions: int = 0,
                   compressions: int = 0) -> None:
        with self._count_lock:
            self.n_decompressions += decompressions
            self.n_compressions += compressions

    # -- host block codec (also used for init/collect outside the pipeline) --
    def encode_host_block(self, key: int, amps: np.ndarray) -> None:
        """Compress one np block on the host and store it under ``key``."""
        if not self.compression:
            self.store.put(key, np.asarray(amps, np.complex64).tobytes())
        else:
            self.store.put_block(
                key, encode_block_host(amps, self.params,
                                       prescan=self.prescan))

    def decode_host_block(self, key: int) -> np.ndarray:
        """Fetch the block under ``key`` and decompress it on the host."""
        if not self.compression:
            return np.frombuffer(self.store.get(key), dtype=np.complex64)
        return decode_block_host(self.store.get_block(key), self.params)

    # -- phase hooks ---------------------------------------------------------
    def fetch_group(self, block_ids: np.ndarray):
        """Worker thread: store -> host staging object for one group."""
        raise NotImplementedError

    def stage_to_device(self, staged, device) -> jax.Array:
        """Dispatch thread: host staging -> (2, 2^(b+m)) f32 device plane
        stack (async) — the stage compute's planes-resident input."""
        raise NotImplementedError

    def fetch_result(self, planes_dev: jax.Array, n_blocks: int):
        """Dispatch thread: device plane stack -> host result object
        (blocks).  This is the pipeline's blocking boundary wait."""
        raise NotImplementedError

    def store_group(self, block_ids: np.ndarray, result) -> None:
        """Worker thread: host result object -> store."""
        raise NotImplementedError

    # -- lane-batched phase hooks (Simulator.run_batch) ----------------------
    #
    # ``key_rows`` is the (L, 2^m) per-lane store-key table of ONE group —
    # row l holds lane l's keys (lane_offset + block id).  The generic
    # implementations loop the single-lane hooks; backends override where
    # one stacked transfer / one kernel dispatch can cover the batch.

    def fetch_group_batch(self, key_rows: np.ndarray):
        """Worker thread: store -> host staging for all lanes of a group."""
        return [self.fetch_group(row) for row in key_rows]

    def stage_to_device_batch(self, staged, device) -> jax.Array:
        """Dispatch thread: host staging -> (L, 2, 2^(b+m)) f32 plane
        stacks (async) — the batched stage compute's input."""
        return jnp.stack([self.stage_to_device(s, device) for s in staged])

    def fetch_result_batch(self, planes_dev: jax.Array, n_blocks: int):
        """Dispatch thread: (L, 2, N) device planes -> per-lane host
        result objects (the pipeline's blocking boundary wait)."""
        return [self.fetch_result(planes_dev[lane], n_blocks)
                for lane in range(planes_dev.shape[0])]

    def store_group_batch(self, key_rows: np.ndarray, results) -> None:
        """Worker thread: per-lane host results -> store."""
        for row, res in zip(key_rows, results):
            self.store_group(row, res)


class HostCodecBackend(CodecBackend):
    """Baseline: the full codec runs on the host (seed engine behavior).

    Raw 2^(b+m) complex64 group arrays cross the host↔device boundary in
    both directions.  Also the only backend usable with
    ``compression=False``.
    """

    name = "host"

    def fetch_group(self, block_ids):
        # decode straight into one preallocated flat group array — no
        # per-group np.concatenate copy
        flat = np.empty(len(block_ids) * self.bsz, dtype=np.complex64)
        for i, bid in enumerate(block_ids):
            flat[i * self.bsz:(i + 1) * self.bsz] = \
                self.decode_host_block(int(bid))
        self.add_counts(decompressions=len(block_ids))
        return flat

    def stage_to_device(self, staged, device):
        self.h2d_bytes += staged.nbytes
        return _complex_to_planes(jax.device_put(jnp.asarray(staged), device))

    def fetch_result(self, planes_dev, n_blocks):
        # complex64 is re-materialized on device, then fetched raw
        out = np.asarray(_planes_to_complex(planes_dev))  # blocking wait
        self.d2h_bytes += out.nbytes
        return out

    def store_group(self, block_ids, result):
        blocks = np.asarray(result).reshape(len(block_ids), self.bsz)
        for i, bid in enumerate(block_ids):
            self.encode_host_block(int(bid), blocks[i])
        self.add_counts(compressions=len(block_ids))

    # -- lane-batched overrides: one stacked boundary crossing per group --
    def fetch_group_batch(self, key_rows):
        lanes, n_blocks = key_rows.shape
        flat = np.empty((lanes, n_blocks * self.bsz), dtype=np.complex64)
        for lane, row in enumerate(key_rows):
            for i, bid in enumerate(row):
                flat[lane, i * self.bsz:(i + 1) * self.bsz] = \
                    self.decode_host_block(int(bid))
        self.add_counts(decompressions=key_rows.size)
        return flat

    def stage_to_device_batch(self, staged, device):
        self.h2d_bytes += staged.nbytes
        return _complex_to_planes_b(jax.device_put(jnp.asarray(staged),
                                                   device))

    def fetch_result_batch(self, planes_dev, n_blocks):
        out = np.asarray(_planes_to_complex_b(planes_dev))  # blocking wait
        self.d2h_bytes += out.nbytes
        return out                     # (L, 2^(b+m)) complex64

    # store_group_batch: the base per-lane loop is already right — the
    # host encode is per-block CPU work with nothing to batch


class DeviceCodecBackend(CodecBackend):
    """Device-resident lossy codec: compressed wire crosses the boundary.

    Requires ``compression=True`` (the raw-block toggle has no device
    half — use :func:`make_backend`, which falls back to the host backend).
    RAW-escape blocks (incompressible data) degrade gracefully to a raw
    transfer for that block only.
    """

    name = "device"

    def __init__(self, store, params, bsz, compression=True, prescan=True,
                 *, interpret: bool = True):
        assert compression, "device codec backend requires compression=True"
        super().__init__(store, params, bsz, compression, prescan)
        self.interpret = interpret

    def fetch_group(self, block_ids):
        staged = []
        for bid in block_ids:
            seg = self.store.get_block(int(bid))
            if seg.is_raw:
                staged.append(("raw", np.frombuffer(
                    seg.raw, dtype=np.complex64, count=seg.n_amps)))
            else:
                staged.append(("wire", segments_to_wire(seg)))
        self.add_counts(decompressions=len(staged))
        return staged

    def stage_to_device(self, staged, device):
        parts: list = [None] * len(staged)        # per block: (2, bsz) f32
        wire_idx = []
        for i, (kind, payload) in enumerate(staged):
            if kind == "raw":
                self.h2d_bytes += payload.nbytes
                parts[i] = _complex_to_planes(
                    jax.device_put(jnp.asarray(payload), device))
            else:
                wire_idx.append(i)
        if wire_idx:
            # batched: 3 transfers + 1 decode dispatch for the whole group;
            # the decode lands directly on f32 planes — no complex detour
            blocks, moved = decode_blocks_planes(
                [staged[i][1] for i in wire_idx], self.bsz, self.params,
                device, interpret=self.interpret)
            self.h2d_bytes += moved
            for j, i in enumerate(wire_idx):
                parts[i] = blocks[j]
        return (jnp.concatenate(parts, axis=1) if len(parts) > 1
                else parts[0])

    def fetch_result(self, planes_dev, n_blocks):
        encoded = encode_group_planes(planes_dev, n_blocks, self.params,
                                      interpret=self.interpret)
        wire, moved = fetch_group_wire(encoded)   # blocks until done
        self.d2h_bytes += moved
        return wire

    def store_group(self, block_ids, result):
        for pair, bid in zip(result, block_ids):
            self.store.put_block(
                int(bid), wire_to_segments(pair, self.bsz,
                                           prescan=self.prescan,
                                           params=self.params))
        self.add_counts(compressions=len(block_ids))

    # -- lane-batched overrides: every lane's wire shares one codec
    # dispatch (the per-call decode/encode launch is the dominant cost on
    # a dispatch-bound config, so K lanes must not pay it K times) -------
    def stage_to_device_batch(self, staged, device):
        parts = [[None] * len(row) for row in staged]
        wire, where = [], []
        for lane, row in enumerate(staged):
            for i, (kind, payload) in enumerate(row):
                if kind == "raw":
                    self.h2d_bytes += payload.nbytes
                    parts[lane][i] = _complex_to_planes(
                        jax.device_put(jnp.asarray(payload), device))
                else:
                    wire.append(payload)
                    where.append((lane, i))
        if wire:
            blocks, moved = decode_blocks_planes(
                wire, self.bsz, self.params, device,
                interpret=self.interpret)
            self.h2d_bytes += moved
            for j, (lane, i) in enumerate(where):
                parts[lane][i] = blocks[j]
        return jnp.stack([
            jnp.concatenate(row, axis=1) if len(row) > 1 else row[0]
            for row in parts])

    def fetch_result_batch(self, planes_dev, n_blocks):
        lanes = planes_dev.shape[0]
        # lane-major block order: (L, 2, N) -> (2, L*N), so one encode
        # dispatch covers every lane's blocks and the wire list splits
        # back per lane below
        flat = jnp.transpose(planes_dev, (1, 0, 2)).reshape(2, -1)
        encoded = encode_group_planes(flat, lanes * n_blocks, self.params,
                                      interpret=self.interpret)
        wire, moved = fetch_group_wire(encoded)   # blocks until done
        self.d2h_bytes += moved
        return [wire[lane * n_blocks:(lane + 1) * n_blocks]
                for lane in range(lanes)]


def make_backend(name: str, store: BlockStore, params: PwRelParams,
                 bsz: int, compression: bool = True, prescan: bool = True,
                 *, interpret: bool = True) -> CodecBackend:
    """Resolve an ``EngineConfig.codec_backend`` name to a backend.

    ``"device"`` degrades to ``"host"`` (with a ``RuntimeWarning``) when
    ``compression`` is off — there is no device half to a raw byte copy.
    """
    if name == "device" and compression:
        return DeviceCodecBackend(store, params, bsz, compression, prescan,
                                  interpret=interpret)
    if name == "device":
        warnings.warn(
            "codec_backend='device' requires compression=True; "
            "falling back to the host codec backend",
            RuntimeWarning, stacklevel=2)
    if name in ("host", "device"):
        return HostCodecBackend(store, params, bsz, compression, prescan)
    raise ValueError(f"unknown codec backend {name!r} "
                     "(expected 'host' or 'device')")


class StagePipeline:
    """Orchestrates the per-group load → compute → store loop of a stage.

    ``depth`` groups are fetched ahead in the decode pool while compressed
    writes drain through the store pool (§4.2's pipeline).  On the device
    side, the decode of the next group is dispatched *before* the current
    group's result is fetched, so it overlaps compute under JAX's async
    dispatch.

    Use as a context manager (owns the worker pools); call
    :meth:`run_stage` once per partition stage, then read the counters off
    ``backend`` and the ``t_*`` attributes.
    """

    def __init__(self, backend: CodecBackend, depth: int = 2,
                 devices: list | None = None):
        self.backend = backend
        self.depth = max(1, depth)
        self.devices = devices or [jax.devices()[0]]
        self.t_load = 0.0
        self.t_compute = 0.0     # h2d staging + kernel dispatch (non-blocking)
        self.t_fetch = 0.0       # blocking result wait at the d2h boundary
        self.t_store = 0.0
        self._t_lock = threading.Lock()  # _load/_store run concurrently
        self._dec_pool: ThreadPoolExecutor | None = None
        self._com_pool: ThreadPoolExecutor | None = None

    def __enter__(self) -> "StagePipeline":
        self._dec_pool = ThreadPoolExecutor(max_workers=self.depth)
        self._com_pool = ThreadPoolExecutor(max_workers=self.depth)
        return self

    def __exit__(self, *exc) -> None:
        self._dec_pool.shutdown(wait=True)
        self._com_pool.shutdown(wait=True)
        self._dec_pool = self._com_pool = None

    # -- timed phase wrappers (run inside worker threads) ---------------------
    def _load(self, fetch, keys):
        t0 = time.perf_counter()
        staged = fetch(keys)
        dt = time.perf_counter() - t0
        with self._t_lock:
            self.t_load += dt
        return staged

    def _store(self, store, keys, result):
        t0 = time.perf_counter()
        store(keys, result)
        dt = time.perf_counter() - t0
        with self._t_lock:
            self.t_store += dt

    def _device_for(self, g: int):
        return self.devices[g % len(self.devices)]

    def run_stage(self, block_ids: np.ndarray, fn, mats,
                  lane_offsets: np.ndarray | None = None) -> None:
        """Run one stage: ``block_ids`` is the (n_groups, 2^m) layout table,
        ``fn`` the jitted group-update function, ``mats`` its operands.

        ``lane_offsets`` switches on the batched path: per group, the
        (L, 2^m) key table ``lane_offsets[:, None] + block_ids[g]`` flows
        through the backend's ``*_batch`` hooks and ``fn`` updates the
        (L, 2, 2^(b+m)) lane stack in one dispatch.
        """
        assert self._dec_pool is not None, "use StagePipeline as a context manager"
        back = self.backend
        n_groups, n_blocks = block_ids.shape
        if lane_offsets is None:
            fetch, to_dev = back.fetch_group, back.stage_to_device
            fetch_res, store = back.fetch_result, back.store_group
            group_keys = [block_ids[g] for g in range(n_groups)]
        else:
            fetch, to_dev = back.fetch_group_batch, back.stage_to_device_batch
            fetch_res, store = back.fetch_result_batch, back.store_group_batch
            group_keys = [lane_offsets[:, None] + block_ids[g][None, :]
                          for g in range(n_groups)]
        pending_load = {
            g: self._dec_pool.submit(self._load, fetch, group_keys[g])
            for g in range(min(self.depth, n_groups))
        }
        staged_dev: dict[int, jax.Array] = {}
        pending_save = []
        for g in range(n_groups):
            amps_dev = staged_dev.pop(g, None)
            if amps_dev is None:
                staged = pending_load.pop(g).result()
                t0 = time.perf_counter()
                amps_dev = to_dev(staged, self._device_for(g))
                self.t_compute += time.perf_counter() - t0
            nxt = g + self.depth
            if nxt < n_groups:
                pending_load[nxt] = self._dec_pool.submit(
                    self._load, fetch, group_keys[nxt])
            t0 = time.perf_counter()
            out = fn(amps_dev, *mats)                  # async dispatch
            # overlap: dispatch the next group's decode behind the compute
            nxt = g + 1
            if nxt in pending_load and pending_load[nxt].done():
                staged_dev[nxt] = to_dev(pending_load.pop(nxt).result(),
                                         self._device_for(nxt))
            self.t_compute += time.perf_counter() - t0
            t0 = time.perf_counter()
            result = fetch_res(out, n_blocks)
            self.t_fetch += time.perf_counter() - t0
            pending_save.append(
                self._com_pool.submit(self._store, store, group_keys[g],
                                      result))
        for fut in pending_save:               # stage barrier (§4.1 semantics)
            fut.result()
