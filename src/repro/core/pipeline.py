"""Stage pipeline: load/decode → compute → encode/store (paper §4.1/§4.2).

One stage of the partitioned simulation processes every SV group through
three phases:

    1. load/decode   — fetch the group's 2^m blocks from the two-level
                       store and produce the flat 2^(b+m) device array
    2. compute       — apply the stage's fused unitaries on-device
    3. encode/store  — compress the updated blocks back into the store

:class:`StagePipeline` owns the phase orchestration; a
:class:`CodecBackend` decides *where the codec runs*:

``host``   (:class:`HostCodecBackend`)   — the correctness baseline: blocks
    are fully decompressed on the host and the **raw** 2^(b+m) complex64
    group array crosses the host↔device boundary (8 bytes/amplitude each
    way).

``device`` (:class:`DeviceCodecBackend`) — the paper's design: only the
    **compressed wire representation** (packed uint16 codes + ballot sign
    words + ``l_max`` scalars, ~4.25 bytes/amplitude) crosses the boundary;
    the Pallas kernels quantize/dequantize next to the compute, and the
    host keeps only the lossless zlib/prescan stage and the store.

The pipeline (§4.2's transfer-concealed workflow) is **wave-coalesced and
double-buffered**:

* ``pipeline_depth`` is the *wave width*: ``depth`` consecutive groups are
  coalesced into one wave that flows through the backend's ``*_batch``
  hooks — ONE stacked boundary crossing and ONE jitted dispatch per phase
  cover the whole wave, amortizing the per-call dispatch overhead that
  dominates the small-block configs (the same mechanism that makes
  ``run_batch`` beat K sequential runs).
* the blocking device→host wait sits in a bounded **in-flight window**:
  wave *w*'s result is only awaited after wave *w+1*'s compute and encode
  have been dispatched, so the await overlaps the next wave's device work
  under JAX's async dispatch.
* the host codec halves run on small worker pools behind a completion
  **ready-queue**: fetches are submitted ahead (bounded lookahead) and the
  compute loop consumes them in *completion* order, so one slow decode
  never serializes the loop; compressed writes drain through the store
  pool and are barriered per stage.

``depth=1`` degenerates to a strictly sequential
fetch→stage→compute→await→store loop on the caller's thread (no pools, no
lookahead) — the reference schedule the overlap tests compare against.
On a **single-core host** depth>1 keeps the wave coalescing (the
dispatch-amortization win needs no threads) but also runs sequentially:
worker pools whose context switches and GIL handoffs cost more than the
overlap they hide are never created unless ``fetch_workers`` explicitly
asks for them.

Both backends read and write the same stored :class:`BlockSegments`
format, so they are interchangeable mid-simulation and verifiable against
each other (tests/test_pipeline.py).
"""
from __future__ import annotations

import os
import queue
import threading
import time
import warnings
from collections import deque
from concurrent.futures import ThreadPoolExecutor

import jax
import jax.numpy as jnp
import numpy as np

from ..compression.codec import decode_block_host, encode_block_host
from ..compression.device_codec import (decode_blocks_planes,
                                        encode_group_planes,
                                        fetch_group_wire, segments_to_wire,
                                        wire_to_segments)
from ..compression.pwrel import PwRelParams
from ..compression.store import BlockStore
from ..errors import BlockCorruptionError, StoreIOError
from .faults import fault_point

__all__ = ["CodecBackend", "HostCodecBackend", "DeviceCodecBackend",
           "StagePipeline", "make_backend",
           "complex_to_planes", "planes_to_complex"]


def complex_to_planes(amps: jax.Array) -> jax.Array:
    """(n,) complex64 -> (2, n) f32 re/im plane stack (traceable)."""
    return jnp.stack([jnp.real(amps), jnp.imag(amps)]).astype(jnp.float32)


def planes_to_complex(planes: jax.Array) -> jax.Array:
    """(2, n) f32 plane stack -> (n,) complex64 (traceable)."""
    return (planes[0] + 1j * planes[1]).astype(jnp.complex64)


_complex_to_planes = jax.jit(complex_to_planes)
_planes_to_complex = jax.jit(planes_to_complex)


def _complex_to_planes_batch(amps: jax.Array) -> jax.Array:
    """(L, n) complex64 -> (L, 2, n) f32 lane-batched plane stacks."""
    return jnp.stack([jnp.real(amps), jnp.imag(amps)],
                     axis=1).astype(jnp.float32)


def _planes_to_complex_batch(planes: jax.Array) -> jax.Array:
    """(L, 2, n) f32 lane-batched plane stacks -> (L, n) complex64."""
    return (planes[:, 0] + 1j * planes[:, 1]).astype(jnp.complex64)


_complex_to_planes_b = jax.jit(_complex_to_planes_batch)
_planes_to_complex_b = jax.jit(_planes_to_complex_batch)


class CodecBackend:
    """Where the block codec runs, as five phase hooks.

    ``fetch_group`` / ``store_group`` are the *host* halves (called from
    worker threads; GIL-friendly numpy/zlib only — they never touch JAX).
    The *device* halves run on the dispatch thread and are split at the
    blocking boundary:

    * ``stage_to_device``   — host staging -> device planes; dispatch only,
      never blocks.
    * ``dispatch_result``   — device planes -> an opaque in-flight *ticket*
      (the encode / plane→complex conversion is dispatched async here);
      never blocks.
    * ``await_result``      — ticket -> host result object; this is the
      ONLY blocking device wait in the pipeline, so the scheduler can park
      it in the in-flight window while later waves dispatch.

    ``fetch_result`` (dispatch + await back to back) remains as the
    convenience form for sequential callers.

    Byte counters ``h2d_bytes`` / ``d2h_bytes`` accumulate the size of
    every array that crosses the host↔device boundary — the quantity the
    device-resident codec exists to shrink.  Phase hooks run concurrently
    on worker threads, so ALL counter updates are read-modify-write under
    ``_count_lock`` — use :meth:`add_bytes` / :meth:`add_counts`, never a
    bare ``+=``.

    Args:
        store: the two-level block store.
        params: pwrel bound shared by both codec halves.
        bsz: amplitudes per SV block (2^b, engine-constant).
        compression: False = raw complex64 blocks (Fig. 11 baseline).
        prescan: bitmap pre-scan RLE in the lossless stage (§4.3).
    """

    name: str = "abstract"

    def __init__(self, store: BlockStore, params: PwRelParams, bsz: int,
                 compression: bool = True, prescan: bool = True):
        self.store = store
        self.params = params
        self.bsz = bsz
        self.compression = compression
        self.prescan = prescan
        # phase hooks run in concurrent worker threads; counter updates
        # are read-modify-write, so the fields below may only be touched
        # inside 'with self._count_lock:' (lock-discipline checker) —
        # mutate through add_counts / add_bytes
        self.h2d_bytes = 0                     # guarded-by: _count_lock
        self.d2h_bytes = 0                     # guarded-by: _count_lock
        self.n_decompressions = 0              # guarded-by: _count_lock
        self.n_compressions = 0                # guarded-by: _count_lock
        self._count_lock = threading.Lock()

    def add_counts(self, decompressions: int = 0,
                   compressions: int = 0) -> None:
        with self._count_lock:
            self.n_decompressions += decompressions
            self.n_compressions += compressions

    def add_bytes(self, h2d: int = 0, d2h: int = 0) -> None:
        """Locked accumulation of the boundary byte ledger (hooks may run
        on several threads at once — a bare ``+=`` here loses updates)."""
        with self._count_lock:
            self.h2d_bytes += h2d
            self.d2h_bytes += d2h

    # -- host block codec (also used for init/collect outside the pipeline) --
    def encode_host_block(self, key: int, amps: np.ndarray) -> None:
        """Compress one np block on the host and store it under ``key``."""
        fault_point("codec.encode")
        if not self.compression:
            self.store.put(key, np.asarray(amps, np.complex64).tobytes())
        else:
            self.store.put_block(
                key, encode_block_host(amps, self.params,
                                       prescan=self.prescan))

    def decode_host_block(self, key: int) -> np.ndarray:
        """Fetch the block under ``key`` and decompress it on the host."""
        fault_point("codec.decode")
        if not self.compression:
            return np.frombuffer(self.store.get(key), dtype=np.complex64)
        return decode_block_host(self.store.get_block(key), self.params)

    # -- phase hooks ---------------------------------------------------------
    def fetch_group(self, block_ids: np.ndarray):
        """Worker thread: store -> host staging object for one group."""
        raise NotImplementedError

    def stage_to_device(self, staged, device) -> jax.Array:
        """Dispatch thread: host staging -> (2, 2^(b+m)) f32 device plane
        stack (async) — the stage compute's planes-resident input."""
        raise NotImplementedError

    def dispatch_result(self, planes_dev: jax.Array, n_blocks: int):
        """Dispatch thread: device plane stack -> in-flight ticket.  The
        device half of the encode is dispatched here (async); MUST NOT
        block."""
        raise NotImplementedError

    def await_result(self, ticket):
        """Dispatch thread: in-flight ticket -> host result object
        (blocks).  The pipeline's only blocking boundary wait."""
        raise NotImplementedError

    def fetch_result(self, planes_dev: jax.Array, n_blocks: int):
        """Dispatch + await back to back (sequential convenience form)."""
        return self.await_result(self.dispatch_result(planes_dev, n_blocks))

    def store_group(self, block_ids: np.ndarray, result) -> None:
        """Worker thread: host result object -> store."""
        raise NotImplementedError

    # -- row-batched phase hooks ---------------------------------------------
    #
    # ``key_rows`` is an (R, 2^m) store-key table: one row of block keys
    # per independent group instance.  The rows are *row-agnostic* — a
    # ``run_batch`` feeds L lanes of one group, the wave scheduler feeds
    # ``depth`` consecutive groups (or their lanes-x-groups product); the
    # hooks only see rows.  The generic implementations loop the
    # single-row hooks; backends override where one stacked transfer /
    # one kernel dispatch can cover the batch.

    def fetch_group_batch(self, key_rows: np.ndarray):
        """Worker thread: store -> host staging for all rows."""
        return [self.fetch_group(row) for row in key_rows]

    def stage_to_device_batch(self, staged, device) -> jax.Array:
        """Dispatch thread: host staging -> (R, 2, 2^(b+m)) f32 plane
        stacks (async) — the batched stage compute's input."""
        return jnp.stack([self.stage_to_device(s, device) for s in staged])

    def dispatch_result_batch(self, planes_dev: jax.Array, n_blocks: int):
        """Dispatch thread: (R, 2, N) device planes -> in-flight ticket
        (async encode dispatch; MUST NOT block)."""
        return [self.dispatch_result(planes_dev[r], n_blocks)
                for r in range(planes_dev.shape[0])]

    def await_result_batch(self, ticket):
        """Dispatch thread: ticket -> per-row host result objects (the
        pipeline's blocking boundary wait)."""
        return [self.await_result(t) for t in ticket]

    def fetch_result_batch(self, planes_dev: jax.Array, n_blocks: int):
        """Dispatch + await back to back for a row batch."""
        return self.await_result_batch(
            self.dispatch_result_batch(planes_dev, n_blocks))

    def store_group_batch(self, key_rows: np.ndarray, results) -> None:
        """Worker thread: per-row host results -> store."""
        for row, res in zip(key_rows, results):
            self.store_group(row, res)


class HostCodecBackend(CodecBackend):
    """Baseline: the full codec runs on the host (seed engine behavior).

    Raw 2^(b+m) complex64 group arrays cross the host↔device boundary in
    both directions.  Also the only backend usable with
    ``compression=False``.
    """

    name = "host"

    def fetch_group(self, block_ids):
        # decode straight into one preallocated flat group array — no
        # per-group np.concatenate copy
        flat = np.empty(len(block_ids) * self.bsz, dtype=np.complex64)
        for i, bid in enumerate(block_ids):
            flat[i * self.bsz:(i + 1) * self.bsz] = \
                self.decode_host_block(int(bid))
        self.add_counts(decompressions=len(block_ids))
        return flat

    def stage_to_device(self, staged, device):
        self.add_bytes(h2d=staged.nbytes)
        return _complex_to_planes(jax.device_put(jnp.asarray(staged), device))

    def dispatch_result(self, planes_dev, n_blocks):
        # complex64 is re-materialized on device (async dispatch); the
        # raw fetch blocks in await_result
        return _planes_to_complex(planes_dev)

    def await_result(self, ticket):
        out = np.asarray(ticket)                  # blocking wait
        self.add_bytes(d2h=out.nbytes)
        return out

    def store_group(self, block_ids, result):
        blocks = np.asarray(result).reshape(len(block_ids), self.bsz)
        for i, bid in enumerate(block_ids):
            self.encode_host_block(int(bid), blocks[i])
        self.add_counts(compressions=len(block_ids))

    # -- row-batched overrides: one stacked boundary crossing per wave --
    def fetch_group_batch(self, key_rows):
        rows, n_blocks = key_rows.shape
        flat = np.empty((rows, n_blocks * self.bsz), dtype=np.complex64)
        for r, row in enumerate(key_rows):
            for i, bid in enumerate(row):
                flat[r, i * self.bsz:(i + 1) * self.bsz] = \
                    self.decode_host_block(int(bid))
        self.add_counts(decompressions=key_rows.size)
        return flat

    def stage_to_device_batch(self, staged, device):
        self.add_bytes(h2d=staged.nbytes)
        return _complex_to_planes_b(jax.device_put(jnp.asarray(staged),
                                                   device))

    def dispatch_result_batch(self, planes_dev, n_blocks):
        return _planes_to_complex_b(planes_dev)   # async dispatch

    def await_result_batch(self, ticket):
        out = np.asarray(ticket)                  # blocking wait
        self.add_bytes(d2h=out.nbytes)
        return out                     # (R, 2^(b+m)) complex64

    # store_group_batch: the base per-row loop is already right — the
    # host encode is per-block CPU work with nothing to batch


class DeviceCodecBackend(CodecBackend):
    """Device-resident lossy codec: compressed wire crosses the boundary.

    Requires ``compression=True`` (the raw-block toggle has no device
    half — use :func:`make_backend`, which falls back to the host backend).
    RAW-escape blocks (incompressible data) degrade gracefully to a raw
    transfer for that block only.
    """

    name = "device"

    def __init__(self, store, params, bsz, compression=True, prescan=True,
                 *, interpret: bool = True):
        assert compression, "device codec backend requires compression=True"
        super().__init__(store, params, bsz, compression, prescan)
        self.interpret = interpret

    def fetch_group(self, block_ids):
        staged = []
        for bid in block_ids:
            fault_point("codec.decode")
            seg = self.store.get_block(int(bid))
            if seg.is_raw:
                staged.append(("raw", np.frombuffer(
                    seg.raw, dtype=np.complex64, count=seg.n_amps)))
            else:
                staged.append(("wire", segments_to_wire(seg)))
        self.add_counts(decompressions=len(staged))
        return staged

    # the wire staged here was fetched through fetch_group, whose
    # per-block fault_point covers the path
    # fault-covered: codec.decode
    def stage_to_device(self, staged, device):
        parts: list = [None] * len(staged)        # per block: (2, bsz) f32
        wire_idx = []
        for i, (kind, payload) in enumerate(staged):
            if kind == "raw":
                self.add_bytes(h2d=payload.nbytes)
                parts[i] = _complex_to_planes(
                    jax.device_put(jnp.asarray(payload), device))
            else:
                wire_idx.append(i)
        if wire_idx:
            # batched: 3 transfers + 1 decode dispatch for the whole group;
            # the decode lands directly on f32 planes — no complex detour
            blocks, moved = decode_blocks_planes(
                [staged[i][1] for i in wire_idx], self.bsz, self.params,
                device, interpret=self.interpret)
            self.add_bytes(h2d=moved)
            for j, i in enumerate(wire_idx):
                parts[i] = blocks[j]
        return (jnp.concatenate(parts, axis=1) if len(parts) > 1
                else parts[0])

    # store_group fires the per-block fault_point on the same encoded
    # wire before it persists
    # fault-covered: codec.encode
    def dispatch_result(self, planes_dev, n_blocks):
        # the quantize/pack kernels launch here (async); only the wire
        # fetch in await_result blocks
        return encode_group_planes(planes_dev, n_blocks, self.params,
                                   interpret=self.interpret)

    def await_result(self, ticket):  # fault-covered: codec.encode
        wire, moved = fetch_group_wire(ticket)    # blocks until done
        self.add_bytes(d2h=moved)
        return wire

    def store_group(self, block_ids, result):
        for pair, bid in zip(result, block_ids):
            fault_point("codec.encode")
            self.store.put_block(
                int(bid), wire_to_segments(pair, self.bsz,
                                           prescan=self.prescan,
                                           params=self.params))
        self.add_counts(compressions=len(block_ids))

    # -- row-batched overrides: every row's wire shares one codec
    # dispatch (the per-call decode/encode launch is the dominant cost on
    # a dispatch-bound config, so R rows must not pay it R times) --------
    # fault-covered: codec.decode — batched sibling of stage_to_device
    def stage_to_device_batch(self, staged, device):
        parts = [[None] * len(row) for row in staged]
        wire, where = [], []
        for r, row in enumerate(staged):
            for i, (kind, payload) in enumerate(row):
                if kind == "raw":
                    self.add_bytes(h2d=payload.nbytes)
                    parts[r][i] = _complex_to_planes(
                        jax.device_put(jnp.asarray(payload), device))
                else:
                    wire.append(payload)
                    where.append((r, i))
        if wire:
            blocks, moved = decode_blocks_planes(
                wire, self.bsz, self.params, device,
                interpret=self.interpret)
            self.add_bytes(h2d=moved)
            for j, (r, i) in enumerate(where):
                parts[r][i] = blocks[j]
        return jnp.stack([
            jnp.concatenate(row, axis=1) if len(row) > 1 else row[0]
            for row in parts])

    # fault-covered: codec.encode — batched sibling of dispatch_result
    def dispatch_result_batch(self, planes_dev, n_blocks):
        rows = planes_dev.shape[0]
        # row-major block order: (R, 2, N) -> (2, R*N), so one encode
        # dispatch covers every row's blocks and the wire list splits
        # back per row in await_result_batch
        flat = jnp.transpose(planes_dev, (1, 0, 2)).reshape(2, -1)
        encoded = encode_group_planes(flat, rows * n_blocks, self.params,
                                      interpret=self.interpret)
        return (encoded, rows, n_blocks)

    def await_result_batch(self, ticket):  # fault-covered: codec.encode
        encoded, rows, n_blocks = ticket
        wire, moved = fetch_group_wire(encoded)   # blocks until done
        self.add_bytes(d2h=moved)
        return [wire[r * n_blocks:(r + 1) * n_blocks]
                for r in range(rows)]


def make_backend(name: str, store: BlockStore, params: PwRelParams,
                 bsz: int, compression: bool = True, prescan: bool = True,
                 *, interpret: bool = True) -> CodecBackend:
    """Resolve an ``EngineConfig.codec_backend`` name to a backend.

    ``"device"`` degrades to ``"host"`` (with a ``RuntimeWarning``) when
    ``compression`` is off — there is no device half to a raw byte copy.
    """
    if name == "device" and compression:
        return DeviceCodecBackend(store, params, bsz, compression, prescan,
                                  interpret=interpret)
    if name == "device":
        warnings.warn(
            "codec_backend='device' requires compression=True; "
            "falling back to the host codec backend",
            RuntimeWarning, stacklevel=2)
    if name in ("host", "device"):
        return HostCodecBackend(store, params, bsz, compression, prescan)
    raise ValueError(f"unknown codec backend {name!r} "
                     "(expected 'host' or 'device')")


#: fetch lookahead beyond the wave being computed (waves, not groups):
#: one decoding while one is staged is the double buffer; more only adds
#: host staging memory without hiding additional latency
_FETCH_LOOKAHEAD = 2

#: in-flight results: wave w's blocking await runs only after wave w+1's
#: compute + encode have been dispatched (the double-buffered boundary)
_INFLIGHT_WINDOW = 2


class StagePipeline:
    """Orchestrates the per-group load → compute → store loop of a stage.

    ``depth`` is the wave width: ``depth`` consecutive groups coalesce
    into one row-batched dispatch through the backend's ``*_batch`` hooks,
    and up to two waves are in flight at once — wave *w*'s blocking
    device→host wait (``await_result``) runs *after* wave *w+1*'s compute
    and encode dispatches, so it hides under device work, while the host
    codec halves run on the fetch/store worker pools behind a completion
    ready-queue (see the module docs).  ``depth=1`` is the strictly
    sequential reference schedule.

    Use as a context manager (owns the worker pools); call
    :meth:`run_stage` once per partition stage, then read the counters off
    ``backend`` and the ``t_*`` attributes:

    ``t_load``    host fetch/decode time (worker threads)
    ``t_compute`` H2D staging + compute + encode *dispatch* time — async
                  dispatch only, never a device wait
    ``t_fetch``   blocking ``await_result`` wait at the D2H boundary
    ``t_store``   host encode/store time (worker threads)

    ``n_group_phases`` counts group×stage phase executions — the
    denominator that turns the ``t_*`` sums into the per-group
    :class:`~repro.core.planner.PipelineCalibration` the planner's
    depth model consumes.
    """

    def __init__(self, backend: CodecBackend, depth: int = 2,
                 devices: list | None = None,
                 fetch_workers: int | None = None):
        self.backend = backend
        self.depth = max(1, depth)
        self.devices = devices or [jax.devices()[0]]
        # fetch pool width.  None = adaptive: one worker per spare core,
        # capped at the lookahead — and NO pools at all on a single-core
        # host, where waves still coalesce but run on the caller's
        # thread (extra decode threads only thrash the dispatch thread's
        # GIL slice).  An explicit >= 1 forces the threaded overlap
        # scheduler regardless of core count (the overlap tests use
        # this); an explicit 0 forces the coalescing-only wave loop.
        self.fetch_workers = fetch_workers
        #: in-flight result window (double buffer).  An instance attr —
        #: not the module constant — so the pressure ladder can shrink it
        #: to 1 between stages (rung 1) without rebuilding the pools.
        self.inflight_window = _INFLIGHT_WINDOW
        # t_load/t_store accumulate inside concurrent worker threads and
        # may only be touched under _t_lock (lock-discipline checker);
        # t_compute/t_fetch belong to the dispatch thread alone
        self.t_load = 0.0                      # guarded-by: _t_lock
        self.t_compute = 0.0     # h2d staging + kernel dispatch (non-blocking)
        self.t_fetch = 0.0       # blocking result wait at the d2h boundary
        self.t_store = 0.0                     # guarded-by: _t_lock
        self.n_group_phases = 0
        self._t_lock = threading.Lock()
        self._dec_pool: ThreadPoolExecutor | None = None
        self._com_pool: ThreadPoolExecutor | None = None
        self._entered = False

    def __enter__(self) -> "StagePipeline":
        # the threaded overlap scheduler only engages when spare cores
        # exist to run the workers (or the caller forces a pool width):
        # on a single-core host the context switches and GIL handoffs
        # cost more than the overlap hides, and wave *coalescing* — the
        # actual dispatch-amortization win — doesn't need threads.  The
        # fetch pool is lookahead-wide so the ready-queue can consume
        # waves in completion order (a slow decode never serializes the
        # loop); one store worker drains the encode queue.
        if self.depth > 1:
            nw = self.fetch_workers
            if nw is None and (os.cpu_count() or 1) > 1:
                nw = min(_FETCH_LOOKAHEAD, os.cpu_count() - 1)
            if nw:
                self._dec_pool = ThreadPoolExecutor(max_workers=nw)
                self._com_pool = ThreadPoolExecutor(max_workers=1)
        self._entered = True
        return self

    def __exit__(self, *exc) -> None:
        if self._dec_pool is not None:
            self._dec_pool.shutdown(wait=True)
            self._com_pool.shutdown(wait=True)
        self._dec_pool = self._com_pool = None
        self._entered = False

    # -- timed phase wrappers (run inside worker threads) ---------------------
    @staticmethod
    def _key_span(keys) -> str:
        flat = np.asarray(keys).reshape(-1)
        if flat.size == 0:
            return "no keys"
        return (f"keys [{int(flat.min())}..{int(flat.max())}] "
                f"({flat.size} blocks)")

    def _load(self, fetch, keys):
        t0 = time.perf_counter()
        try:
            fault_point("pipeline.fetch")
            staged = fetch(keys)
        except (StoreIOError, BlockCorruptionError):
            raise                   # already typed with key/blob context
        except OSError as e:
            # a raw OSError escaping a fetch worker carries no context —
            # name the wave so the failure is attributable
            raise StoreIOError("pipeline fetch",
                               detail=self._key_span(keys)) from e
        dt = time.perf_counter() - t0
        with self._t_lock:
            self.t_load += dt
        return staged

    def _store(self, store, keys, result):
        t0 = time.perf_counter()
        try:
            fault_point("pipeline.store")
            store(keys, result)
        except (StoreIOError, BlockCorruptionError):
            raise
        except OSError as e:
            raise StoreIOError("pipeline store",
                               detail=self._key_span(keys)) from e
        dt = time.perf_counter() - t0
        with self._t_lock:
            self.t_store += dt

    def _device_for(self, w: int):
        return self.devices[w % len(self.devices)]

    def run_stage(self, block_ids: np.ndarray, fn, mats,
                  lane_offsets: np.ndarray | None = None,
                  wave_fn=None, lane_shards=None,
                  group_devices=None) -> None:
        """Run one stage: ``block_ids`` is the (n_groups, 2^m) layout table,
        ``fn`` the jitted single-group update function, ``mats`` its
        operands.

        ``wave_fn`` is the row-batched form of the stage update ((R, 2,
        2^(b+m)) planes -> same; operands broadcast/tiled in-trace) — it
        enables the wave-coalesced scheduler.  Without it (the legacy
        per-gate path has no batched form) the stage runs strictly
        sequentially through the single-group hooks.

        ``lane_offsets`` switches on the lane-batched path: each wave's
        key table stacks ``lane_offsets[:, None] + block_ids[g]`` for the
        wave's groups (groups-major), and ``wave_fn`` updates the
        (depth·L, 2, 2^(b+m)) row stack in one dispatch.

        Multi-device placement (one of):

        * ``lane_shards`` — ``[(device, lane_slice), ...]``: each wave
          splits into one item per shard, carrying that shard's lane
          rows (keys from ``lane_offsets[lane_slice]``) and its slice of
          the lane-stacked operands, pre-placed on the shard's device.
          Shards touch disjoint store-key ranges, so there is nothing to
          exchange — the near-linear tier.
        * ``group_devices`` — per-group device (the plan's
          ``device_slot`` placement): the stage's groups are bucketed by
          device, chunked into depth-wide waves, and interleaved so
          consecutive dispatches land on different devices and overlap
          under async dispatch.  The engine accounts the blocks whose
          owner changed since the previous stage (compressed-wire
          exchange).

        Both default to the single-device schedule when absent.
        """
        assert self._entered, "use StagePipeline as a context manager"
        n_groups, n_blocks = block_ids.shape
        self.n_group_phases += n_groups
        if wave_fn is None:
            # legacy per-gate path: no batched form to shard a wave with,
            # but _run_sequential_single already places group g on
            # devices[g % D] — the same round-robin the plan's
            # device_slot records
            self._run_sequential_single(block_ids, fn, mats, lane_offsets)
            return
        items = self._wave_items(block_ids, mats, lane_offsets,
                                 lane_shards, group_devices)
        if self._dec_pool is None:
            self._run_waves(items, wave_fn, n_blocks)
            return
        self._run_overlapped(items, wave_fn, n_blocks)

    # -- wave item construction ----------------------------------------------
    def _wave_items(self, block_ids, mats, lane_offsets, lane_shards,
                    group_devices):
        """Flatten one stage into ``(key_rows, device, operands)`` wave
        items — the unit both schedulers consume.  Operands are placed on
        their item's device once per stage (committed arrays), so the
        jitted wave fn runs where its planes live instead of dragging
        uncommitted operands across the mesh on every dispatch."""
        n_groups, _ = block_ids.shape
        W = min(self.depth, n_groups)

        def lane_keys(gids, offs):
            return np.concatenate(
                [offs[:, None] + row[None, :] for row in gids])

        items = []
        if lane_shards:
            shard_ops = [
                (dev, sl, tuple(jax.device_put(m[sl], dev) for m in mats))
                for dev, sl in lane_shards]
            for lo in range(0, n_groups, W):
                gids = block_ids[lo:lo + W]
                for dev, sl, smats in shard_ops:
                    items.append((lane_keys(gids, lane_offsets[sl]),
                                  dev, smats))
            return items
        if group_devices is not None:
            # bucket groups by their slot device, chunk each bucket into
            # depth-wide waves, and interleave one chunk per device so
            # consecutive dispatches overlap across the mesh
            buckets: dict[int, list[int]] = {}
            order = []
            for g, dev in enumerate(group_devices):
                k = id(dev)
                if k not in buckets:
                    buckets[k] = []
                    order.append((k, dev))
                buckets[k].append(g)
            dev_mats = {k: tuple(jax.device_put(m, dev) for m in mats)
                        for k, dev in order}
            chunks = {k: [buckets[k][i:i + W]
                          for i in range(0, len(buckets[k]), W)]
                      for k, _ in order}
            while any(chunks[k] for k, _ in order):
                for k, dev in order:
                    if not chunks[k]:
                        continue
                    gids = block_ids[np.asarray(chunks[k].pop(0))]
                    keys = (gids if lane_offsets is None
                            else lane_keys(gids, lane_offsets))
                    items.append((keys, dev, dev_mats[k]))
            return items
        for w, lo in enumerate(range(0, n_groups, W)):
            gids = block_ids[lo:lo + W]
            keys = (gids if lane_offsets is None
                    else lane_keys(gids, lane_offsets))
            items.append((keys, self._device_for(w), mats))
        return items

    @staticmethod
    def _window_for(items, base: int) -> int:
        """In-flight window of a wave-item schedule: at least one item
        per distinct device, so a multi-device stage keeps every device
        busy while older waves drain at the boundary."""
        n_dev = len({id(dev) for _, dev, _ in items})
        if n_dev <= 1:
            return base
        return max(base, min(n_dev, len(items)))

    # -- sequential wave loop (depth 1 / coalescing-only hosts) ---------------
    def _run_waves(self, items, wave_fn, n_blocks) -> None:
        """Caller's-thread wave loop: no pools, no lookahead.  On one
        device the window is 1 — the strictly sequential reference
        schedule.  With several devices the window widens to the device
        count: each device's compute is dispatched (async) before any
        older wave's blocking boundary wait, so the mesh overlaps even
        without worker threads."""
        back = self.backend
        window = self._window_for(items, 1)
        in_flight: deque = deque()

        def drain():
            okeys, oticket = in_flight.popleft()
            t0 = time.perf_counter()
            result = back.await_result_batch(oticket)
            self.t_fetch += time.perf_counter() - t0
            self._store(back.store_group_batch, okeys, result)

        for keys, dev, imats in items:
            staged = self._load(back.fetch_group_batch, keys)
            t0 = time.perf_counter()
            planes = back.stage_to_device_batch(staged, dev)
            out = wave_fn(planes, *imats)
            ticket = back.dispatch_result_batch(out, n_blocks)
            self.t_compute += time.perf_counter() - t0
            in_flight.append((keys, ticket))
            if len(in_flight) >= window:
                drain()
        while in_flight:
            drain()

    # -- strictly sequential fallback (no batched stage fn) -------------------
    def _run_sequential_single(self, block_ids, fn, mats, lane_offsets):
        """Legacy per-gate path: one group per dispatch, in order, on the
        caller's thread (kept for the side-by-side benchmark — it has no
        row-batched stage function to coalesce waves with)."""
        back = self.backend
        n_groups, n_blocks = block_ids.shape
        if lane_offsets is None:
            fetch, to_dev = back.fetch_group, back.stage_to_device
            dispatch, await_ = back.dispatch_result, back.await_result
            store = back.store_group
            group_keys = [block_ids[g] for g in range(n_groups)]
        else:
            fetch, to_dev = back.fetch_group_batch, back.stage_to_device_batch
            dispatch, await_ = (back.dispatch_result_batch,
                                back.await_result_batch)
            store = back.store_group_batch
            group_keys = [lane_offsets[:, None] + block_ids[g][None, :]
                          for g in range(n_groups)]
        for g in range(n_groups):
            staged = self._load(fetch, group_keys[g])
            t0 = time.perf_counter()
            amps_dev = to_dev(staged, self._device_for(g))
            out = fn(amps_dev, *mats)
            ticket = dispatch(out, n_blocks)
            self.t_compute += time.perf_counter() - t0
            t0 = time.perf_counter()
            result = await_(ticket)
            self.t_fetch += time.perf_counter() - t0
            self._store(store, group_keys[g], result)

    # -- the double-buffered wave loop ---------------------------------------
    def _run_overlapped(self, items, wave_fn, n_blocks) -> None:
        back = self.backend
        n_waves = len(items)
        window = self._window_for(items, self.inflight_window)
        ready: queue.SimpleQueue = queue.SimpleQueue()
        outstanding: dict[int, object] = {}
        submitted = 0

        def submit_next():
            nonlocal submitted
            if submitted < n_waves:
                w = submitted
                submitted += 1
                fut = self._dec_pool.submit(self._load,
                                            back.fetch_group_batch,
                                            items[w][0])
                outstanding[w] = fut
                fut.add_done_callback(lambda _f, w=w: ready.put(w))

        in_flight: deque = deque()     # (wave, ticket) dispatched, unawaited
        pending_save = []
        try:
            for _ in range(min(1 + _FETCH_LOOKAHEAD, n_waves)):
                submit_next()
            for _ in range(n_waves):
                # completion-order ready-queue: take whichever lookahead
                # fetch finished first — a slow decode never serializes
                # the loop behind wave order
                w = ready.get()
                staged = outstanding.pop(w).result()
                keys, dev, imats = items[w]
                t0 = time.perf_counter()
                planes = back.stage_to_device_batch(staged, dev)
                out = wave_fn(planes, *imats)
                ticket = back.dispatch_result_batch(out, n_blocks)
                self.t_compute += time.perf_counter() - t0
                submit_next()          # keep the fetch lookahead full
                in_flight.append((w, ticket))
                if len(in_flight) >= window:
                    # double buffer: wave w is computing asynchronously
                    # while this (older) wave's blocking wait drains
                    ow, oticket = in_flight.popleft()
                    t0 = time.perf_counter()
                    result = back.await_result_batch(oticket)
                    self.t_fetch += time.perf_counter() - t0
                    pending_save.append(self._com_pool.submit(
                        self._store, back.store_group_batch,
                        items[ow][0], result))
            while in_flight:           # drain the window
                ow, oticket = in_flight.popleft()
                t0 = time.perf_counter()
                result = back.await_result_batch(oticket)
                self.t_fetch += time.perf_counter() - t0
                pending_save.append(self._com_pool.submit(
                    self._store, back.store_group_batch,
                    items[ow][0], result))
        except BaseException:
            # fail fast without deadlocking the pools: drop queued
            # fetches, let running ones finish (shutdown waits), and
            # surface the ORIGINAL error over any secondary store failure
            for fut in outstanding.values():
                fut.cancel()
            for fut in pending_save:
                try:
                    fut.result()
                except Exception:  # lint: disable=typed-errors -- keep original error
                    pass
            raise
        for fut in pending_save:       # stage barrier (§4.1 semantics)
            fut.result()
