"""BMQSIM core: the paper's contribution (compressed staged SV simulation)."""
from .circuit import CHANNEL_FACTORIES, Circuit, Gate, Parameter  # noqa: F401
from .dense_engine import (  # noqa: F401
    apply_matrix, initial_state, simulate_dense, simulate_dense_sharded,
)
from .engine import BMQSimEngine, EngineConfig, SimStats, simulate_bmqsim  # noqa: F401
from .faults import (  # noqa: F401
    INJECTION_POINTS, FaultInjector, FaultSpec, InjectedCrash, inject_faults,
)
from .fidelity import fidelity, max_pointwise_rel_error, norm  # noqa: F401
from .fusion import FusedGate, fuse_gates, gates_to_unitary  # noqa: F401
from .groups import GroupLayout, expand_bits  # noqa: F401
from .library import (  # noqa: F401
    CIRCUIT_BUILDERS, build_circuit, maxcut_cost_fn, maxcut_edges,
    qaoa_template, random_circuit, with_depolarizing, zsum_cost_fn,
)
from .partition import Partition, Stage, partition_circuit  # noqa: F401
from .plan import ExecutionPlan, PlanPredictions, StagePlan  # noqa: F401
from .pressure import RUNGS, PressureMonitor  # noqa: F401
from .planner import (PipelineCalibration, estimate_bytes_per_amp,  # noqa: F401
                      predict_depth_speedup, resolve_config)
from .pipeline import (  # noqa: F401
    CodecBackend, DeviceCodecBackend, HostCodecBackend, StagePipeline,
    make_backend,
)
from .measure import block_probabilities, expect_diagonal, sample_counts  # noqa: F401
from .result import BatchResult, SimResult  # noqa: F401
from .schedule import StageSchedule, compile_schedule, execute_schedule  # noqa: F401
from .service import Job, ServiceStats, SimService, VirtualClock  # noqa: F401
from .simulator import Simulator, circuit_fingerprint  # noqa: F401
