"""Measurement sampling from a COMPRESSED state (memory-conscious readout).

The paper's engine exists so states too big to materialize can be
simulated; reading results out must honor the same constraint.  Sampling
bitstrings therefore streams the store block-by-block:

  pass 1: decompress each SV block once -> probability mass per block
          (2^c floats — tiny), build the block-level CDF;
  pass 2: multinomial over blocks, then decompress ONLY the blocks that
          received samples and sample local indices within them.

Peak extra memory is one block, matching the engine's working set.
Expectation values of diagonal observables (e.g. computational-basis
energies for QAOA) stream the same way.
"""
from __future__ import annotations

import numpy as np

from .engine import BMQSimEngine

__all__ = ["sample_counts", "block_probabilities", "expect_diagonal"]


def block_probabilities(engine: BMQSimEngine) -> np.ndarray:
    """(2^c,) probability mass per SV block (one streaming pass)."""
    n_blocks = 2 ** (engine.n - engine.b)
    masses = np.empty(n_blocks, np.float64)
    for blk in range(n_blocks):
        amps = engine.backend.decode_host_block(blk)
        masses[blk] = float(np.sum(np.abs(amps) ** 2))
    return masses


def sample_counts(engine: BMQSimEngine, n_shots: int,
                  seed: int = 0) -> dict[int, int]:
    """Sample ``n_shots`` computational-basis outcomes -> {index: count}."""
    rng = np.random.default_rng(seed)
    masses = block_probabilities(engine)
    total = masses.sum()
    if not np.isclose(total, 1.0, atol=1e-2):
        masses = masses / total          # renormalize lossy tail
    else:
        masses = masses / total
    per_block = rng.multinomial(n_shots, masses)
    counts: dict[int, int] = {}
    bsz = 2 ** engine.b
    for blk in np.nonzero(per_block)[0]:
        amps = engine.backend.decode_host_block(int(blk))
        p = np.abs(amps) ** 2
        p = p / p.sum()
        idx = rng.choice(bsz, size=int(per_block[blk]), p=p)
        base = int(blk) << engine.b
        for i in idx:
            key = base | int(i)
            counts[key] = counts.get(key, 0) + 1
    return counts


def expect_diagonal(engine: BMQSimEngine, diag_fn) -> float:
    """<psi| D |psi> for a diagonal observable, streamed per block.

    ``diag_fn(indices) -> values``: vectorized diagonal entries for global
    basis indices (e.g. a QAOA MaxCut cost function).
    """
    bsz = 2 ** engine.b
    n_blocks = 2 ** (engine.n - engine.b)
    local = np.arange(bsz, dtype=np.int64)
    acc = 0.0
    for blk in range(n_blocks):
        amps = engine.backend.decode_host_block(blk)
        vals = diag_fn((blk << engine.b) | local)
        acc += float(np.sum((np.abs(amps) ** 2) * vals))
    return acc
