"""Measurement readout from a COMPRESSED state — legacy free functions.

.. deprecated::
    These engine-taking wrappers predate the session API; the
    implementation lives in :mod:`repro.core.result` and is reachable as
    :class:`SimResult` methods (``result.sample(...)``,
    ``result.expectation(...)``, ``result.block_probabilities()``), which
    is the stable surface.  Kept for callers holding a bare
    :class:`BMQSimEngine`.

All readers stream the store block-by-block: peak extra memory is one
decoded SV block, matching the engine's working set.  When the lossy
tail drifts the total probability mass beyond tolerance, the readout
renormalizes and emits a ``RuntimeWarning``.
"""
from __future__ import annotations

import numpy as np

from .engine import BMQSimEngine
from .result import (stream_block_masses, stream_expectation,
                     stream_sample)

__all__ = ["sample_counts", "block_probabilities", "expect_diagonal"]


def block_probabilities(engine: BMQSimEngine) -> np.ndarray:
    """(2^c,) probability mass per SV block (one streaming pass)."""
    return stream_block_masses(engine.backend, engine.n, engine.b)


def sample_counts(engine: BMQSimEngine, n_shots: int,
                  seed: int = 0) -> dict[int, int]:
    """Sample ``n_shots`` computational-basis outcomes -> {index: count}."""
    return stream_sample(engine.backend, engine.n, engine.b, n_shots,
                         seed=seed)


def expect_diagonal(engine: BMQSimEngine, diag_fn) -> float:
    """<psi| D |psi> for a diagonal observable, streamed per block.

    ``diag_fn(indices) -> values``: vectorized diagonal entries for global
    basis indices (e.g. a QAOA MaxCut cost function).
    """
    return stream_expectation(engine.backend, engine.n, engine.b, diag_fn)
