"""Circuit partitioning — the paper's Algorithm 1 (§4.1).

Given a state-vector layout with ``b`` local bits (block size ``2^b``) and
``c = n - b`` global bits (block count ``2^c``), split the gate list into
*stages* such that the set of **global** qubits targeted inside a stage
(the stage's *inner indices*) never exceeds ``max(inner_size, 2)``.

Within a stage every SV *group* — the ``2^m`` blocks that share the same
*outer* global bits (``m`` = #inner indices) — can be processed with ONE
decompress + ONE recompress, and groups are mutually independent.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from .circuit import Circuit, Gate

__all__ = ["Stage", "Partition", "partition_circuit"]


@dataclass
class Stage:
    """One stage: a run of gates plus its inner (global) index set."""

    gates: list[Gate] = field(default_factory=list)
    inner: list[int] = field(default_factory=list)  # sorted global qubits used

    def global_support(self, b: int) -> set[int]:
        return {q for g in self.gates for q in g.qubits if q >= b}


@dataclass
class Partition:
    n_qubits: int
    local_bits: int            # b
    inner_size: int            # user limit on #inner indices per stage
    stages: list[Stage]

    @property
    def global_bits(self) -> int:
        return self.n_qubits - self.local_bits

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def compression_count(self) -> int:
        """Number of (de)compression passes over the state vector = #stages
        (vs. #gates for the SC19-Sim per-gate baseline)."""
        return len(self.stages)

    def validate(self) -> None:
        """Invariants: gates partition the circuit in order; per-stage
        global support == recorded inner set and within threshold."""
        thr = max(self.inner_size, 2)
        total = 0
        for st in self.stages:
            sup = st.global_support(self.local_bits)
            assert sup == set(st.inner), (sup, st.inner)
            assert len(sup) <= thr, f"stage global support {sup} > {thr}"
            total += len(st.gates)


def partition_circuit(circuit: Circuit, local_bits: int,
                      inner_size: int = 2) -> Partition:
    """Algorithm 1.  ``local_bits`` = b (SV block size = 2^b amplitudes);
    ``inner_size`` = max #global indices per stage (min 2, for 2-qubit
    gates whose targets both land in the global part)."""
    b = local_bits
    n = circuit.n_qubits
    if not 0 <= b <= n:
        raise ValueError(f"local_bits {b} out of range for n={n}")
    threshold = max(inner_size, 2)
    if threshold > n - b:
        # fewer global bits than the threshold: everything fits in one stage
        threshold = max(n - b, 0)

    stages: list[Stage] = []
    cur = Stage()
    cur_glob: set[int] = set()
    for gate in circuit.gates:
        gate_glob = {q for q in gate.qubits if q >= b}
        merged = cur_glob | gate_glob
        if len(merged) > max(threshold, len(gate_glob)):
            # would exceed — flush current stage (Lines 7-9)
            if cur.gates:
                cur.inner = sorted(cur_glob)
                stages.append(cur)
            cur = Stage()
            cur_glob = set(gate_glob)
        else:
            cur_glob = merged
        cur.gates.append(gate)
    if cur.gates:
        cur.inner = sorted(cur_glob)
        stages.append(cur)

    part = Partition(n_qubits=n, local_bits=b, inner_size=inner_size,
                     stages=stages)
    part.validate()
    return part
