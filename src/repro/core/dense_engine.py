"""Dense (uncompressed) state-vector engines.

* ``simulate_dense`` — the reference engine: full state in one array,
  gate-by-gate application via transpose-to-minor + GEMM.  This is the
  oracle that the compressed BMQSIM engine, the Pallas kernels, and the
  fidelity numbers are all validated against.
* ``simulate_dense_sharded`` — an SV-Sim-like distributed baseline: the
  state is sharded over a device mesh axis; gates on "global" qubits
  induce collectives (what BMQSIM's group independence removes).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .circuit import Circuit, Gate

__all__ = [
    "apply_gate_dense",
    "apply_matrix",
    "initial_state",
    "simulate_dense",
    "simulate_dense_sharded",
]


def initial_state(n: int, dtype=jnp.complex64) -> jax.Array:
    """|0...0> as a flat 2^n vector."""
    state = jnp.zeros((2 ** n,), dtype=dtype)
    return state.at[0].set(1.0)


def apply_matrix(state: jax.Array, mat: jax.Array, qubits: tuple[int, ...],
                 n: int) -> jax.Array:
    """Apply a 2^k x 2^k unitary to ``qubits`` of a flat 2^n state.

    Little-endian: qubit q is bit q of the flat index; ``qubits[j]`` is bit j
    of the matrix row/column index.  Implementation: view the state as an
    n-dim (2,)*n tensor whose axis a holds qubit (n-1-a), transpose the
    target qubits to the minor-most axes (qubits[0] last), GEMM, undo.
    """
    k = len(qubits)
    axes = [n - 1 - q for q in qubits]          # tensor axis of each target
    rest = [a for a in range(n) if a not in axes]
    # new axis order: rest ... then qubits[k-1] ... qubits[0]
    perm = rest + [axes[j] for j in range(k - 1, -1, -1)]
    t = state.reshape((2,) * n).transpose(perm).reshape(-1, 2 ** k)
    t = t @ mat.astype(t.dtype).T
    inv = np.argsort(np.asarray(perm))  # jit-ok: perm is a static python list
    return t.reshape([2] * n).transpose(list(inv)).reshape(-1)


def apply_gate_dense(state: jax.Array, gate: Gate, n: int) -> jax.Array:
    return apply_matrix(state, jnp.asarray(gate.matrix), gate.qubits, n)


def simulate_dense(circuit: Circuit, dtype=jnp.complex64,
                   initial: jax.Array | None = None) -> jax.Array:
    """Reference simulation: returns the final flat 2^n state."""
    n = circuit.n_qubits
    state = initial_state(n, dtype) if initial is None else initial.astype(dtype)

    def run(state, mats):
        for gate, mat in zip(circuit.gates, mats):
            state = apply_matrix(state, mat, gate.qubits, n)
        return state

    mats = tuple(jnp.asarray(g.matrix, dtype=dtype) for g in circuit.gates)
    return jax.jit(run)(state, mats)


def simulate_dense_sharded(circuit: Circuit, mesh: jax.sharding.Mesh,
                           axis: str = "data",
                           dtype=jnp.complex64) -> jax.Array:
    """SV-Sim-like baseline: state sharded over ``axis`` of ``mesh``.

    The state is laid out so the mesh axis shards the MOST significant
    qubits; a gate touching those qubits makes XLA insert collectives
    (all-to-all / collective-permute) — the communication cost that
    BMQSIM's independent SV groups avoid.  Used by the comparison bench.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    n = circuit.n_qubits
    n_dev = mesh.shape[axis]
    assert (2 ** n) % n_dev == 0

    sharding = NamedSharding(mesh, P(axis))
    state = jax.device_put(initial_state(n, dtype), sharding)

    def run(state):
        for gate in circuit.gates:
            state = apply_matrix(state, jnp.asarray(gate.matrix), gate.qubits, n)
        return state

    fn = jax.jit(run, in_shardings=sharding, out_shardings=sharding)
    return fn(state)
