"""Pressure monitor: graceful degradation when compression underdelivers.

The planner predicts the compressed state's ``bytes_per_amp`` from an
entropy model of ``b_r`` (:func:`repro.core.planner.estimate_bytes_per_amp`)
— but the achieved ratio is data-dependent (§4.4: QFT/GHZ compress
~130x, QAOA/RCS barely 2x), and a run whose state is incompressible will
blow straight past the plan's working-set budget.  Rather than thrash or
die, the engine checks this monitor at every stage boundary and walks a
degradation ladder while measured ``bytes_per_amp`` exceeds
``headroom ×`` the prediction:

    rung 1  ``shrink_window``  — pipeline in-flight window -> 1
                                 (halves the staged-wave working set)
    rung 2  ``wave_depth_1``   — pipeline wave depth -> 1 (one group's
                                 planes in flight at a time)
    rung 3  ``proactive_spill``— push RAM-tier blobs to disk down to
                                 half the budget (or half current use)
    rung 4  ``abort``          — the disk tier itself overflowed its
                                 budget: raise a typed
                                 :class:`~repro.errors.MemoryPressureError`
                                 at the stage boundary (the simulator
                                 flushes an emergency checkpoint and
                                 re-raises with the resume path)

Rungs 1–3 degrade throughput, never correctness (the store's spill
backstop still guarantees ``peak_ram <= budget``); rung 4 only fires
when ``disk_budget_bytes`` is set and exhausted — an incompressible but
spillable run degrades and completes.  Every rung taken is recorded in
``SimStats.pressure_rungs`` (and counted in ``n_pressure_events``), and
``qsim --explain`` prints the armed ladder.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from ..errors import MemoryPressureError

__all__ = ["PressureMonitor", "RUNGS"]

#: ladder order; "abort" is the terminal disk-overflow rung
RUNGS = ("shrink_window", "wave_depth_1", "proactive_spill")


@dataclass
class PressureMonitor:
    """Stage-boundary memory-pressure watchdog (one per engine run).

    Args:
        predicted_bpa: the planner's bytes-per-amplitude estimate.
        n_qubits: state size (the bpa denominator is ``2^n × lanes``).
        headroom: measured/predicted ratio that counts as pressure
            (default 1.5× — the entropy model is deliberately loose).
        lanes: lanes currently materialized in the store (run_batch).
        ram_budget: the store's RAM budget, for the spill rung's target.
        disk_budget: optional disk-tier byte budget; overflowing it is
            the terminal ``abort`` rung.
    """

    predicted_bpa: float
    n_qubits: int
    headroom: float = 1.5
    lanes: int = 1
    ram_budget: int | None = None
    disk_budget: int | None = None
    rung: int = 0
    #: (stages_done, rung_name) of every escalation, newest last
    events: list = field(default_factory=list)

    def measured_bpa(self, store) -> float:
        denom = float(2 ** self.n_qubits) * max(1, self.lanes)
        return store.total_bytes / denom

    def under_pressure(self, store) -> bool:
        return self.measured_bpa(store) > self.headroom * self.predicted_bpa

    def check(self, store, pipe, stats, stages_done: int) -> None:
        """Escalate one rung if pressure persists; raise at disk overflow.

        Called at stage boundaries only — the store is consistent and no
        pipeline workers are mid-flight, so mutating ``pipe`` and
        spilling are race-free.
        """
        if (self.disk_budget is not None
                and store.stats.disk_bytes > self.disk_budget):
            self._record(stats, stages_done, "abort")
            raise MemoryPressureError(
                f"disk tier overflowed its budget after stage "
                f"{stages_done}: {store.stats.disk_bytes} B spilled > "
                f"{self.disk_budget} B allowed (measured "
                f"{self.measured_bpa(store):.2f} B/amp vs predicted "
                f"{self.predicted_bpa:.2f})",
                stages_done=stages_done)
        if not self.under_pressure(store) or self.rung >= len(RUNGS):
            return
        name = RUNGS[self.rung]
        self.rung += 1
        self._record(stats, stages_done, name)
        if name == "shrink_window":
            pipe.inflight_window = 1
        elif name == "wave_depth_1":
            pipe.depth = 1
            pipe.inflight_window = 1
        elif name == "proactive_spill":
            target = ((self.ram_budget // 2) if self.ram_budget
                      else store.stats.ram_bytes // 2)
            store.spill(target)

    def _record(self, stats, stages_done: int, name: str) -> None:
        self.events.append((stages_done, name))
        if stats is not None:
            stats.pressure_rungs.append(f"stage{stages_done}:{name}")
            stats.n_pressure_events += 1
