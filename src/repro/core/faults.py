"""Canonical import surface of the fault-injection framework.

The implementation lives in :mod:`repro.faults` (top-level and
stdlib-only, so the compression layer's store can register injection
points without importing ``repro.core`` — which imports the store right
back).  Import from here::

    from repro.core.faults import FaultSpec, inject_faults

See the :mod:`repro.faults` module docs for the point registry, fault
kinds and spec syntax.
"""
from ..faults import (  # noqa: F401
    INJECTION_POINTS,
    FaultInjector,
    FaultSpec,
    InjectedCrash,
    active_injector,
    clear_faults,
    fault_point,
    inject_faults,
    install_faults,
)

__all__ = [
    "INJECTION_POINTS",
    "FaultInjector",
    "FaultSpec",
    "InjectedCrash",
    "active_injector",
    "clear_faults",
    "fault_point",
    "inject_faults",
    "install_faults",
]
