"""Circuit IR: a flat list of Gate ops over n qubits (little-endian).

Circuits may be *parameterized*: any gate angle can be a
:class:`Parameter` placeholder instead of a float.  A parameterized gate
defers its matrix (``matrix is None``) until :meth:`Gate.bind` /
:meth:`Circuit.bind` substitutes concrete values — the structural fields
(name, qubits) are always present, so partitioning and scheduling work on
the unbound template while the numeric unitaries are produced per binding
(the :class:`~repro.core.simulator.Simulator` session exploits this to
re-run e.g. a QAOA ansatz at many angles without re-partitioning).

Circuits may also be *stochastic*: a gate whose name is in
:data:`CHANNEL_FACTORIES` is a sampled Pauli channel — a placeholder
(``matrix is None``, like a parameterized gate) whose concrete unitary is
drawn per noise trajectory by :meth:`Gate.realize` from the channel's
outcome table.  Structure (name, qubits) is fixed, so partitioning,
fusion, and scheduling are shared across every trajectory of a batch;
only the matrices differ per lane (``Simulator.run(trajectories=K)``).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Mapping, Sequence

import numpy as np

from . import gates as G

__all__ = ["Parameter", "Gate", "Circuit", "CHANNEL_FACTORIES"]


def _depolarizing(p: float):
    """Uniform 1-qubit depolarizing: I with prob 1-p, X/Y/Z with p/3 each."""
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"depolarizing probability {p} outside [0, 1]")
    return ((1.0 - p, "i"), (p / 3.0, "x"), (p / 3.0, "y"), (p / 3.0, "z"))


def _bitflip(p: float):
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"bit-flip probability {p} outside [0, 1]")
    return ((1.0 - p, "i"), (p, "x"))


def _phaseflip(p: float):
    if not 0.0 <= p <= 1.0:
        raise ValueError(f"phase-flip probability {p} outside [0, 1]")
    return ((1.0 - p, "i"), (p, "z"))


#: stochastic Pauli channels: name -> callable(*params) returning the
#: outcome table ``((probability, gate_name), ...)``.  A gate with one of
#: these names is a per-trajectory placeholder resolved by Gate.realize.
CHANNEL_FACTORIES = {
    "depol": _depolarizing,
    "bitflip": _bitflip,
    "phaseflip": _phaseflip,
}


@dataclass(frozen=True)
class Parameter:
    """A named placeholder for a gate angle, resolved at bind time."""

    name: str

    def __repr__(self) -> str:
        return f"Parameter({self.name!r})"


def _resolve(params: tuple, values: Mapping[str, float]) -> tuple[float, ...]:
    out = []
    for p in params:
        if isinstance(p, Parameter):
            if p.name not in values:
                raise KeyError(f"no value bound for parameter {p.name!r}")
            out.append(float(values[p.name]))
        else:
            out.append(float(p))
    return tuple(out)


@dataclass(frozen=True)
class Gate:
    """One gate application.

    ``qubits`` is the target tuple; ``qubits[0]`` maps to the least-significant
    bit of the matrix index (see gates.py conventions).  ``matrix`` is None
    while any entry of ``params`` is a :class:`Parameter` placeholder.
    """

    name: str
    qubits: tuple[int, ...]
    matrix: np.ndarray | None
    params: tuple = ()

    def __post_init__(self):
        k = len(self.qubits)
        assert len(set(self.qubits)) == k, f"duplicate qubits in {self.name}"
        if self.is_stochastic:
            assert self.matrix is None, self.name
            assert k == 1, f"channel {self.name} must act on one qubit"
            assert not self.is_parameterized, \
                f"channel {self.name} probabilities must be concrete"
        elif self.is_parameterized:
            assert self.matrix is None, self.name
        else:
            assert self.matrix is not None and \
                self.matrix.shape == (2 ** k, 2 ** k), \
                (self.name, None if self.matrix is None else self.matrix.shape)

    @property
    def support(self) -> frozenset[int]:
        return frozenset(self.qubits)

    @property
    def is_parameterized(self) -> bool:
        return any(isinstance(p, Parameter) for p in self.params)

    @property
    def is_stochastic(self) -> bool:
        """True for a sampled Pauli channel (resolved by :meth:`realize`)."""
        return self.name in CHANNEL_FACTORIES

    @property
    def free_parameters(self) -> frozenset[str]:
        return frozenset(p.name for p in self.params
                         if isinstance(p, Parameter))

    def outcomes(self) -> tuple[tuple[float, str], ...]:
        """A channel's ``((probability, gate_name), ...)`` outcome table."""
        if not self.is_stochastic:
            raise ValueError(f"gate {self.name!r} is not a channel")
        return CHANNEL_FACTORIES[self.name](*self.params)

    def realize(self, rng: np.random.Generator) -> "Gate":
        """Draw one concrete realization of a stochastic channel.

        Deterministic given the rng state: the engine's trajectory lanes
        and the dense oracle (:meth:`Circuit.realize`) consume the same
        stream in circuit order, so equal seeds reproduce equal gates.
        Non-stochastic gates return themselves (no draw is consumed).
        """
        if not self.is_stochastic:
            return self
        table = self.outcomes()
        u = float(rng.random())
        acc = 0.0
        picked = table[-1][1]
        for prob, name in table:
            acc += prob
            if u < acc:
                picked = name
                break
        mat = np.asarray(G.GATE_FACTORIES[picked](), dtype=np.complex128)
        return Gate(picked, self.qubits, mat, ())

    def bind(self, values: Mapping[str, float]) -> "Gate":
        """Substitute parameter values; returns a concrete gate."""
        if not self.is_parameterized:
            return self
        params = _resolve(self.params, values)
        mat = np.asarray(G.GATE_FACTORIES[self.name](*params),
                         dtype=np.complex128)
        return Gate(self.name, self.qubits, mat, params)


@dataclass
class Circuit:
    """An ordered gate list over ``n_qubits`` qubits (builder API below)."""
    n_qubits: int
    gates: list[Gate] = field(default_factory=list)

    # -- builder API ---------------------------------------------------------
    def append(self, name: str, qubits: Sequence[int], *params) -> "Circuit":
        for q in qubits:
            if not 0 <= q < self.n_qubits:
                raise ValueError(f"qubit {q} out of range for n={self.n_qubits}")
        if any(isinstance(p, Parameter) for p in params):
            if name not in G.GATE_FACTORIES:     # fail at append, not bind
                raise KeyError(f"unknown gate {name!r}")
            self.gates.append(Gate(name, tuple(qubits), None, tuple(params)))
            return self
        mat = np.asarray(G.GATE_FACTORIES[name](*params), dtype=np.complex128)
        self.gates.append(Gate(name, tuple(qubits), mat,
                               tuple(float(p) for p in params)))
        return self

    def h(self, q):            return self.append("h", [q])
    def x(self, q):            return self.append("x", [q])
    def y(self, q):            return self.append("y", [q])
    def z(self, q):            return self.append("z", [q])
    def s(self, q):            return self.append("s", [q])
    def t(self, q):            return self.append("t", [q])
    def sdg(self, q):          return self.append("sdg", [q])
    def tdg(self, q):          return self.append("tdg", [q])
    def rx(self, th, q):       return self.append("rx", [q], th)
    def ry(self, th, q):       return self.append("ry", [q], th)
    def rz(self, th, q):       return self.append("rz", [q], th)
    def p(self, lam, q):       return self.append("p", [q], lam)
    def u3(self, th, ph, lam, q): return self.append("u3", [q], th, ph, lam)
    # two-qubit: (target, control) order in the stored tuple
    # stochastic Pauli channels (sampled per trajectory at bind time)
    def append_channel(self, name: str, qubits: Sequence[int],
                       *params) -> "Circuit":
        if name not in CHANNEL_FACTORIES:
            raise KeyError(f"unknown channel {name!r}; "
                           f"have {sorted(CHANNEL_FACTORIES)}")
        for q in qubits:
            if not 0 <= q < self.n_qubits:
                raise ValueError(f"qubit {q} out of range for n={self.n_qubits}")
        gate = Gate(name, tuple(qubits), None,
                    tuple(float(p) for p in params))
        gate.outcomes()               # fail on bad probabilities at append
        self.gates.append(gate)
        return self

    def depolarize(self, p, q):  return self.append_channel("depol", [q], p)
    def bitflip(self, p, q):     return self.append_channel("bitflip", [q], p)
    def phaseflip(self, p, q):   return self.append_channel("phaseflip", [q], p)

    def cx(self, c, t):        return self.append("cx", [t, c])
    def cz(self, c, t):        return self.append("cz", [t, c])
    def cp(self, lam, c, t):   return self.append("cp", [t, c], lam)
    def crz(self, th, c, t):   return self.append("crz", [t, c], th)
    def swap(self, a, b_):     return self.append("swap", [a, b_])
    def rzz(self, th, a, b_):  return self.append("rzz", [a, b_], th)
    def rxx(self, th, a, b_):  return self.append("rxx", [a, b_], th)

    # -- parameter binding ---------------------------------------------------
    @property
    def free_parameters(self) -> frozenset[str]:
        """Names of all unbound :class:`Parameter` placeholders."""
        out: set[str] = set()
        for g in self.gates:
            if g.is_parameterized:
                out |= g.free_parameters
        return frozenset(out)

    @property
    def is_parameterized(self) -> bool:
        return any(g.is_parameterized for g in self.gates)

    @property
    def is_stochastic(self) -> bool:
        """True when the circuit contains sampled Pauli channels."""
        return any(g.is_stochastic for g in self.gates)

    def realize(self, rng) -> "Circuit":
        """Draw one concrete noise trajectory: every stochastic channel
        is replaced by a sampled Pauli gate, in circuit order, consuming
        ``rng`` (a seed int or :class:`numpy.random.Generator`).  The
        engine's trajectory lanes use the same stream/order, so the dense
        oracle ``simulate_dense(circuit.realize(seed))`` reproduces lane
        ``seed`` of a batch exactly.
        """
        if isinstance(rng, (int, np.integer)):
            rng = np.random.default_rng(int(rng))
        return Circuit(self.n_qubits,
                       [g.realize(rng) if g.is_stochastic else g
                        for g in self.gates])

    def bind(self, values: Mapping[str, float]) -> "Circuit":
        """Return a concrete circuit with every placeholder substituted.

        ``values`` must cover :attr:`free_parameters`; unknown names raise
        (a typo silently leaving a parameter unbound is the failure mode
        this guards against).
        """
        unknown = set(values) - self.free_parameters
        if unknown:
            raise KeyError(f"unknown parameter(s) {sorted(unknown)}; "
                           f"circuit has {sorted(self.free_parameters)}")
        return Circuit(self.n_qubits, [g.bind(values) for g in self.gates])

    # -- properties ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.gates)

    def __iter__(self) -> Iterable[Gate]:
        return iter(self.gates)

    def qubit_histogram(self) -> dict[int, int]:
        hist: dict[int, int] = {}
        for g in self.gates:
            for q in g.qubits:
                hist[q] = hist.get(q, 0) + 1
        return hist

    def depth(self) -> int:
        """Logical depth (greedy ASAP scheduling)."""
        level = [0] * self.n_qubits
        d = 0
        for g in self.gates:
            lv = max(level[q] for q in g.qubits) + 1
            for q in g.qubits:
                level[q] = lv
            d = max(d, lv)
        return d
