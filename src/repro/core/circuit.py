"""Circuit IR: a flat list of Gate ops over n qubits (little-endian)."""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterable, Sequence

import numpy as np

from . import gates as G


@dataclass(frozen=True)
class Gate:
    """One gate application.

    ``qubits`` is the target tuple; ``qubits[0]`` maps to the least-significant
    bit of the matrix index (see gates.py conventions).
    """

    name: str
    qubits: tuple[int, ...]
    matrix: np.ndarray
    params: tuple[float, ...] = ()

    def __post_init__(self):
        k = len(self.qubits)
        assert self.matrix.shape == (2 ** k, 2 ** k), (self.name, self.matrix.shape)
        assert len(set(self.qubits)) == k, f"duplicate qubits in {self.name}"

    @property
    def support(self) -> frozenset[int]:
        return frozenset(self.qubits)


@dataclass
class Circuit:
    n_qubits: int
    gates: list[Gate] = field(default_factory=list)

    # -- builder API ---------------------------------------------------------
    def append(self, name: str, qubits: Sequence[int], *params: float) -> "Circuit":
        for q in qubits:
            if not 0 <= q < self.n_qubits:
                raise ValueError(f"qubit {q} out of range for n={self.n_qubits}")
        mat = np.asarray(G.GATE_FACTORIES[name](*params), dtype=np.complex128)
        self.gates.append(Gate(name, tuple(qubits), mat, tuple(params)))
        return self

    def h(self, q):            return self.append("h", [q])
    def x(self, q):            return self.append("x", [q])
    def y(self, q):            return self.append("y", [q])
    def z(self, q):            return self.append("z", [q])
    def s(self, q):            return self.append("s", [q])
    def t(self, q):            return self.append("t", [q])
    def sdg(self, q):          return self.append("sdg", [q])
    def tdg(self, q):          return self.append("tdg", [q])
    def rx(self, th, q):       return self.append("rx", [q], th)
    def ry(self, th, q):       return self.append("ry", [q], th)
    def rz(self, th, q):       return self.append("rz", [q], th)
    def p(self, lam, q):       return self.append("p", [q], lam)
    def u3(self, th, ph, lam, q): return self.append("u3", [q], th, ph, lam)
    # two-qubit: (target, control) order in the stored tuple
    def cx(self, c, t):        return self.append("cx", [t, c])
    def cz(self, c, t):        return self.append("cz", [t, c])
    def cp(self, lam, c, t):   return self.append("cp", [t, c], lam)
    def crz(self, th, c, t):   return self.append("crz", [t, c], th)
    def swap(self, a, b_):     return self.append("swap", [a, b_])
    def rzz(self, th, a, b_):  return self.append("rzz", [a, b_], th)
    def rxx(self, th, a, b_):  return self.append("rxx", [a, b_], th)

    # -- properties ----------------------------------------------------------
    def __len__(self) -> int:
        return len(self.gates)

    def __iter__(self) -> Iterable[Gate]:
        return iter(self.gates)

    def qubit_histogram(self) -> dict[int, int]:
        hist: dict[int, int] = {}
        for g in self.gates:
            for q in g.qubits:
                hist[q] = hist.get(q, 0) + 1
        return hist

    def depth(self) -> int:
        """Logical depth (greedy ASAP scheduling)."""
        level = [0] * self.n_qubits
        d = 0
        for g in self.gates:
            lv = max(level[q] for q in g.qubits) + 1
            for q in g.qubits:
                level[q] = lv
            d = max(d, lv)
        return d
