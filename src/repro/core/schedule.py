"""Stage gate schedule: transpose-minimizing compilation of a fused plan.

The naive stage compute (PR-1, kept as ``EngineConfig.gate_schedule=False``)
brackets *every* fused unitary with a full-group transpose pair:

    transpose(perm_i) -> GEMM -> transpose(perm_i^-1)      # per gate i

i.e. up to two HBM passes over the 2^(b+m) group array per gate beyond the
arithmetic itself.  This module compiles the stage's gate list into a
minimal permutation plan instead, exploiting three facts:

1. **Layouts compose.** Between gate i and gate i+1 the array only needs
   to move from gate i's layout to gate i+1's layout — one transpose
   (``perm_i^-1 ∘ perm_{i+1}``), not two.  The single inverse permutation
   back to the canonical layout is emitted once, at the end of the stage.
2. **The major axes are free.** A GEMM only requires the gate's k qubit
   axes minor-most (qubit 0's axis last); the remaining axes can sit in
   *any* order.  Keeping them in their current order means consecutive
   gates on identical qubit sets — and many overlapping sets — need no
   transpose at all.
3. **Diagonal unitaries are layout-invariant.** A diagonal gate is an
   elementwise multiply; in any bit-permuted layout it runs as a
   broadcast multiply against a (2,)*k diagonal tensor placed on the
   gate's current axis positions — never a transpose of the group array.

The compiled :class:`StageSchedule` is a pure function of the stage plan
``((vqubits, diag), ...)`` and ``nv`` — cached with ``lru_cache`` the same
way the engine caches its jitted stage functions — and executes on the
planes-resident representation: a ``(2, 2^nv)`` f32 stack of re/im planes
(see ``kernels/gate_apply.py`` for why the MXU wants planes, not
complex64).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import lru_cache

import jax.numpy as jnp

__all__ = ["TransposeOp", "GemmOp", "MidGemmOp", "DiagOp", "StageSchedule",
           "compile_schedule", "execute_schedule",
           "execute_schedule_batched", "gate_perm"]


@dataclass(frozen=True)
class TransposeOp:
    """Permute the (2,)*nv group tensor axes (one full HBM pass)."""

    perm: tuple[int, ...]


@dataclass(frozen=True)
class GemmOp:
    """Apply dense unitary ``mats[idx]`` (stacked (2, K, K) planes of U)
    to the minor-most K = 2^k amplitudes: C = A @ U^T on re/im planes
    (the transpose folds into the contraction).

    ``bmap`` (when set) is a compile-time index-bit permutation applied to
    U's rows and columns — gates whose qubit axes sit minor-most but in a
    different bit order (a CX stored target-first, say) run without any
    group transpose by permuting the tiny K x K operand instead.
    """

    idx: int
    k: int
    bmap: tuple[int, ...] | None = None


@dataclass(frozen=True)
class MidGemmOp:
    """Apply dense unitary ``mats[idx]`` to a *contiguous* axis block that
    is not minor-most — C[o] = U @ A[o] over (outer, K, inner) planes —
    so gates whose qubit axes already sit together (QFT's recurring
    top-qubit unitaries live at the *major* end) apply with zero
    transposes.  ``bmap`` as in :class:`GemmOp`."""

    idx: int
    k: int
    outer: int
    inner: int
    bmap: tuple[int, ...] | None = None


@dataclass(frozen=True)
class DiagOp:
    """Elementwise multiply by diagonal ``mats[idx]`` ((2, K) planes) in
    the *current* layout — never a transpose.

    When the gate's axes are contiguous in the layout, ``block`` holds
    ``(p, dmap)``: reshape to (outer, K, inner), select diagonal entries
    through the compile-time bit permutation ``dmap`` (identity = None),
    and broadcast along clean axes.  Otherwise ``shape``/``dperm``
    describe the general nv-axis broadcast of the (2,)*k diagonal tensor.
    ``minor`` marks the layout where the gate qubits are already
    minor-most in standard order, so the Pallas ``diag_apply`` row kernel
    applies directly.
    """

    idx: int
    k: int
    minor: bool
    block: tuple[int, tuple[int, ...] | None] | None
    shape: tuple[int, ...]
    dperm: tuple[int, ...]


@dataclass(frozen=True)
class StageSchedule:
    """Compiled op list for one stage + its transpose accounting.

    ``n_transposes`` counts the full-group transposes the schedule
    executes per group; ``n_transposes_naive`` counts what the per-gate
    path would execute for the same plan (a forward + inverse pair per
    gate whose qubits are not already minor-most).
    """

    nv: int
    ops: tuple
    n_transposes: int
    n_transposes_naive: int


def gate_perm(vqubits: tuple[int, ...], nv: int) -> tuple[int, ...]:
    """The per-gate path's canonical transpose: gate axes minor-most
    (qubit 0's axis last), remaining axes ascending."""
    axes = [nv - 1 - q for q in vqubits]
    rest = [a for a in range(nv) if a not in axes]
    return tuple(rest + [axes[j] for j in range(len(axes) - 1, -1, -1)])


def _contiguous_block(vqubits: tuple[int, ...], nv: int,
                      layout: tuple[int, ...]):
    """``(p, bmap)`` if the gate's axes occupy one contiguous run of the
    layout (any bit order), else None.  ``bmap`` is the compile-time
    K-index bit permutation matching the run's actual order (None =
    already canonical)."""
    k = len(vqubits)
    pos = sorted(layout.index(nv - 1 - q) for q in vqubits)
    if pos != list(range(pos[0], pos[0] + k)):
        return None
    p = pos[0]
    sub = layout[p:p + k]
    wbits = [nv - 1 - sub[k - 1 - j] for j in range(k)]  # qubit on bit j
    if wbits == list(vqubits):
        return p, None
    bmap = tuple(
        sum((((r >> j) & 1) << vqubits.index(wbits[j])) for j in range(k))
        for r in range(1 << k))
    return p, bmap


def _diag_op(idx: int, vqubits: tuple[int, ...], nv: int,
             layout: tuple[int, ...]) -> DiagOp:
    k = len(vqubits)
    axes = [nv - 1 - q for q in vqubits]          # canonical axis of bit j
    pos = [layout.index(a) for a in axes]         # its current position
    minor = pos == [nv - 1 - j for j in range(k)]
    block = _contiguous_block(vqubits, nv, layout)
    # general scattered-axis broadcast fallback
    order = sorted(range(k), key=lambda j: pos[j])
    dperm = tuple(k - 1 - j for j in order)
    shape = [1] * nv
    for p in pos:
        shape[p] = 2
    return DiagOp(idx, k, minor, block, tuple(shape), dperm)


@lru_cache(maxsize=1024)
def compile_schedule(plan: tuple[tuple[tuple[int, ...], bool], ...],
                     nv: int) -> StageSchedule:
    """Compile a stage plan into a transpose-minimizing op sequence.

    Args:
        plan: per fused gate, ``(vqubits, is_diagonal)`` — the same tuple
            the engine caches its stage functions on.
        nv: virtual bits of the group array (b + m).
    """
    ident = tuple(range(nv))
    layout: tuple[int, ...] = ident        # position a holds canonical axis
    ops: list = []
    n_transposes = 0
    n_naive = 0
    for idx, (vqubits, diag) in enumerate(plan):
        if gate_perm(vqubits, nv) != ident:
            n_naive += 2                   # per-gate forward + inverse pair
        if diag:
            ops.append(_diag_op(idx, vqubits, nv, layout))
            continue
        k = len(vqubits)
        tail = [nv - 1 - q for q in reversed(vqubits)]
        # gate axes already contiguous in the current layout (any bit
        # order) -> no group transpose: a bit-order mismatch permutes the
        # tiny K x K operand instead, then minor-most runs as A @ U^T and
        # anywhere else as the batched middle contraction U @ A[o]
        block = _contiguous_block(vqubits, nv, layout)
        if block is not None:
            p, bmap = block
            if p == nv - k:
                ops.append(GemmOp(idx, k, bmap=bmap))
            else:
                ops.append(MidGemmOp(idx, k, outer=1 << p,
                                     inner=1 << (nv - p - k), bmap=bmap))
            continue
        head = [a for a in layout if a not in set(tail)]
        target = tuple(head + tail)
        ops.append(TransposeOp(tuple(layout.index(a) for a in target)))
        n_transposes += 1
        layout = target
        ops.append(GemmOp(idx, k))
    if layout != ident:
        ops.append(TransposeOp(tuple(layout.index(a) for a in ident)))
        n_transposes += 1
    return StageSchedule(nv=nv, ops=tuple(ops), n_transposes=n_transposes,
                         n_transposes_naive=n_naive)


def _op_mat(mat, bmap: tuple[int, ...] | None):
    """(2, K, K) stacked U planes -> (br, bi), bit-permuted when needed."""
    br, bi = mat[0], mat[1]
    if bmap is not None:
        idx = jnp.asarray(bmap)
        br = br[idx][:, idx]
        bi = bi[idx][:, idx]
    return br, bi


def execute_schedule(sched: StageSchedule, planes, mats, *,
                     use_kernel: bool, interpret: bool = True):
    """Run a compiled schedule over a (2, 2^nv) f32 plane stack.

    ``mats[i]`` is gate i's operand in plane form: ``(2, K, K)`` stacked
    re/im of U for dense gates (each op folds its own transpose into the
    contraction), ``(2, K)`` stacked re/im of the diagonal for diagonal
    gates.  Traced under jit by the engine; ``use_kernel`` selects the
    Pallas kernels over plain XLA contractions.
    """
    nv = sched.nv
    shape = (2,) * nv
    ar = planes[0].reshape(shape)
    ai = planes[1].reshape(shape)
    for op in sched.ops:
        if isinstance(op, TransposeOp):
            ar = ar.transpose(op.perm)
            ai = ai.transpose(op.perm)
        elif isinstance(op, GemmOp):
            K = 1 << op.k
            br, bi = _op_mat(mats[op.idx], op.bmap)
            br, bi = br.T, bi.T                              # U -> U^T
            a2r, a2i = ar.reshape(-1, K), ai.reshape(-1, K)
            if use_kernel:
                from ..kernels.gate_apply import gemm_planes
                cr, ci = gemm_planes(a2r, a2i, br, bi, interpret=interpret)
            else:
                cr = a2r @ br - a2i @ bi
                ci = a2r @ bi + a2i @ br
            ar, ai = cr.reshape(shape), ci.reshape(shape)
        elif isinstance(op, MidGemmOp):
            K = 1 << op.k
            br, bi = _op_mat(mats[op.idx], op.bmap)
            a3r = ar.reshape(op.outer, K, op.inner)
            a3i = ai.reshape(op.outer, K, op.inner)
            if use_kernel and op.inner >= 128:
                # wide inner axis: lanes stay dense, MXU-shaped kernel
                from ..kernels.gate_apply import gemm_planes_mid
                cr, ci = gemm_planes_mid(a3r, a3i, br, bi,
                                         interpret=interpret)
            else:
                # narrow inner would degenerate the kernel grid — let the
                # compiler batch the contraction instead
                e = lambda b, a: jnp.einsum("jk,oki->oji", b, a)
                cr = e(br, a3r) - e(bi, a3i)
                ci = e(br, a3i) + e(bi, a3r)
            ar, ai = cr.reshape(shape), ci.reshape(shape)
        else:                                   # DiagOp
            dr, di = mats[op.idx][0], mats[op.idx][1]
            K = 1 << op.k
            if use_kernel and op.minor and K >= 128:
                # full-lane diagonal: the VPU row kernel is worth the call;
                # narrower diagonals fuse better as plain broadcasts
                from ..kernels.gate_apply import diag_apply
                cr, ci = diag_apply(ar.reshape(-1, K), ai.reshape(-1, K),
                                    dr, di, interpret=interpret)
                ar, ai = cr.reshape(shape), ci.reshape(shape)
            elif op.block is not None:
                # contiguous axes: reshape + clean-axis broadcast of the
                # (bit-permuted) K-entry diagonal
                p, dmap = op.block
                if dmap is not None:
                    sel = jnp.asarray(dmap)
                    dr, di = dr[sel], di[sel]
                if p == nv - op.k:
                    a2r, a2i = ar.reshape(-1, K), ai.reshape(-1, K)
                    dr, di = dr[None, :], di[None, :]
                else:
                    inner = 1 << (nv - p - op.k)
                    a2r = ar.reshape(-1, K, inner)
                    a2i = ai.reshape(-1, K, inner)
                    dr, di = dr[None, :, None], di[None, :, None]
                cr = a2r * dr - a2i * di
                ci = a2r * di + a2i * dr
                ar, ai = cr.reshape(shape), ci.reshape(shape)
            else:
                # scattered axes: general nv-axis broadcast
                d2 = (2,) * op.k
                dr = dr.reshape(d2).transpose(op.dperm).reshape(op.shape)
                di = di.reshape(d2).transpose(op.dperm).reshape(op.shape)
                ar, ai = ar * dr - ai * di, ar * di + ai * dr
    return jnp.stack([ar.reshape(-1), ai.reshape(-1)])


def _op_mat_batch(mat, bmap: tuple[int, ...] | None):
    """(L, 2, K, K) stacked per-lane U planes -> (br, bi) of shape
    (L, K, K), bit-permuted when needed."""
    br, bi = mat[:, 0], mat[:, 1]
    if bmap is not None:
        idx = jnp.asarray(bmap)
        br = br[:, idx][:, :, idx]
        bi = bi[:, idx][:, :, idx]
    return br, bi


def execute_schedule_batched(sched: StageSchedule, planes, mats, *,
                             use_kernel: bool, interpret: bool = True):
    """Run a compiled schedule over an (L, 2, 2^nv) f32 plane stack.

    The lane-batched sibling of :func:`execute_schedule`: every operand
    and the plane stack carry one extra leading lane axis — ``mats[i]``
    is ``(L, 2, K, K)`` dense / ``(L, 2, K)`` diagonal — and lane ``l``'s
    unitaries apply to lane ``l``'s planes.  The whole L-lane batch
    (parameter-sweep bindings or noise trajectories) traces into ONE
    jitted call per stage, which is where the dispatch-bound speedup of
    ``Simulator.run_batch`` comes from.
    """
    nv = sched.nv
    lanes = planes.shape[0]
    shape = (lanes,) + (2,) * nv
    ar = planes[:, 0].reshape(shape)
    ai = planes[:, 1].reshape(shape)
    for op in sched.ops:
        if isinstance(op, TransposeOp):
            perm = (0,) + tuple(a + 1 for a in op.perm)
            ar = ar.transpose(perm)
            ai = ai.transpose(perm)
        elif isinstance(op, GemmOp):
            K = 1 << op.k
            br, bi = _op_mat_batch(mats[op.idx], op.bmap)
            br, bi = br.swapaxes(1, 2), bi.swapaxes(1, 2)     # U -> U^T
            a2r, a2i = ar.reshape(lanes, -1, K), ai.reshape(lanes, -1, K)
            if use_kernel:
                from ..kernels.gate_apply import gemm_planes_batch
                cr, ci = gemm_planes_batch(a2r, a2i, br, bi,
                                           interpret=interpret)
            else:
                cr = a2r @ br - a2i @ bi                      # lane-batched
                ci = a2r @ bi + a2i @ br
            ar, ai = cr.reshape(shape), ci.reshape(shape)
        elif isinstance(op, MidGemmOp):
            K = 1 << op.k
            br, bi = _op_mat_batch(mats[op.idx], op.bmap)
            a3r = ar.reshape(lanes, op.outer, K, op.inner)
            a3i = ai.reshape(lanes, op.outer, K, op.inner)
            # the batched middle contraction stays an einsum: the lane
            # axis already amortizes dispatch, and XLA batches it fine
            def e(b, a):
                return jnp.einsum("ljk,loki->loji", b, a)
            cr = e(br, a3r) - e(bi, a3i)
            ci = e(br, a3i) + e(bi, a3r)
            ar, ai = cr.reshape(shape), ci.reshape(shape)
        else:                                   # DiagOp
            dr, di = mats[op.idx][:, 0], mats[op.idx][:, 1]   # (L, K)
            K = 1 << op.k
            if op.block is not None:
                p, dmap = op.block
                if dmap is not None:
                    sel = jnp.asarray(dmap)
                    dr, di = dr[:, sel], di[:, sel]
                if p == nv - op.k:
                    a2r = ar.reshape(lanes, -1, K)
                    a2i = ai.reshape(lanes, -1, K)
                    db_r, db_i = dr[:, None, :], di[:, None, :]
                else:
                    inner = 1 << (nv - p - op.k)
                    a2r = ar.reshape(lanes, -1, K, inner)
                    a2i = ai.reshape(lanes, -1, K, inner)
                    db_r, db_i = dr[:, None, :, None], di[:, None, :, None]
                cr = a2r * db_r - a2i * db_i
                ci = a2r * db_i + a2i * db_r
                ar, ai = cr.reshape(shape), ci.reshape(shape)
            else:
                d2 = (2,) * op.k
                perm = (0,) + tuple(a + 1 for a in op.dperm)
                dshape = (lanes,) + op.shape
                dr = dr.reshape((lanes,) + d2).transpose(perm).reshape(dshape)
                di = di.reshape((lanes,) + d2).transpose(perm).reshape(dshape)
                ar, ai = ar * dr - ai * di, ar * di + ai * dr
    return jnp.stack([ar.reshape(lanes, -1), ai.reshape(lanes, -1)], axis=1)
