"""ExecutionPlan: the ahead-of-time compilation artifact of a simulation.

The paper's fourth challenge — unpredictable memory requirements under
variable-ratio compression — used to be handled only *reactively* (the
two-level store spills after the fact), and everything the engine decided
per run (partition, per-stage schedules, stage-fn keys, device placement)
was rebuilt inside ``run()``.  This module makes planning a first-class
phase: an :class:`ExecutionPlan` is the inspectable, hashable,
serializable record of every compile-time decision, produced by
:mod:`repro.core.planner` (which also *chooses* the knobs under a memory
budget) and executed verbatim by :class:`~repro.core.engine.BMQSimEngine`.

Per stage, a :class:`StagePlan` freezes:

* the :class:`~repro.core.groups.GroupLayout` (inner set, group table),
* the fused-gate plan ``((vqubits, is_diagonal), ...)`` — the structural
  tuple the engine keys its jitted stage functions on,
* the precompiled transpose-minimizing :class:`StageSchedule` counts,
* the stage-fn cache key (so a warm process compiles nothing at run time),
* the operand slots (``gate_slice`` into the circuit's gate list) that a
  parameter binding fills with concrete matrices, and
* the round-robin device placement of its groups.

Whole-plan :class:`PlanPredictions` estimate the peak compressed working
set, per-stage boundary traffic and transpose counts before anything
executes; ``plan.describe()`` renders the whole artifact (the
``qsim --explain`` output).

The plan's :attr:`~ExecutionPlan.fingerprint` covers exactly the
*state-layout* decisions — circuit structure, ``(local_bits, inner_size)``,
codec parameters, and the stage partition — the things that must match for
a checkpointed compressed state to be continuable.  It is stamped into
every checkpoint manifest; :meth:`Simulator.resume` rejects mismatches.
Execution-only knobs (backend, kernels, pipeline depth, devices) do not
affect the fingerprint.
"""
from __future__ import annotations

import hashlib
import json
from dataclasses import dataclass

from .groups import GroupLayout

__all__ = ["StagePlan", "PlanPredictions", "ExecutionPlan",
           "circuit_fingerprint", "plan_fingerprint"]


def circuit_fingerprint(circuit) -> str:
    """Structural hash of a circuit template (gate names, qubits, params —
    :class:`Parameter` placeholders hash by name, so one template yields
    one fingerprint across bindings)."""
    h = hashlib.sha1()
    h.update(str(circuit.n_qubits).encode())
    for g in circuit.gates:
        h.update(g.name.encode())
        h.update(repr(g.qubits).encode())
        h.update(repr(g.params).encode())
    return h.hexdigest()


def plan_fingerprint(circuit_fp: str, n_qubits: int, local_bits: int,
                     inner_size: int, b_r: float, compression: bool,
                     prescan: bool,
                     stage_shape: list[tuple[tuple[int, ...], int]]) -> str:
    """Fingerprint of the state-layout half of a plan.

    ``stage_shape`` is ``[(inner set, n_gates), ...]`` per stage.  The
    same function serves :attr:`ExecutionPlan.fingerprint` and the
    engine's binding-free ``plan_fingerprint()`` (checkpoint manifests),
    so the two can never drift apart.
    """
    h = hashlib.sha1()
    h.update(circuit_fp.encode())
    h.update(repr((n_qubits, local_bits, inner_size, float(b_r),
                   bool(compression), bool(prescan))).encode())
    for inner, n_gates in stage_shape:
        h.update(repr((tuple(inner), int(n_gates))).encode())
    return h.hexdigest()


@dataclass(frozen=True)
class StagePlan:
    """One stage's frozen compile-time record (see module docs).

    ``gate_slice`` is the stage's operand slot range into
    ``circuit.gates`` — the partition assigns contiguous runs, and a
    parameter binding fills exactly these slots with concrete matrices.
    ``stagefn_key`` is the full cache key of the jitted stage function
    (structure + execution flags); two stages with equal keys share one
    compilation.  Group ``g`` executes on device ``g % n_devices``
    (:meth:`device_slot`).
    """

    index: int
    layout: GroupLayout
    gate_slice: tuple[int, int]
    plan: tuple                      # ((vqubits, is_diagonal), ...) fused
    stagefn_key: tuple
    n_devices: int
    n_transposes: int                # per group, compiled schedule
    n_transposes_naive: int          # per group, per-gate path
    est_h2d_bytes: int               # predicted boundary traffic, whole stage
    est_d2h_bytes: int

    @property
    def nv(self) -> int:
        return self.layout.b + self.layout.m

    @property
    def n_groups(self) -> int:
        return self.layout.n_groups

    @property
    def n_fused(self) -> int:
        return len(self.plan)

    def device_slot(self, group: int) -> int:
        """Round-robin placement: group ``g`` runs on this device index."""
        return group % self.n_devices


@dataclass(frozen=True)
class PlanPredictions:
    """Whole-plan cost-model outputs (estimates, not measurements).

    ``bytes_per_amp`` is the estimated *stored* compressed size; the
    engine calibrates it against the first encoded stage at run time
    (``SimStats.bytes_per_amp_measured``), with the two-level store's RAM
    budget as the backstop when the estimate was optimistic.
    ``peak_ram_bytes`` predicts the store's primary-tier peak;
    ``pipeline_bytes`` the host-side staging working set on top of it.
    """

    bytes_per_amp: float
    state_bytes: int
    peak_ram_bytes: int
    pipeline_bytes: int
    boundary_bytes: int              # total h2d + d2h across stages
    n_transposes: int                # group-weighted, compiled schedules
    n_transposes_naive: int
    #: predicted whole-run speedup of the plan's pipeline_depth over the
    #: strictly sequential depth-1 schedule (the planner's overlap model,
    #: :func:`repro.core.planner.predict_depth_speedup`); 1.0 at depth 1
    depth_speedup: float = 1.0
    #: predicted working set of the BUSIEST device of the mesh (store
    #: peak + staging for its share of lanes/blocks).  The budget-facing
    #: quantity of a sharded plan — ``memory_budget_bytes`` is per
    #: device, and chunking engages only when this (not the mesh-wide
    #: total) overflows it.  Equals ``working_set_bytes`` at n_devices=1;
    #: 0 only in pre-v9 plan dumps (from_json backfills it).
    per_device_peak_bytes: int = 0

    @property
    def working_set_bytes(self) -> int:
        """Budget-facing total: store peak + pipeline staging."""
        return self.peak_ram_bytes + self.pipeline_bytes


@dataclass(frozen=True)
class ExecutionPlan:
    """The full ahead-of-time compilation artifact (see module docs)."""

    circuit_fp: str
    n_qubits: int
    local_bits: int
    inner_size: int
    pipeline_depth: int
    b_r: float
    compression: bool
    prescan: bool
    codec_backend: str
    use_kernel: bool
    gate_schedule: bool
    max_fused_qubits: int
    interpret: bool
    n_devices: int
    memory_budget_bytes: int | None
    auto_tuned: bool
    params_key: tuple
    stages: tuple[StagePlan, ...]
    predicted: PlanPredictions
    #: batch factor K the plan was provisioned for: predictions (peak
    #: working set, boundary traffic) assume K lanes flow through every
    #: stage together (Simulator.run_batch / noise trajectories); does
    #: not affect the state-layout fingerprint — each lane's blocks are
    #: laid out exactly like a single-lane run's
    batch: int = 1

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    @property
    def fingerprint(self) -> str:
        """State-layout fingerprint (stamped into checkpoint manifests)."""
        return plan_fingerprint(
            self.circuit_fp, self.n_qubits, self.local_bits,
            self.inner_size, self.b_r, self.compression, self.prescan,
            [(sp.layout.inner, sp.gate_slice[1] - sp.gate_slice[0])
             for sp in self.stages])

    # -- rendering -------------------------------------------------------------
    def describe(self, max_stages: int = 24) -> str:
        """Human-readable rendering (the ``qsim --explain`` output)."""
        p = self.predicted
        mib = 2.0 ** 20
        lines = [
            f"ExecutionPlan  n={self.n_qubits}  fingerprint "
            f"{self.fingerprint[:12]}",
            f"  knobs     : local_bits={self.local_bits}"
            f"{' (auto)' if self.auto_tuned else ''} "
            f"inner_size={self.inner_size} "
            f"pipeline_depth={self.pipeline_depth} b_r={self.b_r:g} "
            f"max_fused={self.max_fused_qubits}"
            + (f" batch={self.batch}" if self.batch > 1 else ""),
            f"  codec     : backend={self.codec_backend} "
            f"compression={'on' if self.compression else 'off'} "
            f"prescan={'on' if self.prescan else 'off'}",
            f"  execution : use_kernel={self.use_kernel} "
            f"gate_schedule={self.gate_schedule} "
            f"devices={self.n_devices} (groups round-robin)",
            "  budget    : "
            + (f"{self.memory_budget_bytes / mib:.1f} MiB"
               if self.memory_budget_bytes else "none"),
            f"  predicted : stored {p.bytes_per_amp:.2f} B/amp "
            f"(state {p.state_bytes / mib:.2f} MiB); "
            f"peak RAM {p.peak_ram_bytes / mib:.2f} MiB "
            f"+ pipeline {p.pipeline_bytes / mib:.2f} MiB",
            f"  predicted : boundary {p.boundary_bytes / mib:.2f} MiB "
            f"over {self.n_stages} stages; group transposes "
            f"{p.n_transposes} scheduled vs {p.n_transposes_naive} per-gate",
            f"  predicted : pipeline depth {self.pipeline_depth} overlap "
            f"speedup {p.depth_speedup:.2f}x vs sequential",
        ]
        if self.n_devices > 1:
            lines.insert(6, (
                f"  predicted : per-device peak "
                f"{p.per_device_peak_bytes / mib:.2f} MiB across "
                f"{self.n_devices} devices (budget is per device)"))
        for sp in self.stages[:max_stages]:
            lo, hi = sp.gate_slice
            inner = ",".join(map(str, sp.layout.inner)) or "-"
            lines.append(
                f"  stage {sp.index:3d}: inner={{{inner}}} "
                f"{sp.n_groups} groups x {sp.layout.blocks_per_group} blk "
                f"(nv={sp.nv})  gates[{lo}:{hi}] -> {sp.n_fused} fused, "
                f"{sp.n_transposes} transposes, "
                f"h2d {sp.est_h2d_bytes / mib:.2f} MiB")
        if self.n_stages > max_stages:
            lines.append(f"  ... {self.n_stages - max_stages} more stages")
        return "\n".join(lines)

    # -- serialization ---------------------------------------------------------
    def to_json(self) -> str:
        d = {
            "kind": "bmqsim-execution-plan", "version": 1,
            "circuit_fp": self.circuit_fp, "n_qubits": self.n_qubits,
            "local_bits": self.local_bits, "inner_size": self.inner_size,
            "pipeline_depth": self.pipeline_depth, "b_r": self.b_r,
            "compression": self.compression, "prescan": self.prescan,
            "codec_backend": self.codec_backend,
            "use_kernel": self.use_kernel,
            "gate_schedule": self.gate_schedule,
            "max_fused_qubits": self.max_fused_qubits,
            "interpret": self.interpret,
            "n_devices": self.n_devices,
            "memory_budget_bytes": self.memory_budget_bytes,
            "auto_tuned": self.auto_tuned,
            "batch": self.batch,
            "params_key": list(list(kv) for kv in self.params_key),
            "predicted": {
                "bytes_per_amp": self.predicted.bytes_per_amp,
                "state_bytes": self.predicted.state_bytes,
                "peak_ram_bytes": self.predicted.peak_ram_bytes,
                "pipeline_bytes": self.predicted.pipeline_bytes,
                "boundary_bytes": self.predicted.boundary_bytes,
                "n_transposes": self.predicted.n_transposes,
                "n_transposes_naive": self.predicted.n_transposes_naive,
                "depth_speedup": self.predicted.depth_speedup,
                "per_device_peak_bytes":
                    self.predicted.per_device_peak_bytes,
            },
            "stages": [{
                "index": sp.index,
                "inner": list(sp.layout.inner),
                "gate_slice": list(sp.gate_slice),
                "plan": [[list(vq), diag] for vq, diag in sp.plan],
                "n_transposes": sp.n_transposes,
                "n_transposes_naive": sp.n_transposes_naive,
                "est_h2d_bytes": sp.est_h2d_bytes,
                "est_d2h_bytes": sp.est_d2h_bytes,
            } for sp in self.stages],
        }
        return json.dumps(d, sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "ExecutionPlan":
        d = json.loads(s)
        if d.get("kind") != "bmqsim-execution-plan":
            raise ValueError("not a serialized ExecutionPlan")
        n, b = d["n_qubits"], d["local_bits"]
        stages: list[StagePlan] = []
        for sd in d["stages"]:
            plan = tuple((tuple(vq), bool(diag)) for vq, diag in sd["plan"])
            layout = GroupLayout(n, b, tuple(sd["inner"]))
            key = (plan, layout.b + layout.m, d["use_kernel"],
                   d["gate_schedule"], d["interpret"])
            stages.append(StagePlan(
                index=sd["index"], layout=layout,
                gate_slice=tuple(sd["gate_slice"]), plan=plan,
                stagefn_key=key, n_devices=d["n_devices"],
                n_transposes=sd["n_transposes"],
                n_transposes_naive=sd["n_transposes_naive"],
                est_h2d_bytes=sd["est_h2d_bytes"],
                est_d2h_bytes=sd["est_d2h_bytes"]))
        pd = dict(d["predicted"])
        pd.setdefault("depth_speedup", 1.0)   # pre-v6 plan dumps
        # pre-v9 dumps predate sharded placement: one device held it all
        pd.setdefault("per_device_peak_bytes",
                      pd["peak_ram_bytes"] + pd["pipeline_bytes"])
        return cls(
            circuit_fp=d["circuit_fp"], n_qubits=n, local_bits=b,
            inner_size=d["inner_size"], pipeline_depth=d["pipeline_depth"],
            b_r=d["b_r"], compression=d["compression"],
            prescan=d["prescan"], codec_backend=d["codec_backend"],
            use_kernel=d["use_kernel"], gate_schedule=d["gate_schedule"],
            max_fused_qubits=d["max_fused_qubits"],
            interpret=d["interpret"], n_devices=d["n_devices"],
            memory_budget_bytes=d["memory_budget_bytes"],
            auto_tuned=d["auto_tuned"], batch=d.get("batch", 1),
            params_key=tuple(tuple(kv) for kv in d["params_key"]),
            stages=tuple(stages), predicted=PlanPredictions(**pd))
