"""Gate fusion: collapse runs of gates into <= f-qubit unitaries.

TPU adaptation (DESIGN.md §2): instead of SV-Sim's scattered
thread-per-pair updates, a stage's gates are greedily fused into dense
``2^f x 2^f`` unitaries.  With f = 7 the unitary is 128 x 128 — exactly
one MXU tile — and applying it to a group becomes a plain GEMM over the
(transposed) group tensor, which is what ``kernels/gate_apply.py`` runs.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .circuit import Gate

__all__ = ["FusedGate", "fuse_gates", "embed_unitary", "gates_to_unitary"]


@dataclass(frozen=True)
class FusedGate:
    """A fused unitary on ``qubits`` (ascending; qubits[j] = matrix bit j)."""

    qubits: tuple[int, ...]
    matrix: np.ndarray  # (2^k, 2^k) complex128

    @property
    def k(self) -> int:
        return len(self.qubits)

    @property
    def is_diagonal(self) -> bool:
        off = self.matrix - np.diag(np.diag(self.matrix))
        return bool(np.allclose(off, 0.0, atol=1e-12))


def _apply_on_rows(unitary: np.ndarray, mat: np.ndarray,
                   pos: list[int], k: int) -> np.ndarray:
    """Left-multiply ``mat`` acting on bits ``pos`` of the ROW index of a
    (2^k, C) array (C arbitrary columns)."""
    kk = len(pos)
    cols = unitary.shape[1]
    t = unitary.reshape((2,) * k + (cols,))
    axes = [k - 1 - p for p in pos]              # tensor axis of each bit
    rest = [a for a in range(k) if a not in axes]
    perm = rest + [axes[j] for j in range(kk - 1, -1, -1)] + [k]
    t = t.transpose(perm).reshape(-1, 2 ** kk, cols)
    t = np.einsum("ij,ajc->aic", mat, t)
    inv = np.argsort(np.asarray(perm))
    return t.reshape([2] * k + [cols]).transpose(list(inv)).reshape(2 ** k, cols)


def embed_unitary(mat: np.ndarray, gate_qubits: tuple[int, ...],
                  union_qubits: tuple[int, ...]) -> np.ndarray:
    """Embed a gate unitary into the space of ``union_qubits`` (with
    identity on the extra qubits)."""
    k = len(union_qubits)
    pos = [union_qubits.index(q) for q in gate_qubits]
    return _apply_on_rows(np.eye(2 ** k, dtype=np.complex128), mat, pos, k)


def gates_to_unitary(gates: list[Gate],
                     union_qubits: tuple[int, ...]) -> np.ndarray:
    """Product of a gate run as one unitary over ``union_qubits``."""
    k = len(union_qubits)
    u = np.eye(2 ** k, dtype=np.complex128)
    for g in gates:
        pos = [union_qubits.index(q) for q in g.qubits]
        u = _apply_on_rows(u, g.matrix, pos, k)
    return u


def fuse_gates(gates: list[Gate], max_fused_qubits: int = 7) -> list[FusedGate]:
    """Greedy in-order fusion: grow a run while the union support stays
    within ``max_fused_qubits``; flush into one dense unitary otherwise."""
    fused: list[FusedGate] = []
    run: list[Gate] = []
    support: set[int] = set()

    def flush() -> None:
        nonlocal run, support
        if not run:
            return
        union = tuple(sorted(support))
        fused.append(FusedGate(union, gates_to_unitary(run, union)))
        run, support = [], set()

    for g in gates:
        new_support = support | g.support
        if len(new_support) > max_fused_qubits and run:
            flush()
            new_support = set(g.support)
        if len(new_support) > max_fused_qubits:
            raise ValueError(
                f"gate {g.name} spans {len(g.support)} qubits > fusion limit"
            )
        run.append(g)
        support = new_support
    flush()
    return fused
