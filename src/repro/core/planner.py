"""Planner: budget-driven auto-tuning of the simulation knobs (§4.4).

The knobs that decide everything about a run — ``local_bits`` (SV block
size), ``inner_size`` (Algorithm 1's stage threshold) and
``pipeline_depth`` — were hand-picked constants.  This module chooses
them with a cost model under a user-supplied ``memory_budget_bytes``:

    cost      minimize stage count (one decompress/recompress sweep of
              the whole state each), then group-weighted transposes,
              then prefer larger blocks (bigger GEMMs, fewer boundary
              round trips)
    subject   predicted store peak + pipeline staging working set fits
              the budget

The compression ratio is *estimated* from ``b_r`` (a conservative
entropy-style model of the pwrel code stream — see
:func:`estimate_bytes_per_amp`); the engine *calibrates* the estimate
against the first encoded stage at run time
(``SimStats.bytes_per_amp_measured``), and the resolved config always
carries the budget into the two-level store's ``ram_budget_bytes`` as
the backstop, so a mispredicted ratio spills to disk instead of
aborting — the store guarantees ``peak_ram_bytes <= budget`` even when
the model is wrong.

Entry points:

* :func:`resolve_config` — concrete :class:`EngineConfig` from one with
  ``local_bits=None`` ("auto"); runs the search when a budget is set,
  falls back to a documented heuristic otherwise.
* :func:`fuse_stage` — the one place a stage's gates become the
  structural fused plan the engine keys its caches on (shared with
  :meth:`BMQSimEngine._bind_stages` so planner and executor can't drift).
* :func:`assemble_plan` — freeze a bound engine state into an
  :class:`~repro.core.plan.ExecutionPlan` with predictions.
"""
from __future__ import annotations

import math
import warnings
from dataclasses import dataclass, replace

from .fusion import FusedGate, fuse_gates
from .groups import GroupLayout
from .partition import partition_circuit
from .plan import ExecutionPlan, PlanPredictions, StagePlan
from .schedule import compile_schedule

__all__ = ["DEFAULT_INNER_SIZE", "DEFAULT_PIPELINE_DEPTH",
           "PipelineCalibration", "DEFAULT_CALIBRATION",
           "predict_depth_speedup",
           "estimate_bytes_per_amp", "wire_bytes_per_block",
           "resolve_config", "fuse_stage", "fuse_stage_lanes",
           "max_feasible_lanes", "peak_ram_for", "assemble_plan"]

DEFAULT_INNER_SIZE = 2
DEFAULT_PIPELINE_DEPTH = 2

#: auto search never proposes blocks above 2^20 amplitudes (group arrays
#: must stay jit-traceable and cache-friendly even with inner_size added)
MAX_AUTO_LOCAL_BITS = 20

#: per-block constant overhead in the store (headers, dict slots)
_BLOCK_OVERHEAD = 64

#: inner-size candidates the search sweeps (partition clamps below 2)
_INNER_CANDIDATES = (2, 3, 4)

#: log2 dynamic range the code stream is assumed to span (typical SV
#: blocks concentrate within ~2^40 of their max; wider tails quantize to
#: the exact-zero escape and compress away)
_SPAN_LOG2 = 40.0


@dataclass(frozen=True)
class PipelineCalibration:
    """Measured (or assumed) per-group phase costs of the stage pipeline
    — the inputs of :func:`predict_depth_speedup`.

    The four timings are *per group-phase* (any consistent unit — only
    ratios matter): ``t_load`` host fetch/decode, ``t_compute`` the
    H2D-staging + compute + encode *dispatch* cost, ``t_fetch`` the
    blocking device→host await, ``t_store`` host encode/store.  The
    engine records them into ``SimStats`` and hands them back via
    :meth:`SimStats.pipeline_calibration`.

    ``measured`` optionally pins whole-depth speedups observed on the
    target machine (``((depth, speedup), ...)``, e.g. transcribed from a
    benchmark dump): a measurement always beats the model, so a recorded
    losing profile can never be re-chosen by the auto-tuner.
    """

    t_load: float
    t_compute: float
    t_fetch: float
    t_store: float
    measured: tuple = ()

    def measured_speedup(self, depth: int) -> float | None:
        for d, s in self.measured:
            if d == depth:
                return s
        return None


#: default profile of the scheduled planes path on the dev box (BENCH_6
#: shape): the per-group cost is dominated by the host codec halves and
#: the per-dispatch overhead; the blocking await is short because the
#: device compute drains while the host codec works
DEFAULT_CALIBRATION = PipelineCalibration(
    t_load=1.0, t_compute=0.45, t_fetch=0.1, t_store=0.9)

#: fractional growth of the blocking await per coalesced wave: a wave of
#: d groups awaits one d-times-larger result, which is not entirely free
_WAVE_TAX = 0.25


def predict_depth_speedup(depth: int,
                          calibration: PipelineCalibration | None = None
                          ) -> float:
    """Predicted whole-run speedup of ``pipeline_depth=depth`` over the
    strictly sequential ``depth=1`` schedule.

    Model of the wave-coalesced pipeline (core/pipeline.py): a wave of
    ``d`` groups pays the host codec per group (``t_load`` + ``t_store``
    do not shrink), ONE compute/encode dispatch for the whole wave
    (``t_compute / d`` per group — the amortization that makes depth
    win on dispatch-bound configs), and a slightly larger blocking
    await (``t_fetch`` grown by ``_WAVE_TAX`` at full coalescing).  No
    parallel-speedup credit is taken for the worker threads — on a
    single-core host there is none to take, so the model stays
    conservative.  A ``calibration.measured`` entry for ``depth``
    overrides the model entirely.
    """
    cal = calibration if calibration is not None else DEFAULT_CALIBRATION
    m = cal.measured_speedup(depth)
    if m is not None:
        return m
    if depth <= 1:
        return 1.0
    serial = cal.t_load + cal.t_compute + cal.t_fetch + cal.t_store
    if serial <= 0:
        return 1.0
    piped = (cal.t_load + cal.t_store + cal.t_compute / depth
             + cal.t_fetch * (1.0 + _WAVE_TAX * (1.0 - 1.0 / depth)))
    return serial / piped


def _auto_depth(cands, calibration) -> int:
    """Deepest candidate whose predicted speedup is >= 1 (depth 1 is
    always admissible — the auto-tuner must never pick a losing depth)."""
    best = 1
    for d in cands:
        if d is None:
            continue
        if d <= 1 or predict_depth_speedup(d, calibration) >= 1.0:
            best = max(best, d)
    return best


def estimate_bytes_per_amp(b_r: float, compression: bool = True) -> float:
    """Conservatively estimated *stored* bytes per complex amplitude.

    Model: each of the two f32 planes stores a uint16 code stream plus a
    1-bit sign bitmap.  The codes span roughly ``_SPAN_LOG2 / step``
    distinct values (``step = 2 log2(1+b_r)``), so an entropy coder needs
    about ``log2(span/step)`` bits each; zlib level 1 is charged ~2 bits
    of slack over that.  The RAW escape caps every block at 8 B/amp —
    compression never inflates — so the estimate is clipped there.
    Deliberately conservative: real SV blocks (concentrated amplitudes,
    repeated signs, the all-zero init) compress better, and a *low*
    estimate is the dangerous direction for a budget guarantee.
    """
    if not compression:
        return 8.0
    step = 2.0 * math.log2(1.0 + b_r)
    span_codes = max(2.0, _SPAN_LOG2 / step)
    code_bits = min(16.0, math.log2(span_codes) + 2.0)
    per_plane = code_bits / 8.0 + 0.125          # codes + sign bitmap
    return min(8.0, 2.0 * per_plane)


def wire_bytes_per_block(bsz: int, codec_backend: str,
                         compression: bool) -> int:
    """Bytes one block moves across the host<->device boundary, one way.

    The device codec ships packed uint16 codes + ballot sign words + an
    ``l_max`` scalar per plane (~4.25 B/amp); the host backend moves raw
    complex64 (8 B/amp).
    """
    if codec_backend == "device" and compression:
        return 2 * (2 * bsz + 4 * math.ceil(bsz / 32) + 4)
    return 8 * bsz


def _predict_working_set(n: int, b: int, max_m: int, depth: int,
                         bpa: float, lanes: int = 1,
                         n_devices: int = 1) -> tuple[int, int]:
    """(store peak, pipeline staging) in bytes for one candidate —
    **per device** of an ``n_devices`` mesh (the whole machine at the
    default ``n_devices=1``).

    Store peak: the whole compressed state plus ``depth + 1`` groups'
    worth of fresh blobs coexisting with the blocks they replace (the
    store binds the new blob before releasing the old).  Pipeline
    staging: the wave scheduler holds, per ``depth``-group wave, up to
    two waves on-device (one computing, one decoded ahead), two
    lookahead waves in the fetch worker, and one in-flight result — ~5
    waves of complex64-sized group arrays at ``depth >= 2``, 3 group
    arrays in the strictly sequential ``depth=1`` schedule.  That is the
    host backend's (larger) footprint, so the bound holds for both
    backends.

    ``lanes`` is the batch factor K: a batched run keeps K compressed
    state copies in the store and stages K-lane group stacks through the
    pipeline, so everything scales linearly with it.

    Sharded placement (``n_devices > 1``) divides what a device holds:
    a batched run shards *lanes* (``ceil(K / D)`` lanes per device — the
    busiest device's share), a single-lane run shards *blocks*
    (``ceil(state / D)``, the device_slot round-robin) while every wave
    still stages one full group.  The busiest-device model is what the
    per-device ``memory_budget_bytes`` compares against.
    """
    lanes = max(1, lanes)
    d = max(1, n_devices)
    n_blocks = 1 << (n - b)
    state_one = int((1 << n) * bpa) + n_blocks * _BLOCK_OVERHEAD
    if d == 1:
        state, staged_lanes = lanes * state_one, lanes
    elif lanes > 1:
        staged_lanes = -(-lanes // d)        # busiest device's lane share
        state = staged_lanes * state_one
    else:
        state, staged_lanes = -(-state_one // d), 1   # block round-robin
    group = 1 << (b + max_m)
    peak_ram = state + (depth + 1) * int(group * bpa) * staged_lanes
    waves = 5 * depth if depth > 1 else 3
    pipeline = waves * group * 8 * staged_lanes
    return peak_ram, pipeline


def max_feasible_lanes(n: int, b: int, max_m: int, depth: int, bpa: float,
                       budget: int, lanes: int, n_devices: int = 1) -> int:
    """Largest sub-batch K' <= ``lanes`` whose predicted batched working
    set fits ``budget`` (>= 1: a single lane always runs, relying on the
    store's spill backstop when even that exceeds the budget).  The
    engine chunks an infeasible ``run_batch`` into sub-batches of this
    size.  ``budget`` is per device: on an ``n_devices`` mesh the lanes
    shard, so chunking engages only when the busiest device's lane share
    overflows — the whole mesh must be exhausted first."""
    for cand in range(max(1, lanes), 1, -1):
        peak, pipe = _predict_working_set(n, b, max_m, depth, bpa, cand,
                                          n_devices)
        if peak + pipe <= budget:
            return cand
    return 1


def peak_ram_for(plan, lanes: int = 1, n_devices: int = 1) -> int:
    """Admission-side predicted peak RAM (store peak + pipeline staging,
    bytes) of executing ``plan`` with ``lanes`` concurrent lanes.

    This is the quantity a multi-tenant scheduler sums against a global
    memory budget (see :class:`repro.core.service.SimService`): it reads
    everything from the frozen :class:`~repro.core.plan.ExecutionPlan`
    artifact — no circuit, partition or engine needed — and uses exactly
    the cost model ``resolve_config`` planned under, so admission
    decisions are consistent with what the planner promised.  The model
    is **linear in** ``lanes`` (state copies, staged group stacks and
    pipeline waves all scale with the lane count), which is what makes
    per-job reservations sum exactly: merging K admitted jobs into one
    lane stack needs precisely the K reservations already held.

    ``n_devices=1`` (the default) prices the whole-host working set —
    the right quantity for a single-host service budget; pass the mesh
    size to price the busiest device's share instead (the
    ``per_device_peak_bytes`` form).
    """
    max_m = max((st.layout.m for st in plan.stages), default=0)
    bpa = estimate_bytes_per_amp(plan.b_r, plan.compression)
    peak, pipe = _predict_working_set(
        plan.n_qubits, plan.local_bits, max_m, plan.pipeline_depth, bpa,
        max(1, lanes), n_devices)
    return peak + pipe


def _default_auto(n: int) -> tuple[int, int, int]:
    """No-budget heuristic: paper-ish blocks of 2^(n-4) (>= 16 blocks, so
    stages and groups exist to pipeline), capped at 2^MAX_AUTO_LOCAL_BITS."""
    b = max(1, min(MAX_AUTO_LOCAL_BITS, n - 4))
    return b, DEFAULT_INNER_SIZE, DEFAULT_PIPELINE_DEPTH


def _transpose_cost(circuit, b: int, m: int, part, max_fused: int) -> int:
    """Tie-break metric: elements moved by full-group transposes across
    the whole run (compiled schedule, group-weighted)."""
    cost = 0
    for st in part.stages:
        layout = GroupLayout(circuit.n_qubits, b, tuple(st.inner))
        _, plan = fuse_stage(layout, st.gates, max_fused)
        if not plan:
            continue
        nv = layout.b + layout.m
        sched = compile_schedule(plan, nv)
        cost += sched.n_transposes * layout.n_groups * (1 << nv)
    return cost


def resolve_config(circuit, config, n_devices: int = 1,
                   calibration: PipelineCalibration | None = None):
    """Concrete engine knobs from a possibly-auto :class:`EngineConfig`.

    Returns ``(resolved_config, auto_tuned, partition)`` — ``partition``
    is the winning candidate's (already computed) stage partition when
    the budget search ran, else ``None`` (the engine partitions itself).
    ``local_bits=None`` triggers the budget search (or the no-budget
    heuristic); ``inner_size``/``pipeline_depth`` left ``None`` resolve
    to their defaults, and ``memory_budget_bytes`` always flows into the
    store's ``ram_budget_bytes`` backstop unless one was given
    explicitly.

    An auto ``pipeline_depth`` consults :func:`predict_depth_speedup`
    under ``calibration`` (default profile when None; pass
    ``SimStats.pipeline_calibration()`` to re-plan from measurements):
    the tuner never selects a depth whose predicted speedup is < 1 — an
    explicitly requested depth is always honored verbatim.

    ``memory_budget_bytes`` is **per device**: on an ``n_devices`` mesh a
    candidate is feasible when the busiest device's predicted share fits,
    and the store's derived ``ram_budget_bytes`` backstop scales to the
    whole mesh (``budget * n_devices`` — the host store holds every
    device's partition), so chunking/spilling engage only when the whole
    mesh is exhausted, not when one device's budget would be.
    """
    budget = config.memory_budget_bytes
    ram_budget = (config.ram_budget_bytes
                  if config.ram_budget_bytes is not None
                  else budget * max(1, n_devices) if budget is not None
                  else None)
    if config.local_bits is not None:
        return replace(
            config,
            inner_size=(config.inner_size if config.inner_size is not None
                        else DEFAULT_INNER_SIZE),
            pipeline_depth=(config.pipeline_depth
                            if config.pipeline_depth is not None
                            else _auto_depth((DEFAULT_PIPELINE_DEPTH, 1),
                                             calibration)),
            ram_budget_bytes=ram_budget), False, None

    n = circuit.n_qubits
    if budget is None:
        b, m, depth = _default_auto(n)
        if config.inner_size is not None:
            m = config.inner_size
        if config.pipeline_depth is not None:
            depth = config.pipeline_depth
        else:
            depth = _auto_depth((depth, 1), calibration)
        return replace(config, local_bits=b, inner_size=m,
                       pipeline_depth=depth,
                       ram_budget_bytes=ram_budget), True, None

    bpa = estimate_bytes_per_amp(config.b_r, config.compression)
    lanes = max(1, config.batch)          # provision for the batch factor
    inner_cands = ((config.inner_size,) if config.inner_size is not None
                   else _INNER_CANDIDATES)
    if config.pipeline_depth is not None:
        depth_cands = (config.pipeline_depth,)
    else:
        # deepest-first, losing depths dropped up front: the per-(b, m)
        # scan below keeps "deepest fitting pipeline wins" semantics
        # among depths the overlap model actually endorses
        depth_cands = tuple(
            d for d in (DEFAULT_PIPELINE_DEPTH, 1)
            if d <= 1 or predict_depth_speedup(d, calibration) >= 1.0)
    feasible: list[tuple] = []
    fallback = None                       # least-working-set candidate
    for b in range(min(n, MAX_AUTO_LOCAL_BITS), 0, -1):
        for m in inner_cands:
            eff_m = min(max(m, 2), n - b)     # partition's clamped threshold
            part = partition_circuit(circuit, b, m)
            for depth in depth_cands:
                peak, pipe = _predict_working_set(n, b, eff_m, depth, bpa,
                                                  lanes, n_devices)
                cand = (part.n_stages, b, m, depth, peak + pipe, part)
                if fallback is None or peak + pipe < fallback[4]:
                    fallback = cand
                if peak + pipe <= budget:
                    feasible.append(cand)
                    break                     # deepest fitting pipeline wins

    if not feasible:
        n_stages, b, m, depth, ws, part = fallback
        warnings.warn(
            f"memory budget {budget} B is below the smallest feasible "
            f"working set ({ws} B at local_bits={b}"
            + (f", batch={lanes}" if lanes > 1 else "") + "); planning "
            "the smallest candidate and relying on the disk spill tier "
            "(batched runs fall back to chunked sub-batches)",
            RuntimeWarning, stacklevel=3)
        return replace(config, local_bits=b, inner_size=m,
                       pipeline_depth=depth,
                       ram_budget_bytes=ram_budget), True, part

    min_stages = min(c[0] for c in feasible)
    best = [c for c in feasible if c[0] == min_stages]
    if len(best) > 1 and not circuit.free_parameters \
            and not circuit.is_stochastic:
        # transpose tie-break needs concrete matrices; cap the candidates
        # so plan time stays trivial next to a single stage's compute
        best = sorted(best, key=lambda c: -c[1])[:6]
        best = [min(best, key=lambda c: (
            _transpose_cost(circuit, c[1], c[2], c[5],
                            config.max_fused_qubits), -c[1], c[2]))]
    _, b, m, depth, _, part = max(best, key=lambda c: (c[1], -c[2]))
    return replace(config, local_bits=b, inner_size=m, pipeline_depth=depth,
                   ram_budget_bytes=ram_budget), True, part


def fuse_stage(layout: GroupLayout, gates, max_fused: int,
               params: dict | None = None):
    """Fuse one stage's gates and remap onto the group's virtual bits.

    Returns ``(vgates, plan)``: the fused unitaries (matrices bound with
    ``params`` where parameterized) and the structural
    ``((vqubits, is_diagonal), ...)`` tuple that keys every downstream
    cache (stage fns, schedules, plans).
    """
    concrete = [g.bind(params) if g.is_parameterized else g for g in gates]
    fused = fuse_gates(concrete, max_fused)
    vgates = [FusedGate(layout.remap_qubits(fg.qubits), fg.matrix)
              for fg in fused]
    plan = tuple((fg.qubits, fg.is_diagonal) for fg in vgates)
    return vgates, plan


def fuse_stage_lanes(layout: GroupLayout, gates, max_fused: int, bindings,
                     rngs):
    """Fuse one stage for every lane of a batch -> shared structural plan.

    Args:
        bindings: per lane, the parameter dict (or None).
        rngs: per lane, the trajectory rng realizing stochastic channels
            (or None for a lane of a deterministic circuit); each lane's
            rng is threaded through the stages in circuit order, so one
            seed yields one consistent whole-circuit realization.

    Returns ``(lane_vgates, plan)``: the per-lane fused unitaries and the
    ONE structural plan they all execute under.  Fusion depends only on
    gate supports — identical across lanes by construction — while
    ``is_diagonal`` depends on matrix values (an rx(0) lane fuses to a
    diagonal identity; a trajectory's sampled X does not), so a fused
    gate is marked diagonal iff EVERY lane's realization is: a dense op
    applies any unitary correctly, a diagonal op only diagonal ones.
    """
    lane_vgates, lane_plans = [], []
    for params, rng in zip(bindings, rngs):
        concrete = [g.realize(rng) if g.is_stochastic else g for g in gates]
        vg, plan = fuse_stage(layout, concrete, max_fused, params)
        lane_vgates.append(vg)
        lane_plans.append(plan)
    base = lane_plans[0]
    for plan in lane_plans[1:]:
        if len(plan) != len(base) or \
                any(a[0] != b[0] for a, b in zip(plan, base)):
            raise RuntimeError(
                "batch lanes fused to different stage structures "
                "(fusion must depend on gate supports only)")
    merged = tuple(
        (vq, all(plan[i][1] for plan in lane_plans))
        for i, (vq, _) in enumerate(base))
    return lane_vgates, merged


def assemble_plan(circuit_fp: str, cfg, partition, stage_plans,
                  *, n_devices: int, interpret: bool, params_key: tuple,
                  auto_tuned: bool) -> ExecutionPlan:
    """Freeze a bound engine state into an :class:`ExecutionPlan`.

    ``stage_plans`` is ``[(layout, plan_tuple), ...]`` per partition
    stage (the engine's bound records minus the operand matrices — those
    belong to a binding, not the plan).
    """
    n, b = partition.n_qubits, partition.local_bits
    bpa = estimate_bytes_per_amp(cfg.b_r, cfg.compression)
    wire = wire_bytes_per_block(1 << b, cfg.codec_backend, cfg.compression)
    stages = []
    gate_lo = 0
    tot_t = tot_tn = tot_boundary = 0
    max_m = 0
    for idx, ((layout, plan), st) in enumerate(
            zip(stage_plans, partition.stages)):
        nv = layout.b + layout.m
        max_m = max(max_m, layout.m)
        if plan:
            sched = compile_schedule(plan, nv)
            n_t, n_tn = sched.n_transposes, sched.n_transposes_naive
        else:
            n_t = n_tn = 0
        stage_bytes = (wire * layout.n_groups * layout.blocks_per_group
                       * max(1, cfg.batch))
        key = (plan, nv, cfg.use_kernel, cfg.gate_schedule, interpret)
        stages.append(StagePlan(
            index=idx, layout=layout,
            gate_slice=(gate_lo, gate_lo + len(st.gates)), plan=plan,
            stagefn_key=key, n_devices=n_devices,
            n_transposes=n_t, n_transposes_naive=n_tn,
            est_h2d_bytes=stage_bytes, est_d2h_bytes=stage_bytes))
        gate_lo += len(st.gates)
        tot_t += n_t * layout.n_groups
        tot_tn += n_tn * layout.n_groups
        tot_boundary += 2 * stage_bytes
    # peak_ram/pipeline stay mesh-wide (n_devices=1 form) — the quantity
    # older dumps and the memory benchmarks report; the busiest device's
    # share is the budget-facing per_device_peak_bytes
    peak_ram, pipeline = _predict_working_set(
        n, b, max_m, cfg.pipeline_depth, bpa, cfg.batch)
    dev_peak, dev_pipe = _predict_working_set(
        n, b, max_m, cfg.pipeline_depth, bpa, cfg.batch, n_devices)
    predicted = PlanPredictions(
        bytes_per_amp=bpa,
        state_bytes=int((1 << n) * bpa) + (1 << (n - b)) * _BLOCK_OVERHEAD,
        peak_ram_bytes=peak_ram, pipeline_bytes=pipeline,
        boundary_bytes=tot_boundary,
        n_transposes=tot_t, n_transposes_naive=tot_tn,
        depth_speedup=predict_depth_speedup(cfg.pipeline_depth),
        per_device_peak_bytes=dev_peak + dev_pipe)
    return ExecutionPlan(
        circuit_fp=circuit_fp, n_qubits=n, local_bits=b,
        inner_size=cfg.inner_size, pipeline_depth=cfg.pipeline_depth,
        b_r=cfg.b_r, compression=cfg.compression, prescan=cfg.prescan,
        codec_backend=cfg.codec_backend, use_kernel=cfg.use_kernel,
        gate_schedule=cfg.gate_schedule,
        max_fused_qubits=cfg.max_fused_qubits, interpret=interpret,
        n_devices=n_devices, memory_budget_bytes=cfg.memory_budget_bytes,
        auto_tuned=auto_tuned, params_key=params_key,
        stages=tuple(stages), predicted=predicted, batch=cfg.batch)
