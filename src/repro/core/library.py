"""NWQBench-style benchmark circuits (paper §5.1).

Eight circuits: cat_state, cc, ising, qft, bv, qsvm, ghz_state, qaoa —
the suite BMQSIM is evaluated on, re-implemented from their standard
definitions (QASMBench / NWQBench).  Plus a random-circuit generator
for property tests.
"""
from __future__ import annotations

import math

import numpy as np

from .circuit import Circuit, Parameter

__all__ = ["build_circuit", "CIRCUIT_BUILDERS", "random_circuit",
           "maxcut_edges", "maxcut_cost_fn", "qaoa_template",
           "with_depolarizing", "zsum_cost_fn"]


def cat_state(n: int) -> Circuit:
    """|0..0> + |1..1> via H + CX chain."""
    qc = Circuit(n)
    qc.h(0)
    for q in range(n - 1):
        qc.cx(q, q + 1)
    return qc


def ghz_state(n: int) -> Circuit:
    """GHZ via H + CX star (control fixed at qubit 0)."""
    qc = Circuit(n)
    qc.h(0)
    for q in range(1, n):
        qc.cx(0, q)
    return qc


def bv(n: int, secret: int | None = None) -> Circuit:
    """Bernstein–Vazirani with an n-1 bit secret and ancilla at qubit n-1."""
    qc = Circuit(n)
    if secret is None:
        rng = np.random.default_rng(n)  # deterministic per size
        secret = int(rng.integers(1, 2 ** (n - 1)))
    anc = n - 1
    qc.x(anc)
    for q in range(n):
        qc.h(q)
    for q in range(n - 1):
        if (secret >> q) & 1:
            qc.cx(q, anc)
    for q in range(n - 1):
        qc.h(q)
    return qc


def cc(n: int) -> Circuit:
    """Counterfeit-coin finding (QASMBench `cc`): query superposition over
    n-1 coin qubits, balance oracle onto the ancilla, then interference."""
    qc = Circuit(n)
    anc = n - 1
    rng = np.random.default_rng(7 * n + 1)
    fake = int(rng.integers(0, n - 1))
    for q in range(n - 1):
        qc.h(q)
    # oracle: flip ancilla controlled on each weighed coin, fake coin marked
    for q in range(n - 1):
        qc.cx(q, anc)
    qc.h(anc)
    qc.cx(fake, anc)
    qc.h(anc)
    for q in range(n - 1):
        qc.cx(q, anc)
    for q in range(n - 1):
        qc.h(q)
    return qc


def ising(n: int, layers: int = 2) -> Circuit:
    """Trotterized transverse-field Ising evolution on a 1-D chain."""
    qc = Circuit(n)
    rng = np.random.default_rng(13 * n + layers)
    for q in range(n):
        qc.h(q)
    for _ in range(layers):
        jj = float(rng.uniform(0.2, 1.0))
        hh = float(rng.uniform(0.2, 1.0))
        for q in range(n - 1):
            qc.rzz(2.0 * jj * 0.1, q, q + 1)
        for q in range(n):
            qc.rx(2.0 * hh * 0.1, q)
    return qc


def qft(n: int, swaps: bool = True) -> Circuit:
    """Quantum Fourier transform (the paper's stage-count example)."""
    qc = Circuit(n)
    for q in range(n - 1, -1, -1):
        qc.h(q)
        for j in range(q - 1, -1, -1):
            qc.cp(math.pi / (2 ** (q - j)), j, q)
    if swaps:
        for q in range(n // 2):
            qc.swap(q, n - 1 - q)
    return qc


def qsvm(n: int, reps: int = 2) -> Circuit:
    """ZZ-feature-map kernel circuit (QSVM): U(x) then U(x')^dagger."""
    rng = np.random.default_rng(17 * n + reps)
    x1 = rng.uniform(0, 2 * math.pi, size=n)
    x2 = rng.uniform(0, 2 * math.pi, size=n)

    qc = Circuit(n)

    def feature_map(x: np.ndarray, inverse: bool) -> None:
        ops: list[tuple] = []
        for _ in range(reps):
            for q in range(n):
                ops.append(("h", q))
                ops.append(("p", 2.0 * float(x[q]), q))
            for q in range(n - 1):
                ang = 2.0 * float((math.pi - x[q]) * (math.pi - x[q + 1])) / math.pi
                ops.append(("cx", q, q + 1))
                ops.append(("p", ang, q + 1))
                ops.append(("cx", q, q + 1))
        if inverse:
            for op in reversed(ops):
                if op[0] == "h":
                    qc.h(op[1])
                elif op[0] == "p":
                    qc.p(-op[1], op[2])
                else:
                    qc.cx(op[1], op[2])
        else:
            for op in ops:
                if op[0] == "h":
                    qc.h(op[1])
                elif op[0] == "p":
                    qc.p(op[1], op[2])
                else:
                    qc.cx(op[1], op[2])

    feature_map(x1, inverse=False)
    feature_map(x2, inverse=True)
    return qc


def qaoa(n: int, layers: int = 2) -> Circuit:
    """QAOA MaxCut with fixed pseudo-random angles on the same graph as
    :func:`qaoa_template` (score it with
    ``maxcut_cost_fn(maxcut_edges(n))``)."""
    rng = np.random.default_rng(23 * n + layers)
    edges = maxcut_edges(n)

    qc = Circuit(n)
    for q in range(n):
        qc.h(q)
    for _ in range(layers):
        gamma = float(rng.uniform(0.1, math.pi))
        beta = float(rng.uniform(0.1, math.pi))
        for (a, b_) in sorted(edges):
            qc.rzz(gamma, a, b_)
        for q in range(n):
            qc.rx(2.0 * beta, q)
    return qc


def maxcut_edges(n: int, seed: int | None = None) -> list[tuple[int, int]]:
    """Deterministic pseudo-random 3-regular-ish MaxCut graph on n nodes
    (ring backbone + chords) — the graph behind :func:`qaoa_template`
    and :func:`qaoa`."""
    if n < 2:
        raise ValueError(f"MaxCut needs >= 2 nodes, got {n}")
    rng = np.random.default_rng(29 * n + 5 if seed is None else seed)
    edges: set[tuple[int, int]] = set()
    for q in range(n):
        if q != (q + 1) % n:
            edges.add((min(q, (q + 1) % n), max(q, (q + 1) % n)))
    # chord target capped at C(n,2): small graphs saturate every pair
    target = min(n + max(1, n // 2), n * (n - 1) // 2)
    while len(edges) < target:
        a, b_ = rng.integers(0, n, size=2)
        if a != b_:
            edges.add((min(int(a), int(b_)), max(int(a), int(b_))))
    return sorted(edges)


def maxcut_cost_fn(edges: list[tuple[int, int]]):
    """Vectorized diagonal MaxCut observable: cut size per basis index.

    Returns ``diag_fn(indices) -> values`` suitable for
    :meth:`SimResult.expectation` / :func:`measure.expect_diagonal`.
    """
    def diag_fn(idx):
        idx = np.asarray(idx, dtype=np.int64)
        acc = np.zeros(idx.shape, dtype=np.float64)
        for (a, b_) in edges:
            acc += ((idx >> a) & 1) ^ ((idx >> b_) & 1)
        return acc
    return diag_fn


def qaoa_template(n: int, layers: int = 1) -> Circuit:
    """Parameterized QAOA MaxCut ansatz over :func:`maxcut_edges`.

    Layer ``l`` exposes :class:`Parameter` placeholders ``gamma{l}`` (cost
    angle) and ``beta{l}`` (mixer angle); bind or pass them per run::

        sim = Simulator(qaoa_template(18, layers=1), cfg)
        r = sim.run(params={"gamma0": 0.8, "beta0": 0.4})
    """
    edges = maxcut_edges(n)
    qc = Circuit(n)
    for q in range(n):
        qc.h(q)
    for layer in range(layers):
        gamma = Parameter(f"gamma{layer}")
        beta = Parameter(f"beta{layer}")
        for (a, b_) in edges:
            qc.rzz(gamma, a, b_)
        for q in range(n):
            qc.rx(beta, q)
    return qc


def with_depolarizing(circuit: Circuit, p: float) -> Circuit:
    """Standard stochastic noise model: a 1-qubit depolarizing channel
    (probability ``p``) after every gate, on each of the gate's qubits.

    The result is a *stochastic* circuit — run it with
    ``Simulator.run(trajectories=K)`` / :meth:`Simulator.run_batch`,
    which draw per-trajectory Pauli realizations at bind time and share
    the partition/schedules across all lanes.
    """
    noisy = Circuit(circuit.n_qubits)
    for g in circuit.gates:
        noisy.gates.append(g)
        for q in g.qubits:
            noisy.depolarize(p, q)
    return noisy


def zsum_cost_fn(n: int):
    """Vectorized diagonal ``<sum_i Z_i>`` observable (trajectory tests:
    a product state's value degrades as ``n * (1 - 4p/3)`` per layer of
    depolarizing noise)."""
    def diag_fn(idx):
        idx = np.asarray(idx, dtype=np.int64)
        pop = np.zeros(idx.shape, dtype=np.int64)
        for k in range(n):
            pop += (idx >> k) & 1
        return (n - 2 * pop).astype(np.float64)
    return diag_fn


CIRCUIT_BUILDERS = {
    "cat_state": cat_state,
    "cc": cc,
    "ising": ising,
    "qft": qft,
    "bv": bv,
    "qsvm": qsvm,
    "ghz_state": ghz_state,
    "qaoa": qaoa,
}


def build_circuit(name: str, n_qubits: int, **kwargs) -> Circuit:
    """Instantiate a named benchmark circuit from :data:`CIRCUIT_BUILDERS`."""
    if name not in CIRCUIT_BUILDERS:
        raise KeyError(f"unknown circuit {name!r}; have {sorted(CIRCUIT_BUILDERS)}")
    return CIRCUIT_BUILDERS[name](n_qubits, **kwargs)


def random_circuit(n: int, n_gates: int, seed: int = 0,
                   two_qubit_frac: float = 0.35) -> Circuit:
    """Random circuit over the full gate library (property tests)."""
    rng = np.random.default_rng(seed)
    qc = Circuit(n)
    one_q = ["h", "x", "y", "z", "s", "t", "sdg", "tdg"]
    one_q_param = ["rx", "ry", "rz", "p"]
    two_q = ["cx", "cz", "swap"]
    two_q_param = ["cp", "crz", "rzz", "rxx"]
    for _ in range(n_gates):
        if n >= 2 and rng.random() < two_qubit_frac:
            a, b_ = map(int, rng.choice(n, size=2, replace=False))
            if rng.random() < 0.5:
                qc.append(str(rng.choice(two_q)), [a, b_])
            else:
                qc.append(str(rng.choice(two_q_param)), [a, b_],
                          float(rng.uniform(0, 2 * math.pi)))
        else:
            q = int(rng.integers(0, n))
            if rng.random() < 0.5:
                qc.append(str(rng.choice(one_q)), [q])
            else:
                if rng.random() < 0.25:
                    qc.append("u3", [q], *rng.uniform(0, 2 * math.pi, size=3))
                else:
                    qc.append(str(rng.choice(one_q_param)), [q],
                              float(rng.uniform(0, 2 * math.pi)))
    return qc
