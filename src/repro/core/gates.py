"""Quantum gate library.

Conventions
-----------
* Qubits are little-endian: qubit 0 is the least-significant bit of the
  state-vector index.
* A ``k``-qubit gate acting on qubits ``(q_0, ..., q_{k-1})`` has a
  ``2^k x 2^k`` unitary whose row/column index ``m`` decomposes as
  ``m = sum_j bit_j << j`` where ``bit_j`` is the basis value of ``q_j``.
  I.e. the *first* qubit in the tuple is the least-significant bit of the
  matrix index.
"""
from __future__ import annotations

import math

import numpy as np

__all__ = [
    "I2", "X", "Y", "Z", "H", "S", "SDG", "T", "TDG", "SX",
    "rx", "ry", "rz", "u3", "phase", "cx", "cz", "cp", "swap",
    "rzz", "rxx", "crz", "GATE_FACTORIES", "is_unitary", "controlled",
]

_SQ2 = 1.0 / math.sqrt(2.0)

I2 = np.eye(2, dtype=np.complex128)
X = np.array([[0, 1], [1, 0]], dtype=np.complex128)
Y = np.array([[0, -1j], [1j, 0]], dtype=np.complex128)
Z = np.array([[1, 0], [0, -1]], dtype=np.complex128)
H = np.array([[_SQ2, _SQ2], [_SQ2, -_SQ2]], dtype=np.complex128)
S = np.array([[1, 0], [0, 1j]], dtype=np.complex128)
SDG = S.conj().T
T = np.array([[1, 0], [0, np.exp(1j * math.pi / 4)]], dtype=np.complex128)
TDG = T.conj().T
SX = 0.5 * np.array([[1 + 1j, 1 - 1j], [1 - 1j, 1 + 1j]], dtype=np.complex128)


def rx(theta: float) -> np.ndarray:
    c, s = math.cos(theta / 2), math.sin(theta / 2)
    return np.array([[c, -1j * s], [-1j * s, c]], dtype=np.complex128)


def ry(theta: float) -> np.ndarray:
    c, s = math.cos(theta / 2), math.sin(theta / 2)
    return np.array([[c, -s], [s, c]], dtype=np.complex128)


def rz(theta: float) -> np.ndarray:
    e = np.exp(-0.5j * theta)
    return np.array([[e, 0], [0, np.conj(e)]], dtype=np.complex128)


def phase(lam: float) -> np.ndarray:
    return np.array([[1, 0], [0, np.exp(1j * lam)]], dtype=np.complex128)


def u3(theta: float, phi: float, lam: float) -> np.ndarray:
    c, s = math.cos(theta / 2), math.sin(theta / 2)
    return np.array(
        [
            [c, -np.exp(1j * lam) * s],
            [np.exp(1j * phi) * s, np.exp(1j * (phi + lam)) * c],
        ],
        dtype=np.complex128,
    )


def _two_qubit(u00: np.ndarray, u11: np.ndarray) -> np.ndarray:
    """Controlled-gate builder: control is the SECOND qubit in the tuple
    (bit 1 of the matrix index), target the first (bit 0)."""
    out = np.zeros((4, 4), dtype=np.complex128)
    out[:2, :2] = u00
    out[2:, 2:] = u11
    return out


def controlled(u: np.ndarray) -> np.ndarray:
    """Controlled-U with (target, control) qubit order (control = bit 1)."""
    return _two_qubit(I2, u)


# (target, control) order: index bit0 = target, bit1 = control.
def cx() -> np.ndarray:
    return controlled(X)


def cz() -> np.ndarray:
    return controlled(Z)


def cp(lam: float) -> np.ndarray:
    return controlled(phase(lam))


def crz(theta: float) -> np.ndarray:
    return controlled(rz(theta))


def swap() -> np.ndarray:
    out = np.eye(4, dtype=np.complex128)
    out[[1, 2]] = out[[2, 1]]
    return out


def rzz(theta: float) -> np.ndarray:
    """exp(-i theta/2 Z (x) Z) — diagonal two-qubit gate."""
    e = np.exp(-0.5j * theta)
    ec = np.conj(e)
    return np.diag([e, ec, ec, e]).astype(np.complex128)


def rxx(theta: float) -> np.ndarray:
    c, s = math.cos(theta / 2), -1j * math.sin(theta / 2)
    out = np.eye(4, dtype=np.complex128) * c
    out[0, 3] = out[1, 2] = out[2, 1] = out[3, 0] = s
    return out


# name -> callable(*params) returning the matrix; fixed gates wrapped in lambdas
GATE_FACTORIES = {
    "i": lambda: I2, "x": lambda: X, "y": lambda: Y, "z": lambda: Z,
    "h": lambda: H, "s": lambda: S, "sdg": lambda: SDG, "t": lambda: T,
    "tdg": lambda: TDG, "sx": lambda: SX,
    "rx": rx, "ry": ry, "rz": rz, "p": phase, "u3": u3,
    "cx": cx, "cz": cz, "cp": cp, "crz": crz, "swap": swap,
    "rzz": rzz, "rxx": rxx,
}


def is_unitary(m: np.ndarray, atol: float = 1e-10) -> bool:
    return bool(np.allclose(m @ m.conj().T, np.eye(m.shape[0]), atol=atol))
