"""SimResult: a readout handle over the final COMPRESSED state.

The engine exists so states too big to materialize can be simulated;
reading results out must honor the same constraint.  Every reader here
streams the two-level store block-by-block — peak extra memory is ~one
decoded SV block (2^b amplitudes), never the 2^n state:

    sample(shots)        two-pass: block-mass CDF, then decode only the
                         blocks that received shots (multinomial)
    expectation(diag_fn) <psi|D|psi> for diagonal observables, one pass
    probabilities(qs)    marginal distribution over a qubit subset
    amplitudes(indices)  decode only the blocks containing the indices
    statevector()        the explicit opt-in: materializes 2^n

The module-level ``stream_*`` functions are the implementation and take a
bare ``(backend, n, b)`` triple, so they serve both :class:`SimResult`
and the legacy free functions in :mod:`repro.core.measure`.

A :class:`SimResult` is a *live handle*: it reads the owning session's
store in place (zero-copy).  The next ``Simulator.run()`` overwrites that
store, which invalidates the handle — stale reads raise; call
:meth:`SimResult.save` first to persist a result across runs.
"""
from __future__ import annotations

import warnings
from typing import Sequence

import numpy as np

__all__ = ["SimResult", "BatchResult", "stream_block_masses",
           "stream_sample", "stream_expectation", "stream_marginal",
           "gather_amplitudes", "collect_statevector"]

#: lossy-tail tolerance: beyond this drift of the total probability mass
#: from 1.0 the readout warns (the b_r bound should keep drift tiny)
NORM_DRIFT_TOL = 1e-2

# above this the opt-in statevector() materialization refuses without
# force=True (2^28 complex64 = 2 GiB — defeats the engine's entire point)
_STATEVECTOR_GUARD_QUBITS = 27


def _normalized_masses(masses: np.ndarray, what: str) -> np.ndarray:
    """Renormalize block masses, warning when the lossy tail drifted."""
    total = masses.sum()
    if total <= 0.0:
        raise ValueError(f"{what}: compressed state has zero norm")
    if not np.isclose(total, 1.0, atol=NORM_DRIFT_TOL):
        warnings.warn(
            f"{what}: total probability mass of the compressed state is "
            f"{total:.6f} (codec error drifted beyond {NORM_DRIFT_TOL}); "
            "renormalizing — consider a tighter b_r",
            RuntimeWarning, stacklevel=3)
    return masses / total


def stream_block_masses(backend, n: int, b: int) -> np.ndarray:
    """(2^(n-b),) probability mass per SV block (one streaming pass)."""
    n_blocks = 2 ** (n - b)
    masses = np.empty(n_blocks, np.float64)
    for blk in range(n_blocks):
        amps = backend.decode_host_block(blk)
        masses[blk] = float(np.sum(np.abs(amps) ** 2))
    return masses


def stream_sample(backend, n: int, b: int, n_shots: int,
                  seed: int = 0) -> dict[int, int]:
    """Sample ``n_shots`` computational-basis outcomes -> {index: count}.

    Pass 1 builds the block-level CDF; pass 2 decodes ONLY the blocks the
    multinomial assigned shots to.
    """
    rng = np.random.default_rng(seed)
    masses = _normalized_masses(stream_block_masses(backend, n, b),
                                "sample")
    per_block = rng.multinomial(n_shots, masses)
    counts: dict[int, int] = {}
    bsz = 2 ** b
    for blk in np.nonzero(per_block)[0]:
        amps = backend.decode_host_block(int(blk))
        p = np.abs(amps) ** 2
        p = p / p.sum()
        idx = rng.choice(bsz, size=int(per_block[blk]), p=p)
        base = int(blk) << b
        for i in idx:
            key = base | int(i)
            counts[key] = counts.get(key, 0) + 1
    return counts


def stream_expectation(backend, n: int, b: int, diag_fn) -> float:
    """<psi| D |psi> for a diagonal observable, streamed per block.

    ``diag_fn(indices) -> values``: vectorized diagonal entries for global
    basis indices (e.g. a QAOA MaxCut cost function).
    """
    bsz = 2 ** b
    n_blocks = 2 ** (n - b)
    local = np.arange(bsz, dtype=np.int64)
    acc = 0.0
    norm = 0.0
    for blk in range(n_blocks):
        amps = backend.decode_host_block(blk)
        p = np.abs(amps) ** 2
        vals = diag_fn((blk << b) | local)
        acc += float(np.sum(p * vals))
        norm += float(p.sum())
    _normalized_masses(np.asarray([norm]), "expectation")  # drift warning
    return acc / norm


def stream_marginal(backend, n: int, b: int,
                    qubits: Sequence[int]) -> np.ndarray:
    """Marginal probability distribution over ``qubits`` (streamed).

    Bit ``j`` of the returned index is the basis value of ``qubits[j]``;
    the accumulator is 2^len(qubits) float64 — keep the subset small.
    """
    qubits = list(qubits)
    if len(set(qubits)) != len(qubits):
        raise ValueError(f"duplicate qubits in {qubits}")
    for q in qubits:
        if not 0 <= q < n:
            raise ValueError(f"qubit {q} out of range for n={n}")
    bsz = 2 ** b
    n_blocks = 2 ** (n - b)
    local = np.arange(bsz, dtype=np.int64)
    # the local-qubit part of each amplitude's marginal index is
    # block-invariant — precompute it once
    local_part = np.zeros(bsz, dtype=np.int64)
    for j, q in enumerate(qubits):
        if q < b:
            local_part |= ((local >> q) & 1) << j
    out = np.zeros(2 ** len(qubits), np.float64)
    for blk in range(n_blocks):
        amps = backend.decode_host_block(blk)
        gidx = blk << b
        base = 0
        for j, q in enumerate(qubits):
            if q >= b:
                base |= ((gidx >> q) & 1) << j
        np.add.at(out, base | local_part, np.abs(amps) ** 2)
    return _normalized_masses(out, "probabilities")


def gather_amplitudes(backend, n: int, b: int,
                      indices: Sequence[int]) -> np.ndarray:
    """Amplitudes at global basis ``indices``, decoding each needed block
    once (complex64, in input order)."""
    idx = np.asarray(indices, dtype=np.int64)
    if idx.size and (idx.min() < 0 or idx.max() >= 2 ** n):
        raise ValueError(f"index out of range for n={n}")
    out = np.empty(idx.shape, np.complex64)
    blocks = idx >> b
    local = idx & ((1 << b) - 1)
    for blk in np.unique(blocks):
        amps = backend.decode_host_block(int(blk))
        sel = blocks == blk
        out[sel] = amps[local[sel]]
    return out


def collect_statevector(backend, n: int, b: int) -> np.ndarray:
    """Decode every block into the full 2^n complex64 state."""
    n_blocks = 2 ** (n - b)
    parts = [backend.decode_host_block(blk) for blk in range(n_blocks)]
    return np.concatenate(parts)


class SimResult:
    """Handle over one run's final compressed state (see module docs).

    Obtained from :meth:`Simulator.run` / :meth:`Simulator.result`; all
    readers stream the store block-by-block.  The handle stays valid until
    the owning session runs again or closes; :meth:`save` persists it.
    """

    def __init__(self, backend, n_qubits: int, local_bits: int, stats=None,
                 owner=None, generation: int = 0):
        self._backend = backend
        self.n_qubits = n_qubits
        self.local_bits = local_bits
        self.stats = stats
        self._owner = owner
        self._generation = generation

    def __repr__(self) -> str:
        return (f"SimResult(n_qubits={self.n_qubits}, "
                f"local_bits={self.local_bits}, "
                f"n_blocks={2 ** (self.n_qubits - self.local_bits)})")

    # -- liveness --------------------------------------------------------------
    def _live(self):
        """The handle reads the session's store in place; a newer run has
        overwritten it -> this result no longer exists."""
        if self._owner is not None and \
                self._owner._generation != self._generation:
            raise RuntimeError(
                "stale SimResult: the owning Simulator ran again and "
                "overwrote the compressed store this handle reads; call "
                "result.save(path) before the next run to keep a result")
        return self._backend

    # -- streaming readers -----------------------------------------------------
    def sample(self, n_shots: int, seed: int = 0) -> dict[int, int]:
        """Sample computational-basis bitstrings -> {basis index: count}."""
        return stream_sample(self._live(), self.n_qubits, self.local_bits,
                             n_shots, seed=seed)

    def expectation(self, diag_fn) -> float:
        """<psi|D|psi> for a diagonal observable ``diag_fn(indices)->vals``."""
        return stream_expectation(self._live(), self.n_qubits,
                                  self.local_bits, diag_fn)

    def probabilities(self, qubits: Sequence[int] | None = None) -> np.ndarray:
        """Measurement distribution over ``qubits`` (default: all).

        Streamed block-by-block; the accumulator is 2^len(qubits)
        float64, so pass a subset at large n.  ``qubits=None`` allocates
        the full 2^n distribution (8 bytes/entry — as large as the
        complex64 state) and is therefore guarded like
        :meth:`statevector`; passing an explicit ``qubits=range(n)`` is
        the opt-in.
        """
        if qubits is None:
            if self.n_qubits > _STATEVECTOR_GUARD_QUBITS:
                raise MemoryError(
                    f"probabilities() over all {self.n_qubits} qubits "
                    f"materializes {2 ** (self.n_qubits + 3) / 2**30:.1f} "
                    "GiB; pass a qubit subset (or an explicit "
                    "qubits=range(n) if you really mean it)")
            qubits = range(self.n_qubits)
        return stream_marginal(self._live(), self.n_qubits, self.local_bits,
                               qubits)

    def block_probabilities(self) -> np.ndarray:
        """Raw (un-normalized) probability mass per SV block."""
        return stream_block_masses(self._live(), self.n_qubits,
                                   self.local_bits)

    def amplitudes(self, indices: Sequence[int]) -> np.ndarray:
        """Amplitudes at the given global basis indices (complex64)."""
        return gather_amplitudes(self._live(), self.n_qubits,
                                 self.local_bits, indices)

    def statevector(self, force: bool = False) -> np.ndarray:
        """Materialize the full 2^n complex64 state — the explicit opt-in
        that defeats the memory budget; refuses above
        2^{_STATEVECTOR_GUARD_QUBITS} amplitudes unless ``force=True``."""
        if self.n_qubits > _STATEVECTOR_GUARD_QUBITS and not force:
            raise MemoryError(
                f"statevector() at n={self.n_qubits} materializes "
                f"{2 ** (self.n_qubits + 3) / 2**30:.1f} GiB; pass "
                "force=True if you really mean it")
        return collect_statevector(self._live(), self.n_qubits,
                                   self.local_bits)

    # -- persistence -----------------------------------------------------------
    def save(self, path: str) -> None:
        """Checkpoint the compressed blocks + layout to ``path`` (see
        :meth:`Simulator.resume`)."""
        self._live()
        if self._owner is None:
            raise RuntimeError("this SimResult has no owning session to "
                               "serialize from")
        self._owner._save_checkpoint(path)


class _LaneView:
    """Read-only decode view over one batch lane's key range: lane ``j``
    of a batched run stores its blocks under keys offset by
    ``j * 2^(n-b)``, and every streaming reader only needs
    ``decode_host_block`` — so a thin key-shifting shim turns the shared
    backend into lane ``j``'s."""

    def __init__(self, backend, offset: int):
        self._backend = backend
        self._offset = offset

    def decode_host_block(self, key: int) -> np.ndarray:
        return self._backend.decode_host_block(self._offset + key)


class BatchResult:
    """Readout handle over a batched run's K final compressed states.

    Obtained from :meth:`Simulator.run_batch` /
    ``Simulator.run(trajectories=K)``.  ``result[j]`` (or
    ``result.lanes[j]``) is lane j's full :class:`SimResult` view —
    sampling, expectations, amplitudes, all streaming the shared store
    through a key-shifted lane window.  :meth:`expectation` averages a
    diagonal observable across lanes: for noise trajectories that is the
    Monte-Carlo estimate of the noisy expectation value.

    Like :class:`SimResult`, the handle is live — the owning session's
    next run invalidates it (including every lane view).
    """

    def __init__(self, backend, n_qubits: int, local_bits: int,
                 n_lanes: int, stats=None, owner=None, generation: int = 0):
        self.n_qubits = n_qubits
        self.local_bits = local_bits
        self.stats = stats
        n_blocks = 2 ** (n_qubits - local_bits)
        self.lanes = [
            SimResult(_LaneView(backend, lane * n_blocks), n_qubits,
                      local_bits, stats=stats, owner=owner,
                      generation=generation)
            for lane in range(n_lanes)
        ]

    def __repr__(self) -> str:
        return (f"BatchResult(n_qubits={self.n_qubits}, "
                f"local_bits={self.local_bits}, n_lanes={len(self.lanes)})")

    def __len__(self) -> int:
        return len(self.lanes)

    def __getitem__(self, lane: int) -> SimResult:
        return self.lanes[lane]

    def __iter__(self):
        return iter(self.lanes)

    def expectations(self, diag_fn) -> np.ndarray:
        """Per-lane ``<psi_j|D|psi_j>`` for a diagonal observable."""
        return np.asarray([lane.expectation(diag_fn) for lane in self.lanes])

    def expectation(self, diag_fn) -> float:
        """Lane-averaged diagonal expectation — the trajectory estimate
        of the noisy observable (for a parameter sweep it is just the
        mean over bindings)."""
        return float(self.expectations(diag_fn).mean())
