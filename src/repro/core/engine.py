"""The BMQSIM engine (paper §4): compressed, staged state-vector simulation.

Execution model per stage (from the §4.1 partition):

    for each SV group (independent):            # parallel across devices
        decompress 2^m member blocks -> flat 2^(b+m) group array   (host)
        apply the stage's fused unitaries                          (device)
        recompress the 2^m blocks -> two-level store               (host)

The decompress/compute/compress phases of *different* groups overlap via a
thread pipeline (§4.2's transfer-concealed workflow — zlib/numpy release
the GIL, JAX dispatch is async, so the overlap is real on this host too).
Groups never communicate: multi-device execution (§4.2 multi-GPU) is plain
round-robin group placement with zero collectives.
"""
from __future__ import annotations

import time
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from functools import lru_cache

import jax
import jax.numpy as jnp
import numpy as np

from ..compression.codec import (
    CompressedBlock, compress_complex_block, decompress_complex_block,
)
from ..compression.pwrel import PwRelParams
from ..compression.store import BlockStore
from .circuit import Circuit
from .dense_engine import apply_matrix
from .fusion import FusedGate, fuse_gates
from .groups import GroupLayout
from .partition import Partition, partition_circuit

__all__ = ["EngineConfig", "SimStats", "BMQSimEngine", "simulate_bmqsim"]


@dataclass
class EngineConfig:
    local_bits: int                  # b: SV block = 2^b amplitudes
    inner_size: int = 2              # max inner global indices per stage
    b_r: float = 1e-3                # point-wise relative bound (paper default)
    max_fused_qubits: int = 5        # fusion width (7 => 128x128 MXU tiles on TPU)
    compression: bool = True         # False = raw blocks (Fig. 11 baseline)
    prescan: bool = True             # bitmap pre-scan RLE (§4.3)
    pipeline_depth: int = 2          # decompress-ahead / compress-behind workers
    ram_budget_bytes: int | None = None
    spill_dir: str | None = None
    use_kernel: bool = False         # Pallas gate_apply path (interpret on CPU)
    devices: list | None = None      # round-robin group placement targets
    per_gate: bool = False           # SC19-Sim baseline: one stage per gate
                                     # (decompress+recompress per gate, §3)


@dataclass
class SimStats:
    n_qubits: int = 0
    n_gates: int = 0
    n_stages: int = 0
    n_fused_unitaries: int = 0
    n_block_compressions: int = 0
    n_block_decompressions: int = 0
    peak_ram_bytes: int = 0
    peak_total_bytes: int = 0
    disk_bytes: int = 0
    n_spills: int = 0
    t_decompress: float = 0.0
    t_compute: float = 0.0
    t_compress: float = 0.0
    t_partition: float = 0.0
    t_total: float = 0.0

    @property
    def standard_bytes(self) -> int:
        """The paper's 2^(n+4) standard (complex128 full state)."""
        return 2 ** (self.n_qubits + 4)

    @property
    def standard_bytes_c64(self) -> int:
        return 2 ** (self.n_qubits + 3)

    @property
    def memory_reduction(self) -> float:
        return self.standard_bytes / max(1, self.peak_total_bytes)


# --------------------------------------------------------------------------
# stage compute: fused unitaries applied to a flat 2^nv group array
# --------------------------------------------------------------------------

def _apply_fused(amps: jax.Array, mats: tuple[jax.Array, ...],
                 plan: tuple[tuple[tuple[int, ...], bool], ...],
                 nv: int) -> jax.Array:
    for mat, (vqubits, diag) in zip(mats, plan):
        if diag:
            # diagonal fast path: elementwise multiply, no GEMM
            k = len(vqubits)
            axes = [nv - 1 - q for q in vqubits]
            rest = [a for a in range(nv) if a not in axes]
            perm = rest + [axes[j] for j in range(k - 1, -1, -1)]
            t = amps.reshape((2,) * nv).transpose(perm).reshape(-1, 2 ** k)
            t = t * mat[None, :].astype(t.dtype)
            inv = np.argsort(np.asarray(perm))
            amps = t.reshape([2] * nv).transpose(list(inv)).reshape(-1)
        else:
            amps = apply_matrix(amps, mat, vqubits, nv)
    return amps


@lru_cache(maxsize=512)
def _stage_fn(plan: tuple[tuple[tuple[int, ...], bool], ...], nv: int,
              use_kernel: bool):
    """Jitted group-update function, cached on the stage *structure* so
    stages with identical access patterns share one compilation."""
    if use_kernel:
        from ..kernels import ops as kops

        def fn(amps, *mats):
            for mat, (vqubits, diag) in zip(mats, plan):
                amps = kops.apply_fused_gate(amps, mat, vqubits, nv, diag)
            return amps
    else:
        def fn(amps, *mats):
            return _apply_fused(amps, mats, plan, nv)
    return jax.jit(fn)


class BMQSimEngine:
    def __init__(self, circuit: Circuit, config: EngineConfig):
        self.circuit = circuit
        self.cfg = config
        self.n = circuit.n_qubits
        self.b = min(config.local_bits, self.n)
        self.params = PwRelParams(b_r=config.b_r)
        self.store = BlockStore(ram_budget_bytes=config.ram_budget_bytes,
                                spill_dir=config.spill_dir)
        self.stats = SimStats(n_qubits=self.n, n_gates=len(circuit))

        t0 = time.perf_counter()
        if config.per_gate:
            from .partition import Stage
            stages = [Stage(gates=[g],
                            inner=sorted({q for q in g.qubits if q >= self.b}))
                      for g in circuit.gates]
            self.partition = Partition(self.n, self.b, config.inner_size,
                                       stages)
        else:
            self.partition = partition_circuit(
                circuit, self.b, config.inner_size)
        self.stats.t_partition = time.perf_counter() - t0
        self.stats.n_stages = self.partition.n_stages

        # per-stage: layout + fused gates remapped to virtual qubits
        self._stages: list[tuple[GroupLayout, list[FusedGate]]] = []
        for st in self.partition.stages:
            layout = GroupLayout(self.n, self.b, tuple(st.inner))
            fused = fuse_gates(st.gates, config.max_fused_qubits)
            vgates = [
                FusedGate(layout.remap_qubits(fg.qubits), fg.matrix)
                for fg in fused
            ]
            self.stats.n_fused_unitaries += len(vgates)
            self._stages.append((layout, vgates))

        self._devices = config.devices or [jax.devices()[0]]

    # -- block codec (compression toggle) -----------------------------------
    def _compress(self, amps: np.ndarray) -> bytes:
        if not self.cfg.compression:
            return np.asarray(amps, dtype=np.complex64).tobytes()
        return compress_complex_block(amps, self.params,
                                      prescan=self.cfg.prescan).payload

    def _decompress(self, blob: bytes) -> np.ndarray:
        if not self.cfg.compression:
            return np.frombuffer(blob, dtype=np.complex64)
        return decompress_complex_block(blob, self.params)

    # -- initialization (§4.2 trick) -----------------------------------------
    def _init_state(self) -> None:
        bsz = 2 ** self.b
        first = np.zeros(bsz, dtype=np.complex64)
        first[0] = 1.0
        self.store.put(0, self._compress(first))
        n_blocks = 2 ** (self.n - self.b)
        if n_blocks > 1:
            zero = np.zeros(bsz, dtype=np.complex64)
            self.store.put(1, self._compress(zero))
            for blk in range(2, n_blocks):
                self.store.put_alias(blk, 1)
        self.stats.n_block_compressions += min(n_blocks, 2)

    # -- main loop -------------------------------------------------------------
    def run(self, collect_state: bool = True) -> np.ndarray | None:
        t_start = time.perf_counter()
        self._init_state()
        n_workers = max(1, self.cfg.pipeline_depth)
        with ThreadPoolExecutor(max_workers=n_workers) as dec_pool, \
                ThreadPoolExecutor(max_workers=n_workers) as com_pool:
            for layout, vgates in self._stages:
                if vgates:
                    self._run_stage(layout, vgates, dec_pool, com_pool)
        self.stats.t_total = time.perf_counter() - t_start
        self._snap_store_stats()
        if collect_state:
            return self._collect()
        return None

    def _run_stage(self, layout: GroupLayout, vgates: list[FusedGate],
                   dec_pool: ThreadPoolExecutor,
                   com_pool: ThreadPoolExecutor) -> None:
        nv = layout.b + layout.m
        plan = tuple((fg.qubits, fg.is_diagonal) for fg in vgates)
        fn = _stage_fn(plan, nv, self.cfg.use_kernel)
        mats = [
            jnp.asarray(np.diag(fg.matrix) if diag else fg.matrix,
                        dtype=jnp.complex64)
            for fg, (_, diag) in zip(vgates, plan)
        ]

        block_ids = layout.group_block_ids()      # (G, 2^m)
        n_groups = layout.n_groups
        bsz = 2 ** layout.b

        def load_group(g: int) -> np.ndarray:
            t0 = time.perf_counter()
            parts = [self._decompress(self.store.get(int(bid)))
                     for bid in block_ids[g]]
            self.stats.n_block_decompressions += len(parts)
            out = np.concatenate(parts) if len(parts) > 1 else parts[0]
            self.stats.t_decompress += time.perf_counter() - t0
            return out

        def save_group(g: int, amps: np.ndarray) -> None:
            t0 = time.perf_counter()
            blocks = np.asarray(amps).reshape(layout.blocks_per_group, bsz)
            for i, bid in enumerate(block_ids[g]):
                self.store.put(int(bid), self._compress(blocks[i]))
            self.stats.n_block_compressions += layout.blocks_per_group
            self.stats.t_compress += time.perf_counter() - t0

        depth = max(1, self.cfg.pipeline_depth)
        devices = self._devices
        pending_load = {}
        pending_save = []
        for g in range(min(depth, n_groups)):
            pending_load[g] = dec_pool.submit(load_group, g)

        for g in range(n_groups):
            amps = pending_load.pop(g).result()
            nxt = g + depth
            if nxt < n_groups:
                pending_load[nxt] = dec_pool.submit(load_group, nxt)
            t0 = time.perf_counter()
            dev = devices[g % len(devices)]
            amps_dev = jax.device_put(jnp.asarray(amps), dev)
            out = fn(amps_dev, *mats)
            out_np = np.asarray(out)          # blocks until device finishes
            self.stats.t_compute += time.perf_counter() - t0
            pending_save.append(com_pool.submit(save_group, g, out_np))

        for fut in pending_save:               # stage barrier (§4.1 semantics)
            fut.result()

    def _snap_store_stats(self) -> None:
        s = self.store.stats
        self.stats.peak_ram_bytes = s.peak_ram_bytes
        self.stats.peak_total_bytes = s.peak_total_bytes
        self.stats.disk_bytes = s.disk_bytes
        self.stats.n_spills = s.n_spills

    def _collect(self) -> np.ndarray:
        n_blocks = 2 ** (self.n - self.b)
        parts = [self._decompress(self.store.get(blk))
                 for blk in range(n_blocks)]
        return np.concatenate(parts)

    def close(self) -> None:
        self.store.close()


def simulate_bmqsim(circuit: Circuit, config: EngineConfig,
                    collect_state: bool = True):
    """Convenience wrapper: run and return (state, stats)."""
    eng = BMQSimEngine(circuit, config)
    try:
        state = eng.run(collect_state=collect_state)
        return state, eng.stats
    finally:
        eng.close()
