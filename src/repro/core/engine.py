"""The BMQSIM engine (paper §4): compressed, staged state-vector simulation.

Execution model per stage (from the §4.1 partition):

    for each SV group (independent):            # parallel across devices
        load/decode  2^m member blocks -> flat 2^(b+m) group array
        compute      the stage's fused unitaries              (device)
        encode/store the 2^m blocks -> two-level store

Phase orchestration lives in :mod:`repro.core.pipeline`: host phases of
*different* groups overlap through worker threads (§4.2's
transfer-concealed workflow — zlib/numpy release the GIL, JAX dispatch is
async), and ``EngineConfig.codec_backend`` chooses where the lossy codec
runs — ``"host"`` (baseline: raw group arrays cross the host↔device
boundary) or ``"device"`` (§4.3: the Pallas quantize/pack kernels run next
to the compute and only the compressed wire representation crosses).
Groups never communicate: multi-device execution (§4.2 multi-GPU) is plain
round-robin group placement with zero collectives.

On the device the group is *planes-resident*: it lives as a (2, 2^(b+m))
f32 re/im plane stack from decode through every fused gate to encode, and
each stage's gate list is compiled into a transpose-minimizing schedule
(:mod:`repro.core.schedule`) instead of the per-gate
transpose/apply/inverse-transpose pattern.
"""
from __future__ import annotations

import time
import warnings
from collections import OrderedDict
from dataclasses import dataclass, field, replace
from functools import lru_cache
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from ..compression.pwrel import PwRelParams
from ..compression.store import BlockStore
from ..distributed.lanes import make_lane_mesh, make_lane_shards
from ..kernels.ops import default_interpret
from .faults import fault_point
from .circuit import Circuit, Gate
from .dense_engine import apply_matrix
from .fusion import FusedGate
from .groups import GroupLayout
from .partition import Partition, Stage, partition_circuit
from .pipeline import (StagePipeline, complex_to_planes, make_backend,
                       planes_to_complex)
from .plan import ExecutionPlan, circuit_fingerprint, plan_fingerprint
from .planner import (assemble_plan, estimate_bytes_per_amp, fuse_stage,
                      fuse_stage_lanes, max_feasible_lanes, resolve_config)
from .pressure import PressureMonitor
from .result import collect_statevector
from .schedule import (StageSchedule, compile_schedule, execute_schedule,
                       execute_schedule_batched)

__all__ = ["EngineConfig", "SimStats", "BMQSimEngine", "simulate_bmqsim"]

#: parameter bindings whose fused operands stay resident per engine
_BOUND_CACHE_SIZE = 8


@dataclass
class EngineConfig:
    """Knobs of one BMQSIM run (paper defaults unless noted).

    Attributes:
        local_bits: ``b`` — an SV block holds 2^b amplitudes; the state
            splits into 2^(n-b) blocks (§3).  ``None`` means **auto**:
            the planner (:mod:`repro.core.planner`) chooses it — under
            ``memory_budget_bytes`` when set, by heuristic otherwise.
        inner_size: max inner global indices per stage — Algorithm 1's
            threshold; a group is 2^inner_size blocks.  ``None`` = auto
            (planner default 2, searched when ``local_bits`` is auto and
            a budget is set).
        memory_budget_bytes: total working-set budget the planner tunes
            the knobs against (predicted compressed state + pipeline
            staging).  Always also flows into the store's
            ``ram_budget_bytes`` backstop unless one was given, so the
            run honors the budget even when the compression-ratio
            estimate was optimistic (spilling to disk instead).
        b_r: point-wise relative error bound of the lossy quantizer (§4.3).
        max_fused_qubits: gate-fusion width (7 => 128x128 MXU tiles on TPU).
        compression: False stores raw complex64 blocks (Fig. 11 baseline).
        prescan: bitmap pre-scan RLE in the lossless stage (§4.3).
        pipeline_depth: decode-ahead / encode-behind worker count (§4.2;
            the paper's CUDA stream count).  ``None`` = auto (default 2,
            reduced when the staging working set would break the budget).
        codec_backend: ``"host"`` runs the whole codec on the host and
            moves raw 2^(b+m) complex64 group arrays across the
            host↔device boundary; ``"device"`` runs quantize/dequantize +
            bitmap/code packing on the accelerator (Pallas kernels,
            interpret-mode on CPU) so only packed codes + sign bitmaps +
            scalars cross.  ``"device"`` requires ``compression=True``
            (silently falls back to host otherwise).
        ram_budget_bytes: primary-tier budget of the two-level store (§4.4);
            overflow spills to disk.
        spill_dir: secondary-tier directory (default: a temp dir).
        use_kernel: apply gates via the Pallas gate kernels instead of XLA
            contractions (default: on — the planes-resident schedule makes
            this the fast path).
        gate_schedule: compile each stage's gate list into a
            transpose-minimizing schedule over f32 re/im planes
            (:mod:`repro.core.schedule`).  False restores the PR-1
            per-gate path (transpose -> apply -> inverse transpose per
            fused unitary, complex64 round-trip per gate) — kept for the
            side-by-side benchmark.
        devices: round-robin group placement targets (default: device 0).
        mesh_shape: build the run's device list from a 1-D simulation
            mesh instead (``(N,)`` or a bare ``N`` — see
            :func:`repro.distributed.lanes.make_lane_mesh`; ``qsim
            --devices N`` sets this).  A batched run lane-shards over the
            mesh (near-linear, zero collectives); a single run
            block-shards its groups per the plan's ``device_slot`` with
            compressed-wire exchange at stage boundaries.  An explicit
            ``devices`` list wins over ``mesh_shape``.
        per_gate: SC19-Sim baseline — one stage per gate, i.e. a full
            decompress+recompress sweep per gate (§3).
        batch: the batch factor K the *planner* provisions for — a
            ``run_batch``/trajectory run keeps K compressed state copies
            and K-lane group stacks resident, so the budget search scales
            its working-set model by this before picking
            ``local_bits``/``pipeline_depth``.  Runtime batches larger
            than the budget allows are chunked into feasible sub-batches
            (see :meth:`BMQSimEngine.feasible_lanes`).
        integrity_checks: stamp/verify crc32 content checksums on every
            serialized blob (disk spill tier + checkpoint snapshots); a
            mismatch raises a typed
            :class:`~repro.errors.BlockCorruptionError` instead of
            silently decoding corrupt data.  Default on (overhead is a
            gated ``bench_pipeline`` row).
        io_retries / io_backoff_s: bounded exponential-backoff retry of
            transient spill/checkpoint I/O errors before the store gives
            up with a typed :class:`~repro.errors.StoreIOError`.
        pressure_monitor: check measured ``bytes_per_amp`` against the
            planner's prediction at every stage boundary and walk the
            degradation ladder (shrink in-flight window -> wave depth 1
            -> proactive spill -> typed abort) when compression
            underdelivers; see :mod:`repro.core.pressure`.
        pressure_headroom: measured/predicted ratio that counts as
            pressure (the entropy model is deliberately loose).
        disk_budget_bytes: optional byte budget of the disk spill tier;
            overflowing it is the ladder's terminal rung — a
            :class:`~repro.errors.MemoryPressureError` abort at the next
            stage boundary (resumable when checkpointing is active).
            ``None`` (default) never aborts: incompressible-but-
            spillable runs degrade and complete.
    """

    local_bits: int | None = None
    inner_size: int | None = None
    b_r: float = 1e-3
    max_fused_qubits: int = 5
    compression: bool = True
    prescan: bool = True
    pipeline_depth: int | None = None
    codec_backend: str = "host"
    memory_budget_bytes: int | None = None
    ram_budget_bytes: int | None = None
    spill_dir: str | None = None
    use_kernel: bool = True
    gate_schedule: bool = True
    devices: list | None = None
    mesh_shape: tuple | int | None = None
    per_gate: bool = False
    batch: int = 1
    integrity_checks: bool = True
    io_retries: int = 3
    io_backoff_s: float = 0.01
    pressure_monitor: bool = True
    pressure_headroom: float = 1.5
    disk_budget_bytes: int | None = None


@dataclass
class SimStats:
    """Counters and timings of one run (see the paper's Figs. 9-12).

    ``h2d_bytes`` / ``d2h_bytes`` count every byte that crossed the
    host↔device boundary through the stage pipeline — the quantity the
    device codec backend shrinks; ``per_stage_boundary_bytes`` records the
    per-stage (h2d, d2h) pairs for the boundary-traffic benchmarks.  The
    list is **reset at the start of every run** (it describes the latest
    run only — a sweep must not grow it without bound); the scalar byte
    counters keep accumulating lifetime totals across runs.

    ``bytes_per_amp_measured`` is the achieved stored compression after
    the first encoded stage of the latest run — the run-time calibration
    of the planner's ``predicted.bytes_per_amp`` estimate.

    ``t_compute`` is dispatch + kernel time only; the blocking wait at the
    d2h boundary is ``t_fetch`` (previously misattributed to compute).
    ``n_transposes_naive`` / ``n_transposes_scheduled`` count full-group
    transposes (per group execution) under the per-gate scheme vs the
    compiled stage schedule — both are recorded whichever path ran.

    ``n_stagefn_compiles`` counts stage structures this engine
    instantiated for the first time; ``n_stagefn_cache_hits`` counts
    stage executions that reused one.  A parameter sweep on one session
    must show zero new compiles after the first run (the Simulator API's
    reuse contract); counters accumulate across ``n_runs`` runs.  (The
    jitted functions additionally dedup across engines via a
    process-global cache — these counters are deliberately per-engine.)
    """

    n_qubits: int = 0
    n_gates: int = 0
    n_stages: int = 0
    n_runs: int = 0
    #: lane count of the latest run (1 for a plain run(); K for run_batch)
    n_lanes: int = 1
    #: sub-batches the latest run_batch was chunked into to honor the
    #: memory budget (0 until the first batched run)
    n_batch_chunks: int = 0
    n_stagefn_compiles: int = 0
    n_stagefn_cache_hits: int = 0
    n_fused_unitaries: int = 0
    n_block_compressions: int = 0
    n_block_decompressions: int = 0
    peak_ram_bytes: int = 0
    peak_total_bytes: int = 0
    disk_bytes: int = 0
    n_spills: int = 0
    h2d_bytes: int = 0
    d2h_bytes: int = 0
    per_stage_boundary_bytes: list = field(default_factory=list)
    #: bytes of *encoded wire* (stored blob sizes) that changed owning
    #: device between consecutive stages of a block-sharded run — the
    #: device↔device analogue of the h2d/d2h ledger.  Only compressed
    #: blobs ever cross (the store holds nothing else), so this divided
    #: by ``n_exchanged_blocks * 2^local_bits * 8`` is the interconnect
    #: saving over shipping raw amplitudes.  Lifetime total; the
    #: per-stage list resets per run like per_stage_boundary_bytes.
    exchange_bytes: int = 0
    n_exchanged_blocks: int = 0
    per_stage_exchange_bytes: list = field(default_factory=list)
    bytes_per_amp_measured: float = 0.0
    n_transposes_naive: int = 0
    n_transposes_scheduled: int = 0
    t_decompress: float = 0.0
    t_compute: float = 0.0
    t_fetch: float = 0.0
    t_compress: float = 0.0
    t_partition: float = 0.0
    t_total: float = 0.0
    #: group x stage phase executions behind the t_* pipeline timings —
    #: the denominator for the planner's per-group calibration
    n_group_phases: int = 0
    # -- resilience counters (see repro.core.pressure / repro.errors) -----
    #: transient spill/checkpoint I/O errors absorbed by retry-with-backoff
    n_io_retries: int = 0
    #: blobs moved RAM -> disk by the pressure ladder's spill rung
    n_proactive_spills: int = 0
    #: checksum mismatches detected (every one raised a typed error)
    n_corruptions_detected: int = 0
    #: automatic replays-from-checkpoint after a detected corruption
    n_replays: int = 0
    #: emergency checkpoints flushed at a pressure abort
    n_emergency_checkpoints: int = 0
    #: degradation-ladder escalations across the session
    n_pressure_events: int = 0
    #: "stage{k}:{rung}" per escalation, in firing order
    pressure_rungs: list = field(default_factory=list)

    @property
    def standard_bytes(self) -> int:
        """The paper's 2^(n+4) standard (complex128 full state)."""
        return 2 ** (self.n_qubits + 4)

    @property
    def standard_bytes_c64(self) -> int:
        return 2 ** (self.n_qubits + 3)

    @property
    def memory_reduction(self) -> float:
        return self.standard_bytes / max(1, self.peak_total_bytes)

    @property
    def boundary_bytes(self) -> int:
        """Total host↔device traffic (both directions)."""
        return self.h2d_bytes + self.d2h_bytes

    def pipeline_calibration(self):
        """Measured per-group phase costs of this engine's runs, in the
        form the planner's depth model consumes
        (:class:`~repro.core.planner.PipelineCalibration`) — feed it back
        through ``resolve_config(..., calibration=...)`` so the next
        plan's ``pipeline_depth`` choice rests on measurements instead of
        the default profile."""
        from .planner import PipelineCalibration
        g = max(1, self.n_group_phases)
        return PipelineCalibration(
            t_load=self.t_decompress / g, t_compute=self.t_compute / g,
            t_fetch=self.t_fetch / g, t_store=self.t_compress / g)


# --------------------------------------------------------------------------
# stage compute: fused unitaries applied to a planes-resident group
# --------------------------------------------------------------------------
#
# The group lives as a (2, 2^(b+m)) f32 re/im plane stack from the codec
# backend's decode output all the way through every fused gate to the
# encode input; complex64 exists only inside the host backend and at
# _collect.  The default path executes the stage's compiled
# transpose-minimizing schedule (core/schedule.py); gate_schedule=False
# keeps the PR-1 per-gate path (complex64 round-trip + a transpose pair
# per gate) for the side-by-side benchmark.

def _apply_fused(amps: jax.Array, mats: tuple[jax.Array, ...],
                 plan: tuple[tuple[tuple[int, ...], bool], ...],
                 nv: int) -> jax.Array:
    for mat, (vqubits, diag) in zip(mats, plan):
        if diag:
            # diagonal fast path: elementwise multiply, no GEMM
            k = len(vqubits)
            axes = [nv - 1 - q for q in vqubits]
            rest = [a for a in range(nv) if a not in axes]
            perm = rest + [axes[j] for j in range(k - 1, -1, -1)]
            t = amps.reshape((2,) * nv).transpose(perm).reshape(-1, 2 ** k)
            t = t * mat[None, :].astype(t.dtype)
            inv = np.argsort(np.asarray(perm))  # jit-ok: perm is a static python tuple
            amps = t.reshape([2] * nv).transpose(list(inv)).reshape(-1)
        else:
            amps = apply_matrix(amps, mat, vqubits, nv)
    return amps


@lru_cache(maxsize=512)
def _stage_fn(plan: tuple[tuple[tuple[int, ...], bool], ...], nv: int,
              use_kernel: bool, gate_schedule: bool, interpret: bool):
    """Jitted planes -> planes group-update function, cached on the stage
    *structure* so stages with identical access patterns share one
    compilation.  The plane stack is donated: the decoded input is dead
    once the stage's unitaries consume it, so XLA may update in place."""
    if gate_schedule:
        sched = compile_schedule(plan, nv)

        def fn(planes, *mats):
            return execute_schedule(sched, planes, mats,
                                    use_kernel=use_kernel,
                                    interpret=interpret)
    elif use_kernel:
        from ..kernels import ops as kops

        def fn(planes, *mats):
            amps = planes_to_complex(planes)
            for mat, (vqubits, diag) in zip(mats, plan):
                amps = kops.apply_fused_gate(amps, mat, vqubits, nv, diag,
                                             interpret=interpret)
            return complex_to_planes(amps)
    else:
        def fn(planes, *mats):
            amps = planes_to_complex(planes)
            amps = _apply_fused(amps, mats, plan, nv)
            return complex_to_planes(amps)
    return jax.jit(fn, donate_argnums=0)


def _stage_mats(vgates: list[FusedGate],
                plan: tuple[tuple[tuple[int, ...], bool], ...],
                gate_schedule: bool) -> list[jax.Array]:
    """Per-gate operands in the form the selected stage path consumes:
    stacked (2, K, K) f32 planes of U (or (2, K) diagonal planes) for
    the scheduled path, complex64 matrices for the legacy path."""
    if gate_schedule:
        mats = []
        for fg, (_, diag) in zip(vgates, plan):
            m = np.diag(fg.matrix) if diag else fg.matrix
            mats.append(jnp.asarray(np.stack([m.real, m.imag]), jnp.float32))
        return mats
    return [
        jnp.asarray(np.diag(fg.matrix) if diag else fg.matrix,
                    dtype=jnp.complex64)
        for fg, (_, diag) in zip(vgates, plan)
    ]


@lru_cache(maxsize=256)
def _stage_fn_batch(plan: tuple[tuple[tuple[int, ...], bool], ...], nv: int,
                    use_kernel: bool, interpret: bool):
    """Jitted lane-batched (R, 2, 2^nv) -> (R, 2, 2^nv) group update:
    one dispatch covers every lane of a parameter-sweep / trajectory
    batch (lane l's planes contract against lane l's operands).  Cached
    on stage structure like :func:`_stage_fn`; jit re-specializes per
    row count, so one cache entry serves every batch size.

    Wave-aware: when the pipeline coalesces ``d`` consecutive groups of
    an L-lane batch into one (d·L)-row wave, the (L, ...) operands are
    tiled in-trace to match (groups-major row order — the tile repeats
    the lane block per group)."""
    sched = compile_schedule(plan, nv)

    def fn(planes, *mats):
        if mats and planes.shape[0] != mats[0].shape[0]:
            d = planes.shape[0] // mats[0].shape[0]
            mats = [jnp.tile(m, (d,) + (1,) * (m.ndim - 1)) for m in mats]
        return execute_schedule_batched(sched, planes, mats,
                                        use_kernel=use_kernel,
                                        interpret=interpret)
    return jax.jit(fn, donate_argnums=0)


@lru_cache(maxsize=256)
def _stage_fn_wave(plan: tuple[tuple[tuple[int, ...], bool], ...], nv: int,
                   use_kernel: bool, interpret: bool):
    """Jitted wave-coalesced (W, 2, 2^nv) -> (W, 2, 2^nv) group update
    for a SINGLE-lane run: every row is a different SV group of the same
    stage, so the one set of stage operands broadcasts across rows
    in-trace (no host-side tiling, no extra transfers).  This is what
    lets ``pipeline_depth`` amortize the per-group dispatch overhead —
    one dispatch covers a whole wave (see core/pipeline.py)."""
    sched = compile_schedule(plan, nv)

    def fn(planes, *mats):
        w = planes.shape[0]
        bmats = [jnp.broadcast_to(m[None], (w,) + m.shape) for m in mats]
        return execute_schedule_batched(sched, planes, bmats,
                                        use_kernel=use_kernel,
                                        interpret=interpret)
    return jax.jit(fn, donate_argnums=0)


def _stage_mats_batch(lane_vgates, plan) -> list[jax.Array]:
    """Per-gate lane-stacked operands for the batched scheduled path:
    (L, 2, K, K) stacked re/im planes of each lane's U for dense fused
    gates, (L, 2, K) diagonal planes when every lane's realization is
    diagonal."""
    mats = []
    for i, (_, diag) in enumerate(plan):
        per_lane = []
        for vgates in lane_vgates:
            m = np.diag(vgates[i].matrix) if diag else vgates[i].matrix
            per_lane.append(np.stack([m.real, m.imag]))
        mats.append(jnp.asarray(np.stack(per_lane), jnp.float32))
    return mats


class _BoundStage(NamedTuple):
    """One stage, fully compiled for one parameter binding: everything
    :meth:`BMQSimEngine.run` needs — built once at bind/plan time, never
    inside the run loop."""

    layout: GroupLayout
    plan: tuple                       # ((vqubits, is_diagonal), ...)
    mats: list                        # binding-specific operands
    key: tuple                        # stage-fn cache key
    fn: object                        # jitted planes -> planes update
    sched: StageSchedule | None       # compiled schedule (None if empty)
    wave_fn: object = None            # row-batched update (wave scheduler)


class BMQSimEngine:
    """Executor of one circuit's :class:`ExecutionPlan` (§4).

    Construction *plans*: it resolves auto knobs through the planner's
    cost model (``local_bits=None`` + ``memory_budget_bytes``), performs
    the §4.1 partition, and — per parameter binding, cached — fuses the
    gates, compiles the transpose-minimizing schedules and builds the
    stage-function cache keys.  :meth:`run` is a plain plan walk: no
    schedule compilation or key construction happens inside it.
    :meth:`compile` freezes the current binding's decisions into the
    inspectable :class:`ExecutionPlan` artifact; passing such a plan back
    via ``plan=`` skips planning and executes it verbatim.

    Use :class:`~repro.core.simulator.Simulator` unless you need to poke
    at engine internals between construction and run.
    """

    def __init__(self, circuit: Circuit, config: EngineConfig,
                 *, store: BlockStore | None = None,
                 plan: ExecutionPlan | None = None):
        self.circuit = circuit
        self._circuit_fp = circuit_fingerprint(circuit)
        self.n = circuit.n_qubits
        #: the 1-D simulation mesh (None on a single device) — lanes or
        #: block slots lay out along its one axis (distributed.lanes)
        self.mesh = None
        if config.devices:
            self._devices = list(config.devices)
        elif config.mesh_shape is not None:
            self.mesh = make_lane_mesh(config.mesh_shape)
            self._devices = list(self.mesh.devices.flat)
        else:
            self._devices = [jax.devices()[0]]
        if (self.mesh is None and len(self._devices) > 1
                and len({id(d) for d in self._devices})
                == len(self._devices)):
            # an explicit list with repeats (virtual slots on one device,
            # the single-core CI idiom) is a legal placement but not a
            # legal jax Mesh — run it mesh-less
            self.mesh = make_lane_mesh(devices=self._devices)
        if plan is not None:
            if plan.circuit_fp != self._circuit_fp:
                raise ValueError(
                    "ExecutionPlan was compiled for a different circuit "
                    "(structural fingerprint mismatch)")
            # verbatim execution: every knob the plan records wins over
            # the config's (devices stay config-side — the plan only
            # records their count)
            config = replace(
                config, local_bits=plan.local_bits,
                inner_size=plan.inner_size,
                pipeline_depth=plan.pipeline_depth,
                b_r=plan.b_r, compression=plan.compression,
                prescan=plan.prescan, codec_backend=plan.codec_backend,
                use_kernel=plan.use_kernel,
                gate_schedule=plan.gate_schedule,
                max_fused_qubits=plan.max_fused_qubits,
                batch=plan.batch,
                memory_budget_bytes=plan.memory_budget_bytes,
                ram_budget_bytes=(config.ram_budget_bytes
                                  if config.ram_budget_bytes is not None
                                  else plan.memory_budget_bytes))
            self.auto_tuned = plan.auto_tuned
        pre_part = None
        if plan is None:
            config, self.auto_tuned, pre_part = resolve_config(
                circuit, config, n_devices=len(self._devices))
        self.cfg = config
        self.b = min(config.local_bits, self.n)
        self.params = PwRelParams(b_r=config.b_r)
        self.store = store if store is not None else BlockStore(
            ram_budget_bytes=config.ram_budget_bytes,
            spill_dir=config.spill_dir,
            checksums=config.integrity_checks,
            io_retries=config.io_retries,
            io_backoff_s=config.io_backoff_s)
        self.stats = SimStats(n_qubits=self.n, n_gates=len(circuit))
        self.backend = make_backend(
            config.codec_backend, self.store, self.params, 2 ** self.b,
            compression=config.compression, prescan=config.prescan,
            interpret=default_interpret())

        t0 = time.perf_counter()
        if plan is not None:
            # the slices must tile the gate list exactly — a truncated or
            # overlapping slice (corrupt/hand-edited plan JSON) would
            # silently simulate a different circuit than circuit_fp attests
            expect = 0
            for sp in plan.stages:
                lo, hi = sp.gate_slice
                if lo != expect or hi < lo:
                    raise ValueError(
                        f"ExecutionPlan stage {sp.index} gate_slice "
                        f"{sp.gate_slice} does not tile the gate list "
                        f"(expected start {expect})")
                expect = hi
            if expect != len(circuit.gates):
                raise ValueError(
                    f"ExecutionPlan covers {expect} gates but the circuit "
                    f"has {len(circuit.gates)}")
            stages = [Stage(gates=list(circuit.gates[lo:hi]),
                            inner=sorted(sp.layout.inner))
                      for sp in plan.stages
                      for lo, hi in (sp.gate_slice,)]
            self.partition = Partition(self.n, self.b, config.inner_size,
                                       stages)
            self.partition.validate()
        elif config.per_gate:
            stages = [Stage(gates=[g],
                            inner=sorted({q for q in g.qubits if q >= self.b}))
                      for g in circuit.gates]
            self.partition = Partition(self.n, self.b, config.inner_size,
                                       stages)
        elif pre_part is not None:
            self.partition = pre_part  # the budget search already built it
        else:
            self.partition = partition_circuit(
                circuit, self.b, config.inner_size)
        self.stats.t_partition = time.perf_counter() - t0
        self.stats.n_stages = self.partition.n_stages

        # per-stage: layout + the stage's (possibly parameterized) gate
        # templates; fusion, schedule compilation and operand staging
        # happen per parameter binding in _bind_stages and are cached per
        # binding, so a sweep revisits neither the partition nor
        # previously-bound unitaries
        self._stages: list[tuple[GroupLayout, list[Gate]]] = []
        for st in self.partition.stages:
            layout = GroupLayout(self.n, self.b, tuple(st.inner))
            self._stages.append((layout, st.gates))
        self._free_params = circuit.free_parameters
        self._stochastic = circuit.is_stochastic
        # LRU-bounded: an optimizer loop feeding ever-new angles must not
        # grow the session's memory with one operand set per evaluation
        self._bound: OrderedDict[tuple, list[_BoundStage]] = OrderedDict()
        self._bound_batch: OrderedDict[tuple, list[_BoundStage]] = \
            OrderedDict()
        self._seen_stagefns: set[tuple] = set()
        #: lanes currently materialized in the store (run_batch leaves K
        #: final states resident; the next run clears the surplus)
        self._stored_lanes = 1
        # compiled ExecutionPlans, keyed on the binding's stage structure
        # (parameter *values* don't change it, so a sweep shares one plan)
        self._plans: dict[tuple, ExecutionPlan] = {}
        if not self._free_params and not self._stochastic:
            self._bind_stages(None)   # eager, like the pre-session engine

    # -- parameter binding -----------------------------------------------------
    @staticmethod
    def _params_key(params: dict | None) -> tuple:
        if not params:
            return ()
        return tuple(sorted((str(k), float(v)) for k, v in params.items()))

    def _check_params(self, params: dict | None) -> None:
        given = set(params or {})
        missing = self._free_params - given
        if missing:
            raise ValueError(
                f"circuit has unbound parameters {sorted(missing)}; "
                "pass values via run(params={...})")
        unknown = given - self._free_params
        if unknown:
            raise KeyError(f"unknown parameter(s) {sorted(unknown)}; "
                           f"circuit has {sorted(self._free_params)}")

    def _bind_stages(self, params: dict | None) -> list[_BoundStage]:
        """Compile one parameter binding: fuse + remap the gates, stage
        the operands, compile the schedule and build (and warm) the
        stage-fn cache key per stage — the plan-time work.  Cached, so
        :meth:`run` only ever walks the result."""
        if self._stochastic:
            raise ValueError(
                "circuit contains stochastic Pauli channels; sample "
                "trajectories via run_batch / run(trajectories=K) instead "
                "of a single deterministic run")
        key = self._params_key(params)
        cached = self._bound.get(key)
        if cached is not None:
            self._bound.move_to_end(key)
            return cached
        self._check_params(params)
        interpret = default_interpret()
        bound = []
        for layout, gates in self._stages:
            vgates, plan = fuse_stage(layout, gates,
                                      self.cfg.max_fused_qubits, params)
            mats = _stage_mats(vgates, plan, self.cfg.gate_schedule)
            self.stats.n_fused_unitaries += len(vgates)
            nv = layout.b + layout.m
            fkey = (plan, nv, self.cfg.use_kernel, self.cfg.gate_schedule,
                    interpret)
            fn = _stage_fn(*fkey) if plan else None
            # the scheduled path gets the row-batched wave form too (the
            # per-gate path has none — the pipeline runs it sequentially)
            wave_fn = (_stage_fn_wave(plan, nv, self.cfg.use_kernel,
                                      interpret)
                       if plan and self.cfg.gate_schedule else None)
            sched = compile_schedule(plan, nv) if plan else None
            bound.append(_BoundStage(layout, plan, mats, fkey, fn, sched,
                                     wave_fn))
        self._bound[key] = bound
        while len(self._bound) > _BOUND_CACHE_SIZE:
            self._bound.popitem(last=False)
        return bound

    # -- batched parameter/trajectory binding ----------------------------------
    def _validate_bindings(self, bindings) -> None:
        """Cheap pre-flight of a batch: every lane's params must bind and
        a stochastic circuit needs a trajectory seed per lane — run
        BEFORE any state is invalidated."""
        if not bindings:
            raise ValueError("run_batch needs at least one lane")
        if not self.cfg.gate_schedule or self.cfg.per_gate:
            raise ValueError(
                "run_batch requires the scheduled stage compute "
                "(gate_schedule=True, per_gate=False)")
        for params, seed in bindings:
            self._check_params(params)
            if self._stochastic and seed is None:
                raise ValueError(
                    "stochastic circuit: every batch lane needs a "
                    "trajectory seed (pass seeds=... / trajectories=K)")

    def _bind_stages_batch(self, bindings: tuple) -> list[_BoundStage]:
        """Compile one *batch* binding — ``bindings`` is a tuple of
        ``(params, trajectory_seed)`` per lane.  Fusion/schedules are
        shared across lanes (structure depends only on gate supports);
        the operands are lane-stacked and the stage fns lane-batched, so
        :meth:`run_batch` dispatches once per (stage, group) for the
        whole batch.  Cached like :meth:`_bind_stages`."""
        key = tuple((self._params_key(p), s) for p, s in bindings)
        cached = self._bound_batch.get(key)
        if cached is not None:
            self._bound_batch.move_to_end(key)
            return cached
        self._validate_bindings(bindings)
        interpret = default_interpret()
        # one rng per lane, threaded through the stages in circuit order:
        # a lane's realization is identical to circuit.realize(seed)'s
        rngs = [np.random.default_rng(s) if s is not None else None
                for _, s in bindings]
        params_list = [p for p, _ in bindings]
        bound = []
        for layout, gates in self._stages:
            lane_vgates, plan = fuse_stage_lanes(
                layout, gates, self.cfg.max_fused_qubits, params_list, rngs)
            mats = _stage_mats_batch(lane_vgates, plan)
            self.stats.n_fused_unitaries += len(plan) * len(bindings)
            nv = layout.b + layout.m
            fkey = (plan, nv, self.cfg.use_kernel, "batch", interpret)
            fn = (_stage_fn_batch(plan, nv, self.cfg.use_kernel, interpret)
                  if plan else None)
            sched = compile_schedule(plan, nv) if plan else None
            # the batched stage fn is already row-batched (and tiles its
            # lane operands in-trace for multi-group waves)
            bound.append(_BoundStage(layout, plan, mats, fkey, fn, sched,
                                     fn))
        self._bound_batch[key] = bound
        while len(self._bound_batch) > _BOUND_CACHE_SIZE:
            self._bound_batch.popitem(last=False)
        return bound

    # -- the plan artifact -----------------------------------------------------
    def compile(self, params: dict | None = None) -> ExecutionPlan:
        """Freeze this engine's compile-time decisions for one binding
        into an :class:`ExecutionPlan` (cached per stage structure —
        parameter values don't change it).  A stochastic circuit compiles
        the seed-0 trajectory's realization (the layout/partition half —
        what ``--explain`` inspects — is realization-independent)."""
        if self._stochastic:
            bound = self._bind_stages_batch(((params, 0),))
        else:
            bound = self._bind_stages(params)
        skey = tuple(bs.plan for bs in bound)
        pkey = self._params_key(params)
        plan = self._plans.get(skey)
        if plan is None:
            plan = assemble_plan(
                self._circuit_fp, self.cfg, self.partition,
                [(bs.layout, bs.plan) for bs in bound],
                n_devices=len(self._devices),
                interpret=default_interpret(),
                params_key=pkey,
                auto_tuned=self.auto_tuned)
            self._plans[skey] = plan
        elif plan.params_key != pkey:
            # same structure, different binding: the artifact must name
            # the binding it was asked for, not the first one cached
            plan = replace(plan, params_key=pkey)
        return plan

    def plan_fingerprint(self) -> str:
        """State-layout fingerprint of this engine's plan, computable
        without a parameter binding (partition + codec knobs only) —
        identical to ``compile(...).fingerprint``."""
        return plan_fingerprint(
            self._circuit_fp, self.n, self.b, self.cfg.inner_size,
            self.cfg.b_r, self.cfg.compression, self.cfg.prescan,
            [(tuple(st.inner), len(st.gates))
             for st in self.partition.stages])

    # -- initialization (§4.2 trick) -----------------------------------------
    @property
    def n_blocks(self) -> int:
        return 2 ** (self.n - self.b)

    def _init_state(self) -> None:
        self._init_lanes(0, 1)

    def _init_lanes(self, lane_base: int, lanes: int) -> None:
        """|0..0> in every lane of ``[lane_base, lane_base + lanes)``:
        the §4.2 trick generalizes — the one-hot first block and the zero
        block are each encoded once and aliased across blocks AND lanes."""
        bsz = 2 ** self.b
        n_blocks = self.n_blocks
        base_key = lane_base * n_blocks
        first = np.zeros(bsz, dtype=np.complex64)
        first[0] = 1.0
        self.backend.encode_host_block(base_key, first)
        if n_blocks > 1:
            self.backend.encode_host_block(base_key + 1,
                                           np.zeros(bsz, np.complex64))
        for lane in range(lanes):
            off = (lane_base + lane) * n_blocks
            for blk in range(n_blocks):
                key = off + blk
                if key == base_key or (n_blocks > 1 and key == base_key + 1):
                    continue
                self.store.put_alias(key,
                                     base_key if blk == 0 else base_key + 1)
        self.stats.n_block_compressions += min(n_blocks, 2)

    def _make_monitor(self, lanes: int = 1) -> PressureMonitor | None:
        """Arm the degradation ladder for one run (None when disabled)."""
        if not self.cfg.pressure_monitor:
            return None
        return PressureMonitor(
            predicted_bpa=estimate_bytes_per_amp(self.cfg.b_r,
                                                 self.cfg.compression),
            n_qubits=self.n, lanes=lanes,
            headroom=self.cfg.pressure_headroom,
            ram_budget=self.cfg.ram_budget_bytes,
            disk_budget=self.cfg.disk_budget_bytes)

    def _exchange_ledger(self, owners: dict, gids: np.ndarray,
                         slots: np.ndarray) -> int:
        """Account the compressed-wire exchange one stage boundary of a
        block-sharded run implies: every block whose owning device slot
        changed since the previous stage moves as its *stored encoded
        blob* (the store holds nothing rawer — both codec backends
        persist the same compressed BlockSegments format), so the bytes
        tallied here are exactly what would cross the interconnect.
        ``owners`` maps block key -> previous slot and is updated in
        place; returns the bytes moved at this boundary."""
        moved = 0
        for g, row in enumerate(gids):
            slot = int(slots[g])
            for key in row:
                k = int(key)
                prev = owners.get(k)
                if prev is not None and prev != slot:
                    fault_point("pipeline.exchange")
                    moved += self.store.nbytes_of(k)
                    self.stats.n_exchanged_blocks += 1
                owners[k] = slot
        self.stats.exchange_bytes += moved
        return moved

    def _clear_lanes(self, new_lanes: int) -> None:
        """Drop the final states of lanes a previous (larger) batch left
        in the store — their keys would otherwise leak RAM forever."""
        n_blocks = self.n_blocks
        for lane in range(new_lanes, self._stored_lanes):
            for blk in range(n_blocks):
                self.store.delete(lane * n_blocks + blk)
        self._stored_lanes = new_lanes

    # -- main loop -------------------------------------------------------------
    def run(self, collect_state: bool = True, params: dict | None = None,
            start_stage: int = 0, on_stage_done=None) -> np.ndarray | None:
        """Execute the circuit through the staged pipeline.

        Repeated ``run()`` calls on one engine re-execute from |0...0>,
        reusing the partition, the compiled stage functions, and (per
        distinct ``params``) the fused unitaries; stats accumulate.

        Args:
            collect_state: decompress and return the final 2^n state
                (set False for memory benchmarks at large n).
            params: values for the circuit's free :class:`Parameter`
                placeholders (required iff the circuit is parameterized).
            start_stage: first stage index to execute — nonzero only when
                resuming from a checkpoint whose store already holds the
                state after ``start_stage`` stages (skips |0..0> init).
            on_stage_done: optional ``callback(stage_idx)`` invoked after
                each stage's store barrier (checkpoint hook).

        Returns:
            The final complex64 state vector, or None.
        """
        t_start = time.perf_counter()
        bound = self._bind_stages(params)
        self.stats.n_runs += 1
        self.stats.n_lanes = 1
        # per-run, not lifetime: a parameter sweep must not grow this
        # list without bound (scalar byte counters keep the totals)
        self.stats.per_stage_boundary_bytes = []
        self.stats.per_stage_exchange_bytes = []
        if start_stage == 0:
            self._clear_lanes(1)
            self._init_state()
        pipe = StagePipeline(self.backend, depth=self.cfg.pipeline_depth,
                             devices=self._devices)
        monitor = self._make_monitor()
        # snapshot the backend's lifetime counters so repeated run() calls
        # on one engine accumulate deltas, not running totals
        back = self.backend
        h2d0, d2h0 = back.h2d_bytes, back.d2h_bytes
        dec0, com0 = back.n_decompressions, back.n_compressions
        first_done = False
        # block sharding (D > 1): groups follow the plan's device_slot
        # round-robin; `owners` tracks each block's slot so stage
        # boundaries account exactly the blocks that change hands
        D = len(self._devices)
        owners: dict[int, int] = {}
        with pipe:
            for idx, bs in enumerate(bound):
                if idx < start_stage or not bs.plan:
                    continue
                # stage-function reuse accounting (engine-local, so other
                # engines warming the process-global cache can't skew a
                # session's stats): a sweep must show zero new compiles
                # after its first run
                if bs.key in self._seen_stagefns:
                    self.stats.n_stagefn_cache_hits += 1
                else:
                    self._seen_stagefns.add(bs.key)
                    self.stats.n_stagefn_compiles += 1
                # transpose accounting: both counters are recorded
                # whichever path executes, so the scheduled/naive ratio is
                # always reportable
                self.stats.n_transposes_naive += \
                    bs.sched.n_transposes_naive * bs.layout.n_groups
                self.stats.n_transposes_scheduled += \
                    bs.sched.n_transposes * bs.layout.n_groups
                sh2d, sd2h = back.h2d_bytes, back.d2h_bytes
                gids = bs.layout.group_block_ids()
                group_devices = None
                if D > 1:
                    # the same round-robin StagePlan.device_slot records
                    slots = np.arange(gids.shape[0], dtype=np.int64) % D
                    self.stats.per_stage_exchange_bytes.append(
                        self._exchange_ledger(owners, gids, slots))
                    group_devices = [self._devices[int(s)] for s in slots]
                else:
                    self.stats.per_stage_exchange_bytes.append(0)
                pipe.run_stage(gids, bs.fn, bs.mats,
                               wave_fn=bs.wave_fn,
                               group_devices=group_devices)
                self.stats.per_stage_boundary_bytes.append(
                    (back.h2d_bytes - sh2d, back.d2h_bytes - sd2h))
                if not first_done:
                    # calibrate the planner's compression-ratio estimate
                    # against the first encoded stage (§4.4: variable
                    # ratios are only known once real data flows)
                    first_done = True
                    self.stats.bytes_per_amp_measured = \
                        self.store.total_bytes / 2 ** self.n
                if on_stage_done is not None:
                    on_stage_done(idx)
                if monitor is not None:
                    # after on_stage_done: a periodic checkpoint for this
                    # stage lands on disk before an abort can reference it
                    monitor.check(self.store, pipe, self.stats, idx + 1)
        self.stats.t_decompress += pipe.t_load
        self.stats.t_compute += pipe.t_compute
        self.stats.t_fetch += pipe.t_fetch
        self.stats.t_compress += pipe.t_store
        self.stats.n_group_phases += pipe.n_group_phases
        self.stats.h2d_bytes += back.h2d_bytes - h2d0
        self.stats.d2h_bytes += back.d2h_bytes - d2h0
        self.stats.n_block_decompressions += back.n_decompressions - dec0
        self.stats.n_block_compressions += back.n_compressions - com0
        self.stats.t_total += time.perf_counter() - t_start
        self._snap_store_stats()
        if collect_state:
            return self._collect()
        return None

    # -- batched execution -----------------------------------------------------
    def feasible_lanes(self, lanes: int) -> int:
        """Largest sub-batch the memory budget admits (== ``lanes`` when
        no budget is set); :meth:`run_batch` chunks to this size."""
        budget = self.cfg.memory_budget_bytes
        if budget is None or lanes <= 1:
            return max(1, lanes)
        max_m = max((layout.m for layout, _ in self._stages), default=0)
        return max_feasible_lanes(
            self.n, self.b, max_m, self.cfg.pipeline_depth,
            estimate_bytes_per_amp(self.cfg.b_r, self.cfg.compression),
            budget, lanes, n_devices=len(self._devices))

    def run_batch(self, bindings) -> None:
        """Execute the circuit for a whole batch of bindings at once.

        ``bindings`` is a sequence of ``(params, trajectory_seed)`` pairs
        — one lane per parameter-sweep point or noise trajectory.  Every
        lane flows through the staged pipeline together: per (stage,
        group), ONE lane-batched jitted dispatch, ONE boundary crossing,
        and one store barrier cover all K lanes, which beats K sequential
        :meth:`run` calls wherever the per-call dispatch overhead (not
        the arithmetic) dominates — i.e. the small-block configs.

        Lane ``j``'s final compressed state lands under store keys
        ``[j * n_blocks, (j+1) * n_blocks)``; read it back through a
        :class:`~repro.core.result.BatchResult` lane view.  When a
        memory budget is set and the K-lane working set would break it,
        the batch executes in chunked sub-batches of
        :meth:`feasible_lanes` lanes (with a ``RuntimeWarning``) — the
        result is identical, the staging peak smaller.
        """
        t_start = time.perf_counter()
        bindings = tuple(bindings)
        self._validate_bindings(bindings)
        lanes = len(bindings)
        chunk = self.feasible_lanes(lanes)
        if chunk < lanes:
            warnings.warn(
                f"batch of {lanes} lanes exceeds the memory budget "
                f"({self.cfg.memory_budget_bytes} B); executing "
                f"{-(-lanes // chunk)} chunked sub-batches of <= {chunk}",
                RuntimeWarning, stacklevel=2)
        self.stats.n_runs += 1
        self.stats.n_lanes = lanes
        self.stats.n_batch_chunks = -(-lanes // chunk)
        self.stats.per_stage_boundary_bytes = []
        self.stats.per_stage_exchange_bytes = []
        # every lane re-initializes below, but chunk c's init only touches
        # chunk c's keys — drop ALL previous-run states up front so a
        # chunked batch never carries stale lanes through its first
        # sub-batches (inflating peak RAM and the first-chunk calibration)
        self._clear_lanes(0)
        self._stored_lanes = lanes
        monitor = self._make_monitor(lanes)
        for base in range(0, lanes, chunk):
            self._run_lane_chunk(bindings[base:base + chunk], base, monitor)
        self.stats.t_total += time.perf_counter() - t_start
        self._snap_store_stats()

    def _run_lane_chunk(self, bindings: tuple, lane_base: int,
                        monitor: PressureMonitor | None = None) -> None:
        """One feasible sub-batch: bind, init its lanes, walk the plan
        with lane-batched pipeline stages."""
        bound = self._bind_stages_batch(bindings)
        lanes = len(bindings)
        self._init_lanes(lane_base, lanes)
        if monitor is not None:
            # bpa denominator: lanes materialized so far (finished
            # chunks' final states stay resident in the store)
            monitor.lanes = lane_base + lanes
        offsets = (lane_base + np.arange(lanes, dtype=np.int64)) \
            * self.n_blocks
        # lane sharding (D > 1): contiguous near-even lane slices, one
        # per mesh device.  Each shard owns a disjoint store-key range,
        # so lanes never change hands — exchange bytes stay 0 and the
        # only gather is the readout (the near-linear tier)
        shards = None
        if len(self._devices) > 1 and lanes > 1:
            shards = [(s.device, s.lanes)
                      for s in make_lane_shards(self._devices, lanes)]
            if len(shards) == 1:
                shards = None
        pipe = StagePipeline(self.backend, depth=self.cfg.pipeline_depth,
                             devices=self._devices)
        back = self.backend
        h2d0, d2h0 = back.h2d_bytes, back.d2h_bytes
        dec0, com0 = back.n_decompressions, back.n_compressions
        first_done = False
        with pipe:
            for stage_no, bs in enumerate(bound):
                if not bs.plan:
                    continue
                if bs.key in self._seen_stagefns:
                    self.stats.n_stagefn_cache_hits += 1
                else:
                    self._seen_stagefns.add(bs.key)
                    self.stats.n_stagefn_compiles += 1
                # one batched schedule execution transposes the whole
                # (L, ...) lane stack in a single pass — count per group,
                # not per lane (that is the point)
                self.stats.n_transposes_naive += \
                    bs.sched.n_transposes_naive * bs.layout.n_groups * lanes
                self.stats.n_transposes_scheduled += \
                    bs.sched.n_transposes * bs.layout.n_groups
                sh2d, sd2h = back.h2d_bytes, back.d2h_bytes
                pipe.run_stage(bs.layout.group_block_ids(), bs.fn, bs.mats,
                               lane_offsets=offsets, wave_fn=bs.wave_fn,
                               lane_shards=shards)
                self.stats.per_stage_boundary_bytes.append(
                    (back.h2d_bytes - sh2d, back.d2h_bytes - sd2h))
                self.stats.per_stage_exchange_bytes.append(0)
                if not first_done and lane_base == 0:
                    # calibrate on the first chunk only: later chunks'
                    # store totals include finished lanes' final states
                    first_done = True
                    self.stats.bytes_per_amp_measured = \
                        self.store.total_bytes / (2 ** self.n * lanes)
                if monitor is not None:
                    monitor.check(self.store, pipe, self.stats,
                                  stage_no + 1)
        self.stats.t_decompress += pipe.t_load
        self.stats.t_compute += pipe.t_compute
        self.stats.t_fetch += pipe.t_fetch
        self.stats.t_compress += pipe.t_store
        self.stats.n_group_phases += pipe.n_group_phases
        self.stats.h2d_bytes += back.h2d_bytes - h2d0
        self.stats.d2h_bytes += back.d2h_bytes - d2h0
        self.stats.n_block_decompressions += back.n_decompressions - dec0
        self.stats.n_block_compressions += back.n_compressions - com0

    def _snap_store_stats(self) -> None:
        s = self.store.stats
        self.stats.peak_ram_bytes = s.peak_ram_bytes
        self.stats.peak_total_bytes = s.peak_total_bytes
        self.stats.disk_bytes = s.disk_bytes
        self.stats.n_spills = s.n_spills
        self.stats.n_io_retries = s.n_io_retries
        self.stats.n_proactive_spills = s.n_proactive_spills
        self.stats.n_corruptions_detected = s.n_corruptions_detected

    def _collect(self) -> np.ndarray:
        return collect_statevector(self.backend, self.n, self.b)

    def close(self) -> None:
        self.store.close()


def simulate_bmqsim(circuit: Circuit, config: EngineConfig,
                    collect_state: bool = True):
    """Simulate ``circuit`` with the compressed staged engine.

    .. deprecated::
        This is the one-shot compat wrapper.  Prefer the session API —
        :class:`~repro.core.simulator.Simulator` /
        :class:`~repro.core.result.SimResult` — which keeps the compiled
        stage schedules alive across runs and reads samples, expectation
        values, and amplitudes straight from the compressed store instead
        of materializing the 2^n state.  ``collect_state=False`` returns
        ``(None, stats)`` and throws the compressed final state away;
        ``Simulator.run()`` returns a readout handle over it instead.

    Args:
        circuit: the :class:`~repro.core.circuit.Circuit` to run.
        config: engine knobs; see :class:`EngineConfig`.
        collect_state: return the final state (False to keep only stats).

    Returns:
        ``(state, stats)`` — the final complex64 state vector (or None)
        and the run's :class:`SimStats`.
    """
    eng = BMQSimEngine(circuit, config)
    try:
        state = eng.run(collect_state=collect_state)
        return state, eng.stats
    finally:
        eng.close()
