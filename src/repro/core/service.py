"""Service tier: plan-admission scheduling + continuous lane batching.

A multi-tenant simulation service needs exactly what the planning layer
already provides: every job compiles to an
:class:`~repro.core.plan.ExecutionPlan` whose
``PlanPredictions.peak_ram_bytes`` is a *provable* working-set bound
(backstopped by the store's RAM budget), so admission control can be a
sum instead of a heuristic.  :class:`SimService` turns that into a
scheduler:

* **Session pool keyed by circuit structure.**  One :class:`Simulator`
  per :func:`~repro.core.plan.circuit_fingerprint` — stage functions and
  transpose-minimizing schedules compile once per *structure* (the
  ``SimStats.n_stagefn_cache_hits`` contract), so the first job of a
  structure pays the cold compile and every later one is warm
  (``ServiceStats.n_cold_compiles`` / ``n_warm_hits``).  Idle sessions
  evict LRU past ``max_sessions``.
* **Plan admission.**  ``submit()`` prices the job at
  :func:`~repro.core.planner.peak_ram_for` (plan, lanes=1) and compares
  the *sum of reservations* against the global ``memory_budget_bytes``:
  **reject** only when the job can never fit (``peak_ram > budget`` even
  alone), **admit** (reserve) when it fits now, **queue** when it merely
  can't fit *now*.  The reservation sum never exceeds the budget
  (``ServiceStats.peak_reserved_bytes`` audits the high-water mark).
* **Continuous lane batching.**  Each scheduling round takes the oldest
  admitted job and merges every co-admitted job of the *same structure*
  into one ``run_batch`` lane stack (capped by
  :func:`~repro.core.planner.max_feasible_lanes`) — the sim-engine
  analogue of LLM serving batchers: per (stage, group) the whole merged
  batch pays one jitted dispatch, one boundary crossing, one store
  barrier.  The working-set model is linear in lanes, so the merged
  stack needs exactly the reservations its jobs already hold.

The scheduler is pure Python, single-threaded and **deterministic under
an injected clock** — ``SimService(..., clock=VirtualClock())`` makes
every recorded timestamp (and therefore every latency, every LRU
decision) reproducible in tests.  Network frontends are expected to
serialize into ``submit()``/``step()``; a lock makes that safe but no
concurrency happens inside the service itself.

    svc = SimService(memory_budget_bytes=64 << 20)
    jobs = [svc.submit(qaoa_template(16), params=p, shots=256)
            for p in points]                     # admission decisions
    svc.drain()                                  # merged lane stacks run
    counts = jobs[0].result["counts"]
    print(svc.stats.summary())

See ``docs/SERVING.md`` for the operator guide (decision table, budget
math, merge rules, session lifecycle).
"""
from __future__ import annotations

import threading
import time
from collections import OrderedDict, deque
from dataclasses import dataclass, field, replace
from typing import Callable

from ..errors import (BlockCorruptionError, MemoryPressureError,
                      ResumableError, StoreIOError)
from .engine import EngineConfig
from .planner import estimate_bytes_per_amp, max_feasible_lanes, peak_ram_for
from .simulator import Simulator, circuit_fingerprint

__all__ = ["Job", "ServiceStats", "SimService", "VirtualClock"]

#: job lifecycle states (``Job.state``)
JOB_STATES = ("queued", "admitted", "running", "done", "failed", "rejected")

#: typed engine failures the scheduler absorbs into ``Job.error`` —
#: anything else (including ``InjectedCrash``) propagates to the caller
_JOB_FAILURES = (BlockCorruptionError, MemoryPressureError,
                 ResumableError, StoreIOError)


class VirtualClock:
    """Deterministic clock for tests: time moves only via :meth:`advance`.

    Inject with ``SimService(..., clock=VirtualClock())`` — every
    timestamp the service records then becomes reproducible, so
    scheduler tests can assert exact queueing delays and latencies.
    """

    def __init__(self, start: float = 0.0):
        self.now = float(start)

    def __call__(self) -> float:
        return self.now

    def advance(self, dt: float) -> float:
        if dt < 0:
            raise ValueError(f"clocks only move forward (dt={dt})")
        self.now += dt
        return self.now


@dataclass
class Job:
    """One submitted simulation request and its lifecycle record.

    ``state`` walks ``queued | admitted -> running -> done | failed``,
    or is terminally ``rejected`` at submit time.  ``peak_ram_bytes`` is
    the admission price (predicted peak RAM at lanes=1); ``merge_width``
    records how many same-structure jobs shared the lane stack this job
    ran in (1 = solo).  ``result`` holds whatever readout was requested
    at submit — readout is captured *eagerly* while the underlying
    handle is live, so a finished ``Job`` stays valid after the session
    runs its next batch.
    """

    job_id: int
    structure: str                    #: circuit fingerprint (pool key)
    peak_ram_bytes: int               #: admission price at lanes=1
    params: dict | None = None
    seed: int | None = None
    shots: int | None = None
    observable: Callable | None = None
    readout: Callable | None = None
    state: str = "queued"
    cold: bool = False                #: this job triggered the cold compile
    merge_width: int = 0
    result: dict = field(default_factory=dict)
    error: str | None = None
    submitted_at: float | None = None
    admitted_at: float | None = None
    started_at: float | None = None
    finished_at: float | None = None

    @property
    def done(self) -> bool:
        return self.state in ("done", "failed", "rejected")

    @property
    def wait_s(self) -> float | None:
        """Admission-queue delay (None until admitted)."""
        if self.admitted_at is None or self.submitted_at is None:
            return None
        return self.admitted_at - self.submitted_at

    @property
    def latency_s(self) -> float | None:
        """Submit-to-finish latency (None until finished)."""
        if self.finished_at is None or self.submitted_at is None:
            return None
        return self.finished_at - self.submitted_at


@dataclass
class ServiceStats:
    """Service-level counters (the analogue of ``SimStats`` one tier up).

    ``n_admitted``/``n_queued``/``n_rejected`` partition the *admission
    decisions at submit time* (a queued job is admitted later without
    re-counting); ``n_cold_compiles``/``n_warm_hits`` partition submits
    by session-pool outcome; ``merge_widths`` records the lane count of
    every dispatched batch (``n_batches`` entries).
    """

    n_submitted: int = 0
    n_admitted: int = 0          #: fit at submit time (reserved immediately)
    n_queued: int = 0            #: had to wait for budget headroom
    n_rejected: int = 0          #: can never fit (peak_ram > budget alone)
    n_completed: int = 0
    n_failed: int = 0
    n_cold_compiles: int = 0     #: structure-pool misses (plan compiled)
    n_warm_hits: int = 0         #: structure-pool hits (plan + stage fns reused)
    n_batches: int = 0           #: lane stacks dispatched
    n_merged_jobs: int = 0       #: jobs that ran at merge_width >= 2
    max_merge_width: int = 0
    merge_widths: list = field(default_factory=list)
    n_sessions_evicted: int = 0
    reserved_bytes: int = 0      #: current admission-reservation sum
    peak_reserved_bytes: int = 0  #: high-water mark (must stay <= budget)

    def summary(self) -> str:
        """The one-line stats form the serve CLI prints and CI asserts."""
        return (f"submitted={self.n_submitted} admitted={self.n_admitted} "
                f"queued={self.n_queued} rejected={self.n_rejected} "
                f"completed={self.n_completed} failed={self.n_failed} "
                f"cold={self.n_cold_compiles} warm={self.n_warm_hits} "
                f"batches={self.n_batches} merged={self.n_merged_jobs} "
                f"max_merge={self.max_merge_width} "
                f"peak_reserved_mib={self.peak_reserved_bytes / 2**20:.2f}")


class _Session:
    """One pooled Simulator + its frozen plan and admission price."""

    __slots__ = ("sim", "plan", "peak1", "last_used", "n_pending")

    def __init__(self, sim: Simulator, plan, peak1: int, now: float):
        self.sim = sim
        self.plan = plan
        self.peak1 = peak1
        self.last_used = now
        self.n_pending = 0           # jobs submitted but not finished


class SimService:
    """Admission-controlled, continuously-batched simulation service.

    Args:
        memory_budget_bytes: global admission budget — the sum of every
            admitted-but-unfinished job's predicted peak RAM never
            exceeds it.
        config: template :class:`EngineConfig` for pooled sessions.
            When it carries neither explicit ``local_bits`` nor its own
            ``memory_budget_bytes``, the service budget is passed down
            so the planner auto-tunes each structure's knobs under it
            (and the store's RAM backstop enforces it at run time).
        max_sessions: session-pool size; least-recently-used idle
            sessions beyond it are closed (their next job is a fresh
            cold compile).
        clock: monotonic time source; inject :class:`VirtualClock` for
            deterministic tests.
    """

    def __init__(self, memory_budget_bytes: int, *,
                 config: EngineConfig | None = None, max_sessions: int = 8,
                 clock: Callable[[], float] = time.monotonic):
        if memory_budget_bytes <= 0:
            raise ValueError("memory_budget_bytes must be positive")
        if max_sessions < 1:
            raise ValueError("max_sessions must be >= 1")
        self._budget = int(memory_budget_bytes)
        cfg = config if config is not None else EngineConfig()
        if cfg.local_bits is None and cfg.memory_budget_bytes is None:
            # auto knobs with no budget of their own: plan each structure
            # under the service budget (also arms the store backstop)
            cfg = replace(cfg, memory_budget_bytes=self._budget)
        self._config = cfg
        self._max_sessions = max_sessions
        self._clock = clock
        self._lock = threading.RLock()
        self._sessions: OrderedDict[str, _Session] = OrderedDict()
        self._ready: list[Job] = []      # admitted, reserved, arrival order
        self._wait: deque[Job] = deque()  # queued, arrival order
        self._jobs: list[Job] = []
        self._next_id = 0
        self._closed = False
        self.stats = ServiceStats()

    # -- lifecycle -------------------------------------------------------------
    def __enter__(self) -> "SimService":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def close(self) -> None:
        with self._lock:
            if self._closed:
                return
            self._closed = True
            for sess in self._sessions.values():
                sess.sim.close()
            self._sessions.clear()

    @property
    def memory_budget_bytes(self) -> int:
        return self._budget

    @property
    def reserved_bytes(self) -> int:
        """Current sum of admitted-but-unfinished reservations."""
        with self._lock:
            return self.stats.reserved_bytes

    @property
    def jobs(self) -> list[Job]:
        with self._lock:
            return list(self._jobs)

    @property
    def n_pending(self) -> int:
        """Jobs admitted or queued but not yet finished."""
        with self._lock:
            return len(self._ready) + len(self._wait)

    @property
    def n_sessions(self) -> int:
        with self._lock:
            return len(self._sessions)

    # -- session pool ----------------------------------------------------------
    def _session_for(self, circuit, params) -> tuple[str, _Session, bool]:
        fp = circuit_fingerprint(circuit)
        sess = self._sessions.get(fp)
        if sess is not None:
            self._sessions.move_to_end(fp)
            sess.last_used = self._clock()
            return fp, sess, False
        sim = Simulator(circuit, self._config)
        try:
            plan = sim.compile(params=params)
        except Exception:
            sim.close()
            raise
        sess = _Session(sim, plan, peak_ram_for(plan, 1), self._clock())
        self._sessions[fp] = sess
        self._evict_idle()
        return fp, sess, True

    def _evict_idle(self) -> None:
        # LRU-evict *idle* sessions only — a session with pending jobs
        # holds compiled state its jobs were admitted against.  The MRU
        # entry is always spared: it is the session just created or just
        # used, and evicting it would orphan the submit/round in flight.
        mru = next(reversed(self._sessions), None)
        idle = [fp for fp, s in self._sessions.items()
                if s.n_pending == 0 and fp != mru]
        for fp in idle:
            if len(self._sessions) <= self._max_sessions:
                break
            self._sessions.pop(fp).sim.close()
            self.stats.n_sessions_evicted += 1

    # -- admission -------------------------------------------------------------
    def submit(self, circuit, params: dict | None = None, *,
               seed: int | None = None, shots: int | None = None,
               observable: Callable | None = None,
               readout: Callable | None = None) -> Job:
        """Admit, queue or reject one simulation request.

        The decision (see docs/SERVING.md for the full table) prices the
        job at its plan's predicted peak RAM for one lane:

        ========================================  =============
        condition                                 decision
        ========================================  =============
        ``peak_ram(1) > budget``                  **rejected** — can
                                                  never fit
        ``reserved + peak_ram(1) <= budget``      **admitted** — reserved
                                                  now, runs next round
        otherwise                                 **queued** — admitted
                                                  in arrival order as
                                                  budget frees
        ========================================  =============

        ``shots``/``observable``/``readout`` choose what lands in
        ``job.result`` (``"counts"``, ``"expectation"``, ``"readout"``)
        — captured eagerly at completion, so the job outlives the
        session's next batch.  ``seed`` seeds a stochastic circuit's
        trajectory lane (default 0).
        """
        with self._lock:
            if self._closed:
                raise RuntimeError("SimService is closed")
            fp, sess, cold = self._session_for(circuit, params)
            if cold:
                self.stats.n_cold_compiles += 1
            else:
                self.stats.n_warm_hits += 1
            job = Job(job_id=self._next_id, structure=fp,
                      peak_ram_bytes=sess.peak1, params=params, seed=seed,
                      shots=shots, observable=observable, readout=readout,
                      cold=cold)
            self._next_id += 1
            job.submitted_at = self._clock()
            self._jobs.append(job)
            self.stats.n_submitted += 1
            if job.peak_ram_bytes > self._budget:
                job.state = "rejected"
                job.finished_at = job.submitted_at
                self.stats.n_rejected += 1
                return job
            sess.n_pending += 1
            if self._try_reserve(job):
                self.stats.n_admitted += 1
            else:
                job.state = "queued"
                self._wait.append(job)
                self.stats.n_queued += 1
            return job

    def _try_reserve(self, job: Job) -> bool:
        """Reserve budget for ``job`` and move it to the ready list;
        False (untouched) when the reservation would overflow."""
        if self.stats.reserved_bytes + job.peak_ram_bytes > self._budget:
            return False
        self.stats.reserved_bytes += job.peak_ram_bytes
        self.stats.peak_reserved_bytes = max(self.stats.peak_reserved_bytes,
                                             self.stats.reserved_bytes)
        job.state = "admitted"
        job.admitted_at = self._clock()
        self._ready.append(job)
        return True

    def _promote(self) -> None:
        """Drain the wait queue into freed budget, arrival order.  A job
        that still doesn't fit is skipped, not head-of-line blocking —
        same-structure jobs price identically, so order *within a
        structure class* is always preserved."""
        for job in list(self._wait):
            if self._try_reserve(job):
                self._wait.remove(job)

    # -- scheduling ------------------------------------------------------------
    def _merge_cap(self, sess: _Session, want: int) -> int:
        """Lane cap for one merged batch: `max_feasible_lanes` under the
        global budget.  Reservations already guarantee feasibility (the
        working-set model is linear in lanes), so this is a defensive
        floor, not the usual binding constraint."""
        plan = sess.plan
        max_m = max((st.layout.m for st in plan.stages), default=0)
        bpa = estimate_bytes_per_amp(plan.b_r, plan.compression)
        return max_feasible_lanes(plan.n_qubits, plan.local_bits, max_m,
                                  plan.pipeline_depth, bpa, self._budget,
                                  want)

    def step(self) -> list[Job]:
        """Run one scheduling round; returns the jobs finished in it.

        The round takes the *oldest* admitted job, merges every other
        admitted job of the same structure class (arrival order) into
        one ``run_batch`` lane stack up to the feasible-lane cap,
        executes it on the pooled session, captures each lane's
        requested readout eagerly, releases the reservations and
        promotes waiting jobs into the freed budget.  Returns ``[]``
        when nothing is admitted (idle, or everything queued is still
        over budget — impossible unless jobs are also running
        elsewhere).
        """
        with self._lock:
            if self._closed:
                raise RuntimeError("SimService is closed")
            self._promote()
            if not self._ready:
                return []
            head = self._ready[0]
            sess = self._sessions[head.structure]
            same = [j for j in self._ready if j.structure == head.structure]
            batch = same[:self._merge_cap(sess, len(same))]
            for job in batch:
                self._ready.remove(job)
            self._run_batch(sess, batch)
            for job in batch:
                self.stats.reserved_bytes -= job.peak_ram_bytes
                sess.n_pending -= 1
            sess.last_used = self._clock()
            self._sessions.move_to_end(head.structure)   # keep LRU order
            self._promote()
            self._evict_idle()
            return batch

    def drain(self) -> list[Job]:
        """Run scheduling rounds until no job is admitted or queued;
        returns every job finished during the drain, completion order."""
        finished: list[Job] = []
        while True:
            done = self.step()
            if not done:
                break
            finished.extend(done)
        return finished

    # -- execution -------------------------------------------------------------
    def _run_batch(self, sess: _Session, batch: list[Job]) -> None:
        now = self._clock()
        for job in batch:
            job.state = "running"
            job.started_at = now
        stochastic = sess.sim.circuit.is_stochastic
        seeds = [(job.seed if job.seed is not None else 0) if stochastic
                 else None for job in batch]
        self.stats.n_batches += 1
        self.stats.merge_widths.append(len(batch))
        self.stats.max_merge_width = max(self.stats.max_merge_width,
                                         len(batch))
        if len(batch) > 1:
            self.stats.n_merged_jobs += len(batch)
        try:
            # every dispatch goes through run_batch — width 1 included —
            # so a lane's float path is identical whether it ran solo or
            # merged (the batched executor treats lanes as independent
            # rows), keeping merge results bitwise-equal to solo runs
            result = sess.sim.run_batch([j.params for j in batch],
                                        seeds=seeds)
        except _JOB_FAILURES as e:
            now = self._clock()
            for job in batch:
                job.state = "failed"
                job.error = f"{type(e).__name__}: {e}"
                job.finished_at = now
                self.stats.n_failed += 1
            return
        for lane, job in enumerate(batch):
            view = result[lane]
            if job.shots:
                job.result["counts"] = view.sample(job.shots,
                                                   seed=job.seed or 0)
            if job.observable is not None:
                job.result["expectation"] = view.expectation(job.observable)
            if job.readout is not None:
                job.result["readout"] = job.readout(view)
            job.merge_width = len(batch)
            job.state = "done"
            job.finished_at = self._clock()
            self.stats.n_completed += 1
