"""Fidelity and error metrics (paper §5.3)."""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

__all__ = ["fidelity", "norm", "max_pointwise_rel_error"]


def fidelity(ideal, sim) -> float:
    """|<ideal|sim>| — the paper's metric (Fig. 8)."""
    ideal = jnp.asarray(ideal).reshape(-1)
    sim = jnp.asarray(sim).reshape(-1).astype(ideal.dtype)
    return float(jnp.abs(jnp.vdot(ideal, sim)))


def norm(state) -> float:
    return float(jnp.sqrt(jnp.sum(jnp.abs(jnp.asarray(state)) ** 2)))


def max_pointwise_rel_error(x, xhat, zero_floor: float = 0.0) -> float:
    """max |xhat - x| / |x| over elements with |x| > zero_floor."""
    x = np.asarray(x, dtype=np.float64).reshape(-1)
    xhat = np.asarray(xhat, dtype=np.float64).reshape(-1)
    mask = np.abs(x) > zero_floor
    if not mask.any():
        return 0.0
    return float(np.max(np.abs(xhat[mask] - x[mask]) / np.abs(x[mask])))
