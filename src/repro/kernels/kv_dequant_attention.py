"""Pallas TPU kernel: decode attention reading a pwrel-COMPRESSED KV cache.

The deployment half of EXPERIMENTS.md §Perf climb 1: at the XLA-graph
level, compressed-KV decode shows the *fit* win (uint8 codes halve the
cache footprint) but dequantizing to a bf16 copy before attention gives
back the bandwidth.  This kernel fuses the paper's §4.3 dequantization
into the attention read itself — codes/signs/scale tiles stream HBM→VMEM
(≈2.11× fewer bytes than bf16 K/V) and are expanded in-register, so the
decode memory roofline drops by the compression ratio.

Layout per (BG)-flattened head:
    q      (BG, rep, hd)          f32   query for this step
    codes  (BG, T, hd) uint8      0 = exact-zero escape (k and v)
    signs  (BG, T, hd/8) uint8    packed sign bitmap
    scale  (BG, T, 1)   f32       per-(token, head) log2 max
    out    (BG, rep, hd) f32

Grid: (BG,); the kernel loops over T tiles with running online-softmax
accumulators (same structure as flash_attention.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["kv_dequant_decode_attention", "KV_RANGE", "KV_STEP"]

KV_RANGE = 16.0            # log2 units below the per-(token,head) max
KV_STEP = KV_RANGE / 254.0
_CODE_MAX = 255.0
NEG_INF = -2.0 ** 30


def _dequant(codes, signs_packed, scale):
    """codes (T, hd) u8 + signs (T, hd/8) u8 + scale (T, 1) -> f32 (T, hd)."""
    T, hd = codes.shape
    d = _CODE_MAX - codes.astype(jnp.float32)
    mag = jnp.exp2(scale - d * jnp.float32(KV_STEP))
    mag = jnp.where(codes == 0, 0.0, mag)
    bits = (signs_packed[:, :, None] >>
            jax.lax.broadcasted_iota(jnp.uint8, (T, hd // 8, 8), 2)) & 1
    signs = bits.reshape(T, hd) == 1
    return jnp.where(signs, -mag, mag)


def _kernel(k_tile: int, q_ref, ck_ref, sk_ref, lk_ref, cv_ref, sv_ref,
            lv_ref, pos_ref, o_ref):
    q = q_ref[0]                                   # (rep, hd) f32
    rep, hd = q.shape
    T = ck_ref.shape[1]
    pos = pos_ref[0, 0]
    scale = hd ** -0.5

    n_tiles = T // k_tile

    def body(t, carry):
        acc, m, l = carry
        sl = lambda ref, w: jax.lax.dynamic_slice(   # noqa: E731
            ref[0], (t * k_tile, 0), (k_tile, w))
        k = _dequant(sl(ck_ref, hd), sl(sk_ref, hd // 8), sl(lk_ref, 1))
        v = _dequant(sl(cv_ref, hd), sl(sv_ref, hd // 8), sl(lv_ref, 1))
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
        j = t * k_tile + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        s = jnp.where(j <= pos, s, NEG_INF)        # causal length mask
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((rep, hd), jnp.float32)
    m0 = jnp.full((rep,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((rep,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_tiles, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def kv_dequant_decode_attention(q, codes_k, signs_k, scale_k,
                                codes_v, signs_v, scale_v, pos, *,
                                k_tile: int = 512,
                                interpret: bool = True):
    """q (BG, rep, hd) f32; cache leaves (BG, T, ...) -> out (BG, rep, hd).

    ``pos``: scalar int32 — last valid cache index (causal mask j <= pos).
    """
    BG, rep, hd = q.shape
    T = codes_k.shape[1]
    tk = min(k_tile, T)
    while T % tk:
        tk //= 2
    grid = (BG,)
    full = lambda w, dt: pl.BlockSpec((1, T, w), lambda b: (b, 0, 0))  # noqa: E731
    fn = pl.pallas_call(
        functools.partial(_kernel, tk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, rep, hd), lambda b: (b, 0, 0)),
            full(hd, jnp.uint8), full(hd // 8, jnp.uint8), full(1, jnp.float32),
            full(hd, jnp.uint8), full(hd // 8, jnp.uint8), full(1, jnp.float32),
            pl.BlockSpec((1, 1), lambda b: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, rep, hd), lambda b: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((BG, rep, hd), jnp.float32),
        interpret=interpret,
    )
    pos2d = jnp.asarray(pos, jnp.int32).reshape(1, 1)
    return fn(q, codes_k, signs_k, scale_k, codes_v, signs_v, scale_v, pos2d)
