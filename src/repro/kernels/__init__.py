"""Pallas TPU kernels (validated via interpret=True on CPU; see ops.py)."""
