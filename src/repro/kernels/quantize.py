"""Pallas TPU kernels: point-wise relative-error quantize / dequantize.

The device-resident half of the paper's §4.3 compressor — the part whose
bandwidth matters (the lossless stage runs on host, as bitcomp's does).

Quantize, per (TR, 128) VMEM tile of a f32 plane (VPU elementwise work):

  1. sign bits  s = x < 0
  2. codes      c = CODE_MAX - round((l_max - log2|x|)/step), 0 = exact zero
  3. sign bitmap packed 32 lanes -> one int32 word (4 words / 128 lanes) —
     the TPU analogue of the paper's warp-ballot pack
  4. per-tile uniformity flags (all-zero codes / all-0 signs / all-1 signs)
     — the "pre-scan" that lets the host RLE uniform bitmap chunks without
     touching them again.

``l_max`` (the block's max log2|x|) is a scalar prologue computed by XLA
(one fused reduction) and passed in as a (1, 1) operand.

Dequantize is the inverse: codes + unpacked signs + l_max -> f32 plane.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from ..compression.pwrel import CODE_MAX

__all__ = ["quantize_tiles", "dequantize_tiles", "DEFAULT_TILE_ROWS"]

DEFAULT_TILE_ROWS = 8          # (8, 128) f32 = one native VREG tile
_LANES = 128
_WORDS = _LANES // 32          # packed int32 bitmap words per row


def _quantize_kernel(step: float, x_ref, lmax_ref, codes_ref, packed_ref,
                     flags_ref):
    x = x_ref[...]                                   # (TR, 128) f32
    l_max = lmax_ref[0, 0]
    absx = jnp.abs(x)
    signs = x < 0.0

    L = jnp.log2(jnp.maximum(absx, 1e-45))
    d = jnp.round((l_max - L) / jnp.float32(step))
    codes_f = jnp.float32(CODE_MAX) - d
    codes_f = jnp.where(absx <= 0.0, 0.0, codes_f)
    codes = jnp.clip(codes_f, 0.0, float(CODE_MAX)).astype(jnp.int32)
    codes_ref[...] = codes

    # -- ballot-style bitmap pack: 32 lanes -> int32 word -------------------
    tr = x.shape[0]
    sbits = signs.astype(jnp.int32).reshape(tr, _WORDS, 32)
    lane = jax.lax.broadcasted_iota(jnp.int32, (tr, _WORDS, 32), 2)
    packed_ref[...] = jnp.sum(sbits << lane, axis=-1).astype(jnp.int32)

    # -- per-tile uniformity flags (pre-scan) --------------------------------
    all_zero = jnp.all(codes == 0).astype(jnp.int32)
    sign_none = jnp.all(~signs).astype(jnp.int32)
    sign_all = jnp.all(signs).astype(jnp.int32)
    flags_ref[0, 0] = all_zero
    flags_ref[0, 1] = sign_none
    flags_ref[0, 2] = sign_all


def quantize_tiles(x: jax.Array, l_max: jax.Array, step: float,
                   *, tile_rows: int = DEFAULT_TILE_ROWS,
                   interpret: bool = True):
    """x: (rows, 128) f32; l_max: (1,1) f32 -> (codes i32, packed i32, flags)."""
    rows, lanes = x.shape
    assert lanes == _LANES, f"plane must be (rows, {_LANES}), got {x.shape}"
    tr = min(tile_rows, rows)
    while rows % tr:
        tr //= 2
    grid = (rows // tr,)
    fn = pl.pallas_call(
        lambda *refs: _quantize_kernel(step, *refs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tr, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((tr, _WORDS), lambda i: (i, 0)),
            pl.BlockSpec((1, 3), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, _LANES), jnp.int32),
            jax.ShapeDtypeStruct((rows, _WORDS), jnp.int32),
            jax.ShapeDtypeStruct((rows // tr, 3), jnp.int32),
        ],
        interpret=interpret,
    )
    return fn(x, l_max)


def _dequantize_kernel(step: float, codes_ref, packed_ref, lmax_ref, x_ref):
    codes = codes_ref[...]                           # (TR, 128) i32
    l_max = lmax_ref[0, 0]
    tr = codes.shape[0]
    packed = packed_ref[...]                         # (TR, 4) i32
    lane = jax.lax.broadcasted_iota(jnp.int32, (tr, _WORDS, 32), 2)
    sbits = (packed[:, :, None] >> lane) & 1
    signs = sbits.reshape(tr, _LANES) == 1

    d = jnp.float32(CODE_MAX) - codes.astype(jnp.float32)
    mag = jnp.exp2(l_max - d * jnp.float32(step))
    mag = jnp.where(codes == 0, 0.0, mag)
    x_ref[...] = jnp.where(signs, -mag, mag).astype(jnp.float32)


def dequantize_tiles(codes: jax.Array, packed_signs: jax.Array,
                     l_max: jax.Array, step: float,
                     *, tile_rows: int = DEFAULT_TILE_ROWS,
                     interpret: bool = True) -> jax.Array:
    """codes (rows,128) i32 + packed signs (rows,4) i32 -> (rows,128) f32."""
    rows, lanes = codes.shape
    assert lanes == _LANES
    tr = min(tile_rows, rows)
    while rows % tr:
        tr //= 2
    grid = (rows // tr,)
    fn = pl.pallas_call(
        lambda *refs: _dequantize_kernel(step, *refs),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tr, _LANES), lambda i: (i, 0)),
            pl.BlockSpec((tr, _WORDS), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((tr, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _LANES), jnp.float32),
        interpret=interpret,
    )
    return fn(codes, packed_signs, l_max)
