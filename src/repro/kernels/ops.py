"""Jit'd public wrappers around the Pallas kernels.

``interpret`` defaults to True (this container is CPU-only); on a real TPU
deployment the same calls run compiled with ``interpret=False`` — the env
var ``REPRO_PALLAS_COMPILED=1`` flips the default for the whole process.
"""
from __future__ import annotations

import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from ..compression.pwrel import log_step
from . import gate_apply as _ga
from . import pack as _pk
from . import quantize as _qz

__all__ = ["apply_fused_gate", "quantize_block", "dequantize_block",
           "pack_codes", "unpack_codes",
           "pack_sign_bitmap", "unpack_sign_bitmap",
           "default_interpret"]


def default_interpret() -> bool:
    return os.environ.get("REPRO_PALLAS_COMPILED", "0") != "1"


# --------------------------------------------------------------------------
# fused gate application (engine's use_kernel path)
# --------------------------------------------------------------------------

def apply_fused_gate(amps: jax.Array, mat: jax.Array,
                     vqubits: tuple[int, ...], nv: int,
                     diag: bool, *, interpret: bool | None = None) -> jax.Array:
    """Apply a fused unitary to a flat 2^nv complex group array.

    Host side does the qubit-minor transpose (an XLA copy); the Pallas
    kernel does the arithmetic on re/im planes.
    ``mat`` is the (2^k, 2^k) unitary — or its (2^k,) diagonal if ``diag``.
    """
    if interpret is None:
        interpret = default_interpret()
    k = len(vqubits)
    K = 2 ** k
    axes = [nv - 1 - q for q in vqubits]
    rest = [a for a in range(nv) if a not in axes]
    perm = rest + [axes[j] for j in range(k - 1, -1, -1)]
    t = amps.reshape((2,) * nv).transpose(perm).reshape(-1, K)
    ar, ai = jnp.real(t).astype(jnp.float32), jnp.imag(t).astype(jnp.float32)
    if diag:
        dr = jnp.real(mat).astype(jnp.float32)
        di = jnp.imag(mat).astype(jnp.float32)
        cr, ci = _ga.diag_apply(ar, ai, dr, di, interpret=interpret)
    else:
        b = mat.T  # C = A @ U^T
        br = jnp.real(b).astype(jnp.float32)
        bi = jnp.imag(b).astype(jnp.float32)
        cr, ci = _ga.gemm_planes(ar, ai, br, bi, interpret=interpret)
    out = (cr + 1j * ci).astype(amps.dtype)
    inv = np.argsort(np.asarray(perm))
    return out.reshape([2] * nv).transpose(list(inv)).reshape(-1)


# --------------------------------------------------------------------------
# pwrel quantize / dequantize (device half of the compressor)
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("step", "interpret"))
def _quantize_jit(x2d, step, interpret):
    max_abs = jnp.max(jnp.abs(x2d))
    l_max = jnp.where(max_abs > 0,
                      jnp.log2(jnp.maximum(max_abs, 1e-45)), 0.0)
    l_max = l_max.reshape(1, 1).astype(jnp.float32)
    codes, packed, flags = _qz.quantize_tiles(x2d, l_max, step,
                                              interpret=interpret)
    return codes, packed, flags, l_max


def quantize_block(x: jax.Array, b_r: float,
                   *, interpret: bool | None = None):
    """f32 plane (N,) with N % 128 == 0 -> (codes u16 (N,), packed signs
    (N/128, 4) i32, tile flags, l_max scalar)."""
    if interpret is None:
        interpret = default_interpret()
    x = jnp.asarray(x, jnp.float32)
    n = x.shape[0]
    assert n % 128 == 0, f"plane size {n} not lane-aligned"
    x2d = x.reshape(n // 128, 128)
    codes, packed, flags, l_max = _quantize_jit(x2d, log_step(b_r), interpret)
    return (codes.reshape(-1).astype(jnp.uint16), packed, flags,
            l_max.reshape(()))


@partial(jax.jit, static_argnames=("step", "interpret"))
def _dequantize_jit(codes2d, packed, l_max, step, interpret):
    return _qz.dequantize_tiles(codes2d, packed,
                                l_max.reshape(1, 1).astype(jnp.float32),
                                step, interpret=interpret)


def dequantize_block(codes: jax.Array, packed_signs: jax.Array,
                     l_max, b_r: float,
                     *, interpret: bool | None = None) -> jax.Array:
    if interpret is None:
        interpret = default_interpret()
    codes = jnp.asarray(codes).astype(jnp.int32)
    n = codes.shape[0]
    out = _dequantize_jit(codes.reshape(n // 128, 128), packed_signs,
                          jnp.asarray(l_max, jnp.float32), log_step(b_r),
                          interpret)
    return out.reshape(-1)


# --------------------------------------------------------------------------
# boundary packing (device wire format of the §4.3 codec)
# --------------------------------------------------------------------------

@partial(jax.jit, static_argnames=("interpret",))
def _pack_codes_jit(codes2d, interpret):
    return _pk.pack_codes_tiles(codes2d, interpret=interpret)


def pack_codes(codes: jax.Array, *, interpret: bool | None = None):
    """codes (N,) in [0, 65535], N % 128 == 0 -> (N/128, 64) i32 u16-pair
    words; a little-endian host view of the result is the row-major uint16
    code stream."""
    if interpret is None:
        interpret = default_interpret()
    codes = jnp.asarray(codes).astype(jnp.int32)
    n = codes.shape[0]
    assert n % 128 == 0, f"code stream size {n} not lane-aligned"
    return _pack_codes_jit(codes.reshape(n // 128, 128), interpret)


@partial(jax.jit, static_argnames=("interpret",))
def _unpack_codes_jit(packed, interpret):
    return _pk.unpack_codes_tiles(packed, interpret=interpret)


def unpack_codes(packed: jax.Array,
                 *, interpret: bool | None = None) -> jax.Array:
    """(rows, 64) i32 u16-pair words -> (rows*128,) i32 codes."""
    if interpret is None:
        interpret = default_interpret()
    return _unpack_codes_jit(packed, interpret).reshape(-1)


@partial(jax.jit, static_argnames=("interpret",))
def _pack_bitmap_jit(bits2d, interpret):
    return _pk.pack_bitmap_tiles(bits2d, interpret=interpret)


def pack_sign_bitmap(bits: jax.Array,
                     *, interpret: bool | None = None) -> jax.Array:
    """bits (N,) bool/int, N % 128 == 0 -> (N/128, 4) i32 ballot words
    (LSB = lowest lane), matching the pack fused into ``quantize_block``."""
    if interpret is None:
        interpret = default_interpret()
    bits = jnp.asarray(bits).astype(jnp.int32)
    n = bits.shape[0]
    assert n % 128 == 0, f"bitmap size {n} not lane-aligned"
    return _pack_bitmap_jit(bits.reshape(n // 128, 128), interpret)


@partial(jax.jit, static_argnames=("interpret",))
def _unpack_bitmap_jit(packed, interpret):
    return _pk.unpack_bitmap_tiles(packed, interpret=interpret)


def unpack_sign_bitmap(packed: jax.Array,
                       *, interpret: bool | None = None) -> jax.Array:
    """(rows, 4) i32 ballot words -> (rows*128,) bool signs."""
    if interpret is None:
        interpret = default_interpret()
    return _unpack_bitmap_jit(packed, interpret).reshape(-1) == 1
