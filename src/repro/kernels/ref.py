"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose vs these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..compression.pwrel import CODE_MAX

__all__ = [
    "gemm_planes_ref", "diag_apply_ref",
    "quantize_tiles_ref", "dequantize_tiles_ref",
]

_LANES = 128
_WORDS = 4


def gemm_planes_ref(ar, ai, br, bi):
    cr = ar @ br - ai @ bi
    ci = ar @ bi + ai @ br
    return cr.astype(jnp.float32), ci.astype(jnp.float32)


def diag_apply_ref(ar, ai, dr, di):
    dr = dr.reshape(1, -1)
    di = di.reshape(1, -1)
    cr = ar * dr - ai * di
    ci = ar * di + ai * dr
    return cr.astype(jnp.float32), ci.astype(jnp.float32)


def quantize_tiles_ref(x, l_max, step, tile_rows: int = 8):
    """Mirror of quantize.quantize_tiles (same f32 arithmetic, same layout)."""
    rows, lanes = x.shape
    assert lanes == _LANES
    l_max = jnp.asarray(l_max).reshape(())
    absx = jnp.abs(x)
    signs = x < 0.0
    L = jnp.log2(jnp.maximum(absx, 1e-45))
    d = jnp.round((l_max - L) / jnp.float32(step))
    codes_f = jnp.float32(CODE_MAX) - d
    codes_f = jnp.where(absx <= 0.0, 0.0, codes_f)
    codes = jnp.clip(codes_f, 0.0, float(CODE_MAX)).astype(jnp.int32)

    sbits = signs.astype(jnp.int32).reshape(rows, _WORDS, 32)
    lane = jax.lax.broadcasted_iota(jnp.int32, (rows, _WORDS, 32), 2)
    packed = jnp.sum(sbits << lane, axis=-1).astype(jnp.int32)

    tr = min(tile_rows, rows)
    while rows % tr:
        tr //= 2
    n_tiles = rows // tr
    codes_t = codes.reshape(n_tiles, tr * _LANES)
    signs_t = signs.reshape(n_tiles, tr * _LANES)
    flags = jnp.stack([
        jnp.all(codes_t == 0, axis=1),
        jnp.all(~signs_t, axis=1),
        jnp.all(signs_t, axis=1),
    ], axis=1).astype(jnp.int32)
    return codes, packed, flags


def dequantize_tiles_ref(codes, packed_signs, l_max, step):
    rows = codes.shape[0]
    l_max = jnp.asarray(l_max).reshape(())
    lane = jax.lax.broadcasted_iota(jnp.int32, (rows, _WORDS, 32), 2)
    sbits = (packed_signs[:, :, None] >> lane) & 1
    signs = sbits.reshape(rows, _LANES) == 1
    d = jnp.float32(CODE_MAX) - codes.astype(jnp.float32)
    mag = jnp.exp2(l_max - d * jnp.float32(step))
    mag = jnp.where(codes == 0, 0.0, mag)
    return jnp.where(signs, -mag, mag).astype(jnp.float32)
