"""Pallas TPU kernel: blockwise online-softmax (flash) causal attention.

Why it exists (roofline, EXPERIMENTS.md §Perf): the XLA einsum attention
the models lower by default materializes the full (S, S) score matrix —
at train_4k that is the dominant *memory* term for long-seq cells, and
causal masking wastes half the MXU FLOPs.  This kernel streams K/V tiles
through VMEM with running (max, sum) accumulators, never materializing
scores, and skips fully-masked K tiles (the causal upper triangle), which
halves the attention FLOPs.

Layout: q/k/v (BH, S, hd) — batch*heads flattened into the grid's first
dim.  Grid (BH, S/TQ); each program owns one query tile and loops over
its K tiles with `jax.lax.fori_loop`.  hd padded to a lane multiple by
ops.py.  f32 accumulation throughout.

Validated in interpret mode against ref.flash_attention_ref (tests/
test_kernels_flash.py); GQA is handled by the caller replicating KV heads
(zero-copy broadcast under XLA).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["flash_attention", "DEFAULT_Q_TILE", "DEFAULT_K_TILE"]

DEFAULT_Q_TILE = 256
DEFAULT_K_TILE = 256
NEG_INF = -2.0 ** 30


def _flash_kernel(scale: float, k_tile: int, causal: bool,
                  q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0] * jnp.float32(scale)            # (TQ, hd)
    TQ, hd = q.shape
    S = k_ref.shape[1]
    iq = pl.program_id(1)
    q_start = iq * TQ

    n_kt = S // k_tile
    # causal: K tiles beyond this query tile's end are fully masked
    if causal:
        last = (q_start + TQ + k_tile - 1) // k_tile
        n_live = jnp.minimum(n_kt, last)
    else:
        n_live = n_kt

    def body(kt, carry):
        acc, m, l = carry
        k = jax.lax.dynamic_slice(k_ref[0], (kt * k_tile, 0), (k_tile, hd))
        v = jax.lax.dynamic_slice(v_ref[0], (kt * k_tile, 0), (k_tile, hd))
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (TQ, TK)
        if causal:
            qi = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
            kj = kt * k_tile + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
            s = jnp.where(qi >= kj, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = alpha * l + jnp.sum(p, axis=1)
        acc_new = acc * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        return acc_new, m_new, l_new

    acc0 = jnp.zeros((TQ, hd), jnp.float32)
    m0 = jnp.full((TQ,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((TQ,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, n_live, body, (acc0, m0, l0))
    o_ref[0] = (acc / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True,
                    q_tile: int = DEFAULT_Q_TILE,
                    k_tile: int = DEFAULT_K_TILE,
                    interpret: bool = True) -> jax.Array:
    """q/k/v: (BH, S, hd) f32 -> (BH, S, hd) f32 (softmax(qk^T/sqrt)v)."""
    BH, S, hd = q.shape
    tq = min(q_tile, S)
    while S % tq:
        tq //= 2
    tk = min(k_tile, S)
    while S % tk:
        tk //= 2
    scale = hd ** -0.5
    grid = (BH, S // tq)
    fn = pl.pallas_call(
        functools.partial(_flash_kernel, scale, tk, causal),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tq, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, S, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, S, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, tq, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((BH, S, hd), q.dtype),
        interpret=interpret,
    )
    return fn(q, k, v)
