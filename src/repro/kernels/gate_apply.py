"""Pallas TPU kernel: fused-gate application as an MXU GEMM.

TPU adaptation of SV-Sim's scattered pair updates (DESIGN.md §2): after the
host transposes the group tensor so the fused gate's k virtual qubits are
the minor-most bits, applying the 2^k x 2^k unitary is

    C = A @ B,   A: (R, K) group amplitudes, B = U^T: (K, K), K = 2^k.

With the fusion width f = 7, K = 128 — one MXU tile.  Complex arithmetic
runs as four real GEMMs over re/im planes (the MXU has no complex type):

    Cr = Ar Br - Ai Bi,   Ci = Ar Bi + Ai Br.

Grid: 1-D over row tiles of A; B is broadcast to every program instance
and lives in VMEM for the whole call (K=128 => 2 * 64 KiB planes).
A/C tiles are (TR, K) f32 in VMEM; TR = 256 keeps the working set
(2*(TR*K) in + 2*(TR*K) out + 2*K*K weights) * 4 B ~= 1.2 MiB << 16 MiB VMEM.

There is also a diagonal fast path (``diag_apply``): stage partitions of
phase-heavy circuits (QFT's controlled-phase ladders) fuse into diagonal
unitaries, for which the update is an elementwise complex multiply on the
VPU — no MXU pass at all.

``gemm_planes_mid`` is the transpose-eliding sibling used by the stage
scheduler (core/schedule.py): when a gate's qubit axes form a contiguous
block that is *not* minor-most, the group tensor reshapes to
(outer, K, inner) and the update is the batched left-contraction
C[o] = U @ A[o] — the K axis stays in the sublane dimension and ``inner``
stays in the lanes, so no data movement happens at all.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gemm_planes", "gemm_planes_batch", "gemm_planes_mid",
           "diag_apply", "DEFAULT_ROW_TILE"]

DEFAULT_ROW_TILE = 256


def _gemm_kernel(ar_ref, ai_ref, br_ref, bi_ref, cr_ref, ci_ref):
    ar = ar_ref[...]
    ai = ai_ref[...]
    br = br_ref[...]
    bi = bi_ref[...]
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    cr_ref[...] = dot(ar, br) - dot(ai, bi)
    ci_ref[...] = dot(ar, bi) + dot(ai, br)


def gemm_planes(ar: jax.Array, ai: jax.Array, br: jax.Array, bi: jax.Array,
                *, row_tile: int = DEFAULT_ROW_TILE,
                interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """(R, K) x (K, K) complex GEMM over separate re/im f32 planes."""
    R, K = ar.shape
    assert br.shape == (K, K) and bi.shape == (K, K) and ai.shape == (R, K)
    tr = min(row_tile, R)
    while R % tr:       # R, tr are powers of two in every caller; keep safe
        tr //= 2
    grid = (R // tr,)
    a_spec = pl.BlockSpec((tr, K), lambda i: (i, 0))
    b_spec = pl.BlockSpec((K, K), lambda i: (0, 0))
    out_spec = pl.BlockSpec((tr, K), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((R, K), jnp.float32)] * 2
    fn = pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[a_spec, a_spec, b_spec, b_spec],
        out_specs=[out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(ar, ai, br, bi)


def _gemm_batch_kernel(ar_ref, ai_ref, br_ref, bi_ref, cr_ref, ci_ref):
    ar = ar_ref[0]            # (TR, K) row tile of one lane
    ai = ai_ref[0]
    br = br_ref[0]            # (K, K) = lane's own U^T
    bi = bi_ref[0]
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    cr_ref[0] = dot(ar, br) - dot(ai, bi)
    ci_ref[0] = dot(ar, bi) + dot(ai, br)


def gemm_planes_batch(ar: jax.Array, ai: jax.Array,
                      br: jax.Array, bi: jax.Array,
                      *, row_tile: int = DEFAULT_ROW_TILE,
                      interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """(L, R, K) x (L, K, K) lane-batched complex GEMM on re/im planes.

    The batched-execution sibling of :func:`gemm_planes`: lane ``l`` of A
    contracts against lane ``l`` of B (each lane of a parameter-sweep /
    noise-trajectory batch carries its own unitary), with the grid 2-D
    over (lane, row tiles) so the whole batch is one kernel dispatch.
    ``br``/``bi`` are the per-lane U^T planes, like :func:`gemm_planes`.
    """
    L, R, K = ar.shape
    assert br.shape == (L, K, K) and bi.shape == (L, K, K) \
        and ai.shape == (L, R, K)
    tr = min(row_tile, R)
    while R % tr:       # R, tr are powers of two in every caller; keep safe
        tr //= 2
    grid = (L, R // tr)
    a_spec = pl.BlockSpec((1, tr, K), lambda lane, i: (lane, i, 0))
    b_spec = pl.BlockSpec((1, K, K), lambda lane, i: (lane, 0, 0))
    out_shape = [jax.ShapeDtypeStruct((L, R, K), jnp.float32)] * 2
    fn = pl.pallas_call(
        _gemm_batch_kernel,
        grid=grid,
        in_specs=[a_spec, a_spec, b_spec, b_spec],
        out_specs=[a_spec, a_spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(ar, ai, br, bi)


def _gemm_mid_kernel(ar_ref, ai_ref, br_ref, bi_ref, cr_ref, ci_ref):
    ar = ar_ref[0]            # (K, TI) slab of one outer batch
    ai = ai_ref[0]
    br = br_ref[...]          # (K, K) = U, broadcast to every program
    bi = bi_ref[...]
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    cr_ref[0] = dot(br, ar) - dot(bi, ai)
    ci_ref[0] = dot(br, ai) + dot(bi, ar)


def gemm_planes_mid(ar: jax.Array, ai: jax.Array,
                    br: jax.Array, bi: jax.Array,
                    *, inner_tile: int = DEFAULT_ROW_TILE,
                    interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """(O, K, I) complex batched left-GEMM C[o] = U @ A[o] on re/im planes.

    ``br``/``bi`` are U's planes (NOT transposed — the contraction is over
    A's middle axis).  Grid is 2-D over (outer batch, inner tiles); the
    inner axis stays minor so the existing memory layout feeds the MXU
    with no transpose.
    """
    O, K, I = ar.shape
    assert br.shape == (K, K) and bi.shape == (K, K) and ai.shape == (O, K, I)
    ti = min(inner_tile, I)
    while I % ti:       # O, K, I are powers of two in every caller
        ti //= 2
    grid = (O, I // ti)
    a_spec = pl.BlockSpec((1, K, ti), lambda o, t: (o, 0, t))
    b_spec = pl.BlockSpec((K, K), lambda o, t: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((O, K, I), jnp.float32)] * 2
    fn = pl.pallas_call(
        _gemm_mid_kernel,
        grid=grid,
        in_specs=[a_spec, a_spec, b_spec, b_spec],
        out_specs=[a_spec, a_spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(ar, ai, br, bi)


def _diag_kernel(ar_ref, ai_ref, dr_ref, di_ref, cr_ref, ci_ref):
    ar = ar_ref[...]
    ai = ai_ref[...]
    dr = dr_ref[...]          # (1, K) broadcast row
    di = di_ref[...]
    cr_ref[...] = ar * dr - ai * di
    ci_ref[...] = ar * di + ai * dr


def diag_apply(ar: jax.Array, ai: jax.Array, dr: jax.Array, di: jax.Array,
               *, row_tile: int = DEFAULT_ROW_TILE,
               interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Elementwise complex multiply by a diagonal (1, K) — VPU path."""
    R, K = ar.shape
    tr = min(row_tile, R)
    while R % tr:
        tr //= 2
    grid = (R // tr,)
    a_spec = pl.BlockSpec((tr, K), lambda i: (i, 0))
    d_spec = pl.BlockSpec((1, K), lambda i: (0, 0))
    out_spec = pl.BlockSpec((tr, K), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((R, K), jnp.float32)] * 2
    fn = pl.pallas_call(
        _diag_kernel,
        grid=grid,
        in_specs=[a_spec, a_spec, d_spec, d_spec],
        out_specs=[out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(ar, ai, dr.reshape(1, K), di.reshape(1, K))
