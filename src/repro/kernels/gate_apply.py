"""Pallas TPU kernel: fused-gate application as an MXU GEMM.

TPU adaptation of SV-Sim's scattered pair updates (DESIGN.md §2): after the
host transposes the group tensor so the fused gate's k virtual qubits are
the minor-most bits, applying the 2^k x 2^k unitary is

    C = A @ B,   A: (R, K) group amplitudes, B = U^T: (K, K), K = 2^k.

With the fusion width f = 7, K = 128 — one MXU tile.  Complex arithmetic
runs as four real GEMMs over re/im planes (the MXU has no complex type):

    Cr = Ar Br - Ai Bi,   Ci = Ar Bi + Ai Br.

Grid: 1-D over row tiles of A; B is broadcast to every program instance
and lives in VMEM for the whole call (K=128 => 2 * 64 KiB planes).
A/C tiles are (TR, K) f32 in VMEM; TR = 256 keeps the working set
(2*(TR*K) in + 2*(TR*K) out + 2*K*K weights) * 4 B ~= 1.2 MiB << 16 MiB VMEM.

There is also a diagonal fast path (``diag_apply``): stage partitions of
phase-heavy circuits (QFT's controlled-phase ladders) fuse into diagonal
unitaries, for which the update is an elementwise complex multiply on the
VPU — no MXU pass at all.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["gemm_planes", "diag_apply", "DEFAULT_ROW_TILE"]

DEFAULT_ROW_TILE = 256


def _gemm_kernel(ar_ref, ai_ref, br_ref, bi_ref, cr_ref, ci_ref):
    ar = ar_ref[...]
    ai = ai_ref[...]
    br = br_ref[...]
    bi = bi_ref[...]
    dot = functools.partial(jnp.dot, preferred_element_type=jnp.float32)
    cr_ref[...] = dot(ar, br) - dot(ai, bi)
    ci_ref[...] = dot(ar, bi) + dot(ai, br)


def gemm_planes(ar: jax.Array, ai: jax.Array, br: jax.Array, bi: jax.Array,
                *, row_tile: int = DEFAULT_ROW_TILE,
                interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """(R, K) x (K, K) complex GEMM over separate re/im f32 planes."""
    R, K = ar.shape
    assert br.shape == (K, K) and bi.shape == (K, K) and ai.shape == (R, K)
    tr = min(row_tile, R)
    while R % tr:       # R, tr are powers of two in every caller; keep safe
        tr //= 2
    grid = (R // tr,)
    a_spec = pl.BlockSpec((tr, K), lambda i: (i, 0))
    b_spec = pl.BlockSpec((K, K), lambda i: (0, 0))
    out_spec = pl.BlockSpec((tr, K), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((R, K), jnp.float32)] * 2
    fn = pl.pallas_call(
        _gemm_kernel,
        grid=grid,
        in_specs=[a_spec, a_spec, b_spec, b_spec],
        out_specs=[out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(ar, ai, br, bi)


def _diag_kernel(ar_ref, ai_ref, dr_ref, di_ref, cr_ref, ci_ref):
    ar = ar_ref[...]
    ai = ai_ref[...]
    dr = dr_ref[...]          # (1, K) broadcast row
    di = di_ref[...]
    cr_ref[...] = ar * dr - ai * di
    ci_ref[...] = ar * di + ai * dr


def diag_apply(ar: jax.Array, ai: jax.Array, dr: jax.Array, di: jax.Array,
               *, row_tile: int = DEFAULT_ROW_TILE,
               interpret: bool = True) -> tuple[jax.Array, jax.Array]:
    """Elementwise complex multiply by a diagonal (1, K) — VPU path."""
    R, K = ar.shape
    tr = min(row_tile, R)
    while R % tr:
        tr //= 2
    grid = (R // tr,)
    a_spec = pl.BlockSpec((tr, K), lambda i: (i, 0))
    d_spec = pl.BlockSpec((1, K), lambda i: (0, 0))
    out_spec = pl.BlockSpec((tr, K), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((R, K), jnp.float32)] * 2
    fn = pl.pallas_call(
        _diag_kernel,
        grid=grid,
        in_specs=[a_spec, a_spec, d_spec, d_spec],
        out_specs=[out_spec, out_spec],
        out_shape=out_shape,
        interpret=interpret,
    )
    return fn(ar, ai, dr.reshape(1, K), di.reshape(1, K))
