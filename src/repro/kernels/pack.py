"""Pallas kernels: uint16 code packing and sign-bitmap pack/unpack.

These are the boundary-compression kernels of the device-resident codec
(paper §4.3): after ``quantize.py`` produces int32 codes and ballot-packed
sign words, these kernels shrink what actually crosses the host↔device
boundary —

* ``pack_codes_tiles``    — two uint16 codes per int32 word (lane pairs), so
  a little-endian host view of the words is exactly the row-major uint16
  code stream the lossless stage zlib-encodes.  2 bytes/element on the wire
  instead of 4.
* ``unpack_codes_tiles``  — the inverse, run before ``dequantize_tiles``.
* ``pack_bitmap_tiles`` / ``unpack_bitmap_tiles`` — standalone ballot-style
  sign packing (32 lanes -> one int32 word, LSB = lowest lane), the TPU
  analogue of the paper's warp-ballot bitmap build.  1 bit/element on the
  wire.  ``quantize_tiles`` fuses this pack into its kernel; the standalone
  version exists for decode-side symmetry and for reuse outside the
  quantizer.

Lane-pair packing uses an in-register ``reshape(tr, 64, 2)``; interpret
mode (this container) executes it exactly, and on hardware Mosaic lowers
small trailing-dim reshapes via lane shuffles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = [
    "pack_codes_tiles", "unpack_codes_tiles",
    "pack_bitmap_tiles", "unpack_bitmap_tiles",
    "CODE_WORDS", "BITMAP_WORDS",
]

_LANES = 128
CODE_WORDS = _LANES // 2       # int32 words per row of packed uint16 codes
BITMAP_WORDS = _LANES // 32    # int32 words per row of packed sign bits


def _tile_rows(rows: int, tile_rows: int) -> int:
    tr = min(tile_rows, rows)
    while rows % tr:
        tr //= 2
    return tr


def _pack_codes_kernel(codes_ref, out_ref):
    c = codes_ref[...]                       # (TR, 128) i32, values in u16 range
    tr = c.shape[0]
    pairs = c.reshape(tr, CODE_WORDS, 2)
    out_ref[...] = (pairs[..., 0] | (pairs[..., 1] << 16)).astype(jnp.int32)


def pack_codes_tiles(codes: jax.Array, *, tile_rows: int = 8,
                     interpret: bool = True) -> jax.Array:
    """codes (rows, 128) i32 in [0, 65535] -> (rows, 64) i32 u16-pair words."""
    rows, lanes = codes.shape
    assert lanes == _LANES, f"codes must be (rows, {_LANES}), got {codes.shape}"
    tr = _tile_rows(rows, tile_rows)
    return pl.pallas_call(
        _pack_codes_kernel,
        grid=(rows // tr,),
        in_specs=[pl.BlockSpec((tr, _LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tr, CODE_WORDS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, CODE_WORDS), jnp.int32),
        interpret=interpret,
    )(codes)


def _unpack_codes_kernel(packed_ref, codes_ref):
    w = packed_ref[...]                      # (TR, 64) i32
    tr = w.shape[0]
    lo = w & 0xFFFF
    hi = (w >> 16) & 0xFFFF
    codes_ref[...] = jnp.stack([lo, hi], axis=-1).reshape(tr, _LANES)


def unpack_codes_tiles(packed: jax.Array, *, tile_rows: int = 8,
                       interpret: bool = True) -> jax.Array:
    """(rows, 64) i32 u16-pair words -> (rows, 128) i32 codes."""
    rows, words = packed.shape
    assert words == CODE_WORDS
    tr = _tile_rows(rows, tile_rows)
    return pl.pallas_call(
        _unpack_codes_kernel,
        grid=(rows // tr,),
        in_specs=[pl.BlockSpec((tr, CODE_WORDS), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tr, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _LANES), jnp.int32),
        interpret=interpret,
    )(packed)


def _pack_bitmap_kernel(bits_ref, out_ref):
    bits = bits_ref[...]                     # (TR, 128) i32 in {0, 1}
    tr = bits.shape[0]
    b = bits.reshape(tr, BITMAP_WORDS, 32)
    lane = jax.lax.broadcasted_iota(jnp.int32, (tr, BITMAP_WORDS, 32), 2)
    out_ref[...] = jnp.sum(b << lane, axis=-1).astype(jnp.int32)


def pack_bitmap_tiles(bits: jax.Array, *, tile_rows: int = 8,
                      interpret: bool = True) -> jax.Array:
    """bits (rows, 128) i32/bool -> (rows, 4) i32 ballot words (LSB first)."""
    rows, lanes = bits.shape
    assert lanes == _LANES
    tr = _tile_rows(rows, tile_rows)
    return pl.pallas_call(
        _pack_bitmap_kernel,
        grid=(rows // tr,),
        in_specs=[pl.BlockSpec((tr, _LANES), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tr, BITMAP_WORDS), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, BITMAP_WORDS), jnp.int32),
        interpret=interpret,
    )(bits.astype(jnp.int32))


def _unpack_bitmap_kernel(packed_ref, bits_ref):
    w = packed_ref[...]                      # (TR, 4) i32
    tr = w.shape[0]
    lane = jax.lax.broadcasted_iota(jnp.int32, (tr, BITMAP_WORDS, 32), 2)
    bits_ref[...] = ((w[:, :, None] >> lane) & 1).reshape(tr, _LANES)


def unpack_bitmap_tiles(packed: jax.Array, *, tile_rows: int = 8,
                        interpret: bool = True) -> jax.Array:
    """(rows, 4) i32 ballot words -> (rows, 128) i32 bits in {0, 1}."""
    rows, words = packed.shape
    assert words == BITMAP_WORDS
    tr = _tile_rows(rows, tile_rows)
    return pl.pallas_call(
        _unpack_bitmap_kernel,
        grid=(rows // tr,),
        in_specs=[pl.BlockSpec((tr, BITMAP_WORDS), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((tr, _LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((rows, _LANES), jnp.int32),
        interpret=interpret,
    )(packed)
