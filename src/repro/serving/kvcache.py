"""Compressed KV cache — the paper's technique as a first-class LM feature.

BMQSIM's §4.3 scheme (sign bitmap + log2 transform + bounded quantization)
applied to decode KV caches: K/V live in HBM as uint8 log-codes + packed
sign bits + a per-(token, kv-head) scale, 2.11x smaller than bf16.  Decode
attention reads ~the whole cache every step, so its roofline is the memory
term — compressing the cache moves that term directly (see EXPERIMENTS.md
§Perf).

Layout per KV tensor (..., T, G, hd):
    codes  uint8 (..., T, G, hd)      0 = exact-zero escape
    signs  uint8 (..., T, G, hd/8)    paper's bitmap, packed 8/byte
    scale  f32   (..., T, G, 1)       per-(token, head) log2 max

Quantization step: 16 log2 units over 254 codes -> point-wise relative
error <= 2^(8/254) - 1 ~= 2.2% — far below attention's own bf16 noise
floor, verified by tests/test_serving.py against raw-cache decode.

Each cache entry is quantized ONCE when written (the paper's per-stage,
not per-gate, lesson: no accumulating requantization).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from ..models import attention as A
from ..models import transformer as T
from ..models.config import ModelConfig
from ..models.layers import rope, rope_cos_sin

__all__ = ["quantize_kv", "dequantize_kv", "compress_prefill_cache",
           "compressed_attention_decode", "make_compressed_decode_step",
           "kv_bytes_ratio"]

_RANGE = 16.0                 # log2 units of dynamic range below the max
_STEP = _RANGE / 254.0
_CODE_MAX = 255


def kv_bytes_ratio(hd: int) -> float:
    """bf16 bytes / compressed bytes per element."""
    return 2.0 / (1.0 + 1.0 / 8.0 + 4.0 / hd)


def quantize_kv(x: jax.Array) -> dict:
    """x: (..., T, G, hd) -> codes/signs/scale dict (see module doc)."""
    xf = x.astype(jnp.float32)
    absx = jnp.abs(xf)
    scale = jnp.max(jnp.log2(jnp.maximum(absx, 1e-30)), axis=-1,
                    keepdims=True)                       # (..., T, G, 1)
    L = jnp.log2(jnp.maximum(absx, 1e-30))
    d = jnp.round((scale - L) / _STEP)
    codes = jnp.clip(jnp.float32(_CODE_MAX) - d, 0.0, float(_CODE_MAX))
    codes = jnp.where(absx == 0.0, 0.0, codes).astype(jnp.uint8)
    signs = (xf < 0).astype(jnp.uint8)
    sh = signs.shape
    signs = signs.reshape(*sh[:-1], sh[-1] // 8, 8)
    weights = (2 ** jnp.arange(8, dtype=jnp.uint8))
    signs = jnp.sum(signs * weights, axis=-1).astype(jnp.uint8)
    return {"codes": codes, "signs": signs, "scale": scale}


def dequantize_kv(q: dict, dtype=jnp.bfloat16) -> jax.Array:
    codes = q["codes"]
    d = jnp.float32(_CODE_MAX) - codes.astype(jnp.float32)
    mag = jnp.exp2(q["scale"] - d * _STEP)
    mag = jnp.where(codes == 0, 0.0, mag)
    sh = codes.shape
    sbytes = q["signs"][..., None]                        # (..., hd/8, 1)
    bits = (sbytes >> jnp.arange(8, dtype=jnp.uint8)) & 1
    signs = bits.reshape(*sh[:-1], sh[-1]) == 1
    return jnp.where(signs, -mag, mag).astype(dtype)


def compress_prefill_cache(cache) -> dict:
    """Quantize every attention k/v leaf of a prefill-produced cache."""
    def conv(entry):
        if isinstance(entry, dict) and "k" in entry:
            out = dict(entry)
            for key in ("k", "v"):
                q = quantize_kv(entry[key])
                out[f"codes_{key}"] = q["codes"]
                out[f"signs_{key}"] = q["signs"]
                out[f"scale_{key}"] = q["scale"]
                del out[key]
            return out
        return entry

    def walk(node):
        if isinstance(node, dict) and ("k" in node):
            return conv(node)
        if isinstance(node, dict):
            return {k: walk(v) for k, v in node.items()}
        if isinstance(node, (list, tuple)):
            return type(node)(walk(v) for v in node)
        return node

    return walk(cache)


def _unpack(qc: dict, key: str) -> dict:
    return {"codes": qc[f"codes_{key}"], "signs": qc[f"signs_{key}"],
            "scale": qc[f"scale_{key}"]}


def _update_q(qc: dict, key: str, new: dict, pos) -> dict:
    out = dict(qc)
    for f in ("codes", "signs", "scale"):
        tgt = qc[f"{f}_{key}"]
        idx = (0, pos) + (0,) * (tgt.ndim - 2)
        out[f"{f}_{key}"] = jax.lax.dynamic_update_slice(tgt, new[f], idx)
    return out


def compressed_attention_decode(x, prm, cfg: ModelConfig, qcache: dict,
                                pos, *, window: int = 0):
    """attention_decode against a quantized cache; quantizes the new entry
    once and reads the cache through dequantization."""
    B = x.shape[0]
    Tlen = qcache["codes_k"].shape[1]
    ring = bool(window) and Tlen == window
    q, k, v = A._project_qkv(x, prm, cfg)
    posv = jnp.full((B, 1), pos, jnp.int32)
    cos, sin = rope_cos_sin(posv, cfg.hd, cfg.rope_theta)
    q = rope(q, cos, sin)
    k = rope(k, cos, sin)

    slot = jnp.mod(pos, Tlen) if ring else pos
    qcache = _update_q(qcache, "k", quantize_kv(k), slot)
    qcache = _update_q(qcache, "v", quantize_kv(v), slot)
    cache_k = dequantize_kv(_unpack(qcache, "k"))
    cache_v = dequantize_kv(_unpack(qcache, "v"))

    scores = A._gqa_scores(q, cache_k, cfg)
    j = jnp.arange(Tlen)
    if ring:
        mask = (j <= pos) | (pos >= Tlen)
    else:
        mask = j <= pos
        if window:
            mask = mask & (pos - j < window)
    scores = jnp.where(mask[None, None, None, None, :], scores, A.NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = A._gqa_out(probs, cache_v, cfg) @ prm["wo"]
    return out, qcache


def make_compressed_decode_step(cfg: ModelConfig):
    """Decode step whose cache leaves are quantized (attn kinds only;
    recurrent states are O(1) and stay raw — DESIGN.md §Arch-applicability)."""
    def decode(params, batch):
        return T.forward_decode(cfg, params, batch["token"], batch["cache"],
                                batch["pos"], batch.get("aux"),
                                kv_codec=True)
    return decode


def init_compressed_cache_spec(cfg: ModelConfig, batch: int, max_len: int):
    """Abstract quantized-cache pytree for the dry-run."""
    raw = jax.eval_shape(lambda: T.init_decode_cache(cfg, batch, max_len))
    return jax.eval_shape(compress_prefill_cache, raw)
