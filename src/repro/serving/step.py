"""Serve-step factories: prefill (full prompt -> cache) and decode (1 tok).

These are the programs the ``decode_*``/``long_*``/``prefill_*`` dry-run
cells lower (NOT train_step, per the assignment).
"""
from __future__ import annotations

from ..models import encdec as E
from ..models import transformer as T
from ..models.config import ModelConfig

__all__ = ["make_prefill_step", "make_decode_step"]


def make_prefill_step(cfg: ModelConfig, max_len: int | None = None):
    if cfg.family == "audio":
        def prefill(params, batch):
            return E.encdec_prefill(cfg, params, batch["frames"],
                                    batch["tokens"], max_len=max_len)
    else:
        def prefill(params, batch):
            return T.forward_prefill(cfg, params, batch["tokens"],
                                     batch.get("aux"), max_len=max_len)
    return prefill


def make_decode_step(cfg: ModelConfig):
    if cfg.family == "audio":
        def decode(params, batch):
            return E.encdec_decode(cfg, params, batch["token"],
                                   batch["cache"], batch["pos"])
    else:
        def decode(params, batch):
            return T.forward_decode(cfg, params, batch["token"],
                                    batch["cache"], batch["pos"],
                                    batch.get("aux"))
    return decode
