from .step import make_prefill_step, make_decode_step  # noqa: F401
from .kvcache import (  # noqa: F401
    quantize_kv, dequantize_kv, make_compressed_decode_step,
)
