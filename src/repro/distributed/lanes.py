"""Simulation mesh: lane/group placement over the visible JAX devices.

The simulator's multi-device story (paper §4.2 multi-GPU, ISSUE 9) has
two tiers, both built on one 1-D ``jax.sharding.Mesh`` whose single axis
is :data:`LANE_AXIS`:

* **lane sharding** — a ``run_batch`` / trajectory run of K lanes splits
  the lanes into contiguous :class:`LaneShard` slices, one per mesh
  device.  Each device runs *its* lane slice of every wave against its
  own partition of the block store (lane keys never collide), so there
  are zero collectives; the only gather is the host-side readout
  (:func:`gather_lanes`).
* **block sharding** — a single large state's SV groups are placed per
  the plan's ``StagePlan.device_slot`` round-robin (:func:`device_slots`
  mirrors it).  Stage boundaries exchange only the *encoded wire* blobs
  through the host store — the engine's exchange ledger
  (``SimStats.exchange_bytes``) accounts every byte whose block changed
  owners.

This module replaces the LLM-training sharding rules that used to live
in :mod:`repro.distributed.sharding` (quarantined — see
``analysis/quarantine.txt``): a state-vector simulator shards *lanes and
blocks*, not parameter pytrees.

Everything here is deliberately explicit-placement (``jax.device_put``
per shard) rather than GSPMD: the Pallas codec kernels run in interpret
mode on CPU hosts and must see plain per-device arrays, and explicit
shards keep the store-key partition — the thing checkpoints and the
exchange ledger reason about — trivially auditable.
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Any, Sequence

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec

__all__ = [
    "LANE_AXIS",
    "LaneShard",
    "activate_mesh",
    "device_slots",
    "gather_lanes",
    "lane_sharding",
    "lane_spec",
    "make_lane_mesh",
    "make_lane_shards",
    "sim_devices",
]

#: the one mesh axis of the simulation tier: independent lanes (batch
#: lanes / noise trajectories), or — for a single-lane run — the
#: round-robin slot dimension its SV groups are placed over
LANE_AXIS = "lanes"


def sim_devices(n_devices: int | None = None,
                devices: Sequence[Any] | None = None) -> list:
    """The device list one simulation mesh is built over.

    ``devices`` (default: ``jax.devices()``) is truncated to
    ``n_devices`` when given; asking for more devices than are visible
    clamps to the visible count with a ``RuntimeWarning`` (on a CPU host
    pass ``XLA_FLAGS=--xla_force_host_platform_device_count=N`` — or
    ``qsim --devices N``, which sets it — to create virtual devices).
    """
    devs = list(devices) if devices is not None else list(jax.devices())
    if not devs:
        raise ValueError("no JAX devices visible")
    if n_devices is None:
        return devs
    if n_devices < 1:
        raise ValueError(f"n_devices={n_devices} must be >= 1")
    if n_devices > len(devs):
        warnings.warn(
            f"requested {n_devices} devices but only {len(devs)} are "
            f"visible; clamping (set XLA_FLAGS="
            f"--xla_force_host_platform_device_count={n_devices} for "
            "virtual host devices)", RuntimeWarning, stacklevel=2)
        return devs
    return devs[:n_devices]


def make_lane_mesh(mesh_shape: tuple[int, ...] | int | None = None,
                   devices: Sequence[Any] | None = None) -> Mesh:
    """Build the 1-D simulation mesh (axis :data:`LANE_AXIS`).

    ``mesh_shape`` is ``(n_devices,)`` (or a bare int); ``None`` spans
    every visible device.  Only 1-D meshes exist in the simulation tier
    — lanes and block slots are both laid out along the one axis.
    """
    if isinstance(mesh_shape, int):
        mesh_shape = (mesh_shape,)
    if mesh_shape is not None:
        if len(mesh_shape) != 1:
            raise ValueError(
                f"simulation meshes are 1-D (lanes axis); got "
                f"mesh_shape={mesh_shape!r}")
        n = int(mesh_shape[0])
    else:
        n = None
    devs = sim_devices(n, devices)
    return Mesh(np.array(devs), (LANE_AXIS,))


def activate_mesh(mesh: Mesh):
    """Context manager activating ``mesh``, across jax versions.

    jax >= 0.6 spells it ``jax.set_mesh(mesh)``; on 0.4/0.5 the Mesh
    object is itself the context manager.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def lane_spec() -> PartitionSpec:
    """PartitionSpec splitting a leading lane axis over the mesh."""
    return PartitionSpec(LANE_AXIS)


def lane_sharding(mesh: Mesh) -> NamedSharding:
    """NamedSharding placing an (L, ...) lane stack over ``mesh``."""
    return NamedSharding(mesh, lane_spec())


@dataclass(frozen=True)
class LaneShard:
    """One device's contiguous lane slice of a batched run.

    ``lanes`` indexes the run's lane axis (and thereby its
    ``lane_offsets`` row block and its store-key range) — the shard's
    partition of the block store is ``[lane.start * n_blocks,
    lane.stop * n_blocks)`` shifted by the chunk base.
    """

    device: Any
    lanes: slice

    @property
    def n_lanes(self) -> int:
        return self.lanes.stop - self.lanes.start


def make_lane_shards(devices: Sequence[Any], n_lanes: int
                     ) -> list[LaneShard]:
    """Contiguous, near-even lane shards over ``devices``.

    The first ``n_lanes % len(devices)`` shards get one extra lane
    (``np.array_split`` semantics); devices with zero lanes are dropped,
    so K < D simply uses K devices.  A ragged split is legal but costs
    one extra jit specialization per distinct shard width — the plan
    verifier surfaces non-divisible lane counts as a warning.
    """
    if n_lanes < 1:
        raise ValueError(f"n_lanes={n_lanes} must be >= 1")
    d = max(1, len(devices))
    base, extra = divmod(n_lanes, d)
    shards = []
    lo = 0
    for i, dev in enumerate(devices):
        width = base + (1 if i < extra else 0)
        if width == 0:
            break
        shards.append(LaneShard(dev, slice(lo, lo + width)))
        lo += width
    return shards


def device_slots(n_groups: int, n_devices: int) -> np.ndarray:
    """Round-robin slot of every group — mirrors
    :meth:`repro.core.plan.StagePlan.device_slot`, so the engine's
    placement and the plan artifact can never drift."""
    return np.arange(n_groups, dtype=np.int64) % max(1, n_devices)


def gather_lanes(parts: Sequence[np.ndarray]) -> np.ndarray:
    """The one readout gather of a lane-sharded batch: concatenate the
    per-shard host results back into lane order (shards are contiguous,
    so a plain concatenate is the inverse of :func:`make_lane_shards`)."""
    arrs = [np.asarray(p) for p in parts]
    return arrs[0] if len(arrs) == 1 else np.concatenate(arrs)
