"""Multi-device placement for the simulator: the lane/block mesh tier.

The live API is :mod:`repro.distributed.lanes` (1-D simulation mesh,
lane shards, group slots, readout gather).  The old LLM-training
sharding rules survive as the quarantined submodule
``repro.distributed.sharding`` — importable, but outside the lint/mypy
surface (see ``analysis/quarantine.txt``).
"""
from .lanes import (  # noqa: F401
    LANE_AXIS, LaneShard, activate_mesh, device_slots, gather_lanes,
    lane_sharding, lane_spec, make_lane_mesh, make_lane_shards,
    sim_devices,
)
