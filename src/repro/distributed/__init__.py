from .sharding import (  # noqa: F401
    TP_AXIS, dp_axes, param_pspecs, batch_pspecs, cache_pspecs,
    named_shardings,
)
