"""Logical-axis sharding rules -> PartitionSpecs for params/batches/caches.

Strategy (DESIGN.md §8): 2-D "FSDP x TP" —

* ``data`` (x ``pod`` when multi-pod) is the FSDP axis: batch is
  data-parallel over it AND every weight matrix shards its non-TP dim over
  it (GSPMD inserts the all-gathers; grads reduce-scatter back).
* ``model`` is the tensor-parallel axis: attention heads / ff / vocab.
* MoE expert dim shards over the FSDP axes (expert parallelism); each
  expert's ff still shards over ``model``.
* Decode KV caches shard batch over FSDP and the *sequence* dim over
  ``model`` (sequence parallelism — the only layout that fits 500k-token
  caches); recurrent states shard their width over ``model``.

Rules are name-based over the param pytree paths, with leading stacked
dims (scan units / layers) padded with None.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from ..models.config import ModelConfig

TP_AXIS = "model"

__all__ = ["TP_AXIS", "activate_mesh", "dp_axes", "param_pspecs",
           "batch_pspecs", "cache_pspecs", "named_shardings"]


def activate_mesh(mesh: Mesh):
    """Context manager activating ``mesh``, across jax versions.

    jax >= 0.6 spells it ``jax.set_mesh(mesh)``; on 0.4/0.5 the Mesh
    object is itself the context manager.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def dp_axes(mesh: Mesh):
    """FSDP/DP axes present in the mesh ('pod' first when multi-pod)."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def _norm_axes(axes):
    """Collapse a 1-tuple mesh-axis set to its element and () to None —
    PartitionSpec equality distinguishes ('data',) from 'data'."""
    if isinstance(axes, tuple):
        if not axes:
            return None
        if len(axes) == 1:
            return axes[0]
    return axes


def _path_names(path) -> list[str]:
    names = []
    for ent in path:
        if hasattr(ent, "key"):
            names.append(str(ent.key))
        elif hasattr(ent, "name"):
            names.append(str(ent.name))
    return names


_REPLICATED = {
    "ln1", "ln2", "lnx", "final_norm", "enc_norm", "q_norm", "k_norm",
    "b_gates", "conv_b", "lam", "router", "step",
}


def _axes_size(axes, mesh: Mesh) -> int:
    size = 1
    for a in (axes if isinstance(axes, tuple) else (axes,)):
        size *= mesh.shape[a]
    return size


def _base_spec(cfg: ModelConfig, names: list[str], name: str, fsdp, tp,
               shape=None, mesh: Mesh = None):
    """Spec for the *unstacked* trailing dims of a leaf."""
    if name in _REPLICATED:
        return P()
    if name == "embed":
        return P(tp, fsdp)              # (V, d): vocab over TP
    if name == "unembed":
        return P(fsdp, tp)
    if name in ("wq", "wk", "wv"):
        return P(fsdp, tp)
    if name == "wo":
        return P(tp, fsdp)
    if name in ("bq", "bk", "bv"):
        return P(tp)
    if name in ("w_in", "w_gate", "w_out"):
        is_moe = (cfg.moe is not None and "mlp" in names
                  and "dense" not in names)
        if is_moe:                       # (E, d, ff) / (E, ff, d)
            # expert-parallel over FSDP when E divides it (arctic 128e);
            # otherwise FSDP the d/ff dims (mixtral 8e < 16 devices —
            # replicated E would cost 18.9 GiB/device of expert weights)
            e_ok = (shape is not None and len(shape) == 3
                    and fsdp and shape[0] % _axes_size(fsdp, mesh) == 0)
            if name != "w_out":
                return P(fsdp, None, tp) if e_ok else P(None, fsdp, tp)
            return P(fsdp, tp, None) if e_ok else P(None, tp, fsdp)
        return P(fsdp, tp) if name != "w_out" else P(tp, fsdp)
    if name in ("w_x", "w_g", "w_up", "w_q", "w_k", "w_v", "w_gates",
                "r_gates", "w_if"):
        return P(fsdp, tp)
    if name in ("w_down", "w_out_proj"):
        return P(tp, fsdp)
    if name == "conv_w":
        return P(None, tp)
    if name in ("w_a", "w_i"):
        return P(None, tp)
    return P()                           # safe default: replicate


def param_pspecs(cfg: ModelConfig, params_tree, mesh: Mesh):
    """PartitionSpec pytree mirroring ``params_tree`` (arrays or SDS)."""
    fsdp = _norm_axes(dp_axes(mesh))
    tp = TP_AXIS if TP_AXIS in mesh.axis_names else None

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        # strip stacked leading dims before shape-aware rules
        base_probe = _base_spec(cfg, names, name, fsdp, tp)
        trail = leaf.shape[leaf.ndim - len(base_probe):] \
            if leaf.ndim >= len(base_probe) else leaf.shape
        base = _base_spec(cfg, names, name, fsdp, tp, shape=trail, mesh=mesh)
        extra = leaf.ndim - len(base)
        if extra < 0:                    # scalar against P() etc.
            return P()
        full = P(*([None] * extra + list(base)))
        # drop axes that don't divide the dim (e.g. tiny reduced configs)
        fixed = []
        for dim, ax in zip(leaf.shape, full):
            if ax is None:
                fixed.append(None)
                continue
            axes = ax if isinstance(ax, tuple) else (ax,)
            size = 1
            for a in axes:
                size *= mesh.shape[a]
            fixed.append(ax if dim % size == 0 else None)
        return P(*fixed)

    return jax.tree_util.tree_map_with_path(spec_for, params_tree)


def _dp_if_divisible(b: int, mesh: Mesh):
    fsdp = dp_axes(mesh)
    size = 1
    for a in fsdp:
        size *= mesh.shape[a]
    return _norm_axes(fsdp) if (b % size == 0 and b >= size) else None


def cache_pspecs(cfg: ModelConfig, cache_tree, mesh: Mesh, batch: int):
    """Decode cache/state sharding: batch over FSDP, seq/width over TP.

    ``batch`` disambiguates the batch dim (caches may carry a leading
    stacked-layer dim).
    """
    tp = TP_AXIS if TP_AXIS in mesh.axis_names else None
    tp_size = mesh.shape[tp] if tp else 1

    def divis(dim: int) -> bool:
        return bool(tp) and dim % tp_size == 0 and dim >= tp_size

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1] if names else ""
        nd = leaf.ndim
        shape = leaf.shape
        spec = [None] * nd
        # locate the batch dim (0 or 1 depending on stacking)
        bidx = None
        for i in range(min(2, nd)):
            if shape[i] == batch and (i == 0 or shape[0] != batch):
                bidx = i
                break
        if bidx is None and nd >= 2 and shape[0] == batch:
            bidx = 0
        if bidx is not None:
            spec[bidx] = _dp_if_divisible(batch, mesh)
        kv_names = ("k", "v", "xk", "xv",
                    "codes_k", "codes_v", "signs_k", "signs_v",
                    "scale_k", "scale_v")
        if name in kv_names and nd >= 4 and bidx is not None:
            t = shape[bidx + 1]          # sequence-parallel KV (raw OR
            if divis(t):                 # pwrel-compressed leaves)
                spec[bidx + 1] = tp
        elif name in ("h", "c", "n", "m", "conv") and nd >= 2:
            if divis(shape[-1]):
                spec[-1] = tp            # state width over TP
        # "C" (hd x hd matrix memory) stays replicated over TP
        return P(*spec)

    return jax.tree_util.tree_map_with_path(spec_for, cache_tree)


def batch_pspecs(cfg: ModelConfig, specs: dict, mesh: Mesh):
    """Shardings for an input_specs dict (tokens/aux/frames/token/cache/pos)."""
    batch = next(v.shape[0] for k, v in specs.items()
                 if k in ("tokens", "token", "frames"))
    out = {}
    for k, v in specs.items():
        if k == "cache":
            out[k] = cache_pspecs(cfg, v, mesh, batch)
        elif k == "pos":
            out[k] = P()
        else:
            dp = _dp_if_divisible(v.shape[0], mesh)
            out[k] = P(*([dp] + [None] * (v.ndim - 1)))
    return out


def named_shardings(tree_specs, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), tree_specs,
        is_leaf=lambda x: isinstance(x, P))
